//! Experiment E4 — paper Fig. 7: the comprehensive double-precision L3
//! BLAS benchmark on (simulated) Everest. 6 routines × 1–3 GPUs ×
//! a matrix-size sweep, BLASX vs the four baseline schedulers.
//!
//! Default grid subsamples the paper's 39 sizes; set BLASX_BENCH_FULL=1
//! for the full 1024..39936 step-1024 sweep.
//!
//! Expected shape (paper): BLASX tops every panel; PaRSEC close on
//! DGEMM but dies at N>22528 (in-core); MAGMA partial coverage;
//! SuperMatrix far below; near-linear BLASX multi-GPU speedup past
//! N≈15000.

use blasx::api::types::Routine;
use blasx::api::Dtype;
use blasx::bench::{fmt_gf, print_table, size_grid, write_json};
use blasx::coordinator::{run_sim, square_workload, Policy, RunConfig};
use blasx::sim::everest;
use blasx::util::json::Json;

/// The paper benches these policies per routine (Table III "N/A"
/// pattern: PaRSEC published only GEMM; MAGMA lacks multi-GPU SYRK/
/// TRMM/SYMM).
fn policies_for(routine: Routine) -> Vec<Policy> {
    let mut ps = vec![Policy::Blasx, Policy::CublasXt, Policy::SuperMatrix];
    match routine {
        Routine::Gemm => ps.push(Policy::Parsec),
        Routine::Trsm | Routine::Syr2k => ps.push(Policy::Magma),
        _ => {}
    }
    ps
}

fn main() {
    let t = 1024;
    let sizes = size_grid();
    let mut json = Json::obj();

    for routine in Routine::ALL {
        let mut routine_json = Json::obj();
        for gpus in 1..=3usize {
            let machine = everest(gpus);
            let mut rows = Vec::new();
            let mut series: Vec<(Policy, Vec<Json>)> =
                policies_for(routine).into_iter().map(|p| (p, Vec::new())).collect();
            for &n in &sizes {
                let w = square_workload(routine, n, t, Dtype::F64);
                let flops = w.total_flops();
                let mut row = vec![n.to_string()];
                for (policy, ser) in series.iter_mut() {
                    let cfg = RunConfig { t, policy: *policy, ..Default::default() };
                    let rep = run_sim(&cfg, &machine, &w);
                    row.push(fmt_gf(rep.feasible, rep.gflops(flops)));
                    ser.push(Json::Num(if rep.feasible { rep.gflops(flops) } else { -1.0 }));
                }
                rows.push(row);
            }
            let mut header = vec!["N"];
            let names: Vec<&str> = series.iter().map(|(p, _)| p.name()).collect();
            header.extend(names.iter());
            print_table(
                &format!("Fig 7: {} on {gpus} GPU(s), GFLOPS", routine.dname()),
                &header,
                &rows,
            );
            let mut g = Json::obj();
            for (p, ser) in series {
                g.set(p.name(), Json::Arr(ser));
            }
            g.set("sizes", Json::Arr(sizes.iter().map(|&x| Json::Num(x as f64)).collect()));
            routine_json.set(&format!("gpus{gpus}"), g);
        }
        json.set(routine.name(), routine_json);
    }
    write_json("fig7_routines", &json);

    println!("\npaper reference points (Everest): single-GPU BLASX DGEMM ≈ 92.7% of");
    println!("in-core peak (1.2 TF → ~1110 GF); 3-GPU DSYR2K speedup 2.91x; PaRSEC");
    println!("infeasible for N > 22528 (12 GB); cuBLAS-XT ~25% below BLASX on average.");
}
