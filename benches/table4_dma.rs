//! Experiment E6 — paper Table IV: average DMA-engine throughput,
//! bidirectional host↔GPU vs GPU↔GPU, measured as bytes moved per lane
//! busy-second during a BLASX DSYR2K run on simulated Everest (the P2P
//! pair GPU1/GPU2 gets exercised by L2-cache fetches).

use blasx::api::types::Routine;
use blasx::api::Dtype;
use blasx::bench::{print_table, write_json};
use blasx::coordinator::{run_sim, square_workload, Policy, RunConfig};
use blasx::sim::everest;
use blasx::util::json::Json;

fn main() {
    let t = 1024;
    let n = 16384;
    let machine = everest(3);
    let mut rows = Vec::new();
    let mut json = Json::obj();
    for routine in [Routine::Gemm, Routine::Syr2k, Routine::Symm] {
        let w = square_workload(routine, n, t, Dtype::F64);
        let cfg = RunConfig { t, policy: Policy::Blasx, ..Default::default() };
        let rep = run_sim(&cfg, &machine, &w);
        let (hd, pp) = rep.dma_throughput;
        rows.push(vec![
            w.routine.dname(),
            format!("{:.2} GB/s", hd / 1e9),
            if pp > 0.0 { format!("{:.2} GB/s", pp / 1e9) } else { "-".into() },
        ]);
        let mut o = Json::obj();
        o.set("hd_gbps", Json::Num(hd / 1e9));
        o.set("p2p_gbps", Json::Num(pp / 1e9));
        json.set(w.routine.name(), o);
    }
    print_table(
        "Table IV: measured DMA throughput (N=16384, Everest, BLASX)",
        &["routine", "bidir host<->GPU", "GPU<->GPU (P2P)"],
        &rows,
    );
    write_json("table4_dma", &json);
    println!("\npaper reference: 6.54 GB/s host<->GPU, 7.8 GB/s GPU<->GPU —");
    println!("P2P ≈ 19% faster, which is what justifies the L2 tile cache (§IV-B).");
}
