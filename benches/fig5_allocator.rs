//! Experiment E3 — paper Fig. 5: performance degeneration under
//! cudaMalloc/cudaFree vs the BLASX_Malloc fast heap (§IV-E, Fig. 6).
//!
//! Two measurements:
//! 1. Simulated: DGEMM size sweep on 1 GPU with the allocator strategy
//!    switched between the CudaMalloc cost model (per-call latency +
//!    implicit sync) and the FastHeap — reproducing the Fig. 5 gap.
//! 2. Real: wall-clock microbenchmark of the actual FastHeap
//!    (alloc/free/coalesce) against raw Vec allocation for tile-sized
//!    blocks, demonstrating the amortization on this host.

use blasx::api::types::Routine;
use blasx::api::Dtype;
use blasx::bench::{print_table, write_json};
use blasx::coordinator::{run_sim, square_workload, Policy, RunConfig};
use blasx::mem::{AllocStrategy, FastHeap};
use blasx::sim::everest;
use blasx::util::json::Json;
use blasx::util::prng::Prng;

fn main() {
    let t = 1024;
    let machine = everest(1);
    let mut rows = Vec::new();
    let mut json = Json::obj();
    let mut fast_arr = Vec::new();
    let mut slow_arr = Vec::new();
    let sizes: Vec<usize> = vec![2048, 4096, 8192, 12288, 16384, 20480];
    for &n in &sizes {
        let w = square_workload(Routine::Gemm, n, t, Dtype::F64);
        let flops = w.total_flops();
        let run = |alloc: AllocStrategy| {
            // 1.5 GB cache on both arms: past N≈8192 the working set
            // overflows and every move-in allocates — the on-demand
            // allocation regime the paper's Fig. 5 measures.
            let cfg = RunConfig {
                t,
                policy: Policy::Blasx,
                alloc,
                vram_override: Some(192 * t * t * 8),
                ..Default::default()
            };
            run_sim(&cfg, &machine, &w)
        };
        let fast = run(AllocStrategy::FastHeap);
        let slow = run(AllocStrategy::CudaNative);
        rows.push(vec![
            n.to_string(),
            format!("{:.0}", fast.gflops(flops)),
            format!("{:.0}", slow.gflops(flops)),
            format!("{:.3}s", slow.alloc_cost),
        ]);
        fast_arr.push(Json::Num(fast.gflops(flops)));
        slow_arr.push(Json::Num(slow.gflops(flops)));
    }
    json.set("sizes", Json::Arr(sizes.iter().map(|&x| Json::Num(x as f64)).collect()));
    json.set("fastheap_gflops", Json::Arr(fast_arr));
    json.set("cudamalloc_gflops", Json::Arr(slow_arr));
    print_table(
        "Fig 5 (simulated): DGEMM with FastHeap vs cudaMalloc cost model, 1 GPU",
        &["N", "FastHeap GF", "cudaMalloc GF", "alloc cost"],
        &rows,
    );

    // --- real microbenchmark of the heap itself
    let tile = t * t * 8;
    let capacity = 512 * tile;
    let iters = 200_000;
    let mut heap = FastHeap::new(capacity);
    let mut prng = Prng::new(1);
    let mut live: Vec<blasx::mem::Offset> = Vec::new();
    let t0 = std::time::Instant::now();
    for _ in 0..iters {
        if !live.is_empty() && prng.chance(0.5) {
            let i = prng.below(live.len());
            heap.free(live.swap_remove(i));
        } else if let Some(off) = heap.alloc(tile) {
            live.push(off);
        } else {
            let i = prng.below(live.len());
            heap.free(live.swap_remove(i));
        }
    }
    let heap_ns = t0.elapsed().as_nanos() as f64 / iters as f64;

    let mut sys_live: Vec<Vec<u8>> = Vec::new();
    let mut prng = Prng::new(1);
    let t0 = std::time::Instant::now();
    for _ in 0..iters {
        if !sys_live.is_empty() && prng.chance(0.5) {
            let i = prng.below(sys_live.len());
            drop(sys_live.swap_remove(i));
        } else {
            // touch one byte per page-ish stride so the allocation is real
            let mut v = vec![0u8; tile];
            v[tile / 2] = 1;
            sys_live.push(v);
            if sys_live.len() > 512 {
                let i = prng.below(sys_live.len());
                drop(sys_live.swap_remove(i));
            }
        }
    }
    let sys_ns = t0.elapsed().as_nanos() as f64 / iters as f64;

    println!("\nreal microbench (8 MiB tile blocks, {iters} ops):");
    println!("  FastHeap alloc/free: {heap_ns:.0} ns/op");
    println!("  system allocator   : {sys_ns:.0} ns/op   ({:.1}x)", sys_ns / heap_ns);
    json.set("fastheap_ns_per_op", Json::Num(heap_ns));
    json.set("system_ns_per_op", Json::Num(sys_ns));
    write_json("fig5_allocator", &json);
    println!("\npaper shape: naive per-tile cudaMalloc/cudaFree collapses GFLOPS as N");
    println!("grows; the preallocated heap holds the curve flat (Fig. 5).");
}
