//! Experiment E9 — paper Fig. 9: the CPU contribution to DGEMM under
//! BLASX's demand-driven CPU worker vs cuBLAS-XT's fixed CPU-ratio
//! split, on simulated Makalu at N=16384.
//!
//! cuBLAS-XT asks the user for a *static* CPU ratio r: r·tasks go to the
//! host BLAS regardless of actual speeds; too large a ratio overloads
//! the CPU at the GPUs' expense (the downtrend in Fig. 9). BLASX assigns
//! tasks to the CPU worker by demand, so its contribution is a flat
//! line the user never tunes.

use blasx::api::types::Routine;
use blasx::api::Dtype;
use blasx::bench::{print_table, write_json};
use blasx::coordinator::{run_sim, square_workload, Policy, RunConfig};
use blasx::sim::makalu;
use blasx::util::json::Json;

fn main() {
    let t = 1024;
    let n = 16384;
    let machine = makalu(4);
    let w = square_workload(Routine::Gemm, n, t, Dtype::F64);
    let flops = w.total_flops();

    // GPU-only and demand-driven-CPU BLASX runs
    let base = {
        let cfg = RunConfig { t, policy: Policy::Blasx, use_cpu: false, ..Default::default() };
        run_sim(&cfg, &machine, &w)
    };
    let with_cpu = {
        let cfg = RunConfig { t, policy: Policy::Blasx, use_cpu: true, ..Default::default() };
        run_sim(&cfg, &machine, &w)
    };
    let blasx_contrib = with_cpu.gflops(flops) - base.gflops(flops);

    // cuBLAS-XT with a fixed CPU ratio r: r·tasks run on the host at the
    // host rate, concurrently with the XT GPU schedule of the rest;
    // makespan = max(cpu_time, gpu_time(1-r share)).
    let cpu_rate = machine.cpu.as_ref().unwrap().dp_gflops * 1e9;
    let xt_gpu_only = {
        let cfg = RunConfig { t, policy: Policy::CublasXt, ..Default::default() };
        run_sim(&cfg, &machine, &w)
    };
    let mut rows = Vec::new();
    let mut json = Json::obj();
    let mut xt_arr = Vec::new();
    for r_pct in [0usize, 5, 10, 15, 20, 25] {
        let r = r_pct as f64 / 100.0;
        let cpu_secs = flops * r / cpu_rate;
        let gpu_secs = xt_gpu_only.makespan * (1.0 - r);
        let total = cpu_secs.max(gpu_secs);
        let gf = flops / total / 1e9;
        let contrib = gf - xt_gpu_only.gflops(flops);
        rows.push(vec![
            format!("{r_pct}%"),
            format!("{gf:.0}"),
            format!("{contrib:+.0}"),
            format!("{blasx_contrib:+.0}"),
        ]);
        xt_arr.push(Json::Num(contrib));
    }
    json.set("xt_cpu_contrib_by_ratio", Json::Arr(xt_arr));
    json.set("blasx_cpu_contrib", Json::Num(blasx_contrib));
    print_table(
        "Fig 9: CPU contribution to DGEMM N=16384 (Makalu)",
        &["XT cpu-ratio", "XT GFLOPS", "XT contrib", "BLASX contrib (flat)"],
        &rows,
    );
    write_json("fig9_cpu_ratio", &json);
    println!("\npaper shape: BLASX's demand-driven CPU contribution ≈ 78% above the");
    println!("best static ratio; past the optimum the static split *hurts* (downtrend).");
}
