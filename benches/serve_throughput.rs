//! Multi-tenant serving throughput: jobs/sec and worker-idle fraction
//! as the client count grows — the workload the serve subsystem
//! (`rust/src/serve/`) exists for.
//!
//! Each configuration runs `CLIENTS` threads over ONE shared
//! persistent `Context`, every client issuing `JOBS_PER_CLIENT`
//! independent same-size DGEMMs on private buffers (disjoint ranges ⇒
//! the scheduler admits them concurrently and interleaves rounds under
//! flop-weighted fairness). Reported per client count:
//!
//! - **jobs/s** — aggregate completed calls per second;
//! - **busy/idle fraction** — resident-worker nanoseconds inside
//!   scheduler rounds vs wall × device count (the under-utilization
//!   the multi-tenant table removes: with 1 client the workers idle
//!   between submit gaps, with 4/16 they stay fed);
//! - **latency percentiles** — per-job end-to-end and queue-wait
//!   p50/p95/p99 pulled from the runtime's own metrics registry (the
//!   same histograms `blasx serve` and `--metrics-out` report), not
//!   bench-side timers;
//! - **speedup** — jobs/s relative to the 1-client row.
//!
//! The overlap acceptance check of the serve PR also lands here: with
//! 4 clients issuing one identical DGEMM each, total wall time must be
//! measurably below 4× the warm single-call time. Results print as a
//! table and land in `bench_out/BENCH_serve.json` plus the repo-root
//! `BENCH_serve.json` (committed snapshot — regenerate on a host with
//! cargo; the committed numbers are from the authoring container).

use blasx::api::types::Trans;
use blasx::api::{self, Context};
use blasx::bench::{print_table, write_json};
use blasx::util::json::Json;
use blasx::util::prng::Prng;
use std::time::Instant;

const N: usize = 256;
const T: usize = 64;
const DEVICES: usize = 2;
const JOBS_PER_CLIENT: usize = 6;
const CLIENT_COUNTS: [usize; 3] = [1, 4, 16];

fn ctx() -> Context {
    Context::new(DEVICES).with_arena(32 << 20).with_tile(T)
}

struct Row {
    clients: usize,
    jobs: usize,
    wall_ms: f64,
    jobs_per_sec: f64,
    busy_frac: f64,
    /// End-to-end latency percentiles (ms) from the runtime's metrics
    /// registry (per-routine histogram), not bench-side timers.
    p50_ms: f64,
    p95_ms: f64,
    p99_ms: f64,
    queue_p95_ms: f64,
}

/// One client's buffers (private ⇒ jobs are admission-independent).
struct Client {
    a: Vec<f64>,
    b: Vec<f64>,
    c: Vec<f64>,
}

fn client(seed: u64) -> Client {
    let mut p = Prng::new(seed);
    let mut a = vec![0.0; N * N];
    let mut b = vec![0.0; N * N];
    p.fill_f64(&mut a, -1.0, 1.0);
    p.fill_f64(&mut b, -1.0, 1.0);
    Client { a, b, c: vec![0.0; N * N] }
}

fn run_clients(ctx: &Context, clients: &mut [Client], jobs_each: usize) -> f64 {
    let start = Instant::now();
    std::thread::scope(|scope| {
        for cl in clients.iter_mut() {
            let ctx = ctx.clone();
            scope.spawn(move || {
                for _ in 0..jobs_each {
                    api::dgemm(
                        &ctx, Trans::No, Trans::No, N, N, N, 1.0, &cl.a, N, &cl.b, N, 0.0,
                        &mut cl.c, N,
                    )
                    .expect("serve bench dgemm");
                }
            });
        }
    });
    start.elapsed().as_secs_f64()
}

fn bench_clients(n_clients: usize, rows: &mut Vec<Row>) {
    let ctx = ctx();
    let mut clients: Vec<Client> = (0..n_clients).map(|i| client(7 + i as u64)).collect();
    // Warm: boot the runtime and stage every client's tiles once.
    let _ = run_clients(&ctx, &mut clients, 1);
    let busy0: u64 = ctx.runtime_busy_nanos().iter().sum();
    let wall = run_clients(&ctx, &mut clients, JOBS_PER_CLIENT);
    let busy1: u64 = ctx.runtime_busy_nanos().iter().sum();
    let jobs = n_clients * JOBS_PER_CLIENT;
    let busy_frac = ((busy1.saturating_sub(busy0)) as f64 / 1e9) / (wall * DEVICES as f64);
    let snap = ctx.snapshot_metrics();
    let q = |field: &str, p: &str| {
        snap.as_ref()
            .and_then(|m| m.get("per_routine"))
            .and_then(|r| r.get("gemm"))
            .and_then(|g| g.get(field))
            .and_then(|h| h.get(p))
            .and_then(Json::as_f64)
            .unwrap_or(0.0)
    };
    rows.push(Row {
        clients: n_clients,
        jobs,
        wall_ms: wall * 1e3,
        jobs_per_sec: jobs as f64 / wall,
        busy_frac: busy_frac.min(1.0),
        p50_ms: q("end_to_end_ms", "p50"),
        p95_ms: q("end_to_end_ms", "p95"),
        p99_ms: q("end_to_end_ms", "p99"),
        queue_p95_ms: q("queue_wait_ms", "p95"),
    });
}

/// The serve-PR acceptance probe: 4 concurrent clients, one warm
/// same-size DGEMM each, against 4× the warm single-call wall time.
fn overlap_probe() -> (f64, f64, f64) {
    let ctx = ctx();
    let mut clients: Vec<Client> = (0..4).map(|i| client(100 + i as u64)).collect();
    let _ = run_clients(&ctx, &mut clients, 1); // warm all four
    // warm single-call time (best of 5)
    let single = (0..5)
        .map(|_| {
            let one = &mut clients[0];
            let t0 = Instant::now();
            api::dgemm(
                &ctx, Trans::No, Trans::No, N, N, N, 1.0, &one.a, N, &one.b, N, 0.0, &mut one.c,
                N,
            )
            .expect("probe dgemm");
            t0.elapsed().as_secs_f64()
        })
        .fold(f64::INFINITY, f64::min);
    // 4 clients, 1 job each, concurrently (best of 5)
    let four = (0..5)
        .map(|_| run_clients(&ctx, &mut clients, 1))
        .fold(f64::INFINITY, f64::min);
    (single * 1e3, four * 1e3, four / (4.0 * single))
}

fn main() {
    let mut rows = Vec::new();
    for &c in &CLIENT_COUNTS {
        bench_clients(c, &mut rows);
    }
    let base = rows[0].jobs_per_sec;
    let table: Vec<Vec<String>> = rows
        .iter()
        .map(|r| {
            vec![
                r.clients.to_string(),
                r.jobs.to_string(),
                format!("{:.1}", r.wall_ms),
                format!("{:.1}", r.jobs_per_sec),
                format!("{:.2}", r.busy_frac),
                format!("{:.2}", 1.0 - r.busy_frac),
                format!("{:.2}/{:.2}/{:.2}", r.p50_ms, r.p95_ms, r.p99_ms),
                format!("{:.2}", r.queue_p95_ms),
                format!("{:.2}x", r.jobs_per_sec / base),
            ]
        })
        .collect();
    print_table(
        "serve throughput: concurrent clients over one resident runtime",
        &["clients", "jobs", "wall ms", "jobs/s", "busy", "idle", "lat p50/p95/p99 ms", "queue p95 ms", "speedup"],
        &table,
    );

    let (single_ms, four_ms, ratio) = overlap_probe();
    println!(
        "\noverlap probe: warm single call {single_ms:.2} ms, 4 concurrent clients {four_ms:.2} ms \
         => {ratio:.2} of 4x serial (< 1.0 means the scheduler overlaps independent jobs)"
    );

    let mut json = Json::obj();
    json.set("bench", Json::Str("serve_throughput".into()));
    json.set("n", Json::Num(N as f64));
    json.set("tile", Json::Num(T as f64));
    json.set("devices", Json::Num(DEVICES as f64));
    json.set("jobs_per_client", Json::Num(JOBS_PER_CLIENT as f64));
    let mut arr = Vec::new();
    for r in &rows {
        let mut o = Json::obj();
        o.set("clients", Json::Num(r.clients as f64));
        o.set("jobs", Json::Num(r.jobs as f64));
        o.set("wall_ms", Json::Num(r.wall_ms));
        o.set("jobs_per_sec", Json::Num(r.jobs_per_sec));
        o.set("worker_busy_fraction", Json::Num(r.busy_frac));
        o.set("worker_idle_fraction", Json::Num(1.0 - r.busy_frac));
        o.set("latency_p50_ms", Json::Num(r.p50_ms));
        o.set("latency_p95_ms", Json::Num(r.p95_ms));
        o.set("latency_p99_ms", Json::Num(r.p99_ms));
        o.set("queue_wait_p95_ms", Json::Num(r.queue_p95_ms));
        arr.push(o);
    }
    json.set("results", Json::Arr(arr));
    let mut probe = Json::obj();
    probe.set("warm_single_call_ms", Json::Num(single_ms));
    probe.set("four_clients_wall_ms", Json::Num(four_ms));
    probe.set("ratio_vs_4x_serial", Json::Num(ratio));
    json.set("overlap_probe", probe);
    write_json("BENCH_serve", &json);
    let root = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("../BENCH_serve.json");
    match std::fs::write(&root, json.to_string_pretty()) {
        Ok(()) => println!("[bench] wrote {}", root.display()),
        Err(e) => eprintln!("[bench] cannot write {}: {e}", root.display()),
    }
}
