//! Experiment E8/E13 — paper Fig. 8: per-GPU execution-time dissection
//! (COMPT / COMM / OTHER) at N=16384 on Everest, plus the load-balance
//! gap (elapsed difference between fastest and slowest GPU).
//!
//! Paper headlines: BLASX COMM ≈ 0.0575 s vs cuBLAS-XT 0.4917 s;
//! fastest-to-slowest gap 0.0391 s (BLASX) vs 0.2961 s (cuBLAS-XT).

use blasx::api::types::Routine;
use blasx::api::Dtype;
use blasx::bench::{print_table, write_json};
use blasx::coordinator::{run_sim, square_workload, Policy, RunConfig};
use blasx::sim::everest;
use blasx::trace::{all_profiles, balance_gap};
use blasx::util::json::Json;

fn main() {
    let t = 1024;
    let n = 16384;
    let machine = everest(3);
    let mut json = Json::obj();

    for routine in Routine::ALL {
        let w = square_workload(routine, n, t, Dtype::F64);
        let mut rows = Vec::new();
        let mut o = Json::obj();
        for policy in [Policy::Blasx, Policy::CublasXt, Policy::Magma, Policy::Parsec] {
            let cfg = RunConfig { t, policy, ..Default::default() };
            let rep = run_sim(&cfg, &machine, &w);
            if !rep.feasible {
                rows.push(vec![policy.name().into(), "N/A".into(), "".into(), "".into(), "".into()]);
                continue;
            }
            let profs = all_profiles(&rep.trace);
            let gap = balance_gap(&rep.trace);
            let mut parr = Vec::new();
            for (d, p) in profs.iter().take(3).enumerate() {
                rows.push(vec![
                    if d == 0 { policy.name().into() } else { String::new() },
                    format!("gpu{d}"),
                    format!("{:.4}", p.compt),
                    format!("{:.4}", p.comm),
                    format!("{:.4}", p.other),
                ]);
                let mut dv = Json::obj();
                dv.set("compt", Json::Num(p.compt));
                dv.set("comm", Json::Num(p.comm));
                dv.set("other", Json::Num(p.other));
                parr.push(dv);
            }
            rows.push(vec![String::new(), "gap".into(), format!("{gap:.4}s"), String::new(), String::new()]);
            let mut pol = Json::obj();
            pol.set("devices", Json::Arr(parr));
            pol.set("balance_gap", Json::Num(gap));
            o.set(policy.name(), pol);
        }
        print_table(
            &format!("Fig 8: {} execution profile at N=16384 (seconds)", routine.dname()),
            &["policy", "gpu", "COMPT", "COMM", "OTHER"],
            &rows,
        );
        json.set(routine.name(), o);
    }
    write_json("fig8_profile", &json);
    println!("\npaper shape: BLASX has the least non-computation time and the");
    println!("smallest fastest-vs-slowest gap; static schedulers (MAGMA/XT) gap 5-20x wider.");
}
