//! Experiment E11 — paper Table VI: application-level speedups from
//! pointing BLAS-offloading workloads at BLASX.
//!
//! The paper measures MATLAB routines on a 3-GPU server against MATLAB's
//! reference CPU BLAS. This testbed has one CPU core, so the *real-mode*
//! threaded runtime cannot show parallel speedup (see
//! examples/matlab_workloads.rs for real numerics); the speedup shape is
//! reproduced on the simulated Everest: app time = Σ of its BLAS calls'
//! simulated makespans, CPU baseline = the same flops at the host-BLAS
//! rate.

use blasx::api::types::Routine;
use blasx::api::Dtype;
use blasx::bench::{print_table, write_json};
use blasx::coordinator::{run_sim, square_workload, Policy, RunConfig};
use blasx::sim::everest;
use blasx::util::json::Json;

/// One app = a bag of L3-BLAS calls (routine, n, dtype, times-called).
struct App {
    name: &'static str,
    calls: Vec<(Routine, usize, Dtype, usize)>,
    paper_speedup: f64,
}

fn main() {
    let t = 1024;
    let machine = everest(3);
    // Everest's CPU complex: 2x Xeon E5 4655 v3 (28 cores) — a realistic
    // multithreaded OpenBLAS sustains ~500 DP / ~1000 SP GFLOPS, which is
    // the MATLAB baseline the paper's Table VI divides by.
    let cpu_dp = 500e9;
    let cpu_sp = 1000e9;

    let apps = vec![
        App {
            name: "A*B (single)",
            calls: vec![(Routine::Gemm, 16384, Dtype::F32, 1)],
            paper_speedup: 12.75,
        },
        App {
            name: "A*B (double)",
            calls: vec![(Routine::Gemm, 16384, Dtype::F64, 1)],
            paper_speedup: 8.27,
        },
        App {
            // nnmf: per iteration ~6 GEMMs of rank-r shapes; dominated by
            // the two m×n×r products — model 4 iterations at N=8192
            name: "nnmf",
            calls: vec![(Routine::Gemm, 8192, Dtype::F64, 6)],
            paper_speedup: 6.72,
        },
        App {
            // rotatefactors (varimax): repeated tall GEMMs + small SVDs
            name: "rotatefactors",
            calls: vec![(Routine::Gemm, 8192, Dtype::F64, 4), (Routine::Syrk, 8192, Dtype::F64, 2)],
            paper_speedup: 5.83,
        },
        App {
            // lsqlin: normal equations (SYRK) + triangular solves
            name: "lsqlin",
            calls: vec![
                (Routine::Syrk, 8192, Dtype::F64, 1),
                (Routine::Trsm, 8192, Dtype::F64, 2),
                (Routine::Gemm, 8192, Dtype::F64, 1),
            ],
            paper_speedup: 3.09,
        },
    ];

    let mut rows = Vec::new();
    let mut json = Json::obj();
    for app in apps {
        let mut blasx_secs = 0.0;
        let mut cpu_secs = 0.0;
        for &(routine, n, dtype, times) in &app.calls {
            let w = square_workload(routine, n, t, dtype);
            let cfg = RunConfig { t, policy: Policy::Blasx, ..Default::default() };
            let rep = run_sim(&cfg, &machine, &w);
            blasx_secs += rep.makespan * times as f64;
            let rate = if dtype == Dtype::F32 { cpu_sp } else { cpu_dp };
            cpu_secs += w.total_flops() / rate * times as f64;
        }
        let speedup = cpu_secs / blasx_secs;
        rows.push(vec![
            app.name.to_string(),
            format!("{cpu_secs:.2}s"),
            format!("{blasx_secs:.2}s"),
            format!("{speedup:.2}x"),
            format!("{:.2}x", app.paper_speedup),
        ]);
        json.set(app.name, Json::Num(speedup));
    }
    print_table(
        "Table VI: app-level speedup, BLASX (3-GPU sim Everest) vs host BLAS",
        &["app", "cpu BLAS", "BLASX", "speedup", "paper"],
        &rows,
    );
    write_json("table6_apps", &json);
    println!("\nShape check: double-digit for SP GEMM, mid-single-digit for DP apps,");
    println!("smallest for solver-bound lsqlin — same ordering as the paper's column.");
}
