//! Persistent-runtime call overhead: cold (boot + teardown per call)
//! vs warm (resident workers + cross-call tile-cache reuse) latency
//! for small repeated DGEMMs — the serving-workload regime the
//! resident runtime exists for.
//!
//! Four configurations per size:
//! - `one-shot`  — `Context::with_persistent(false)`: fresh scoped
//!   threads, arenas and caches every call (the pre-runtime engine);
//! - `cold-boot` — a brand-new persistent `Context` per call: measures
//!   runtime boot + first-touch transfers;
//! - `warm`      — one persistent `Context`, repeated calls: resident
//!   workers, warm tile caches (zero host reads after call 1);
//! - `warm-traced` — warm calls with the span recorder enabled: the
//!   observability layer's tracing tax (the `warm` row doubles as the
//!   disabled-recorder gate — recording off is the default).
//!
//! Results print as a table and land in `bench_out/BENCH_runtime.json`
//! plus the repo-root `BENCH_runtime.json` (committed snapshot —
//! regenerate on a host with cargo; the committed numbers are from the
//! authoring container).

use blasx::api::types::Trans;
use blasx::api::{self, Context};
use blasx::bench::{print_table, write_json};
use blasx::util::json::Json;
use blasx::util::prng::Prng;
use std::time::Instant;

const T: usize = 64;
const REPS: usize = 8;

struct Row {
    n: usize,
    mode: &'static str,
    best_ms: f64,
    mean_ms: f64,
    warm_host_reads: usize,
}

fn ctx() -> Context {
    Context::new(2).with_arena(32 << 20).with_tile(T)
}

fn time_call(ctx: &Context, n: usize, a: &[f64], b: &[f64], c: &mut [f64]) -> (f64, usize) {
    let t0 = Instant::now();
    let rep = api::dgemm(ctx, Trans::No, Trans::No, n, n, n, 1.0, a, n, b, n, 0.0, c, n)
        .expect("bench dgemm");
    (t0.elapsed().as_secs_f64() * 1e3, rep.transfers.total_host_reads())
}

fn bench_size(n: usize, rows: &mut Vec<Row>) {
    let mut p = Prng::new(2026);
    let mut a = vec![0.0; n * n];
    let mut b = vec![0.0; n * n];
    let mut c = vec![0.0; n * n];
    p.fill_f64(&mut a, -1.0, 1.0);
    p.fill_f64(&mut b, -1.0, 1.0);

    let mut record = |mode: &'static str, samples: &[(f64, usize)]| {
        let best = samples.iter().map(|s| s.0).fold(f64::INFINITY, f64::min);
        let mean = samples.iter().map(|s| s.0).sum::<f64>() / samples.len() as f64;
        let last_reads = samples.last().map_or(0, |s| s.1);
        rows.push(Row { n, mode, best_ms: best, mean_ms: mean, warm_host_reads: last_reads });
    };

    // one-shot engine per call
    let one_shot = ctx().with_persistent(false);
    let samples: Vec<_> = (0..REPS).map(|_| time_call(&one_shot, n, &a, &b, &mut c)).collect();
    record("one-shot", &samples);

    // cold persistent boot per call
    let samples: Vec<_> = (0..REPS)
        .map(|_| {
            let cold = ctx();
            time_call(&cold, n, &a, &b, &mut c)
        })
        .collect();
    record("cold-boot", &samples);

    // warm resident runtime
    let warm = ctx();
    let _ = time_call(&warm, n, &a, &b, &mut c); // boot + first touch
    let samples: Vec<_> = (0..REPS).map(|_| time_call(&warm, n, &a, &b, &mut c)).collect();
    assert_eq!(samples.last().unwrap().1, 0, "warm calls must be transfer-free");
    record("warm", &samples);

    // warm + span recorder enabled: the observability tax when tracing.
    // The disabled-recorder path (the "warm" row above) is one relaxed
    // atomic load per probe site — the two rows bounding the recorder's
    // cost is the perf gate the observability PR ships under.
    warm.set_tracing(true);
    let _ = time_call(&warm, n, &a, &b, &mut c);
    let samples: Vec<_> = (0..REPS).map(|_| time_call(&warm, n, &a, &b, &mut c)).collect();
    warm.set_tracing(false);
    warm.reset_trace();
    record("warm-traced", &samples);
}

fn main() {
    let sizes = [128usize, 256, 512];
    let mut rows = Vec::new();
    for &n in &sizes {
        bench_size(n, &mut rows);
    }

    let table: Vec<Vec<String>> = rows
        .iter()
        .map(|r| {
            vec![
                r.n.to_string(),
                r.mode.to_string(),
                format!("{:.3}", r.best_ms),
                format!("{:.3}", r.mean_ms),
                r.warm_host_reads.to_string(),
            ]
        })
        .collect();
    print_table(
        "call overhead: one-shot vs cold-boot vs warm resident runtime",
        &["N", "mode", "best ms", "mean ms", "host reads (last call)"],
        &table,
    );

    let mut json = Json::obj();
    json.set("bench", Json::Str("call_overhead".into()));
    json.set("tile", Json::Num(T as f64));
    json.set("reps", Json::Num(REPS as f64));
    let mut arr = Vec::new();
    for r in &rows {
        let mut o = Json::obj();
        o.set("n", Json::Num(r.n as f64));
        o.set("mode", Json::Str(r.mode.into()));
        o.set("best_ms", Json::Num(r.best_ms));
        o.set("mean_ms", Json::Num(r.mean_ms));
        o.set("last_call_host_reads", Json::Num(r.warm_host_reads as f64));
        arr.push(o);
    }
    json.set("results", Json::Arr(arr));
    // Recorder overhead per size: warm-traced best vs warm best. The
    // disabled-recorder case is the "warm" rows themselves (recording
    // off is the default), so any warm regression IS the disabled cost.
    let mut overhead = Vec::new();
    for &n in &sizes {
        let best = |mode: &str| {
            rows.iter()
                .filter(|r| r.n == n && r.mode == mode)
                .map(|r| r.best_ms)
                .next()
                .unwrap_or(0.0)
        };
        let (off, on) = (best("warm"), best("warm-traced"));
        let mut o = Json::obj();
        o.set("n", Json::Num(n as f64));
        o.set("warm_best_ms", Json::Num(off));
        o.set("traced_best_ms", Json::Num(on));
        o.set("trace_overhead_ms", Json::Num(on - off));
        overhead.push(o);
    }
    json.set("recorder_overhead", Json::Arr(overhead));
    write_json("BENCH_runtime", &json);
    let root = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("../BENCH_runtime.json");
    match std::fs::write(&root, json.to_string_pretty()) {
        Ok(()) => println!("[bench] wrote {}", root.display()),
        Err(e) => eprintln!("[bench] cannot write {}: {e}", root.display()),
    }
}
