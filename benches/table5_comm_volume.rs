//! Experiment E7 — paper Table V: per-GPU communication volume (MB) at
//! N=16384 on Everest, split into bidirectional host↔device (black) and
//! P2P (red). BLASX vs cuBLAS-XT-like vs the cache-ful baselines.
//!
//! Paper headline: cuBLAS-XT moves ≈2.95× more than BLASX on average;
//! BLASX's P2P traffic appears only between the switch-sharing pair
//! (GPU1/GPU2 here, the paper's GPU2/GPU3).

use blasx::api::types::Routine;
use blasx::api::Dtype;
use blasx::bench::{print_table, write_json};
use blasx::coordinator::{run_sim, square_workload, Policy, RunConfig};
use blasx::sim::everest;
use blasx::trace::comm_volumes;
use blasx::util::json::Json;

fn main() {
    let t = 1024;
    let n = 16384;
    let machine = everest(3);
    let mut json = Json::obj();

    for routine in Routine::ALL {
        let w = square_workload(routine, n, t, Dtype::F64);
        let mut rows = Vec::new();
        let mut o = Json::obj();
        let mut totals: Vec<(Policy, f64)> = Vec::new();
        for policy in [Policy::Blasx, Policy::CublasXt, Policy::Parsec, Policy::Magma] {
            let cfg = RunConfig { t, policy, ..Default::default() };
            let rep = run_sim(&cfg, &machine, &w);
            if !rep.feasible {
                rows.push(vec![policy.name().into(), "N/A".into(), "N/A".into(), "N/A".into()]);
                continue;
            }
            let vols = comm_volumes(&rep.trace);
            let mut cells = vec![policy.name().to_string()];
            let mut arr = Vec::new();
            let mut total = 0.0;
            for v in vols.iter().take(3) {
                let hd_mb = v.hd_bytes / 1e6;
                let pp_mb = v.p2p_bytes / 1e6;
                total += hd_mb + pp_mb;
                cells.push(if pp_mb > 0.5 {
                    format!("{:.0}+[{:.0} p2p]", hd_mb, pp_mb)
                } else {
                    format!("{hd_mb:.0}")
                });
                let mut dv = Json::obj();
                dv.set("hd_mb", Json::Num(hd_mb));
                dv.set("p2p_mb", Json::Num(pp_mb));
                arr.push(dv);
            }
            totals.push((policy, total));
            o.set(policy.name(), Json::Arr(arr));
            rows.push(cells);
        }
        print_table(
            &format!("Table V: {} comm volume (MB) per GPU at N=16384", routine.dname()),
            &["policy", "GPU0", "GPU1", "GPU2"],
            &rows,
        );
        if let (Some(bx), Some(xt)) = (
            totals.iter().find(|(p, _)| *p == Policy::Blasx),
            totals.iter().find(|(p, _)| *p == Policy::CublasXt),
        ) {
            println!("   cuBLAS-XT / BLASX volume ratio: {:.2}x (paper avg 2.95x)", xt.1 / bx.1);
        }
        json.set(routine.name(), o);
    }
    write_json("table5_comm_volume", &json);
}
