//! Batch throughput — the batched subsystem's reason to exist.
//!
//! Workload: many small DGEMMs (N ≤ 512, the ANN-serving regime) on the
//! simulated 4-GPU Makalu preset. Two execution strategies over the
//! *identical* problem list:
//!
//! - **looped**: one scheduler invocation per problem (the only thing
//!   the pre-batch API could express) — each problem's few tiles leave
//!   most of the 4-device machine idle, and the per-call ramp-up
//!   (cold caches, empty stations) repeats N times;
//! - **fused**: one `taskize_batch` invocation — problem-namespaced
//!   tiles, flop-balanced problem-interleaved scheduling quanta, one
//!   warm cache/queue shared by the whole batch.
//!
//! Reported metric is aggregate throughput (total flops / virtual
//! seconds); the acceptance bar for this subsystem is fused ≥ 2×
//! looped at sizes ≤ 512 on the 4-device preset.
//!
//! `BLASX_BENCH_FULL=1` widens the batch-size sweep.

use blasx::api::types::Trans;
use blasx::api::Dtype;
use blasx::bench::{full_grid, print_table, write_json};
use blasx::coordinator::{gemm_batch_workload, run_sim, RunConfig};
use blasx::sim::makalu;
use blasx::task::GemmDesc;
use blasx::util::json::Json;
use blasx::util::prng::Prng;
use blasx::util::stats::gflops;

fn main() {
    let t = 128;
    let machine = makalu(4);
    let cfg = RunConfig { t, ..Default::default() };
    let batch_sizes: Vec<usize> =
        if full_grid() { vec![8, 16, 32, 64, 128, 256] } else { vec![16, 64, 256] };

    let mut rows = Vec::new();
    let mut json = Json::obj();
    for &nprob in &batch_sizes {
        // variable problem sizes in [64, 512] — the small/irregular mix
        let mut rng = Prng::new(4096 + nprob as u64);
        let probs: Vec<GemmDesc> = (0..nprob)
            .map(|_| {
                let n = 64 + 32 * rng.below(15); // 64..512 step 32
                GemmDesc { ta: Trans::No, tb: Trans::No, m: n, n, k: n, alpha: 1.0, beta: 1.0, t }
            })
            .collect();

        // looped: one run_sim per problem, serialized end to end
        let mut looped_secs = 0.0;
        let mut total_flops = 0.0;
        for d in &probs {
            let w = gemm_batch_workload(vec![*d], t, Dtype::F64, machine.devices.len());
            let rep = run_sim(&cfg, &machine, &w);
            assert!(rep.feasible);
            looped_secs += rep.makespan;
            total_flops += w.total_flops();
        }

        // fused: the whole batch through one scheduler invocation
        let w = gemm_batch_workload(probs, t, Dtype::F64, machine.devices.len());
        let rep = run_sim(&cfg, &machine, &w);
        assert!(rep.feasible);
        let fused_secs = rep.makespan;

        let looped_gf = gflops(total_flops, looped_secs);
        let fused_gf = gflops(total_flops, fused_secs);
        let speedup = looped_secs / fused_secs;
        rows.push(vec![
            nprob.to_string(),
            format!("{looped_gf:.0}"),
            format!("{fused_gf:.0}"),
            format!("{speedup:.2}x"),
            format!("{:?}", rep.tasks_per_worker),
        ]);
        let mut entry = Json::obj();
        entry.set("problems", Json::Num(nprob as f64));
        entry.set("looped_gflops", Json::Num(looped_gf));
        entry.set("fused_gflops", Json::Num(fused_gf));
        entry.set("speedup", Json::Num(speedup));
        json.set(&format!("batch{nprob}"), entry);
    }

    print_table(
        "Batch throughput: fused batch vs looped single calls (DGEMM \u{2264} 512, Makalu 4-GPU)",
        &["problems", "looped GF", "fused GF", "speedup", "tasks/worker"],
        &rows,
    );
    write_json("batch_throughput", &json);

    println!("\nthe fused batch amortizes taskization/cache-warmup across problems and");
    println!("its quanta interleave keeps all 4 (heterogeneous) devices fed; looping");
    println!("serializes problems whose tile grids cannot fill the machine alone.");
    println!("acceptance bar: fused/looped >= 2x at sizes <= 512 on the 4-device preset.");
}
