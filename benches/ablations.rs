//! Ablation study over the "4 major factors" the paper credits for
//! BLASX's performance (§V-A): demand-driven load balancing, seamless
//! stream occupancy, the L1 tile cache's volume reduction, and the L2
//! (P2P) cache — plus the design knobs DESIGN.md §6 calls out
//! (work stealing, k-chunk sync granularity, reservation-station size).
//!
//! Each row disables or varies exactly one mechanism on the same
//! workload (DGEMM N=16384, 3-GPU Everest; Makalu where heterogeneity is
//! the point).

use blasx::api::types::Routine;
use blasx::api::Dtype;
use blasx::bench::{print_table, write_json};
use blasx::coordinator::{run_sim, square_workload, Policy, RunConfig};
use blasx::sim::{everest, makalu, Machine, TopologyConfig};
use blasx::trace::comm_volumes;
use blasx::util::json::Json;

fn gf(cfg: &RunConfig, machine: &Machine, w: &blasx::coordinator::Workload) -> (f64, f64) {
    let rep = run_sim(cfg, machine, w);
    let p2p: f64 = comm_volumes(&rep.trace).iter().map(|v| v.p2p_bytes).sum();
    (rep.gflops(w.total_flops()), p2p / 1e6)
}

fn main() {
    let t = 1024;
    let w = square_workload(Routine::Gemm, 16384, t, Dtype::F64);
    let everest3 = everest(3);
    let base_cfg = RunConfig { t, policy: Policy::Blasx, ..Default::default() };
    let (base, base_p2p) = gf(&base_cfg, &everest3, &w);

    let mut rows = Vec::new();
    let mut json = Json::obj();
    let mut push = |rows: &mut Vec<Vec<String>>, json: &mut Json, name: &str, v: f64, note: &str| {
        rows.push(vec![
            name.to_string(),
            format!("{v:.0}"),
            format!("{:+.1}%", 100.0 * (v - base) / base),
            note.to_string(),
        ]);
        json.set(name, Json::Num(v));
    };

    push(&mut rows, &mut json, "baseline (all on)", base, &format!("{base_p2p:.0} MB P2P"));

    // -- no work stealing
    let cfg = RunConfig { work_stealing: false, ..base_cfg.clone() };
    let (v, _) = gf(&cfg, &everest3, &w);
    push(&mut rows, &mut json, "no work stealing", v, "homogeneous: small effect");

    // -- no P2P (kill the L2 tile cache): all devices on separate switches
    let mut machine = everest(3);
    machine.topology = TopologyConfig::paper_defaults(3, vec![vec![0], vec![1], vec![2]]);
    let (v, p2p) = gf(&base_cfg, &machine, &w);
    push(&mut rows, &mut json, "no P2P / L2 cache", v, &format!("{p2p:.0} MB P2P"));

    // -- tiny L1 cache (64 tiles): constant eviction, volume balloons
    let cfg = RunConfig { vram_override: Some(64 * t * t * 8), ..base_cfg.clone() };
    let (v, _) = gf(&cfg, &everest3, &w);
    push(&mut rows, &mut json, "L1 cache 64 tiles", v, "eviction thrash");

    // -- single stream: no communication/computation overlap
    let cfg = RunConfig { n_streams: 1, rs_capacity: 4, ..base_cfg.clone() };
    let (v, _) = gf(&cfg, &everest3, &w);
    push(&mut rows, &mut json, "1 stream (no overlap)", v, "paper Fig 1a regime");

    // -- k-chunk granularity
    for k in [1usize, 2, 8, 16] {
        let cfg = RunConfig { k_chunk: k, ..base_cfg.clone() };
        let (v, _) = gf(&cfg, &everest3, &w);
        push(&mut rows, &mut json, &format!("k_chunk={k}"), v, "sync granularity");
    }

    // -- RS capacity
    for rs in [4usize, 16] {
        let cfg = RunConfig { rs_capacity: rs, ..base_cfg.clone() };
        let (v, _) = gf(&cfg, &everest3, &w);
        push(&mut rows, &mut json, &format!("rs_capacity={rs}"), v, "lookahead depth");
    }

    print_table(
        "Ablations: DGEMM N=16384, 3-GPU Everest (GFLOPS, delta vs baseline)",
        &["variant", "GFLOPS", "delta", "note"],
        &rows,
    );

    // -- stealing on heterogeneous Makalu (where it actually matters)
    let mk = makalu(4);
    let wmk = square_workload(Routine::Gemm, 16384, t, Dtype::F64);
    let on = {
        let cfg = RunConfig { t, ..Default::default() };
        run_sim(&cfg, &mk, &wmk)
    };
    let off = {
        let cfg = RunConfig { t, work_stealing: false, ..Default::default() };
        run_sim(&cfg, &mk, &wmk)
    };
    println!(
        "\nwork stealing on Makalu (2xK40+2xTITAN X): on {:.0} GF {:?} | off {:.0} GF {:?}",
        on.gflops(wmk.total_flops()),
        on.tasks_per_worker,
        off.gflops(wmk.total_flops()),
        off.tasks_per_worker,
    );
    json.set("makalu_steal_on", Json::Num(on.gflops(wmk.total_flops())));
    json.set("makalu_steal_off", Json::Num(off.gflops(wmk.total_flops())));
    write_json("ablations", &json);
}
