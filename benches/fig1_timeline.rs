//! Experiment E2 — paper Fig. 1: single-GPU DGEMM execution-profile
//! snapshots. SuperMatrix shows blocking, non-overlapped transfers;
//! StarPU partial overlap and low occupancy; cuBLAS-XT contiguous
//! transfer pressure; BLASX tight kernel packing with hidden transfers.
//!
//! We render the same four snapshots as ASCII gantts (kernel rows `#`
//! per stream, transfer rows `>`/`<`/`=`) from the simulated traces, and
//! quantify each with its COMPT/COMM/OTHER split.

use blasx::api::types::Routine;
use blasx::api::Dtype;
use blasx::bench::{print_table, write_json};
use blasx::coordinator::{run_sim, square_workload, Policy, RunConfig};
use blasx::sim::everest;
use blasx::trace::{device_profile, gantt};
use blasx::util::json::Json;

fn main() {
    let n = 8192;
    let t = 1024;
    let machine = everest(1);
    let w = square_workload(Routine::Gemm, n, t, Dtype::F64);

    // "StarPU" per the paper's Fig. 1b: partial overlap, low saturation —
    // its published DGEMM used a single stream per GPU with eager
    // transfers; we model it as the SuperMatrix central queue but with
    // async (non-blocking) issue.
    let scenarios: [(&str, Policy); 4] = [
        ("SuperMatrix (Fig 1a)", Policy::SuperMatrix),
        ("StarPU-like (Fig 1b)", Policy::Magma),
        ("cuBLAS-XT (Fig 1c)", Policy::CublasXt),
        ("BLASX (Fig 1d)", Policy::Blasx),
    ];

    let mut rows = Vec::new();
    let mut json = Json::obj();
    for (label, policy) in scenarios {
        let cfg = RunConfig { t, policy, ..Default::default() };
        let rep = run_sim(&cfg, &machine, &w);
        println!("\n--- {label}: N={n} 1×K40c ---");
        print!("{}", gantt::render(&rep.trace, 100));
        let p = device_profile(&rep.trace, 0);
        rows.push(vec![
            label.to_string(),
            format!("{:.3}", rep.makespan),
            format!("{:.0}", rep.gflops(w.total_flops())),
            format!("{:.3}", p.compt),
            format!("{:.3}", p.comm),
            format!("{:.3}", p.other),
        ]);
        let mut o = Json::obj();
        o.set("makespan", Json::Num(rep.makespan));
        o.set("gflops", Json::Num(rep.gflops(w.total_flops())));
        o.set("compt", Json::Num(p.compt));
        o.set("comm", Json::Num(p.comm));
        o.set("other", Json::Num(p.other));
        json.set(policy.name(), o);
    }
    print_table(
        "Fig 1 quantified: single-GPU DGEMM profile",
        &["scheduler", "makespan(s)", "GFLOPS", "COMPT", "COMM", "OTHER"],
        &rows,
    );
    write_json("fig1_timeline", &json);
    println!("\npaper shape: BLASX packs kernels seamlessly (COMM≈0), cuBLAS-XT");
    println!("saturates the PCI-E (large COMM), SuperMatrix serializes (large OTHER+COMM).");
}
