//! Experiment E14 — the Makalu heterogeneity claim (paper §V, Fig. 7
//! discussion): on 2×K40 + 2×TITAN X (DP-crippled Maxwell), BLASX keeps
//! tracking the machine's useful DP capacity while static schedulers
//! collapse — adding slow devices *hurts* cuBLAS-XT.

use blasx::api::types::Routine;
use blasx::api::Dtype;
use blasx::bench::{fmt_gf, print_table, write_json};
use blasx::coordinator::{run_sim, square_workload, Policy, RunConfig};
use blasx::sim::makalu;
use blasx::trace::balance_gap;
use blasx::util::json::Json;

fn main() {
    let t = 1024;
    let n = 16384;
    let w = square_workload(Routine::Gemm, n, t, Dtype::F64);
    let flops = w.total_flops();

    let mut rows = Vec::new();
    let mut json = Json::obj();
    for gpus in 1..=4usize {
        let machine = makalu(gpus);
        let mut row = vec![gpus.to_string()];
        let mut o = Json::obj();
        for policy in [Policy::Blasx, Policy::CublasXt, Policy::Parsec, Policy::SuperMatrix] {
            let cfg = RunConfig { t, policy, ..Default::default() };
            let rep = run_sim(&cfg, &machine, &w);
            row.push(fmt_gf(rep.feasible, rep.gflops(flops)));
            if policy == Policy::Blasx && rep.feasible {
                row.push(format!("{:.3}s", balance_gap(&rep.trace)));
                row.push(format!("{:?}", rep.tasks_per_worker));
            }
            o.set(policy.name(), Json::Num(rep.gflops(flops)));
        }
        json.set(&format!("gpus{gpus}"), o);
        rows.push(row);
    }
    print_table(
        "Fig 7 (Makalu): DGEMM N=16384 across 1-4 heterogeneous GPUs",
        &["gpus", "blasx", "gap", "tasks/device", "cublasxt", "parsec", "supermatrix"],
        &rows,
    );
    write_json("fig7_makalu", &json);
    println!("\nuseful DP capacity: 1.2 / 2.4 / 2.59 / 2.78 TFLOPS for 1/2/3/4 GPUs —");
    println!("BLASX should track it (speed-proportional task counts); static");
    println!("round-robin must wait for the TITANs and falls *below* its 2-GPU point.");

    // --- the reversal: in single precision the Maxwells are the FAST
    // devices (5.0 vs 3.3 TFLOPS). Demand-driven scheduling must flip
    // the task split without any configuration change.
    let wsp = square_workload(Routine::Gemm, 16384, t, Dtype::F32);
    let mut rows = Vec::new();
    let mut jsp = Json::obj();
    for gpus in [2usize, 4] {
        let machine = makalu(gpus);
        let cfg = RunConfig { t, ..Default::default() };
        let rep = run_sim(&cfg, &machine, &wsp);
        rows.push(vec![
            gpus.to_string(),
            format!("{:.0}", rep.gflops(wsp.total_flops())),
            format!("{:?}", rep.tasks_per_worker),
        ]);
        jsp.set(&format!("gpus{gpus}"), Json::Num(rep.gflops(wsp.total_flops())));
    }
    print_table(
        "SGEMM on Makalu: the TITANs are now the fast devices",
        &["gpus", "blasx GFLOPS", "tasks/device (K40, K40, TITAN, TITAN)"],
        &rows,
    );
    write_json("fig7_makalu_sgemm", &jsp);
    println!("\nSP capacity: K40 3.3, TITAN X 5.0 TFLOPS — the task split should");
    println!("invert (TITANs take MORE) with zero configuration: the queue is the");
    println!("only load balancer (paper §IV-C).");
}
