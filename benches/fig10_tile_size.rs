//! Experiment E10 — paper Fig. 10: DGEMM performance vs tile size (the
//! library's only tuning parameter, §V-B) at N = 14336 and 16384 on
//! simulated Everest (3 GPUs).
//!
//! Trade-off under test: large tiles saturate the GPU kernel and the
//! PCI-E but shrink the task pool (Eq. 2 parallelism); small tiles
//! starve the kernel. The curve should rise with T and plateau around
//! T ≈ 1024 — where the paper pins its benchmarks.

use blasx::api::types::Routine;
use blasx::api::Dtype;
use blasx::bench::{print_table, write_json};
use blasx::coordinator::{run_sim, square_workload, Policy, RunConfig};
use blasx::sim::everest;
use blasx::util::json::Json;

fn main() {
    let machine = everest(3);
    let tiles = [128usize, 256, 512, 768, 1024, 1536, 2048];
    let mut json = Json::obj();
    let mut rows = Vec::new();
    for n in [14336usize, 16384] {
        let mut arr = Vec::new();
        let mut row = vec![format!("N={n}")];
        for &t in &tiles {
            let w = square_workload(Routine::Gemm, n, t, Dtype::F64);
            let cfg = RunConfig { t, policy: Policy::Blasx, ..Default::default() };
            let rep = run_sim(&cfg, &machine, &w);
            let gf = rep.gflops(w.total_flops());
            row.push(format!("{gf:.0}"));
            arr.push(Json::Num(gf));
        }
        rows.push(row);
        json.set(&format!("n{n}"), Json::Arr(arr));
    }
    json.set("tiles", Json::Arr(tiles.iter().map(|&t| Json::Num(t as f64)).collect()));
    let mut header = vec![""];
    let tile_labels: Vec<String> = tiles.iter().map(|t| format!("T={t}")).collect();
    header.extend(tile_labels.iter().map(String::as_str));
    print_table("Fig 10: DGEMM GFLOPS vs tile size (3-GPU Everest)", &header, &rows);
    write_json("fig10_tile_size", &json);
    println!("\npaper shape: rising curve, plateau by T≈1024 (the benchmark setting).");
}
