//! Comm/compute overlap of the asynchronous transfer pipeline — the
//! measurement half of the prefetch PR (paper Fig. 8's claim that a
//! multi-GPU L3 call hides its PCI-E traffic under tile kernels).
//!
//! Four scenarios: prefetch off/on × cold/warm, each a multi-tile
//! DGEMM on a fresh resident runtime with the span recorder on.
//! Reported per row:
//!
//! - **wall ms** — end-to-end call time;
//! - **overlap fraction** — from [`blasx::trace::overlap_report`]:
//!   the fraction of wall-clock comm span time (H2D/P2P/D2H) covered
//!   by concurrent compute spans anywhere in the fleet;
//! - **prefetch hits / wasted** — the pipeline's own ledger counters;
//! - **host tiles read** — A/B/C host reads summed (warm rows must be
//!   zero: lookahead must never break residency).
//!
//! A **lock-hold probe** rides along: while a cold prefetch-on DGEMM
//! runs, a sampler thread hammers `Context::render_prometheus` (whose
//! gauge gather takes the global cache lock) and records its latency.
//! With every byte move off-lock, the max stall stays small and — the
//! actual acceptance — does not grow with prefetch on vs off.
//!
//! Results print as a table and land in `bench_out/BENCH_overlap.json`
//! plus the committed repo-root `BENCH_overlap.json` (regenerate on a
//! host with cargo; an empty committed `results` array means the
//! snapshot was authored without a toolchain — see its `note`).

use blasx::api::types::Trans;
use blasx::api::{self, Context};
use blasx::bench::{print_table, write_json};
use blasx::trace::overlap_report;
use blasx::util::json::Json;
use blasx::util::prng::Prng;
use std::sync::atomic::{AtomicBool, Ordering};
use std::time::Instant;

const N: usize = 384;
const T: usize = 64;
const DEVICES: usize = 2;
const ARENA: usize = 32 << 20;

fn ctx(prefetch: usize) -> Context {
    Context::new(DEVICES).with_arena(ARENA).with_tile(T).with_prefetch(Some(prefetch))
}

struct Row {
    config: &'static str,
    phase: &'static str,
    wall_ms: f64,
    overlap_fraction: f64,
    comm_s: f64,
    comm_hidden_s: f64,
    prefetch_hits: usize,
    prefetch_wasted: usize,
    host_read_tiles: usize,
}

fn one_call(ctx: &Context, a: &[f64], b: &[f64], c: &mut [f64]) -> (f64, blasx::coordinator::real_engine::TransferStats) {
    let t0 = Instant::now();
    let rep = api::dgemm(ctx, Trans::No, Trans::No, N, N, N, 1.0, a, N, b, N, 0.0, c, N)
        .expect("overlap bench dgemm");
    (t0.elapsed().as_secs_f64() * 1e3, rep.transfers)
}

fn scenario(config: &'static str, prefetch: usize, rows: &mut Vec<Row>) {
    let ctx = ctx(prefetch);
    ctx.set_tracing(true);
    let mut p = Prng::new(11);
    let mut a = vec![0.0; N * N];
    let mut b = vec![0.0; N * N];
    p.fill_f64(&mut a, -1.0, 1.0);
    p.fill_f64(&mut b, -1.0, 1.0);
    let mut c = vec![0.0; N * N];
    for phase in ["cold", "warm"] {
        ctx.reset_trace();
        let (wall_ms, tr) = one_call(&ctx, &a, &b, &mut c);
        let trace = ctx.snapshot_trace().expect("runtime booted");
        let ov = overlap_report(&trace);
        rows.push(Row {
            config,
            phase,
            wall_ms,
            overlap_fraction: ov.hidden_frac(),
            comm_s: ov.comm_total,
            comm_hidden_s: ov.comm_hidden,
            prefetch_hits: tr.prefetch_hits,
            prefetch_wasted: tr.prefetch_wasted,
            host_read_tiles: tr.host_reads.iter().sum(),
        });
    }
}

/// Latency of a cache-lock-taking observer while a cold DGEMM runs:
/// `render_prometheus` gathers gauges under the global cache lock, so
/// its worst-case stall bounds how long any worker holds that lock.
/// Returns `(samples, max_ms, mean_ms)`.
fn lock_probe(prefetch: usize) -> (usize, f64, f64) {
    let ctx = ctx(prefetch);
    // Boot the runtime (and its caches) before sampling begins.
    let mut p = Prng::new(12);
    let mut a = vec![0.0; N * N];
    let mut b = vec![0.0; N * N];
    p.fill_f64(&mut a, -1.0, 1.0);
    p.fill_f64(&mut b, -1.0, 1.0);
    let mut warm = vec![0.0; 64 * 64];
    api::dgemm(&ctx, Trans::No, Trans::No, 64, 64, 64, 1.0, &a[..64 * 64], 64, &b[..64 * 64], 64, 0.0, &mut warm, 64)
        .expect("probe warmup");
    let stop = AtomicBool::new(false);
    let mut c = vec![0.0; N * N];
    std::thread::scope(|s| {
        let sampler = s.spawn(|| {
            let (mut n, mut max_s, mut sum_s) = (0usize, 0.0f64, 0.0f64);
            while !stop.load(Ordering::Relaxed) {
                let t0 = Instant::now();
                let _ = ctx.render_prometheus();
                let dt = t0.elapsed().as_secs_f64();
                n += 1;
                sum_s += dt;
                max_s = max_s.max(dt);
            }
            (n, max_s, sum_s)
        });
        for _ in 0..3 {
            let _ = one_call(&ctx, &a, &b, &mut c);
        }
        stop.store(true, Ordering::Relaxed);
        let (n, max_s, sum_s) = sampler.join().expect("sampler thread");
        (n, max_s * 1e3, if n == 0 { 0.0 } else { sum_s * 1e3 / n as f64 })
    })
}

fn main() {
    let mut rows = Vec::new();
    scenario("prefetch-off", 0, &mut rows);
    scenario("prefetch-on", 8, &mut rows);

    let table: Vec<Vec<String>> = rows
        .iter()
        .map(|r| {
            vec![
                r.config.to_string(),
                r.phase.to_string(),
                format!("{:.1}", r.wall_ms),
                format!("{:.0}%", 100.0 * r.overlap_fraction),
                format!("{:.3}/{:.3}", r.comm_hidden_s, r.comm_s),
                format!("{}/{}", r.prefetch_hits, r.prefetch_wasted),
                r.host_read_tiles.to_string(),
            ]
        })
        .collect();
    print_table(
        "transfer overlap: comm hidden under compute, prefetch off vs on",
        &["config", "phase", "wall ms", "overlap", "hidden/comm s", "pf hit/waste", "host tiles"],
        &table,
    );

    let (off_n, off_max, off_mean) = lock_probe(0);
    let (on_n, on_max, on_mean) = lock_probe(8);
    println!(
        "\nlock probe (gauge gather under the cache lock, during 3 cold dgemms):\n\
         \x20 prefetch off: {off_n} samples, max {off_max:.3} ms, mean {off_mean:.3} ms\n\
         \x20 prefetch on:  {on_n} samples, max {on_max:.3} ms, mean {on_mean:.3} ms\n\
         (copies run off-lock: turning the prefetcher on must not stretch the max)"
    );

    let mut json = Json::obj();
    json.set("bench", Json::Str("transfer_overlap".into()));
    json.set("n", Json::Num(N as f64));
    json.set("tile", Json::Num(T as f64));
    json.set("devices", Json::Num(DEVICES as f64));
    let mut arr = Vec::new();
    for r in &rows {
        let mut o = Json::obj();
        o.set("config", Json::Str(r.config.into()));
        o.set("phase", Json::Str(r.phase.into()));
        o.set("wall_ms", Json::Num(r.wall_ms));
        o.set("overlap_fraction", Json::Num(r.overlap_fraction));
        o.set("comm_s", Json::Num(r.comm_s));
        o.set("comm_hidden_s", Json::Num(r.comm_hidden_s));
        o.set("prefetch_hits", Json::Num(r.prefetch_hits as f64));
        o.set("prefetch_wasted", Json::Num(r.prefetch_wasted as f64));
        o.set("host_read_tiles", Json::Num(r.host_read_tiles as f64));
        arr.push(o);
    }
    json.set("results", Json::Arr(arr));
    let mut probe = Json::obj();
    probe.set("off_samples", Json::Num(off_n as f64));
    probe.set("off_max_ms", Json::Num(off_max));
    probe.set("off_mean_ms", Json::Num(off_mean));
    probe.set("on_samples", Json::Num(on_n as f64));
    probe.set("on_max_ms", Json::Num(on_max));
    probe.set("on_mean_ms", Json::Num(on_mean));
    json.set("lock_probe", probe);
    write_json("BENCH_overlap", &json);
    let root = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("../BENCH_overlap.json");
    match std::fs::write(&root, json.to_string_pretty()) {
        Ok(()) => println!("[bench] wrote {}", root.display()),
        Err(e) => eprintln!("[bench] cannot write {}: {e}", root.display()),
    }
}
