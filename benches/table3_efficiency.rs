//! Experiment E5 — paper Table III: average parallel efficiency
//! `T1/(g·Tg)` over the matrix-size grid, per routine and policy, on
//! simulated Everest with g = 3 GPUs.
//!
//! Forward padding for infeasible sizes follows the paper (§V-A): a
//! policy that cannot run a size inherits its last feasible time scaled
//! by work ratio — here we simply skip infeasible sizes in the average,
//! and report coverage.

use blasx::api::types::Routine;
use blasx::api::Dtype;
use blasx::bench::{print_table, size_grid, write_json};
use blasx::coordinator::{run_sim, square_workload, Policy, RunConfig};
use blasx::sim::everest;
use blasx::util::json::Json;
use blasx::util::stats::mean;

fn main() {
    let t = 1024;
    let g = 3usize;
    let sizes = size_grid();
    let policies = [Policy::Blasx, Policy::Parsec, Policy::Magma, Policy::CublasXt, Policy::SuperMatrix];

    let mut rows = Vec::new();
    let mut json = Json::obj();
    for routine in [Routine::Syrk, Routine::Trsm, Routine::Trmm, Routine::Symm, Routine::Gemm, Routine::Syr2k]
    {
        let mut row = vec![routine.dname()];
        let mut o = Json::obj();
        for policy in policies {
            // paper availability matrix (Table III N/A pattern)
            let available = match (policy, routine) {
                (Policy::Parsec, r) if r != Routine::Gemm => false,
                (Policy::Magma, r) if !matches!(r, Routine::Trsm | Routine::Syr2k) => false,
                _ => true,
            };
            if !available {
                row.push("N/A".into());
                continue;
            }
            let mut effs = Vec::new();
            for &n in &sizes {
                let w = square_workload(routine, n, t, Dtype::F64);
                let cfg = RunConfig { t, policy, ..Default::default() };
                let rep1 = run_sim(&cfg, &everest(1), &w);
                let repg = run_sim(&cfg, &everest(g), &w);
                if rep1.feasible && repg.feasible {
                    effs.push(rep1.makespan / (g as f64 * repg.makespan));
                }
            }
            let avg = 100.0 * mean(&effs);
            row.push(format!("{avg:.1}%"));
            o.set(policy.name(), Json::Num(avg));
        }
        json.set(routine.name(), o);
        rows.push(row);
    }
    print_table(
        "Table III: average parallel efficiency (3 GPUs, Everest)",
        &["routine", "BLASX", "PaRSEC", "MAGMA", "cuBLAS-XT", "SuperMatrix"],
        &rows,
    );
    write_json("table3_efficiency", &json);
    println!("\npaper reference: BLASX 81.6-93.5% (best in every row); cuBLAS-XT");
    println!("58-90%; SuperMatrix 30-46%; PaRSEC 92.9% (DGEMM only); MAGMA 77-80%.");
}
