//! Kernel GFLOPS — the perf trajectory of the packed hostblas engine.
//!
//! Measures single-thread GFLOPS per routine and dtype at tile sizes
//! T ∈ {128, 256, 512} for three kernel generations:
//!
//! - **ref**    — the naive `*_ref` oracles (T=128 only; they are
//!   orders of magnitude off and exist for correctness, not speed);
//! - **seed**   — a verbatim copy of the seed-era `gemm_blocked`
//!   (per-call pack allocation, column micro-kernel), embedded here so
//!   the baseline survives the engine rewrite;
//! - **packed** — the register-tiled packed engine that now runs every
//!   real-engine tile task, plus `gemm_mt` at the host's core count.
//!
//! Acceptance bars (ISSUE 2): packed ≥ 3× seed for f64 GEMM at T=256,
//! and packed SYRK/TRSM within 2× of packed GEMM GFLOPS.
//!
//! Results print as a table and land in `bench_out/BENCH_kernels.json`
//! plus the repo-root `BENCH_kernels.json` (the committed snapshot that
//! seeds the perf trajectory across PRs).

use blasx::api::types::{Diag, Scalar, Side, Trans, Uplo};
use blasx::bench::{print_table, write_json};
use blasx::hostblas;
use blasx::util::json::Json;
use blasx::util::prng::Prng;
use std::hint::black_box;
use std::time::Instant;

/// Verbatim seed-era blocked kernel (PR 0/1 vintage): fixed 64/64/128
/// blocking, pack buffers allocated per call, column micro-kernel with
/// the 4-wide k-unroll. Kept private to the bench as the baseline.
#[allow(clippy::too_many_arguments)]
fn seed_gemm_blocked<T: Scalar>(
    ta: Trans,
    tb: Trans,
    m: usize,
    n: usize,
    k: usize,
    alpha: T,
    a: &[T],
    lda: usize,
    b: &[T],
    ldb: usize,
    beta: T,
    c: &mut [T],
    ldc: usize,
) {
    const MC: usize = 64;
    const NC: usize = 64;
    const KC: usize = 128;
    let opx = |x: &[T], ld: usize, trans: Trans, r: usize, cc: usize| match trans {
        Trans::No => x[cc * ld + r],
        Trans::Yes => x[r * ld + cc],
    };
    if m == 0 || n == 0 {
        return;
    }
    if alpha == T::zero() || k == 0 {
        for j in 0..n {
            for i in 0..m {
                let v = c[j * ldc + i];
                c[j * ldc + i] = beta * v;
            }
        }
        return;
    }
    if beta != T::one() {
        for j in 0..n {
            for i in 0..m {
                let v = c[j * ldc + i];
                c[j * ldc + i] = beta * v;
            }
        }
    }
    let mut apack = vec![T::zero(); MC * KC];
    let mut bpack = vec![T::zero(); KC * NC];
    let mut pc = 0;
    while pc < k {
        let kb = KC.min(k - pc);
        let mut jc = 0;
        while jc < n {
            let nb = NC.min(n - jc);
            for jj in 0..nb {
                for pp in 0..kb {
                    bpack[jj * kb + pp] = opx(b, ldb, tb, pc + pp, jc + jj);
                }
            }
            let mut ic = 0;
            while ic < m {
                let mb = MC.min(m - ic);
                for pp in 0..kb {
                    for ii in 0..mb {
                        apack[pp * mb + ii] = opx(a, lda, ta, ic + ii, pc + pp);
                    }
                }
                for jj in 0..nb {
                    let ccol = (jc + jj) * ldc + ic;
                    let bcol = jj * kb;
                    let cs = &mut c[ccol..ccol + mb];
                    let mut pp = 0;
                    while pp + 4 <= kb {
                        let b0 = alpha * bpack[bcol + pp];
                        let b1 = alpha * bpack[bcol + pp + 1];
                        let b2 = alpha * bpack[bcol + pp + 2];
                        let b3 = alpha * bpack[bcol + pp + 3];
                        let (a0s, rest) = apack[pp * mb..].split_at(mb);
                        let (a1s, rest) = rest.split_at(mb);
                        let (a2s, rest) = rest.split_at(mb);
                        let a3s = &rest[..mb];
                        for ((((cv, &x0), &x1), &x2), &x3) in
                            cs.iter_mut().zip(a0s).zip(a1s).zip(a2s).zip(a3s)
                        {
                            *cv += x0 * b0 + x1 * b1 + x2 * b2 + x3 * b3;
                        }
                        pp += 4;
                    }
                    while pp < kb {
                        let bv = alpha * bpack[bcol + pp];
                        let aos = &apack[pp * mb..pp * mb + mb];
                        for (cv, &x) in cs.iter_mut().zip(aos) {
                            *cv += x * bv;
                        }
                        pp += 1;
                    }
                }
                ic += mb;
            }
            jc += nb;
        }
        pc += kb;
    }
}

/// Best-of-`reps` seconds for `f`.
fn time_best(reps: usize, mut f: impl FnMut()) -> f64 {
    let mut best = f64::INFINITY;
    for _ in 0..reps {
        let t0 = Instant::now();
        f();
        best = best.min(t0.elapsed().as_secs_f64());
    }
    best
}

/// Repetitions sized so each variant gets a few hundred MFLOP of work.
fn reps_for(flops: f64) -> usize {
    ((4.0e8 / flops).ceil() as usize).clamp(2, 50)
}

struct Row {
    routine: &'static str,
    dtype: &'static str,
    t: usize,
    kernel: &'static str,
    gflops: f64,
}

fn gf(flops: f64, secs: f64) -> f64 {
    flops / secs / 1e9
}

fn bench_dtype<T: Scalar>(dtype: &'static str, rows: &mut Vec<Row>) {
    let mut rng = Prng::new(4242);
    for &t in &[128usize, 256, 512] {
        let mut a = vec![T::zero(); t * t];
        let mut b = vec![T::zero(); t * t];
        let mut c = vec![T::zero(); t * t];
        for x in a.iter_mut() {
            *x = T::from_f64(rng.range_f64(-1.0, 1.0));
        }
        for x in b.iter_mut() {
            *x = T::from_f64(rng.range_f64(-1.0, 1.0));
        }
        // triangular/symmetric operands want a dominant diagonal
        let mut tri = a.clone();
        for i in 0..t {
            tri[i * t + i] = T::from_f64(4.0);
        }
        let gemm_flops = 2.0 * (t * t * t) as f64;
        let reps = reps_for(gemm_flops);

        // GEMM: packed / seed / (ref at 128 only)
        let secs = time_best(reps, || {
            hostblas::gemm_packed(
                Trans::No, Trans::No, t, t, t, T::one(), &a, t, &b, t, T::zero(), &mut c, t,
            );
            black_box(&c);
        });
        let packed_gemm = gf(gemm_flops, secs);
        rows.push(Row { routine: "gemm", dtype, t, kernel: "packed", gflops: packed_gemm });
        let secs = time_best(reps, || {
            seed_gemm_blocked(
                Trans::No, Trans::No, t, t, t, T::one(), &a, t, &b, t, T::zero(), &mut c, t,
            );
            black_box(&c);
        });
        rows.push(Row { routine: "gemm", dtype, t, kernel: "seed", gflops: gf(gemm_flops, secs) });
        if t == 128 {
            let secs = time_best(2, || {
                hostblas::gemm_ref(
                    Trans::No, Trans::No, t, t, t, T::one(), &a, t, &b, t, T::zero(), &mut c, t,
                );
                black_box(&c);
            });
            rows.push(Row { routine: "gemm", dtype, t, kernel: "ref", gflops: gf(gemm_flops, secs) });
        }

        // gemm_mt at the host's core count
        let threads = std::thread::available_parallelism().map(|x| x.get()).unwrap_or(1);
        let secs = time_best(reps, || {
            hostblas::gemm_mt(
                threads, Trans::No, Trans::No, t, t, t, T::one(), &a, t, &b, t, T::zero(), &mut c,
                t,
            );
            black_box(&c);
        });
        rows.push(Row { routine: "gemm_mt", dtype, t, kernel: "packed", gflops: gf(gemm_flops, secs) });

        // SYRK
        let flops = (t * t * (t + 1)) as f64;
        let secs = time_best(reps, || {
            hostblas::syrk_packed(Uplo::Lower, Trans::No, t, t, T::one(), &a, t, T::zero(), &mut c, t);
            black_box(&c);
        });
        rows.push(Row { routine: "syrk", dtype, t, kernel: "packed", gflops: gf(flops, secs) });
        if t == 128 {
            let secs = time_best(2, || {
                hostblas::syrk_ref(Uplo::Lower, Trans::No, t, t, T::one(), &a, t, T::zero(), &mut c, t);
                black_box(&c);
            });
            rows.push(Row { routine: "syrk", dtype, t, kernel: "ref", gflops: gf(flops, secs) });
        }

        // SYR2K
        let flops = 2.0 * (t * t * (t + 1)) as f64;
        let secs = time_best(reps, || {
            hostblas::syr2k_packed(
                Uplo::Lower, Trans::No, t, t, T::one(), &a, t, &b, t, T::zero(), &mut c, t,
            );
            black_box(&c);
        });
        rows.push(Row { routine: "syr2k", dtype, t, kernel: "packed", gflops: gf(flops, secs) });

        // SYMM
        let flops = 2.0 * (t * t * t) as f64;
        let secs = time_best(reps, || {
            hostblas::symm_packed(
                Side::Left, Uplo::Upper, t, t, T::one(), &a, t, &b, t, T::zero(), &mut c, t,
            );
            black_box(&c);
        });
        rows.push(Row { routine: "symm", dtype, t, kernel: "packed", gflops: gf(flops, secs) });

        // TRMM (in place on c; the RHS is re-seeded each rep — an O(T²)
        // copy against the O(T³) kernel — so repeated multiplies can't
        // overflow out of the float range across reps)
        let flops = (t * t * t) as f64;
        let secs = time_best(reps, || {
            c.copy_from_slice(&b);
            hostblas::trmm_packed(
                Side::Left, Uplo::Upper, Trans::No, Diag::NonUnit, t, t, T::one(), &tri, t,
                &mut c, t,
            );
            black_box(&c);
        });
        rows.push(Row { routine: "trmm", dtype, t, kernel: "packed", gflops: gf(flops, secs) });
        if t == 128 {
            let secs = time_best(2, || {
                c.copy_from_slice(&b);
                hostblas::trmm_ref(
                    Side::Left, Uplo::Upper, Trans::No, Diag::NonUnit, t, t, T::one(), &tri, t,
                    &mut c, t,
                );
                black_box(&c);
            });
            rows.push(Row { routine: "trmm", dtype, t, kernel: "ref", gflops: gf(flops, secs) });
        }

        // TRSM (same re-seeding discipline as TRMM)
        let flops = (t * t * t) as f64;
        let secs = time_best(reps, || {
            c.copy_from_slice(&b);
            hostblas::trsm_packed(
                Side::Left, Uplo::Upper, Trans::No, Diag::NonUnit, t, t, T::one(), &tri, t,
                &mut c, t,
            );
            black_box(&c);
        });
        rows.push(Row { routine: "trsm", dtype, t, kernel: "packed", gflops: gf(flops, secs) });
        if t == 128 {
            let secs = time_best(2, || {
                c.copy_from_slice(&b);
                hostblas::trsm_ref(
                    Side::Left, Uplo::Upper, Trans::No, Diag::NonUnit, t, t, T::one(), &tri, t,
                    &mut c, t,
                );
                black_box(&c);
            });
            rows.push(Row { routine: "trsm", dtype, t, kernel: "ref", gflops: gf(flops, secs) });
        }
    }
}

fn find(rows: &[Row], routine: &str, dtype: &str, t: usize, kernel: &str) -> Option<f64> {
    rows.iter()
        .find(|r| r.routine == routine && r.dtype == dtype && r.t == t && r.kernel == kernel)
        .map(|r| r.gflops)
}

fn main() {
    let mut rows: Vec<Row> = Vec::new();
    bench_dtype::<f64>("f64", &mut rows);
    bench_dtype::<f32>("f32", &mut rows);

    let table: Vec<Vec<String>> = rows
        .iter()
        .map(|r| {
            vec![
                r.routine.to_string(),
                r.dtype.to_string(),
                r.t.to_string(),
                r.kernel.to_string(),
                format!("{:.2}", r.gflops),
            ]
        })
        .collect();
    print_table("kernel GFLOPS", &["routine", "dtype", "T", "kernel", "GFLOPS"], &table);

    let mut json = Json::obj();
    json.set("bench", Json::Str("kernel_gflops".into()));
    json.set(
        "dims",
        Json::Str("square T x T x T per routine, single thread unless gemm_mt".into()),
    );
    let mut arr = Vec::new();
    for r in &rows {
        let mut e = Json::obj();
        e.set("routine", Json::Str(r.routine.into()));
        e.set("dtype", Json::Str(r.dtype.into()));
        e.set("t", Json::Num(r.t as f64));
        e.set("kernel", Json::Str(r.kernel.into()));
        e.set("gflops", Json::Num((r.gflops * 100.0).round() / 100.0));
        arr.push(e);
    }
    json.set("results", Json::Arr(arr));

    // acceptance summary (ISSUE 2)
    let mut summary = Json::obj();
    if let (Some(p), Some(s)) = (
        find(&rows, "gemm", "f64", 256, "packed"),
        find(&rows, "gemm", "f64", 256, "seed"),
    ) {
        summary.set("gemm_f64_t256_packed_gflops", Json::Num((p * 100.0).round() / 100.0));
        summary.set("gemm_f64_t256_seed_gflops", Json::Num((s * 100.0).round() / 100.0));
        summary.set("packed_vs_seed_speedup_t256_f64", Json::Num((p / s * 100.0).round() / 100.0));
    }
    if let (Some(g), Some(sy), Some(tr)) = (
        find(&rows, "gemm", "f64", 256, "packed"),
        find(&rows, "syrk", "f64", 256, "packed"),
        find(&rows, "trsm", "f64", 256, "packed"),
    ) {
        summary.set("syrk_over_gemm_t256_f64", Json::Num((sy / g * 100.0).round() / 100.0));
        summary.set("trsm_over_gemm_t256_f64", Json::Num((tr / g * 100.0).round() / 100.0));
    }
    json.set("summary", summary);

    write_json("BENCH_kernels", &json);
    // Repo-root committed snapshot: the perf trajectory across PRs.
    let root = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("../BENCH_kernels.json");
    match std::fs::write(&root, json.to_string_pretty()) {
        Ok(()) => println!("[bench] wrote {}", root.display()),
        Err(e) => eprintln!("[bench] cannot write {}: {e}", root.display()),
    }
}
