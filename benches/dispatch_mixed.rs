//! The mixed-tile workload the PR-8 dispatch work exists for: tenants
//! alternating DIFFERENT tile sizes through one resident runtime.
//!
//! Pre-PR-8 the runtime serialized every tile-size change behind an
//! admission barrier and then purged EVERY device cache, so an
//! alternating two-tenant workload re-staged its whole working set on
//! each call. With the tile size folded into `TileKey`, each geometry
//! is its own cache generation and alternation is transfer-free after
//! one cold call per tenant. Three scenarios make the gap measurable:
//!
//! - **single-tile warm** — one tenant, fixed `t` (the best case the
//!   old runtime could reach: never switch);
//! - **mixed-tile warm** — two tenants alternating `t`=64/128 over one
//!   shared runtime (the case the old runtime thrashed on; the column
//!   `warm host reads` must be 0 — that IS the acceptance property);
//! - **mixed-tile cold** — the same alternation with every call on a
//!   fresh one-shot engine: a faithful floor for what the purge made
//!   each switch cost (the old path also paid the barrier drain).
//!
//! A second probe measures dispatcher overhead: warm single-tenant
//! calls with a profile-backed dispatcher on the hot path vs without
//! (one BTreeMap lookup per call — the table shows it is noise).
//!
//! Results print as a table and land in `bench_out/BENCH_dispatch.json`
//! plus the repo-root `BENCH_dispatch.json` (committed snapshot —
//! regenerate on a host with cargo; the committed numbers are from the
//! authoring container).

use blasx::api::types::{Dtype, Trans};
use blasx::api::{self, Context};
use blasx::bench::{print_table, write_json};
use blasx::dispatch::{shape_key, Choice, Placement, Profile};
use blasx::util::json::Json;
use blasx::util::prng::Prng;
use std::time::Instant;

const N: usize = 256;
const DEVICES: usize = 2;
const TILES: [usize; 2] = [64, 128];
const ROUNDS: usize = 6;

struct Tenant {
    a: Vec<f64>,
    b: Vec<f64>,
    c: Vec<f64>,
}

fn tenant(seed: u64) -> Tenant {
    let mut p = Prng::new(seed);
    let mut a = vec![0.0; N * N];
    let mut b = vec![0.0; N * N];
    p.fill_f64(&mut a, -1.0, 1.0);
    p.fill_f64(&mut b, -1.0, 1.0);
    Tenant { a, b, c: vec![0.0; N * N] }
}

fn call(ctx: &Context, t: &mut Tenant) -> usize {
    let rep = api::dgemm(
        ctx, Trans::No, Trans::No, N, N, N, 1.0, &t.a, N, &t.b, N, 0.0, &mut t.c, N,
    )
    .expect("bench dgemm");
    rep.transfers.input_host_reads()
}

struct Row {
    scenario: &'static str,
    calls: usize,
    wall_ms: f64,
    calls_per_sec: f64,
    /// Host→device tile reads summed over every post-warmup call (the
    /// purge-era runtime re-read everything here; PR-8 reads nothing).
    warm_host_reads: usize,
}

fn row(scenario: &'static str, calls: usize, wall: f64, warm_host_reads: usize) -> Row {
    Row { scenario, calls, wall_ms: wall * 1e3, calls_per_sec: calls as f64 / wall, warm_host_reads }
}

/// One tenant, one tile size, warm repeats.
fn single_tile_warm() -> Row {
    let ctx = Context::new(DEVICES).with_arena(32 << 20).with_tile(TILES[0]);
    let mut t = tenant(7);
    call(&ctx, &mut t); // warm
    let start = Instant::now();
    let mut reads = 0;
    for _ in 0..2 * ROUNDS {
        reads += call(&ctx, &mut t);
    }
    row("single-tile warm", 2 * ROUNDS, start.elapsed().as_secs_f64(), reads)
}

/// Two tenants alternating tile sizes over ONE shared runtime.
fn mixed_tile_warm() -> Row {
    let ctx_a = Context::new(DEVICES).with_arena(32 << 20).with_tile(TILES[0]);
    let ctx_b = ctx_a.clone().with_tile(TILES[1]);
    let mut ta = tenant(8);
    let mut tb = tenant(9);
    call(&ctx_a, &mut ta); // one cold call per generation
    call(&ctx_b, &mut tb);
    let start = Instant::now();
    let mut reads = 0;
    for _ in 0..ROUNDS {
        reads += call(&ctx_a, &mut ta);
        reads += call(&ctx_b, &mut tb);
    }
    row("mixed-tile warm", 2 * ROUNDS, start.elapsed().as_secs_f64(), reads)
}

/// The purge-era floor: every switch pays full re-staging (fresh
/// one-shot engine per call, cold caches — the old runtime additionally
/// paid the admission-barrier drain).
fn mixed_tile_cold() -> Row {
    let mut ta = tenant(8);
    let mut tb = tenant(9);
    let start = Instant::now();
    let mut reads = 0;
    for _ in 0..ROUNDS {
        for (tile, t) in [(TILES[0], &mut ta), (TILES[1], &mut tb)] {
            let ctx = Context::new(DEVICES)
                .with_arena(32 << 20)
                .with_tile(tile)
                .with_persistent(false);
            reads += call(&ctx, t);
        }
    }
    row("mixed-tile cold (purge floor)", 2 * ROUNDS, start.elapsed().as_secs_f64(), reads)
}

/// Dispatcher hot-path overhead: warm calls with a profile entry
/// covering the shape vs the dispatch-free context.
fn overhead_probe() -> (f64, f64) {
    let warm_best = |ctx: &Context| {
        let mut t = tenant(10);
        call(ctx, &mut t);
        (0..5)
            .map(|_| {
                let t0 = Instant::now();
                call(ctx, &mut t);
                t0.elapsed().as_secs_f64()
            })
            .fold(f64::INFINITY, f64::min)
    };
    let plain = Context::new(DEVICES).with_arena(32 << 20).with_tile(TILES[0]);
    let base_ms = warm_best(&plain) * 1e3;
    let mut prof = Profile::new();
    prof.set(
        shape_key("gemm", Dtype::F64, N, N, N),
        Choice { t: TILES[0], kernel_threads: 1, mt_cutoff: None, place: Placement::Device },
    );
    let dispatched =
        Context::new(DEVICES).with_arena(32 << 20).with_tile(TILES[0]).with_profile(prof);
    let disp_ms = warm_best(&dispatched) * 1e3;
    (base_ms, disp_ms)
}

fn main() {
    let rows = vec![single_tile_warm(), mixed_tile_warm(), mixed_tile_cold()];
    let table: Vec<Vec<String>> = rows
        .iter()
        .map(|r| {
            vec![
                r.scenario.to_string(),
                r.calls.to_string(),
                format!("{:.1}", r.wall_ms),
                format!("{:.1}", r.calls_per_sec),
                r.warm_host_reads.to_string(),
            ]
        })
        .collect();
    print_table(
        "mixed-tile dispatch: alternating tile sizes over one resident runtime",
        &["scenario", "calls", "wall ms", "calls/s", "warm host reads"],
        &table,
    );
    let (base_ms, disp_ms) = overhead_probe();
    println!(
        "\ndispatch overhead probe: warm call {base_ms:.3} ms plain vs {disp_ms:.3} ms \
         with a profile-backed dispatcher on the hot path"
    );

    let mut json = Json::obj();
    json.set("bench", Json::Str("dispatch_mixed".into()));
    json.set("n", Json::Num(N as f64));
    json.set("devices", Json::Num(DEVICES as f64));
    json.set("tiles", Json::Arr(TILES.iter().map(|&t| Json::Num(t as f64)).collect()));
    json.set("rounds", Json::Num(ROUNDS as f64));
    let mut arr = Vec::new();
    for r in &rows {
        let mut o = Json::obj();
        o.set("scenario", Json::Str(r.scenario.into()));
        o.set("calls", Json::Num(r.calls as f64));
        o.set("wall_ms", Json::Num(r.wall_ms));
        o.set("calls_per_sec", Json::Num(r.calls_per_sec));
        o.set("warm_host_reads", Json::Num(r.warm_host_reads as f64));
        arr.push(o);
    }
    json.set("results", Json::Arr(arr));
    let mut probe = Json::obj();
    probe.set("warm_call_ms_plain", Json::Num(base_ms));
    probe.set("warm_call_ms_dispatched", Json::Num(disp_ms));
    json.set("overhead_probe", probe);
    write_json("BENCH_dispatch", &json);
    let root = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("../BENCH_dispatch.json");
    match std::fs::write(&root, json.to_string_pretty()) {
        Ok(()) => println!("[bench] wrote {}", root.display()),
        Err(e) => eprintln!("[bench] cannot write {}: {e}", root.display()),
    }
}
