//! Experiment E1 — paper Table I: the fraction of L3-BLAS flops executed
//! by the full-GEMM tile kernel, per routine, at N ∈ {5K, 10K, 20K}.
//!
//! The paper's claim: the GEMM share rises with N toward 100%, so L3
//! BLAS performance reduces to GEMM performance. Our numbers come
//! straight from the taskizer's flop accounting (no simulation needed).

use blasx::api::types::Routine;
use blasx::api::Dtype;
use blasx::bench::{print_table, write_json};
use blasx::coordinator::square_workload;
use blasx::util::json::Json;

fn main() {
    let t = 1024;
    let sizes = [5120usize, 10240, 20480];
    let routines =
        [Routine::Syrk, Routine::Trsm, Routine::Trmm, Routine::Syr2k, Routine::Symm];

    let mut rows = Vec::new();
    let mut json = Json::obj();
    for r in routines {
        let mut row = vec![r.name().to_uppercase()];
        let mut arr = Vec::new();
        for &n in &sizes {
            let w = square_workload(r, n, t, Dtype::F64);
            let pct = 100.0 * w.ts.gemm_fraction();
            row.push(format!("{pct:.1}%"));
            arr.push(Json::Num(pct));
        }
        json.set(r.name(), Json::Arr(arr));
        rows.push(row);
    }
    print_table(
        "Table I: GEMM percentage of L3 routines (paper: 68-93%, rising with N)",
        &["routine", "N=5K", "N=10K", "N=20K"],
        &rows,
    );
    write_json("table1_gemm_pct", &json);

    println!("\npaper reference (N=5K→20K): SYRK 74.5→92.8, TRSM 68.5→89,");
    println!("TRMM 69→92.8, SYR2K 74.4→92.9, SYMM 71.7→92.1");
}
