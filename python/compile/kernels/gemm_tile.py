"""Layer-1: the Pallas GEMM tile kernel — the paper's compute hot spot.

The paper's hot spot is cuBLAS DGEMM on a K40c (threadblock tiling,
shared-memory staging, warp-level MMA). This kernel re-expresses the same
insight for TPU (see DESIGN.md §Hardware-Adaptation):

* the ``pallas_call`` grid over ``(T/bm, T/bn, T/bk)`` plays the role of
  the CUDA threadblock grid;
* ``BlockSpec`` index maps express the HBM→VMEM staging schedule that CUDA
  did with ``cp.async`` into shared memory;
* the inner ``jnp.dot`` with ``preferred_element_type=f32`` targets the
  MXU systolic array (bf16/f32-friendly 128-aligned shapes);
* the accumulator lives in a VMEM scratch buffer across the k-steps of the
  grid's innermost dimension (double-buffering of the next A/B blocks is
  what the grid pipelining gives us for free on real hardware).

``interpret=True`` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls; interpret mode lowers to plain HLO so the artifact runs on
the Rust CPU client while keeping the *structure* a TPU would execute.

VMEM footprint at the default block (bm, bn, bk) = (128, 128, 128) in f32:
3 blocks live (A, B, acc) + the next (A, B) in flight = 5 * 64 KiB ≈ 320
KiB, far below the ~16 MiB VMEM budget — see DESIGN.md §Perf for the MXU
utilization estimate.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _matmul_kernel(a_ref, b_ref, o_ref, acc_ref, *, n_k: int):
    """One (i, j, k) grid step: acc += A[i,k] @ B[k,j]; flush at k == K-1.

    The grid iterates k innermost, so ``acc_ref`` (VMEM scratch) carries
    the running sum for the (i, j) output block across k steps.
    """
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _zero():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # MXU-shaped block product, accumulated at f32 (or f64 for DP tiles).
    acc_ref[...] += jnp.dot(
        a_ref[...], b_ref[...],
        preferred_element_type=acc_ref.dtype,
    )

    @pl.when(k == n_k - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def pick_blocks(m: int, n: int, k: int):
    """Largest MXU-aligned blocks that divide the tile.

    Tiles are powers of two in BLASX (default T = 1024 on the paper's
    machines, 256 in real-mode here), so 128-alignment holds whenever
    T >= 128; smaller tiles fall back to the tile itself (single block).
    """
    def pick(d):
        for b in (256, 128):
            if d % b == 0:
                return b
        return d
    return pick(m), pick(n), pick(k)


@functools.partial(jax.jit, static_argnames=("interpret",))
def matmul_tile(a, b, *, interpret: bool = True):
    """``a @ b`` over one tile pair via the Pallas blocked kernel."""
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, f"inner dims mismatch {k} vs {k2}"
    bm, bn, bk = pick_blocks(m, n, k)
    n_k = k // bk
    grid = (m // bm, n // bn, n_k)
    return pl.pallas_call(
        functools.partial(_matmul_kernel, n_k=n_k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), a.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), a.dtype)],
        interpret=interpret,
    )(a, b)


def gemm_update(a, b, c, alpha, beta, ta: str = "n", tb: str = "n",
                *, interpret: bool = True):
    """The full tile update ``c := alpha * op(a) @ op(b) + beta * c``.

    Transposes are resolved at trace time (the paper's §III-C trick: the
    runtime hands us the *raw* B_jk tile and asks for the ``t`` variant),
    the product runs through the Pallas kernel, and the axpby epilogue is
    fused by XLA into the same program.
    """
    at = a.T if ta == "t" else a
    bt = b.T if tb == "t" else b
    return alpha * matmul_tile(at, bt, interpret=interpret) + beta * c
