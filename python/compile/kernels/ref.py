"""Pure-jnp oracles for the tile kernels (the correctness reference).

Each function mirrors one tile-op variant from the Rust side's
``TileOp::kernel_name()`` vocabulary (see rust/src/task/op.rs): the
accumulator tile ``c`` is updated semantically in place, a new array is
returned. These are deliberately written with the most transparent jnp
expressions possible — no Pallas, no tiling — so they can serve as the
oracle for both the Pallas kernels (L1) and the lowered tile graphs (L2).

All functions take runtime scalars ``alpha``/``beta`` so a single lowered
artifact serves every invocation.
"""

import jax.numpy as jnp
from jax import lax


def _op(x, trans: str):
    """Apply a BLAS transpose flag ('n' or 't')."""
    return x.T if trans == "t" else x


def tri(a, uplo: str, diag: str):
    """Materialize the triangular operand tri(A) that TRMM/TRSM read."""
    n = a.shape[0]
    out = jnp.triu(a) if uplo == "up" else jnp.tril(a)
    if diag == "un":
        out = out - jnp.diag(jnp.diag(out)) + jnp.eye(n, dtype=a.dtype)
    return out


def sym(a, uplo: str):
    """Materialize sym(A): read the `uplo` triangle, mirror it."""
    if uplo == "up":
        u = jnp.triu(a)
        return u + u.T - jnp.diag(jnp.diag(a))
    lo = jnp.tril(a)
    return lo + lo.T - jnp.diag(jnp.diag(a))


# --- the tile-op vocabulary -------------------------------------------------

def gemm(a, b, c, alpha, beta, ta: str = "n", tb: str = "n"):
    """c := alpha * op(a) @ op(b) + beta * c   (the dominant kernel)."""
    return alpha * _op(a, ta) @ _op(b, tb) + beta * c


def syrk_diag(a, c, alpha, beta, trans: str = "n"):
    """Diagonal tile of SYRK: c := alpha * op(a) op(a)^T + beta * c.

    trans == 'n': A.A^T ; trans == 't': A^T.A. The full symmetric tile is
    produced; the Rust side's WriteMask stores only the requested triangle.
    """
    p = a @ a.T if trans == "n" else a.T @ a
    return alpha * p + beta * c


def syr2k_diag(a, b, c, alpha, beta, trans: str = "n"):
    """Diagonal tile of SYR2K: c := alpha*(op(a) op(b)^T + op(b) op(a)^T) + beta*c."""
    if trans == "n":
        p = a @ b.T + b @ a.T
    else:
        p = a.T @ b + b.T @ a
    return alpha * p + beta * c


def trmm_diag(a, c, alpha, side: str = "l", uplo: str = "up",
              ta: str = "n", diag: str = "nu"):
    """Diagonal tile of TRMM: c := alpha * op(tri(a)) @ c (left)
    or c := alpha * c @ op(tri(a)) (right)."""
    t = _op(tri(a, uplo, diag), ta)
    return alpha * (t @ c) if side == "l" else alpha * (c @ t)


def trsm_diag(a, c, alpha, side: str = "l", uplo: str = "up",
              ta: str = "n", diag: str = "nu"):
    """Diagonal tile of TRSM: solve op(tri(a)) X = alpha*c (left) or
    X op(tri(a)) = alpha*c (right); returns X.

    tri() already materializes the unit diagonal when diag == 'un', so the
    solve itself always runs in non-unit mode.
    """
    t = _op(tri(a, uplo, diag), ta)
    rhs = alpha * c
    lower = (uplo == "lo") != (ta == "t")
    return lax.linalg.triangular_solve(
        t, rhs, left_side=(side == "l"), lower=lower, unit_diagonal=False)


def symm_diag(a, b, c, alpha, beta, side: str = "l", uplo: str = "up"):
    """Diagonal tile of SYMM: c := alpha * sym(a) @ b + beta*c (left) or
    c := alpha * b @ sym(a) + beta*c (right)."""
    s = sym(a, uplo)
    p = s @ b if side == "l" else b @ s
    return alpha * p + beta * c


def scal(c, beta):
    """c := beta * c (alpha == 0 / k == 0 quick path)."""
    return beta * c
