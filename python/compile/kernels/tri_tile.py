"""Layer-1/2 boundary: diagonal-tile kernels (SYRK/SYR2K/TRMM/TRSM/SYMM).

Paper Table I shows the full-GEMM kernel dominates every L3 routine
(74–93% of flops already at N = 5K, rising with N); the diagonal-tile
specials are the residue. We therefore route every *product* through the
Pallas matmul kernel (the hot spot) and keep the cheap elementwise
structure ops — triangle masks, symmetrization, the small triangular
solve — as plain jnp/lax that XLA fuses around the Pallas call.

The mask construction uses ``broadcasted_iota`` comparisons, which is the
same row/col-predicate trick a TPU kernel would use in VMEM (there is no
gather/scatter on the MXU path); see DESIGN.md §Hardware-Adaptation.
"""

import jax.numpy as jnp
from jax import lax

from .gemm_tile import matmul_tile


def tri_mask(n: int, uplo: str, dtype):
    """1 inside the `uplo` triangle (diagonal included), else 0."""
    r = lax.broadcasted_iota(jnp.int32, (n, n), 0)
    c = lax.broadcasted_iota(jnp.int32, (n, n), 1)
    keep = (r <= c) if uplo == "up" else (r >= c)
    return keep.astype(dtype)


def tri_operand(a, uplo: str, diag: str):
    """tri(A): zero outside the triangle; force unit diagonal if asked."""
    n = a.shape[0]
    t = a * tri_mask(n, uplo, a.dtype)
    if diag == "un":
        r = lax.broadcasted_iota(jnp.int32, (n, n), 0)
        c = lax.broadcasted_iota(jnp.int32, (n, n), 1)
        eye = (r == c).astype(a.dtype)
        t = t * (1 - eye) + eye
    return t


def sym_operand(a, uplo: str):
    """sym(A): mirror the `uplo` triangle across the diagonal."""
    n = a.shape[0]
    m = tri_mask(n, uplo, a.dtype)
    r = lax.broadcasted_iota(jnp.int32, (n, n), 0)
    c = lax.broadcasted_iota(jnp.int32, (n, n), 1)
    eye = (r == c).astype(a.dtype)
    t = a * m
    return t + t.T - a * eye


def syrk_diag_update(a, c, alpha, beta, trans: str = "n", *, interpret=True):
    """c := alpha * op(a) op(a)^T + beta * c (full tile; Rust masks the store)."""
    at = a if trans == "n" else a.T
    return alpha * matmul_tile(at, at.T, interpret=interpret) + beta * c


def syr2k_diag_update(a, b, c, alpha, beta, trans: str = "n", *, interpret=True):
    """c := alpha*(op(a) op(b)^T + op(b) op(a)^T) + beta*c."""
    if trans == "n":
        p = matmul_tile(a, b.T, interpret=interpret) + matmul_tile(b, a.T, interpret=interpret)
    else:
        p = matmul_tile(a.T, b, interpret=interpret) + matmul_tile(b.T, a, interpret=interpret)
    return alpha * p + beta * c


def trmm_diag_update(a, c, alpha, side: str, uplo: str, ta: str, diag: str,
                     *, interpret=True):
    """c := alpha * op(tri(a)) @ c (left) or alpha * c @ op(tri(a)) (right)."""
    t = tri_operand(a, uplo, diag)
    if ta == "t":
        t = t.T
    p = matmul_tile(t, c, interpret=interpret) if side == "l" \
        else matmul_tile(c, t, interpret=interpret)
    return alpha * p


def _solve_lower_left(t_mat, b):
    """Forward substitution for lower-triangular ``t_mat @ X = b``.

    Written as a ``fori_loop`` of masked matvecs so it lowers to plain HLO
    (while + dot). ``lax.linalg.triangular_solve`` would emit a typed-FFI
    LAPACK custom-call that xla_extension 0.5.1 (the Rust runtime's XLA)
    refuses to compile; this form round-trips. O(T^3/2) work — the same
    as a native trsm and a negligible share of any task (paper Table I).
    """
    n = t_mat.shape[0]
    idx = lax.broadcasted_iota(jnp.int32, (n,), 0)

    def body(i, x):
        row = t_mat[i, :]
        mask = (idx < i).astype(t_mat.dtype)
        contrib = (row * mask) @ x  # rows >= i are masked out
        xi = (b[i, :] - contrib) / t_mat[i, i]
        return x.at[i, :].set(xi)

    return lax.fori_loop(0, n, body, jnp.zeros_like(b))


def trsm_diag_update(a, c, alpha, side: str, uplo: str, ta: str, diag: str):
    """Solve op(tri(a)) X = alpha*c (left) / X op(tri(a)) = alpha*c (right).

    Every case canonicalizes to the lower-left forward substitution:
    an upper-triangular solve is the reversal-conjugated lower solve
    (J U J is lower-triangular for the flip matrix J), and a right-side
    solve is the transposed left-side solve.
    """
    t = tri_operand(a, uplo, diag)
    if ta == "t":
        t = t.T
    lower = (uplo == "lo") != (ta == "t")
    rhs = alpha * c
    if side == "r":
        # X op(T) = rhs  <=>  op(T)^T X^T = rhs^T
        t, rhs, lower = t.T, rhs.T, not lower
    if not lower:
        # U x = b  <=>  (JUJ)(Jx) = Jb with J = index reversal
        t = jnp.flip(t, (0, 1))
        rhs = jnp.flip(rhs, 0)
    x = _solve_lower_left(t, rhs)
    if not lower:
        x = jnp.flip(x, 0)
    if side == "r":
        x = x.T
    return x


def symm_diag_update(a, b, c, alpha, beta, side: str, uplo: str, *, interpret=True):
    """c := alpha * sym(a) @ b + beta*c (left) / alpha * b @ sym(a) + beta*c."""
    s = sym_operand(a, uplo)
    p = matmul_tile(s, b, interpret=interpret) if side == "l" \
        else matmul_tile(b, s, interpret=interpret)
    return alpha * p + beta * c


def scal_update(c, beta):
    """c := beta * c."""
    return beta * c
