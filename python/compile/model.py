"""Layer-2: the tile-update graphs — one JAX function per tile-op variant.

BLASX's "model" is the tile update of Eq. 1: every k-step of every L3
routine is one of the functions registered here. The registry key is
exactly ``TileOp::kernel_name()`` on the Rust side (rust/src/task/op.rs),
so the coordinator can look artifacts up by name.

Every variant takes its tile operands plus *runtime* scalars alpha/beta —
one lowered artifact serves all scalar values. Argument order is recorded
in the manifest that ``aot.py`` writes next to the artifacts.

All product work inside these graphs runs through the Pallas kernel
(kernels/gemm_tile.py); see kernels/tri_tile.py for the diagonal-tile
split rationale.
"""

from .kernels import gemm_tile, tri_tile

# arity signature tags: which tile operands the variant consumes, in order.
# "a"/"b"/"c" are T x T tiles; scalars follow in the order listed.
ABC_AB = ("a", "b", "c", "alpha", "beta")   # gemm, syr2k, symm
AC_AB = ("a", "c", "alpha", "beta")         # syrk
AC_A = ("a", "c", "alpha")                  # trmm, trsm
C_B = ("c", "beta")                         # scal


def _gemm_variant(ta, tb):
    def fn(a, b, c, alpha, beta):
        return (gemm_tile.gemm_update(a, b, c, alpha, beta, ta, tb),)
    fn.__name__ = f"gemm_{ta}{tb}"
    return fn, ABC_AB


def _syrk_variant(trans):
    def fn(a, c, alpha, beta):
        return (tri_tile.syrk_diag_update(a, c, alpha, beta, trans),)
    fn.__name__ = f"syrk_{trans}"
    return fn, AC_AB


def _syr2k_variant(trans):
    def fn(a, b, c, alpha, beta):
        return (tri_tile.syr2k_diag_update(a, b, c, alpha, beta, trans),)
    fn.__name__ = f"syr2k_{trans}"
    return fn, ABC_AB


def _trmm_variant(side, uplo, ta, diag):
    def fn(a, c, alpha):
        return (tri_tile.trmm_diag_update(a, c, alpha, side, uplo, ta, diag),)
    fn.__name__ = f"trmm_{side}_{uplo}_{ta}_{diag}"
    return fn, AC_A


def _trsm_variant(side, uplo, ta, diag):
    def fn(a, c, alpha):
        return (tri_tile.trsm_diag_update(a, c, alpha, side, uplo, ta, diag),)
    fn.__name__ = f"trsm_{side}_{uplo}_{ta}_{diag}"
    return fn, AC_A


def _symm_variant(side, uplo):
    def fn(a, b, c, alpha, beta):
        return (tri_tile.symm_diag_update(a, b, c, alpha, beta, side, uplo),)
    fn.__name__ = f"symm_{side}_{uplo}"
    return fn, ABC_AB


def _scal():
    def fn(c, beta):
        return (tri_tile.scal_update(c, beta),)
    fn.__name__ = "scal"
    return fn, C_B


def build_registry():
    """kernel_name -> (jax_fn, arg_signature).

    Names stay in lockstep with ``TileOp::kernel_name()``:
    gemm_{n|t}{n|t}, syrk_{up|lo}_{n|t}, syr2k_{up|lo}_{n|t},
    trmm_{l|r}_{up|lo}_{n|t}_{nu|un}, trsm_…, symm_{l|r}_{up|lo}, scal.

    SYRK/SYR2K compute the full symmetric tile (the Rust WriteMask stores
    only the triangle), so both uplo spellings map to the same graph.
    """
    reg = {}
    for ta in "nt":
        for tb in "nt":
            fn, sig = _gemm_variant(ta, tb)
            reg[f"gemm_{ta}{tb}"] = (fn, sig)
    for uplo in ("up", "lo"):
        for trans in "nt":
            fn, sig = _syrk_variant(trans)
            reg[f"syrk_{uplo}_{trans}"] = (fn, sig)
            fn2, sig2 = _syr2k_variant(trans)
            reg[f"syr2k_{uplo}_{trans}"] = (fn2, sig2)
    for side in "lr":
        for uplo in ("up", "lo"):
            for ta in "nt":
                for diag in ("nu", "un"):
                    fn, sig = _trmm_variant(side, uplo, ta, diag)
                    reg[f"trmm_{side}_{uplo}_{ta}_{diag}"] = (fn, sig)
                    fn2, sig2 = _trsm_variant(side, uplo, ta, diag)
                    reg[f"trsm_{side}_{uplo}_{ta}_{diag}"] = (fn2, sig2)
            fn, sig = _symm_variant(side, uplo)
            reg[f"symm_{side}_{uplo}"] = (fn, sig)
    fn, sig = _scal()
    reg["scal"] = (fn, sig)
    return reg


REGISTRY = build_registry()
