"""AOT compiler: lower every tile-op variant to HLO text artifacts.

Run once at ``make artifacts``:

    cd python && python -m compile.aot --out ../artifacts

Per (variant, dtype, tile-size) it writes ``<name>_<dtype>_<T>.hlo.txt``
plus a single ``manifest.json`` describing every artifact's argument
signature, so the Rust runtime (rust/src/runtime/) can marshal literals
without any Python at run time.

Interchange format is HLO **text**, not a serialized HloModuleProto:
jax >= 0.5 emits protos with 64-bit instruction ids which xla_extension
0.5.1 (behind the published ``xla`` crate) rejects; the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).
"""

import argparse
import json
import os
import sys

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp  # noqa: E402
from jax._src.lib import xla_client as xc  # noqa: E402

from .model import REGISTRY  # noqa: E402

DTYPES = {"f32": jnp.float32, "f64": jnp.float64}

# The default artifact set the Rust runtime expects. Real-mode tile size
# is 256 (CPU-budget analogue of the paper's 1024 on K40c — same
# VMEM-pressure shape, tractable single-core wall-clock); 64 is built for
# the fast test grid.
DEFAULT_TILES = (64, 256)
DEFAULT_DTYPES = ("f32", "f64")


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def example_args(sig, t: int, dtype):
    """ShapeDtypeStructs for one artifact's signature."""
    tile = jax.ShapeDtypeStruct((t, t), dtype)
    scalar = jax.ShapeDtypeStruct((), dtype)
    return tuple(tile if s in ("a", "b", "c") else scalar for s in sig)


def lower_variant(name: str, t: int, dt_name: str):
    """Lower one (variant, tile, dtype) to HLO text. Returns (text, sig)."""
    fn, sig = REGISTRY[name]
    args = example_args(sig, t, DTYPES[dt_name])
    lowered = jax.jit(fn).lower(*args)
    return to_hlo_text(lowered), sig


def build(out_dir: str, tiles, dtypes, names=None, quiet=False):
    os.makedirs(out_dir, exist_ok=True)
    manifest = {"tile_sizes": sorted(tiles), "dtypes": sorted(dtypes),
                "kernels": {}}
    # A partial rebuild (--only) must not orphan the other variants'
    # artifacts: merge into the existing manifest.
    man_path = os.path.join(out_dir, "manifest.json")
    if names is not None and os.path.exists(man_path):
        with open(man_path) as f:
            old = json.load(f)
        manifest["kernels"].update(old.get("kernels", {}))
        manifest["tile_sizes"] = sorted(set(old.get("tile_sizes", [])) | set(tiles))
        manifest["dtypes"] = sorted(set(old.get("dtypes", [])) | set(dtypes))
    todo = sorted(names or REGISTRY.keys())
    n_done = 0
    for name in todo:
        _, sig = REGISTRY[name]
        manifest["kernels"][name] = {"args": list(sig)}
        for dt_name in dtypes:
            for t in tiles:
                text, _ = lower_variant(name, t, dt_name)
                fname = f"{name}_{dt_name}_{t}.hlo.txt"
                with open(os.path.join(out_dir, fname), "w") as f:
                    f.write(text)
                n_done += 1
                if not quiet:
                    print(f"  [{n_done}] {fname} ({len(text)} chars)",
                          file=sys.stderr)
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
    if not quiet:
        print(f"wrote {n_done} artifacts + manifest.json to {out_dir}",
              file=sys.stderr)


def main():
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--out", default="../artifacts",
                   help="artifact output directory")
    p.add_argument("--tiles", default=",".join(str(t) for t in DEFAULT_TILES),
                   help="comma-separated tile sizes")
    p.add_argument("--dtypes", default=",".join(DEFAULT_DTYPES),
                   help="comma-separated dtypes (f32,f64)")
    p.add_argument("--only", default=None,
                   help="comma-separated variant names (default: all)")
    p.add_argument("--quiet", action="store_true")
    args = p.parse_args()
    tiles = tuple(int(x) for x in args.tiles.split(","))
    dtypes = tuple(args.dtypes.split(","))
    names = args.only.split(",") if args.only else None
    build(args.out, tiles, dtypes, names, args.quiet)


if __name__ == "__main__":
    main()
