"""AOT path: every registered variant lowers to loadable HLO text and the
lowered computation (executed through jax itself) matches the oracle.

This is the L2 correctness gate: what the Rust runtime loads is exactly
what these tests validate, so an artifact regression fails here first.
"""

import os

import jax
import numpy as np
import pytest

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp  # noqa: E402

from compile import aot, model  # noqa: E402
from compile.kernels import ref  # noqa: E402

RNG = np.random.default_rng(7)


def test_registry_complete():
    reg = model.REGISTRY
    # 4 gemm + 4 syrk + 4 syr2k + 16 trmm + 16 trsm + 4 symm + 1 scal
    assert len(reg) == 49
    for name in ("gemm_nn", "gemm_nt", "gemm_tn", "gemm_tt",
                 "syrk_up_n", "syr2k_lo_t", "trmm_l_up_n_nu",
                 "trsm_r_lo_t_un", "symm_r_lo", "scal"):
        assert name in reg, name


def test_registry_names_match_rust_vocabulary():
    # Spellings the Rust TileOp::kernel_name() emits (op.rs tests pin the
    # same strings on the other side).
    for side in "lr":
        for uplo in ("up", "lo"):
            for ta in "nt":
                for diag in ("nu", "un"):
                    assert f"trmm_{side}_{uplo}_{ta}_{diag}" in model.REGISTRY
                    assert f"trsm_{side}_{uplo}_{ta}_{diag}" in model.REGISTRY


@pytest.mark.parametrize("name", sorted(model.REGISTRY.keys()))
def test_every_variant_lowers(name):
    text, sig = aot.lower_variant(name, 32, "f64")
    assert text.startswith("HloModule")
    assert "f64[32,32]" in text
    # signature sanity: tiles then scalars
    tiles = [s for s in sig if s in ("a", "b", "c")]
    assert tiles and sig[: len(tiles)] == tuple(tiles)


@pytest.mark.parametrize("name,args,oracle", [
    ("gemm_nt", ("a", "b", "c"), lambda a, b, c: ref.gemm(a, b, c, 1.5, -0.5, "n", "t")),
    ("syrk_up_t", ("a", "c"), lambda a, c: ref.syrk_diag(a, c, 1.5, -0.5, "t")),
    ("symm_l_up", ("a", "b", "c"), lambda a, b, c: ref.symm_diag(a, b, c, 1.5, -0.5, "l", "up")),
])
def test_lowered_graph_executes_correctly(name, args, oracle):
    """Compile the same jitted fn jax-side and compare to the oracle —
    the HLO the artifact contains is this exact computation."""
    fn, sig = model.REGISTRY[name]
    t = 32
    tiles = {k: jnp.asarray(RNG.standard_normal((t, t)), jnp.float64)
             for k in args}
    call = []
    for s in sig:
        if s in tiles:
            call.append(tiles[s])
        elif s == "alpha":
            call.append(jnp.float64(1.5))
        else:
            call.append(jnp.float64(-0.5))
    (got,) = jax.jit(fn)(*call)
    want = oracle(*(tiles[k] for k in args))
    np.testing.assert_allclose(got, want, atol=1e-9 * t)


def test_build_writes_manifest(tmp_path):
    aot.build(str(tmp_path), tiles=(32,), dtypes=("f64",),
              names=["gemm_nn", "scal"], quiet=True)
    files = sorted(os.listdir(tmp_path))
    assert files == ["gemm_nn_f64_32.hlo.txt", "manifest.json",
                     "scal_f64_32.hlo.txt"]
    import json
    man = json.loads((tmp_path / "manifest.json").read_text())
    assert man["kernels"]["gemm_nn"]["args"] == ["a", "b", "c", "alpha", "beta"]
    assert man["tile_sizes"] == [32]
