"""L2 performance sanity: structural checks on the lowered HLO.

The paper's L2 target (DESIGN.md §Perf): no redundant recomputation, the
product fused around a single dot per matmul, and the artifact's flop
content matching the analytic count. We check the HLO text itself — the
exact artifact the Rust runtime executes.
"""

import re

import jax
import pytest

jax.config.update("jax_enable_x64", True)

from compile import aot  # noqa: E402


def count_ops(hlo: str, op: str) -> int:
    return len(re.findall(rf"\b{op}\(", hlo))


def test_gemm_artifact_has_single_fused_dot():
    hlo, _ = aot.lower_variant("gemm_nn", 64, "f64")
    # one dot for the product — no duplicated compute
    assert count_ops(hlo, "dot") == 1, f"{count_ops(hlo, 'dot')} dots"
    # the alpha/beta epilogue must not spawn extra full-tile copies of
    # the product: multiplies stay elementwise (fusable)
    assert "f64[64,64]" in hlo


@pytest.mark.parametrize("name,max_dots", [
    ("gemm_nt", 1),
    ("syrk_up_n", 1),      # A·Aᵀ — one dot
    ("syr2k_up_n", 2),     # A·Bᵀ + B·Aᵀ — XLA CSEs the second product
                           # to transpose(first), so 1 dot in practice
    ("symm_l_up", 1),      # sym(A)·B
    ("trmm_l_up_n_nu", 1), # tri(A)·C
])
def test_product_op_counts(name, max_dots):
    hlo, _ = aot.lower_variant(name, 64, "f64")
    n = count_ops(hlo, "dot")
    assert 1 <= n <= max_dots, f"{name}: {n} dots"


def test_trsm_artifact_is_loop_not_custom_call():
    """The solve must lower to a while-loop of dots (pure HLO): a LAPACK
    custom-call would be rejected by the Rust runtime's XLA 0.5.1."""
    hlo, _ = aot.lower_variant("trsm_l_up_n_nu", 64, "f64")
    assert "custom-call" not in hlo, "custom-call cannot round-trip"
    assert count_ops(hlo, "while") >= 1


@pytest.mark.parametrize("t", [64, 256])
def test_no_custom_calls_anywhere(t):
    """Every artifact variant must stay custom-call-free (the CPU PJRT
    plugin cannot execute Mosaic/LAPACK custom calls)."""
    from compile.model import REGISTRY
    # spot-check the structurally distinct families (full sweep runs in
    # test_aot.py::test_every_variant_lowers at t=32)
    for name in ["gemm_tt", "syrk_lo_t", "syr2k_lo_t", "trmm_r_lo_t_un",
                 "trsm_r_lo_t_un", "symm_r_lo", "scal"]:
        assert name in REGISTRY
        hlo, _ = aot.lower_variant(name, t, "f64")
        assert "custom-call" not in hlo, name


def test_scal_is_trivially_small():
    hlo, _ = aot.lower_variant("scal", 256, "f64")
    assert count_ops(hlo, "dot") == 0
    assert len(hlo) < 2000, "scal artifact should be a single multiply"
