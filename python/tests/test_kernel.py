"""L1 correctness: the Pallas tile kernels vs the pure-jnp oracle.

This is the core numerics signal of the whole stack: the Rust runtime
executes exactly these graphs (AOT-lowered), so Pallas == ref here means
the coordinator computes correct tiles there.
"""

import jax
import numpy as np
import pytest

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp  # noqa: E402

from compile.kernels import gemm_tile, ref, tri_tile  # noqa: E402

RNG = np.random.default_rng(0xB1A5)


def rand(shape, dtype):
    return jnp.asarray(RNG.standard_normal(shape), dtype)


def tol(dtype):
    return 2e-4 if dtype == jnp.float32 else 1e-10


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.float64])
@pytest.mark.parametrize("t", [32, 64, 128])
@pytest.mark.parametrize("ta,tb", [("n", "n"), ("n", "t"), ("t", "n"), ("t", "t")])
def test_gemm_update_matches_ref(t, dtype, ta, tb):
    a, b, c = (rand((t, t), dtype) for _ in range(3))
    got = gemm_tile.gemm_update(a, b, c, 1.25, -0.5, ta, tb)
    want = ref.gemm(a, b, c, 1.25, -0.5, ta, tb)
    np.testing.assert_allclose(got, want, atol=tol(dtype) * t)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.float64])
def test_gemm_nonsquare_blocks(dtype):
    # matmul_tile itself handles rectangular operands (L2 uses it for
    # masked triangular products where shapes stay square, but the kernel
    # must not silently assume m == n == k).
    a = rand((128, 64), dtype)
    b = rand((64, 256), dtype)
    got = gemm_tile.matmul_tile(a, b)
    np.testing.assert_allclose(got, a @ b, atol=tol(dtype) * 64)


@pytest.mark.parametrize("trans", ["n", "t"])
@pytest.mark.parametrize("t", [32, 64])
def test_syrk_diag(t, trans):
    a, c = rand((t, t), jnp.float64), rand((t, t), jnp.float64)
    got = tri_tile.syrk_diag_update(a, c, 0.7, 1.1, trans)
    want = ref.syrk_diag(a, c, 0.7, 1.1, trans)
    np.testing.assert_allclose(got, want, atol=1e-10 * t)
    # result (at beta=0) must be symmetric
    sym = tri_tile.syrk_diag_update(a, jnp.zeros_like(c), 1.0, 0.0, trans)
    np.testing.assert_allclose(sym, sym.T, atol=1e-12 * t)


@pytest.mark.parametrize("trans", ["n", "t"])
@pytest.mark.parametrize("t", [32, 64])
def test_syr2k_diag(t, trans):
    a, b, c = (rand((t, t), jnp.float64) for _ in range(3))
    got = tri_tile.syr2k_diag_update(a, b, c, -0.3, 0.9, trans)
    want = ref.syr2k_diag(a, b, c, -0.3, 0.9, trans)
    np.testing.assert_allclose(got, want, atol=1e-10 * t)


@pytest.mark.parametrize("side", ["l", "r"])
@pytest.mark.parametrize("uplo", ["up", "lo"])
@pytest.mark.parametrize("ta", ["n", "t"])
@pytest.mark.parametrize("diag", ["nu", "un"])
def test_trmm_diag(side, uplo, ta, diag):
    t = 32
    a, c = rand((t, t), jnp.float64), rand((t, t), jnp.float64)
    got = tri_tile.trmm_diag_update(a, c, 1.5, side, uplo, ta, diag)
    want = ref.trmm_diag(a, c, 1.5, side, uplo, ta, diag)
    np.testing.assert_allclose(got, want, atol=1e-10 * t)


@pytest.mark.parametrize("side", ["l", "r"])
@pytest.mark.parametrize("uplo", ["up", "lo"])
@pytest.mark.parametrize("ta", ["n", "t"])
@pytest.mark.parametrize("diag", ["nu", "un"])
def test_trsm_diag_solves(side, uplo, ta, diag):
    t = 32
    a = rand((t, t), jnp.float64) + 4.0 * jnp.eye(t)  # well-conditioned
    c = rand((t, t), jnp.float64)
    x = tri_tile.trsm_diag_update(a, c, 2.0, side, uplo, ta, diag)
    # verify against the defining equation, not another solver
    tri_a = ref.tri(a, uplo, diag)
    opa = tri_a.T if ta == "t" else tri_a
    lhs = opa @ x if side == "l" else x @ opa
    np.testing.assert_allclose(lhs, 2.0 * c, atol=1e-9 * t)


@pytest.mark.parametrize("side", ["l", "r"])
@pytest.mark.parametrize("uplo", ["up", "lo"])
def test_symm_diag(side, uplo):
    t = 64
    a, b, c = (rand((t, t), jnp.float64) for _ in range(3))
    got = tri_tile.symm_diag_update(a, b, c, 0.25, -1.0, side, uplo)
    want = ref.symm_diag(a, b, c, 0.25, -1.0, side, uplo)
    np.testing.assert_allclose(got, want, atol=1e-10 * t)


def test_scal():
    c = rand((64, 64), jnp.float64)
    np.testing.assert_allclose(tri_tile.scal_update(c, 0.5), 0.5 * c)
    np.testing.assert_allclose(tri_tile.scal_update(c, 0.0), jnp.zeros_like(c))


def test_operand_builders():
    a = rand((16, 16), jnp.float64)
    np.testing.assert_allclose(tri_tile.tri_operand(a, "up", "nu"), jnp.triu(a))
    np.testing.assert_allclose(tri_tile.sym_operand(a, "lo"), ref.sym(a, "lo"))
    un = tri_tile.tri_operand(a, "lo", "un")
    np.testing.assert_allclose(jnp.diag(un), jnp.ones(16))
    np.testing.assert_allclose(jnp.tril(un, -1), jnp.tril(a, -1))
    np.testing.assert_allclose(jnp.triu(un, 1), jnp.zeros((16, 16)))


def test_identity_padding_is_exact_for_trsm():
    # The Rust runtime pads edge tiles: zero-pad C, identity-pad the
    # triangular diagonal tile. The padded solve must embed the unpadded
    # solve exactly.
    t, h = 32, 20
    a = rand((h, h), jnp.float64) + 4.0 * jnp.eye(h)
    c = rand((h, h), jnp.float64)
    want = ref.trsm_diag(a, c, 1.0, "l", "up", "n", "nu")

    a_pad = jnp.eye(t, dtype=jnp.float64).at[:h, :h].set(a)
    c_pad = jnp.zeros((t, t), jnp.float64).at[:h, :h].set(c)
    got = tri_tile.trsm_diag_update(a_pad, c_pad, 1.0, "l", "up", "n", "nu")
    np.testing.assert_allclose(got[:h, :h], want, atol=1e-9 * t)
    np.testing.assert_allclose(got[h:, :], jnp.zeros((t - h, t)), atol=1e-12)


def test_zero_padding_is_exact_for_gemm():
    t, h, w, kk = 32, 20, 24, 16
    a = rand((h, kk), jnp.float64)
    b = rand((kk, w), jnp.float64)
    c = rand((h, w), jnp.float64)
    a_pad = jnp.zeros((t, t), jnp.float64).at[:h, :kk].set(a)
    b_pad = jnp.zeros((t, t), jnp.float64).at[:kk, :w].set(b)
    c_pad = jnp.zeros((t, t), jnp.float64).at[:h, :w].set(c)
    got = gemm_tile.gemm_update(a_pad, b_pad, c_pad, 1.5, 0.5, "n", "n")
    want = ref.gemm(a, b, c, 1.5, 0.5)
    np.testing.assert_allclose(got[:h, :w], want, atol=1e-10 * t)
