"""Hypothesis sweeps over the Pallas kernel's shape/dtype/scalar space.

Property-based companion to test_kernel.py: instead of a fixed grid,
hypothesis drives (m, n, k, dtype, alpha, beta, transposes) and asserts
the Pallas path tracks the oracle everywhere — including the awkward
non-128-aligned shapes the block picker has to fall back on.
"""

import jax
import numpy as np
from hypothesis import given, settings, strategies as st

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp  # noqa: E402

from compile.kernels import gemm_tile, ref, tri_tile  # noqa: E402

# Dimensions: mix of powers of two (the fast path) and odd sizes (the
# fallback path where the block = the whole dim).
DIMS = st.sampled_from([8, 16, 24, 32, 48, 64, 96, 128])
SCALARS = st.floats(min_value=-2.0, max_value=2.0,
                    allow_nan=False, allow_infinity=False)
DTYPES = st.sampled_from(["f32", "f64"])
TRANS = st.sampled_from(["n", "t"])


def _mk(rng, shape, dt):
    x = rng.standard_normal(shape)
    return jnp.asarray(x, jnp.float32 if dt == "f32" else jnp.float64)


def _tol(dt, k):
    return (1e-3 if dt == "f32" else 1e-9) * max(k, 1)


@settings(max_examples=25, deadline=None)
@given(m=DIMS, n=DIMS, k=DIMS, dt=DTYPES, alpha=SCALARS, beta=SCALARS,
       ta=TRANS, tb=TRANS, seed=st.integers(0, 2**32 - 1))
def test_gemm_update_property(m, n, k, dt, alpha, beta, ta, tb, seed):
    rng = np.random.default_rng(seed)
    a = _mk(rng, (m, k) if ta == "n" else (k, m), dt)
    b = _mk(rng, (k, n) if tb == "n" else (n, k), dt)
    c = _mk(rng, (m, n), dt)
    got = gemm_tile.gemm_update(a, b, c, alpha, beta, ta, tb)
    want = ref.gemm(a, b, c, alpha, beta, ta, tb)
    np.testing.assert_allclose(got, want, atol=_tol(dt, k))


@settings(max_examples=15, deadline=None)
@given(t=DIMS, dt=DTYPES, alpha=SCALARS, beta=SCALARS, trans=TRANS,
       seed=st.integers(0, 2**32 - 1))
def test_syrk_diag_property(t, dt, alpha, beta, trans, seed):
    rng = np.random.default_rng(seed)
    a, c = _mk(rng, (t, t), dt), _mk(rng, (t, t), dt)
    got = tri_tile.syrk_diag_update(a, c, alpha, beta, trans)
    want = ref.syrk_diag(a, c, alpha, beta, trans)
    np.testing.assert_allclose(got, want, atol=_tol(dt, t))


@settings(max_examples=15, deadline=None)
@given(t=DIMS, side=st.sampled_from(["l", "r"]),
       uplo=st.sampled_from(["up", "lo"]), ta=TRANS,
       diag=st.sampled_from(["nu", "un"]),
       seed=st.integers(0, 2**32 - 1))
def test_trsm_diag_property(t, side, uplo, ta, diag, seed):
    rng = np.random.default_rng(seed)
    # Random triangular matrices are exponentially ill-conditioned in t;
    # damp the off-diagonal mass so the residual check stays meaningful.
    a = _mk(rng, (t, t), "f64") / np.sqrt(t) + 2.0 * jnp.eye(t)
    c = _mk(rng, (t, t), "f64")
    x = tri_tile.trsm_diag_update(a, c, 1.0, side, uplo, ta, diag)
    tri_a = ref.tri(a, uplo, diag)
    opa = tri_a.T if ta == "t" else tri_a
    lhs = opa @ x if side == "l" else x @ opa
    np.testing.assert_allclose(lhs, c, atol=1e-8 * t)


@settings(max_examples=15, deadline=None)
@given(t=DIMS, side=st.sampled_from(["l", "r"]),
       uplo=st.sampled_from(["up", "lo"]), alpha=SCALARS, beta=SCALARS,
       seed=st.integers(0, 2**32 - 1))
def test_symm_diag_property(t, side, uplo, alpha, beta, seed):
    rng = np.random.default_rng(seed)
    a, b, c = (_mk(rng, (t, t), "f64") for _ in range(3))
    got = tri_tile.symm_diag_update(a, b, c, alpha, beta, side, uplo)
    want = ref.symm_diag(a, b, c, alpha, beta, side, uplo)
    np.testing.assert_allclose(got, want, atol=1e-9 * t)
