/* smoke.c — drop-in C client for libblasx.
 *
 * Exercises the blocking CBLAS surface and the asynchronous job API,
 * including an aliasing dgemm -> dtrsm chain on one buffer (ordered by
 * the runtime's admission table). Verifies against naive references;
 * exits non-zero on any mismatch.
 *
 * Build & run (from the repo root, after `cargo build --release`):
 *   cc examples/c/smoke.c -Iinclude -Lrust/target/release -lblasx \
 *      -lm -o smoke
 *   LD_LIBRARY_PATH=rust/target/release ./smoke
 */
#include <math.h>
#include <stdio.h>
#include <stdlib.h>
#include <string.h>

#include "blasx.h"

#define N 64

/* column-major naive references ------------------------------------- */

static void ref_gemm(int n, double alpha, const double *a, const double *b,
                     double beta, double *c) {
    for (int j = 0; j < n; j++)
        for (int i = 0; i < n; i++) {
            double acc = 0.0;
            for (int l = 0; l < n; l++) acc += a[l * n + i] * b[j * n + l];
            c[j * n + i] = alpha * acc + beta * c[j * n + i];
        }
}

/* forward substitution for upper-triangular T x = b, column-wise */
static void ref_trsm_upper(int n, const double *t, double *b) {
    for (int j = 0; j < n; j++) {
        double *col = b + (size_t)j * n;
        for (int i = n - 1; i >= 0; i--) {
            double acc = col[i];
            for (int l = i + 1; l < n; l++) acc -= t[l * n + i] * col[l];
            col[i] = acc / t[i * n + i];
        }
    }
}

static double max_abs_diff(const double *x, const double *y, size_t n) {
    double m = 0.0;
    for (size_t i = 0; i < n; i++) {
        double d = fabs(x[i] - y[i]);
        if (d > m) m = d;
    }
    return m;
}

static void fill(double *x, size_t n, unsigned *seed) {
    for (size_t i = 0; i < n; i++) {
        *seed = *seed * 1664525u + 1013904223u;
        x[i] = ((double)(*seed >> 8) / (double)(1u << 24)) - 0.5;
    }
}

static int failures = 0;
static void check(const char *name, double diff, double tol) {
    printf("  %-34s diff %.3e  %s\n", name, diff, diff < tol ? "OK" : "FAILED");
    if (!(diff < tol)) failures++;
}

int main(void) {
    /* Explicit configuration — must precede every other BLASX entry.
     * Zero-init means "all defaults"; we pin the fleet shape so the
     * smoke run is independent of BLASX_* environment knobs. */
    blasx_config_t cfg = {0};
    cfg.devices = 2;
    cfg.arena_mb = 32;
    cfg.prefetch = 4; /* lookahead transfer pipeline on: results must
                       * be bit-identical to prefetch off */
    if (blasx_init(&cfg) != BLASX_OK) {
        char msg[256];
        blasx_last_error(msg, sizeof msg);
        fprintf(stderr, "blasx_init failed: %s\n", msg);
        return 1;
    }
    printf("%s C smoke client\n", blasx_version());
    unsigned seed = 2015;
    size_t bytes = (size_t)N * N * sizeof(double);
    double *a = malloc(bytes), *b = malloc(bytes), *c = malloc(bytes);
    double *want = malloc(bytes), *t = malloc(bytes);
    if (!a || !b || !c || !want || !t) return 2;
    fill(a, (size_t)N * N, &seed);
    fill(b, (size_t)N * N, &seed);
    fill(c, (size_t)N * N, &seed);

    /* 1. blocking cblas_dgemm (column-major) */
    memcpy(want, c, bytes);
    cblas_dgemm(CblasColMajor, CblasNoTrans, CblasNoTrans, N, N, N, 1.5, a, N,
                b, N, -0.5, c, N);
    ref_gemm(N, 1.5, a, b, -0.5, want);
    check("cblas_dgemm", max_abs_diff(c, want, (size_t)N * N), 1e-10);

    /* 2. asynchronous aliasing chain: C := A*B, then solve T X = C in
     *    place on the same buffer. The runtime's admission edges order
     *    the two jobs; waits may complete out of order. */
    fill(t, (size_t)N * N, &seed);
    for (int i = 0; i < N; i++) t[i * N + i] = 2.0 + fabs(t[i * N + i]);
    memset(c, 0, bytes);
    blasx_job_t *j1 = blasx_dgemm_async(CblasColMajor, CblasNoTrans,
                                        CblasNoTrans, N, N, N, 1.0, a, N, b, N,
                                        0.0, c, N);
    blasx_job_t *j2 = blasx_dtrsm_async(CblasColMajor, CblasLeft, CblasUpper,
                                        CblasNoTrans, CblasNonUnit, N, N, 1.0,
                                        t, N, c, N);
    if (!j1 || !j2) {
        char msg[256];
        blasx_last_error(msg, sizeof msg);
        fprintf(stderr, "async submission failed: %s\n", msg);
        return 1;
    }
    /* live observability: blasx_job_stats is valid any time between
     * submit and wait (counters are monotone). Once j2 retires, j1 has
     * too (the chain edge orders them), so its counters are final. */
    blasx_stats_t live;
    if (blasx_job_stats(j1, &live) != BLASX_OK) {
        fprintf(stderr, "blasx_job_stats failed on a live handle\n");
        return 1;
    }
    while (blasx_job_done(j2) == 0) { /* spin: the smoke problem is tiny */ }
    if (blasx_job_stats(j1, &live) != BLASX_OK) {
        fprintf(stderr, "blasx_job_stats failed on a retired handle\n");
        return 1;
    }
    printf("  gemm job stats: tasks %llu  host reads A/B/C %llu/%llu/%llu  "
           "peer %llu  L1 hits %llu  steals %llu\n",
           (unsigned long long)live.tasks, (unsigned long long)live.host_reads_a,
           (unsigned long long)live.host_reads_b, (unsigned long long)live.host_reads_c,
           (unsigned long long)live.peer_copies, (unsigned long long)live.l1_hits,
           (unsigned long long)live.steals);
    /* the fault-recovery ledger: all zero on this healthy run, nonzero
     * under a BLASX_FAULTS chaos schedule */
    printf("  fault ledger:   retried %llu  degraded %llu  migrated %llu\n",
           (unsigned long long)live.retried, (unsigned long long)live.degraded,
           (unsigned long long)live.migrated);
    /* the transfer pipeline's lookahead ledger (cfg.prefetch above) */
    printf("  prefetch:       hits %llu  wasted %llu\n",
           (unsigned long long)live.prefetch_hits,
           (unsigned long long)live.prefetch_wasted);
    if (live.tasks == 0) {
        fprintf(stderr, "retired gemm job reports zero tasks\n");
        failures++;
    }
    int s2 = blasx_wait(j2); /* newest first: order must not matter */
    int s1 = blasx_wait(j1);
    if (s1 != BLASX_OK || s2 != BLASX_OK) {
        fprintf(stderr, "blasx_wait: %d / %d\n", s1, s2);
        return 1;
    }
    memset(want, 0, bytes);
    ref_gemm(N, 1.0, a, b, 0.0, want);
    ref_trsm_upper(N, t, want);
    check("async dgemm->dtrsm chain", max_abs_diff(c, want, (size_t)N * N),
          1e-9);

    /* 3. input mutation + declaration (the host-liveness contract) */
    for (size_t i = 0; i < (size_t)N * N; i++) a[i] *= 2.0;
    blasx_invalidate_host(a, bytes);
    memcpy(c, want, bytes);
    memcpy(want, c, bytes);
    cblas_dgemm(CblasColMajor, CblasNoTrans, CblasNoTrans, N, N, N, 1.0, a, N,
                b, N, 0.25, c, N);
    ref_gemm(N, 1.0, a, b, 0.25, want);
    check("post-invalidate cblas_dgemm", max_abs_diff(c, want, (size_t)N * N),
          1e-10);

    /* 4. cooperative cancellation: re-run the chain but cancel the
     *    solve. Cancellation is honoured at a round boundary, so the
     *    trsm either aborts with BLASX_ERR_CANCELLED (buffer holds the
     *    gemm result) or won the race and finished (buffer holds the
     *    chain result) — both are verified, anything else fails. */
    memset(c, 0, bytes);
    blasx_job_t *j3 = blasx_dgemm_async(CblasColMajor, CblasNoTrans,
                                        CblasNoTrans, N, N, N, 1.0, a, N, b, N,
                                        0.0, c, N);
    blasx_job_t *j4 = blasx_dtrsm_async(CblasColMajor, CblasLeft, CblasUpper,
                                        CblasNoTrans, CblasNonUnit, N, N, 1.0,
                                        t, N, c, N);
    if (!j3 || !j4) {
        fprintf(stderr, "async submission failed in cancel section\n");
        return 1;
    }
    blasx_job_cancel(j4);
    blasx_job_cancel(j4); /* idempotent */
    int s3 = blasx_wait(j3);
    int s4 = blasx_wait(j4);
    if (s3 != BLASX_OK) {
        fprintf(stderr, "predecessor of a cancelled job failed: %d\n", s3);
        return 1;
    }
    memset(want, 0, bytes);
    ref_gemm(N, 1.0, a, b, 0.0, want);
    if (s4 == BLASX_OK) {
        ref_trsm_upper(N, t, want); /* cancel lost the race: full chain */
    } else if (s4 != BLASX_ERR_CANCELLED) {
        fprintf(stderr, "cancelled job reported %d, want %d or %d\n", s4,
                BLASX_ERR_CANCELLED, BLASX_OK);
        return 1;
    }
    check(s4 == BLASX_OK ? "cancel raced: chain intact"
                         : "cancelled solve left gemm result",
          max_abs_diff(c, want, (size_t)N * N), 1e-9);

    /* 5. live telemetry through the C ABI: the same Prometheus text
     *    `blasx serve --telemetry-addr` exposes at /metrics. Call with
     *    (NULL, 0) to size the buffer, then fetch. */
    size_t need = blasx_telemetry_text(NULL, 0);
    char *metrics = malloc(need + 1);
    if (!metrics || blasx_telemetry_text(metrics, need + 1) != need ||
        strstr(metrics, "blasx_up 1") == NULL) {
        fprintf(stderr, "blasx_telemetry_text: bad scrape\n");
        failures++;
    } else {
        printf("  %-34s %zu bytes  OK\n", "blasx_telemetry_text scrape", need);
    }
    free(metrics);

    blasx_shutdown();
    free(a); free(b); free(c); free(want); free(t);
    if (failures) {
        fprintf(stderr, "%d check(s) FAILED\n", failures);
        return 1;
    }
    printf("all checks passed\n");
    return 0;
}
