//! End-to-end driver (paper §V-C(a), experiment E12): train a Caffe-style
//! MLP where EVERY dense product is a BLASX `sgemm` call — the library's
//! drop-in-replacement claim, exercised on a real training loop.
//!
//! Architecture (scaled from the paper's 3072→16384→16384→10 to this
//! single-core testbed): 3072 → H → H → 10, ReLU, softmax cross-entropy,
//! plain SGD. Synthetic CIFAR-like data is produced by a fixed random
//! teacher network so the loss actually has structure to learn.
//!
//! ```text
//! cargo run --release --example ann_training -- [steps] [H] [batch] [--pjrt]
//! ```
//!
//! The `--pjrt` flag routes all tile kernels through the AOT Pallas
//! artifacts (L1 Pallas → L2 JAX → HLO → PJRT), proving the three-layer
//! stack composes on a real workload; default is the hostblas backend
//! for wall-clock sanity on the 1-core CI box. Loss curve is logged and
//! recorded in EXPERIMENTS.md.

use blasx::api::types::Trans;
use blasx::api::{self, Context};
use blasx::coordinator::Backend;
use blasx::util::prng::Prng;

/// C := alpha * op(A) op(B) + beta*C through BLASX.
#[allow(clippy::too_many_arguments)]
fn mm(
    ctx: &Context,
    ta: Trans,
    tb: Trans,
    m: usize,
    n: usize,
    k: usize,
    alpha: f32,
    a: &[f32],
    b: &[f32],
    beta: f32,
    c: &mut [f32],
) {
    let lda = if ta == Trans::No { m } else { k };
    let ldb = if tb == Trans::No { k } else { n };
    api::sgemm(ctx, ta, tb, m, n, k, alpha, a, lda, b, ldb, beta, c, m).expect("sgemm");
}

struct Mlp {
    w1: Vec<f32>, // h x d
    w2: Vec<f32>, // h x h
    w3: Vec<f32>, // 10 x h
    d: usize,
    h: usize,
    classes: usize,
}

impl Mlp {
    fn new(d: usize, h: usize, classes: usize, rng: &mut Prng) -> Mlp {
        let mut init = |rows: usize, cols: usize| {
            let mut w = vec![0.0f32; rows * cols];
            let s = (2.0 / cols as f64).sqrt() as f32;
            rng.fill_f32(&mut w, -s, s);
            w
        };
        Mlp { w1: init(h, d), w2: init(h, h), w3: init(classes, h), d, h, classes }
    }

    fn params(&self) -> usize {
        self.w1.len() + self.w2.len() + self.w3.len()
    }
}

fn relu_inplace(x: &mut [f32]) {
    for v in x.iter_mut() {
        if *v < 0.0 {
            *v = 0.0;
        }
    }
}

/// Softmax cross-entropy: returns mean loss; writes dlogits (prob - y).
fn softmax_xent(logits: &mut [f32], labels: &[usize], classes: usize, batch: usize) -> f32 {
    let mut loss = 0.0f64;
    for s in 0..batch {
        let col = &mut logits[s * classes..(s + 1) * classes];
        let mx = col.iter().cloned().fold(f32::MIN, f32::max);
        let mut z = 0.0f32;
        for v in col.iter_mut() {
            *v = (*v - mx).exp();
            z += *v;
        }
        for v in col.iter_mut() {
            *v /= z;
        }
        loss -= (col[labels[s]].max(1e-12) as f64).ln();
        col[labels[s]] -= 1.0; // dlogits = prob - onehot
    }
    (loss / batch as f64) as f32
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let steps: usize = args.first().and_then(|s| s.parse().ok()).unwrap_or(300);
    let h: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(512);
    let batch: usize = args.get(2).and_then(|s| s.parse().ok()).unwrap_or(128);
    let use_pjrt = args.iter().any(|a| a == "--pjrt");

    let d = 3072; // CIFAR-10 input dim (32*32*3)
    let classes = 10;
    let mut ctx = Context::new(2).with_tile(256);
    if use_pjrt {
        ctx = ctx.with_backend(Backend::Pjrt);
    }

    let mut rng = Prng::new(0xCAFE);
    let mut net = Mlp::new(d, h, classes, &mut rng);
    // fixed random teacher generates labels => learnable structure
    let teacher = Mlp::new(d, 64, classes, &mut rng);

    println!(
        "ANN {d}->{h}->{h}->{classes}, {} params, batch {batch}, {} backend",
        net.params(),
        if use_pjrt { "PJRT(Pallas artifacts)" } else { "hostblas" }
    );

    let lr = 0.05f32 / batch as f32;
    let t0 = std::time::Instant::now();
    for step in 0..steps {
        // --- synthetic batch from the teacher
        let mut x = vec![0.0f32; d * batch];
        rng.fill_f32(&mut x, -1.0, 1.0);
        // x is a fresh allocation with new contents every step (and the
        // allocator may reuse last step's address): declare it to the
        // persistent runtime so no stale tiles survive. Within the
        // step, the three products reading x then share its cached
        // tiles for free. (The activations/gradients are outputs first
        // — their invalidation epochs bump automatically.)
        ctx.invalidate_host(&x);
        let labels: Vec<usize> = {
            let mut th = vec![0.0f32; teacher.h * batch];
            mm(&ctx, Trans::No, Trans::No, teacher.h, batch, d, 1.0, &teacher.w1, &x, 0.0, &mut th);
            relu_inplace(&mut th);
            let mut tl = vec![0.0f32; classes * batch];
            mm(&ctx, Trans::No, Trans::No, classes, batch, teacher.h, 1.0, &teacher.w3, &th, 0.0, &mut tl);
            (0..batch)
                .map(|s| {
                    let col = &tl[s * classes..(s + 1) * classes];
                    col.iter().enumerate().max_by(|a, b| a.1.total_cmp(b.1)).unwrap().0
                })
                .collect()
        };

        // --- forward: every product is a BLASX sgemm
        let mut h1 = vec![0.0f32; h * batch];
        mm(&ctx, Trans::No, Trans::No, h, batch, d, 1.0, &net.w1, &x, 0.0, &mut h1);
        relu_inplace(&mut h1);
        let mut h2 = vec![0.0f32; h * batch];
        mm(&ctx, Trans::No, Trans::No, h, batch, h, 1.0, &net.w2, &h1, 0.0, &mut h2);
        relu_inplace(&mut h2);
        let mut logits = vec![0.0f32; classes * batch];
        mm(&ctx, Trans::No, Trans::No, classes, batch, h, 1.0, &net.w3, &h2, 0.0, &mut logits);

        let loss = softmax_xent(&mut logits, &labels, classes, batch);
        let dlogits = logits; // renamed: now holds prob - y

        // --- backward
        // dW3 = dlogits h2^T ; dh2 = W3^T dlogits
        let mut dh2 = vec![0.0f32; h * batch];
        mm(&ctx, Trans::Yes, Trans::No, h, batch, classes, 1.0, &net.w3, &dlogits, 0.0, &mut dh2);
        let mut dw3 = vec![0.0f32; classes * h];
        mm(&ctx, Trans::No, Trans::Yes, classes, h, batch, 1.0, &dlogits, &h2, 0.0, &mut dw3);
        for (v, g) in dh2.iter_mut().zip(&h2) {
            if *g <= 0.0 {
                *v = 0.0; // relu'
            }
        }
        let mut dh1 = vec![0.0f32; h * batch];
        mm(&ctx, Trans::Yes, Trans::No, h, batch, h, 1.0, &net.w2, &dh2, 0.0, &mut dh1);
        let mut dw2 = vec![0.0f32; h * h];
        mm(&ctx, Trans::No, Trans::Yes, h, h, batch, 1.0, &dh2, &h1, 0.0, &mut dw2);
        for (v, g) in dh1.iter_mut().zip(&h1) {
            if *g <= 0.0 {
                *v = 0.0;
            }
        }
        let mut dw1 = vec![0.0f32; h * d];
        mm(&ctx, Trans::No, Trans::Yes, h, d, batch, 1.0, &dh1, &x, 0.0, &mut dw1);

        // --- SGD
        for (w, g) in net.w1.iter_mut().zip(&dw1) {
            *w -= lr * g;
        }
        for (w, g) in net.w2.iter_mut().zip(&dw2) {
            *w -= lr * g;
        }
        for (w, g) in net.w3.iter_mut().zip(&dw3) {
            *w -= lr * g;
        }
        // SGD mutated the weights in place — tell the warm runtime so
        // the next step's forward pass re-reads them. The fixed teacher
        // weights are never declared: their tiles stay cached across
        // every step (that cross-call reuse is the resident runtime's
        // whole point).
        ctx.invalidate_host(&net.w1);
        ctx.invalidate_host(&net.w2);
        ctx.invalidate_host(&net.w3);

        if step < 5 || step % 20 == 0 || step == steps - 1 {
            println!("step {step:4}  loss {loss:.4}  ({:.1}s elapsed)", t0.elapsed().as_secs_f64());
        }
    }
    let secs = t0.elapsed().as_secs_f64();
    // fwd+bwd flops: 2*(3 fwd + 3 bwd-ish) gemms dominated by h*d and h*h terms
    let flops_per_step = 2.0
        * batch as f64
        * (2.0 * (h * d) as f64 + 2.0 * (h * h) as f64 + 2.0 * (classes * h) as f64
            + (64 * d + 64 * classes) as f64);
    println!(
        "done: {steps} steps in {secs:.1}s  ({:.2} GFLOPS sustained)",
        flops_per_step * steps as f64 / secs / 1e9
    );
}
