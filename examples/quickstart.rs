//! Quickstart: BLASX as a drop-in BLAS — one DGEMM call, verified.
//!
//! ```text
//! cargo run --release --example quickstart
//! ```
//!
//! The call taskizes C := alpha*A*B + beta*C into tile tasks, runs them
//! across the virtual devices of the default [`blasx::api::Context`]
//! (two devices, ALRU tile caches, work stealing — the whole paper
//! stack), and writes the result back into `c`. The caller sees plain
//! BLAS semantics, per the paper's backward-compatibility claim (§I).

use blasx::api::types::Trans;
use blasx::api::{self, Context};
use blasx::hostblas;
use blasx::util::prng::Prng;
use blasx::util::stats::gflops;

fn main() {
    let n = 1024;
    let ctx = Context::default(); // 2 devices, T=256, hostblas kernels

    let mut rng = Prng::new(2015);
    let mut a = vec![0.0f64; n * n];
    let mut b = vec![0.0f64; n * n];
    let mut c = vec![0.0f64; n * n];
    rng.fill_f64(&mut a, -1.0, 1.0);
    rng.fill_f64(&mut b, -1.0, 1.0);
    rng.fill_f64(&mut c, -1.0, 1.0);
    let c0 = c.clone();

    let start = std::time::Instant::now();
    let report = api::dgemm(
        &ctx,
        Trans::No,
        Trans::No,
        n,
        n,
        n,
        1.5,
        &a,
        n,
        &b,
        n,
        -0.5,
        &mut c,
        n,
    )
    .expect("dgemm");
    let secs = start.elapsed().as_secs_f64();

    println!("DGEMM {n}x{n}x{n}: {:.3}s  ({:.2} GFLOPS)", secs, gflops(2.0 * (n as f64).powi(3), secs));
    println!("tasks per device: {:?}", report.tasks_per_device);
    println!("cache activity this call: {:?}", report.cache_delta);

    // verify against the single-threaded host oracle
    let mut want = c0;
    hostblas::gemm_blocked(Trans::No, Trans::No, n, n, n, 1.5, &a, n, &b, n, -0.5, &mut want, n);
    let diff = c.iter().zip(&want).map(|(x, y)| (x - y).abs()).fold(0.0, f64::max);
    println!("max |diff| vs oracle: {diff:.3e}");
    assert!(diff < 1e-9, "numerics drifted");
    println!("quickstart OK");
}
