//! Heterogeneity study (paper §V, Fig. 7 Makalu analysis, experiment
//! E14): on a machine mixing strong-DP Keplers with weak-DP Maxwells,
//! demand-driven scheduling keeps scaling while static partitions clog
//! the slow devices.
//!
//! ```text
//! cargo run --release --example heterogeneous -- [n]
//! ```
//!
//! Runs DGEMM on simulated Makalu with 1–4 GPUs under BLASX and the
//! static baselines, printing achieved GFLOPS and the per-device task
//! split — the TITAN X devices (190 DP GFLOPS vs the K40's 1200) should
//! receive proportionally fewer tasks under BLASX, while cuBLAS-XT's
//! round-robin forces 25% onto each and stalls the fast cards.

use blasx::api::types::Routine;
use blasx::api::Dtype;
use blasx::coordinator::{run_sim, square_workload, Policy, RunConfig};
use blasx::sim::makalu;
use blasx::trace::balance_gap;

fn main() {
    let n: usize = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(16384);
    let t = 1024;
    let w = square_workload(Routine::Gemm, n, t, Dtype::F64);
    let flops = w.total_flops();

    println!("DGEMM N={n} T={t} on simulated Makalu (2x K40c + 2x TITAN X)");
    println!();
    println!("gpus  policy       GFLOPS   balance-gap  tasks per device");

    for gpus in 1..=4 {
        let machine = makalu(gpus);
        for policy in [Policy::Blasx, Policy::CublasXt, Policy::Parsec] {
            let cfg = RunConfig { t, policy, ..Default::default() };
            let rep = run_sim(&cfg, &machine, &w);
            if !rep.feasible {
                println!("{gpus:>4}  {:<11}  {:>7}   {:>10}  infeasible", policy.name(), "N/A", "-");
                continue;
            }
            println!(
                "{gpus:>4}  {:<11}  {:>7.0}   {:>9.4}s  {:?}",
                policy.name(),
                rep.gflops(flops),
                balance_gap(&rep.trace),
                rep.tasks_per_worker,
            );
        }
        println!();
    }

    // The paper's headline: BLASX speedup stays near-linear in *useful*
    // compute (adding two 0.19 TF cards to two 1.2 TF cards adds ~16%
    // DP capacity — linear speedup means tracking that capacity curve).
    let cap1 = 1200.0;
    let cap: Vec<f64> = vec![cap1, 2.0 * cap1, 2.0 * cap1 + 190.0, 2.0 * cap1 + 380.0];
    println!("DP capacity curve (GFLOPS): {cap:?}");
    println!("BLASX should track it; static round-robin should fall off at 3-4 GPUs.");
}
