//! Out-of-core operation (paper §IV-D, §V-A): BLASX keeps the operands
//! in host RAM and streams tiles, so problems far larger than device
//! memory still run — where in-core designs (PaRSEC, MAGMA) hit a wall
//! at `3·N²·8 > VRAM` (N > 22528 on a 12 GB K40).
//!
//! Two demonstrations:
//!
//! 1. **Simulated paper scale**: DGEMM N=24576 (13.5 GB of operands) on
//!    Everest — BLASX and cuBLAS-XT run out-of-core; the PaRSEC- and
//!    MAGMA-like baselines report infeasible, matching the paper's
//!    truncated curves in Fig. 7.
//! 2. **Real numerics under pressure**: a DGEMM whose tile working set
//!    is 30× the device arena, forcing continuous ALRU eviction, with
//!    the result verified against the host oracle.
//!
//! ```text
//! cargo run --release --example out_of_core
//! ```

use blasx::api::types::{Routine, Trans};
use blasx::api::Dtype;
use blasx::coordinator::real_engine::{run_real, Mats};
use blasx::coordinator::{run_sim, square_workload, Policy, RunConfig};
use blasx::hostblas;
use blasx::sim::everest;
use blasx::task::{taskize_gemm, GemmDesc};
use blasx::tile::{HostMat, MatId};
use blasx::util::prng::Prng;
use blasx::util::stats::fmt_bytes;

fn main() {
    // ---- 1. paper-scale out-of-core sim
    let n = 24576;
    let t = 1024;
    println!(
        "DGEMM N={n}: operands {} vs 12 GiB VRAM",
        fmt_bytes((3 * n * n * 8) as u64)
    );
    let w = square_workload(Routine::Gemm, n, t, Dtype::F64);
    let machine = everest(3);
    for policy in [Policy::Blasx, Policy::CublasXt, Policy::Parsec, Policy::Magma] {
        let cfg = RunConfig { t, policy, ..Default::default() };
        let rep = run_sim(&cfg, &machine, &w);
        if rep.feasible {
            println!("  {:<12} {:>8.0} GFLOPS (out-of-core)", policy.name(), rep.gflops(w.total_flops()));
        } else {
            println!("  {:<12} {:>8} (in-core only: 3N²·8 exceeds VRAM)", policy.name(), "N/A");
        }
    }

    // ---- 2. real numerics under heavy eviction
    println!();
    let (m2, t2) = (640, 64);
    let arena = 12 * t2 * t2 * 8; // 12 tiles vs 100-tile working set
    println!(
        "real-mode DGEMM {m2}x{m2}x{m2}, arena {} per device ({} tiles) — forcing eviction",
        fmt_bytes(arena as u64),
        arena / (t2 * t2 * 8)
    );
    let mut p = Prng::new(7);
    let mut a = vec![0.0; m2 * m2];
    let mut b = vec![0.0; m2 * m2];
    let mut c = vec![0.0; m2 * m2];
    p.fill_f64(&mut a, -1.0, 1.0);
    p.fill_f64(&mut b, -1.0, 1.0);
    p.fill_f64(&mut c, -1.0, 1.0);
    let mut want = c.clone();

    let d = GemmDesc { ta: Trans::No, tb: Trans::No, m: m2, n: m2, k: m2, alpha: 1.0, beta: 1.0, t: t2 };
    let ts = taskize_gemm(&d);
    let am = HostMat::new_ro(&a, m2, m2, m2, t2, MatId::A);
    let bm = HostMat::new_ro(&b, m2, m2, m2, t2, MatId::B);
    let cm = HostMat::new(&mut c, m2, m2, m2, t2, MatId::C);
    let cfg = RunConfig { t: t2, ..Default::default() };
    let rep = run_real(&cfg, &ts, Mats { a: &am, b: Some(&bm), c: &cm }, 2, arena).expect("run");
    println!("  cache stats per device: {:?}", rep.cache_delta);
    assert!(
        rep.cache_delta.iter().any(|s| s.evictions > 0),
        "expected evictions under pressure"
    );

    hostblas::gemm_blocked(Trans::No, Trans::No, m2, m2, m2, 1.0, &a, m2, &b, m2, 1.0, &mut want, m2);
    let diff = c.iter().zip(&want).map(|(x, y)| (x - y).abs()).fold(0.0, f64::max);
    println!("  max |diff| vs oracle: {diff:.3e}");
    assert!(diff < 1e-9);
    println!("out_of_core OK");
}
