//! Perf probe: isolate the real-engine overhead vs the raw host kernel.
use blasx::api::types::Trans;
use blasx::api::{self, Context};
use blasx::hostblas;
use blasx::util::prng::Prng;
use blasx::util::stats::gflops;

fn main() {
    let n = 1024;
    let mut p = Prng::new(1);
    let mut a = vec![0.0f64; n * n];
    let mut b = vec![0.0f64; n * n];
    let mut c = vec![0.0f64; n * n];
    p.fill_f64(&mut a, -1.0, 1.0);
    p.fill_f64(&mut b, -1.0, 1.0);
    let flops = 2.0 * (n as f64).powi(3);

    // raw single-thread blocked kernel (roofline for this box)
    let t0 = std::time::Instant::now();
    hostblas::gemm_blocked(Trans::No, Trans::No, n, n, n, 1.0, &a, n, &b, n, 0.0, &mut c, n);
    let raw = t0.elapsed().as_secs_f64();
    println!("hostblas 1-thread:      {:.3}s {:.2} GF", raw, gflops(flops, raw));

    // runtime, 1 device (pure overhead vs raw)
    for devices in [1usize, 2, 4] {
        for t in [128usize, 256, 512] {
            let ctx = Context::new(devices).with_tile(t);
            let t0 = std::time::Instant::now();
            api::dgemm(&ctx, Trans::No, Trans::No, n, n, n, 1.0, &a, n, &b, n, 0.0, &mut c, n).unwrap();
            let s = t0.elapsed().as_secs_f64();
            println!("runtime dev={devices} T={t}:  {:.3}s {:.2} GF  (x{:.2} vs raw)", s, gflops(flops, s), s / raw);
        }
    }
}
