//! LAPACK-on-BLASX composability (paper §V-C: finite-element analysis in
//! structural mechanics): a right-looking *tiled Cholesky* factorization
//! where every panel update is a BLASX L3 call — dpotrf built from
//! `dsyrk` + `dgemm` + `dtrsm`, then a stiffness-system solve.
//!
//! ```text
//! cargo run --release --example cholesky_fea -- [n] [t]
//! ```
//!
//! This is the adoption story of the paper's §V-C in miniature: a legacy
//! blocked algorithm written against plain BLAS gets the multi-device
//! runtime (caches, stealing, out-of-core tiles) by relinking, with no
//! algorithmic change.

use blasx::api::types::{Diag, Side, Trans, Uplo};
use blasx::api::{self, Context};
use blasx::hostblas;
use blasx::util::prng::Prng;
use blasx::util::stats::gflops;

/// Unblocked Cholesky of the leading `nb × nb` block (column-major,
/// lower triangle) — the only non-BLAS kernel, O(nb³) on an nb ≪ n tile.
fn potf2_lower(a: &mut [f64], n: usize, off_r: usize, off_c: usize, nb: usize, ld: usize) {
    let _ = n;
    for j in 0..nb {
        let jj = (off_c + j) * ld + off_r + j;
        let mut d = a[jj];
        for k in 0..j {
            let v = a[(off_c + k) * ld + off_r + j];
            d -= v * v;
        }
        assert!(d > 0.0, "matrix not positive definite at column {j}");
        let d = d.sqrt();
        a[jj] = d;
        for i in (j + 1)..nb {
            let mut v = a[(off_c + j) * ld + off_r + i];
            for k in 0..j {
                v -= a[(off_c + k) * ld + off_r + i] * a[(off_c + k) * ld + off_r + j];
            }
            a[(off_c + j) * ld + off_r + i] = v / d;
        }
    }
}

/// Right-looking blocked Cholesky, panel width `nb`: every trailing
/// update is a BLASX call.
fn potrf_blasx(ctx: &Context, a: &mut Vec<f64>, n: usize, nb: usize) {
    let ld = n;
    let mut j = 0;
    while j < n {
        let jb = nb.min(n - j);
        // diagonal block: unblocked factor
        potf2_lower(a, n, j, j, jb, ld);
        if j + jb < n {
            let rest = n - j - jb;
            // panel: A[j+jb.., j..j+jb] := A[..] * L_jj^-T   (dtrsm)
            let (head, tail) = a.split_at_mut((j) * ld + j + jb);
            let _ = (head, tail);
            // Safe re-borrow: BLASX takes disjoint slices; we pass the
            // whole buffer with offsets via raw indexing below.
            let ajj: Vec<f64> = (0..jb * jb)
                .map(|idx| a[(j + idx / jb) * ld + j + idx % jb])
                .collect();
            // `ajj` is a fresh nb×nb copy every panel — same byte size
            // each time, so the allocator may hand back the previous
            // panel's address with new contents. Declare it to the
            // persistent runtime's cross-call tile cache. (The other
            // temporaries are either outputs — epoch-bumped
            // automatically — or change leading dimension per panel.)
            ctx.invalidate_host(&ajj);
            let mut panel: Vec<f64> = (0..rest * jb)
                .map(|idx| a[(j + idx / rest) * ld + j + jb + idx % rest])
                .collect();
            api::trsm(
                ctx,
                Side::Right,
                Uplo::Lower,
                Trans::Yes,
                Diag::NonUnit,
                rest,
                jb,
                1.0,
                &ajj,
                jb,
                &mut panel,
                rest,
            )
            .expect("trsm");
            for (idx, v) in panel.iter().enumerate() {
                a[(j + idx / rest) * ld + j + jb + idx % rest] = *v;
            }
            // trailing update: A22 := A22 - L21 L21^T   (dsyrk)
            let mut a22: Vec<f64> = (0..rest * rest)
                .map(|idx| a[(j + jb + idx / rest) * ld + j + jb + idx % rest])
                .collect();
            api::syrk(ctx, Uplo::Lower, Trans::No, rest, jb, -1.0, &panel, rest, 1.0, &mut a22, rest)
                .expect("syrk");
            for (idx, v) in a22.iter().enumerate() {
                a[(j + jb + idx / rest) * ld + j + jb + idx % rest] = *v;
            }
        }
        j += jb;
    }
}

fn main() {
    let n: usize = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(768);
    let nb: usize = std::env::args().nth(2).and_then(|s| s.parse().ok()).unwrap_or(128);
    let ctx = Context::new(2).with_tile(64);

    // SPD "stiffness" matrix: K = B Bᵀ + n·I (diagonally dominant)
    let mut rng = Prng::new(0xFEA);
    let mut b = vec![0.0f64; n * n];
    rng.fill_f64(&mut b, -1.0, 1.0);
    let mut k = vec![0.0f64; n * n];
    hostblas::gemm_blocked(Trans::No, Trans::Yes, n, n, n, 1.0, &b, n, &b, n, 0.0, &mut k, n);
    for i in 0..n {
        k[i * n + i] += n as f64;
    }
    let k0 = k.clone();

    // factor K = L Lᵀ with BLASX doing the heavy lifting
    let t0 = std::time::Instant::now();
    potrf_blasx(&ctx, &mut k, n, nb);
    let secs = t0.elapsed().as_secs_f64();
    let flops = (n as f64).powi(3) / 3.0;
    println!("tiled Cholesky n={n} nb={nb}: {secs:.3}s ({:.2} GFLOPS)", gflops(flops, secs));

    // verify: L Lᵀ == K (lower triangle of L is in `k`)
    let mut l = vec![0.0f64; n * n];
    for c in 0..n {
        for r in c..n {
            l[c * n + r] = k[c * n + r];
        }
    }
    let mut llt = vec![0.0f64; n * n];
    hostblas::gemm_blocked(Trans::No, Trans::Yes, n, n, n, 1.0, &l, n, &l, n, 0.0, &mut llt, n);
    let mut max_diff = 0.0f64;
    for c in 0..n {
        for r in c..n {
            // compare lower triangle (K is symmetric)
            max_diff = max_diff.max((llt[c * n + r] - k0[c * n + r]).abs());
        }
    }
    println!("||L L^T - K||_max = {max_diff:.3e} (tolerance scaled by n)");
    assert!(max_diff < 1e-8 * n as f64, "factorization drifted");

    // solve K x = f via the factor: L y = f; Lᵀ x = y  (two dtrsm calls)
    let mut f = vec![0.0f64; n];
    rng.fill_f64(&mut f, -1.0, 1.0);
    let mut x = f.clone();
    api::trsm(&ctx, Side::Left, Uplo::Lower, Trans::No, Diag::NonUnit, n, 1, 1.0, &l, n, &mut x, n)
        .expect("forward solve");
    api::trsm(&ctx, Side::Left, Uplo::Lower, Trans::Yes, Diag::NonUnit, n, 1, 1.0, &l, n, &mut x, n)
        .expect("back solve");
    // residual ||K x - f||
    let mut kx = vec![0.0f64; n];
    hostblas::gemm_blocked(Trans::No, Trans::No, n, 1, n, 1.0, &k0, n, &x, n, 0.0, &mut kx, n);
    let res = kx.iter().zip(&f).map(|(a, b)| (a - b).abs()).fold(0.0, f64::max);
    println!("||K x - f||_max = {res:.3e}");
    assert!(res < 1e-7 * n as f64);
    println!("cholesky_fea OK — dpotrf/dpotrs built entirely on BLASX calls");
}
