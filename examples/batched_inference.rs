//! Batched inference: an ANN-serving workload through `dgemm_batched`.
//!
//! ```text
//! cargo run --release --example batched_inference
//! ```
//!
//! Model: one dense layer `Y_i := X_i · W` applied to a queue of
//! requests with ragged token counts (1–48 tokens each). Two properties
//! of the batch subsystem do the heavy lifting:
//!
//! - all requests fuse into ONE scheduler invocation — taskization,
//!   cache warm-up and stream setup are paid once, and the scheduling
//!   quanta interleave requests so every virtual device works from the
//!   first round;
//! - every request multiplies the SAME weight matrix `W`, and tiles are
//!   cache-keyed by host address, so W's tiles are fetched into each
//!   device once and hit for every subsequent request (cross-problem
//!   reuse for free — visible in the report's hit counts).
//!
//! The result is verified against looping the single-call API, which is
//! bit-for-bit identical by construction.

use blasx::api::{self, Context, GemmBatchEntry};
use blasx::api::types::Trans;
use blasx::util::prng::Prng;
use blasx::util::stats::gflops;

fn main() {
    let hidden = 192; // k: model width
    let out = 128; // n: layer output width
    let requests = 48;
    let ctx = Context::new(2).with_tile(64).with_arena(16 << 20);

    // ragged request queue: m_i tokens each
    let mut rng = Prng::new(2015);
    let entries: Vec<GemmBatchEntry> = (0..requests)
        .map(|_| GemmBatchEntry::new(1 + rng.below(48), out, hidden, 1.0, 0.0))
        .collect();

    // one shared weight matrix, per-request activations/outputs
    let mut w = vec![0.0f64; hidden * out];
    rng.fill_f64(&mut w, -0.1, 0.1);
    let mut xs: Vec<Vec<f64>> = Vec::with_capacity(requests);
    let mut ys: Vec<Vec<f64>> = Vec::with_capacity(requests);
    let mut total_flops = 0.0;
    for e in &entries {
        let mut x = vec![0.0f64; e.m * hidden];
        rng.fill_f64(&mut x, -1.0, 1.0);
        xs.push(x);
        ys.push(vec![0.0f64; e.m * out]);
        total_flops += 2.0 * (e.m * e.n * e.k) as f64;
    }

    // -- fused: the whole request queue in one call
    let arefs: Vec<&[f64]> = xs.iter().map(Vec::as_slice).collect();
    let brefs: Vec<&[f64]> = entries.iter().map(|_| w.as_slice()).collect();
    let mut crefs: Vec<&mut [f64]> = ys.iter_mut().map(Vec::as_mut_slice).collect();
    let start = std::time::Instant::now();
    let report = api::dgemm_batched(&ctx, &entries, &arefs, &brefs, &mut crefs).expect("batched");
    let fused_secs = start.elapsed().as_secs_f64();
    drop(crefs);

    println!(
        "fused:  {requests} requests ({} total tokens) in {fused_secs:.4}s  ({:.2} GFLOPS)",
        entries.iter().map(|e| e.m).sum::<usize>(),
        gflops(total_flops, fused_secs)
    );
    println!("  tasks/device {:?}  steals {:?}", report.tasks_per_device, report.steals);
    println!("  cache activity this call: {:?}", report.cache_delta);

    // -- looped single calls: identical numerics, N scheduler ramp-ups
    let mut ys_loop: Vec<Vec<f64>> = entries.iter().map(|e| vec![0.0f64; e.m * out]).collect();
    let start = std::time::Instant::now();
    for (i, e) in entries.iter().enumerate() {
        api::dgemm(
            &ctx, Trans::No, Trans::No, e.m, e.n, e.k, 1.0, &xs[i], e.lda, &w, e.ldb, 0.0,
            &mut ys_loop[i], e.ldc,
        )
        .expect("dgemm");
    }
    let looped_secs = start.elapsed().as_secs_f64();
    println!(
        "looped: {requests} requests in {looped_secs:.4}s  ({:.2} GFLOPS)",
        gflops(total_flops, looped_secs)
    );

    for (i, (y, yl)) in ys.iter().zip(&ys_loop).enumerate() {
        assert_eq!(y, yl, "request {i}: fused result differs from looped single calls");
    }
    println!("verification: fused == looped single calls, bit for bit");
    if looped_secs > 0.0 && fused_secs > 0.0 {
        println!("wall-clock speedup on this host: {:.2}x", looped_secs / fused_secs);
    }
    println!("batched_inference OK");
}
