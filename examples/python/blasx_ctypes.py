#!/usr/bin/env python3
"""Drop-in use of libblasx from Python via ctypes — no bindings, just
the C ABI (the same surface a legacy CBLAS application links against).

Run from the repo root after building the cdylib:

    cd rust && cargo build --release && cd ..
    python3 examples/python/blasx_ctypes.py

Demonstrates the blocking cblas_dgemm path and an aliasing
blasx_dgemm_async -> blasx_dtrsm_async chain (the runtime's admission
table orders the two in-flight jobs; results match serial execution).
Verifies with numpy when available, otherwise with a naive loop.
"""

import ctypes
import os
import sys

# CBLAS enum values (see include/blasx.h)
COL_MAJOR = 102
NO_TRANS = 111
UPPER = 121
NON_UNIT = 131
LEFT = 141


def load_libblasx():
    here = os.path.dirname(os.path.abspath(__file__))
    root = os.path.dirname(os.path.dirname(here))
    candidates = [
        os.environ.get("LIBBLASX"),
        os.path.join(root, "rust", "target", "release", "libblasx.so"),
        os.path.join(root, "rust", "target", "debug", "libblasx.so"),
        os.path.join(root, "rust", "target", "release", "libblasx.dylib"),
        "libblasx.so",
    ]
    for path in candidates:
        if not path:
            continue
        try:
            return ctypes.CDLL(path)
        except OSError:
            continue
    sys.exit("libblasx not found — build it with `cd rust && cargo build --release`")


def declare(lib):
    i, d, szt = ctypes.c_int, ctypes.c_double, ctypes.c_size_t
    pd = ctypes.POINTER(ctypes.c_double)
    lib.blasx_init.argtypes = [ctypes.POINTER(BlasxConfig)]
    lib.blasx_init.restype = i
    lib.cblas_dgemm.argtypes = [i, i, i, i, i, i, d, pd, i, pd, i, d, pd, i]
    lib.cblas_dgemm.restype = None
    lib.blasx_dgemm_async.argtypes = lib.cblas_dgemm.argtypes
    lib.blasx_dgemm_async.restype = ctypes.c_void_p
    lib.blasx_dtrsm_async.argtypes = [i, i, i, i, i, i, i, d, pd, i, pd, i]
    lib.blasx_dtrsm_async.restype = ctypes.c_void_p
    lib.blasx_wait.argtypes = [ctypes.c_void_p]
    lib.blasx_wait.restype = i
    lib.blasx_job_done.argtypes = [ctypes.c_void_p]
    lib.blasx_job_done.restype = i
    lib.blasx_job_cancel.argtypes = [ctypes.c_void_p]
    lib.blasx_job_cancel.restype = i
    lib.blasx_job_stats.argtypes = [ctypes.c_void_p, ctypes.POINTER(BlasxStats)]
    lib.blasx_job_stats.restype = i
    lib.blasx_last_error.argtypes = [ctypes.c_char_p, szt]
    lib.blasx_last_error.restype = szt
    lib.blasx_telemetry_text.argtypes = [ctypes.c_char_p, szt]
    lib.blasx_telemetry_text.restype = szt
    lib.blasx_version.restype = ctypes.c_char_p
    lib.blasx_shutdown.restype = None


class BlasxConfig(ctypes.Structure):
    """struct blasx_config (include/blasx.h): zero = use the default."""

    _fields_ = [
        ("devices", ctypes.c_int),
        ("tile", ctypes.c_int),
        ("arena_mb", ctypes.c_int),
        ("kernel_threads", ctypes.c_int),
        ("one_shot", ctypes.c_int),
        ("deadline_ms", ctypes.c_uint64),
        ("max_inflight", ctypes.c_int),
        ("tenant_quota", ctypes.c_int),
        ("prefetch", ctypes.c_int),
        ("faults", ctypes.c_char_p),
        ("profile", ctypes.c_char_p),
    ]


class BlasxStats(ctypes.Structure):
    """struct blasx_stats (include/blasx.h): live per-job counters."""

    _fields_ = [
        ("tasks", ctypes.c_uint64),
        ("host_reads_a", ctypes.c_uint64),
        ("host_reads_b", ctypes.c_uint64),
        ("host_reads_c", ctypes.c_uint64),
        ("peer_copies", ctypes.c_uint64),
        ("l1_hits", ctypes.c_uint64),
        ("steals", ctypes.c_uint64),
        ("retried", ctypes.c_uint64),
        ("degraded", ctypes.c_uint64),
        ("migrated", ctypes.c_uint64),
        ("prefetch_hits", ctypes.c_uint64),
        ("prefetch_wasted", ctypes.c_uint64),
    ]


def buf(values):
    return (ctypes.c_double * len(values))(*values)


def main():
    lib = load_libblasx()
    declare(lib)
    # Explicit configuration — must be the first BLASX call. Zeroed
    # fields keep their defaults; `faults` would take a BLASX_FAULTS
    # schedule (e.g. b"kill@dev1:op40") for chaos runs, `profile` a
    # `blasx tune` dispatch-profile path (e.g. b"profile.json").
    # prefetch=4 arms the lookahead transfer pipeline (results are
    # bit-identical with it off; the counters below show it working).
    cfg = BlasxConfig(devices=2, arena_mb=32, prefetch=4)
    assert lib.blasx_init(ctypes.byref(cfg)) == 0, "blasx_init must be first"
    print(lib.blasx_version().decode(), "from Python/ctypes")

    n = 32
    import random

    rng = random.Random(7)
    a = buf([rng.uniform(-1, 1) for _ in range(n * n)])
    b = buf([rng.uniform(-1, 1) for _ in range(n * n)])
    c = buf([0.0] * (n * n))

    # -- blocking drop-in call
    lib.cblas_dgemm(COL_MAJOR, NO_TRANS, NO_TRANS, n, n, n, 1.0, a, n, b, n, 0.0, c, n)

    # -- aliasing async chain on one buffer: C := A·B, then T·X = C
    t = buf([rng.uniform(-0.05, 0.05) for _ in range(n * n)])
    for idx in range(n):
        t[idx * n + idx] = 2.0
    x = buf([0.0] * (n * n))
    j1 = lib.blasx_dgemm_async(COL_MAJOR, NO_TRANS, NO_TRANS, n, n, n, 1.0, a, n, b, n, 0.0, x, n)
    j2 = lib.blasx_dtrsm_async(COL_MAJOR, LEFT, UPPER, NO_TRANS, NON_UNIT, n, n, 1.0, t, n, x, n)
    if not j1 or not j2:
        msg = ctypes.create_string_buffer(256)
        lib.blasx_last_error(msg, 256)
        sys.exit(f"async submission failed: {msg.value.decode()}")
    # -- live observability: per-job counters, valid before the wait
    while lib.blasx_job_done(j2) == 0:
        pass  # spin: the example problem is tiny
    stats = BlasxStats()
    assert lib.blasx_job_stats(j1, ctypes.byref(stats)) == 0
    print(
        f"gemm job stats: tasks {stats.tasks}, host reads "
        f"A/B/C {stats.host_reads_a}/{stats.host_reads_b}/{stats.host_reads_c}, "
        f"peer {stats.peer_copies}, L1 hits {stats.l1_hits}, steals {stats.steals}"
    )
    # The fault-recovery ledger: zero on a healthy run, nonzero when a
    # BLASX_FAULTS schedule (or cfg.faults) injects chaos.
    print(
        f"fault ledger: retried {stats.retried}, degraded {stats.degraded}, "
        f"migrated {stats.migrated}"
    )
    # The transfer pipeline's lookahead ledger (cfg.prefetch above).
    print(f"prefetch: hits {stats.prefetch_hits}, wasted {stats.prefetch_wasted}")
    assert stats.tasks > 0, "retired gemm job reports zero tasks"

    # -- live telemetry through the C ABI: the Prometheus text that
    #    `blasx serve --telemetry-addr` exposes at /metrics.
    need = lib.blasx_telemetry_text(None, 0)
    raw = ctypes.create_string_buffer(need + 1)
    lib.blasx_telemetry_text(raw, need + 1)
    text = raw.value.decode()
    assert "blasx_up 1" in text, "telemetry scrape must report the runtime up"
    print(f"telemetry scrape: {need} bytes, {len(text.splitlines())} lines of Prometheus text")
    assert lib.blasx_wait(j2) == 0  # newest first — order must not matter
    assert lib.blasx_wait(j1) == 0

    # -- verify
    try:
        import numpy as np

        A = np.array(a[:], dtype=float).reshape(n, n, order="F")
        B = np.array(b[:], dtype=float).reshape(n, n, order="F")
        T = np.triu(np.array(t[:], dtype=float).reshape(n, n, order="F"))
        want_c = A @ B
        got_c = np.array(c[:], dtype=float).reshape(n, n, order="F")
        assert np.allclose(got_c, want_c, atol=1e-10), "cblas_dgemm mismatch"
        want_x = np.linalg.solve(T, want_c)
        got_x = np.array(x[:], dtype=float).reshape(n, n, order="F")
        assert np.allclose(got_x, want_x, atol=1e-8), "async chain mismatch"
        print("verified against numpy: OK")
    except ImportError:
        # naive spot check of one column without numpy
        j = 0
        for i in range(n):
            acc = sum(a[l * n + i] * b[j * n + l] for l in range(n))
            assert abs(c[j * n + i] - acc) < 1e-10, "cblas_dgemm mismatch"
        print("verified first column with a naive loop: OK (install numpy for the full check)")

    lib.blasx_shutdown()
    print("done")


if __name__ == "__main__":
    main()
