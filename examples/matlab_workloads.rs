//! MATLAB-style application workloads (paper §V-C(b), Table VI):
//! BLAS-offloading applications sped up by pointing their matrix ops at
//! BLASX instead of a single-threaded host BLAS.
//!
//! Four workloads, each timed twice — BLASX multi-device runtime vs the
//! single-threaded hostblas oracle — reporting the speedup column of
//! Table VI:
//!
//! - `A*B` single precision (Table VI row 1)
//! - `A*B` double precision (row 2)
//! - `nnmf`: non-negative matrix factorization by multiplicative
//!   updates — a pure chain of GEMMs (row 3)
//! - `lsqlin`: least squares via conjugate gradient on the normal
//!   equations — GEMM/SYRK-dominant (row 5)
//!
//! ```text
//! cargo run --release --example matlab_workloads -- [n]
//! ```

use blasx::api::types::{Trans, Uplo};
use blasx::api::{self, Context};
use blasx::hostblas;
use blasx::util::prng::Prng;

fn time<F: FnMut()>(mut f: F) -> f64 {
    let t0 = std::time::Instant::now();
    f();
    t0.elapsed().as_secs_f64()
}

fn main() {
    let n: usize = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(768);
    // MATLAB-script style code interleaves host-side elementwise
    // updates with L3 calls every few lines; the one-shot engine keeps
    // the example free of `invalidate_host` declarations (see
    // ann_training.rs for the warm-runtime pattern done properly).
    let ctx = Context::new(2).with_tile(256).with_persistent(false);
    let mut rng = Prng::new(42);
    println!("NOTE: this box has one CPU core — the multi-device runtime cannot show");
    println!("parallel speedup here (Table VI's shape is reproduced on the simulated");
    println!("Everest by `cargo bench --bench table6_apps`); this example proves the");
    println!("apps compute CORRECT results through the full runtime.\n");
    println!("workload                        single-thread   blasx      speedup");

    // --- A*B single precision
    {
        let mut a = vec![0.0f32; n * n];
        let mut b = vec![0.0f32; n * n];
        rng.fill_f32(&mut a, -1.0, 1.0);
        rng.fill_f32(&mut b, -1.0, 1.0);
        let mut c1 = vec![0.0f32; n * n];
        let t_ref = time(|| {
            hostblas::gemm_blocked(Trans::No, Trans::No, n, n, n, 1.0f32, &a, n, &b, n, 0.0, &mut c1, n)
        });
        let mut c2 = vec![0.0f32; n * n];
        let t_x = time(|| {
            api::sgemm(&ctx, Trans::No, Trans::No, n, n, n, 1.0, &a, n, &b, n, 0.0, &mut c2, n).unwrap();
        });
        report("A*B (single)", t_ref, t_x);
        let d = c1.iter().zip(&c2).map(|(x, y)| (x - y).abs()).fold(0.0f32, f32::max);
        assert!(d < 1e-2, "sgemm mismatch {d}");
    }

    // --- A*B double precision
    {
        let mut a = vec![0.0f64; n * n];
        let mut b = vec![0.0f64; n * n];
        rng.fill_f64(&mut a, -1.0, 1.0);
        rng.fill_f64(&mut b, -1.0, 1.0);
        let mut c1 = vec![0.0f64; n * n];
        let t_ref = time(|| {
            hostblas::gemm_blocked(Trans::No, Trans::No, n, n, n, 1.0, &a, n, &b, n, 0.0, &mut c1, n)
        });
        let mut c2 = vec![0.0f64; n * n];
        let t_x = time(|| {
            api::dgemm(&ctx, Trans::No, Trans::No, n, n, n, 1.0, &a, n, &b, n, 0.0, &mut c2, n).unwrap();
        });
        report("A*B (double)", t_ref, t_x);
    }

    // --- nnmf: V ≈ W H by multiplicative updates (all GEMM)
    {
        let (m, r, iters) = (n, 32, 4);
        let mut v = vec![0.0f64; m * n];
        rng.fill_f64(&mut v, 0.0, 1.0);
        let run = |mm: &dyn Fn(Trans, Trans, usize, usize, usize, &[f64], usize, &[f64], usize, &mut [f64], usize)| {
            let mut w = vec![0.5f64; m * r];
            let mut h = vec![0.5f64; r * n];
            for _ in 0..iters {
                // H <- H .* (W^T V) ./ (W^T W H)
                let mut wtv = vec![0.0; r * n];
                mm(Trans::Yes, Trans::No, r, n, m, &w, m, &v, m, &mut wtv, r);
                let mut wtw = vec![0.0; r * r];
                mm(Trans::Yes, Trans::No, r, r, m, &w, m, &w, m, &mut wtw, r);
                let mut wtwh = vec![0.0; r * n];
                mm(Trans::No, Trans::No, r, n, r, &wtw, r, &h, r, &mut wtwh, r);
                for i in 0..h.len() {
                    h[i] *= wtv[i] / (wtwh[i] + 1e-9);
                }
                // W <- W .* (V H^T) ./ (W H H^T)
                let mut vht = vec![0.0; m * r];
                mm(Trans::No, Trans::Yes, m, r, n, &v, m, &h, r, &mut vht, m);
                let mut hht = vec![0.0; r * r];
                mm(Trans::No, Trans::Yes, r, r, n, &h, r, &h, r, &mut hht, r);
                let mut whht = vec![0.0; m * r];
                mm(Trans::No, Trans::No, m, r, r, &w, m, &hht, r, &mut whht, m);
                for i in 0..w.len() {
                    w[i] *= vht[i] / (whht[i] + 1e-9);
                }
            }
            (w, h)
        };
        let t_ref = time(|| {
            run(&|ta, tb, m2, n2, k2, a, lda, b, ldb, c, ldc| {
                hostblas::gemm_blocked(ta, tb, m2, n2, k2, 1.0, a, lda, b, ldb, 0.0, c, ldc)
            });
        });
        let ctx2 = &ctx;
        let t_x = time(|| {
            run(&|ta, tb, m2, n2, k2, a, lda, b, ldb, c, ldc| {
                api::dgemm(ctx2, ta, tb, m2, n2, k2, 1.0, a, lda, b, ldb, 0.0, c, ldc).unwrap();
            });
        });
        report("nnmf (mult. updates)", t_ref, t_x);
    }

    // --- lsqlin: min ||Ax - b|| via CG on A^T A x = A^T b
    {
        let (rows, cols, iters) = (n, n / 2, 8);
        let mut a = vec![0.0f64; rows * cols];
        rng.fill_f64(&mut a, -1.0, 1.0);
        let mut b = vec![0.0f64; rows];
        rng.fill_f64(&mut b, -1.0, 1.0);

        // Gram matrix by SYRK, the CG loop by GEMV-as-GEMM — all L3.
        let run = |use_blasx: bool| {
            let mut g = vec![0.0f64; cols * cols]; // G = A^T A
            if use_blasx {
                api::syrk(&ctx, Uplo::Upper, Trans::Yes, cols, rows, 1.0, &a, rows, 0.0, &mut g, cols)
                    .unwrap();
            } else {
                hostblas::syrk_ref(Uplo::Upper, Trans::Yes, cols, rows, 1.0, &a, rows, 0.0, &mut g, cols);
            }
            // mirror to full storage for the CG products
            for j in 0..cols {
                for i in 0..j {
                    g[i * cols + j] = g[j * cols + i];
                }
            }
            let mut atb = vec![0.0f64; cols];
            hostblas::gemm_blocked(Trans::Yes, Trans::No, cols, 1, rows, 1.0, &a, rows, &b, rows, 0.0, &mut atb, cols);
            // CG (small vectors: host arithmetic; products via G)
            let mut x = vec![0.0f64; cols];
            let mut rvec = atb.clone();
            let mut p = rvec.clone();
            let mut rs = rvec.iter().map(|v| v * v).sum::<f64>();
            for _ in 0..iters {
                let mut gp = vec![0.0f64; cols];
                hostblas::gemm_blocked(Trans::No, Trans::No, cols, 1, cols, 1.0, &g, cols, &p, cols, 0.0, &mut gp, cols);
                let alpha = rs / p.iter().zip(&gp).map(|(x, y)| x * y).sum::<f64>();
                for i in 0..cols {
                    x[i] += alpha * p[i];
                    rvec[i] -= alpha * gp[i];
                }
                let rs2 = rvec.iter().map(|v| v * v).sum::<f64>();
                let beta = rs2 / rs;
                rs = rs2;
                for i in 0..cols {
                    p[i] = rvec[i] + beta * p[i];
                }
            }
            x
        };
        let t_ref = time(|| {
            run(false);
        });
        let t_x = time(|| {
            run(true);
        });
        report("lsqlin (CG normal eqns)", t_ref, t_x);
    }
}

fn report(name: &str, t_ref: f64, t_x: f64) {
    println!("{name:<30}  {t_ref:>8.3}s     {t_x:>8.3}s   {:>5.2}x", t_ref / t_x);
}
