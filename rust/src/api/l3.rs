//! The public L3 BLAS API — BLASX's backward-compatibility surface
//! (paper §I: "all the details … can be ignored by library users").
//!
//! Signatures mirror CBLAS column-major conventions: `{s,d}gemm`,
//! `{s,d}syrk`, `{s,d}syr2k`, `{s,d}trmm`, `{s,d}trsm`, `{s,d}symm`.
//! Each call taskizes the problem, spins up the multi-device runtime and
//! returns once C (or B for TRMM/TRSM) holds the result — exactly the
//! drop-in-replacement contract the paper demonstrates with Caffe and
//! MATLAB.
//!
//! The execution context (device count, arena bytes, tile size, kernel
//! backend) comes from a [`Context`], with a process-default tuned for
//! this testbed.
//!
//! ## Persistent runtime (default)
//!
//! A `Context` lazily boots a resident [`crate::runtime::Runtime`] on
//! its first call: worker threads, device arenas and the ALRU/MESI-X
//! tile caches then *survive across calls*, so repeated calls touching
//! the same host matrices start on a warm cache — the second identical
//! `dgemm` performs zero host→device tile transfers for unchanged
//! operands (observable via [`RealReport::transfers`]). Outputs are
//! invalidation-epoch-bumped automatically each call; if you mutate an
//! *input* buffer between calls you must tell the runtime via
//! [`Context::invalidate_host`] (the library cannot observe foreign
//! writes). Set [`Context::persistent`] to `false` (or build with
//! [`Context::with_persistent`]) to get the old tear-down-per-call
//! engine. Clones of a `Context` share the booted runtime; dropping
//! the last clone shuts it down.
//!
//! ## Serving mode: concurrent calls and scoped async
//!
//! The resident runtime is **multi-tenant** (see [`crate::serve`]):
//! calls from any number of client threads are admitted as concurrent
//! *jobs* and interleaved across the device workers under
//! flop-weighted fairness. Independent calls overlap on the devices;
//! calls whose operand byte ranges alias are ordered by admission-time
//! dependency edges and stay bit-for-bit identical to serial
//! execution. Blocking routines are submit-then-wait; non-blocking
//! submission goes through [`Context::scope`] (see
//! [`crate::api::scope`]): inside `ctx.scope(|s| { .. })` jobs issued
//! via `s.dgemm(..)` etc. return immediately with a
//! [`crate::serve::JobHandle`], operand ranges may alias *across*
//! jobs (the admission table orders them), and the scope's close is
//! the completion barrier — sound by construction, like
//! [`std::thread::scope`]. The C ABI ([`crate::ffi`]) exposes the same
//! machinery to cblas-compatible callers over raw pointers.

use super::check;
use super::types::{Diag, Dtype, Scalar, Side, Trans, Uplo};
use crate::batch::{taskize_batch, BatchDesc, BatchedGemm};
use crate::cache::CacheStats;
use crate::coordinator::real_engine::{run_real_batch, Mats, RealReport, TransferStats};
use crate::coordinator::{Backend, RunConfig};
use crate::dispatch::{Choice, Dispatcher, Placement, Profile};
use crate::error::{illegal, Result};
use crate::hostblas;
use crate::runtime::Runtime;
use crate::task::{
    taskize_gemm, taskize_symm, taskize_syr2k, taskize_syrk, taskize_trmm, taskize_trsm,
    GemmDesc, SymmDesc, SyrkDesc, TaskSet, TriDesc,
};
use crate::tile::{HostMat, MatId};
use crate::trace::{chrome_trace, Trace};
use crate::util::json::Json;
use std::sync::{Arc, Mutex};

/// Execution context: how many virtual devices, how much arena each,
/// which tile size and kernel backend — plus the resident runtime the
/// calls execute on (see module docs).
#[derive(Clone, Debug)]
pub struct Context {
    pub n_devices: usize,
    pub arena_bytes: usize,
    pub cfg: RunConfig,
    /// Keep the engine (workers, arenas, tile caches) alive across
    /// calls (default). `false` restores the one-shot engine: fresh
    /// threads and cold caches per call.
    pub persistent: bool,
    /// Per-shape adaptive dispatch (see [`crate::dispatch`]): when set,
    /// blocking calls consult it for tile size, kernel fan-out, the
    /// gemm_mt cutoff and host-vs-device placement. `None` (default)
    /// keeps the historical fixed-`cfg` behaviour exactly.
    dispatch: Option<Arc<Dispatcher>>,
    /// The lazily-booted resident runtime, shared by clones.
    runtime: Arc<Mutex<Option<Arc<Runtime>>>>,
}

impl Default for Context {
    fn default() -> Context {
        // 2 virtual devices exercises the full multi-device protocol
        // (arena-to-arena peer copies, stealing) while staying sensible
        // on small hosts. The 64 MiB (= 67,108,864 byte) arena holds
        // exactly 128 f64 tiles at the default T=256 (one tile is
        // 256·256·8 B = 512 KiB; f32 runs fit 256 tiles) — far above
        // the 8-tile working-set floor `run_real` enforces, small
        // enough that big problems still exercise eviction. Size it
        // explicitly with [`Context::with_arena`].
        Context {
            n_devices: 2,
            arena_bytes: 64 << 20,
            cfg: RunConfig { t: 256, ..Default::default() },
            persistent: true,
            dispatch: None,
            runtime: Arc::new(Mutex::new(None)),
        }
    }
}

impl Context {
    pub fn new(n_devices: usize) -> Context {
        Context { n_devices, ..Default::default() }
    }

    pub fn with_tile(mut self, t: usize) -> Context {
        self.cfg.t = t;
        // Tile-size clones deliberately KEEP the shared runtime slot:
        // the tile size is a discriminant of `crate::tile::TileKey`, so
        // each geometry is its own cache generation — clones with
        // different tile sizes share the warm engine and never disturb
        // each other's cached tiles.
        self
    }

    pub fn with_backend(mut self, b: Backend) -> Context {
        self.cfg.backend = b;
        self
    }

    /// Threads each device worker may fan a tile kernel across (the
    /// paper's "multithreaded BLAS kernel", §IV-C.2). Small tiles stay
    /// serial under `hostblas::gemm_mt`'s flop cutoff regardless; big
    /// ones run their cells on the persistent kernel pool.
    pub fn with_kernel_threads(mut self, threads: usize) -> Context {
        self.cfg.worker_threads = threads.max(1);
        self
    }

    /// Size each device's tile-cache arena in bytes. Batch callers in
    /// particular should budget `n` live tiles as `n · t · t · esz`
    /// (the runtime needs at least 8 tiles per device; `run_real`
    /// asserts the floor).
    pub fn with_arena(mut self, bytes: usize) -> Context {
        self.arena_bytes = bytes;
        // Geometry diverged from whatever this context was cloned
        // from: give the derived context its own runtime slot, so two
        // differently-sized clones never ping-pong-reboot a shared
        // engine (each keeps its warm caches).
        self.runtime = Arc::new(Mutex::new(None));
        self
    }

    /// Toggle the resident runtime (see module docs). Default on.
    pub fn with_persistent(mut self, on: bool) -> Context {
        self.persistent = on;
        self
    }

    /// Dispatch from a recorded profile (`blasx tune` output): blocking
    /// calls look their shape bucket up and get that exact tile size /
    /// kernel fan-out / cutoff / placement, deterministically, falling
    /// back to the static heuristic for unseen shapes. See
    /// [`crate::dispatch`].
    pub fn with_profile(mut self, profile: Profile) -> Context {
        self.dispatch = Some(Arc::new(Dispatcher::from_profile(profile)));
        self
    }

    /// Load and install a dispatch profile from a JSON file (see
    /// [`Context::with_profile`]).
    pub fn with_profile_file(self, path: &str) -> Result<Context> {
        Ok(self.with_profile(Profile::load(path)?))
    }

    /// Adaptive per-shape dispatch with no recorded profile: choices
    /// start at the heuristic, explore the tile-size candidates in a
    /// deterministic rotation, and settle on the best measured
    /// throughput per shape bucket. See [`crate::dispatch`].
    pub fn with_adaptive_dispatch(mut self) -> Context {
        self.dispatch = Some(Arc::new(Dispatcher::adaptive(Profile::new())));
        self
    }

    /// The installed dispatcher, if any (shared by clones).
    pub fn dispatcher(&self) -> Option<&Arc<Dispatcher>> {
        self.dispatch.as_ref()
    }

    /// Arm the fault-injection plane (see [`crate::fault`]): the plan
    /// is installed when this context's runtime boots, so a derived
    /// context gets its own runtime slot — chaos never leaks into a
    /// sibling's warm engine. `None` disarms (modulo the
    /// `BLASX_FAULTS` environment fallback).
    pub fn with_fault_plan(mut self, plan: Option<crate::fault::FaultPlan>) -> Context {
        self.cfg.fault_plan = plan;
        self.runtime = Arc::new(Mutex::new(None));
        self
    }

    /// Background telemetry sampler interval in milliseconds
    /// (`Some(0)` forces it off, `None` — the default — defers to
    /// `BLASX_TELEMETRY_MS`). The sampler thread is spawned at runtime
    /// boot, so the derived context gets its own runtime slot; when
    /// off, no thread is spawned and no telemetry memory is allocated
    /// (see [`crate::trace::telemetry`]).
    pub fn with_telemetry_ms(mut self, ms: Option<u64>) -> Context {
        self.cfg.telemetry_ms = ms;
        self.runtime = Arc::new(Mutex::new(None));
        self
    }

    /// Lookahead prefetch depth: each device worker stages up to
    /// `depth` not-yet-resident input tiles of its upcoming scheduler
    /// window ahead of demand (L2/peer-first, eviction-aware — see the
    /// README's "Transfer pipeline & prefetch"). `Some(0)` forces
    /// prefetch off; `None` (default) defers to `BLASX_PREFETCH_DEPTH`
    /// (unset: off). Takes effect from the next call — no runtime
    /// reboot, results are bit-identical either way.
    pub fn with_prefetch(mut self, depth: Option<usize>) -> Context {
        self.cfg.prefetch = depth;
        self
    }

    /// Per-job deadline in milliseconds: a call still unfinished this
    /// long after admission aborts with
    /// [`crate::error::Error::DeadlineExceeded`] at the next round
    /// boundary, leaving other tenants' jobs untouched. `None`
    /// (default) disables deadlines.
    pub fn with_deadline_ms(mut self, ms: Option<u64>) -> Context {
        self.cfg.deadline_ms = ms;
        self
    }

    /// Bound the admission queue: at `cap` live jobs further calls
    /// fail fast with [`crate::error::Error::Backpressure`] instead of
    /// queueing unboundedly (floored at 1).
    pub fn with_admit_capacity(mut self, cap: usize) -> Context {
        self.cfg.admit_capacity = cap.max(1);
        self
    }

    /// Bound one tenant's (= submitting thread's) concurrently live
    /// jobs; over quota its calls fail with
    /// [`crate::error::Error::Backpressure`] while other tenants admit
    /// freely (floored at 1).
    pub fn with_tenant_quota(mut self, quota: usize) -> Context {
        self.cfg.tenant_quota = quota.max(1);
        self
    }

    /// Tile size floor: degenerate matrices still need one tile.
    pub(crate) fn tile(&self) -> usize {
        self.cfg.t
    }

    /// The resident runtime, booting it (or rebooting on a geometry
    /// change) as needed.
    pub(crate) fn runtime(&self) -> Arc<Runtime> {
        let mut slot = self.runtime.lock().unwrap_or_else(|e| e.into_inner());
        match slot.as_ref() {
            Some(rt)
                if rt.n_devices() == self.n_devices && rt.arena_bytes() == self.arena_bytes =>
            {
                rt.clone()
            }
            _ => {
                let rt = Arc::new(Runtime::boot_with_telemetry(
                    self.n_devices,
                    self.arena_bytes,
                    self.cfg.alloc,
                    self.cfg.telemetry_ms,
                ));
                if let Some(plan) = &self.cfg.fault_plan {
                    rt.install_fault_plan(plan.clone());
                }
                *slot = Some(rt.clone());
                rt
            }
        }
    }

    /// The resident runtime if (and only if) it has already booted —
    /// for operations that are no-ops on a cold runtime (e.g. the C
    /// ABI's `blasx_invalidate_host`), which must not trigger a boot.
    pub(crate) fn runtime_if_booted(&self) -> Option<Arc<Runtime>> {
        self.runtime.lock().unwrap_or_else(|e| e.into_inner()).clone()
    }

    /// Is the resident runtime currently booted? (Observability/tests —
    /// boot is lazy, so this is `false` until the first persistent
    /// call.)
    pub fn runtime_booted(&self) -> bool {
        self.runtime.lock().unwrap_or_else(|e| e.into_inner()).is_some()
    }

    /// Calls served by the resident runtime since it booted (0 when
    /// not booted).
    pub fn runtime_calls(&self) -> usize {
        self.runtime.lock().unwrap_or_else(|e| e.into_inner()).as_ref().map_or(0, |rt| rt.calls())
    }

    /// Cumulative per-device busy nanoseconds of the resident workers
    /// (empty when not booted). Against wall time × device count this
    /// yields the worker-idle fraction `benches/serve_throughput.rs`
    /// reports.
    pub fn runtime_busy_nanos(&self) -> Vec<u64> {
        self.runtime
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .as_ref()
            .map_or_else(Vec::new, |rt| rt.busy_nanos())
    }

    /// Jobs currently admitted to the resident runtime (running or
    /// queued behind aliasing dependencies). 0 when not booted.
    pub fn jobs_in_flight(&self) -> usize {
        self.runtime
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .as_ref()
            .map_or(0, |rt| rt.jobs_in_flight())
    }

    /// Shut the resident runtime down now (it reboots lazily on the
    /// next call). Equivalent to dropping every clone of this context.
    pub fn shutdown_runtime(&self) {
        *self.runtime.lock().unwrap_or_else(|e| e.into_inner()) = None;
    }

    /// Declare that the host buffer `buf` has been mutated (or freed
    /// and reallocated) since the last call that read it: every tile
    /// the resident runtime cached from it is invalidated, so the next
    /// call re-reads fresh bytes. A no-op when the runtime isn't
    /// booted and for non-persistent contexts (their caches die with
    /// each call anyway). Output matrices never need this — each call
    /// bumps its outputs' epochs automatically.
    pub fn invalidate_host<T: Scalar>(&self, buf: &[T]) {
        if let Some(rt) = self.runtime.lock().unwrap_or_else(|e| e.into_inner()).as_ref() {
            let lo = buf.as_ptr() as usize;
            rt.invalidate_bytes(lo, lo + std::mem::size_of_val(buf));
        }
    }

    /// Turn the wall-clock span recorder on or off (see
    /// `crate::trace::spans`). Boots the resident runtime if needed so
    /// the recorder exists to flip; a no-op for non-persistent contexts
    /// (their one-shot cores read `BLASX_TRACE` at construction).
    pub fn set_tracing(&self, on: bool) {
        if self.persistent {
            self.runtime().core().rec.set_enabled(on);
        }
    }

    /// Is the span recorder currently capturing? `false` when the
    /// runtime has not booted.
    pub fn tracing_enabled(&self) -> bool {
        self.runtime_if_booted().map_or(false, |rt| rt.core().rec.is_enabled())
    }

    /// The spans captured so far as a sim-compatible [`Trace`] with
    /// real timestamps — feed it to
    /// [`crate::trace::device_profile`] / [`crate::trace::comm_volumes`]
    /// for the paper's Fig. 8 / Table V breakdowns on wall-clock data.
    /// `None` when the runtime has not booted.
    pub fn snapshot_trace(&self) -> Option<Trace> {
        self.runtime_if_booted().map(|rt| rt.core().rec.to_trace())
    }

    /// The captured spans + job lifecycles as a Chrome trace-event
    /// JSON document (load in Perfetto / `chrome://tracing`). `None`
    /// when the runtime has not booted.
    pub fn chrome_trace_json(&self) -> Option<String> {
        self.runtime_if_booted().map(|rt| {
            let rec = &rt.core().rec;
            chrome_trace(&rec.spans(), &rec.job_records()).to_string_compact()
        })
    }

    /// Drop every captured span and job record (the enabled flag is
    /// unchanged). No-op when the runtime has not booted.
    pub fn reset_trace(&self) {
        if let Some(rt) = self.runtime_if_booted() {
            rt.core().rec.reset();
        }
    }

    /// Snapshot of the resident runtime's metrics registry (job
    /// counters, per-worker busy fractions, per-tenant / per-routine
    /// latency quantiles) as JSON, plus the fleet-health section
    /// (`devices[].up`, `fleet_healthy`) sourced from the SAME device-
    /// death ledger `/healthz` reads. `None` when the runtime has not
    /// booted. Schema: see README §Observability.
    pub fn snapshot_metrics(&self) -> Option<Json> {
        self.runtime_if_booted().map(|rt| rt.snapshot_metrics())
    }

    /// Render the live gauges in Prometheus text exposition format
    /// (0.0.4) — the body `/metrics` serves. A cold (unbooted) runtime
    /// renders the `blasx_up 0` stub without triggering a boot; a
    /// booted one gathers a fresh sample (works with the background
    /// sampler off) and overlays the dispatcher's online-EWMA gauges,
    /// which live on the `Context`, not the runtime.
    pub fn render_prometheus(&self) -> String {
        let Some(rt) = self.runtime_if_booted() else {
            return crate::trace::prometheus::render_unbooted();
        };
        let mut s = rt.telemetry_now();
        if let Some(d) = self.dispatch.as_ref() {
            let (shapes, obs) = d.online_stats();
            s.dispatch_shapes = shapes;
            s.dispatch_observations = obs;
        }
        crate::trace::prometheus::render(&s)
    }

    /// Fleet health: `(healthy, dead_devices)` from the fault plane's
    /// device-death ledger — the single source `/healthz`,
    /// `snapshot_metrics` and the telemetry gauges all read. An
    /// unbooted runtime is vacuously healthy (and stays unbooted).
    pub fn health(&self) -> (bool, Vec<usize>) {
        match self.runtime_if_booted() {
            None => (true, Vec::new()),
            Some(rt) => {
                let dead = rt.dead_devices();
                (dead.is_empty(), dead)
            }
        }
    }

    /// Point the flight recorder's auto-dump at `dir` (`None` disarms).
    /// Boots the runtime if needed — arming the black box is an
    /// explicit request for a live fleet to observe.
    pub fn set_flight_dir(&self, dir: Option<std::path::PathBuf>) {
        if self.persistent {
            self.runtime().flight().set_dump_dir(dir);
        }
    }

    /// Dump the flight ring to `dir` right now (manual incident
    /// capture, reason `"manual"`), returning the report path. `None`
    /// when the runtime has not booted.
    pub fn flight_dump(&self, dir: &std::path::Path) -> Option<std::io::Result<std::path::PathBuf>> {
        self.runtime_if_booted().map(|rt| {
            let dead = rt.dead_devices();
            rt.flight().dump(dir, "manual", &dead)
        })
    }

    /// Telemetry sample history from the background sampler's ring
    /// (empty when the sampler is off or the runtime unbooted).
    pub fn telemetry_history(&self) -> Vec<crate::trace::TelemetrySample> {
        self.runtime_if_booted().map_or_else(Vec::new, |rt| rt.telemetry().history())
    }

    /// Is a background telemetry sampler thread running?
    pub fn sampler_running(&self) -> bool {
        self.runtime_if_booted().map_or(false, |rt| rt.sampler_running())
    }

    /// Route a task set to the resident runtime (persistent) or the
    /// one-shot engine. Under the resident runtime this is
    /// submit-then-wait through the multi-tenant scheduler: the call
    /// parks, but OTHER threads' calls interleave with it on the
    /// devices. `routine` labels the call in the metrics registry and
    /// trace exports.
    pub(crate) fn execute<T: Scalar>(
        &self,
        routine: &'static str,
        ts: &TaskSet,
        problems: Vec<Mats<'_, T>>,
    ) -> Result<RealReport> {
        let mut cfg = self.cfg.clone();
        cfg.routine = routine;
        self.execute_cfg(&cfg, ts, problems)
    }

    /// [`Context::execute`] with a fully-resolved per-call config (the
    /// dispatcher may have overridden tile size / fan-out / cutoff).
    pub(crate) fn execute_cfg<T: Scalar>(
        &self,
        cfg: &RunConfig,
        ts: &TaskSet,
        problems: Vec<Mats<'_, T>>,
    ) -> Result<RealReport> {
        if !self.persistent {
            return run_real_batch(cfg, ts, problems, self.n_devices, self.arena_bytes);
        }
        self.runtime().submit(cfg, ts, problems)
    }

    /// The dispatcher's decision for a blocking call, when one is
    /// installed. The base choice carries this context's own defaults;
    /// the chosen tile size is halved until the arena can hold the
    /// engine's 8-tile round working set (a profile recorded on a
    /// bigger machine must not wedge a smaller one).
    fn dispatch_choice(
        &self,
        routine: &'static str,
        dt: Dtype,
        m: usize,
        n: usize,
        k: usize,
    ) -> Option<Choice> {
        let d = self.dispatch.as_ref()?;
        let base = Choice {
            t: self.cfg.t,
            kernel_threads: self.cfg.worker_threads,
            mt_cutoff: self.cfg.mt_cutoff,
            place: Placement::Device,
        };
        let mut ch = d.choose(routine, dt, m, n, k, &base);
        let esz = dt.size_bytes();
        while ch.t > 64 && self.arena_bytes < 8 * ch.t * ch.t * esz {
            ch.t /= 2;
        }
        Some(ch)
    }

    /// Resolve a blocking call's effective (tile size, run config):
    /// the context defaults, overridden by the dispatcher's
    /// device-placement choice when one is installed. Host placement
    /// is resolved by the caller (only `gemm` has a host fast path) —
    /// this helper applies Device choices only, so every other routine
    /// can use it unconditionally.
    fn plan_call(
        &self,
        routine: &'static str,
        dt: Dtype,
        m: usize,
        n: usize,
        k: usize,
    ) -> (usize, RunConfig) {
        let mut cfg = self.cfg.clone();
        cfg.routine = routine;
        if let Some(ch) = self.dispatch_choice(routine, dt, m, n, k) {
            if ch.place == Placement::Device {
                cfg.t = ch.t;
                cfg.worker_threads = ch.kernel_threads.max(1);
                if ch.mt_cutoff.is_some() {
                    cfg.mt_cutoff = ch.mt_cutoff;
                }
            }
        }
        (cfg.t, cfg)
    }

    /// Execute a dispatched call and feed the wall time back to the
    /// dispatcher (adaptive mode refines its per-shape EWMAs; profile
    /// mode ignores it). `(m, n, k)` is the shape key the choice was
    /// made under, not necessarily the routine's own letters.
    #[allow(clippy::too_many_arguments)]
    fn execute_planned<T: Scalar>(
        &self,
        cfg: &RunConfig,
        m: usize,
        n: usize,
        k: usize,
        ts: &TaskSet,
        problems: Vec<Mats<'_, T>>,
    ) -> Result<RealReport> {
        let t0 = std::time::Instant::now();
        let rep = self.execute_cfg(cfg, ts, problems)?;
        if let Some(d) = &self.dispatch {
            d.observe(cfg.routine, T::DTYPE, m, n, k, cfg.t, t0.elapsed().as_secs_f64());
        }
        Ok(rep)
    }
}

/// The all-zeros report of a host-placed call: nothing was staged, no
/// tiles moved, no cache was touched — which is the point.
fn host_report(n_devices: usize) -> RealReport {
    RealReport {
        tasks_per_device: vec![0; n_devices],
        cache_stats: vec![CacheStats::default(); n_devices],
        cache_delta: vec![CacheStats::default(); n_devices],
        steals: vec![0; n_devices],
        transfers: TransferStats::default(),
    }
}

// --- Per-routine call plans ------------------------------------------
//
// One validation + taskization step shared by every doorway into the
// engine: the blocking functions below, the scoped-async methods
// (`crate::api::scope`) and the C ABI (`crate::ffi`). A plan is the
// task set plus the stored (rows, cols) of each operand — what a
// caller needs to wrap its buffers, however it owns them.

/// Stored (rows, cols) of each operand of a planned call. `b` is
/// absent for the single-input routines (SYRK, TRMM, TRSM); `c` is the
/// output (B for the in-place triangular routines).
pub(crate) struct OperandDims {
    pub a: (usize, usize),
    pub b: Option<(usize, usize)>,
    pub c: (usize, usize),
}

#[allow(clippy::too_many_arguments)]
pub(crate) fn plan_gemm(
    t: usize,
    ta: Trans,
    tb: Trans,
    m: usize,
    n: usize,
    k: usize,
    alpha: f64,
    beta: f64,
    lda: usize,
    ldb: usize,
    ldc: usize,
) -> Result<(TaskSet, OperandDims)> {
    check::check_gemm(ta, tb, m, n, k, lda, ldb, ldc)?;
    let d = GemmDesc { ta, tb, m, n, k, alpha, beta, t };
    let a = if ta == Trans::No { (m, k) } else { (k, m) };
    let b = if tb == Trans::No { (k, n) } else { (n, k) };
    Ok((taskize_gemm(&d), OperandDims { a, b: Some(b), c: (m, n) }))
}

#[allow(clippy::too_many_arguments)]
pub(crate) fn plan_syrk(
    t: usize,
    uplo: Uplo,
    trans: Trans,
    n: usize,
    k: usize,
    alpha: f64,
    beta: f64,
    lda: usize,
    ldc: usize,
) -> Result<(TaskSet, OperandDims)> {
    check::check_syrk(trans, n, k, lda, None, ldc, "syrk")?;
    let d = SyrkDesc { uplo, trans, n, k, alpha, beta, t };
    let a = if trans == Trans::No { (n, k) } else { (k, n) };
    Ok((taskize_syrk(&d), OperandDims { a, b: None, c: (n, n) }))
}

#[allow(clippy::too_many_arguments)]
pub(crate) fn plan_syr2k(
    t: usize,
    uplo: Uplo,
    trans: Trans,
    n: usize,
    k: usize,
    alpha: f64,
    beta: f64,
    lda: usize,
    ldb: usize,
    ldc: usize,
) -> Result<(TaskSet, OperandDims)> {
    check::check_syrk(trans, n, k, lda, Some(ldb), ldc, "syr2k")?;
    let d = SyrkDesc { uplo, trans, n, k, alpha, beta, t };
    let a = if trans == Trans::No { (n, k) } else { (k, n) };
    Ok((taskize_syr2k(&d), OperandDims { a, b: Some(a), c: (n, n) }))
}

#[allow(clippy::too_many_arguments)]
pub(crate) fn plan_symm(
    t: usize,
    side: Side,
    uplo: Uplo,
    m: usize,
    n: usize,
    alpha: f64,
    beta: f64,
    lda: usize,
    ldb: usize,
    ldc: usize,
) -> Result<(TaskSet, OperandDims)> {
    check::check_symm(side, m, n, lda, ldb, ldc)?;
    let d = SymmDesc { side, uplo, m, n, alpha, beta, t };
    let na = if side == Side::Left { m } else { n };
    Ok((taskize_symm(&d), OperandDims { a: (na, na), b: Some((m, n)), c: (m, n) }))
}

#[allow(clippy::too_many_arguments)]
pub(crate) fn plan_trmm(
    t: usize,
    side: Side,
    uplo: Uplo,
    ta: Trans,
    diag: Diag,
    m: usize,
    n: usize,
    alpha: f64,
    lda: usize,
    ldb: usize,
) -> Result<(TaskSet, OperandDims)> {
    check::check_trxm(side, m, n, lda, ldb, "trmm")?;
    let d = TriDesc { side, uplo, ta, diag, m, n, alpha, t };
    let na = if side == Side::Left { m } else { n };
    Ok((taskize_trmm(&d), OperandDims { a: (na, na), b: None, c: (m, n) }))
}

#[allow(clippy::too_many_arguments)]
pub(crate) fn plan_trsm(
    t: usize,
    side: Side,
    uplo: Uplo,
    ta: Trans,
    diag: Diag,
    m: usize,
    n: usize,
    alpha: f64,
    lda: usize,
    ldb: usize,
) -> Result<(TaskSet, OperandDims)> {
    check::check_trxm(side, m, n, lda, ldb, "trsm")?;
    let d = TriDesc { side, uplo, ta, diag, m, n, alpha, t };
    let na = if side == Side::Left { m } else { n };
    Ok((taskize_trsm(&d), OperandDims { a: (na, na), b: None, c: (m, n) }))
}

/// `C := alpha*op(A)*op(B) + beta*C` (column-major, leading dims).
#[allow(clippy::too_many_arguments)]
pub fn gemm<T: Scalar>(
    ctx: &Context,
    ta: Trans,
    tb: Trans,
    m: usize,
    n: usize,
    k: usize,
    alpha: T,
    a: &[T],
    lda: usize,
    b: &[T],
    ldb: usize,
    beta: T,
    c: &mut [T],
    ldc: usize,
) -> Result<RealReport> {
    // Host placement: a dispatcher may route sub-tile problems around
    // the tiled engine entirely — one host kernel shot, still
    // admission-ordered against aliasing device jobs when persistent.
    if let Some(ch) = ctx.dispatch_choice("gemm", T::DTYPE, m, n, k) {
        if ch.place == Placement::Host {
            check::check_gemm(ta, tb, m, n, k, lda, ldb, ldc)?;
            let threads = ch.kernel_threads.max(1);
            let cutoff = ch
                .mt_cutoff
                .or(ctx.cfg.mt_cutoff)
                .unwrap_or_else(hostblas::mt_flop_cutoff);
            if ctx.persistent {
                let mut cfg = ctx.cfg.clone();
                cfg.routine = "gemm";
                cfg.worker_threads = threads;
                cfg.mt_cutoff = Some(cutoff);
                return ctx
                    .runtime()
                    .submit_host(&cfg, ta, tb, m, n, k, alpha, a, lda, b, ldb, beta, c, ldc);
            }
            hostblas::gemm_mt_with_cutoff(
                threads, cutoff, ta, tb, m, n, k, alpha, a, lda, b, ldb, beta, c, ldc,
            );
            return Ok(host_report(ctx.n_devices));
        }
    }
    let (t, cfg) = ctx.plan_call("gemm", T::DTYPE, m, n, k);
    let (ts, dims) =
        plan_gemm(t, ta, tb, m, n, k, alpha.to_f64(), beta.to_f64(), lda, ldb, ldc)?;
    let (ar, ac) = dims.a;
    let (br, bc) = dims.b.expect("gemm has a B operand");
    let am = HostMat::new_ro(a, ar, ac, lda, t, MatId::A);
    let bm = HostMat::new_ro(b, br, bc, ldb, t, MatId::B);
    let cm = HostMat::new(c, m, n, ldc, t, MatId::C);
    ctx.execute_planned(&cfg, m, n, k, &ts, vec![Mats { a: &am, b: Some(&bm), c: &cm }])
}

/// `C := alpha*op(A)*op(A)^T + beta*C`, C symmetric stored in `uplo`.
#[allow(clippy::too_many_arguments)]
pub fn syrk<T: Scalar>(
    ctx: &Context,
    uplo: Uplo,
    trans: Trans,
    n: usize,
    k: usize,
    alpha: T,
    a: &[T],
    lda: usize,
    beta: T,
    c: &mut [T],
    ldc: usize,
) -> Result<RealReport> {
    let (t, cfg) = ctx.plan_call("syrk", T::DTYPE, n, n, k);
    let (ts, dims) = plan_syrk(t, uplo, trans, n, k, alpha.to_f64(), beta.to_f64(), lda, ldc)?;
    let (ar, ac) = dims.a;
    let am = HostMat::new_ro(a, ar, ac, lda, t, MatId::A);
    let cm = HostMat::new(c, n, n, ldc, t, MatId::C);
    ctx.execute_planned(&cfg, n, n, k, &ts, vec![Mats { a: &am, b: None, c: &cm }])
}

/// `C := alpha*(op(A)op(B)^T + op(B)op(A)^T) + beta*C`.
#[allow(clippy::too_many_arguments)]
pub fn syr2k<T: Scalar>(
    ctx: &Context,
    uplo: Uplo,
    trans: Trans,
    n: usize,
    k: usize,
    alpha: T,
    a: &[T],
    lda: usize,
    b: &[T],
    ldb: usize,
    beta: T,
    c: &mut [T],
    ldc: usize,
) -> Result<RealReport> {
    let (t, cfg) = ctx.plan_call("syr2k", T::DTYPE, n, n, k);
    let (ts, dims) =
        plan_syr2k(t, uplo, trans, n, k, alpha.to_f64(), beta.to_f64(), lda, ldb, ldc)?;
    let (ar, ac) = dims.a;
    let am = HostMat::new_ro(a, ar, ac, lda, t, MatId::A);
    let bm = HostMat::new_ro(b, ar, ac, ldb, t, MatId::B);
    let cm = HostMat::new(c, n, n, ldc, t, MatId::C);
    ctx.execute_planned(&cfg, n, n, k, &ts, vec![Mats { a: &am, b: Some(&bm), c: &cm }])
}

/// `C := alpha*sym(A)*B + beta*C` (Left) / `alpha*B*sym(A) + beta*C`.
#[allow(clippy::too_many_arguments)]
pub fn symm<T: Scalar>(
    ctx: &Context,
    side: Side,
    uplo: Uplo,
    m: usize,
    n: usize,
    alpha: T,
    a: &[T],
    lda: usize,
    b: &[T],
    ldb: usize,
    beta: T,
    c: &mut [T],
    ldc: usize,
) -> Result<RealReport> {
    let na = if side == Side::Left { m } else { n };
    let (t, cfg) = ctx.plan_call("symm", T::DTYPE, m, n, na);
    let (ts, dims) =
        plan_symm(t, side, uplo, m, n, alpha.to_f64(), beta.to_f64(), lda, ldb, ldc)?;
    let (na, _) = dims.a;
    let am = HostMat::new_ro(a, na, na, lda, t, MatId::A);
    let bm = HostMat::new_ro(b, m, n, ldb, t, MatId::B);
    let cm = HostMat::new(c, m, n, ldc, t, MatId::C);
    ctx.execute_planned(&cfg, m, n, na, &ts, vec![Mats { a: &am, b: Some(&bm), c: &cm }])
}

/// `B := alpha*op(tri(A))*B` (Left) / `alpha*B*op(tri(A))` (Right),
/// in place in `b`.
#[allow(clippy::too_many_arguments)]
pub fn trmm<T: Scalar>(
    ctx: &Context,
    side: Side,
    uplo: Uplo,
    ta: Trans,
    diag: Diag,
    m: usize,
    n: usize,
    alpha: T,
    a: &[T],
    lda: usize,
    b: &mut [T],
    ldb: usize,
) -> Result<RealReport> {
    let na = if side == Side::Left { m } else { n };
    let (t, cfg) = ctx.plan_call("trmm", T::DTYPE, m, n, na);
    let (ts, dims) = plan_trmm(t, side, uplo, ta, diag, m, n, alpha.to_f64(), lda, ldb)?;
    let (na, _) = dims.a;
    let am = HostMat::new_ro(a, na, na, lda, t, MatId::A);
    let cm = HostMat::new(b, m, n, ldb, t, MatId::C);
    ctx.execute_planned(&cfg, m, n, na, &ts, vec![Mats { a: &am, b: None, c: &cm }])
}

/// Solve `op(tri(A))*X = alpha*B` (Left) / `X*op(tri(A)) = alpha*B`,
/// X overwriting `b`.
#[allow(clippy::too_many_arguments)]
pub fn trsm<T: Scalar>(
    ctx: &Context,
    side: Side,
    uplo: Uplo,
    ta: Trans,
    diag: Diag,
    m: usize,
    n: usize,
    alpha: T,
    a: &[T],
    lda: usize,
    b: &mut [T],
    ldb: usize,
) -> Result<RealReport> {
    let na = if side == Side::Left { m } else { n };
    let (t, cfg) = ctx.plan_call("trsm", T::DTYPE, m, n, na);
    let (ts, dims) = plan_trsm(t, side, uplo, ta, diag, m, n, alpha.to_f64(), lda, ldb)?;
    let (na, _) = dims.a;
    let am = HostMat::new_ro(a, na, na, lda, t, MatId::A);
    let cm = HostMat::new(b, m, n, ldb, t, MatId::C);
    ctx.execute_planned(&cfg, m, n, na, &ts, vec![Mats { a: &am, b: None, c: &cm }])
}

// --- Non-blocking (serving-mode) submission --------------------------
//
// The old free-function `*_async` surface (a `JobHandle<'buf>` that
// borrowed the operand buffers and waited on drop) repeated the
// pre-1.0 `thread::scoped` unsoundness: `std::mem::forget(handle)` was
// safe code that skipped the drop-side wait, and its borrow rules
// forbade expressing the cross-job aliasing chains the admission table
// exists to order. Both are fixed by the closure-scoped API in
// `crate::api::scope` — see [`Context::scope`]: the completion barrier
// lives in a stack frame the caller cannot skip, and scope-registered
// buffers may alias across jobs (ordered by admission edges). C
// callers get the raw-pointer equivalent through `crate::ffi`
// (`blasx_*_async` / `blasx_wait`).

// --- Batched entry points (crate::batch) -----------------------------

/// One problem of a pointer-array GEMM batch: shape, transposes,
/// scalars and leading dimensions (the data rides in parallel slices).
#[derive(Clone, Copy, Debug)]
pub struct GemmBatchEntry {
    pub ta: Trans,
    pub tb: Trans,
    pub m: usize,
    pub n: usize,
    pub k: usize,
    pub alpha: f64,
    pub beta: f64,
    pub lda: usize,
    pub ldb: usize,
    pub ldc: usize,
}

impl GemmBatchEntry {
    /// A plain `C := alpha*A*B + beta*C` entry with tight leading dims.
    pub fn new(m: usize, n: usize, k: usize, alpha: f64, beta: f64) -> GemmBatchEntry {
        GemmBatchEntry {
            ta: Trans::No,
            tb: Trans::No,
            m,
            n,
            k,
            alpha,
            beta,
            lda: m.max(1),
            ldb: k.max(1),
            ldc: m.max(1),
        }
    }
}

/// Stored (rows, cols) of op(A) and op(B) for an entry.
fn gemm_operand_dims(e: &GemmBatchEntry) -> ((usize, usize), (usize, usize)) {
    let a = if e.ta == Trans::No { (e.m, e.k) } else { (e.k, e.m) };
    let b = if e.tb == Trans::No { (e.k, e.n) } else { (e.n, e.k) };
    (a, b)
}

/// Column-major footprint of an `rows × cols` operand with leading
/// dimension `ld` — the minimum buffer length `HostMat` accepts.
/// Shared by the batch validators here, the scope token checks, and
/// the C ABI's pointer validation.
pub(crate) fn footprint(ld: usize, rows: usize, cols: usize) -> usize {
    if cols == 0 {
        0
    } else {
        ld * (cols - 1) + rows
    }
}

/// Batched GEMM, pointer-array flavour: `c[i] := alpha_i * op(A_i) *
/// op(B_i) + beta_i * c[i]` for every entry, through ONE scheduler
/// invocation — all problems fused into a single task set with
/// problem-namespaced tiles (see [`crate::batch`]), so taskization,
/// cache warm-up and stream setup are paid once for the whole batch
/// and small problems share devices instead of serializing.
///
/// Shapes may vary per entry (variable-size batch). Numerics are
/// bit-identical to looping [`gemm`] over the entries with the same
/// context: the per-problem tile decomposition and per-tile summation
/// order are exactly the single-call ones.
pub fn gemm_batched<T: Scalar>(
    ctx: &Context,
    entries: &[GemmBatchEntry],
    a: &[&[T]],
    b: &[&[T]],
    c: &mut [&mut [T]],
) -> Result<RealReport> {
    if a.len() != entries.len() || b.len() != entries.len() || c.len() != entries.len() {
        return Err(illegal(
            "gemm_batched",
            2,
            format!(
                "operand count mismatch: {} entries vs {} A / {} B / {} C buffers",
                entries.len(),
                a.len(),
                b.len(),
                c.len()
            ),
        ));
    }
    let t = ctx.tile();
    let mut descs = Vec::with_capacity(entries.len());
    for (i, e) in entries.iter().enumerate() {
        check::check_gemm(e.ta, e.tb, e.m, e.n, e.k, e.lda, e.ldb, e.ldc).map_err(|err| {
            illegal("gemm_batched", 2, format!("entry {i}: {err}"))
        })?;
        descs.push(GemmDesc {
            ta: e.ta,
            tb: e.tb,
            m: e.m,
            n: e.n,
            k: e.k,
            alpha: e.alpha,
            beta: e.beta,
            t,
        });
    }
    let ts = taskize_batch(&BatchDesc::Gemm(BatchedGemm::variable(descs)), t, ctx.n_devices);

    let mut amats = Vec::with_capacity(entries.len());
    let mut bmats = Vec::with_capacity(entries.len());
    let mut cmats = Vec::with_capacity(entries.len());
    for (i, e) in entries.iter().enumerate() {
        let ((ar, ac), (br, bc)) = gemm_operand_dims(e);
        amats.push(HostMat::new_ro(a[i], ar, ac, e.lda, t, MatId::A));
        bmats.push(HostMat::new_ro(b[i], br, bc, e.ldb, t, MatId::B));
    }
    for (e, ci) in entries.iter().zip(c.iter_mut()) {
        cmats.push(HostMat::new(ci, e.m, e.n, e.ldc, t, MatId::C));
    }
    let problems: Vec<Mats<'_, T>> = (0..entries.len())
        .map(|i| Mats { a: &amats[i], b: Some(&bmats[i]), c: &cmats[i] })
        .collect();
    // Fused batches ride the same doorway as single calls: through the
    // resident runtime (quanta-ordered heads land in the persistent
    // workers' stations) or the one-shot engine when persistence is off.
    ctx.execute("gemm_batched", &ts, problems)
}

/// Batched GEMM, strided flavour: problem `i` reads `a[i*stride_a..]`,
/// `b[i*stride_b..]` and updates `c[i*stride_c..]`; all problems share
/// one shape/transpose/scalar set (the cuBLAS
/// `gemmStridedBatched` contract). `stride_x == 0` is allowed for A/B
/// when every problem reads the same operand (broadcast — e.g. one
/// weight matrix against many activation blocks); C strides must be
/// non-overlapping.
#[allow(clippy::too_many_arguments)]
pub fn gemm_batched_strided<T: Scalar>(
    ctx: &Context,
    ta: Trans,
    tb: Trans,
    m: usize,
    n: usize,
    k: usize,
    alpha: T,
    a: &[T],
    lda: usize,
    stride_a: usize,
    b: &[T],
    ldb: usize,
    stride_b: usize,
    beta: T,
    c: &mut [T],
    ldc: usize,
    stride_c: usize,
    batch: usize,
) -> Result<RealReport> {
    check::check_gemm(ta, tb, m, n, k, lda, ldb, ldc)?;
    let entry = GemmBatchEntry {
        ta,
        tb,
        m,
        n,
        k,
        alpha: alpha.to_f64(),
        beta: beta.to_f64(),
        lda,
        ldb,
        ldc,
    };
    let ((ar, ac), (br, bc)) = gemm_operand_dims(&entry);
    let need_a = footprint(lda, ar, ac);
    let need_b = footprint(ldb, br, bc);
    let need_c = footprint(ldc, m, n);
    if batch > 1 {
        if stride_a != 0 && stride_a < need_a {
            return Err(illegal("gemm_batched_strided", 10, format!("stride_a {stride_a} < operand footprint {need_a}")));
        }
        if stride_b != 0 && stride_b < need_b {
            return Err(illegal("gemm_batched_strided", 13, format!("stride_b {stride_b} < operand footprint {need_b}")));
        }
        if stride_c < need_c.max(1) {
            return Err(illegal("gemm_batched_strided", 17, format!("stride_c {stride_c} overlaps output footprint {need_c}")));
        }
    }
    if batch > 0 {
        let last = batch - 1;
        if a.len() < last * stride_a + need_a {
            return Err(illegal("gemm_batched_strided", 8, format!("A buffer too small: len {} for batch {batch}", a.len())));
        }
        if b.len() < last * stride_b + need_b {
            return Err(illegal("gemm_batched_strided", 11, format!("B buffer too small: len {} for batch {batch}", b.len())));
        }
        if c.len() < last * stride_c + need_c {
            return Err(illegal("gemm_batched_strided", 15, format!("C buffer too small: len {} for batch {batch}", c.len())));
        }
    }
    let entries = vec![entry; batch];
    let aslices: Vec<&[T]> = (0..batch).map(|i| &a[i * stride_a..]).collect();
    let bslices: Vec<&[T]> = (0..batch).map(|i| &b[i * stride_b..]).collect();
    // C must be split into disjoint &mut chunks.
    let mut cslices: Vec<&mut [T]> = Vec::with_capacity(batch);
    let mut rest = c;
    for i in 0..batch {
        let cur = std::mem::take(&mut rest);
        if i + 1 == batch {
            cslices.push(cur);
        } else {
            let (head, tail) = cur.split_at_mut(stride_c);
            cslices.push(head);
            rest = tail;
        }
    }
    gemm_batched(ctx, &entries, &aslices, &bslices, &mut cslices)
}

/// Double-precision batched GEMM (pointer-array variant).
pub fn dgemm_batched(
    ctx: &Context,
    entries: &[GemmBatchEntry],
    a: &[&[f64]],
    b: &[&[f64]],
    c: &mut [&mut [f64]],
) -> Result<RealReport> {
    gemm_batched(ctx, entries, a, b, c)
}

/// Single-precision batched GEMM (pointer-array variant).
pub fn sgemm_batched(
    ctx: &Context,
    entries: &[GemmBatchEntry],
    a: &[&[f32]],
    b: &[&[f32]],
    c: &mut [&mut [f32]],
) -> Result<RealReport> {
    gemm_batched(ctx, entries, a, b, c)
}

/// Double-precision strided batched GEMM.
#[allow(clippy::too_many_arguments)]
pub fn dgemm_batched_strided(
    ctx: &Context,
    ta: Trans,
    tb: Trans,
    m: usize,
    n: usize,
    k: usize,
    alpha: f64,
    a: &[f64],
    lda: usize,
    stride_a: usize,
    b: &[f64],
    ldb: usize,
    stride_b: usize,
    beta: f64,
    c: &mut [f64],
    ldc: usize,
    stride_c: usize,
    batch: usize,
) -> Result<RealReport> {
    gemm_batched_strided(
        ctx, ta, tb, m, n, k, alpha, a, lda, stride_a, b, ldb, stride_b, beta, c, ldc, stride_c,
        batch,
    )
}

/// Single-precision strided batched GEMM.
#[allow(clippy::too_many_arguments)]
pub fn sgemm_batched_strided(
    ctx: &Context,
    ta: Trans,
    tb: Trans,
    m: usize,
    n: usize,
    k: usize,
    alpha: f32,
    a: &[f32],
    lda: usize,
    stride_a: usize,
    b: &[f32],
    ldb: usize,
    stride_b: usize,
    beta: f32,
    c: &mut [f32],
    ldc: usize,
    stride_c: usize,
    batch: usize,
) -> Result<RealReport> {
    gemm_batched_strided(
        ctx, ta, tb, m, n, k, alpha, a, lda, stride_a, b, ldb, stride_b, beta, c, ldc, stride_c,
        batch,
    )
}

// --- CBLAS-flavoured aliases -----------------------------------------

/// Double-precision GEMM with the classic parameter order.
#[allow(clippy::too_many_arguments)]
pub fn dgemm(
    ctx: &Context,
    ta: Trans,
    tb: Trans,
    m: usize,
    n: usize,
    k: usize,
    alpha: f64,
    a: &[f64],
    lda: usize,
    b: &[f64],
    ldb: usize,
    beta: f64,
    c: &mut [f64],
    ldc: usize,
) -> Result<RealReport> {
    gemm(ctx, ta, tb, m, n, k, alpha, a, lda, b, ldb, beta, c, ldc)
}

/// Single-precision GEMM.
#[allow(clippy::too_many_arguments)]
pub fn sgemm(
    ctx: &Context,
    ta: Trans,
    tb: Trans,
    m: usize,
    n: usize,
    k: usize,
    alpha: f32,
    a: &[f32],
    lda: usize,
    b: &[f32],
    ldb: usize,
    beta: f32,
    c: &mut [f32],
    ldc: usize,
) -> Result<RealReport> {
    gemm(ctx, ta, tb, m, n, k, alpha, a, lda, b, ldb, beta, c, ldc)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hostblas;
    use crate::util::prng::Prng;

    fn small_ctx() -> Context {
        Context::new(2).with_arena(4 << 20).with_tile(32)
    }

    #[test]
    fn dgemm_smoke() {
        let ctx = small_ctx();
        let (m, n, k) = (65, 47, 83);
        let mut p = Prng::new(11);
        let mut a = vec![0.0; m * k];
        let mut b = vec![0.0; k * n];
        let mut c = vec![0.0; m * n];
        p.fill_f64(&mut a, -1.0, 1.0);
        p.fill_f64(&mut b, -1.0, 1.0);
        p.fill_f64(&mut c, -1.0, 1.0);
        let mut want = c.clone();
        dgemm(&ctx, Trans::No, Trans::No, m, n, k, 1.1, &a, m, &b, k, -0.3, &mut c, m).unwrap();
        hostblas::gemm_blocked(Trans::No, Trans::No, m, n, k, 1.1, &a, m, &b, k, -0.3, &mut want, m);
        let diff = c.iter().zip(&want).map(|(x, y)| (x - y).abs()).fold(0.0, f64::max);
        assert!(diff < 1e-10, "{diff}");
    }

    #[test]
    fn sgemm_smoke() {
        let ctx = small_ctx();
        let (m, n, k) = (64, 64, 64);
        let mut p = Prng::new(12);
        let mut a = vec![0.0f32; m * k];
        let mut b = vec![0.0f32; k * n];
        let mut c = vec![0.0f32; m * n];
        p.fill_f32(&mut a, -1.0, 1.0);
        p.fill_f32(&mut b, -1.0, 1.0);
        p.fill_f32(&mut c, -1.0, 1.0);
        let mut want = c.clone();
        sgemm(&ctx, Trans::No, Trans::No, m, n, k, 2.0, &a, m, &b, k, 0.5, &mut c, m).unwrap();
        hostblas::gemm_blocked(Trans::No, Trans::No, m, n, k, 2.0f32, &a, m, &b, k, 0.5, &mut want, m);
        let diff = c.iter().zip(&want).map(|(x, y)| (x - y).abs()).fold(0.0f32, f32::max);
        assert!(diff < 1e-3, "{diff}");
    }

    #[test]
    fn ld_larger_than_rows() {
        let ctx = small_ctx();
        let (m, n, k, lda) = (30, 20, 25, 40);
        let mut p = Prng::new(13);
        let mut a = vec![0.0; lda * k];
        let mut b = vec![0.0; k * n];
        let mut c = vec![0.0; m * n];
        p.fill_f64(&mut a, -1.0, 1.0);
        p.fill_f64(&mut b, -1.0, 1.0);
        let mut want = c.clone();
        dgemm(&ctx, Trans::No, Trans::No, m, n, k, 1.0, &a, lda, &b, k, 0.0, &mut c, m).unwrap();
        hostblas::gemm_blocked(Trans::No, Trans::No, m, n, k, 1.0, &a, lda, &b, k, 0.0, &mut want, m);
        assert_eq!(c, want);
    }

    #[test]
    fn rejects_bad_ld() {
        let ctx = small_ctx();
        let a = vec![0.0; 100];
        let b = vec![0.0; 100];
        let mut c = vec![0.0; 100];
        let err = dgemm(&ctx, Trans::No, Trans::No, 10, 10, 10, 1.0, &a, 5, &b, 10, 0.0, &mut c, 10);
        assert!(err.is_err());
    }

    #[test]
    fn with_arena_sizes_the_tile_cache() {
        let ctx = Context::default().with_arena(16 << 20);
        assert_eq!(ctx.arena_bytes, 16 << 20);
        // default: 64 MiB / (256*256*8 B) = exactly 128 f64 tiles
        let d = Context::default();
        assert_eq!(d.arena_bytes / (d.cfg.t * d.cfg.t * 8), 128);
    }

    #[test]
    fn persistent_runtime_boots_lazily_and_counts_calls() {
        let ctx = small_ctx();
        assert!(ctx.persistent, "persistent engine is the default");
        assert!(!ctx.runtime_booted(), "boot is lazy");
        let (m, n, k) = (40, 40, 40);
        let a = vec![1.0; m * k];
        let b = vec![1.0; k * n];
        let mut c = vec![0.0; m * n];
        dgemm(&ctx, Trans::No, Trans::No, m, n, k, 1.0, &a, m, &b, k, 0.0, &mut c, m).unwrap();
        assert!(ctx.runtime_booted());
        assert_eq!(ctx.runtime_calls(), 1);
        // clones share the warm runtime
        let clone = ctx.clone();
        dgemm(&clone, Trans::No, Trans::No, m, n, k, 1.0, &a, m, &b, k, 0.0, &mut c, m).unwrap();
        assert_eq!(ctx.runtime_calls(), 2);
        ctx.shutdown_runtime();
        assert!(!ctx.runtime_booted());
    }

    #[test]
    fn non_persistent_path_never_boots() {
        let ctx = small_ctx().with_persistent(false);
        let a = vec![1.0; 32 * 32];
        let b = vec![1.0; 32 * 32];
        let mut c = vec![0.0; 32 * 32];
        dgemm(&ctx, Trans::No, Trans::No, 32, 32, 32, 1.0, &a, 32, &b, 32, 0.0, &mut c, 32)
            .unwrap();
        assert!(!ctx.runtime_booted());
        assert!(c.iter().all(|&x| x == 32.0));
    }

    #[test]
    fn dgemm_batched_smoke_vs_hostblas() {
        let ctx = small_ctx();
        let shapes = [(40usize, 24usize, 33usize), (65, 17, 9), (16, 16, 16)];
        let mut p = Prng::new(77);
        let entries: Vec<GemmBatchEntry> =
            shapes.iter().map(|&(m, n, k)| GemmBatchEntry::new(m, n, k, 1.25, -0.5)).collect();
        let mut abufs = Vec::new();
        let mut bbufs = Vec::new();
        let mut cbufs = Vec::new();
        for &(m, n, k) in &shapes {
            let mut a = vec![0.0; m * k];
            let mut b = vec![0.0; k * n];
            let mut c = vec![0.0; m * n];
            p.fill_f64(&mut a, -1.0, 1.0);
            p.fill_f64(&mut b, -1.0, 1.0);
            p.fill_f64(&mut c, -1.0, 1.0);
            abufs.push(a);
            bbufs.push(b);
            cbufs.push(c);
        }
        let want: Vec<Vec<f64>> = cbufs.clone();
        let arefs: Vec<&[f64]> = abufs.iter().map(Vec::as_slice).collect();
        let brefs: Vec<&[f64]> = bbufs.iter().map(Vec::as_slice).collect();
        let mut crefs: Vec<&mut [f64]> = cbufs.iter_mut().map(Vec::as_mut_slice).collect();
        dgemm_batched(&ctx, &entries, &arefs, &brefs, &mut crefs).unwrap();
        for (i, &(m, n, k)) in shapes.iter().enumerate() {
            let mut w = want[i].clone();
            hostblas::gemm_blocked(
                Trans::No, Trans::No, m, n, k, 1.25, &abufs[i], m, &bbufs[i], k, -0.5, &mut w, m,
            );
            let diff =
                cbufs[i].iter().zip(&w).map(|(x, y)| (x - y).abs()).fold(0.0, f64::max);
            assert!(diff < 1e-10, "problem {i}: {diff}");
        }
    }

    #[test]
    fn gemm_batched_rejects_count_mismatch() {
        let ctx = small_ctx();
        let entries = [GemmBatchEntry::new(4, 4, 4, 1.0, 0.0)];
        let a = vec![0.0f64; 16];
        let b = vec![0.0f64; 16];
        let err = dgemm_batched(&ctx, &entries, &[&a, &a], &[&b], &mut []);
        assert!(err.is_err());
    }

    #[test]
    fn scope_async_gemm_smoke() {
        let ctx = small_ctx();
        let (m, n, k) = (64, 48, 40);
        let mut p = Prng::new(21);
        let mut a = vec![0.0; m * k];
        let mut b = vec![0.0; k * n];
        let mut c = vec![0.0; m * n];
        p.fill_f64(&mut a, -1.0, 1.0);
        p.fill_f64(&mut b, -1.0, 1.0);
        ctx.scope(|s| {
            let (ra, rb) = (s.input(&a), s.input(&b));
            let rc = s.buffer(&mut c);
            let h = s.dgemm(Trans::No, Trans::No, m, n, k, 1.0, ra, m, rb, k, 0.0, rc, m)?;
            let rep = h.wait()?;
            assert!(rep.transfers.total_host_reads() > 0);
            Ok(())
        })
        .unwrap();
        let mut want = vec![0.0; m * n];
        hostblas::gemm_blocked(Trans::No, Trans::No, m, n, k, 1.0, &a, m, &b, k, 0.0, &mut want, m);
        let diff = c.iter().zip(&want).map(|(x, y)| (x - y).abs()).fold(0.0, f64::max);
        assert!(diff < 1e-10, "{diff}");
        assert_eq!(ctx.runtime_calls(), 1);
    }

    #[test]
    fn scope_requires_persistent_runtime() {
        let ctx = small_ctx().with_persistent(false);
        let err = ctx.scope(|_s| Ok(()));
        assert!(err.is_err());
    }

    #[test]
    fn scope_close_is_the_completion_barrier() {
        let ctx = small_ctx();
        let n = 64;
        let a = vec![1.0; n * n];
        let b = vec![1.0; n * n];
        let mut c = vec![0.0; n * n];
        ctx.scope(|s| {
            let (ra, rb) = (s.input(&a), s.input(&b));
            let rc = s.buffer(&mut c);
            // Detached (never waited): the scope close must still wait.
            let _ = s.dgemm(Trans::No, Trans::No, n, n, n, 1.0, ra, n, rb, n, 0.0, rc, n)?;
            Ok(())
        })
        .unwrap();
        assert!(c.iter().all(|&x| x == n as f64), "scope close is a completion barrier");
        assert_eq!(ctx.jobs_in_flight(), 0);
    }

    #[test]
    fn host_placement_matches_the_tiled_oracle() {
        // A profile that routes this shape bucket to the host: the call
        // must produce the exact serial-kernel bytes and touch neither
        // tiles nor caches — on both the persistent (admission-ordered
        // HostGemm job) and one-shot paths.
        use crate::dispatch::shape_key;
        let (m, n, k) = (48, 40, 44);
        let mut prof = Profile::new();
        prof.set(
            shape_key("gemm", Dtype::F64, m, n, k),
            Choice { t: 32, kernel_threads: 1, mt_cutoff: None, place: Placement::Host },
        );
        let mut p = Prng::new(31);
        let mut a = vec![0.0; m * k];
        let mut b = vec![0.0; k * n];
        let mut c0 = vec![0.0; m * n];
        p.fill_f64(&mut a, -1.0, 1.0);
        p.fill_f64(&mut b, -1.0, 1.0);
        p.fill_f64(&mut c0, -1.0, 1.0);
        let mut want = c0.clone();
        hostblas::gemm_mt(1, Trans::No, Trans::No, m, n, k, 1.5, &a, m, &b, k, -0.25, &mut want, m);
        for persistent in [true, false] {
            let ctx = small_ctx().with_profile(prof.clone()).with_persistent(persistent);
            let mut c = c0.clone();
            let rep =
                dgemm(&ctx, Trans::No, Trans::No, m, n, k, 1.5, &a, m, &b, k, -0.25, &mut c, m)
                    .unwrap();
            assert_eq!(c, want, "persistent={persistent}");
            assert_eq!(rep.transfers, TransferStats::default(), "host call stages nothing");
            assert_eq!(rep.tasks_per_device.iter().sum::<usize>(), 0);
        }
    }

    #[test]
    fn profile_overrides_the_tile_size() {
        use crate::dispatch::shape_key;
        let (m, n, k) = (64, 64, 64);
        let mut prof = Profile::new();
        prof.set(
            shape_key("gemm", Dtype::F64, m, n, k),
            Choice { t: 16, kernel_threads: 1, mt_cutoff: None, place: Placement::Device },
        );
        let ctx = small_ctx().with_profile(prof);
        let mut p = Prng::new(32);
        let mut a = vec![0.0; m * k];
        let mut b = vec![0.0; k * n];
        let mut c = vec![0.0; m * n];
        p.fill_f64(&mut a, -1.0, 1.0);
        p.fill_f64(&mut b, -1.0, 1.0);
        p.fill_f64(&mut c, -1.0, 1.0);
        let mut want = c.clone();
        dgemm(&ctx, Trans::No, Trans::No, m, n, k, 1.0, &a, m, &b, k, 0.5, &mut c, m).unwrap();
        hostblas::gemm_blocked(Trans::No, Trans::No, m, n, k, 1.0, &a, m, &b, k, 0.5, &mut want, m);
        let diff = c.iter().zip(&want).map(|(x, y)| (x - y).abs()).fold(0.0, f64::max);
        assert!(diff < 1e-10, "profile-chosen t=16 run diverged: {diff}");
    }

    #[test]
    fn adaptive_dispatch_stays_correct_across_exploration() {
        // The adaptive explorer rotates tile sizes call-to-call; every
        // choice must stay bit-level-accurate against the oracle.
        let ctx = Context::new(2).with_arena(8 << 20).with_tile(64).with_adaptive_dispatch();
        assert!(ctx.dispatcher().unwrap().is_adaptive());
        let (m, n, k) = (100, 90, 110);
        let mut p = Prng::new(33);
        let mut a = vec![0.0; m * k];
        let mut b = vec![0.0; k * n];
        p.fill_f64(&mut a, -1.0, 1.0);
        p.fill_f64(&mut b, -1.0, 1.0);
        for call in 0..5 {
            let mut c = vec![1.0; m * n];
            let mut want = c.clone();
            dgemm(&ctx, Trans::No, Trans::No, m, n, k, 1.0, &a, m, &b, k, 1.0, &mut c, m)
                .unwrap();
            hostblas::gemm_blocked(
                Trans::No, Trans::No, m, n, k, 1.0, &a, m, &b, k, 1.0, &mut want, m,
            );
            let diff = c.iter().zip(&want).map(|(x, y)| (x - y).abs()).fold(0.0, f64::max);
            assert!(diff < 1e-10, "call {call}: {diff}");
        }
    }

    #[test]
    fn trsm_roundtrip_with_trmm() {
        // trmm then trsm with the same triangle is the identity.
        let ctx = small_ctx();
        let n = 48;
        let mut p = Prng::new(14);
        let mut a = vec![0.0; n * n];
        p.fill_f64(&mut a, -0.2, 0.2);
        for i in 0..n {
            a[i * n + i] = 2.0;
        }
        let mut b = vec![0.0; n * n];
        p.fill_f64(&mut b, -1.0, 1.0);
        let orig = b.clone();
        trmm(&ctx, Side::Left, Uplo::Upper, Trans::No, Diag::NonUnit, n, n, 2.0, &a, n, &mut b, n)
            .unwrap();
        trsm(&ctx, Side::Left, Uplo::Upper, Trans::No, Diag::NonUnit, n, n, 0.5, &a, n, &mut b, n)
            .unwrap();
        let diff = b.iter().zip(&orig).map(|(x, y)| (x - y).abs()).fold(0.0, f64::max);
        assert!(diff < 1e-10, "{diff}");
    }
}
