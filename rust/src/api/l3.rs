//! The public L3 BLAS API — BLASX's backward-compatibility surface
//! (paper §I: "all the details … can be ignored by library users").
//!
//! Signatures mirror CBLAS column-major conventions: `{s,d}gemm`,
//! `{s,d}syrk`, `{s,d}syr2k`, `{s,d}trmm`, `{s,d}trsm`, `{s,d}symm`.
//! Each call taskizes the problem, spins up the multi-device runtime and
//! returns once C (or B for TRMM/TRSM) holds the result — exactly the
//! drop-in-replacement contract the paper demonstrates with Caffe and
//! MATLAB.
//!
//! The execution context (device count, arena bytes, tile size, kernel
//! backend) comes from a [`Context`], with a process-default tuned for
//! this testbed.

use super::check;
use super::types::{Diag, Scalar, Side, Trans, Uplo};
use crate::coordinator::real_engine::{run_real, Mats, RealReport};
use crate::coordinator::{Backend, RunConfig};
use crate::error::Result;
use crate::task::{
    taskize_gemm, taskize_symm, taskize_syr2k, taskize_syrk, taskize_trmm, taskize_trsm,
    GemmDesc, SymmDesc, SyrkDesc, TriDesc,
};
use crate::tile::{HostMat, MatId};

/// Execution context: how many virtual devices, how much arena each,
/// which tile size and kernel backend.
#[derive(Clone, Debug)]
pub struct Context {
    pub n_devices: usize,
    pub arena_bytes: usize,
    pub cfg: RunConfig,
}

impl Default for Context {
    fn default() -> Context {
        // 2 virtual devices exercises the full multi-device protocol
        // (P2P path, stealing) while staying sensible on small hosts;
        // 64 MiB arena each ≈ 128 tiles at T=256/f64.
        Context {
            n_devices: 2,
            arena_bytes: 64 << 20,
            cfg: RunConfig { t: 256, ..Default::default() },
        }
    }
}

impl Context {
    pub fn new(n_devices: usize) -> Context {
        Context { n_devices, ..Default::default() }
    }

    pub fn with_tile(mut self, t: usize) -> Context {
        self.cfg.t = t;
        self
    }

    pub fn with_backend(mut self, b: Backend) -> Context {
        self.cfg.backend = b;
        self
    }

    /// Tile size floor: degenerate matrices still need one tile.
    fn tile(&self) -> usize {
        self.cfg.t
    }
}

/// `C := alpha*op(A)*op(B) + beta*C` (column-major, leading dims).
#[allow(clippy::too_many_arguments)]
pub fn gemm<T: Scalar>(
    ctx: &Context,
    ta: Trans,
    tb: Trans,
    m: usize,
    n: usize,
    k: usize,
    alpha: T,
    a: &[T],
    lda: usize,
    b: &[T],
    ldb: usize,
    beta: T,
    c: &mut [T],
    ldc: usize,
) -> Result<RealReport> {
    check::check_gemm(ta, tb, m, n, k, lda, ldb, ldc)?;
    let t = ctx.tile();
    let d = GemmDesc { ta, tb, m, n, k, alpha: alpha.to_f64(), beta: beta.to_f64(), t };
    let ts = taskize_gemm(&d);
    let (ar, ac) = if ta == Trans::No { (m, k) } else { (k, m) };
    let (br, bc) = if tb == Trans::No { (k, n) } else { (n, k) };
    let am = HostMat::new_ro(a, ar, ac, lda, t, MatId::A);
    let bm = HostMat::new_ro(b, br, bc, ldb, t, MatId::B);
    let cm = HostMat::new(c, m, n, ldc, t, MatId::C);
    run_real(&ctx.cfg, &ts, Mats { a: &am, b: Some(&bm), c: &cm }, ctx.n_devices, ctx.arena_bytes)
}

/// `C := alpha*op(A)*op(A)^T + beta*C`, C symmetric stored in `uplo`.
#[allow(clippy::too_many_arguments)]
pub fn syrk<T: Scalar>(
    ctx: &Context,
    uplo: Uplo,
    trans: Trans,
    n: usize,
    k: usize,
    alpha: T,
    a: &[T],
    lda: usize,
    beta: T,
    c: &mut [T],
    ldc: usize,
) -> Result<RealReport> {
    check::check_syrk(trans, n, k, lda, None, ldc, "syrk")?;
    let t = ctx.tile();
    let d = SyrkDesc { uplo, trans, n, k, alpha: alpha.to_f64(), beta: beta.to_f64(), t };
    let ts = taskize_syrk(&d);
    let (ar, ac) = if trans == Trans::No { (n, k) } else { (k, n) };
    let am = HostMat::new_ro(a, ar, ac, lda, t, MatId::A);
    let cm = HostMat::new(c, n, n, ldc, t, MatId::C);
    run_real(&ctx.cfg, &ts, Mats { a: &am, b: None, c: &cm }, ctx.n_devices, ctx.arena_bytes)
}

/// `C := alpha*(op(A)op(B)^T + op(B)op(A)^T) + beta*C`.
#[allow(clippy::too_many_arguments)]
pub fn syr2k<T: Scalar>(
    ctx: &Context,
    uplo: Uplo,
    trans: Trans,
    n: usize,
    k: usize,
    alpha: T,
    a: &[T],
    lda: usize,
    b: &[T],
    ldb: usize,
    beta: T,
    c: &mut [T],
    ldc: usize,
) -> Result<RealReport> {
    check::check_syrk(trans, n, k, lda, Some(ldb), ldc, "syr2k")?;
    let t = ctx.tile();
    let d = SyrkDesc { uplo, trans, n, k, alpha: alpha.to_f64(), beta: beta.to_f64(), t };
    let ts = taskize_syr2k(&d);
    let (ar, ac) = if trans == Trans::No { (n, k) } else { (k, n) };
    let am = HostMat::new_ro(a, ar, ac, lda, t, MatId::A);
    let bm = HostMat::new_ro(b, ar, ac, ldb, t, MatId::B);
    let cm = HostMat::new(c, n, n, ldc, t, MatId::C);
    run_real(&ctx.cfg, &ts, Mats { a: &am, b: Some(&bm), c: &cm }, ctx.n_devices, ctx.arena_bytes)
}

/// `C := alpha*sym(A)*B + beta*C` (Left) / `alpha*B*sym(A) + beta*C`.
#[allow(clippy::too_many_arguments)]
pub fn symm<T: Scalar>(
    ctx: &Context,
    side: Side,
    uplo: Uplo,
    m: usize,
    n: usize,
    alpha: T,
    a: &[T],
    lda: usize,
    b: &[T],
    ldb: usize,
    beta: T,
    c: &mut [T],
    ldc: usize,
) -> Result<RealReport> {
    check::check_symm(side, m, n, lda, ldb, ldc)?;
    let t = ctx.tile();
    let d = SymmDesc { side, uplo, m, n, alpha: alpha.to_f64(), beta: beta.to_f64(), t };
    let ts = taskize_symm(&d);
    let na = if side == Side::Left { m } else { n };
    let am = HostMat::new_ro(a, na, na, lda, t, MatId::A);
    let bm = HostMat::new_ro(b, m, n, ldb, t, MatId::B);
    let cm = HostMat::new(c, m, n, ldc, t, MatId::C);
    run_real(&ctx.cfg, &ts, Mats { a: &am, b: Some(&bm), c: &cm }, ctx.n_devices, ctx.arena_bytes)
}

/// `B := alpha*op(tri(A))*B` (Left) / `alpha*B*op(tri(A))` (Right),
/// in place in `b`.
#[allow(clippy::too_many_arguments)]
pub fn trmm<T: Scalar>(
    ctx: &Context,
    side: Side,
    uplo: Uplo,
    ta: Trans,
    diag: Diag,
    m: usize,
    n: usize,
    alpha: T,
    a: &[T],
    lda: usize,
    b: &mut [T],
    ldb: usize,
) -> Result<RealReport> {
    check::check_trxm(side, m, n, lda, ldb, "trmm")?;
    let t = ctx.tile();
    let d = TriDesc { side, uplo, ta, diag, m, n, alpha: alpha.to_f64(), t };
    let ts = taskize_trmm(&d);
    let na = if side == Side::Left { m } else { n };
    let am = HostMat::new_ro(a, na, na, lda, t, MatId::A);
    let cm = HostMat::new(b, m, n, ldb, t, MatId::C);
    run_real(&ctx.cfg, &ts, Mats { a: &am, b: None, c: &cm }, ctx.n_devices, ctx.arena_bytes)
}

/// Solve `op(tri(A))*X = alpha*B` (Left) / `X*op(tri(A)) = alpha*B`,
/// X overwriting `b`.
#[allow(clippy::too_many_arguments)]
pub fn trsm<T: Scalar>(
    ctx: &Context,
    side: Side,
    uplo: Uplo,
    ta: Trans,
    diag: Diag,
    m: usize,
    n: usize,
    alpha: T,
    a: &[T],
    lda: usize,
    b: &mut [T],
    ldb: usize,
) -> Result<RealReport> {
    check::check_trxm(side, m, n, lda, ldb, "trsm")?;
    let t = ctx.tile();
    let d = TriDesc { side, uplo, ta, diag, m, n, alpha: alpha.to_f64(), t };
    let ts = taskize_trsm(&d);
    let na = if side == Side::Left { m } else { n };
    let am = HostMat::new_ro(a, na, na, lda, t, MatId::A);
    let cm = HostMat::new(b, m, n, ldb, t, MatId::C);
    run_real(&ctx.cfg, &ts, Mats { a: &am, b: None, c: &cm }, ctx.n_devices, ctx.arena_bytes)
}

// --- CBLAS-flavoured aliases -----------------------------------------

/// Double-precision GEMM with the classic parameter order.
#[allow(clippy::too_many_arguments)]
pub fn dgemm(
    ctx: &Context,
    ta: Trans,
    tb: Trans,
    m: usize,
    n: usize,
    k: usize,
    alpha: f64,
    a: &[f64],
    lda: usize,
    b: &[f64],
    ldb: usize,
    beta: f64,
    c: &mut [f64],
    ldc: usize,
) -> Result<RealReport> {
    gemm(ctx, ta, tb, m, n, k, alpha, a, lda, b, ldb, beta, c, ldc)
}

/// Single-precision GEMM.
#[allow(clippy::too_many_arguments)]
pub fn sgemm(
    ctx: &Context,
    ta: Trans,
    tb: Trans,
    m: usize,
    n: usize,
    k: usize,
    alpha: f32,
    a: &[f32],
    lda: usize,
    b: &[f32],
    ldb: usize,
    beta: f32,
    c: &mut [f32],
    ldc: usize,
) -> Result<RealReport> {
    gemm(ctx, ta, tb, m, n, k, alpha, a, lda, b, ldb, beta, c, ldc)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hostblas;
    use crate::util::prng::Prng;

    fn small_ctx() -> Context {
        Context { n_devices: 2, arena_bytes: 4 << 20, cfg: RunConfig { t: 32, ..Default::default() } }
    }

    #[test]
    fn dgemm_smoke() {
        let ctx = small_ctx();
        let (m, n, k) = (65, 47, 83);
        let mut p = Prng::new(11);
        let mut a = vec![0.0; m * k];
        let mut b = vec![0.0; k * n];
        let mut c = vec![0.0; m * n];
        p.fill_f64(&mut a, -1.0, 1.0);
        p.fill_f64(&mut b, -1.0, 1.0);
        p.fill_f64(&mut c, -1.0, 1.0);
        let mut want = c.clone();
        dgemm(&ctx, Trans::No, Trans::No, m, n, k, 1.1, &a, m, &b, k, -0.3, &mut c, m).unwrap();
        hostblas::gemm_blocked(Trans::No, Trans::No, m, n, k, 1.1, &a, m, &b, k, -0.3, &mut want, m);
        let diff = c.iter().zip(&want).map(|(x, y)| (x - y).abs()).fold(0.0, f64::max);
        assert!(diff < 1e-10, "{diff}");
    }

    #[test]
    fn sgemm_smoke() {
        let ctx = small_ctx();
        let (m, n, k) = (64, 64, 64);
        let mut p = Prng::new(12);
        let mut a = vec![0.0f32; m * k];
        let mut b = vec![0.0f32; k * n];
        let mut c = vec![0.0f32; m * n];
        p.fill_f32(&mut a, -1.0, 1.0);
        p.fill_f32(&mut b, -1.0, 1.0);
        p.fill_f32(&mut c, -1.0, 1.0);
        let mut want = c.clone();
        sgemm(&ctx, Trans::No, Trans::No, m, n, k, 2.0, &a, m, &b, k, 0.5, &mut c, m).unwrap();
        hostblas::gemm_blocked(Trans::No, Trans::No, m, n, k, 2.0f32, &a, m, &b, k, 0.5, &mut want, m);
        let diff = c.iter().zip(&want).map(|(x, y)| (x - y).abs()).fold(0.0f32, f32::max);
        assert!(diff < 1e-3, "{diff}");
    }

    #[test]
    fn ld_larger_than_rows() {
        let ctx = small_ctx();
        let (m, n, k, lda) = (30, 20, 25, 40);
        let mut p = Prng::new(13);
        let mut a = vec![0.0; lda * k];
        let mut b = vec![0.0; k * n];
        let mut c = vec![0.0; m * n];
        p.fill_f64(&mut a, -1.0, 1.0);
        p.fill_f64(&mut b, -1.0, 1.0);
        let mut want = c.clone();
        dgemm(&ctx, Trans::No, Trans::No, m, n, k, 1.0, &a, lda, &b, k, 0.0, &mut c, m).unwrap();
        hostblas::gemm_blocked(Trans::No, Trans::No, m, n, k, 1.0, &a, lda, &b, k, 0.0, &mut want, m);
        assert_eq!(c, want);
    }

    #[test]
    fn rejects_bad_ld() {
        let ctx = small_ctx();
        let a = vec![0.0; 100];
        let b = vec![0.0; 100];
        let mut c = vec![0.0; 100];
        let err = dgemm(&ctx, Trans::No, Trans::No, 10, 10, 10, 1.0, &a, 5, &b, 10, 0.0, &mut c, 10);
        assert!(err.is_err());
    }

    #[test]
    fn trsm_roundtrip_with_trmm() {
        // trmm then trsm with the same triangle is the identity.
        let ctx = small_ctx();
        let n = 48;
        let mut p = Prng::new(14);
        let mut a = vec![0.0; n * n];
        p.fill_f64(&mut a, -0.2, 0.2);
        for i in 0..n {
            a[i * n + i] = 2.0;
        }
        let mut b = vec![0.0; n * n];
        p.fill_f64(&mut b, -1.0, 1.0);
        let orig = b.clone();
        trmm(&ctx, Side::Left, Uplo::Upper, Trans::No, Diag::NonUnit, n, n, 2.0, &a, n, &mut b, n)
            .unwrap();
        trsm(&ctx, Side::Left, Uplo::Upper, Trans::No, Diag::NonUnit, n, n, 0.5, &a, n, &mut b, n)
            .unwrap();
        let diff = b.iter().zip(&orig).map(|(x, y)| (x - y).abs()).fold(0.0, f64::max);
        assert!(diff < 1e-10, "{diff}");
    }
}
