//! xerbla-style argument validation for the L3 routines.
//!
//! BLASX's backward-compatibility promise (paper §I) includes faithful
//! BLAS error semantics: invalid dimension/ld parameters are rejected
//! with the 1-based parameter index of the reference BLAS.

use crate::api::types::{Side, Trans};
use crate::error::{illegal, Result};

/// op-dims of A in GEMM: (rows, cols) of op(A).
fn op_dims(trans: Trans, rows: usize, cols: usize) -> (usize, usize) {
    match trans {
        Trans::No => (rows, cols),
        Trans::Yes => (cols, rows),
    }
}

/// Validate GEMM arguments (parameter indices follow reference dgemm).
pub fn check_gemm(
    ta: Trans,
    tb: Trans,
    m: usize,
    n: usize,
    k: usize,
    lda: usize,
    ldb: usize,
    ldc: usize,
) -> Result<()> {
    let _ = (m, n, k); // unsigned: negativity unrepresentable, keep names for clarity
    // A is m×k (No) or k×m (Yes); lda >= its row count
    let (a_rows, _) = op_dims(ta, m, k);
    let a_stored_rows = if ta == Trans::No { a_rows } else { k };
    if lda < a_stored_rows.max(1) {
        return Err(illegal("gemm", 8, format!("lda {lda} < {}", a_stored_rows.max(1))));
    }
    let b_stored_rows = if tb == Trans::No { k } else { n };
    if ldb < b_stored_rows.max(1) {
        return Err(illegal("gemm", 10, format!("ldb {ldb} < {}", b_stored_rows.max(1))));
    }
    if ldc < m.max(1) {
        return Err(illegal("gemm", 13, format!("ldc {ldc} < {}", m.max(1))));
    }
    Ok(())
}

/// Validate SYRK/SYR2K arguments. `ldb_opt` is None for SYRK.
pub fn check_syrk(
    trans: Trans,
    n: usize,
    k: usize,
    lda: usize,
    ldb_opt: Option<usize>,
    ldc: usize,
    routine: &'static str,
) -> Result<()> {
    // A is n×k (No) or k×n (Yes)
    let a_rows = if trans == Trans::No { n } else { k };
    if lda < a_rows.max(1) {
        return Err(illegal(routine, 7, format!("lda {lda} < {}", a_rows.max(1))));
    }
    if let Some(ldb) = ldb_opt {
        if ldb < a_rows.max(1) {
            return Err(illegal(routine, 9, format!("ldb {ldb} < {}", a_rows.max(1))));
        }
    }
    if ldc < n.max(1) {
        return Err(illegal(routine, if ldb_opt.is_some() { 12 } else { 10 }, format!("ldc {ldc} < {}", n.max(1))));
    }
    Ok(())
}

/// Validate SYMM arguments.
pub fn check_symm(
    side: Side,
    m: usize,
    n: usize,
    lda: usize,
    ldb: usize,
    ldc: usize,
) -> Result<()> {
    let ka = match side {
        Side::Left => m,
        Side::Right => n,
    };
    if lda < ka.max(1) {
        return Err(illegal("symm", 7, format!("lda {lda} < {}", ka.max(1))));
    }
    if ldb < m.max(1) {
        return Err(illegal("symm", 9, format!("ldb {ldb} < {}", m.max(1))));
    }
    if ldc < m.max(1) {
        return Err(illegal("symm", 12, format!("ldc {ldc} < {}", m.max(1))));
    }
    Ok(())
}

/// Validate TRMM/TRSM arguments.
pub fn check_trxm(
    side: Side,
    m: usize,
    n: usize,
    lda: usize,
    ldb: usize,
    routine: &'static str,
) -> Result<()> {
    let ka = match side {
        Side::Left => m,
        Side::Right => n,
    };
    if lda < ka.max(1) {
        return Err(illegal(routine, 9, format!("lda {lda} < {}", ka.max(1))));
    }
    if ldb < m.max(1) {
        return Err(illegal(routine, 11, format!("ldb {ldb} < {}", m.max(1))));
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::types::{Trans, Side};

    #[test]
    fn gemm_accepts_valid() {
        assert!(check_gemm(Trans::No, Trans::No, 4, 5, 6, 4, 6, 4).is_ok());
        assert!(check_gemm(Trans::Yes, Trans::No, 4, 5, 6, 6, 6, 4).is_ok());
        assert!(check_gemm(Trans::No, Trans::Yes, 4, 5, 6, 4, 5, 4).is_ok());
    }

    #[test]
    fn gemm_rejects_bad_lds() {
        let e = check_gemm(Trans::No, Trans::No, 4, 5, 6, 3, 6, 4).unwrap_err();
        assert!(e.to_string().contains("#8"));
        let e = check_gemm(Trans::No, Trans::No, 4, 5, 6, 4, 5, 4).unwrap_err();
        assert!(e.to_string().contains("#10"));
        let e = check_gemm(Trans::No, Trans::No, 4, 5, 6, 4, 6, 3).unwrap_err();
        assert!(e.to_string().contains("#13"));
    }

    #[test]
    fn gemm_degenerate_dims_ok() {
        // m = 0 and k = 0 are legal quick-return cases in BLAS
        assert!(check_gemm(Trans::No, Trans::No, 0, 5, 6, 1, 6, 1).is_ok());
        assert!(check_gemm(Trans::No, Trans::No, 4, 5, 0, 4, 1, 4).is_ok());
    }

    #[test]
    fn syrk_checks() {
        assert!(check_syrk(Trans::No, 4, 6, 4, None, 4, "syrk").is_ok());
        assert!(check_syrk(Trans::Yes, 4, 6, 6, None, 4, "syrk").is_ok());
        assert!(check_syrk(Trans::No, 4, 6, 3, None, 4, "syrk").is_err());
        assert!(check_syrk(Trans::No, 4, 6, 4, Some(3), 4, "syr2k").is_err());
        assert!(check_syrk(Trans::No, 4, 6, 4, None, 3, "syrk").is_err());
    }

    #[test]
    fn symm_checks() {
        assert!(check_symm(Side::Left, 4, 5, 4, 4, 4).is_ok());
        assert!(check_symm(Side::Right, 4, 5, 5, 4, 4).is_ok());
        assert!(check_symm(Side::Right, 4, 5, 4, 4, 4).is_err());
        assert!(check_symm(Side::Left, 4, 5, 4, 3, 4).is_err());
    }

    #[test]
    fn trxm_checks() {
        assert!(check_trxm(Side::Left, 4, 5, 4, 4, "trsm").is_ok());
        assert!(check_trxm(Side::Right, 4, 5, 5, 4, "trmm").is_ok());
        assert!(check_trxm(Side::Left, 4, 5, 3, 4, "trsm").is_err());
        assert!(check_trxm(Side::Left, 4, 5, 4, 3, "trmm").is_err());
    }
}
