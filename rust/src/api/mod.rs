//! Public BLAS-compatible API surface.
//!
//! [`types`] defines the CBLAS-style parameter enums and the
//! [`types::Scalar`] trait; `l3` (added with the coordinator) exposes the
//! six routines with legacy signatures; [`scope`] is the closure-scoped
//! non-blocking surface ([`Context::scope`]); `check` implements
//! xerbla-style argument validation. C callers link against the
//! cblas-compatible exports in [`crate::ffi`] instead.

pub mod check;
pub mod l3;
pub mod scope;
pub mod types;

pub use crate::serve::JobHandle;
pub use l3::{
    dgemm, dgemm_batched, dgemm_batched_strided, gemm, gemm_batched, gemm_batched_strided, sgemm,
    sgemm_batched, sgemm_batched_strided, symm, syr2k, syrk, trmm, trsm, Context, GemmBatchEntry,
};
pub use scope::{BufRef, Scope};
pub use types::{Diag, Dtype, Routine, Scalar, Side, Trans, Uplo};
