//! Public BLAS-compatible API surface.
//!
//! [`types`] defines the CBLAS-style parameter enums and the
//! [`types::Scalar`] trait; `l3` (added with the coordinator) exposes the
//! six routines with legacy signatures; `check` implements xerbla-style
//! argument validation.

pub mod check;
pub mod l3;
pub mod types;

pub use crate::serve::JobHandle;
pub use l3::{
    dgemm, dgemm_async, dgemm_batched, dgemm_batched_strided, gemm, gemm_async, gemm_batched,
    gemm_batched_strided, sgemm, sgemm_async, sgemm_batched, sgemm_batched_strided, symm,
    symm_async, syr2k, syr2k_async, syrk, syrk_async, trmm, trmm_async, trsm, trsm_async, Context,
    GemmBatchEntry,
};
pub use types::{Diag, Dtype, Routine, Scalar, Side, Trans, Uplo};
