//! Closure-scoped asynchronous submission — the sound non-blocking API.
//!
//! ## Shape
//!
//! ```no_run
//! use blasx::api::{Context, Trans};
//!
//! let ctx = Context::default();
//! let a = vec![1.0f64; 64 * 64];
//! let b = vec![1.0f64; 64 * 64];
//! let d = vec![1.0f64; 64 * 64];
//! let mut c = vec![0.0f64; 64 * 64];
//! let mut e = vec![0.0f64; 64 * 64];
//! ctx.scope(|s| {
//!     let (ra, rb, rd) = (s.input(&a), s.input(&b), s.input(&d));
//!     let rc = s.buffer(&mut c); // read-write: usable as output AND input
//!     let re = s.buffer(&mut e);
//!     // RAW chain: the second job reads the first's output. Both are
//!     // admitted immediately; the admission table's conflict edge
//!     // orders them, bit-for-bit equal to the blocking sequence.
//!     let _ = s.dgemm(Trans::No, Trans::No, 64, 64, 64, 1.0, ra, 64, rb, 64, 0.0, rc, 64)?;
//!     let _ = s.dgemm(Trans::No, Trans::No, 64, 64, 64, 1.0, rc, 64, rd, 64, 0.0, re, 64)?;
//!     Ok(())
//! }).unwrap();
//! // Scope closed: every job has retired, c and e hold the results.
//! ```
//!
//! ## Why a scope (and not wait-on-drop handles)
//!
//! A non-blocking call hands the runtime raw pointers into the
//! caller's buffers; *something* must guarantee the buffers outlive
//! the job. Hanging that guarantee on a handle's destructor is the
//! pre-1.0 `thread::scoped` bug — `std::mem::forget(handle)` is safe
//! code that skips the destructor. [`Context::scope`] instead runs the
//! completion barrier in its **own stack frame**, after the user
//! closure returns (or unwinds): no safe operation inside the closure
//! can prevent it, so the `'env` borrows registered via
//! [`Scope::input`]/[`Scope::buffer`] are always live until every job
//! has retired. This is the `std::thread::scope` construction applied
//! to device jobs.
//!
//! ## Why tokens (and not `&mut` operands)
//!
//! The point of concurrent submission is *pipelined aliasing chains*:
//! job 2 reading the buffer job 1 writes, in-place solves queued
//! behind the multiply that produced their input. Passing `&mut [T]`
//! per call would let the borrow checker reject exactly those chains
//! (each call would demand exclusive access for the whole scope).
//! Registering a buffer once — [`Scope::buffer`] takes the one `&'env
//! mut` borrow and hands back a *copyable* [`BufRef`] token — lets any
//! number of jobs name the same bytes while the admission table's
//! RAW/WAR/WAW edges serialize the conflicting ones. Data-race
//! freedom comes from the scheduler (conflicting jobs never overlap on
//! the devices), liveness from the scope barrier.

use super::l3::{
    footprint, plan_gemm, plan_symm, plan_syr2k, plan_syrk, plan_trmm, plan_trsm, Context,
    OperandDims,
};
use super::types::{Diag, Scalar, Side, Trans, Uplo};
use crate::coordinator::real_engine::OwnedProblem;
use crate::error::{illegal, Error, Result};
use crate::serve::handle::ScopeToken;
use crate::serve::JobHandle;
use crate::task::TaskSet;
use crate::tile::{HostMat, MatId};
use std::marker::PhantomData;

/// A scope-registered operand buffer: a copyable token naming a host
/// byte range for the jobs of one [`Scope`]. Created by
/// [`Scope::input`] (read-only) or [`Scope::buffer`] (read-write); the
/// same token may appear in any number of jobs, as input and output
/// alike — aliasing across jobs is ordered by the admission table.
pub struct BufRef<'scope, T: Scalar> {
    ptr: *mut T,
    len: usize,
    writable: bool,
    _scope: PhantomData<&'scope T>,
}

// Manual Copy/Clone: derive would bound them on `T: Copy` — true for
// Scalar, but spelling it out keeps the token unconditionally cheap.
impl<T: Scalar> Clone for BufRef<'_, T> {
    fn clone(&self) -> Self {
        *self
    }
}
impl<T: Scalar> Copy for BufRef<'_, T> {}

impl<T: Scalar> std::fmt::Debug for BufRef<'_, T> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("BufRef")
            .field("addr", &self.ptr)
            .field("len", &self.len)
            .field("writable", &self.writable)
            .finish()
    }
}

impl<T: Scalar> BufRef<'_, T> {
    /// Elements the token spans.
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// May this token be used as a job output?
    pub fn writable(&self) -> bool {
        self.writable
    }
}

/// A scope for issuing non-blocking L3 jobs (see the module docs).
/// Obtained from [`Context::scope`]; `'scope` is the scope's own
/// region, `'env` the enclosing environment the operand buffers live
/// in (both invariant, mirroring [`std::thread::scope`]).
pub struct Scope<'scope, 'env: 'scope> {
    ctx: &'env Context,
    token: ScopeToken,
    _scope: PhantomData<&'scope mut &'scope ()>,
    _env: PhantomData<&'env mut &'env ()>,
}

impl Context {
    /// Open a job scope: the closure may issue non-blocking jobs whose
    /// operand ranges alias across jobs (the admission table orders
    /// them); the scope's close — which runs in THIS function's frame,
    /// on the success, error and panic paths alike — waits for every
    /// admitted job, so all outputs are written back when `scope`
    /// returns. A closure error takes precedence; otherwise the close
    /// surfaces the first failure of any job whose handle was detached
    /// or forgotten (jobs observed via [`JobHandle::wait`] already
    /// delivered their result and are not re-reported). Requires the
    /// persistent runtime (the one-shot engine has no resident workers
    /// to leave a job with).
    pub fn scope<'env, F, R>(&'env self, f: F) -> Result<R>
    where
        F: for<'scope> FnOnce(&'scope Scope<'scope, 'env>) -> Result<R>,
    {
        if !self.persistent {
            return Err(Error::Config(
                "scoped async submission requires the persistent runtime \
                 (Context::with_persistent(true))"
                    .into(),
            ));
        }
        let scope = Scope {
            ctx: self,
            token: ScopeToken::new(self.runtime()),
            _scope: PhantomData,
            _env: PhantomData,
        };
        let result = f(&scope);
        // The completion barrier. If `f` unwound instead of returning,
        // `scope.token`'s Drop runs a wait-only close during unwinding
        // — either way no `'env` borrow ends before every job retires.
        // On the normal path the close also surfaces the first failure
        // of any job whose handle was detached/forgotten (a waited
        // handle already delivered its error): a failed kernel must
        // not let `scope` return Ok over a garbage output buffer.
        let barrier = scope.token.close_and_report();
        let value = result?;
        barrier?;
        Ok(value)
    }
}

impl<'scope, 'env> Scope<'scope, 'env> {
    /// Register a read-only operand buffer with the scope.
    pub fn input<T: Scalar>(&'scope self, buf: &'env [T]) -> BufRef<'scope, T> {
        BufRef {
            ptr: buf.as_ptr() as *mut T,
            len: buf.len(),
            writable: false,
            _scope: PhantomData,
        }
    }

    /// Register a read-write operand buffer with the scope. The `&mut`
    /// borrow is taken once, here, for the whole `'env`; the returned
    /// token is freely copyable into any number of jobs (aliasing jobs
    /// are ordered by admission).
    pub fn buffer<T: Scalar>(&'scope self, buf: &'env mut [T]) -> BufRef<'scope, T> {
        BufRef { ptr: buf.as_mut_ptr(), len: buf.len(), writable: true, _scope: PhantomData }
    }

    /// Wrap a token as one operand of a job, validating length and
    /// writability (the geometry itself was validated by the plan).
    #[allow(clippy::too_many_arguments)]
    fn operand<T: Scalar>(
        &self,
        routine: &'static str,
        index: usize,
        buf: BufRef<'scope, T>,
        rows: usize,
        cols: usize,
        ld: usize,
        id: MatId,
        write: bool,
    ) -> Result<HostMat<T>> {
        if write && !buf.writable {
            return Err(illegal(
                routine,
                index,
                "output operand is a read-only token (register it with Scope::buffer, not Scope::input)",
            ));
        }
        let need = footprint(ld, rows, cols);
        if buf.len < need {
            return Err(illegal(
                routine,
                index,
                format!("buffer too small: len {} for ld {ld} × {rows}×{cols}", buf.len),
            ));
        }
        // SAFETY: the token was created from a `'env` borrow; the scope
        // close barrier (Context::scope's own frame) keeps that borrow
        // live until every job of this scope has retired, and jobs with
        // overlapping writes are ordered by the admission table.
        Ok(unsafe { HostMat::from_raw(buf.ptr, rows, cols, ld, self.ctx.tile(), id) })
    }

    /// Admit one planned job and hand back its handle.
    fn submit<T: Scalar>(
        &'scope self,
        routine: &'static str,
        ts: TaskSet,
        a: HostMat<T>,
        b: Option<HostMat<T>>,
        c: HostMat<T>,
    ) -> Result<JobHandle<'scope>> {
        let rt = self.token.runtime().clone();
        let mut cfg = self.ctx.cfg.clone();
        cfg.routine = routine;
        let (job, ctl) = rt.submit_owned(&cfg, ts, vec![OwnedProblem { a, b, c }])?;
        self.token.register(ctl.clone(), job.clone());
        Ok(JobHandle::new(rt, job, ctl))
    }

    /// Non-blocking `C := alpha*op(A)*op(B) + beta*C`; returns
    /// immediately with the job's [`JobHandle`].
    #[allow(clippy::too_many_arguments)]
    pub fn gemm<T: Scalar>(
        &'scope self,
        ta: Trans,
        tb: Trans,
        m: usize,
        n: usize,
        k: usize,
        alpha: T,
        a: BufRef<'scope, T>,
        lda: usize,
        b: BufRef<'scope, T>,
        ldb: usize,
        beta: T,
        c: BufRef<'scope, T>,
        ldc: usize,
    ) -> Result<JobHandle<'scope>> {
        let t = self.ctx.tile();
        let (ts, dims) =
            plan_gemm(t, ta, tb, m, n, k, alpha.to_f64(), beta.to_f64(), lda, ldb, ldc)?;
        let OperandDims { a: (ar, ac), b: bdims, c: _ } = dims;
        let (br, bc) = bdims.expect("gemm has a B operand");
        let am = self.operand("gemm", 7, a, ar, ac, lda, MatId::A, false)?;
        let bm = self.operand("gemm", 9, b, br, bc, ldb, MatId::B, false)?;
        let cm = self.operand("gemm", 12, c, m, n, ldc, MatId::C, true)?;
        self.submit("gemm", ts, am, Some(bm), cm)
    }

    /// Non-blocking SYRK: `C := alpha*op(A)*op(A)^T + beta*C`.
    #[allow(clippy::too_many_arguments)]
    pub fn syrk<T: Scalar>(
        &'scope self,
        uplo: Uplo,
        trans: Trans,
        n: usize,
        k: usize,
        alpha: T,
        a: BufRef<'scope, T>,
        lda: usize,
        beta: T,
        c: BufRef<'scope, T>,
        ldc: usize,
    ) -> Result<JobHandle<'scope>> {
        let t = self.ctx.tile();
        let (ts, dims) =
            plan_syrk(t, uplo, trans, n, k, alpha.to_f64(), beta.to_f64(), lda, ldc)?;
        let (ar, ac) = dims.a;
        let am = self.operand("syrk", 6, a, ar, ac, lda, MatId::A, false)?;
        let cm = self.operand("syrk", 9, c, n, n, ldc, MatId::C, true)?;
        self.submit("syrk", ts, am, None, cm)
    }

    /// Non-blocking SYR2K.
    #[allow(clippy::too_many_arguments)]
    pub fn syr2k<T: Scalar>(
        &'scope self,
        uplo: Uplo,
        trans: Trans,
        n: usize,
        k: usize,
        alpha: T,
        a: BufRef<'scope, T>,
        lda: usize,
        b: BufRef<'scope, T>,
        ldb: usize,
        beta: T,
        c: BufRef<'scope, T>,
        ldc: usize,
    ) -> Result<JobHandle<'scope>> {
        let t = self.ctx.tile();
        let (ts, dims) =
            plan_syr2k(t, uplo, trans, n, k, alpha.to_f64(), beta.to_f64(), lda, ldb, ldc)?;
        let (ar, ac) = dims.a;
        let am = self.operand("syr2k", 6, a, ar, ac, lda, MatId::A, false)?;
        let bm = self.operand("syr2k", 8, b, ar, ac, ldb, MatId::B, false)?;
        let cm = self.operand("syr2k", 11, c, n, n, ldc, MatId::C, true)?;
        self.submit("syr2k", ts, am, Some(bm), cm)
    }

    /// Non-blocking SYMM.
    #[allow(clippy::too_many_arguments)]
    pub fn symm<T: Scalar>(
        &'scope self,
        side: Side,
        uplo: Uplo,
        m: usize,
        n: usize,
        alpha: T,
        a: BufRef<'scope, T>,
        lda: usize,
        b: BufRef<'scope, T>,
        ldb: usize,
        beta: T,
        c: BufRef<'scope, T>,
        ldc: usize,
    ) -> Result<JobHandle<'scope>> {
        let t = self.ctx.tile();
        let (ts, dims) =
            plan_symm(t, side, uplo, m, n, alpha.to_f64(), beta.to_f64(), lda, ldb, ldc)?;
        let (na, _) = dims.a;
        let am = self.operand("symm", 6, a, na, na, lda, MatId::A, false)?;
        let bm = self.operand("symm", 8, b, m, n, ldb, MatId::B, false)?;
        let cm = self.operand("symm", 11, c, m, n, ldc, MatId::C, true)?;
        self.submit("symm", ts, am, Some(bm), cm)
    }

    /// Non-blocking TRMM, in place in `b` (the token must be
    /// writable).
    #[allow(clippy::too_many_arguments)]
    pub fn trmm<T: Scalar>(
        &'scope self,
        side: Side,
        uplo: Uplo,
        ta: Trans,
        diag: Diag,
        m: usize,
        n: usize,
        alpha: T,
        a: BufRef<'scope, T>,
        lda: usize,
        b: BufRef<'scope, T>,
        ldb: usize,
    ) -> Result<JobHandle<'scope>> {
        let t = self.ctx.tile();
        let (ts, dims) = plan_trmm(t, side, uplo, ta, diag, m, n, alpha.to_f64(), lda, ldb)?;
        let (na, _) = dims.a;
        let am = self.operand("trmm", 8, a, na, na, lda, MatId::A, false)?;
        let cm = self.operand("trmm", 10, b, m, n, ldb, MatId::C, true)?;
        self.submit("trmm", ts, am, None, cm)
    }

    /// Non-blocking TRSM: X overwrites `b` (the token must be
    /// writable).
    #[allow(clippy::too_many_arguments)]
    pub fn trsm<T: Scalar>(
        &'scope self,
        side: Side,
        uplo: Uplo,
        ta: Trans,
        diag: Diag,
        m: usize,
        n: usize,
        alpha: T,
        a: BufRef<'scope, T>,
        lda: usize,
        b: BufRef<'scope, T>,
        ldb: usize,
    ) -> Result<JobHandle<'scope>> {
        let t = self.ctx.tile();
        let (ts, dims) = plan_trsm(t, side, uplo, ta, diag, m, n, alpha.to_f64(), lda, ldb)?;
        let (na, _) = dims.a;
        let am = self.operand("trsm", 8, a, na, na, lda, MatId::A, false)?;
        let cm = self.operand("trsm", 10, b, m, n, ldb, MatId::C, true)?;
        self.submit("trsm", ts, am, None, cm)
    }

    // -- precision-suffixed conveniences (the CBLAS-flavoured names) --

    /// Double-precision non-blocking GEMM.
    #[allow(clippy::too_many_arguments)]
    pub fn dgemm(
        &'scope self,
        ta: Trans,
        tb: Trans,
        m: usize,
        n: usize,
        k: usize,
        alpha: f64,
        a: BufRef<'scope, f64>,
        lda: usize,
        b: BufRef<'scope, f64>,
        ldb: usize,
        beta: f64,
        c: BufRef<'scope, f64>,
        ldc: usize,
    ) -> Result<JobHandle<'scope>> {
        self.gemm(ta, tb, m, n, k, alpha, a, lda, b, ldb, beta, c, ldc)
    }

    /// Single-precision non-blocking GEMM.
    #[allow(clippy::too_many_arguments)]
    pub fn sgemm(
        &'scope self,
        ta: Trans,
        tb: Trans,
        m: usize,
        n: usize,
        k: usize,
        alpha: f32,
        a: BufRef<'scope, f32>,
        lda: usize,
        b: BufRef<'scope, f32>,
        ldb: usize,
        beta: f32,
        c: BufRef<'scope, f32>,
        ldc: usize,
    ) -> Result<JobHandle<'scope>> {
        self.gemm(ta, tb, m, n, k, alpha, a, lda, b, ldb, beta, c, ldc)
    }

    /// Double-precision non-blocking SYRK.
    #[allow(clippy::too_many_arguments)]
    pub fn dsyrk(
        &'scope self,
        uplo: Uplo,
        trans: Trans,
        n: usize,
        k: usize,
        alpha: f64,
        a: BufRef<'scope, f64>,
        lda: usize,
        beta: f64,
        c: BufRef<'scope, f64>,
        ldc: usize,
    ) -> Result<JobHandle<'scope>> {
        self.syrk(uplo, trans, n, k, alpha, a, lda, beta, c, ldc)
    }

    /// Double-precision non-blocking SYR2K.
    #[allow(clippy::too_many_arguments)]
    pub fn dsyr2k(
        &'scope self,
        uplo: Uplo,
        trans: Trans,
        n: usize,
        k: usize,
        alpha: f64,
        a: BufRef<'scope, f64>,
        lda: usize,
        b: BufRef<'scope, f64>,
        ldb: usize,
        beta: f64,
        c: BufRef<'scope, f64>,
        ldc: usize,
    ) -> Result<JobHandle<'scope>> {
        self.syr2k(uplo, trans, n, k, alpha, a, lda, b, ldb, beta, c, ldc)
    }

    /// Double-precision non-blocking SYMM.
    #[allow(clippy::too_many_arguments)]
    pub fn dsymm(
        &'scope self,
        side: Side,
        uplo: Uplo,
        m: usize,
        n: usize,
        alpha: f64,
        a: BufRef<'scope, f64>,
        lda: usize,
        b: BufRef<'scope, f64>,
        ldb: usize,
        beta: f64,
        c: BufRef<'scope, f64>,
        ldc: usize,
    ) -> Result<JobHandle<'scope>> {
        self.symm(side, uplo, m, n, alpha, a, lda, b, ldb, beta, c, ldc)
    }

    /// Double-precision non-blocking TRMM.
    #[allow(clippy::too_many_arguments)]
    pub fn dtrmm(
        &'scope self,
        side: Side,
        uplo: Uplo,
        ta: Trans,
        diag: Diag,
        m: usize,
        n: usize,
        alpha: f64,
        a: BufRef<'scope, f64>,
        lda: usize,
        b: BufRef<'scope, f64>,
        ldb: usize,
    ) -> Result<JobHandle<'scope>> {
        self.trmm(side, uplo, ta, diag, m, n, alpha, a, lda, b, ldb)
    }

    /// Double-precision non-blocking TRSM.
    #[allow(clippy::too_many_arguments)]
    pub fn dtrsm(
        &'scope self,
        side: Side,
        uplo: Uplo,
        ta: Trans,
        diag: Diag,
        m: usize,
        n: usize,
        alpha: f64,
        a: BufRef<'scope, f64>,
        lda: usize,
        b: BufRef<'scope, f64>,
        ldb: usize,
    ) -> Result<JobHandle<'scope>> {
        self.trsm(side, uplo, ta, diag, m, n, alpha, a, lda, b, ldb)
    }
}

impl std::fmt::Debug for Scope<'_, '_> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Scope").finish_non_exhaustive()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ctx() -> Context {
        Context::new(2).with_arena(4 << 20).with_tile(32)
    }

    #[test]
    fn tokens_track_writability_and_len() {
        let c = ctx();
        let a = vec![0.0f64; 16];
        let mut b = vec![0.0f64; 8];
        c.scope(|s| {
            let ra = s.input(&a);
            let rb = s.buffer(&mut b);
            assert_eq!(ra.len(), 16);
            assert!(!ra.writable());
            assert!(rb.writable());
            assert!(!rb.is_empty());
            // tokens are Copy: both uses below are fine
            let _ = (ra, ra, rb, rb);
            Ok(())
        })
        .unwrap();
    }

    #[test]
    fn read_only_token_rejected_as_output() {
        let c = ctx();
        let a = vec![0.0f64; 32 * 32];
        let b = vec![0.0f64; 32 * 32];
        let co = vec![0.0f64; 32 * 32];
        let err = c.scope(|s| {
            let (ra, rb, rc) = (s.input(&a), s.input(&b), s.input(&co));
            s.dgemm(Trans::No, Trans::No, 32, 32, 32, 1.0, ra, 32, rb, 32, 0.0, rc, 32)
                .map(|h| h.detach())
        });
        assert!(err.is_err(), "read-only output token must be rejected");
    }

    #[test]
    fn short_token_rejected() {
        let c = ctx();
        let a = vec![0.0f64; 10]; // far below the 32×32 footprint
        let b = vec![0.0f64; 32 * 32];
        let mut co = vec![0.0f64; 32 * 32];
        let err = c.scope(|s| {
            let (ra, rb) = (s.input(&a), s.input(&b));
            let rc = s.buffer(&mut co);
            s.dgemm(Trans::No, Trans::No, 32, 32, 32, 1.0, ra, 32, rb, 32, 0.0, rc, 32)
                .map(|h| h.detach())
        });
        assert!(err.is_err(), "undersized operand token must be rejected");
    }

    #[test]
    fn scope_flattens_closure_errors() {
        let c = ctx();
        let out: Result<u32> = c.scope(|_s| Err(Error::Config("user error".into())));
        assert!(out.is_err());
        // and passes values through on success
        assert_eq!(c.scope(|_s| Ok(7u32)).unwrap(), 7);
    }
}
