//! BLAS parameter enums and the scalar trait.
//!
//! These mirror the CBLAS conventions so that porting legacy BLAS callers
//! to BLASX (the paper's backward-compatibility goal, §I/§V-C) is a
//! drop-in rename.

/// Transpose flag. BLASX implements the real-valued routines, so
/// conjugate-transpose is equivalent to transpose.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Trans {
    No,
    Yes,
}

impl Trans {
    /// Parse a BLAS character flag ('N'/'T'/'C').
    pub fn from_char(c: char) -> Option<Trans> {
        match c.to_ascii_uppercase() {
            'N' => Some(Trans::No),
            'T' | 'C' => Some(Trans::Yes),
            _ => None,
        }
    }

    pub fn flipped(self) -> Trans {
        match self {
            Trans::No => Trans::Yes,
            Trans::Yes => Trans::No,
        }
    }

    pub fn is_trans(self) -> bool {
        self == Trans::Yes
    }
}

/// Which triangle of a symmetric/triangular matrix is referenced.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Uplo {
    Upper,
    Lower,
}

impl Uplo {
    pub fn from_char(c: char) -> Option<Uplo> {
        match c.to_ascii_uppercase() {
            'U' => Some(Uplo::Upper),
            'L' => Some(Uplo::Lower),
            _ => None,
        }
    }

    pub fn flipped(self) -> Uplo {
        match self {
            Uplo::Upper => Uplo::Lower,
            Uplo::Lower => Uplo::Upper,
        }
    }
}

/// Whether the triangular/symmetric operand multiplies from the left or
/// the right.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Side {
    Left,
    Right,
}

impl Side {
    pub fn from_char(c: char) -> Option<Side> {
        match c.to_ascii_uppercase() {
            'L' => Some(Side::Left),
            'R' => Some(Side::Right),
            _ => None,
        }
    }
}

/// Unit-diagonal flag for triangular routines.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Diag {
    NonUnit,
    Unit,
}

impl Diag {
    pub fn from_char(c: char) -> Option<Diag> {
        match c.to_ascii_uppercase() {
            'N' => Some(Diag::NonUnit),
            'U' => Some(Diag::Unit),
            _ => None,
        }
    }
}

/// The six level-3 routines BLASX implements (paper §III, Eq. 1a–1f).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Routine {
    Gemm,
    Syrk,
    Syr2k,
    Trmm,
    Trsm,
    Symm,
}

impl Routine {
    pub const ALL: [Routine; 6] =
        [Routine::Gemm, Routine::Syrk, Routine::Syr2k, Routine::Trmm, Routine::Trsm, Routine::Symm];

    pub fn name(self) -> &'static str {
        match self {
            Routine::Gemm => "gemm",
            Routine::Syrk => "syrk",
            Routine::Syr2k => "syr2k",
            Routine::Trmm => "trmm",
            Routine::Trsm => "trsm",
            Routine::Symm => "symm",
        }
    }

    /// Double-precision BLAS name, e.g. "DGEMM" (used in reports).
    pub fn dname(self) -> String {
        format!("D{}", self.name().to_uppercase())
    }

    /// Total floating-point operations for the square case of size N
    /// (standard BLAS flop counts).
    pub fn flops_square(self, n: f64) -> f64 {
        match self {
            Routine::Gemm => 2.0 * n * n * n,
            Routine::Syrk => n * n * (n + 1.0),
            Routine::Syr2k => 2.0 * n * n * (n + 1.0),
            Routine::Trmm => n * n * n,
            Routine::Trsm => n * n * n,
            Routine::Symm => 2.0 * n * n * n,
        }
    }
}

/// Element type tag for artifacts and kernels.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Dtype {
    F32,
    F64,
}

impl Dtype {
    pub fn name(self) -> &'static str {
        match self {
            Dtype::F32 => "f32",
            Dtype::F64 => "f64",
        }
    }

    pub fn size_bytes(self) -> usize {
        match self {
            Dtype::F32 => 4,
            Dtype::F64 => 8,
        }
    }
}

/// Scalar element trait: the two real BLAS precisions.
///
/// The arithmetic surface is spelled out as std `ops` bounds plus the
/// two identities the kernels need (`num_traits` is unreachable in the
/// offline build, and f32/f64 are the only implementors anyway).
pub trait Scalar:
    Copy
    + Send
    + Sync
    + PartialEq
    + PartialOrd
    + std::fmt::Debug
    + std::fmt::Display
    + std::ops::Add<Output = Self>
    + std::ops::Sub<Output = Self>
    + std::ops::Mul<Output = Self>
    + std::ops::Div<Output = Self>
    + std::ops::Neg<Output = Self>
    + std::ops::AddAssign
    + std::ops::SubAssign
    + std::ops::MulAssign
    + std::ops::DivAssign
    + 'static
{
    const DTYPE: Dtype;
    fn from_f64(x: f64) -> Self;
    fn to_f64(self) -> f64;
    /// Additive identity.
    fn zero() -> Self;
    /// Multiplicative identity.
    fn one() -> Self;
}

impl Scalar for f32 {
    const DTYPE: Dtype = Dtype::F32;
    fn from_f64(x: f64) -> f32 {
        x as f32
    }
    fn to_f64(self) -> f64 {
        self as f64
    }
    fn zero() -> f32 {
        0.0
    }
    fn one() -> f32 {
        1.0
    }
}

impl Scalar for f64 {
    const DTYPE: Dtype = Dtype::F64;
    fn from_f64(x: f64) -> f64 {
        x
    }
    fn to_f64(self) -> f64 {
        self
    }
    fn zero() -> f64 {
        0.0
    }
    fn one() -> f64 {
        1.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn char_parsing() {
        assert_eq!(Trans::from_char('n'), Some(Trans::No));
        assert_eq!(Trans::from_char('T'), Some(Trans::Yes));
        assert_eq!(Trans::from_char('C'), Some(Trans::Yes));
        assert_eq!(Trans::from_char('x'), None);
        assert_eq!(Uplo::from_char('u'), Some(Uplo::Upper));
        assert_eq!(Side::from_char('R'), Some(Side::Right));
        assert_eq!(Diag::from_char('U'), Some(Diag::Unit));
    }

    #[test]
    fn flips() {
        assert_eq!(Trans::No.flipped(), Trans::Yes);
        assert_eq!(Uplo::Upper.flipped(), Uplo::Lower);
    }

    #[test]
    fn flop_counts() {
        let n = 100.0;
        assert_eq!(Routine::Gemm.flops_square(n), 2e6);
        assert_eq!(Routine::Trsm.flops_square(n), 1e6);
        // SYRK is half of GEMM plus lower-order terms.
        assert!(Routine::Syrk.flops_square(n) < Routine::Gemm.flops_square(n));
    }

    #[test]
    fn names() {
        assert_eq!(Routine::Gemm.dname(), "DGEMM");
        assert_eq!(Dtype::F64.size_bytes(), 8);
        assert_eq!(Dtype::F32.name(), "f32");
    }

    #[test]
    fn scalar_roundtrip() {
        assert_eq!(f32::from_f64(1.5).to_f64(), 1.5);
        assert_eq!(<f64 as Scalar>::DTYPE, Dtype::F64);
        assert_eq!(<f32 as Scalar>::DTYPE, Dtype::F32);
    }
}
