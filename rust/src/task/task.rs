//! Task and step definitions (paper §IV-A).
//!
//! A *task* solves one output tile `C_ij` of Eq. 1. It carries only
//! metadata (tile indices, step list, scalars) — "taskizing a L3 BLAS
//! does not require significant additional memory" (§IV-A). A *step* is
//! one k-iteration: a tile-kernel invocation with up to two input tiles.

use super::op::TileOp;
use crate::tile::MatId;

/// Reference to an input tile by operand matrix and tile indices. The
/// concrete host address (cache key) is resolved against the routine's
/// `HostMat`s at execution time.
///
/// `p` is the *problem index*: single-routine calls use 0 throughout;
/// the batch subsystem (`crate::batch`) namespaces the fused task set
/// by assigning each problem its own `p`, so the same `(mat, ti, tj)`
/// coordinates in different problems resolve to different operands
/// while the cache/coherence layers see ordinary per-key tiles.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct TileRef {
    pub mat: MatId,
    pub ti: usize,
    pub tj: usize,
    /// Problem index within a fused batch (0 for single-problem runs).
    pub p: usize,
}

impl TileRef {
    pub fn new(mat: MatId, ti: usize, tj: usize) -> TileRef {
        TileRef { mat, ti, tj, p: 0 }
    }

    /// A tile reference inside problem `p` of a fused batch.
    pub fn for_problem(p: usize, mat: MatId, ti: usize, tj: usize) -> TileRef {
        TileRef { mat, ti, tj, p }
    }
}

/// One k-step of a task: `acc := alpha * op_kernel(a [, b]) + beta * acc`
/// (exact semantics per [`TileOp`]).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Step {
    pub op: TileOp,
    /// Primary input tile (A-side of the kernel). `None` only for Scal.
    pub a: Option<TileRef>,
    /// Secondary input tile (B-side), when the kernel takes two.
    pub b: Option<TileRef>,
    /// Step scaling of the kernel product.
    pub alpha: f64,
    /// Step scaling of the accumulator (folds the routine's beta into
    /// the first step; 1.0 afterwards).
    pub beta: f64,
    /// Step dims (m, n, k): accumulator tile is m×n; k is the reduction
    /// extent (0 where not applicable).
    pub dims: (usize, usize, usize),
}

impl Step {
    /// Flops of this step.
    pub fn flops(&self) -> f64 {
        let (m, n, k) = self.dims;
        self.op.flops(m, n, k)
    }

    /// Input tiles of this step (for cache priority, Eq. 3).
    pub fn inputs(&self) -> impl Iterator<Item = TileRef> + '_ {
        self.a.into_iter().chain(self.b)
    }
}

/// Which part of the accumulator tile is written back to the host
/// (diagonal tiles of SYRK/SYR2K store only one triangle).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum WriteMask {
    Full,
    UpperTri,
    LowerTri,
}

/// A schedulable task: all work needed to produce output tile
/// `(ci, cj)`. Paper §IV-A properties: reads are dependency-free within
/// a task; distinct tasks write distinct tiles; workload varies per task.
#[derive(Clone, Debug)]
pub struct Task {
    /// Dense id within the owning `TaskSet`.
    pub id: usize,
    /// Output tile indices into the C (output) grid.
    pub ci: usize,
    pub cj: usize,
    /// Problem index within a fused batch (0 for single-problem runs);
    /// resolves which operand set the task's tiles belong to.
    pub p: usize,
    /// Output tile element dims.
    pub m: usize,
    pub n: usize,
    /// Whether the first step's `beta` consumes the original C tile
    /// value — if false the accumulator may start uninitialised.
    pub reads_c: bool,
    /// Write-back mask (triangle-stored diagonal tiles).
    pub mask: WriteMask,
    /// Ordered k-steps.
    pub steps: Vec<Step>,
    /// Next task in this task's dependency chain (TRMM/TRSM row/column
    /// ordering); `None` for independent tasks and chain tails.
    pub successor: Option<usize>,
    /// Number of unfinished predecessors (0 = initially ready; chains
    /// give at most 1).
    pub n_deps: usize,
    /// Total flops (cached sum over steps).
    pub flops: f64,
}

impl Task {
    /// Recompute `flops` from the step list (taskizers call this once).
    pub fn seal(mut self) -> Task {
        self.flops = self.steps.iter().map(Step::flops).sum();
        self
    }

    /// All distinct input tiles (for priority Eq. 3 and prefetch).
    pub fn input_tiles(&self) -> Vec<TileRef> {
        let mut v: Vec<TileRef> = self.steps.iter().flat_map(|s| s.inputs()).collect();
        v.sort_by_key(|r| (r.p, r.mat, r.ti, r.tj));
        v.dedup();
        v
    }

    /// Reference to this task's output tile (problem-namespaced).
    pub fn c_ref(&self) -> TileRef {
        TileRef { mat: MatId::C, ti: self.ci, tj: self.cj, p: self.p }
    }

    /// Flops attributable to full-GEMM steps (Table I numerator).
    pub fn gemm_flops(&self) -> f64 {
        self.steps.iter().filter(|s| s.op.is_gemm()).map(Step::flops).sum()
    }
}

/// The output of a taskizer: tasks plus the initial ready set.
#[derive(Clone, Debug)]
pub struct TaskSet {
    pub tasks: Vec<Task>,
    /// Ids of tasks with no predecessors (enqueued at start).
    pub heads: Vec<usize>,
}

impl TaskSet {
    /// Degree of parallelism = number of tasks (paper Eq. 2 for the
    /// dependency-free routines; chains reduce *instantaneous* but not
    /// total parallelism).
    pub fn degree_of_parallelism(&self) -> usize {
        self.tasks.len()
    }

    /// Total flops across tasks.
    pub fn total_flops(&self) -> f64 {
        self.tasks.iter().map(|t| t.flops).sum()
    }

    /// Fraction of flops executed by the full-GEMM kernel — the paper's
    /// Table I metric.
    pub fn gemm_fraction(&self) -> f64 {
        let total = self.total_flops();
        if total == 0.0 {
            return 0.0;
        }
        self.tasks.iter().map(|t| t.gemm_flops()).sum::<f64>() / total
    }

    /// Internal consistency check used by tests and debug builds:
    /// distinct output tiles, chain links in range and acyclic, head set
    /// consistent with `n_deps`.
    pub fn validate(&self) -> Result<(), String> {
        let n = self.tasks.len();
        let mut outs = std::collections::HashSet::new();
        for (idx, t) in self.tasks.iter().enumerate() {
            if t.id != idx {
                return Err(format!("task {idx} has id {}", t.id));
            }
            if !outs.insert((t.p, t.ci, t.cj)) {
                return Err(format!(
                    "duplicate output tile ({}, {}) in problem {}",
                    t.ci, t.cj, t.p
                ));
            }
            if let Some(s) = t.successor {
                if s >= n {
                    return Err(format!("task {idx} successor {s} out of range"));
                }
                if self.tasks[s].n_deps == 0 {
                    return Err(format!("task {s} is a successor but has n_deps 0"));
                }
            }
            if t.steps.is_empty() {
                return Err(format!("task {idx} has no steps"));
            }
        }
        // heads = exactly the tasks with n_deps == 0
        let expect: Vec<usize> =
            self.tasks.iter().filter(|t| t.n_deps == 0).map(|t| t.id).collect();
        let mut heads = self.heads.clone();
        heads.sort_unstable();
        let mut e = expect.clone();
        e.sort_unstable();
        if heads != e {
            return Err("heads inconsistent with n_deps".to_string());
        }
        // chains acyclic: follow successors, visits bounded by n
        for t in &self.tasks {
            let mut cur = t.successor;
            let mut hops = 0;
            while let Some(s) = cur {
                hops += 1;
                if hops > n {
                    return Err("successor cycle".to_string());
                }
                cur = self.tasks[s].successor;
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::types::Trans;

    fn gemm_step(i: usize, k: usize, j: usize, dims: (usize, usize, usize)) -> Step {
        Step {
            op: TileOp::Gemm { ta: Trans::No, tb: Trans::No },
            a: Some(TileRef::new(MatId::A, i, k)),
            b: Some(TileRef::new(MatId::B, k, j)),
            alpha: 1.0,
            beta: if k == 0 { 0.5 } else { 1.0 },
            dims,
        }
    }

    #[test]
    fn task_flops_and_inputs() {
        let t = Task {
            id: 0,
            ci: 0,
            cj: 0,
            p: 0,
            m: 4,
            n: 4,
            reads_c: true,
            mask: WriteMask::Full,
            steps: vec![gemm_step(0, 0, 0, (4, 4, 4)), gemm_step(0, 1, 0, (4, 4, 4))],
            successor: None,
            n_deps: 0,
            flops: 0.0,
        }
        .seal();
        assert_eq!(t.flops, 2.0 * (2 * 4 * 4 * 4) as f64);
        assert_eq!(t.input_tiles().len(), 4);
        assert_eq!(t.gemm_flops(), t.flops);
    }

    #[test]
    fn dedups_repeated_inputs() {
        let mut t = Task {
            id: 0,
            ci: 0,
            cj: 0,
            p: 0,
            m: 2,
            n: 2,
            reads_c: false,
            mask: WriteMask::Full,
            steps: vec![gemm_step(0, 0, 0, (2, 2, 2)), gemm_step(0, 0, 0, (2, 2, 2))],
            successor: None,
            n_deps: 0,
            flops: 0.0,
        };
        t = t.seal();
        assert_eq!(t.input_tiles().len(), 2);
    }

    #[test]
    fn validate_catches_duplicate_outputs() {
        let mk = |id| Task {
            id,
            ci: 0,
            cj: 0,
            p: 0,
            m: 1,
            n: 1,
            reads_c: true,
            mask: WriteMask::Full,
            steps: vec![gemm_step(0, 0, 0, (1, 1, 1))],
            successor: None,
            n_deps: 0,
            flops: 0.0,
        };
        let ts = TaskSet { tasks: vec![mk(0), mk(1)], heads: vec![0, 1] };
        assert!(ts.validate().unwrap_err().contains("duplicate"));
    }

    #[test]
    fn validate_checks_heads() {
        let mut t0 = Task {
            id: 0,
            ci: 0,
            cj: 0,
            p: 0,
            m: 1,
            n: 1,
            reads_c: true,
            mask: WriteMask::Full,
            steps: vec![gemm_step(0, 0, 0, (1, 1, 1))],
            successor: Some(1),
            n_deps: 0,
            flops: 0.0,
        };
        t0 = t0.clone().seal();
        let t1 = Task { id: 1, ci: 1, cj: 0, n_deps: 1, successor: None, ..t0.clone() }.seal();
        let good = TaskSet { tasks: vec![t0.clone(), t1.clone()], heads: vec![0] };
        assert!(good.validate().is_ok());
        let bad = TaskSet { tasks: vec![t0, t1], heads: vec![0, 1] };
        assert!(bad.validate().is_err());
    }
}
