//! Taskization of L3 BLAS (paper §III, §IV-A).
//!
//! - [`op::TileOp`] — tile-kernel vocabulary (GEMM + diagonal specials).
//! - [`task::Task`] / [`task::Step`] — a task solves one output tile
//!   `C_ij` as an ordered list of k-steps.
//! - [`taskize`] — the six routine decompositions of Eq. 1a–1f, including
//!   the per-column/row dependency chains of TRMM/TRSM.

pub mod op;
pub mod task;
pub mod taskize;

pub use op::TileOp;
pub use task::{Step, Task, TaskSet, TileRef, WriteMask};
pub use taskize::{
    taskize_gemm, taskize_symm, taskize_syr2k, taskize_syrk, taskize_trmm, taskize_trsm,
    GemmDesc, SymmDesc, SyrkDesc, TriDesc,
};
