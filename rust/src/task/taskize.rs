//! Taskizers: decompose each L3 BLAS routine into tile tasks
//! (paper §III-B Eq. 1a–1f and §IV-A).
//!
//! Conventions
//! - All matrices are column-major with square tile size `t` (edge tiles
//!   truncated, see [`crate::tile::TileGrid`]).
//! - The *output* operand is always registered as `MatId::C` — for
//!   TRMM/TRSM that is the in/out matrix B of the BLAS signature, whose
//!   tiles appear both as the task accumulator and as *inputs* of other
//!   tasks (which is what creates the per-column/row dependency chains).
//! - GEMM/SYRK/SYR2K/SYMM tasks are fully independent (§IV-A); TRMM and
//!   TRSM tasks form one chain per output column (Left) or row (Right),
//!   ordered so every read of a neighbouring C tile happens at the
//!   correct version. Chains are expressed via `Task::successor`.

use super::op::TileOp;
use super::task::{Step, Task, TaskSet, TileRef, WriteMask};
use crate::api::types::{Diag, Side, Trans, Uplo};
use crate::tile::{MatId, TileGrid};

/// GEMM problem description (dims are element counts).
#[derive(Clone, Copy, Debug)]
pub struct GemmDesc {
    pub ta: Trans,
    pub tb: Trans,
    pub m: usize,
    pub n: usize,
    pub k: usize,
    pub alpha: f64,
    pub beta: f64,
    pub t: usize,
}

/// SYRK / SYR2K description: `C` is n×n, reduction extent `k`.
#[derive(Clone, Copy, Debug)]
pub struct SyrkDesc {
    pub uplo: Uplo,
    pub trans: Trans,
    pub n: usize,
    pub k: usize,
    pub alpha: f64,
    pub beta: f64,
    pub t: usize,
}

/// SYMM description: `C` is m×n; `A` is m×m (Left) or n×n (Right).
#[derive(Clone, Copy, Debug)]
pub struct SymmDesc {
    pub side: Side,
    pub uplo: Uplo,
    pub m: usize,
    pub n: usize,
    pub alpha: f64,
    pub beta: f64,
    pub t: usize,
}

/// TRMM / TRSM description: `B` (in/out) is m×n; `A` triangular m×m
/// (Left) or n×n (Right).
#[derive(Clone, Copy, Debug)]
pub struct TriDesc {
    pub side: Side,
    pub uplo: Uplo,
    pub ta: Trans,
    pub diag: Diag,
    pub m: usize,
    pub n: usize,
    pub alpha: f64,
    pub t: usize,
}

// ---------------------------------------------------------------------
// helpers

fn c_grid(m: usize, n: usize, t: usize) -> TileGrid {
    TileGrid::new(m, n, t)
}

/// Reduction-extent of tile index `kk` along a dimension of `len`.
fn kdim(len: usize, t: usize, kk: usize) -> usize {
    (len - kk * t).min(t)
}

fn num_ktiles(len: usize, t: usize) -> usize {
    if len == 0 { 0 } else { len.div_ceil(t) }
}

/// Build a task with `steps`, defaulting chain fields; caller links
/// chains afterwards.
#[allow(clippy::too_many_arguments)]
fn mk_task(
    id: usize,
    ci: usize,
    cj: usize,
    m: usize,
    n: usize,
    reads_c: bool,
    mask: WriteMask,
    steps: Vec<Step>,
) -> Task {
    Task { id, ci, cj, p: 0, m, n, reads_c, mask, steps, successor: None, n_deps: 0, flops: 0.0 }
        .seal()
}

/// A `C := beta*C` fallback task (alpha == 0 or empty reduction).
fn scal_task(id: usize, ci: usize, cj: usize, m: usize, n: usize, beta: f64) -> Task {
    mk_task(
        id,
        ci,
        cj,
        m,
        n,
        true,
        WriteMask::Full,
        vec![Step { op: TileOp::Scal, a: None, b: None, alpha: 0.0, beta, dims: (m, n, 0) }],
    )
}

// ---------------------------------------------------------------------
// GEMM (Eq. 1a)

/// `C := alpha * op(A) * op(B) + beta * C`.
pub fn taskize_gemm(d: &GemmDesc) -> TaskSet {
    let grid = c_grid(d.m, d.n, d.t);
    let z = num_ktiles(d.k, d.t);
    let mut tasks = Vec::with_capacity(grid.num_tiles());
    for (ci, cj) in grid.iter() {
        let (h, w) = grid.tile_dims(ci, cj);
        let id = tasks.len();
        if d.alpha == 0.0 || z == 0 {
            tasks.push(scal_task(id, ci, cj, h, w, d.beta));
            continue;
        }
        let mut steps = Vec::with_capacity(z);
        for kk in 0..z {
            let kd = kdim(d.k, d.t, kk);
            let a = match d.ta {
                Trans::No => TileRef::new(MatId::A, ci, kk),
                Trans::Yes => TileRef::new(MatId::A, kk, ci),
            };
            let b = match d.tb {
                Trans::No => TileRef::new(MatId::B, kk, cj),
                Trans::Yes => TileRef::new(MatId::B, cj, kk),
            };
            steps.push(Step {
                op: TileOp::Gemm { ta: d.ta, tb: d.tb },
                a: Some(a),
                b: Some(b),
                alpha: d.alpha,
                beta: if kk == 0 { d.beta } else { 1.0 },
                dims: (h, w, kd),
            });
        }
        tasks.push(mk_task(id, ci, cj, h, w, d.beta != 0.0, WriteMask::Full, steps));
    }
    let heads = (0..tasks.len()).collect();
    TaskSet { tasks, heads }
}

// ---------------------------------------------------------------------
// SYRK (Eq. 1b)

/// `C := alpha * op(A) op(A)^T + beta * C`, C symmetric n×n, only the
/// `uplo` triangle of C is referenced/updated.
pub fn taskize_syrk(d: &SyrkDesc) -> TaskSet {
    let grid = c_grid(d.n, d.n, d.t);
    let z = num_ktiles(d.k, d.t);
    let mut tasks = Vec::new();
    for (ci, cj) in grid.iter() {
        // only the stored triangle has tasks
        let in_tri = match d.uplo {
            Uplo::Upper => ci <= cj,
            Uplo::Lower => ci >= cj,
        };
        if !in_tri {
            continue;
        }
        let (h, w) = grid.tile_dims(ci, cj);
        let id = tasks.len();
        let mask = if ci == cj {
            match d.uplo {
                Uplo::Upper => WriteMask::UpperTri,
                Uplo::Lower => WriteMask::LowerTri,
            }
        } else {
            WriteMask::Full
        };
        if d.alpha == 0.0 || z == 0 {
            let mut t = scal_task(id, ci, cj, h, w, d.beta);
            t.mask = mask;
            tasks.push(t);
            continue;
        }
        let mut steps = Vec::with_capacity(z);
        for kk in 0..z {
            let kd = kdim(d.k, d.t, kk);
            let beta = if kk == 0 { d.beta } else { 1.0 };
            if ci == cj {
                // diagonal tile: true rank-k update
                let a = match d.trans {
                    Trans::No => TileRef::new(MatId::A, ci, kk),
                    Trans::Yes => TileRef::new(MatId::A, kk, ci),
                };
                steps.push(Step {
                    op: TileOp::SyrkDiag { uplo: d.uplo, trans: d.trans },
                    a: Some(a),
                    b: None,
                    alpha: d.alpha,
                    beta,
                    dims: (h, w, kd),
                });
            } else {
                // off-diagonal: plain GEMM of two A tiles
                let (op, a, b) = match d.trans {
                    // C_ij = A_[i,kk] * A_[j,kk]^T
                    Trans::No => (
                        TileOp::Gemm { ta: Trans::No, tb: Trans::Yes },
                        TileRef::new(MatId::A, ci, kk),
                        TileRef::new(MatId::A, cj, kk),
                    ),
                    // C_ij = A_[kk,i]^T * A_[kk,j]
                    Trans::Yes => (
                        TileOp::Gemm { ta: Trans::Yes, tb: Trans::No },
                        TileRef::new(MatId::A, kk, ci),
                        TileRef::new(MatId::A, kk, cj),
                    ),
                };
                steps.push(Step { op, a: Some(a), b: Some(b), alpha: d.alpha, beta, dims: (h, w, kd) });
            }
        }
        tasks.push(mk_task(id, ci, cj, h, w, d.beta != 0.0, mask, steps));
    }
    let heads = (0..tasks.len()).collect();
    TaskSet { tasks, heads }
}

// ---------------------------------------------------------------------
// SYR2K (Eq. 1e)

/// `C := alpha*(op(A) op(B)^T + op(B) op(A)^T) + beta*C`, C n×n.
pub fn taskize_syr2k(d: &SyrkDesc) -> TaskSet {
    let grid = c_grid(d.n, d.n, d.t);
    let z = num_ktiles(d.k, d.t);
    let mut tasks = Vec::new();
    for (ci, cj) in grid.iter() {
        let in_tri = match d.uplo {
            Uplo::Upper => ci <= cj,
            Uplo::Lower => ci >= cj,
        };
        if !in_tri {
            continue;
        }
        let (h, w) = grid.tile_dims(ci, cj);
        let id = tasks.len();
        let mask = if ci == cj {
            match d.uplo {
                Uplo::Upper => WriteMask::UpperTri,
                Uplo::Lower => WriteMask::LowerTri,
            }
        } else {
            WriteMask::Full
        };
        if d.alpha == 0.0 || z == 0 {
            let mut t = scal_task(id, ci, cj, h, w, d.beta);
            t.mask = mask;
            tasks.push(t);
            continue;
        }
        let mut steps = Vec::with_capacity(2 * z);
        for kk in 0..z {
            let kd = kdim(d.k, d.t, kk);
            let beta = if kk == 0 { d.beta } else { 1.0 };
            if ci == cj {
                let (a, b) = match d.trans {
                    Trans::No => {
                        (TileRef::new(MatId::A, ci, kk), TileRef::new(MatId::B, ci, kk))
                    }
                    Trans::Yes => {
                        (TileRef::new(MatId::A, kk, ci), TileRef::new(MatId::B, kk, ci))
                    }
                };
                steps.push(Step {
                    op: TileOp::Syr2kDiag { uplo: d.uplo, trans: d.trans },
                    a: Some(a),
                    b: Some(b),
                    alpha: d.alpha,
                    beta,
                    dims: (h, w, kd),
                });
            } else {
                match d.trans {
                    Trans::No => {
                        // alpha * A_[i,kk] B_[j,kk]^T
                        steps.push(Step {
                            op: TileOp::Gemm { ta: Trans::No, tb: Trans::Yes },
                            a: Some(TileRef::new(MatId::A, ci, kk)),
                            b: Some(TileRef::new(MatId::B, cj, kk)),
                            alpha: d.alpha,
                            beta,
                            dims: (h, w, kd),
                        });
                        // alpha * B_[i,kk] A_[j,kk]^T
                        steps.push(Step {
                            op: TileOp::Gemm { ta: Trans::No, tb: Trans::Yes },
                            a: Some(TileRef::new(MatId::B, ci, kk)),
                            b: Some(TileRef::new(MatId::A, cj, kk)),
                            alpha: d.alpha,
                            beta: 1.0,
                            dims: (h, w, kd),
                        });
                    }
                    Trans::Yes => {
                        // alpha * A_[kk,i]^T B_[kk,j]
                        steps.push(Step {
                            op: TileOp::Gemm { ta: Trans::Yes, tb: Trans::No },
                            a: Some(TileRef::new(MatId::A, kk, ci)),
                            b: Some(TileRef::new(MatId::B, kk, cj)),
                            alpha: d.alpha,
                            beta,
                            dims: (h, w, kd),
                        });
                        // alpha * B_[kk,i]^T A_[kk,j]
                        steps.push(Step {
                            op: TileOp::Gemm { ta: Trans::Yes, tb: Trans::No },
                            a: Some(TileRef::new(MatId::B, kk, ci)),
                            b: Some(TileRef::new(MatId::A, kk, cj)),
                            alpha: d.alpha,
                            beta: 1.0,
                            dims: (h, w, kd),
                        });
                    }
                }
            }
        }
        tasks.push(mk_task(id, ci, cj, h, w, d.beta != 0.0, mask, steps));
    }
    let heads = (0..tasks.len()).collect();
    TaskSet { tasks, heads }
}

// ---------------------------------------------------------------------
// SYMM (Eq. 1f)

/// `C := alpha * sym(A) * B + beta * C` (Left) or
/// `C := alpha * B * sym(A) + beta * C` (Right).
pub fn taskize_symm(d: &SymmDesc) -> TaskSet {
    let grid = c_grid(d.m, d.n, d.t);
    // reduction runs over the symmetric dimension
    let kext = match d.side {
        Side::Left => d.m,
        Side::Right => d.n,
    };
    let z = num_ktiles(kext, d.t);
    let mut tasks = Vec::with_capacity(grid.num_tiles());
    for (ci, cj) in grid.iter() {
        let (h, w) = grid.tile_dims(ci, cj);
        let id = tasks.len();
        if d.alpha == 0.0 || z == 0 {
            tasks.push(scal_task(id, ci, cj, h, w, d.beta));
            continue;
        }
        let mut steps = Vec::with_capacity(z);
        for kk in 0..z {
            let kd = kdim(kext, d.t, kk);
            let beta = if kk == 0 { d.beta } else { 1.0 };
            match d.side {
                Side::Left => {
                    // C_ij += sym(A)_{ci,kk} * B_{kk,cj}
                    let b = TileRef::new(MatId::B, kk, cj);
                    if kk == ci {
                        steps.push(Step {
                            op: TileOp::SymmDiag { side: Side::Left, uplo: d.uplo },
                            a: Some(TileRef::new(MatId::A, ci, ci)),
                            b: Some(b),
                            alpha: d.alpha,
                            beta,
                            dims: (h, w, kd),
                        });
                    } else {
                        // stored tile + trans decided by uplo
                        let stored_direct = match d.uplo {
                            Uplo::Upper => ci < kk,
                            Uplo::Lower => ci > kk,
                        };
                        let (op, a) = if stored_direct {
                            (
                                TileOp::Gemm { ta: Trans::No, tb: Trans::No },
                                TileRef::new(MatId::A, ci, kk),
                            )
                        } else {
                            (
                                TileOp::Gemm { ta: Trans::Yes, tb: Trans::No },
                                TileRef::new(MatId::A, kk, ci),
                            )
                        };
                        steps.push(Step {
                            op,
                            a: Some(a),
                            b: Some(b),
                            alpha: d.alpha,
                            beta,
                            dims: (h, w, kd),
                        });
                    }
                }
                Side::Right => {
                    // C_ij += B_{ci,kk} * sym(A)_{kk,cj}
                    let a = TileRef::new(MatId::B, ci, kk);
                    if kk == cj {
                        // Kernel convention (hostblas + the PJRT
                        // registry): slot `a` is ALWAYS the symmetric
                        // operand, slot `b` the dense one.
                        steps.push(Step {
                            op: TileOp::SymmDiag { side: Side::Right, uplo: d.uplo },
                            a: Some(TileRef::new(MatId::A, cj, cj)),
                            b: Some(a),
                            alpha: d.alpha,
                            beta,
                            dims: (h, w, kd),
                        });
                    } else {
                        let stored_direct = match d.uplo {
                            Uplo::Upper => kk < cj,
                            Uplo::Lower => kk > cj,
                        };
                        let (op, b) = if stored_direct {
                            (
                                TileOp::Gemm { ta: Trans::No, tb: Trans::No },
                                TileRef::new(MatId::A, kk, cj),
                            )
                        } else {
                            (
                                TileOp::Gemm { ta: Trans::No, tb: Trans::Yes },
                                TileRef::new(MatId::A, cj, kk),
                            )
                        };
                        steps.push(Step {
                            op,
                            a: Some(a),
                            b: Some(b),
                            alpha: d.alpha,
                            beta,
                            dims: (h, w, kd),
                        });
                    }
                }
            }
        }
        tasks.push(mk_task(id, ci, cj, h, w, d.beta != 0.0, WriteMask::Full, steps));
    }
    let heads = (0..tasks.len()).collect();
    TaskSet { tasks, heads }
}

// ---------------------------------------------------------------------
// TRMM (Eq. 1d) and TRSM (Eq. 1c)

/// Does `op(A)` act as an *upper* triangular matrix?
fn op_upper(uplo: Uplo, ta: Trans) -> bool {
    match (uplo, ta) {
        (Uplo::Upper, Trans::No) | (Uplo::Lower, Trans::Yes) => true,
        (Uplo::Lower, Trans::No) | (Uplo::Upper, Trans::Yes) => false,
    }
}

/// Off-diagonal tile of op(A) at logical position (r, c), r != c:
/// the stored tile and whether the kernel transposes it. Storage
/// validity: callers only request (r, c) inside op(A)'s triangle, which
/// maps to A's stored triangle per `uplo`.
fn tri_tile(_uplo: Uplo, ta: Trans, r: usize, c: usize) -> (TileRef, Trans) {
    match ta {
        Trans::No => (TileRef::new(MatId::A, r, c), Trans::No),
        Trans::Yes => (TileRef::new(MatId::A, c, r), Trans::Yes),
    }
}

/// TRMM: `B := alpha * op(A) * B` (Left) / `B := alpha * B * op(A)` (Right).
///
/// Chains: Left ⇒ one chain per output *column*, ordered so each task
/// reads neighbour B tiles before their owners overwrite them
/// (ascending row index when op(A) is upper, descending when lower).
/// Right ⇒ one chain per output *row* (ascending column when op(A) is
/// lower, descending when upper).
pub fn taskize_trmm(d: &TriDesc) -> TaskSet {
    let grid = c_grid(d.m, d.n, d.t);
    let tr = grid.tile_rows();
    let tc = grid.tile_cols();
    let upper = op_upper(d.uplo, d.ta);
    let mut tasks: Vec<Task> = Vec::with_capacity(grid.num_tiles());
    // id layout: column-major (ci + cj * tr), so chain linking is easy.
    for (ci, cj) in grid.iter() {
        let (h, w) = grid.tile_dims(ci, cj);
        let id = tasks.len();
        debug_assert_eq!(id, ci + cj * tr);
        if d.alpha == 0.0 {
            tasks.push(scal_task(id, ci, cj, h, w, 0.0));
            continue;
        }
        let mut steps = Vec::new();
        match d.side {
            Side::Left => {
                // first: diagonal multiply consumes original B_ij
                steps.push(Step {
                    op: TileOp::TrmmDiag { side: Side::Left, uplo: d.uplo, ta: d.ta, diag: d.diag },
                    a: Some(TileRef::new(MatId::A, ci, ci)),
                    b: None,
                    alpha: d.alpha,
                    beta: 0.0,
                    dims: (h, w, 0),
                });
                let ks: Vec<usize> =
                    if upper { (ci + 1..tr).collect() } else { (0..ci).collect() };
                for k in ks {
                    let (a, tak) = tri_tile(d.uplo, d.ta, ci, k);
                    steps.push(Step {
                        op: TileOp::Gemm { ta: tak, tb: Trans::No },
                        a: Some(a),
                        b: Some(TileRef::new(MatId::C, k, cj)),
                        alpha: d.alpha,
                        beta: 1.0,
                        dims: (h, w, grid.tile_height(k)),
                    });
                }
            }
            Side::Right => {
                steps.push(Step {
                    op: TileOp::TrmmDiag { side: Side::Right, uplo: d.uplo, ta: d.ta, diag: d.diag },
                    a: Some(TileRef::new(MatId::A, cj, cj)),
                    b: None,
                    alpha: d.alpha,
                    beta: 0.0,
                    dims: (h, w, 0),
                });
                // op(A)_{k,cj} nonzero: upper ⇒ k < cj stored rows above;
                // wait — for the *multiplication* B·op(A), column cj of
                // op(A) has nonzeros at k ≤ cj (upper) / k ≥ cj (lower).
                let ks: Vec<usize> =
                    if upper { (0..cj).collect() } else { (cj + 1..tc).collect() };
                for k in ks {
                    let (b, tak) = tri_tile(d.uplo, d.ta, k, cj);
                    steps.push(Step {
                        op: TileOp::Gemm { ta: Trans::No, tb: tak },
                        a: Some(TileRef::new(MatId::C, ci, k)),
                        b: Some(b),
                        alpha: d.alpha,
                        beta: 1.0,
                        dims: (h, w, grid.tile_width(k)),
                    });
                }
            }
        }
        tasks.push(mk_task(id, ci, cj, h, w, true, WriteMask::Full, steps));
    }
    link_chains(&mut tasks, tr, tc, d.side, trmm_order(d.side, upper));
    finish_chained(tasks)
}

/// TRSM: solve `op(A) * X = alpha * B` (Left) / `X * op(A) = alpha * B`
/// (Right), X overwriting B.
///
/// Chains: Left ⇒ per column; the *first* task is the one whose diagonal
/// block has no off-diagonal dependencies (bottom row for upper op(A) —
/// back substitution — top row for lower). Right ⇒ per row.
pub fn taskize_trsm(d: &TriDesc) -> TaskSet {
    let grid = c_grid(d.m, d.n, d.t);
    let tr = grid.tile_rows();
    let tc = grid.tile_cols();
    let upper = op_upper(d.uplo, d.ta);
    let mut tasks: Vec<Task> = Vec::with_capacity(grid.num_tiles());
    for (ci, cj) in grid.iter() {
        let (h, w) = grid.tile_dims(ci, cj);
        let id = tasks.len();
        if d.alpha == 0.0 {
            // op(A) X = 0 ⇒ X = 0
            tasks.push(scal_task(id, ci, cj, h, w, 0.0));
            continue;
        }
        let mut steps = Vec::new();
        match d.side {
            Side::Left => {
                let ks: Vec<usize> =
                    if upper { (ci + 1..tr).collect() } else { (0..ci).collect() };
                for (idx, k) in ks.iter().enumerate() {
                    let (a, tak) = tri_tile(d.uplo, d.ta, ci, *k);
                    steps.push(Step {
                        op: TileOp::Gemm { ta: tak, tb: Trans::No },
                        a: Some(a),
                        b: Some(TileRef::new(MatId::C, *k, cj)),
                        alpha: -1.0,
                        // fold `alpha * B_ij` into the first accumulation
                        beta: if idx == 0 { d.alpha } else { 1.0 },
                        dims: (h, w, grid.tile_height(*k)),
                    });
                }
                steps.push(Step {
                    op: TileOp::TrsmDiag { side: Side::Left, uplo: d.uplo, ta: d.ta, diag: d.diag },
                    a: Some(TileRef::new(MatId::A, ci, ci)),
                    b: None,
                    // if no gemm steps preceded, alpha scaling happens here
                    alpha: if steps.is_empty() { d.alpha } else { 1.0 },
                    beta: 0.0,
                    dims: (h, w, 0),
                });
            }
            Side::Right => {
                // X_{i,cj} * op(A)_{cj,cj} = alpha B_{i,cj} - Σ X_{i,k} op(A)_{k,cj}
                // column cj of op(A): k < cj (upper) / k > cj (lower)
                let ks: Vec<usize> =
                    if upper { (0..cj).collect() } else { (cj + 1..tc).collect() };
                for (idx, k) in ks.iter().enumerate() {
                    let (b, tak) = tri_tile(d.uplo, d.ta, *k, cj);
                    steps.push(Step {
                        op: TileOp::Gemm { ta: Trans::No, tb: tak },
                        a: Some(TileRef::new(MatId::C, ci, *k)),
                        b: Some(b),
                        alpha: -1.0,
                        beta: if idx == 0 { d.alpha } else { 1.0 },
                        dims: (h, w, grid.tile_width(*k)),
                    });
                }
                steps.push(Step {
                    op: TileOp::TrsmDiag { side: Side::Right, uplo: d.uplo, ta: d.ta, diag: d.diag },
                    a: Some(TileRef::new(MatId::A, cj, cj)),
                    b: None,
                    alpha: if steps.is_empty() { d.alpha } else { 1.0 },
                    beta: 0.0,
                    dims: (h, w, 0),
                });
            }
        }
        tasks.push(mk_task(id, ci, cj, h, w, true, WriteMask::Full, steps));
    }
    link_chains(&mut tasks, tr, tc, d.side, trsm_order(d.side, upper));
    finish_chained(tasks)
}

/// Chain direction: does the chain walk ascending indices?
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum ChainOrder {
    Asc,
    Desc,
}

/// TRMM execution order (reads ORIGINAL neighbour values, so tasks run
/// before the neighbours they read are overwritten):
/// op(A) upper / Left reads rows k > i ⇒ ascending i;
/// op(A) lower / Left reads rows k < i ⇒ descending i;
/// Right mirrors over columns: upper reads k < j ⇒ descending j;
/// lower reads k > j ⇒ ascending j.
fn trmm_order(side: Side, op_is_upper: bool) -> ChainOrder {
    match (side, op_is_upper) {
        (Side::Left, true) => ChainOrder::Asc,
        (Side::Left, false) => ChainOrder::Desc,
        (Side::Right, true) => ChainOrder::Desc,
        (Side::Right, false) => ChainOrder::Asc,
    }
}

/// TRSM execution order (reads COMPUTED neighbour values, so tasks run
/// after their dependencies): exactly the opposite of TRMM.
fn trsm_order(side: Side, op_is_upper: bool) -> ChainOrder {
    match trmm_order(side, op_is_upper) {
        ChainOrder::Asc => ChainOrder::Desc,
        ChainOrder::Desc => ChainOrder::Asc,
    }
}

/// Link per-column (Left) or per-row (Right) chains through
/// `Task::successor` / `Task::n_deps`. Task ids are column-major
/// `ci + cj * tile_rows`.
fn link_chains(tasks: &mut [Task], tr: usize, tc: usize, side: Side, order: ChainOrder) {
    let idx = |ci: usize, cj: usize| ci + cj * tr;
    match side {
        Side::Left => {
            for cj in 0..tc {
                let ids: Vec<usize> = match order {
                    ChainOrder::Asc => (0..tr).map(|ci| idx(ci, cj)).collect(),
                    ChainOrder::Desc => (0..tr).rev().map(|ci| idx(ci, cj)).collect(),
                };
                for win in ids.windows(2) {
                    tasks[win[0]].successor = Some(win[1]);
                    tasks[win[1]].n_deps = 1;
                }
            }
        }
        Side::Right => {
            for ci in 0..tr {
                let ids: Vec<usize> = match order {
                    ChainOrder::Asc => (0..tc).map(|cj| idx(ci, cj)).collect(),
                    ChainOrder::Desc => (0..tc).rev().map(|cj| idx(ci, cj)).collect(),
                };
                for win in ids.windows(2) {
                    tasks[win[0]].successor = Some(win[1]);
                    tasks[win[1]].n_deps = 1;
                }
            }
        }
    }
}

fn finish_chained(tasks: Vec<Task>) -> TaskSet {
    let heads = tasks.iter().filter(|t| t.n_deps == 0).map(|t| t.id).collect();
    TaskSet { tasks, heads }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn gemm_desc(m: usize, n: usize, k: usize, t: usize) -> GemmDesc {
        GemmDesc { ta: Trans::No, tb: Trans::No, m, n, k, alpha: 1.0, beta: 1.0, t }
    }

    #[test]
    fn gemm_task_count_matches_eq2() {
        let ts = taskize_gemm(&gemm_desc(100, 60, 80, 32));
        // ceil(100/32)*ceil(60/32) = 4*2
        assert_eq!(ts.degree_of_parallelism(), 8);
        assert!(ts.validate().is_ok());
        // every task has ceil(80/32)=3 steps
        assert!(ts.tasks.iter().all(|t| t.steps.len() == 3));
    }

    #[test]
    fn gemm_total_flops_matches_closed_form() {
        let (m, n, k) = (96, 64, 80);
        let ts = taskize_gemm(&gemm_desc(m, n, k, 32));
        let expect = 2.0 * (m * n * k) as f64;
        assert!((ts.total_flops() - expect).abs() / expect < 1e-12);
        assert!((ts.gemm_fraction() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn gemm_alpha_zero_degenerates_to_scal() {
        let mut d = gemm_desc(10, 10, 10, 4);
        d.alpha = 0.0;
        let ts = taskize_gemm(&d);
        assert!(ts.tasks.iter().all(|t| t.steps.len() == 1 && t.steps[0].op == TileOp::Scal));
    }

    #[test]
    fn gemm_transposed_tile_indices() {
        let d = GemmDesc { ta: Trans::Yes, tb: Trans::Yes, m: 8, n: 8, k: 8, alpha: 1.0, beta: 0.0, t: 4 };
        let ts = taskize_gemm(&d);
        // task for C tile (1,0): steps read A[kk,1], B[0,kk]
        let t = ts.tasks.iter().find(|t| t.ci == 1 && t.cj == 0).unwrap();
        let s0 = &t.steps[0];
        assert_eq!(s0.a.unwrap(), TileRef::new(MatId::A, 0, 1));
        assert_eq!(s0.b.unwrap(), TileRef::new(MatId::B, 0, 0));
    }

    #[test]
    fn syrk_upper_triangle_only() {
        let d = SyrkDesc { uplo: Uplo::Upper, trans: Trans::No, n: 8, k: 8, alpha: 1.0, beta: 1.0, t: 4 };
        let ts = taskize_syrk(&d);
        assert_eq!(ts.tasks.len(), 3); // (0,0), (0,1), (1,1)
        assert!(ts.validate().is_ok());
        assert!(ts.tasks.iter().all(|t| t.ci <= t.cj));
        let diag = ts.tasks.iter().find(|t| t.ci == t.cj && t.ci == 0).unwrap();
        assert_eq!(diag.mask, WriteMask::UpperTri);
        assert!(matches!(diag.steps[0].op, TileOp::SyrkDiag { .. }));
        let off = ts.tasks.iter().find(|t| t.ci != t.cj).unwrap();
        assert_eq!(off.mask, WriteMask::Full);
        assert!(off.steps[0].op.is_gemm());
    }

    #[test]
    fn syrk_gemm_fraction_grows_with_n() {
        let frac = |n: usize| {
            let d = SyrkDesc { uplo: Uplo::Lower, trans: Trans::No, n, k: n, alpha: 1.0, beta: 1.0, t: 1024 };
            taskize_syrk(&d).gemm_fraction()
        };
        let f5 = frac(5120);
        let f10 = frac(10240);
        let f20 = frac(20480);
        assert!(f5 < f10 && f10 < f20, "{f5} {f10} {f20}");
        // paper Table I band: 74.5% / 86.3% / 92.8%
        assert!(f5 > 0.6 && f5 < 0.9, "{f5}");
        assert!(f20 > 0.88, "{f20}");
    }

    #[test]
    fn syr2k_has_two_gemms_per_k_offdiag() {
        let d = SyrkDesc { uplo: Uplo::Upper, trans: Trans::Yes, n: 8, k: 12, alpha: 2.0, beta: 0.5, t: 4 };
        let ts = taskize_syr2k(&d);
        assert!(ts.validate().is_ok());
        let off = ts.tasks.iter().find(|t| t.ci != t.cj).unwrap();
        assert_eq!(off.steps.len(), 2 * 3);
        // first step carries routine beta, all others 1.0 within pairs
        assert_eq!(off.steps[0].beta, 0.5);
        assert_eq!(off.steps[1].beta, 1.0);
    }

    #[test]
    fn symm_left_upper_uses_transposed_below_diag() {
        let d = SymmDesc { side: Side::Left, uplo: Uplo::Upper, m: 12, n: 8, alpha: 1.0, beta: 0.0, t: 4 };
        let ts = taskize_symm(&d);
        assert!(ts.validate().is_ok());
        // task (2, 0): k = 0,1 are below-diagonal ⇒ A[k,2] transposed;
        // k == 2 diagonal ⇒ SymmDiag
        let t = ts.tasks.iter().find(|t| t.ci == 2 && t.cj == 0).unwrap();
        assert_eq!(t.steps.len(), 3);
        match t.steps[0].op {
            TileOp::Gemm { ta, .. } => assert_eq!(ta, Trans::Yes),
            ref other => panic!("unexpected {:?}", other),
        }
        assert_eq!(t.steps[0].a.unwrap(), TileRef::new(MatId::A, 0, 2));
        assert!(matches!(t.steps[2].op, TileOp::SymmDiag { .. }));
    }

    #[test]
    fn trmm_left_upper_chains_ascend() {
        let d = TriDesc { side: Side::Left, uplo: Uplo::Upper, ta: Trans::No, diag: Diag::NonUnit, m: 12, n: 8, alpha: 1.0, t: 4 };
        let ts = taskize_trmm(&d);
        assert!(ts.validate().is_ok());
        assert_eq!(ts.tasks.len(), 6); // 3x2 tiles
        // per column: head is ci=0, successor ci=1, then ci=2
        let heads: Vec<_> = ts.heads.iter().map(|&h| (ts.tasks[h].ci, ts.tasks[h].cj)).collect();
        assert!(heads.contains(&(0, 0)) && heads.contains(&(0, 1)));
        let t00 = ts.tasks.iter().find(|t| t.ci == 0 && t.cj == 0).unwrap();
        let succ = t00.successor.unwrap();
        assert_eq!((ts.tasks[succ].ci, ts.tasks[succ].cj), (1, 0));
        // first step is the diagonal multiply
        assert!(matches!(t00.steps[0].op, TileOp::TrmmDiag { .. }));
        // task (0,0) accumulates A[0,1] B[1,0] and A[0,2] B[2,0]
        assert_eq!(t00.steps.len(), 3);
        assert_eq!(t00.steps[1].b.unwrap(), TileRef::new(MatId::C, 1, 0));
    }

    #[test]
    fn trsm_left_upper_chains_descend() {
        let d = TriDesc { side: Side::Left, uplo: Uplo::Upper, ta: Trans::No, diag: Diag::NonUnit, m: 12, n: 4, alpha: 2.0, t: 4 };
        let ts = taskize_trsm(&d);
        assert!(ts.validate().is_ok());
        // back substitution: head is bottom row ci=2
        assert_eq!(ts.heads.len(), 1);
        let head = &ts.tasks[ts.heads[0]];
        assert_eq!(head.ci, 2);
        // head task: no gemm steps; TrsmDiag carries alpha
        assert_eq!(head.steps.len(), 1);
        assert_eq!(head.steps[0].alpha, 2.0);
        // interior task ci=0: 2 gemm steps (k=1,2) then solve
        let t0 = ts.tasks.iter().find(|t| t.ci == 0).unwrap();
        assert_eq!(t0.steps.len(), 3);
        assert_eq!(t0.steps[0].alpha, -1.0);
        assert_eq!(t0.steps[0].beta, 2.0); // folded routine alpha
        assert_eq!(t0.steps[1].beta, 1.0);
        assert!(matches!(t0.steps[2].op, TileOp::TrsmDiag { .. }));
    }

    #[test]
    fn trsm_right_lower_chains_over_rows_desc() {
        let d = TriDesc { side: Side::Right, uplo: Uplo::Lower, ta: Trans::No, diag: Diag::Unit, m: 4, n: 12, alpha: 1.0, t: 4 };
        let ts = taskize_trsm(&d);
        assert!(ts.validate().is_ok());
        // op(A) lower, Right: solve runs descending j? lower ⇒ reads k > j
        // computed ⇒ chain descends columns: head at cj = 2.
        assert_eq!(ts.heads.len(), 1);
        assert_eq!(ts.tasks[ts.heads[0]].cj, 2);
    }

    #[test]
    fn trmm_chain_directions_cover_all_variants() {
        for &side in &[Side::Left, Side::Right] {
            for &uplo in &[Uplo::Upper, Uplo::Lower] {
                for &ta in &[Trans::No, Trans::Yes] {
                    let d = TriDesc { side, uplo, ta, diag: Diag::NonUnit, m: 12, n: 12, alpha: 1.0, t: 4 };
                    let tm = taskize_trmm(&d);
                    let tsv = taskize_trsm(&d);
                    assert!(tm.validate().is_ok(), "{side:?} {uplo:?} {ta:?}");
                    assert!(tsv.validate().is_ok(), "{side:?} {uplo:?} {ta:?}");
                    // 3 chains of length 3 each ⇒ 3 heads
                    assert_eq!(tm.heads.len(), 3);
                    assert_eq!(tsv.heads.len(), 3);
                }
            }
        }
    }

    #[test]
    fn trsm_total_flops_near_closed_form() {
        // square left-sided solve: n^3 flops
        let n = 64;
        let d = TriDesc { side: Side::Left, uplo: Uplo::Lower, ta: Trans::No, diag: Diag::NonUnit, m: n, n, alpha: 1.0, t: 16 };
        let ts = taskize_trsm(&d);
        let expect = (n * n * n) as f64;
        let got = ts.total_flops();
        assert!((got - expect).abs() / expect < 0.1, "got {got}, expect {expect}");
    }
}
