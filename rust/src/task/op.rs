//! Tile-level operation vocabulary.
//!
//! Every L3 BLAS routine decomposes into a stream of *tile ops* (paper
//! §III-B): the overwhelming majority are full GEMM tile updates
//! (`TileOp::Gemm`), plus a small family of diagonal-tile specials
//! (triangular multiply/solve, symmetric multiply, rank-k update) — the
//! "small amount of other BLAS" of Goto & van de Geijn that the paper's
//! Table I quantifies.

use crate::api::types::{Diag, Side, Trans, Uplo};

/// One tile-kernel invocation type. The accumulator tile (the task's C
/// tile) is implicit; `a`/`b` operands come from the owning [`super::Step`].
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum TileOp {
    /// `C := alpha * op(A) * op(B) + beta * C` — the dominant kernel.
    Gemm { ta: Trans, tb: Trans },
    /// Diagonal tile of SYRK: `C := alpha * op(A) op(A)^T + beta * C`
    /// (`trans == No`: A·Aᵀ; `trans == Yes`: Aᵀ·A). Result is symmetric;
    /// only the `uplo` triangle is written back to the host.
    SyrkDiag { uplo: Uplo, trans: Trans },
    /// Diagonal tile of SYR2K: `C := alpha*(op(A) op(B)^T + op(B) op(A)^T) + beta*C`.
    Syr2kDiag { uplo: Uplo, trans: Trans },
    /// Diagonal tile of TRMM: `C := alpha * op(Atri) * C` (side = Left)
    /// or `C := alpha * C * op(Atri)` (side = Right). Must be the FIRST
    /// step of its task (it consumes the original C value).
    TrmmDiag { side: Side, uplo: Uplo, ta: Trans, diag: Diag },
    /// Diagonal tile of TRSM: solve `op(Atri) X = alpha*C` (Left) or
    /// `X op(Atri) = alpha*C` (Right), X overwriting the accumulator.
    /// Must be the LAST step of its task.
    TrsmDiag { side: Side, uplo: Uplo, ta: Trans, diag: Diag },
    /// Diagonal tile of SYMM: `C := alpha * sym(A) * B + beta * C` (Left)
    /// or `C := alpha * B * sym(A) + beta * C` (Right); `sym(A)` reads
    /// only the `uplo` triangle and mirrors it.
    SymmDiag { side: Side, uplo: Uplo },
    /// Pure scaling `C := beta * C` (alpha == 0 or k == 0 quick paths).
    Scal,
}

impl TileOp {
    /// Is this the full-GEMM kernel (numerator of the paper's Table I)?
    pub fn is_gemm(self) -> bool {
        matches!(self, TileOp::Gemm { .. })
    }

    /// Floating-point operations for this op at step dims `(m, n, k)`
    /// (`m`,`n` = accumulator tile dims; `k` = reduction extent where
    /// applicable). Standard BLAS flop counts.
    pub fn flops(self, m: usize, n: usize, k: usize) -> f64 {
        let (m, n, k) = (m as f64, n as f64, k as f64);
        match self {
            TileOp::Gemm { .. } => 2.0 * m * n * k,
            // Symmetric rank-k on an n×n diagonal tile: n(n+1)k.
            TileOp::SyrkDiag { .. } => n * (n + 1.0) * k,
            TileOp::Syr2kDiag { .. } => 2.0 * n * (n + 1.0) * k,
            // Triangular multiply/solve against an m×m (Left) or n×n
            // (Right) triangle: half the GEMM count.
            TileOp::TrmmDiag { side, .. } | TileOp::TrsmDiag { side, .. } => match side {
                Side::Left => m * m * n,
                Side::Right => m * n * n,
            },
            TileOp::SymmDiag { side, .. } => match side {
                // sym(A) is m×m (Left) / n×n (Right); dense multiply.
                Side::Left => 2.0 * m * m * n,
                Side::Right => 2.0 * m * n * n,
            },
            TileOp::Scal => m * n,
        }
    }

    /// Stable kernel name used for artifact lookup and traces, e.g.
    /// `gemm_nn`, `gemm_tn`, `trsm_l_up_n_nu`.
    pub fn kernel_name(self) -> String {
        fn t(x: Trans) -> &'static str {
            match x {
                Trans::No => "n",
                Trans::Yes => "t",
            }
        }
        fn u(x: Uplo) -> &'static str {
            match x {
                Uplo::Upper => "up",
                Uplo::Lower => "lo",
            }
        }
        fn s(x: Side) -> &'static str {
            match x {
                Side::Left => "l",
                Side::Right => "r",
            }
        }
        fn d(x: Diag) -> &'static str {
            match x {
                Diag::NonUnit => "nu",
                Diag::Unit => "un",
            }
        }
        match self {
            TileOp::Gemm { ta, tb } => format!("gemm_{}{}", t(ta), t(tb)),
            TileOp::SyrkDiag { uplo, trans } => format!("syrk_{}_{}", u(uplo), t(trans)),
            TileOp::Syr2kDiag { uplo, trans } => format!("syr2k_{}_{}", u(uplo), t(trans)),
            TileOp::TrmmDiag { side, uplo, ta, diag } => {
                format!("trmm_{}_{}_{}_{}", s(side), u(uplo), t(ta), d(diag))
            }
            TileOp::TrsmDiag { side, uplo, ta, diag } => {
                format!("trsm_{}_{}_{}_{}", s(side), u(uplo), t(ta), d(diag))
            }
            TileOp::SymmDiag { side, uplo } => format!("symm_{}_{}", s(side), u(uplo)),
            TileOp::Scal => "scal".to_string(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gemm_flops() {
        let op = TileOp::Gemm { ta: Trans::No, tb: Trans::Yes };
        assert_eq!(op.flops(10, 20, 30), 2.0 * 10.0 * 20.0 * 30.0);
        assert!(op.is_gemm());
    }

    #[test]
    fn diag_ops_cost_less_than_gemm() {
        let g = TileOp::Gemm { ta: Trans::No, tb: Trans::No }.flops(64, 64, 64);
        let s = TileOp::SyrkDiag { uplo: Uplo::Upper, trans: Trans::No }.flops(64, 64, 64);
        let tr = TileOp::TrsmDiag {
            side: Side::Left,
            uplo: Uplo::Upper,
            ta: Trans::No,
            diag: Diag::NonUnit,
        }
        .flops(64, 64, 0);
        assert!(s < g);
        assert!(tr < g);
        assert!(!TileOp::Scal.is_gemm());
    }

    #[test]
    fn kernel_names_stable() {
        assert_eq!(
            TileOp::Gemm { ta: Trans::Yes, tb: Trans::No }.kernel_name(),
            "gemm_tn"
        );
        assert_eq!(
            TileOp::TrsmDiag {
                side: Side::Left,
                uplo: Uplo::Upper,
                ta: Trans::No,
                diag: Diag::NonUnit
            }
            .kernel_name(),
            "trsm_l_up_n_nu"
        );
        assert_eq!(TileOp::SymmDiag { side: Side::Right, uplo: Uplo::Lower }.kernel_name(), "symm_r_lo");
    }
}
