//! Baseline schedulers (system S15): the four competitors of the
//! paper's evaluation, re-implemented from their published descriptions
//! and run on the same simulated substrate as BLASX.
//!
//! | baseline     | assignment        | streams | cache       | overlap |
//! |--------------|-------------------|---------|-------------|---------|
//! | cuBLAS-XT    | static round-robin| 2       | none        | async   |
//! | MAGMA        | block-cyclic      | 2       | per-GPU LRU | async   |
//! | SuperMatrix  | central queue     | 1       | none        | blocking|
//! | PaRSEC       | speed-weighted    | 4       | per-GPU LRU | async, in-core only |
//!
//! None use P2P — that is BLASX's contribution (§IV-B).

pub mod engine;

use crate::coordinator::sim_engine::SimReport;
use crate::coordinator::{Policy, RunConfig, Workload};
use crate::sim::Machine;
use engine::{run_baseline, Assignment, BaselineSpec};

/// The published shape of each baseline policy.
pub fn spec_of(policy: Policy) -> BaselineSpec {
    match policy {
        Policy::CublasXt => BaselineSpec {
            assignment: Assignment::RoundRobin,
            n_streams: 2,
            caching: false,
            blocking: false,
            in_core_only: false,
            per_task_overhead: 0.0,
        },
        Policy::Magma => BaselineSpec {
            assignment: Assignment::BlockCyclic,
            n_streams: 2,
            caching: true,
            blocking: false,
            in_core_only: true,
            per_task_overhead: 0.0,
        },
        Policy::SuperMatrix => BaselineSpec {
            assignment: Assignment::CentralQueue,
            n_streams: 1,
            caching: false,
            blocking: true,
            in_core_only: false,
            // Tomasulo-style dependence tracking per tile op
            per_task_overhead: 100e-6,
        },
        Policy::Parsec => BaselineSpec {
            assignment: Assignment::SpeedWeighted,
            n_streams: 4,
            caching: true,
            blocking: false,
            in_core_only: true,
            // DAG build + activation per task (paper §II)
            per_task_overhead: 250e-6,
        },
        Policy::Blasx => unreachable!("BLASX is not a baseline"),
    }
}

/// Run a baseline policy on a workload (dispatched from
/// `coordinator::dispatch::run_sim`).
///
/// Baselines model *single-problem* schedulers: none of the published
/// systems expose batched L3 calls, and the engine sizes its in-core
/// gate and C-tile geometry from problem 0 only. A fused batch
/// workload is therefore reported infeasible (rendered "N/A" by the
/// harness) rather than simulated with wrong geometry.
pub fn run(cfg: &RunConfig, machine: &Machine, w: &Workload) -> SimReport {
    if w.keymap.n_problems() > 1 {
        return SimReport::infeasible();
    }
    let spec = spec_of(cfg.policy);
    run_baseline(&spec, cfg, machine, &w.ts, &w.keymap, w.dtype)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::types::Routine;
    use crate::api::Dtype;
    use crate::coordinator::dispatch::square_workload;
    use crate::sim::{everest, toy};

    fn small(policy: Policy) -> SimReport {
        let cfg = RunConfig { t: 64, policy, ..Default::default() };
        // roomy VRAM: the in-core baselines (MAGMA/PaRSEC) need all
        // three 512² operands resident (3 * 2 MB)
        let machine = toy(2, 64 << 20);
        let w = square_workload(Routine::Gemm, 512, 64, Dtype::F64);
        run(&cfg, &machine, &w)
    }

    #[test]
    fn all_baselines_complete_small_gemm() {
        for p in [Policy::CublasXt, Policy::Magma, Policy::SuperMatrix, Policy::Parsec] {
            let rep = small(p);
            assert!(rep.feasible, "{p:?}");
            assert!(rep.makespan > 0.0, "{p:?}");
            assert_eq!(rep.tasks_per_worker.iter().sum::<usize>(), 64, "{p:?}");
        }
    }

    #[test]
    fn parsec_incore_gate_rejects_oversize() {
        let cfg = RunConfig { t: 64, policy: Policy::Parsec, ..Default::default() };
        // tiny VRAM: 3 tiles worth, matrices need 192 tiles
        let machine = toy(2, 3 * 64 * 64 * 8);
        let w = square_workload(Routine::Gemm, 512, 64, Dtype::F64);
        let rep = run(&cfg, &machine, &w);
        assert!(!rep.feasible);
        assert!(rep.gflops(1e9) == 0.0);
    }

    #[test]
    fn supermatrix_slower_than_xt_on_everest() {
        // The paper's core qualitative claim about SuperMatrix: blocking
        // transfers + single stream => clearly worse than overlapped XT.
        let w = square_workload(Routine::Gemm, 8192, 1024, Dtype::F64);
        let machine = everest(3);
        let xt = {
            let cfg = RunConfig::paper().with_policy(Policy::CublasXt);
            run(&cfg, &machine, &w)
        };
        let sm = {
            let cfg = RunConfig::paper().with_policy(Policy::SuperMatrix);
            run(&cfg, &machine, &w)
        };
        assert!(
            sm.makespan > xt.makespan * 1.05,
            "SuperMatrix {:.4}s vs XT {:.4}s",
            sm.makespan,
            xt.makespan
        );
    }
}
