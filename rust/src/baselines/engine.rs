//! A parameterized DES engine covering the four competitor policies the
//! paper benchmarks against (§II, §V). Each baseline is the *published
//! scheduling policy* re-implemented on the same simulated substrate as
//! BLASX, so comparisons isolate exactly the scheduling/caching variable
//! (DESIGN.md §1).
//!
//! The knobs:
//! - **assignment**: static per-task owner (round-robin / block-cyclic /
//!   speed-weighted) or a shared central queue;
//! - **streams**: how many concurrent stream lanes a device drives
//!   (cuBLAS-XT uses 2, SuperMatrix effectively 1);
//! - **caching**: none (every step re-transfers, cuBLAS-XT-style) or a
//!   per-device ALRU without P2P (MAGMA/PaRSEC-style);
//! - **blocking**: fork-join transfers (SuperMatrix) vs async overlap;
//! - **in-core gate**: reject problems larger than device RAM (PaRSEC,
//!   MAGMA per the paper's partial benchmarks).

use crate::api::Dtype;
use crate::cache::{Source, TileCacheSet};
use crate::coordinator::keymap::KeyMap;
use crate::coordinator::sim_engine::SimReport;
use crate::coordinator::RunConfig;
use crate::mem::AllocStrategy;
use crate::sim::{Dir, EventQueue, Lane, Machine, SimTime, Topology};
use crate::task::{Task, TaskSet, TileRef};
use crate::tile::MatId;
use crate::trace::{EvKind, Trace};
use std::collections::VecDeque;

/// How tasks map to devices.
pub enum Assignment {
    /// task i → device (i mod n): cuBLAS-XT's static tile blocks.
    RoundRobin,
    /// Owner by output tile column, block-cyclic: MAGMA's static 1D
    /// distribution.
    BlockCyclic,
    /// Static split proportional to device DP/SP rate: the PaRSEC
    /// assumption of constant per-device speed.
    SpeedWeighted,
    /// Central ready queue, pulled on demand (SuperMatrix's Tomasulo-
    /// style dispatch — dynamic but blocking).
    CentralQueue,
}

/// One baseline's shape.
pub struct BaselineSpec {
    pub assignment: Assignment,
    pub n_streams: usize,
    /// Per-device tile cache (no P2P). None = re-transfer every step.
    pub caching: bool,
    /// Fork-join: the kernel waits for its transfer AND the next
    /// transfer waits for the kernel (single in-order pipe).
    pub blocking: bool,
    /// Reject problems whose three operands exceed one device's RAM.
    pub in_core_only: bool,
    /// Per-task runtime overhead, seconds, charged on the device before
    /// the first kernel (PaRSEC's DAG build/activation cost — §II:
    /// "building DAGs at runtime ... can be a huge cost"; Tomasulo
    /// bookkeeping for SuperMatrix).
    pub per_task_overhead: f64,
}

struct BWorker {
    queue: VecDeque<usize>,
    stream_free: Vec<SimTime>,
    kernel_lane: Lane,
    tasks_done: usize,
    /// Deferred ALRU releases (applied when the device goes idle — the
    /// baselines have no sync-point reader protocol; releasing at task
    /// end is the closest analogue).
    pending_release: Vec<crate::tile::TileKey>,
}

/// Run a baseline policy over a task set.
pub fn run_baseline(
    spec: &BaselineSpec,
    cfg: &RunConfig,
    machine: &Machine,
    ts: &TaskSet,
    keymap: &KeyMap,
    dtype: Dtype,
) -> SimReport {
    let n = machine.devices.len();
    if spec.in_core_only {
        // All three operands must fit in one device's RAM (the paper:
        // PaRSEC "limits ... to handle matrix sizes N > 22528" on 12 GB).
        let need: usize = [MatId::A, MatId::B, MatId::C]
            .iter()
            .map(|&m| {
                let g = keymap.grid(m);
                g.rows * g.cols * keymap.esz
            })
            .sum();
        let vram = cfg.vram_override.unwrap_or(machine.devices[0].vram);
        if need > vram {
            return SimReport::infeasible();
        }
    }

    let mut topo = Topology::new(machine.topology.clone());
    let capacities: Vec<usize> =
        machine.devices.iter().map(|d| cfg.vram_override.unwrap_or(d.vram)).collect();
    // Baselines never use P2P: empty peer lists.
    let mut caches = spec
        .caching
        .then(|| TileCacheSet::new(&capacities, vec![Vec::new(); n], AllocStrategy::FastHeap));

    // --- distribute tasks
    let mut workers: Vec<BWorker> = (0..n)
        .map(|_| BWorker {
            queue: VecDeque::new(),
            stream_free: vec![0.0; spec.n_streams],
            kernel_lane: Lane::new(),
            tasks_done: 0,
            pending_release: Vec::new(),
        })
        .collect();
    let mut central: VecDeque<usize> = VecDeque::new();
    let mut deps: Vec<usize> = ts.tasks.iter().map(|t| t.n_deps).collect();
    let assign_of = |tid: usize, task: &Task| -> usize {
        match spec.assignment {
            Assignment::RoundRobin => tid % n,
            Assignment::BlockCyclic => task.cj % n,
            Assignment::SpeedWeighted => {
                // deterministic proportional split over task ids
                let rates: Vec<f64> = machine.devices.iter().map(|d| d.rate(dtype)).collect();
                let total: f64 = rates.iter().sum();
                let frac = (tid as f64 + 0.5) / ts.tasks.len() as f64;
                let mut acc = 0.0;
                for (i, r) in rates.iter().enumerate() {
                    acc += r / total;
                    if frac <= acc {
                        return i;
                    }
                }
                n - 1
            }
            Assignment::CentralQueue => usize::MAX,
        }
    };
    for &h in &ts.heads {
        match spec.assignment {
            Assignment::CentralQueue => central.push_back(h),
            _ => workers[assign_of(h, &ts.tasks[h])].queue.push_back(h),
        }
    }

    let mut trace = Trace::new();
    let mut events: EventQueue<usize> = EventQueue::new();
    // SuperMatrix issues *synchronous* cudaMemcpy from its runtime
    // thread (paper Fig. 1a): every transfer in the machine serializes
    // through that one host thread, which is what wrecks its multi-GPU
    // scaling. Modelled as a shared lane used only by blocking policies.
    let mut host_thread = Lane::new();
    let mut idle = vec![false; n];
    for d in 0..n {
        events.schedule(0.0, d);
    }
    let mut remaining = ts.tasks.len();
    let mut guard = 0u64;

    // Round-based issue mirroring how a host thread actually drives CUDA
    // streams: bind up to `n_streams` tasks, then issue their k-steps
    // interleaved k-major so stream B's step-k transfer overlaps stream
    // A's step-k kernel. A blocking policy (SuperMatrix) has one stream,
    // which degenerates to fork-join exactly as the paper's Fig. 1a.
    while let Some((now, d)) = events.pop() {
        guard += 1;
        assert!(guard < 1_000_000_000, "baseline runaway");

        // release cached readers from the previous round (task-end scope)
        if let Some(c) = caches.as_mut() {
            for k in std::mem::take(&mut workers[d].pending_release) {
                c.release(d, &k);
            }
        }

        // bind one task per stream
        let mut bound: Vec<(usize, usize)> = Vec::new(); // (task, stream)
        for s in 0..spec.n_streams {
            let tid = match spec.assignment {
                Assignment::CentralQueue => central.pop_front(),
                _ => workers[d].queue.pop_front(),
            };
            match tid {
                Some(t) => bound.push((t, s)),
                None => break,
            }
        }
        if bound.is_empty() {
            idle[d] = true;
            continue;
        }
        idle[d] = false;

        // C move-ins
        for &(tid, s) in &bound {
            let task = &ts.tasks[tid];
            let mut ready = workers[d].stream_free[s].max(now) + spec.per_task_overhead;
            if task.reads_c {
                let bytes = keymap.transfer_bytes(TileRef::new(MatId::C, task.ci, task.cj));
                let t0 = if spec.blocking { host_thread.book(ready, 0.0).0 } else { ready };
                let done = topo.book_hd(d, Dir::H2D, bytes, t0);
                if spec.blocking {
                    host_thread.book(t0, done - t0);
                }
                trace.record(d, s, EvKind::H2d, t0, done, bytes as f64);
                ready = done;
            }
            workers[d].stream_free[s] = ready;
        }

        // k-major interleaved issue
        let max_steps = bound.iter().map(|&(t, _)| ts.tasks[t].steps.len()).max().unwrap();
        for k in 0..max_steps {
            for &(tid, s) in &bound {
                let Some(step) = ts.tasks[tid].steps.get(k) else { continue };
                let mut ready = workers[d].stream_free[s];
                for tile in step.inputs() {
                    let bytes = keymap.transfer_bytes(tile);
                    let hit = if let Some(c) = caches.as_mut() {
                        let key = keymap.key(tile);
                        match c.acquire(d, key, keymap.tile_bytes()) {
                            Some(acq) => {
                                workers[d].pending_release.push(key);
                                matches!(acq.source, Source::L1 | Source::Peer { .. })
                            }
                            None => false, // cache thrashing: plain transfer
                        }
                    } else {
                        false
                    };
                    if !hit {
                        let t0 = if spec.blocking { host_thread.book(ready, 0.0).0 } else { ready };
                        let done = topo.book_hd(d, Dir::H2D, bytes, t0);
                        if spec.blocking {
                            host_thread.book(t0, done - t0);
                        }
                        trace.record(d, s, EvKind::H2d, t0, done, bytes as f64);
                        ready = done;
                    }
                }
                let secs = machine.devices[d].kernel_secs(step.flops(), cfg.t, dtype)
                    * crate::coordinator::config::jitter_factor(cfg.jitter, d, tid);
                let (ks, ke) = workers[d].kernel_lane.book(ready, secs);
                trace.record(d, s, EvKind::Kernel, ks, ke, step.flops());
                workers[d].stream_free[s] = ke;
            }
        }

        // write-backs + completion bookkeeping
        for &(tid, s) in &bound {
            let task = &ts.tasks[tid];
            let ready = workers[d].stream_free[s];
            let bytes = keymap.transfer_bytes(TileRef::new(MatId::C, task.ci, task.cj));
            let t0 = if spec.blocking { host_thread.book(ready, 0.0).0 } else { ready };
            let done = topo.book_hd(d, Dir::D2H, bytes, t0);
            if spec.blocking {
                host_thread.book(t0, done - t0);
            }
            trace.record(d, s, EvKind::D2h, t0, done, bytes as f64);
            workers[d].stream_free[s] = done;
            workers[d].tasks_done += 1;
            remaining -= 1;

            if let Some(succ) = task.successor {
                deps[succ] -= 1;
                if deps[succ] == 0 {
                    match spec.assignment {
                        Assignment::CentralQueue => {
                            central.push_back(succ);
                            for (w, is_idle) in idle.iter_mut().enumerate() {
                                if *is_idle {
                                    *is_idle = false;
                                    events.schedule(now, w);
                                }
                            }
                        }
                        _ => {
                            let owner = assign_of(succ, &ts.tasks[succ]);
                            workers[owner].queue.push_back(succ);
                            if idle[owner] {
                                idle[owner] = false;
                                events.schedule(now, owner);
                            }
                        }
                    }
                }
            }
        }

        // next round at the sync point
        let t_sync = workers[d].stream_free.iter().cloned().fold(now, f64::max);
        events.schedule(t_sync.max(now + 1e-9), d);
    }
    assert_eq!(remaining, 0, "baseline stalled");

    trace.makespan = trace.events.iter().map(|e| e.end).fold(0.0, f64::max);
    SimReport {
        makespan: trace.makespan,
        tasks_per_worker: workers.iter().map(|w| w.tasks_done).collect(),
        alloc_cost: 0.0,
        cache_stats: (0..n)
            .map(|d| caches.as_ref().map(|c| c.stats(d)).unwrap_or_default())
            .collect(),
        steals: vec![0; n],
        dma_throughput: topo.measured_throughput(),
        trace,
        feasible: true,
    }
}
