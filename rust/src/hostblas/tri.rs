//! Host triangular kernels: TRMM and TRSM (naive, trustworthy oracles)
//! plus the diagonal-tile variants used by the tile executor.
//!
//! Column-major throughout. `op(A)` is the `uplo` triangle of A (with
//! implicit unit diagonal for `Diag::Unit`), optionally transposed.

use crate::api::types::{Diag, Scalar, Side, Trans, Uplo};

/// Read element `(r, c)` of the *logical* triangular operand op(A) from
/// the stored triangle: zero outside the triangle, one on the diagonal
/// when `diag == Unit`.
#[inline]
fn tri_elem<T: Scalar>(
    a: &[T],
    lda: usize,
    uplo: Uplo,
    ta: Trans,
    diag: Diag,
    r: usize,
    c: usize,
) -> T {
    // logical (r,c) of op(A) = stored (r,c) or (c,r)
    let (sr, sc) = match ta {
        Trans::No => (r, c),
        Trans::Yes => (c, r),
    };
    if sr == sc {
        return match diag {
            Diag::Unit => T::one(),
            Diag::NonUnit => a[sc * lda + sr],
        };
    }
    let stored = match uplo {
        Uplo::Upper => sr < sc,
        Uplo::Lower => sr > sc,
    };
    if stored {
        a[sc * lda + sr]
    } else {
        T::zero()
    }
}

/// TRMM: `B := alpha * op(A) * B` (Left, A is m×m) or
/// `B := alpha * B * op(A)` (Right, A is n×n). Naive reference.
#[allow(clippy::too_many_arguments)]
pub fn trmm_ref<T: Scalar>(
    side: Side,
    uplo: Uplo,
    ta: Trans,
    diag: Diag,
    m: usize,
    n: usize,
    alpha: T,
    a: &[T],
    lda: usize,
    b: &mut [T],
    ldb: usize,
) {
    match side {
        Side::Left => {
            // column by column: b_col := alpha * op(A) * b_col
            let mut tmp = vec![T::zero(); m];
            for j in 0..n {
                for i in 0..m {
                    let mut acc = T::zero();
                    for p in 0..m {
                        let av = tri_elem(a, lda, uplo, ta, diag, i, p);
                        if av != T::zero() {
                            acc += av * b[j * ldb + p];
                        }
                    }
                    tmp[i] = alpha * acc;
                }
                for i in 0..m {
                    b[j * ldb + i] = tmp[i];
                }
            }
        }
        Side::Right => {
            // row by row: b_row := alpha * b_row * op(A)
            let mut tmp = vec![T::zero(); n];
            for i in 0..m {
                for j in 0..n {
                    let mut acc = T::zero();
                    for p in 0..n {
                        let av = tri_elem(a, lda, uplo, ta, diag, p, j);
                        if av != T::zero() {
                            acc += b[p * ldb + i] * av;
                        }
                    }
                    tmp[j] = alpha * acc;
                }
                for j in 0..n {
                    b[j * ldb + i] = tmp[j];
                }
            }
        }
    }
}

/// TRSM: solve `op(A) * X = alpha * B` (Left) or `X * op(A) = alpha * B`
/// (Right), overwriting B with X. Naive forward/back substitution.
#[allow(clippy::too_many_arguments)]
pub fn trsm_ref<T: Scalar>(
    side: Side,
    uplo: Uplo,
    ta: Trans,
    diag: Diag,
    m: usize,
    n: usize,
    alpha: T,
    a: &[T],
    lda: usize,
    b: &mut [T],
    ldb: usize,
) {
    // scale RHS by alpha first
    for j in 0..n {
        for i in 0..m {
            let v = b[j * ldb + i];
            b[j * ldb + i] = alpha * v;
        }
    }
    // op(A) acts upper-triangular?
    let op_upper = matches!(
        (uplo, ta),
        (Uplo::Upper, Trans::No) | (Uplo::Lower, Trans::Yes)
    );
    match side {
        Side::Left => {
            // solve op(A) x = rhs per column
            for j in 0..n {
                if op_upper {
                    // back substitution
                    for ii in (0..m).rev() {
                        let mut acc = b[j * ldb + ii];
                        for p in ii + 1..m {
                            acc -= tri_elem(a, lda, uplo, ta, diag, ii, p) * b[j * ldb + p];
                        }
                        let d = tri_elem(a, lda, uplo, ta, diag, ii, ii);
                        b[j * ldb + ii] = acc / d;
                    }
                } else {
                    // forward substitution
                    for ii in 0..m {
                        let mut acc = b[j * ldb + ii];
                        for p in 0..ii {
                            acc -= tri_elem(a, lda, uplo, ta, diag, ii, p) * b[j * ldb + p];
                        }
                        let d = tri_elem(a, lda, uplo, ta, diag, ii, ii);
                        b[j * ldb + ii] = acc / d;
                    }
                }
            }
        }
        Side::Right => {
            // solve x op(A) = rhs per row: column jj of x depends on
            // columns p<jj (op upper: forward over columns) or p>jj
            for i in 0..m {
                if op_upper {
                    for jj in 0..n {
                        let mut acc = b[jj * ldb + i];
                        for p in 0..jj {
                            acc -= b[p * ldb + i] * tri_elem(a, lda, uplo, ta, diag, p, jj);
                        }
                        let d = tri_elem(a, lda, uplo, ta, diag, jj, jj);
                        b[jj * ldb + i] = acc / d;
                    }
                } else {
                    for jj in (0..n).rev() {
                        let mut acc = b[jj * ldb + i];
                        for p in jj + 1..n {
                            acc -= b[p * ldb + i] * tri_elem(a, lda, uplo, ta, diag, p, jj);
                        }
                        let d = tri_elem(a, lda, uplo, ta, diag, jj, jj);
                        b[jj * ldb + i] = acc / d;
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hostblas::gemm::gemm_ref;
    use crate::util::prng::Prng;

    fn rand_tri(rng: &mut Prng, n: usize, uplo: Uplo) -> Vec<f64> {
        // well-conditioned triangle: strong diagonal
        let mut a = vec![0.0; n * n];
        for c in 0..n {
            for r in 0..n {
                let stored = match uplo {
                    Uplo::Upper => r <= c,
                    Uplo::Lower => r >= c,
                };
                if stored {
                    a[c * n + r] =
                        if r == c { 3.0 + rng.next_f64() } else { rng.range_f64(-0.5, 0.5) };
                } else {
                    a[c * n + r] = f64::NAN; // must never be read
                }
            }
        }
        a
    }

    fn dense_of_tri(a: &[f64], n: usize, uplo: Uplo, ta: Trans, diag: Diag) -> Vec<f64> {
        let mut d = vec![0.0; n * n];
        for c in 0..n {
            for r in 0..n {
                d[c * n + r] = tri_elem(a, n, uplo, ta, diag, r, c);
            }
        }
        d
    }

    fn close(a: &[f64], b: &[f64], tol: f64) -> bool {
        a.iter().zip(b).all(|(x, y)| (x - y).abs() <= tol * x.abs().max(y.abs()).max(1.0))
    }

    #[test]
    fn trmm_matches_dense_gemm_all_variants() {
        let mut rng = Prng::new(101);
        let (m, n) = (9, 7);
        for &side in &[Side::Left, Side::Right] {
            for &uplo in &[Uplo::Upper, Uplo::Lower] {
                for &ta in &[Trans::No, Trans::Yes] {
                    for &diag in &[Diag::NonUnit, Diag::Unit] {
                        let na = if side == Side::Left { m } else { n };
                        let a = rand_tri(&mut rng, na, uplo);
                        let mut b = vec![0.0; m * n];
                        rng.fill_f64(&mut b, -1.0, 1.0);
                        let b0 = b.clone();
                        trmm_ref(side, uplo, ta, diag, m, n, 1.5, &a, na, &mut b, m);
                        // dense check
                        let ad = dense_of_tri(&a, na, uplo, ta, diag);
                        let mut expect = vec![0.0; m * n];
                        match side {
                            Side::Left => gemm_ref(
                                Trans::No, Trans::No, m, n, m, 1.5, &ad, na, &b0, m, 0.0,
                                &mut expect, m,
                            ),
                            Side::Right => gemm_ref(
                                Trans::No, Trans::No, m, n, n, 1.5, &b0, m, &ad, na, 0.0,
                                &mut expect, m,
                            ),
                        }
                        assert!(
                            close(&b, &expect, 1e-10),
                            "trmm {side:?} {uplo:?} {ta:?} {diag:?}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn trsm_inverts_trmm_all_variants() {
        let mut rng = Prng::new(202);
        let (m, n) = (8, 6);
        for &side in &[Side::Left, Side::Right] {
            for &uplo in &[Uplo::Upper, Uplo::Lower] {
                for &ta in &[Trans::No, Trans::Yes] {
                    for &diag in &[Diag::NonUnit, Diag::Unit] {
                        let na = if side == Side::Left { m } else { n };
                        let a = rand_tri(&mut rng, na, uplo);
                        let mut x = vec![0.0; m * n];
                        rng.fill_f64(&mut x, -1.0, 1.0);
                        let x0 = x.clone();
                        // b = op(A)·x (or x·op(A)); then solving must return x
                        trmm_ref(side, uplo, ta, diag, m, n, 1.0, &a, na, &mut x, m);
                        trsm_ref(side, uplo, ta, diag, m, n, 1.0, &a, na, &mut x, m);
                        assert!(
                            close(&x, &x0, 1e-9),
                            "trsm·trmm ≠ id: {side:?} {uplo:?} {ta:?} {diag:?}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn trsm_scales_by_alpha() {
        let mut rng = Prng::new(7);
        let n = 5;
        let a = rand_tri(&mut rng, n, Uplo::Upper);
        let mut b1 = vec![0.0; n * n];
        rng.fill_f64(&mut b1, -1.0, 1.0);
        let mut b2 = b1.clone();
        trsm_ref(Side::Left, Uplo::Upper, Trans::No, Diag::NonUnit, n, n, 2.0, &a, n, &mut b1, n);
        trsm_ref(Side::Left, Uplo::Upper, Trans::No, Diag::NonUnit, n, n, 1.0, &a, n, &mut b2, n);
        let twice: Vec<f64> = b2.iter().map(|x| 2.0 * x).collect();
        assert!(close(&b1, &twice, 1e-12));
    }

    #[test]
    fn unit_diag_ignores_stored_diagonal() {
        // stored diagonal set to NaN-free junk; Unit must not read it
        let n = 4;
        let mut a = vec![0.0; n * n];
        for c in 0..n {
            for r in 0..=c {
                a[c * n + r] = if r == c { 999.0 } else { 0.25 };
            }
        }
        let mut b = vec![1.0f64; n];
        trmm_ref(Side::Left, Uplo::Upper, Trans::No, Diag::Unit, n, 1, 1.0, &a, n, &mut b, n);
        // row 3 (last): only diagonal (unit) contributes = 1.0
        assert_eq!(b[3], 1.0);
        // row 0: 1 + 0.25*3 = 1.75
        assert!((b[0] - 1.75).abs() < 1e-12);
    }
}
