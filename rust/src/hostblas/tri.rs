//! Host triangular kernels: TRMM and TRSM.
//!
//! Column-major throughout. `op(A)` is the `uplo` triangle of A (with
//! implicit unit diagonal for `Diag::Unit`), optionally transposed.
//!
//! `*_ref` are the naive, trustworthy oracles (test-only since the
//! packed engine landed). `*_packed` are the blocked macro-kernels: the
//! triangular operand is processed in `NB×NB` diagonal blocks —
//! densified once per block into a thread-reused scratch so the inner
//! loops are branch-free — and everything off the block diagonal is a
//! panel GEMM through [`super::gemm::gemm_packed`]. TRSM solves the
//! diagonal block by forward/back substitution and folds the rest of
//! the triangle into rank-NB GEMM updates (the classical right-looking
//! blocked algorithm).

use super::gemm::gemm_packed;
use super::pack::{give_buf, take_buf};
use crate::api::types::{Diag, Scalar, Side, Trans, Uplo};

/// Read element `(r, c)` of the *logical* triangular operand op(A) from
/// the stored triangle: zero outside the triangle, one on the diagonal
/// when `diag == Unit`.
#[inline]
fn tri_elem<T: Scalar>(
    a: &[T],
    lda: usize,
    uplo: Uplo,
    ta: Trans,
    diag: Diag,
    r: usize,
    c: usize,
) -> T {
    // logical (r,c) of op(A) = stored (r,c) or (c,r)
    let (sr, sc) = match ta {
        Trans::No => (r, c),
        Trans::Yes => (c, r),
    };
    if sr == sc {
        return match diag {
            Diag::Unit => T::one(),
            Diag::NonUnit => a[sc * lda + sr],
        };
    }
    let stored = match uplo {
        Uplo::Upper => sr < sc,
        Uplo::Lower => sr > sc,
    };
    if stored {
        a[sc * lda + sr]
    } else {
        T::zero()
    }
}

/// TRMM: `B := alpha * op(A) * B` (Left, A is m×m) or
/// `B := alpha * B * op(A)` (Right, A is n×n). Naive reference.
#[allow(clippy::too_many_arguments)]
pub fn trmm_ref<T: Scalar>(
    side: Side,
    uplo: Uplo,
    ta: Trans,
    diag: Diag,
    m: usize,
    n: usize,
    alpha: T,
    a: &[T],
    lda: usize,
    b: &mut [T],
    ldb: usize,
) {
    match side {
        Side::Left => {
            // column by column: b_col := alpha * op(A) * b_col
            let mut tmp = vec![T::zero(); m];
            for j in 0..n {
                for i in 0..m {
                    let mut acc = T::zero();
                    for p in 0..m {
                        let av = tri_elem(a, lda, uplo, ta, diag, i, p);
                        if av != T::zero() {
                            acc += av * b[j * ldb + p];
                        }
                    }
                    tmp[i] = alpha * acc;
                }
                for i in 0..m {
                    b[j * ldb + i] = tmp[i];
                }
            }
        }
        Side::Right => {
            // row by row: b_row := alpha * b_row * op(A)
            let mut tmp = vec![T::zero(); n];
            for i in 0..m {
                for j in 0..n {
                    let mut acc = T::zero();
                    for p in 0..n {
                        let av = tri_elem(a, lda, uplo, ta, diag, p, j);
                        if av != T::zero() {
                            acc += b[p * ldb + i] * av;
                        }
                    }
                    tmp[j] = alpha * acc;
                }
                for j in 0..n {
                    b[j * ldb + i] = tmp[j];
                }
            }
        }
    }
}

/// TRSM: solve `op(A) * X = alpha * B` (Left) or `X * op(A) = alpha * B`
/// (Right), overwriting B with X. Naive forward/back substitution.
#[allow(clippy::too_many_arguments)]
pub fn trsm_ref<T: Scalar>(
    side: Side,
    uplo: Uplo,
    ta: Trans,
    diag: Diag,
    m: usize,
    n: usize,
    alpha: T,
    a: &[T],
    lda: usize,
    b: &mut [T],
    ldb: usize,
) {
    // scale RHS by alpha first
    for j in 0..n {
        for i in 0..m {
            let v = b[j * ldb + i];
            b[j * ldb + i] = alpha * v;
        }
    }
    // op(A) acts upper-triangular?
    let op_upper = matches!(
        (uplo, ta),
        (Uplo::Upper, Trans::No) | (Uplo::Lower, Trans::Yes)
    );
    match side {
        Side::Left => {
            // solve op(A) x = rhs per column
            for j in 0..n {
                if op_upper {
                    // back substitution
                    for ii in (0..m).rev() {
                        let mut acc = b[j * ldb + ii];
                        for p in ii + 1..m {
                            acc -= tri_elem(a, lda, uplo, ta, diag, ii, p) * b[j * ldb + p];
                        }
                        let d = tri_elem(a, lda, uplo, ta, diag, ii, ii);
                        b[j * ldb + ii] = acc / d;
                    }
                } else {
                    // forward substitution
                    for ii in 0..m {
                        let mut acc = b[j * ldb + ii];
                        for p in 0..ii {
                            acc -= tri_elem(a, lda, uplo, ta, diag, ii, p) * b[j * ldb + p];
                        }
                        let d = tri_elem(a, lda, uplo, ta, diag, ii, ii);
                        b[j * ldb + ii] = acc / d;
                    }
                }
            }
        }
        Side::Right => {
            // solve x op(A) = rhs per row: column jj of x depends on
            // columns p<jj (op upper: forward over columns) or p>jj
            for i in 0..m {
                if op_upper {
                    for jj in 0..n {
                        let mut acc = b[jj * ldb + i];
                        for p in 0..jj {
                            acc -= b[p * ldb + i] * tri_elem(a, lda, uplo, ta, diag, p, jj);
                        }
                        let d = tri_elem(a, lda, uplo, ta, diag, jj, jj);
                        b[jj * ldb + i] = acc / d;
                    }
                } else {
                    for jj in (0..n).rev() {
                        let mut acc = b[jj * ldb + i];
                        for p in jj + 1..n {
                            acc -= b[p * ldb + i] * tri_elem(a, lda, uplo, ta, diag, p, jj);
                        }
                        let d = tri_elem(a, lda, uplo, ta, diag, jj, jj);
                        b[jj * ldb + i] = acc / d;
                    }
                }
            }
        }
    }
}

// ------------------------------------------------------------------
// packed macro-kernels

use super::sy::DIAG_NB;

/// Densify the logical `db×db` diagonal block of op(A) at offset `d0`
/// into `td` (column-major, ld `db`): zero outside the triangle, unit
/// diagonal applied. Only the stored triangle of `a` is read.
#[allow(clippy::too_many_arguments)]
fn densify_tri<T: Scalar>(
    td: &mut [T],
    a: &[T],
    lda: usize,
    uplo: Uplo,
    ta: Trans,
    diag: Diag,
    d0: usize,
    db: usize,
) {
    for jj in 0..db {
        for ii in 0..db {
            td[jj * db + ii] = tri_elem(a, lda, uplo, ta, diag, d0 + ii, d0 + jj);
        }
    }
}

/// Does op(A) act as an upper triangle?
fn op_is_upper(uplo: Uplo, ta: Trans) -> bool {
    matches!((uplo, ta), (Uplo::Upper, Trans::No) | (Uplo::Lower, Trans::Yes))
}

/// Packed TRMM, same semantics as [`trmm_ref`].
#[allow(clippy::too_many_arguments)]
pub fn trmm_packed<T: Scalar>(
    side: Side,
    uplo: Uplo,
    ta: Trans,
    diag: Diag,
    m: usize,
    n: usize,
    alpha: T,
    a: &[T],
    lda: usize,
    b: &mut [T],
    ldb: usize,
) {
    trmm_packed_nb(DIAG_NB, side, uplo, ta, diag, m, n, alpha, a, lda, b, ldb)
}

/// [`trmm_packed`] with an explicit diagonal-block size.
#[doc(hidden)]
#[allow(clippy::too_many_arguments)]
pub fn trmm_packed_nb<T: Scalar>(
    nb: usize,
    side: Side,
    uplo: Uplo,
    ta: Trans,
    diag: Diag,
    m: usize,
    n: usize,
    alpha: T,
    a: &[T],
    lda: usize,
    b: &mut [T],
    ldb: usize,
) {
    if m == 0 || n == 0 {
        return;
    }
    if alpha == T::zero() {
        for j in 0..n {
            for i in 0..m {
                b[j * ldb + i] = T::zero();
            }
        }
        return;
    }
    let nb = nb.max(1);
    let op_upper = op_is_upper(uplo, ta);
    // One full copy of B up front: every block row/column of the result
    // is then an independent pair of GEMMs out of `w`, with no
    // read-after-write hazards inside `b`.
    let mut w = take_buf::<T>(m * n);
    for j in 0..n {
        w[j * m..j * m + m].copy_from_slice(&b[j * ldb..j * ldb + m]);
    }
    let na = match side {
        Side::Left => m,
        Side::Right => n,
    };
    let mut td = take_buf::<T>(nb.min(na) * nb.min(na));
    match side {
        Side::Left => {
            // B_i := alpha * (T_ii w_i + op(A)[i, rest] w_rest)
            let mut i0 = 0;
            while i0 < m {
                let ib = nb.min(m - i0);
                let i1 = i0 + ib;
                densify_tri(&mut td[..ib * ib], a, lda, uplo, ta, diag, i0, ib);
                gemm_packed(
                    Trans::No, Trans::No, ib, n, ib, alpha, &td[..ib * ib], ib, &w[i0..], m,
                    T::zero(), &mut b[i0..], ldb,
                );
                if op_upper && i1 < m {
                    let aoff = match ta {
                        Trans::No => i1 * lda + i0,
                        Trans::Yes => i0 * lda + i1,
                    };
                    gemm_packed(
                        ta, Trans::No, ib, n, m - i1, alpha, &a[aoff..], lda, &w[i1..], m,
                        T::one(), &mut b[i0..], ldb,
                    );
                }
                if !op_upper && i0 > 0 {
                    let aoff = match ta {
                        Trans::No => i0,
                        Trans::Yes => i0 * lda,
                    };
                    gemm_packed(
                        ta, Trans::No, ib, n, i0, alpha, &a[aoff..], lda, &w, m, T::one(),
                        &mut b[i0..], ldb,
                    );
                }
                i0 = i1;
            }
        }
        Side::Right => {
            // B_j := alpha * (w_j T_jj + w_rest op(A)[rest, j])
            let mut j0 = 0;
            while j0 < n {
                let jb = nb.min(n - j0);
                let j1 = j0 + jb;
                densify_tri(&mut td[..jb * jb], a, lda, uplo, ta, diag, j0, jb);
                gemm_packed(
                    Trans::No, Trans::No, m, jb, jb, alpha, &w[j0 * m..], m, &td[..jb * jb], jb,
                    T::zero(), &mut b[j0 * ldb..], ldb,
                );
                if op_upper && j0 > 0 {
                    let (boff, tb_g) = match ta {
                        Trans::No => (j0 * lda, Trans::No),
                        Trans::Yes => (j0, Trans::Yes),
                    };
                    gemm_packed(
                        Trans::No, tb_g, m, jb, j0, alpha, &w, m, &a[boff..], lda, T::one(),
                        &mut b[j0 * ldb..], ldb,
                    );
                }
                if !op_upper && j1 < n {
                    let (boff, tb_g) = match ta {
                        Trans::No => (j0 * lda + j1, Trans::No),
                        Trans::Yes => (j1 * lda + j0, Trans::Yes),
                    };
                    gemm_packed(
                        Trans::No, tb_g, m, jb, n - j1, alpha, &w[j1 * m..], m, &a[boff..], lda,
                        T::one(), &mut b[j0 * ldb..], ldb,
                    );
                }
                j0 = j1;
            }
        }
    }
    give_buf(td);
    give_buf(w);
}

/// Packed TRSM, same semantics as [`trsm_ref`]: blocked forward/back
/// substitution with rank-NB GEMM trailing updates.
#[allow(clippy::too_many_arguments)]
pub fn trsm_packed<T: Scalar>(
    side: Side,
    uplo: Uplo,
    ta: Trans,
    diag: Diag,
    m: usize,
    n: usize,
    alpha: T,
    a: &[T],
    lda: usize,
    b: &mut [T],
    ldb: usize,
) {
    trsm_packed_nb(DIAG_NB, side, uplo, ta, diag, m, n, alpha, a, lda, b, ldb)
}

/// [`trsm_packed`] with an explicit diagonal-block size.
#[doc(hidden)]
#[allow(clippy::too_many_arguments)]
pub fn trsm_packed_nb<T: Scalar>(
    nb: usize,
    side: Side,
    uplo: Uplo,
    ta: Trans,
    diag: Diag,
    m: usize,
    n: usize,
    alpha: T,
    a: &[T],
    lda: usize,
    b: &mut [T],
    ldb: usize,
) {
    if m == 0 || n == 0 {
        return;
    }
    // Scale the RHS once; the solve then runs with alpha = 1.
    for j in 0..n {
        for i in 0..m {
            let idx = j * ldb + i;
            b[idx] = if alpha == T::zero() { T::zero() } else { alpha * b[idx] };
        }
    }
    if alpha == T::zero() {
        return; // X = 0 solves op(A) X = 0 exactly
    }
    let nb = nb.max(1);
    let op_upper = op_is_upper(uplo, ta);
    match side {
        Side::Left => {
            let nblk = m.div_ceil(nb);
            let bs = nb.min(m);
            let mut td = take_buf::<T>(bs * bs);
            let mut w = take_buf::<T>(bs * n);
            for step in 0..nblk {
                // forward over row blocks for a lower op(A), backward
                // for upper — the direction of substitution.
                let bi = if op_upper { nblk - 1 - step } else { step };
                let p0 = bi * nb;
                let pb = nb.min(m - p0);
                let p1 = p0 + pb;
                densify_tri(&mut td[..pb * pb], a, lda, uplo, ta, diag, p0, pb);
                // Solve T_pp X_p = B_p per RHS column (column-sweep
                // substitution over the densified, branch-free block).
                for j in 0..n {
                    let x = &mut b[j * ldb + p0..j * ldb + p0 + pb];
                    if !op_upper {
                        for q in 0..pb {
                            x[q] /= td[q * pb + q];
                            let xq = x[q];
                            for r in q + 1..pb {
                                x[r] -= xq * td[q * pb + r];
                            }
                        }
                    } else {
                        for q in (0..pb).rev() {
                            x[q] /= td[q * pb + q];
                            let xq = x[q];
                            for r in 0..q {
                                x[r] -= xq * td[q * pb + r];
                            }
                        }
                    }
                }
                // X_p panel copy: the trailing GEMM reads it while
                // writing other rows of the same buffer.
                for j in 0..n {
                    w[j * pb..j * pb + pb].copy_from_slice(&b[j * ldb + p0..j * ldb + p0 + pb]);
                }
                if !op_upper && p1 < m {
                    let aoff = match ta {
                        Trans::No => p0 * lda + p1,
                        Trans::Yes => p1 * lda + p0,
                    };
                    gemm_packed(
                        ta, Trans::No, m - p1, n, pb, -T::one(), &a[aoff..], lda, &w, pb,
                        T::one(), &mut b[p1..], ldb,
                    );
                }
                if op_upper && p0 > 0 {
                    let aoff = match ta {
                        Trans::No => p0 * lda,
                        Trans::Yes => p0,
                    };
                    gemm_packed(
                        ta, Trans::No, p0, n, pb, -T::one(), &a[aoff..], lda, &w, pb, T::one(),
                        b, ldb,
                    );
                }
            }
            give_buf(w);
            give_buf(td);
        }
        Side::Right => {
            let nblk = n.div_ceil(nb);
            let bs = nb.min(n);
            let mut td = take_buf::<T>(bs * bs);
            let mut w = take_buf::<T>(m * bs);
            for step in 0..nblk {
                // X op(A) = B solves columns forward when op(A) is
                // upper, backward when lower.
                let bj = if op_upper { step } else { nblk - 1 - step };
                let p0 = bj * nb;
                let pb = nb.min(n - p0);
                let p1 = p0 + pb;
                densify_tri(&mut td[..pb * pb], a, lda, uplo, ta, diag, p0, pb);
                // Solve X_p T_pp = B_p by sweeping the block's columns;
                // each axpy runs over a contiguous m-vector.
                if op_upper {
                    for q in 0..pb {
                        let (head, tail) = b.split_at_mut((p0 + q) * ldb);
                        let colq = &mut tail[..m];
                        for r in 0..q {
                            let colr = &head[(p0 + r) * ldb..(p0 + r) * ldb + m];
                            let t = td[q * pb + r];
                            for (x, &y) in colq.iter_mut().zip(colr) {
                                *x -= t * y;
                            }
                        }
                        let d = td[q * pb + q];
                        for x in colq.iter_mut() {
                            *x /= d;
                        }
                    }
                } else {
                    for q in (0..pb).rev() {
                        let split = (p0 + q) * ldb + m;
                        let (head, tail) = b.split_at_mut(split);
                        let colq = &mut head[(p0 + q) * ldb..];
                        for r in q + 1..pb {
                            let off = (p0 + r) * ldb - split;
                            let colr = &tail[off..off + m];
                            let t = td[q * pb + r];
                            for (x, &y) in colq.iter_mut().zip(colr) {
                                *x -= t * y;
                            }
                        }
                        let d = td[q * pb + q];
                        for x in colq.iter_mut() {
                            *x /= d;
                        }
                    }
                }
                for q in 0..pb {
                    w[q * m..q * m + m].copy_from_slice(&b[(p0 + q) * ldb..(p0 + q) * ldb + m]);
                }
                if op_upper && p1 < n {
                    let (boff, tb_g) = match ta {
                        Trans::No => (p1 * lda + p0, Trans::No),
                        Trans::Yes => (p0 * lda + p1, Trans::Yes),
                    };
                    gemm_packed(
                        Trans::No, tb_g, m, n - p1, pb, -T::one(), &w, m, &a[boff..], lda,
                        T::one(), &mut b[p1 * ldb..], ldb,
                    );
                }
                if !op_upper && p0 > 0 {
                    let (boff, tb_g) = match ta {
                        Trans::No => (p0, Trans::No),
                        Trans::Yes => (p0 * lda, Trans::Yes),
                    };
                    gemm_packed(
                        Trans::No, tb_g, m, p0, pb, -T::one(), &w, m, &a[boff..], lda, T::one(),
                        b, ldb,
                    );
                }
            }
            give_buf(w);
            give_buf(td);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hostblas::gemm::gemm_ref;
    use crate::util::prng::Prng;

    fn rand_tri(rng: &mut Prng, n: usize, uplo: Uplo) -> Vec<f64> {
        // well-conditioned triangle: strong diagonal
        let mut a = vec![0.0; n * n];
        for c in 0..n {
            for r in 0..n {
                let stored = match uplo {
                    Uplo::Upper => r <= c,
                    Uplo::Lower => r >= c,
                };
                if stored {
                    a[c * n + r] =
                        if r == c { 3.0 + rng.next_f64() } else { rng.range_f64(-0.5, 0.5) };
                } else {
                    a[c * n + r] = f64::NAN; // must never be read
                }
            }
        }
        a
    }

    fn dense_of_tri(a: &[f64], n: usize, uplo: Uplo, ta: Trans, diag: Diag) -> Vec<f64> {
        let mut d = vec![0.0; n * n];
        for c in 0..n {
            for r in 0..n {
                d[c * n + r] = tri_elem(a, n, uplo, ta, diag, r, c);
            }
        }
        d
    }

    fn close(a: &[f64], b: &[f64], tol: f64) -> bool {
        a.iter().zip(b).all(|(x, y)| (x - y).abs() <= tol * x.abs().max(y.abs()).max(1.0))
    }

    #[test]
    fn trmm_matches_dense_gemm_all_variants() {
        let mut rng = Prng::new(101);
        let (m, n) = (9, 7);
        for &side in &[Side::Left, Side::Right] {
            for &uplo in &[Uplo::Upper, Uplo::Lower] {
                for &ta in &[Trans::No, Trans::Yes] {
                    for &diag in &[Diag::NonUnit, Diag::Unit] {
                        let na = if side == Side::Left { m } else { n };
                        let a = rand_tri(&mut rng, na, uplo);
                        let mut b = vec![0.0; m * n];
                        rng.fill_f64(&mut b, -1.0, 1.0);
                        let b0 = b.clone();
                        trmm_ref(side, uplo, ta, diag, m, n, 1.5, &a, na, &mut b, m);
                        // dense check
                        let ad = dense_of_tri(&a, na, uplo, ta, diag);
                        let mut expect = vec![0.0; m * n];
                        match side {
                            Side::Left => gemm_ref(
                                Trans::No, Trans::No, m, n, m, 1.5, &ad, na, &b0, m, 0.0,
                                &mut expect, m,
                            ),
                            Side::Right => gemm_ref(
                                Trans::No, Trans::No, m, n, n, 1.5, &b0, m, &ad, na, 0.0,
                                &mut expect, m,
                            ),
                        }
                        assert!(
                            close(&b, &expect, 1e-10),
                            "trmm {side:?} {uplo:?} {ta:?} {diag:?}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn trsm_inverts_trmm_all_variants() {
        let mut rng = Prng::new(202);
        let (m, n) = (8, 6);
        for &side in &[Side::Left, Side::Right] {
            for &uplo in &[Uplo::Upper, Uplo::Lower] {
                for &ta in &[Trans::No, Trans::Yes] {
                    for &diag in &[Diag::NonUnit, Diag::Unit] {
                        let na = if side == Side::Left { m } else { n };
                        let a = rand_tri(&mut rng, na, uplo);
                        let mut x = vec![0.0; m * n];
                        rng.fill_f64(&mut x, -1.0, 1.0);
                        let x0 = x.clone();
                        // b = op(A)·x (or x·op(A)); then solving must return x
                        trmm_ref(side, uplo, ta, diag, m, n, 1.0, &a, na, &mut x, m);
                        trsm_ref(side, uplo, ta, diag, m, n, 1.0, &a, na, &mut x, m);
                        assert!(
                            close(&x, &x0, 1e-9),
                            "trsm·trmm ≠ id: {side:?} {uplo:?} {ta:?} {diag:?}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn trsm_scales_by_alpha() {
        let mut rng = Prng::new(7);
        let n = 5;
        let a = rand_tri(&mut rng, n, Uplo::Upper);
        let mut b1 = vec![0.0; n * n];
        rng.fill_f64(&mut b1, -1.0, 1.0);
        let mut b2 = b1.clone();
        trsm_ref(Side::Left, Uplo::Upper, Trans::No, Diag::NonUnit, n, n, 2.0, &a, n, &mut b1, n);
        trsm_ref(Side::Left, Uplo::Upper, Trans::No, Diag::NonUnit, n, n, 1.0, &a, n, &mut b2, n);
        let twice: Vec<f64> = b2.iter().map(|x| 2.0 * x).collect();
        assert!(close(&b1, &twice, 1e-12));
    }

    #[test]
    fn unit_diag_ignores_stored_diagonal() {
        // stored diagonal set to NaN-free junk; Unit must not read it
        let n = 4;
        let mut a = vec![0.0; n * n];
        for c in 0..n {
            for r in 0..=c {
                a[c * n + r] = if r == c { 999.0 } else { 0.25 };
            }
        }
        let mut b = vec![1.0f64; n];
        trmm_ref(Side::Left, Uplo::Upper, Trans::No, Diag::Unit, n, 1, 1.0, &a, n, &mut b, n);
        // row 3 (last): only diagonal (unit) contributes = 1.0
        assert_eq!(b[3], 1.0);
        // row 0: 1 + 0.25*3 = 1.75
        assert!((b[0] - 1.75).abs() < 1e-12);
    }
}
