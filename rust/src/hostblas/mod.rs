//! Pure-Rust host BLAS (system S14 in DESIGN.md).
//!
//! Three roles:
//! 1. **Correctness oracle** — `*_ref` naive kernels are the ground truth
//!    every other execution path (blocked, PJRT/Pallas, full runtime) is
//!    tested against.
//! 2. **CPU worker kernel** — [`threaded::gemm_mt`] / [`gemm::gemm_blocked`]
//!    execute tasks assigned to the CPU compute thread (paper §IV-C.2).
//! 3. **Baseline** — the single-threaded CPU numbers in the Table VI
//!    application speedups.

pub mod gemm;
pub mod sy;
pub mod threaded;
pub mod tri;

pub use gemm::{gemm_blocked, gemm_ref};
pub use sy::{symm_ref, syr2k_ref, syrk_ref};
pub use threaded::gemm_mt;
pub use tri::{trmm_ref, trsm_ref};
