//! Pure-Rust host BLAS (system S14 in DESIGN.md) — the packed
//! register-tiled kernel engine plus its naive oracles.
//!
//! Three roles:
//! 1. **Correctness oracle** — `*_ref` naive kernels are the ground truth
//!    every other execution path (packed, PJRT/Pallas, full runtime) is
//!    tested against. They are *test-only*: nothing in the hot path
//!    dispatches to them anymore.
//! 2. **CPU worker kernel** — the packed engine ([`gemm::gemm_packed`],
//!    the `*_packed` macro-kernels, [`threaded::gemm_mt`]) executes
//!    every tile task in the real engine (paper §IV-C.2). Structure:
//!    [`pack`] holds the per-thread pack scratch, [`gemm`] the BLIS-style
//!    blocked loops + MR×NR micro-kernel, [`sy`]/[`tri`] the symmetric
//!    and triangular macro-kernels that decompose into panel GEMMs,
//!    [`tune`] the startup blocking probe (feature `autotune`).
//! 3. **Baseline** — the single-threaded CPU numbers in the Table VI
//!    application speedups.
//!
//! Measured throughput for all of this lives in EXPERIMENTS.md §Perf /
//! BENCH_kernels.json (regenerate with `cargo bench --bench
//! kernel_gflops`).

pub mod gemm;
pub mod pack;
pub mod sy;
pub mod threaded;
pub mod tri;
pub mod tune;

pub use gemm::{gemm_blocked, gemm_packed, gemm_packed_with, gemm_ref};
pub use pack::{give_buf, take_buf, PackBuf};
pub use sy::{symm_packed, symm_ref, syr2k_packed, syr2k_ref, syrk_packed, syrk_ref};
pub use threaded::{gemm_mt, gemm_mt_with_cutoff, mt_flop_cutoff, MT_FLOP_CUTOFF};
pub use tri::{trmm_packed, trmm_ref, trsm_packed, trsm_ref};
pub use tune::{block_dims, BlockDims, DEFAULT_DIMS};
