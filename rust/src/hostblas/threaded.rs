//! Multithreaded host GEMM for the CPU compute thread (paper §IV-C.2:
//! "The CPU cores dequeue one task at each time and solve the task with
//! a multithreaded BLAS kernel, where the tile is further factorized").
//!
//! The tile is split into column panels, one per worker thread; each
//! panel runs the blocked single-thread kernel. std::thread::scope keeps
//! lifetimes simple — these are short-lived compute bursts, not a pool.

use super::gemm::gemm_blocked;
use crate::api::types::{Scalar, Trans};

/// Multithreaded GEMM with [`gemm_blocked`] semantics, splitting the N
/// dimension across up to `threads` workers.
#[allow(clippy::too_many_arguments)]
pub fn gemm_mt<T: Scalar>(
    threads: usize,
    ta: Trans,
    tb: Trans,
    m: usize,
    n: usize,
    k: usize,
    alpha: T,
    a: &[T],
    lda: usize,
    b: &[T],
    ldb: usize,
    beta: T,
    c: &mut [T],
    ldc: usize,
) {
    let threads = threads.max(1).min(n.max(1));
    if threads == 1 || n < 64 {
        gemm_blocked(ta, tb, m, n, k, alpha, a, lda, b, ldb, beta, c, ldc);
        return;
    }
    // Split C's columns into `threads` contiguous panels. Each panel is a
    // disjoint &mut slice of C, so this is safe-Rust parallelism.
    let cols_per = n.div_ceil(threads);
    // Panel boundaries in elements of C (column-major: col j starts at j*ldc).
    let mut panels: Vec<(usize, usize, &mut [T])> = Vec::new(); // (j0, ncols, slice)
    let mut rest = c;
    let mut consumed_cols = 0usize;
    for t in 0..threads {
        let j0 = t * cols_per;
        if j0 >= n {
            break;
        }
        let ncols = cols_per.min(n - j0);
        let split_at = ncols * ldc;
        // `rest` currently starts at column `consumed_cols`
        debug_assert_eq!(consumed_cols, j0);
        if rest.len() >= split_at && t + 1 < threads && j0 + ncols < n {
            let (head, tail) = rest.split_at_mut(split_at);
            panels.push((j0, ncols, head));
            rest = tail;
            consumed_cols += ncols;
        } else {
            // last panel takes the remainder
            let len = rest.len();
            panels.push((j0, n - j0, &mut rest[..len]));
            break;
        }
    }
    std::thread::scope(|scope| {
        for (j0, ncols, cpanel) in panels {
            scope.spawn(move || {
                // B panel: op(B)[:, j0..j0+ncols]
                match tb {
                    Trans::No => {
                        let boff = j0 * ldb;
                        gemm_blocked(
                            ta,
                            tb,
                            m,
                            ncols,
                            k,
                            alpha,
                            a,
                            lda,
                            &b[boff..],
                            ldb,
                            beta,
                            cpanel,
                            ldc,
                        );
                    }
                    Trans::Yes => {
                        // op(B)=Bᵀ: columns of op(B) are rows of B; offset rows
                        gemm_blocked(
                            ta,
                            tb,
                            m,
                            ncols,
                            k,
                            alpha,
                            a,
                            lda,
                            &b[j0..],
                            ldb,
                            beta,
                            cpanel,
                            ldc,
                        );
                    }
                }
            });
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hostblas::gemm::gemm_ref;
    use crate::util::prng::Prng;

    fn close(a: &[f64], b: &[f64]) -> bool {
        a.iter().zip(b).all(|(x, y)| (x - y).abs() <= 1e-9 * x.abs().max(y.abs()).max(1.0))
    }

    #[test]
    fn mt_matches_ref_nn_and_nt() {
        let mut rng = Prng::new(31);
        for &(ta, tb) in &[
            (Trans::No, Trans::No),
            (Trans::No, Trans::Yes),
            (Trans::Yes, Trans::No),
            (Trans::Yes, Trans::Yes),
        ] {
            let (m, n, k) = (65, 200, 33);
            let (ar, ac) = if ta == Trans::No { (m, k) } else { (k, m) };
            let (br, bc) = if tb == Trans::No { (k, n) } else { (n, k) };
            let mut a = vec![0.0; ar * ac];
            let mut b = vec![0.0; br * bc];
            rng.fill_f64(&mut a, -1.0, 1.0);
            rng.fill_f64(&mut b, -1.0, 1.0);
            let mut c0 = vec![0.0; m * n];
            rng.fill_f64(&mut c0, -1.0, 1.0);
            let mut c_ref = c0.clone();
            let mut c_mt = c0.clone();
            gemm_ref(ta, tb, m, n, k, 0.9, &a, ar, &b, br, 1.1, &mut c_ref, m);
            gemm_mt(4, ta, tb, m, n, k, 0.9, &a, ar, &b, br, 1.1, &mut c_mt, m);
            assert!(close(&c_ref, &c_mt), "ta={ta:?} tb={tb:?}");
        }
    }

    #[test]
    fn mt_small_n_falls_back() {
        let a = vec![1.0; 4];
        let b = vec![1.0; 2];
        let mut c = vec![0.0; 2];
        gemm_mt(8, Trans::No, Trans::No, 2, 1, 2, 1.0, &a, 2, &b, 2, 0.0, &mut c, 2);
        assert_eq!(c, vec![2.0, 2.0]);
    }

    #[test]
    fn mt_thread_counts_agree() {
        let mut rng = Prng::new(37);
        let (m, n, k) = (48, 130, 48);
        let mut a = vec![0.0; m * k];
        let mut b = vec![0.0; k * n];
        rng.fill_f64(&mut a, -1.0, 1.0);
        rng.fill_f64(&mut b, -1.0, 1.0);
        let mut c1 = vec![0.0; m * n];
        let mut c2 = vec![0.0; m * n];
        let mut c3 = vec![0.0; m * n];
        gemm_mt(1, Trans::No, Trans::No, m, n, k, 1.0, &a, m, &b, k, 0.0, &mut c1, m);
        gemm_mt(3, Trans::No, Trans::No, m, n, k, 1.0, &a, m, &b, k, 0.0, &mut c2, m);
        gemm_mt(16, Trans::No, Trans::No, m, n, k, 1.0, &a, m, &b, k, 0.0, &mut c3, m);
        assert!(close(&c1, &c2));
        assert!(close(&c1, &c3));
    }
}
