//! Multithreaded host GEMM for the CPU compute thread (paper §IV-C.2:
//! "The CPU cores dequeue one task at each time and solve the task with
//! a multithreaded BLAS kernel, where the tile is further factorized").
//!
//! Work-centric 2D partitioning (the Stream-K framing, arXiv
//! 2301.03598): C is cut into a `tr × tc` grid chosen to balance the
//! per-cell output area, and each cell runs the packed single-thread
//! engine independently — every worker packs exactly the A/B panels its
//! cell consumes, so there is no inter-thread pack sharing to
//! synchronize. The seed's 1D column split left tall-skinny C (large m,
//! small n) entirely serial; the 2D grid splits whichever dimensions
//! have the work.
//!
//! The serial cutoff is flop-based: a 2·m·n·k budget below the cutoff
//! is cheaper to run in-place than to fork for (see EXPERIMENTS.md
//! §Perf for the sizing rationale). [`MT_FLOP_CUTOFF`] is the built-in
//! *default*; the effective process-wide value ([`mt_flop_cutoff`])
//! can be overridden with `BLASX_MT_CUTOFF`, and the adaptive
//! dispatcher (`crate::dispatch`) overrides it per call via
//! [`gemm_mt_with_cutoff`] / `RunConfig::mt_cutoff`.
//!
//! Cells execute on the process-wide persistent
//! [`crate::runtime::KernelPool`] (plus the submitting thread, which
//! participates): pool threads are long-lived, so each cell's
//! thread-local `PackBuf` and workspace free-list survive across
//! kernel invocations and steady-state forked GEMM allocates nothing —
//! the same zero-allocation guarantee the serial path has always had.
//! (The seed used fresh `std::thread::scope` threads per call, whose
//! empty thread-locals forfeited pack reuse on exactly the calls big
//! enough to fork.)

use super::gemm::{gemm_packed, gemm_packed_ptr};
use super::tune::block_dims;
use crate::api::types::{Scalar, Trans};
use crate::runtime::KernelPool;

/// Minimum flops (2·m·n·k) before forking pays for itself — the
/// built-in default of the dispatch table (see [`mt_flop_cutoff`] for
/// the effective value).
pub const MT_FLOP_CUTOFF: f64 = 8.4e6; // ≈ 2·160³

/// Parse a `BLASX_MT_CUTOFF`-style override: any positive float (`2e6`,
/// `500000`) replaces the default; absent, empty, non-numeric or
/// non-positive values keep [`MT_FLOP_CUTOFF`]. Pure so the policy is
/// testable without mutating process-global environment.
fn parse_cutoff(env: Option<&str>) -> f64 {
    env.and_then(|s| s.trim().parse::<f64>().ok())
        .filter(|&v| v.is_finite() && v > 0.0)
        .unwrap_or(MT_FLOP_CUTOFF)
}

/// The process-wide effective serial/fork cutoff: [`MT_FLOP_CUTOFF`]
/// unless `BLASX_MT_CUTOFF` overrides it. Read once (the env is not
/// re-consulted after the first call); per-call overrides go through
/// [`gemm_mt_with_cutoff`].
pub fn mt_flop_cutoff() -> f64 {
    static CUTOFF: std::sync::OnceLock<f64> = std::sync::OnceLock::new();
    *CUTOFF.get_or_init(|| parse_cutoff(std::env::var("BLASX_MT_CUTOFF").ok().as_deref()))
}

/// A raw C pointer that may cross into the kernel pool's threads. Each
/// submitted cell derives from it a pointer to a *disjoint* sub-block
/// of C, so no element is ever reachable from two threads.
struct SendPtr<T>(*mut T);
unsafe impl<T> Send for SendPtr<T> {}
unsafe impl<T> Sync for SendPtr<T> {}

/// `(start, len)` of chunk `idx` when `total` splits into `parts`.
fn chunk(total: usize, parts: usize, idx: usize) -> (usize, usize) {
    let per = total.div_ceil(parts);
    let lo = (idx * per).min(total);
    (lo, per.min(total - lo))
}

/// Choose a `tr × tc = threads` grid minimizing the largest cell area
/// (primary) and cell aspect skew (tie-break, for pack reuse).
fn grid_for(threads: usize, m: usize, n: usize) -> (usize, usize) {
    let mut best = (1, threads);
    let mut best_score = (usize::MAX, usize::MAX);
    for tr in 1..=threads {
        if threads % tr != 0 {
            continue;
        }
        let tc = threads / tr;
        let cm = m.div_ceil(tr);
        let cn = n.div_ceil(tc);
        let score = (cm * cn, cm.abs_diff(cn));
        if score < best_score {
            best_score = score;
            best = (tr, tc);
        }
    }
    best
}

/// Multithreaded GEMM with [`gemm_packed`] semantics, partitioning C's
/// M×N output plane across up to `threads` workers. Uses the
/// process-wide serial/fork cutoff ([`mt_flop_cutoff`]).
#[allow(clippy::too_many_arguments)]
pub fn gemm_mt<T: Scalar>(
    threads: usize,
    ta: Trans,
    tb: Trans,
    m: usize,
    n: usize,
    k: usize,
    alpha: T,
    a: &[T],
    lda: usize,
    b: &[T],
    ldb: usize,
    beta: T,
    c: &mut [T],
    ldc: usize,
) {
    gemm_mt_with_cutoff(
        threads,
        mt_flop_cutoff(),
        ta,
        tb,
        m,
        n,
        k,
        alpha,
        a,
        lda,
        b,
        ldb,
        beta,
        c,
        ldc,
    );
}

/// [`gemm_mt`] with an explicit serial/fork cutoff — the adaptive
/// dispatcher's per-call doorway (`RunConfig::mt_cutoff`).
#[allow(clippy::too_many_arguments)]
pub fn gemm_mt_with_cutoff<T: Scalar>(
    threads: usize,
    cutoff: f64,
    ta: Trans,
    tb: Trans,
    m: usize,
    n: usize,
    k: usize,
    alpha: T,
    a: &[T],
    lda: usize,
    b: &[T],
    ldb: usize,
    beta: T,
    c: &mut [T],
    ldc: usize,
) {
    if m == 0 || n == 0 {
        return;
    }
    let flops = 2.0 * m as f64 * n as f64 * k as f64;
    let threads = threads.max(1).min(m * n);
    // alpha == 0 joins the serial path: BLAS says A/B are unreferenced
    // then, so the fork path's &a[aoff..] shrink would be the only
    // reader — and a legally undersized A/B would make it panic.
    if threads == 1 || alpha == T::zero() || flops < cutoff {
        gemm_packed(ta, tb, m, n, k, alpha, a, lda, b, ldb, beta, c, ldc);
        return;
    }
    // Hard asserts (not debug): the sole safety boundary before C's
    // pointer crosses into the spawned cells.
    assert!(ldc >= m, "ldc must cover C's rows");
    assert!(c.len() >= (n - 1) * ldc + m, "C buffer too small");
    let (tr, tc) = grid_for(threads, m, n);
    let dims = block_dims(T::DTYPE);
    let cptr = SendPtr(c.as_mut_ptr());
    {
        let cptr = &cptr;
        let mut cells: Vec<Box<dyn FnOnce() + Send + '_>> = Vec::with_capacity(tr * tc);
        for ri in 0..tr {
            for cj in 0..tc {
                cells.push(Box::new(move || {
                    let (i0, ib) = chunk(m, tr, ri);
                    let (j0, jb) = chunk(n, tc, cj);
                    if ib == 0 || jb == 0 {
                        return;
                    }
                    let aoff = match ta {
                        Trans::No => i0,
                        Trans::Yes => i0 * lda,
                    };
                    let boff = match tb {
                        Trans::No => j0 * ldb,
                        Trans::Yes => j0,
                    };
                    // SAFETY: cells are disjoint rectangles of C (chunk
                    // ranges never overlap across (ri, cj)), each within
                    // the extent covered by the caller's &mut slice; a/b
                    // are shared reads. k ≥ 1 here (k = 0 falls below
                    // the flop cutoff), so the a/b offsets stay in
                    // bounds for the shrunken views. The pool's scoped
                    // contract (KernelPool::run returns only after every
                    // cell completes) bounds all borrows to this call.
                    unsafe {
                        gemm_packed_ptr(
                            dims,
                            ta,
                            tb,
                            ib,
                            jb,
                            k,
                            alpha,
                            &a[aoff..],
                            lda,
                            &b[boff..],
                            ldb,
                            beta,
                            cptr.0.add(j0 * ldc + i0),
                            ldc,
                        );
                    }
                }));
            }
        }
        KernelPool::global().run(cells);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hostblas::gemm::gemm_ref;
    use crate::util::prng::Prng;

    fn close(a: &[f64], b: &[f64]) -> bool {
        a.iter().zip(b).all(|(x, y)| (x - y).abs() <= 1e-9 * x.abs().max(y.abs()).max(1.0))
    }

    #[test]
    fn mt_matches_ref_all_trans_combos() {
        let mut rng = Prng::new(31);
        for &(ta, tb) in &[
            (Trans::No, Trans::No),
            (Trans::No, Trans::Yes),
            (Trans::Yes, Trans::No),
            (Trans::Yes, Trans::Yes),
        ] {
            // sized just above MT_FLOP_CUTOFF so every trans combo
            // exercises the forked 2D path (and its a/b offsets)
            let (m, n, k) = (256, 260, 64);
            let (ar, ac) = if ta == Trans::No { (m, k) } else { (k, m) };
            let (br, bc) = if tb == Trans::No { (k, n) } else { (n, k) };
            let mut a = vec![0.0; ar * ac];
            let mut b = vec![0.0; br * bc];
            rng.fill_f64(&mut a, -1.0, 1.0);
            rng.fill_f64(&mut b, -1.0, 1.0);
            let mut c0 = vec![0.0; m * n];
            rng.fill_f64(&mut c0, -1.0, 1.0);
            let mut c_ref = c0.clone();
            let mut c_mt = c0.clone();
            gemm_ref(ta, tb, m, n, k, 0.9, &a, ar, &b, br, 1.1, &mut c_ref, m);
            gemm_mt(4, ta, tb, m, n, k, 0.9, &a, ar, &b, br, 1.1, &mut c_mt, m);
            assert!(close(&c_ref, &c_mt), "ta={ta:?} tb={tb:?}");
        }
    }

    #[test]
    fn mt_small_n_falls_back() {
        let a = vec![1.0; 4];
        let b = vec![1.0; 2];
        let mut c = vec![0.0; 2];
        gemm_mt(8, Trans::No, Trans::No, 2, 1, 2, 1.0, &a, 2, &b, 2, 0.0, &mut c, 2);
        assert_eq!(c, vec![2.0, 2.0]);
    }

    #[test]
    fn mt_tall_skinny_partitions_rows() {
        // The seed's `n < 64` fallback left this case serial; the 2D
        // grid must split rows and still agree with the oracle. The
        // problem is sized above MT_FLOP_CUTOFF so forking engages.
        let mut rng = Prng::new(41);
        let (m, n, k) = (2048, 8, 300);
        assert!(2.0 * (m * n * k) as f64 >= MT_FLOP_CUTOFF);
        let mut a = vec![0.0; m * k];
        let mut b = vec![0.0; k * n];
        rng.fill_f64(&mut a, -1.0, 1.0);
        rng.fill_f64(&mut b, -1.0, 1.0);
        let mut c0 = vec![0.0; m * n];
        rng.fill_f64(&mut c0, -1.0, 1.0);
        let mut c_ref = c0.clone();
        let mut c_mt = c0.clone();
        gemm_ref(Trans::No, Trans::No, m, n, k, 1.0, &a, m, &b, k, 0.5, &mut c_ref, m);
        gemm_mt(4, Trans::No, Trans::No, m, n, k, 1.0, &a, m, &b, k, 0.5, &mut c_mt, m);
        assert!(close(&c_ref, &c_mt));
    }

    #[test]
    fn mt_thread_counts_agree() {
        let mut rng = Prng::new(37);
        let (m, n, k) = (48, 130, 48);
        let mut a = vec![0.0; m * k];
        let mut b = vec![0.0; k * n];
        rng.fill_f64(&mut a, -1.0, 1.0);
        rng.fill_f64(&mut b, -1.0, 1.0);
        let mut c1 = vec![0.0; m * n];
        let mut c2 = vec![0.0; m * n];
        let mut c3 = vec![0.0; m * n];
        gemm_mt(1, Trans::No, Trans::No, m, n, k, 1.0, &a, m, &b, k, 0.0, &mut c1, m);
        gemm_mt(3, Trans::No, Trans::No, m, n, k, 1.0, &a, m, &b, k, 0.0, &mut c2, m);
        gemm_mt(16, Trans::No, Trans::No, m, n, k, 1.0, &a, m, &b, k, 0.0, &mut c3, m);
        assert!(close(&c1, &c2));
        assert!(close(&c1, &c3));
    }

    #[test]
    fn tall_skinny_stays_fixed_under_any_cutoff() {
        // Satellite regression: the tall-skinny serial-trap fix must
        // hold both at the default cutoff (forked 2D path) and under an
        // overridden cutoff that forces the opposite branch — both must
        // match the oracle, so a `BLASX_MT_CUTOFF` override can shift
        // the fork point but never the answer.
        let mut rng = Prng::new(43);
        let (m, n, k) = (2048, 8, 300);
        let flops = 2.0 * (m * n * k) as f64;
        let mut a = vec![0.0; m * k];
        let mut b = vec![0.0; k * n];
        rng.fill_f64(&mut a, -1.0, 1.0);
        rng.fill_f64(&mut b, -1.0, 1.0);
        let mut c0 = vec![0.0; m * n];
        rng.fill_f64(&mut c0, -1.0, 1.0);
        let mut c_ref = c0.clone();
        gemm_ref(Trans::No, Trans::No, m, n, k, 1.0, &a, m, &b, k, 0.5, &mut c_ref, m);
        // Cutoff far below the problem: fork engages (default-like).
        let mut c_fork = c0.clone();
        assert!(flops >= MT_FLOP_CUTOFF);
        gemm_mt_with_cutoff(
            4,
            1.0,
            Trans::No,
            Trans::No,
            m,
            n,
            k,
            1.0,
            &a,
            m,
            &b,
            k,
            0.5,
            &mut c_fork,
            m,
        );
        assert!(close(&c_ref, &c_fork));
        // Cutoff far above the problem: serial path, same answer.
        let mut c_serial = c0.clone();
        gemm_mt_with_cutoff(
            4,
            flops * 10.0,
            Trans::No,
            Trans::No,
            m,
            n,
            k,
            1.0,
            &a,
            m,
            &b,
            k,
            0.5,
            &mut c_serial,
            m,
        );
        assert!(close(&c_ref, &c_serial));
    }

    #[test]
    fn cutoff_parse_policy() {
        assert_eq!(parse_cutoff(None), MT_FLOP_CUTOFF);
        assert_eq!(parse_cutoff(Some("")), MT_FLOP_CUTOFF);
        assert_eq!(parse_cutoff(Some("banana")), MT_FLOP_CUTOFF);
        assert_eq!(parse_cutoff(Some("-5")), MT_FLOP_CUTOFF);
        assert_eq!(parse_cutoff(Some("0")), MT_FLOP_CUTOFF);
        assert_eq!(parse_cutoff(Some("inf")), MT_FLOP_CUTOFF);
        assert_eq!(parse_cutoff(Some("2e6")), 2e6);
        assert_eq!(parse_cutoff(Some(" 500000 ")), 5e5);
    }

    #[test]
    fn grid_selection_balances_work() {
        // 4 threads on square C → 2×2; on tall C → 4×1; on wide C → 1×4.
        assert_eq!(grid_for(4, 100, 100), (2, 2));
        assert_eq!(grid_for(4, 1000, 8), (4, 1));
        assert_eq!(grid_for(4, 8, 1000), (1, 4));
        // chunk covers the whole range without overlap
        let (m, parts) = (103, 4);
        let mut covered = 0;
        for i in 0..parts {
            let (lo, len) = chunk(m, parts, i);
            assert_eq!(lo, covered.min(m));
            covered += len;
        }
        assert_eq!(covered, m);
    }
}
