//! Host GEMM: a naive oracle and the packed register-tiled engine.
//!
//! All matrices are column-major. `op(X)` is selected by a `Trans` flag.
//! The naive version is the *correctness oracle* for everything else in
//! the repo (its triple loop is simple enough to trust by inspection);
//! [`gemm_packed`] is the CPU worker's hot kernel (paper §IV-C.2: "the
//! CPU cores … solve the task with a multithreaded BLAS kernel").
//!
//! The packed engine follows the BLIS decomposition: op(B) is packed
//! into KC×NC panels of NR-column micro-strips, op(A) into MC×KC blocks
//! of MR-row micro-strips (both normalizing away the transpose), and an
//! MR×NR register-tiled micro-kernel with the seed's 4-wide k-unroll
//! walks the packed panels. Pack buffers live in a per-thread
//! [`super::pack::PackBuf`], so steady-state tile tasks allocate
//! nothing; blocking parameters come from [`super::tune::block_dims`]
//! (startup probe, feature `autotune`). Throughput measurements are
//! recorded in EXPERIMENTS.md §Perf with machine-readable results in
//! BENCH_kernels.json.

use super::pack::with_pack;
use super::tune::{block_dims, BlockDims};
use crate::api::types::{Dtype, Scalar, Trans};

/// Read `op(X)[r, c]` from a column-major buffer with leading dim `ld`.
#[inline(always)]
fn opx<T: Scalar>(x: &[T], ld: usize, trans: Trans, r: usize, c: usize) -> T {
    match trans {
        Trans::No => x[c * ld + r],
        Trans::Yes => x[r * ld + c],
    }
}

/// Naive reference GEMM: `C := alpha * op(A) * op(B) + beta * C` where
/// op(A) is m×k and op(B) is k×n.
#[allow(clippy::too_many_arguments)]
pub fn gemm_ref<T: Scalar>(
    ta: Trans,
    tb: Trans,
    m: usize,
    n: usize,
    k: usize,
    alpha: T,
    a: &[T],
    lda: usize,
    b: &[T],
    ldb: usize,
    beta: T,
    c: &mut [T],
    ldc: usize,
) {
    for j in 0..n {
        for i in 0..m {
            let mut acc = T::zero();
            for p in 0..k {
                acc += opx(a, lda, ta, i, p) * opx(b, ldb, tb, p, j);
            }
            let old = c[j * ldc + i];
            c[j * ldc + i] = alpha * acc + beta * old;
        }
    }
}

/// Register micro-tile: rows per micro-panel of packed op(A).
/// f64: 8 lanes = two 4-wide AVX2 vectors per column of the tile.
const MR_F64: usize = 8;
/// f32 gets twice the rows for the same register budget.
const MR_F32: usize = 16;
/// Columns per micro-panel of packed op(B) (both precisions): 4 columns
/// × MR rows of accumulators stay comfortably inside 16 vector regs.
const NR: usize = 4;

/// Pack `op(A)[i0..i0+mb, p0..p0+kb]` into `ap` as MR-row strips:
/// strip `s` holds rows `s*MR..` in k-major order (`ap[s*MR*kb + p*MR +
/// i]`), zero-padded to MR rows so the micro-kernel never branches on
/// the row edge.
#[allow(clippy::too_many_arguments)]
fn pack_a<T: Scalar>(
    ap: &mut [T],
    a: &[T],
    lda: usize,
    ta: Trans,
    i0: usize,
    mb: usize,
    p0: usize,
    kb: usize,
    mr_tile: usize,
) {
    let nstrips = mb.div_ceil(mr_tile);
    for s in 0..nstrips {
        let r0 = s * mr_tile;
        let rows = mr_tile.min(mb - r0);
        let dst = &mut ap[s * mr_tile * kb..(s + 1) * mr_tile * kb];
        match ta {
            Trans::No => {
                for p in 0..kb {
                    let src = &a[(p0 + p) * lda + i0 + r0..];
                    let out = &mut dst[p * mr_tile..p * mr_tile + mr_tile];
                    for (o, v) in out[..rows].iter_mut().zip(&src[..rows]) {
                        *o = *v;
                    }
                    for o in out[rows..].iter_mut() {
                        *o = T::zero();
                    }
                }
            }
            Trans::Yes => {
                for ii in 0..rows {
                    let src = &a[(i0 + r0 + ii) * lda + p0..];
                    for p in 0..kb {
                        dst[p * mr_tile + ii] = src[p];
                    }
                }
                if rows < mr_tile {
                    for p in 0..kb {
                        for ii in rows..mr_tile {
                            dst[p * mr_tile + ii] = T::zero();
                        }
                    }
                }
            }
        }
    }
}

/// Pack `op(B)[p0..p0+kb, j0..j0+nb]` into `bp` as NR-column strips
/// (`bp[s*NR*kb + p*NR + j]`), zero-padded to NR columns.
#[allow(clippy::too_many_arguments)]
fn pack_b<T: Scalar>(
    bp: &mut [T],
    b: &[T],
    ldb: usize,
    tb: Trans,
    p0: usize,
    kb: usize,
    j0: usize,
    nb: usize,
) {
    let nstrips = nb.div_ceil(NR);
    for s in 0..nstrips {
        let c0 = s * NR;
        let cols = NR.min(nb - c0);
        let dst = &mut bp[s * NR * kb..(s + 1) * NR * kb];
        match tb {
            Trans::No => {
                for jj in 0..cols {
                    let src = &b[(j0 + c0 + jj) * ldb + p0..];
                    for p in 0..kb {
                        dst[p * NR + jj] = src[p];
                    }
                }
                if cols < NR {
                    for p in 0..kb {
                        for jj in cols..NR {
                            dst[p * NR + jj] = T::zero();
                        }
                    }
                }
            }
            Trans::Yes => {
                for p in 0..kb {
                    let src = &b[(p0 + p) * ldb + j0 + c0..];
                    let out = &mut dst[p * NR..p * NR + NR];
                    for (o, v) in out[..cols].iter_mut().zip(&src[..cols]) {
                        *o = *v;
                    }
                    for o in out[cols..].iter_mut() {
                        *o = T::zero();
                    }
                }
            }
        }
    }
}

/// MR×NR register-tiled micro-kernel over packed micro-panels:
/// `C[0..mr, 0..nr] += alpha * Ap · Bp` where `c` points at the
/// tile's top-left element (column-major, leading dim `ldc`).
///
/// The accumulator lives in `[[T; MR]; NR]` locals — exact-size array
/// ops the compiler keeps in vector registers — and the k loop keeps
/// the seed kernel's 4-wide unroll over rank-1 updates.
///
/// # Safety
/// `c` must be valid for reads/writes of elements `{ j*ldc + i | i <
/// mr, j < nr }`, and no other thread may touch those elements during
/// the call.
#[inline(always)]
#[allow(clippy::too_many_arguments)]
unsafe fn micro_kernel<T: Scalar, const MR: usize>(
    kb: usize,
    alpha: T,
    ap: &[T],
    bp: &[T],
    c: *mut T,
    ldc: usize,
    mr: usize,
    nr: usize,
) {
    let mut acc = [[T::zero(); MR]; NR];
    let mut p = 0;
    while p + 4 <= kb {
        // 4-wide k-unroll (kept from the seed kernel): four fused
        // rank-1 updates per iteration.
        for u in 0..4 {
            let av = &ap[(p + u) * MR..(p + u) * MR + MR];
            let bv = &bp[(p + u) * NR..(p + u) * NR + NR];
            for (j, accj) in acc.iter_mut().enumerate() {
                let bj = bv[j];
                for (x, &av_i) in accj.iter_mut().zip(av) {
                    *x += av_i * bj;
                }
            }
        }
        p += 4;
    }
    while p < kb {
        let av = &ap[p * MR..p * MR + MR];
        let bv = &bp[p * NR..p * NR + NR];
        for (j, accj) in acc.iter_mut().enumerate() {
            let bj = bv[j];
            for (x, &av_i) in accj.iter_mut().zip(av) {
                *x += av_i * bj;
            }
        }
        p += 1;
    }
    if mr == MR && nr == NR {
        for (j, accj) in acc.iter().enumerate() {
            let col = std::slice::from_raw_parts_mut(c.add(j * ldc), MR);
            for (cv, &x) in col.iter_mut().zip(accj) {
                *cv += alpha * x;
            }
        }
    } else {
        for (j, accj) in acc.iter().enumerate().take(nr) {
            let col = std::slice::from_raw_parts_mut(c.add(j * ldc), mr);
            for (cv, &x) in col.iter_mut().zip(&accj[..mr]) {
                *cv += alpha * x;
            }
        }
    }
}

/// The packed engine over a raw C pointer — the shared core of
/// [`gemm_packed_with`] and the threaded 2D partitioner (whose row
/// splits cannot be expressed as disjoint `&mut` slices of a
/// column-major C).
///
/// # Safety
/// `c` must be valid for reads/writes of all elements `{ j*ldc + i |
/// i < m, j < n }`, and no other thread may touch those elements for
/// the duration of the call. `a`/`b` must cover `op(A)` m×k / `op(B)`
/// k×n under their leading dims.
#[allow(clippy::too_many_arguments)]
pub(crate) unsafe fn gemm_packed_ptr<T: Scalar>(
    dims: BlockDims,
    ta: Trans,
    tb: Trans,
    m: usize,
    n: usize,
    k: usize,
    alpha: T,
    a: &[T],
    lda: usize,
    b: &[T],
    ldb: usize,
    beta: T,
    c: *mut T,
    ldc: usize,
) {
    if m == 0 || n == 0 {
        return;
    }
    // Beta pass once up front; the packed loops accumulate with beta=1.
    if beta == T::zero() {
        for j in 0..n {
            let col = std::slice::from_raw_parts_mut(c.add(j * ldc), m);
            for x in col.iter_mut() {
                *x = T::zero();
            }
        }
    } else if beta != T::one() {
        for j in 0..n {
            let col = std::slice::from_raw_parts_mut(c.add(j * ldc), m);
            for x in col.iter_mut() {
                *x *= beta;
            }
        }
    }
    if alpha == T::zero() || k == 0 {
        return;
    }
    match T::DTYPE {
        Dtype::F32 => gemm_loops::<T, MR_F32>(dims, ta, tb, m, n, k, alpha, a, lda, b, ldb, c, ldc),
        Dtype::F64 => gemm_loops::<T, MR_F64>(dims, ta, tb, m, n, k, alpha, a, lda, b, ldb, c, ldc),
    }
}

/// The five BLIS loops around [`micro_kernel`]. Caller guarantees
/// `m, n, k ≥ 1`, that beta has been applied, and (as in
/// [`gemm_packed_ptr`]) that `c` exclusively covers the m×n extent —
/// the function is safe to *declare* because it is private and every
/// caller upholds the pointer contract stated there.
#[allow(clippy::too_many_arguments)]
fn gemm_loops<T: Scalar, const MR: usize>(
    dims: BlockDims,
    ta: Trans,
    tb: Trans,
    m: usize,
    n: usize,
    k: usize,
    alpha: T,
    a: &[T],
    lda: usize,
    b: &[T],
    ldb: usize,
    c: *mut T,
    ldc: usize,
) {
    let (mc, nc, kc) = (dims.mc.max(MR), dims.nc.max(NR), dims.kc.max(4));
    with_pack(|pb: &mut super::pack::PackBuf<T>| {
        let kb_max = kc.min(k);
        let a_need = mc.min(m).div_ceil(MR) * MR * kb_max;
        let b_need = nc.min(n).div_ceil(NR) * NR * kb_max;
        pb.ensure(a_need, b_need);
        let (ap, bp) = (&mut pb.a, &mut pb.b);
        let mut jc = 0;
        while jc < n {
            let nb = nc.min(n - jc);
            let mut pc = 0;
            while pc < k {
                let kb = kc.min(k - pc);
                pack_b(bp, b, ldb, tb, pc, kb, jc, nb);
                let mut ic = 0;
                while ic < m {
                    let mb = mc.min(m - ic);
                    pack_a(ap, a, lda, ta, ic, mb, pc, kb, MR);
                    let mut jr = 0;
                    while jr < nb {
                        let nr = NR.min(nb - jr);
                        let bs = &bp[(jr / NR) * NR * kb..];
                        let mut ir = 0;
                        while ir < mb {
                            let mr = MR.min(mb - ir);
                            let a_strip = &ap[(ir / MR) * MR * kb..];
                            // SAFETY: the (ic+ir, jc+jr) micro-tile lies
                            // inside the m×n extent the caller owns.
                            unsafe {
                                micro_kernel::<T, MR>(
                                    kb,
                                    alpha,
                                    a_strip,
                                    bs,
                                    c.add((jc + jr) * ldc + ic + ir),
                                    ldc,
                                    mr,
                                    nr,
                                );
                            }
                            ir += MR;
                        }
                        jr += NR;
                    }
                    ic += mb;
                }
                pc += kb;
            }
            jc += nb;
        }
    });
}

/// Packed GEMM with explicit blocking parameters (the autotune probe
/// and tests use this; everything else goes through [`gemm_packed`]).
#[allow(clippy::too_many_arguments)]
pub fn gemm_packed_with<T: Scalar>(
    dims: BlockDims,
    ta: Trans,
    tb: Trans,
    m: usize,
    n: usize,
    k: usize,
    alpha: T,
    a: &[T],
    lda: usize,
    b: &[T],
    ldb: usize,
    beta: T,
    c: &mut [T],
    ldc: usize,
) {
    if m == 0 || n == 0 {
        return;
    }
    // Hard asserts, not debug: these two comparisons are the entire
    // safety boundary between a caller-supplied slice and the
    // raw-pointer engine. The seed kernel bounds-checked every C index
    // through the slice; a release-mode caller error must still panic,
    // never scribble.
    assert!(ldc >= m, "ldc must cover C's rows");
    assert!(c.len() >= (n - 1) * ldc + m, "C buffer too small");
    // SAFETY: `c` is an exclusive slice covering the full m×n extent
    // (asserted above), so the raw-pointer engine writes only
    // in-bounds elements no one else can alias.
    unsafe {
        gemm_packed_ptr(dims, ta, tb, m, n, k, alpha, a, lda, b, ldb, beta, c.as_mut_ptr(), ldc);
    }
}

/// Packed register-tiled GEMM: `C := alpha * op(A) * op(B) + beta * C`,
/// same semantics as [`gemm_ref`], blocking chosen by the startup probe.
#[allow(clippy::too_many_arguments)]
pub fn gemm_packed<T: Scalar>(
    ta: Trans,
    tb: Trans,
    m: usize,
    n: usize,
    k: usize,
    alpha: T,
    a: &[T],
    lda: usize,
    b: &[T],
    ldb: usize,
    beta: T,
    c: &mut [T],
    ldc: usize,
) {
    gemm_packed_with(block_dims(T::DTYPE), ta, tb, m, n, k, alpha, a, lda, b, ldb, beta, c, ldc)
}

/// Compatibility alias for the seed-era name: the cache-blocked kernel
/// is now the packed engine. Call sites (tests, examples, benches)
/// keep working; new code should say [`gemm_packed`].
#[allow(clippy::too_many_arguments)]
pub fn gemm_blocked<T: Scalar>(
    ta: Trans,
    tb: Trans,
    m: usize,
    n: usize,
    k: usize,
    alpha: T,
    a: &[T],
    lda: usize,
    b: &[T],
    ldb: usize,
    beta: T,
    c: &mut [T],
    ldc: usize,
) {
    gemm_packed(ta, tb, m, n, k, alpha, a, lda, b, ldb, beta, c, ldc)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Prng;

    fn rand_mat(rng: &mut Prng, rows: usize, cols: usize, ld: usize) -> Vec<f64> {
        let mut v = vec![0.0; ld * cols];
        for c in 0..cols {
            for r in 0..rows {
                v[c * ld + r] = rng.range_f64(-1.0, 1.0);
            }
        }
        v
    }

    fn close(a: &[f64], b: &[f64]) -> bool {
        a.iter().zip(b).all(|(x, y)| (x - y).abs() <= 1e-9 * x.abs().max(y.abs()).max(1.0))
    }

    #[test]
    fn ref_known_small_case() {
        // A = [[1,3],[2,4]] (col-major [1,2,3,4]), B = I
        let a = vec![1.0, 2.0, 3.0, 4.0];
        let b = vec![1.0, 0.0, 0.0, 1.0];
        let mut c = vec![0.5, 0.5, 0.5, 0.5];
        gemm_ref(Trans::No, Trans::No, 2, 2, 2, 2.0, &a, 2, &b, 2, 1.0, &mut c, 2);
        assert_eq!(c, vec![2.5, 4.5, 6.5, 8.5]);
    }

    #[test]
    fn ref_transpose_semantics() {
        // op(A)=A^T: A is k×m stored (2×3)
        let a = vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]; // 2x3 col-major
        let b = vec![1.0, 1.0]; // 2x1
        let mut c = vec![0.0; 3];
        gemm_ref(Trans::Yes, Trans::No, 3, 1, 2, 1.0, &a, 2, &b, 2, 0.0, &mut c, 3);
        // A^T rows = columns of A: [1,2],[3,4],[5,6] · [1,1] = [3,7,11]
        assert_eq!(c, vec![3.0, 7.0, 11.0]);
    }

    #[test]
    fn packed_matches_ref_all_trans_combos() {
        let mut rng = Prng::new(77);
        for &(ta, tb) in &[
            (Trans::No, Trans::No),
            (Trans::No, Trans::Yes),
            (Trans::Yes, Trans::No),
            (Trans::Yes, Trans::Yes),
        ] {
            for &(m, n, k) in &[(1, 1, 1), (7, 5, 9), (64, 64, 64), (130, 67, 129), (33, 129, 70)] {
                let (ar, ac) = if ta == Trans::No { (m, k) } else { (k, m) };
                let (br, bc) = if tb == Trans::No { (k, n) } else { (n, k) };
                let lda = ar + 3;
                let ldb = br + 1;
                let ldc = m + 2;
                let a = rand_mat(&mut rng, ar, ac, lda);
                let b = rand_mat(&mut rng, br, bc, ldb);
                let c0 = rand_mat(&mut rng, m, n, ldc);
                let mut c_ref = c0.clone();
                let mut c_blk = c0.clone();
                gemm_ref(ta, tb, m, n, k, 1.3, &a, lda, &b, ldb, -0.7, &mut c_ref, ldc);
                gemm_packed(ta, tb, m, n, k, 1.3, &a, lda, &b, ldb, -0.7, &mut c_blk, ldc);
                assert!(close(&c_ref, &c_blk), "mismatch ta={ta:?} tb={tb:?} m={m} n={n} k={k}");
            }
        }
    }

    #[test]
    fn packed_awkward_blockings_match_ref() {
        // Exercise every pack/edge path with blockings that do not
        // divide the problem (nor the MR/NR tiles).
        let mut rng = Prng::new(5150);
        let dims_list = [
            BlockDims { mc: 8, nc: 4, kc: 4 },
            BlockDims { mc: 13, nc: 10, kc: 9 },
            BlockDims { mc: 16, nc: 16, kc: 16 },
        ];
        let (m, n, k) = (29, 23, 17);
        let a = rand_mat(&mut rng, m, k, m);
        let b = rand_mat(&mut rng, k, n, k);
        let c0 = rand_mat(&mut rng, m, n, m);
        let mut want = c0.clone();
        gemm_ref(Trans::No, Trans::No, m, n, k, 0.9, &a, m, &b, k, 0.3, &mut want, m);
        for dims in dims_list {
            let mut c = c0.clone();
            gemm_packed_with(dims, Trans::No, Trans::No, m, n, k, 0.9, &a, m, &b, k, 0.3, &mut c, m);
            assert!(close(&want, &c), "dims {dims:?}");
        }
    }

    #[test]
    fn packed_alpha_zero_scales_only() {
        let mut rng = Prng::new(3);
        let a = rand_mat(&mut rng, 8, 8, 8);
        let b = rand_mat(&mut rng, 8, 8, 8);
        let c0 = rand_mat(&mut rng, 8, 8, 8);
        let mut c = c0.clone();
        gemm_packed(Trans::No, Trans::No, 8, 8, 8, 0.0, &a, 8, &b, 8, 2.0, &mut c, 8);
        let expect: Vec<f64> = c0.iter().map(|x| 2.0 * x).collect();
        assert!(close(&c, &expect));
    }

    #[test]
    fn packed_beta_zero_ignores_c_contents() {
        // beta=0 must overwrite, never read, C (proper BLAS semantics).
        let a = vec![1.0f64; 4];
        let b = vec![1.0f64; 4];
        let mut c = vec![f64::NAN; 4];
        gemm_packed(Trans::No, Trans::No, 2, 2, 2, 1.0, &a, 2, &b, 2, 0.0, &mut c, 2);
        assert_eq!(c, vec![2.0, 2.0, 2.0, 2.0]);
    }

    #[test]
    fn packed_f32_path() {
        let a: Vec<f32> = vec![1.0, 2.0, 3.0, 4.0];
        let b: Vec<f32> = vec![1.0, 1.0, 1.0, 1.0];
        let mut c: Vec<f32> = vec![0.0; 4];
        gemm_packed(Trans::No, Trans::No, 2, 2, 2, 1.0, &a, 2, &b, 2, 0.0, &mut c, 2);
        assert_eq!(c, vec![4.0, 6.0, 4.0, 6.0]);
    }

    #[test]
    fn packed_beta_preserved_outside_mn() {
        // ld padding rows must not be touched
        let a = vec![1.0; 4];
        let b = vec![1.0; 4];
        let mut c = vec![9.0; 6]; // 2x2 with ldc=3: rows 2 are padding
        gemm_packed(Trans::No, Trans::No, 2, 2, 2, 1.0, &a, 2, &b, 2, 0.0, &mut c, 3);
        assert_eq!(c[2], 9.0);
        assert_eq!(c[5], 9.0);
    }

    #[test]
    fn packed_degenerate_sizes_no_panic() {
        let a: Vec<f64> = vec![];
        let b: Vec<f64> = vec![];
        let mut c: Vec<f64> = vec![];
        gemm_packed(Trans::No, Trans::No, 0, 0, 0, 1.0, &a, 1, &b, 1, 0.0, &mut c, 1);
        let mut c1 = vec![3.0f64; 2];
        // k == 0: pure beta scale
        gemm_packed(Trans::No, Trans::Yes, 2, 1, 0, 1.0, &a, 1, &b, 1, 0.5, &mut c1, 2);
        assert_eq!(c1, vec![1.5, 1.5]);
    }
}
