//! Host GEMM: a naive oracle and a register/cache-blocked kernel.
//!
//! All matrices are column-major. `op(X)` is selected by a `Trans` flag.
//! The naive version is the *correctness oracle* for everything else in
//! the repo (its triple loop is simple enough to trust by inspection);
//! the blocked version is the CPU worker's hot kernel (paper §IV-C.2:
//! "the CPU cores … solve the task with a multithreaded BLAS kernel").

use crate::api::types::{Scalar, Trans};

/// Read `op(X)[r, c]` from a column-major buffer with leading dim `ld`.
#[inline(always)]
fn opx<T: Scalar>(x: &[T], ld: usize, trans: Trans, r: usize, c: usize) -> T {
    match trans {
        Trans::No => x[c * ld + r],
        Trans::Yes => x[r * ld + c],
    }
}

/// Naive reference GEMM: `C := alpha * op(A) * op(B) + beta * C` where
/// op(A) is m×k and op(B) is k×n.
#[allow(clippy::too_many_arguments)]
pub fn gemm_ref<T: Scalar>(
    ta: Trans,
    tb: Trans,
    m: usize,
    n: usize,
    k: usize,
    alpha: T,
    a: &[T],
    lda: usize,
    b: &[T],
    ldb: usize,
    beta: T,
    c: &mut [T],
    ldc: usize,
) {
    for j in 0..n {
        for i in 0..m {
            let mut acc = T::zero();
            for p in 0..k {
                acc += opx(a, lda, ta, i, p) * opx(b, ldb, tb, p, j);
            }
            let old = c[j * ldc + i];
            c[j * ldc + i] = alpha * acc + beta * old;
        }
    }
}

/// Panel size for the blocked kernel (fits comfortably in L1/L2 for f64).
const MC: usize = 64;
const NC: usize = 64;
const KC: usize = 128;

/// Cache-blocked GEMM with the same semantics as [`gemm_ref`].
///
/// Strategy: pack op(A) and op(B) panels into contiguous buffers (which
/// also normalizes away the transpose), then run a 4-wide unrolled
/// micro-kernel over columns. ~5-15× faster than naive at T=256 f64 while
/// staying dependency-free.
#[allow(clippy::too_many_arguments)]
pub fn gemm_blocked<T: Scalar>(
    ta: Trans,
    tb: Trans,
    m: usize,
    n: usize,
    k: usize,
    alpha: T,
    a: &[T],
    lda: usize,
    b: &[T],
    ldb: usize,
    beta: T,
    c: &mut [T],
    ldc: usize,
) {
    if m == 0 || n == 0 {
        return;
    }
    if alpha == T::zero() || k == 0 {
        // C := beta * C
        for j in 0..n {
            for i in 0..m {
                let v = c[j * ldc + i];
                c[j * ldc + i] = beta * v;
            }
        }
        return;
    }
    // apply beta once up front, accumulate with beta=1 afterwards
    if beta != T::one() {
        for j in 0..n {
            for i in 0..m {
                let v = c[j * ldc + i];
                c[j * ldc + i] = beta * v;
            }
        }
    }
    let mut apack = vec![T::zero(); MC * KC];
    let mut bpack = vec![T::zero(); KC * NC];
    let mut pc = 0;
    while pc < k {
        let kb = KC.min(k - pc);
        let mut jc = 0;
        while jc < n {
            let nb = NC.min(n - jc);
            // pack op(B)[pc..pc+kb, jc..jc+nb] column-major kb×nb
            for jj in 0..nb {
                for pp in 0..kb {
                    bpack[jj * kb + pp] = opx(b, ldb, tb, pc + pp, jc + jj);
                }
            }
            let mut ic = 0;
            while ic < m {
                let mb = MC.min(m - ic);
                // pack op(A)[ic..ic+mb, pc..pc+kb] column-major mb×kb
                for pp in 0..kb {
                    for ii in 0..mb {
                        apack[pp * mb + ii] = opx(a, lda, ta, ic + ii, pc + pp);
                    }
                }
                // micro-kernel: C[ic.., jc..] += alpha * apack * bpack.
                // Exact-length slice zips instead of indexed loops: the
                // compiler drops the bounds checks and autovectorizes
                // the fused rank-4 update (≈2.5× on this host — see
                // EXPERIMENTS.md §Perf).
                for jj in 0..nb {
                    let ccol = (jc + jj) * ldc + ic;
                    let bcol = jj * kb;
                    let cs = &mut c[ccol..ccol + mb];
                    let mut pp = 0;
                    // unroll the k loop by 4 over rank-1 updates
                    while pp + 4 <= kb {
                        let b0 = alpha * bpack[bcol + pp];
                        let b1 = alpha * bpack[bcol + pp + 1];
                        let b2 = alpha * bpack[bcol + pp + 2];
                        let b3 = alpha * bpack[bcol + pp + 3];
                        let (a0s, rest) = apack[pp * mb..].split_at(mb);
                        let (a1s, rest) = rest.split_at(mb);
                        let (a2s, rest) = rest.split_at(mb);
                        let a3s = &rest[..mb];
                        for ((((cv, &x0), &x1), &x2), &x3) in
                            cs.iter_mut().zip(a0s).zip(a1s).zip(a2s).zip(a3s)
                        {
                            *cv += x0 * b0 + x1 * b1 + x2 * b2 + x3 * b3;
                        }
                        pp += 4;
                    }
                    while pp < kb {
                        let bv = alpha * bpack[bcol + pp];
                        let aos = &apack[pp * mb..pp * mb + mb];
                        for (cv, &x) in cs.iter_mut().zip(aos) {
                            *cv += x * bv;
                        }
                        pp += 1;
                    }
                }
                ic += mb;
            }
            jc += nb;
        }
        pc += kb;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Prng;

    fn rand_mat(rng: &mut Prng, rows: usize, cols: usize, ld: usize) -> Vec<f64> {
        let mut v = vec![0.0; ld * cols];
        for c in 0..cols {
            for r in 0..rows {
                v[c * ld + r] = rng.range_f64(-1.0, 1.0);
            }
        }
        v
    }

    fn close(a: &[f64], b: &[f64]) -> bool {
        a.iter().zip(b).all(|(x, y)| (x - y).abs() <= 1e-9 * x.abs().max(y.abs()).max(1.0))
    }

    #[test]
    fn ref_known_small_case() {
        // A = [[1,3],[2,4]] (col-major [1,2,3,4]), B = I
        let a = vec![1.0, 2.0, 3.0, 4.0];
        let b = vec![1.0, 0.0, 0.0, 1.0];
        let mut c = vec![0.5, 0.5, 0.5, 0.5];
        gemm_ref(Trans::No, Trans::No, 2, 2, 2, 2.0, &a, 2, &b, 2, 1.0, &mut c, 2);
        assert_eq!(c, vec![2.5, 4.5, 6.5, 8.5]);
    }

    #[test]
    fn ref_transpose_semantics() {
        // op(A)=A^T: A is k×m stored (2×3)
        let a = vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]; // 2x3 col-major
        let b = vec![1.0, 1.0]; // 2x1
        let mut c = vec![0.0; 3];
        gemm_ref(Trans::Yes, Trans::No, 3, 1, 2, 1.0, &a, 2, &b, 2, 0.0, &mut c, 3);
        // A^T rows = columns of A: [1,2],[3,4],[5,6] · [1,1] = [3,7,11]
        assert_eq!(c, vec![3.0, 7.0, 11.0]);
    }

    #[test]
    fn blocked_matches_ref_all_trans_combos() {
        let mut rng = Prng::new(77);
        for &(ta, tb) in &[
            (Trans::No, Trans::No),
            (Trans::No, Trans::Yes),
            (Trans::Yes, Trans::No),
            (Trans::Yes, Trans::Yes),
        ] {
            for &(m, n, k) in &[(1, 1, 1), (7, 5, 9), (64, 64, 64), (130, 67, 129), (33, 129, 70)] {
                let (ar, ac) = if ta == Trans::No { (m, k) } else { (k, m) };
                let (br, bc) = if tb == Trans::No { (k, n) } else { (n, k) };
                let lda = ar + 3;
                let ldb = br + 1;
                let ldc = m + 2;
                let a = rand_mat(&mut rng, ar, ac, lda);
                let b = rand_mat(&mut rng, br, bc, ldb);
                let c0 = rand_mat(&mut rng, m, n, ldc);
                let mut c_ref = c0.clone();
                let mut c_blk = c0.clone();
                gemm_ref(ta, tb, m, n, k, 1.3, &a, lda, &b, ldb, -0.7, &mut c_ref, ldc);
                gemm_blocked(ta, tb, m, n, k, 1.3, &a, lda, &b, ldb, -0.7, &mut c_blk, ldc);
                assert!(close(&c_ref, &c_blk), "mismatch ta={ta:?} tb={tb:?} m={m} n={n} k={k}");
            }
        }
    }

    #[test]
    fn blocked_alpha_zero_scales_only() {
        let mut rng = Prng::new(3);
        let a = rand_mat(&mut rng, 8, 8, 8);
        let b = rand_mat(&mut rng, 8, 8, 8);
        let c0 = rand_mat(&mut rng, 8, 8, 8);
        let mut c = c0.clone();
        gemm_blocked(Trans::No, Trans::No, 8, 8, 8, 0.0, &a, 8, &b, 8, 2.0, &mut c, 8);
        let expect: Vec<f64> = c0.iter().map(|x| 2.0 * x).collect();
        assert!(close(&c, &expect));
    }

    #[test]
    fn blocked_f32_path() {
        let a: Vec<f32> = vec![1.0, 2.0, 3.0, 4.0];
        let b: Vec<f32> = vec![1.0, 1.0, 1.0, 1.0];
        let mut c: Vec<f32> = vec![0.0; 4];
        gemm_blocked(Trans::No, Trans::No, 2, 2, 2, 1.0, &a, 2, &b, 2, 0.0, &mut c, 2);
        assert_eq!(c, vec![4.0, 6.0, 4.0, 6.0]);
    }

    #[test]
    fn blocked_beta_preserved_outside_mn() {
        // ld padding rows must not be touched
        let a = vec![1.0; 4];
        let b = vec![1.0; 4];
        let mut c = vec![9.0; 6]; // 2x2 with ldc=3: rows 2 are padding
        gemm_blocked(Trans::No, Trans::No, 2, 2, 2, 1.0, &a, 2, &b, 2, 0.0, &mut c, 3);
        assert_eq!(c[2], 9.0);
        assert_eq!(c[5], 9.0);
    }
}
