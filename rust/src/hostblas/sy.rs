//! Host symmetric kernels: SYRK, SYR2K, SYMM.
//!
//! Column-major. Symmetric operands store one `uplo` triangle; the other
//! triangle of the buffer is never read (tests fill it with NaN to prove
//! it).
//!
//! Two tiers per routine:
//! - `*_ref` — naive oracles, trusted by inspection, **test-only** since
//!   the packed engine landed;
//! - `*_packed` — blocked macro-kernels that decompose into panel GEMMs
//!   over the packed engine ([`super::gemm::gemm_packed`]): off-diagonal
//!   panels are plain GEMMs straight into C's stored triangle, diagonal
//!   blocks are computed as full squares into a thread-reused scratch
//!   and merged triangle-only (so the unstored triangle of C is never
//!   touched, same contract as the oracles).

use super::gemm::gemm_packed;
use super::pack::{give_buf, take_buf};
use crate::api::types::{Scalar, Side, Trans, Uplo};

/// Read `sym(A)[r, c]` from a triangle-stored buffer.
#[inline]
fn sym_elem<T: Scalar>(a: &[T], lda: usize, uplo: Uplo, r: usize, c: usize) -> T {
    let stored = match uplo {
        Uplo::Upper => r <= c,
        Uplo::Lower => r >= c,
    };
    if stored {
        a[c * lda + r]
    } else {
        a[r * lda + c]
    }
}

/// Is `(r, c)` inside the stored triangle?
#[inline]
fn in_tri(uplo: Uplo, r: usize, c: usize) -> bool {
    match uplo {
        Uplo::Upper => r <= c,
        Uplo::Lower => r >= c,
    }
}

/// SYRK: `C := alpha * op(A) op(A)^T + beta * C` (trans == No, A n×k) or
/// `C := alpha * op(A)^T op(A) + beta * C` (trans == Yes, A k×n); only
/// the `uplo` triangle of C is referenced/updated.
#[allow(clippy::too_many_arguments)]
pub fn syrk_ref<T: Scalar>(
    uplo: Uplo,
    trans: Trans,
    n: usize,
    k: usize,
    alpha: T,
    a: &[T],
    lda: usize,
    beta: T,
    c: &mut [T],
    ldc: usize,
) {
    for j in 0..n {
        for i in 0..n {
            if !in_tri(uplo, i, j) {
                continue;
            }
            let mut acc = T::zero();
            for p in 0..k {
                let (x, y) = match trans {
                    Trans::No => (a[p * lda + i], a[p * lda + j]),
                    Trans::Yes => (a[i * lda + p], a[j * lda + p]),
                };
                acc += x * y;
            }
            let old = c[j * ldc + i];
            c[j * ldc + i] = alpha * acc + beta * old;
        }
    }
}

/// SYR2K: `C := alpha*(op(A) op(B)^T + op(B) op(A)^T) + beta*C`
/// (trans == No) or `alpha*(op(A)^T op(B) + op(B)^T op(A)) + beta*C`.
#[allow(clippy::too_many_arguments)]
pub fn syr2k_ref<T: Scalar>(
    uplo: Uplo,
    trans: Trans,
    n: usize,
    k: usize,
    alpha: T,
    a: &[T],
    lda: usize,
    b: &[T],
    ldb: usize,
    beta: T,
    c: &mut [T],
    ldc: usize,
) {
    for j in 0..n {
        for i in 0..n {
            if !in_tri(uplo, i, j) {
                continue;
            }
            let mut acc = T::zero();
            for p in 0..k {
                let (ai, aj, bi, bj) = match trans {
                    Trans::No => {
                        (a[p * lda + i], a[p * lda + j], b[p * ldb + i], b[p * ldb + j])
                    }
                    Trans::Yes => {
                        (a[i * lda + p], a[j * lda + p], b[i * ldb + p], b[j * ldb + p])
                    }
                };
                acc += ai * bj + bi * aj;
            }
            let old = c[j * ldc + i];
            c[j * ldc + i] = alpha * acc + beta * old;
        }
    }
}

/// SYMM: `C := alpha * sym(A) * B + beta * C` (Left, A m×m) or
/// `C := alpha * B * sym(A) + beta * C` (Right, A n×n); C is m×n.
#[allow(clippy::too_many_arguments)]
pub fn symm_ref<T: Scalar>(
    side: Side,
    uplo: Uplo,
    m: usize,
    n: usize,
    alpha: T,
    a: &[T],
    lda: usize,
    b: &[T],
    ldb: usize,
    beta: T,
    c: &mut [T],
    ldc: usize,
) {
    for j in 0..n {
        for i in 0..m {
            let mut acc = T::zero();
            match side {
                Side::Left => {
                    for p in 0..m {
                        acc += sym_elem(a, lda, uplo, i, p) * b[j * ldb + p];
                    }
                }
                Side::Right => {
                    for p in 0..n {
                        acc += b[p * ldb + i] * sym_elem(a, lda, uplo, p, j);
                    }
                }
            }
            let old = c[j * ldc + i];
            c[j * ldc + i] = alpha * acc + beta * old;
        }
    }
}

// ------------------------------------------------------------------
// packed macro-kernels

/// Default diagonal-block size for the symmetric/triangular macro
/// kernels: big enough that off-diagonal GEMM panels dominate, small
/// enough that the `NB×NB` diagonal scratch stays cache-resident.
pub(crate) const DIAG_NB: usize = 128;

/// `C[tri] := beta * C[tri]` (with BLAS beta-zero semantics: C is
/// overwritten, never read).
pub(crate) fn scale_tri<T: Scalar>(uplo: Uplo, n: usize, beta: T, c: &mut [T], ldc: usize) {
    for j in 0..n {
        let (lo, hi) = match uplo {
            Uplo::Upper => (0, j + 1),
            Uplo::Lower => (j, n),
        };
        for i in lo..hi {
            let idx = j * ldc + i;
            c[idx] = if beta == T::zero() { T::zero() } else { beta * c[idx] };
        }
    }
}

/// Merge a densely computed `jb×jb` diagonal block (scratch `w`, ld
/// `jb`) into C's stored triangle at block offset `j0`, applying beta.
fn merge_tri<T: Scalar>(
    uplo: Uplo,
    j0: usize,
    jb: usize,
    w: &[T],
    beta: T,
    c: &mut [T],
    ldc: usize,
) {
    for jj in 0..jb {
        let (lo, hi) = match uplo {
            Uplo::Upper => (0, jj + 1),
            Uplo::Lower => (jj, jb),
        };
        for ii in lo..hi {
            let idx = (j0 + jj) * ldc + j0 + ii;
            let v = w[jj * jb + ii];
            c[idx] = if beta == T::zero() { v } else { v + beta * c[idx] };
        }
    }
}

/// Packed SYRK, same semantics as [`syrk_ref`]: only the `uplo`
/// triangle of C is referenced/updated.
#[allow(clippy::too_many_arguments)]
pub fn syrk_packed<T: Scalar>(
    uplo: Uplo,
    trans: Trans,
    n: usize,
    k: usize,
    alpha: T,
    a: &[T],
    lda: usize,
    beta: T,
    c: &mut [T],
    ldc: usize,
) {
    syrk_packed_nb(DIAG_NB, uplo, trans, n, k, alpha, a, lda, beta, c, ldc)
}

/// [`syrk_packed`] with an explicit diagonal-block size (tests sweep
/// tiny blocks to exercise every edge path).
#[doc(hidden)]
#[allow(clippy::too_many_arguments)]
pub fn syrk_packed_nb<T: Scalar>(
    nb: usize,
    uplo: Uplo,
    trans: Trans,
    n: usize,
    k: usize,
    alpha: T,
    a: &[T],
    lda: usize,
    beta: T,
    c: &mut [T],
    ldc: usize,
) {
    if n == 0 {
        return;
    }
    if alpha == T::zero() || k == 0 {
        scale_tri(uplo, n, beta, c, ldc);
        return;
    }
    let nb = nb.max(1);
    let mut j0 = 0;
    while j0 < n {
        let jb = nb.min(n - j0);
        let j1 = j0 + jb;
        // Diagonal block: full square into scratch, merge the triangle.
        let mut w = take_buf::<T>(jb * jb);
        match trans {
            Trans::No => gemm_packed(
                Trans::No, Trans::Yes, jb, jb, k, alpha, &a[j0..], lda, &a[j0..], lda,
                T::zero(), &mut w, jb,
            ),
            Trans::Yes => gemm_packed(
                Trans::Yes, Trans::No, jb, jb, k, alpha, &a[j0 * lda..], lda, &a[j0 * lda..], lda,
                T::zero(), &mut w, jb,
            ),
        }
        merge_tri(uplo, j0, jb, &w, beta, c, ldc);
        give_buf(w);
        // Off-diagonal panel of this block column: one plain GEMM whose
        // rectangular extent lies entirely inside the stored triangle.
        if uplo == Uplo::Lower && j1 < n {
            match trans {
                Trans::No => gemm_packed(
                    Trans::No, Trans::Yes, n - j1, jb, k, alpha, &a[j1..], lda, &a[j0..], lda,
                    beta, &mut c[j0 * ldc + j1..], ldc,
                ),
                Trans::Yes => gemm_packed(
                    Trans::Yes, Trans::No, n - j1, jb, k, alpha, &a[j1 * lda..], lda,
                    &a[j0 * lda..], lda, beta, &mut c[j0 * ldc + j1..], ldc,
                ),
            }
        }
        if uplo == Uplo::Upper && j0 > 0 {
            match trans {
                Trans::No => gemm_packed(
                    Trans::No, Trans::Yes, j0, jb, k, alpha, a, lda, &a[j0..], lda, beta,
                    &mut c[j0 * ldc..], ldc,
                ),
                Trans::Yes => gemm_packed(
                    Trans::Yes, Trans::No, j0, jb, k, alpha, a, lda, &a[j0 * lda..], lda, beta,
                    &mut c[j0 * ldc..], ldc,
                ),
            }
        }
        j0 = j1;
    }
}

/// Packed SYR2K, same semantics as [`syr2k_ref`].
#[allow(clippy::too_many_arguments)]
pub fn syr2k_packed<T: Scalar>(
    uplo: Uplo,
    trans: Trans,
    n: usize,
    k: usize,
    alpha: T,
    a: &[T],
    lda: usize,
    b: &[T],
    ldb: usize,
    beta: T,
    c: &mut [T],
    ldc: usize,
) {
    syr2k_packed_nb(DIAG_NB, uplo, trans, n, k, alpha, a, lda, b, ldb, beta, c, ldc)
}

/// [`syr2k_packed`] with an explicit diagonal-block size.
#[doc(hidden)]
#[allow(clippy::too_many_arguments)]
pub fn syr2k_packed_nb<T: Scalar>(
    nb: usize,
    uplo: Uplo,
    trans: Trans,
    n: usize,
    k: usize,
    alpha: T,
    a: &[T],
    lda: usize,
    b: &[T],
    ldb: usize,
    beta: T,
    c: &mut [T],
    ldc: usize,
) {
    if n == 0 {
        return;
    }
    if alpha == T::zero() || k == 0 {
        scale_tri(uplo, n, beta, c, ldc);
        return;
    }
    let nb = nb.max(1);
    let mut j0 = 0;
    while j0 < n {
        let jb = nb.min(n - j0);
        let j1 = j0 + jb;
        let mut w = take_buf::<T>(jb * jb);
        match trans {
            Trans::No => {
                gemm_packed(
                    Trans::No, Trans::Yes, jb, jb, k, alpha, &a[j0..], lda, &b[j0..], ldb,
                    T::zero(), &mut w, jb,
                );
                gemm_packed(
                    Trans::No, Trans::Yes, jb, jb, k, alpha, &b[j0..], ldb, &a[j0..], lda,
                    T::one(), &mut w, jb,
                );
            }
            Trans::Yes => {
                gemm_packed(
                    Trans::Yes, Trans::No, jb, jb, k, alpha, &a[j0 * lda..], lda, &b[j0 * ldb..],
                    ldb, T::zero(), &mut w, jb,
                );
                gemm_packed(
                    Trans::Yes, Trans::No, jb, jb, k, alpha, &b[j0 * ldb..], ldb, &a[j0 * lda..],
                    lda, T::one(), &mut w, jb,
                );
            }
        }
        merge_tri(uplo, j0, jb, &w, beta, c, ldc);
        give_buf(w);
        if uplo == Uplo::Lower && j1 < n {
            match trans {
                Trans::No => {
                    gemm_packed(
                        Trans::No, Trans::Yes, n - j1, jb, k, alpha, &a[j1..], lda, &b[j0..], ldb,
                        beta, &mut c[j0 * ldc + j1..], ldc,
                    );
                    gemm_packed(
                        Trans::No, Trans::Yes, n - j1, jb, k, alpha, &b[j1..], ldb, &a[j0..], lda,
                        T::one(), &mut c[j0 * ldc + j1..], ldc,
                    );
                }
                Trans::Yes => {
                    gemm_packed(
                        Trans::Yes, Trans::No, n - j1, jb, k, alpha, &a[j1 * lda..], lda,
                        &b[j0 * ldb..], ldb, beta, &mut c[j0 * ldc + j1..], ldc,
                    );
                    gemm_packed(
                        Trans::Yes, Trans::No, n - j1, jb, k, alpha, &b[j1 * ldb..], ldb,
                        &a[j0 * lda..], lda, T::one(), &mut c[j0 * ldc + j1..], ldc,
                    );
                }
            }
        }
        if uplo == Uplo::Upper && j0 > 0 {
            match trans {
                Trans::No => {
                    gemm_packed(
                        Trans::No, Trans::Yes, j0, jb, k, alpha, a, lda, &b[j0..], ldb, beta,
                        &mut c[j0 * ldc..], ldc,
                    );
                    gemm_packed(
                        Trans::No, Trans::Yes, j0, jb, k, alpha, b, ldb, &a[j0..], lda, T::one(),
                        &mut c[j0 * ldc..], ldc,
                    );
                }
                Trans::Yes => {
                    gemm_packed(
                        Trans::Yes, Trans::No, j0, jb, k, alpha, a, lda, &b[j0 * ldb..], ldb,
                        beta, &mut c[j0 * ldc..], ldc,
                    );
                    gemm_packed(
                        Trans::Yes, Trans::No, j0, jb, k, alpha, b, ldb, &a[j0 * lda..], lda,
                        T::one(), &mut c[j0 * ldc..], ldc,
                    );
                }
            }
        }
        j0 = j1;
    }
}

/// Packed SYMM, same semantics as [`symm_ref`]: densify the stored
/// triangle of `sym(A)` into a thread-reused scratch (O(na²) against
/// the O(m·n·na) multiply), then run one packed GEMM.
#[allow(clippy::too_many_arguments)]
pub fn symm_packed<T: Scalar>(
    side: Side,
    uplo: Uplo,
    m: usize,
    n: usize,
    alpha: T,
    a: &[T],
    lda: usize,
    b: &[T],
    ldb: usize,
    beta: T,
    c: &mut [T],
    ldc: usize,
) {
    if m == 0 || n == 0 {
        return;
    }
    if alpha == T::zero() {
        for j in 0..n {
            for i in 0..m {
                let idx = j * ldc + i;
                c[idx] = if beta == T::zero() { T::zero() } else { beta * c[idx] };
            }
        }
        return;
    }
    let na = match side {
        Side::Left => m,
        Side::Right => n,
    };
    let mut w = take_buf::<T>(na * na);
    for cc in 0..na {
        for rr in 0..na {
            w[cc * na + rr] = sym_elem(a, lda, uplo, rr, cc);
        }
    }
    match side {
        Side::Left => {
            gemm_packed(Trans::No, Trans::No, m, n, m, alpha, &w, na, b, ldb, beta, c, ldc)
        }
        Side::Right => {
            gemm_packed(Trans::No, Trans::No, m, n, n, alpha, b, ldb, &w, na, beta, c, ldc)
        }
    }
    give_buf(w);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hostblas::gemm::gemm_ref;
    use crate::util::prng::Prng;

    fn close(a: &[f64], b: &[f64], tol: f64) -> bool {
        a.iter().zip(b).all(|(x, y)| {
            (x.is_nan() && y.is_nan()) || (x - y).abs() <= tol * x.abs().max(y.abs()).max(1.0)
        })
    }

    /// Triangle-stored symmetric matrix with NaN in the unread half.
    fn rand_sym(rng: &mut Prng, n: usize, uplo: Uplo) -> Vec<f64> {
        let mut a = vec![f64::NAN; n * n];
        for c in 0..n {
            for r in 0..n {
                if in_tri(uplo, r, c) {
                    a[c * n + r] = rng.range_f64(-1.0, 1.0);
                }
            }
        }
        a
    }

    fn densify(a: &[f64], n: usize, uplo: Uplo) -> Vec<f64> {
        let mut d = vec![0.0; n * n];
        for c in 0..n {
            for r in 0..n {
                d[c * n + r] = sym_elem(a, n, uplo, r, c);
            }
        }
        d
    }

    #[test]
    fn syrk_matches_dense_gemm() {
        let mut rng = Prng::new(11);
        let (n, k) = (7, 5);
        for &uplo in &[Uplo::Upper, Uplo::Lower] {
            for &trans in &[Trans::No, Trans::Yes] {
                let (ar, ac) = if trans == Trans::No { (n, k) } else { (k, n) };
                let mut a = vec![0.0; ar * ac];
                rng.fill_f64(&mut a, -1.0, 1.0);
                let mut c = vec![f64::NAN; n * n];
                for j in 0..n {
                    for i in 0..n {
                        if in_tri(uplo, i, j) {
                            c[j * n + i] = rng.range_f64(-1.0, 1.0);
                        }
                    }
                }
                let c0 = c.clone();
                syrk_ref(uplo, trans, n, k, 1.2, &a, ar, 0.3, &mut c, n);
                // dense expectation over full matrix, compare triangle
                let mut full = vec![0.0; n * n];
                let (ta, tb) = if trans == Trans::No {
                    (Trans::No, Trans::Yes)
                } else {
                    (Trans::Yes, Trans::No)
                };
                gemm_ref(ta, tb, n, n, k, 1.2, &a, ar, &a, ar, 0.0, &mut full, n);
                for j in 0..n {
                    for i in 0..n {
                        if in_tri(uplo, i, j) {
                            let expect = full[j * n + i] + 0.3 * c0[j * n + i];
                            assert!(
                                (c[j * n + i] - expect).abs() < 1e-10,
                                "{uplo:?} {trans:?} ({i},{j})"
                            );
                        } else {
                            assert!(c[j * n + i].is_nan(), "other triangle must be untouched");
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn syr2k_symmetry_of_result() {
        let mut rng = Prng::new(13);
        let (n, k) = (6, 4);
        let mut a = vec![0.0; n * k];
        let mut b = vec![0.0; n * k];
        rng.fill_f64(&mut a, -1.0, 1.0);
        rng.fill_f64(&mut b, -1.0, 1.0);
        // compute both triangles with beta=0; result must be symmetric
        let mut cu = vec![0.0; n * n];
        let mut cl = vec![0.0; n * n];
        syr2k_ref(Uplo::Upper, Trans::No, n, k, 1.0, &a, n, &b, n, 0.0, &mut cu, n);
        syr2k_ref(Uplo::Lower, Trans::No, n, k, 1.0, &a, n, &b, n, 0.0, &mut cl, n);
        for j in 0..n {
            for i in 0..=j {
                assert!((cu[j * n + i] - cl[i * n + j]).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn syr2k_trans_matches_dense() {
        let mut rng = Prng::new(17);
        let (n, k) = (5, 7);
        let mut a = vec![0.0; k * n];
        let mut b = vec![0.0; k * n];
        rng.fill_f64(&mut a, -1.0, 1.0);
        rng.fill_f64(&mut b, -1.0, 1.0);
        let mut c = vec![0.0; n * n];
        syr2k_ref(Uplo::Upper, Trans::Yes, n, k, 2.0, &a, k, &b, k, 0.0, &mut c, n);
        // dense: 2(AᵀB + BᵀA)
        let mut d1 = vec![0.0; n * n];
        let mut d2 = vec![0.0; n * n];
        gemm_ref(Trans::Yes, Trans::No, n, n, k, 2.0, &a, k, &b, k, 0.0, &mut d1, n);
        gemm_ref(Trans::Yes, Trans::No, n, n, k, 2.0, &b, k, &a, k, 0.0, &mut d2, n);
        for j in 0..n {
            for i in 0..=j {
                assert!((c[j * n + i] - (d1[j * n + i] + d2[j * n + i])).abs() < 1e-10);
            }
        }
    }

    #[test]
    fn symm_matches_dense_and_never_reads_other_triangle() {
        let mut rng = Prng::new(19);
        let (m, n) = (6, 5);
        for &side in &[Side::Left, Side::Right] {
            for &uplo in &[Uplo::Upper, Uplo::Lower] {
                let na = if side == Side::Left { m } else { n };
                let a = rand_sym(&mut rng, na, uplo);
                let ad = densify(&a, na, uplo);
                let mut b = vec![0.0; m * n];
                rng.fill_f64(&mut b, -1.0, 1.0);
                let mut c = vec![0.0; m * n];
                rng.fill_f64(&mut c, -1.0, 1.0);
                let c0 = c.clone();
                symm_ref(side, uplo, m, n, 1.1, &a, na, &b, m, 0.4, &mut c, m);
                let mut expect = c0;
                match side {
                    Side::Left => {
                        gemm_ref(Trans::No, Trans::No, m, n, m, 1.1, &ad, na, &b, m, 0.4, &mut expect, m)
                    }
                    Side::Right => {
                        gemm_ref(Trans::No, Trans::No, m, n, n, 1.1, &b, m, &ad, na, 0.4, &mut expect, m)
                    }
                }
                assert!(close(&c, &expect, 1e-10), "symm {side:?} {uplo:?}");
                assert!(!c.iter().any(|x| x.is_nan()), "NaN leaked from unread triangle");
            }
        }
    }
}
