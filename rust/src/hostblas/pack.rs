//! Per-thread pack scratch for the packed kernel engine.
//!
//! The seed `gemm_blocked` allocated two fresh `Vec`s per call — on a
//! tile-task workload that is two heap round-trips per *k-step*, easily
//! thousands per routine call. [`PackBuf`] moves the pack panels into a
//! thread-local that survives across kernel invocations, so on
//! long-lived threads (the real engine's device workers, the serial
//! kernel path) steady-state execution allocates nothing — buffers only
//! grow, monotonically, to the largest panel the thread has seen.
//! `gemm_mt`'s forked cells get the same guarantee: they run on the
//! persistent [`crate::runtime::KernelPool`], whose threads — and
//! therefore these thread-locals — survive across calls.
//!
//! [`take_buf`]/[`give_buf`] are the same idea for the macro-kernels'
//! workspace needs (densified triangles, B copies): a thread-local
//! free-list of `Vec<T>` keyed by element type. A stack (not a single
//! slot) so nested macro-kernels each get their own buffer.

use crate::api::types::Scalar;
use std::any::TypeId;
use std::cell::RefCell;

/// Reusable pack panels for one thread: `a` holds the packed op(A)
/// block (MR-row strips), `b` the packed op(B) panel (NR-column
/// strips).
pub struct PackBuf<T> {
    pub a: Vec<T>,
    pub b: Vec<T>,
}

impl<T: Scalar> PackBuf<T> {
    pub const fn new() -> PackBuf<T> {
        PackBuf { a: Vec::new(), b: Vec::new() }
    }

    /// Grow (never shrink) the panels to at least the given element
    /// counts. Newly exposed elements are zeroed; the pack loops
    /// overwrite everything they read, so stale tails are harmless.
    pub fn ensure(&mut self, a_elems: usize, b_elems: usize) {
        if self.a.len() < a_elems {
            self.a.resize(a_elems, T::zero());
        }
        if self.b.len() < b_elems {
            self.b.resize(b_elems, T::zero());
        }
    }
}

impl<T: Scalar> Default for PackBuf<T> {
    fn default() -> Self {
        PackBuf::new()
    }
}

thread_local! {
    static PACK_F32: RefCell<PackBuf<f32>> = const { RefCell::new(PackBuf::new()) };
    static PACK_F64: RefCell<PackBuf<f64>> = const { RefCell::new(PackBuf::new()) };
    static BUFS_F32: RefCell<Vec<Vec<f32>>> = const { RefCell::new(Vec::new()) };
    static BUFS_F64: RefCell<Vec<Vec<f64>>> = const { RefCell::new(Vec::new()) };
}

/// Run `f` with this thread's reusable [`PackBuf`] for `T`.
///
/// Falls back to a fresh (per-call) buffer if the thread-local is
/// already borrowed (re-entrant kernel call) or `T` is neither f32 nor
/// f64 — correctness never depends on the reuse.
pub fn with_pack<T, R, F>(f: F) -> R
where
    T: Scalar,
    F: FnOnce(&mut PackBuf<T>) -> R,
{
    if TypeId::of::<T>() == TypeId::of::<f64>() {
        PACK_F64.with(|cell| match cell.try_borrow_mut() {
            Ok(mut pb) => {
                // SAFETY: TypeId equality above proves T == f64, so
                // PackBuf<f64> and PackBuf<T> are the same type.
                let pb: &mut PackBuf<T> =
                    unsafe { &mut *(&mut *pb as *mut PackBuf<f64>).cast::<PackBuf<T>>() };
                f(pb)
            }
            Err(_) => f(&mut PackBuf::new()),
        })
    } else if TypeId::of::<T>() == TypeId::of::<f32>() {
        PACK_F32.with(|cell| match cell.try_borrow_mut() {
            Ok(mut pb) => {
                // SAFETY: as above with T == f32.
                let pb: &mut PackBuf<T> =
                    unsafe { &mut *(&mut *pb as *mut PackBuf<f32>).cast::<PackBuf<T>>() };
                f(pb)
            }
            Err(_) => f(&mut PackBuf::new()),
        })
    } else {
        f(&mut PackBuf::new())
    }
}

/// Reinterpret a `Vec<A>` as `Vec<B>` where the caller has proven
/// `A == B` (same `TypeId`).
fn cast_vec<A: 'static, B: 'static>(v: Vec<A>) -> Vec<B> {
    debug_assert_eq!(TypeId::of::<A>(), TypeId::of::<B>());
    let mut v = std::mem::ManuallyDrop::new(v);
    // SAFETY: A == B per the caller's TypeId check, so ptr/len/capacity
    // describe a valid Vec<B>.
    unsafe { Vec::from_raw_parts(v.as_mut_ptr().cast::<B>(), v.len(), v.capacity()) }
}

/// Take a workspace of `len` elements from this thread's free-list (or
/// allocate one). **Contents are unspecified** — a recycled buffer
/// keeps its previous values (only newly grown elements are zeroed), so
/// callers must fully overwrite before reading; every macro-kernel use
/// does (densify/copy/beta-0 GEMM). Not re-zeroing avoids an O(len)
/// memset per kernel task — the same class of waste the tile-acquire
/// path eliminated (EXPERIMENTS.md §Perf). Return the buffer with
/// [`give_buf`] so the allocation is reused; dropping it is merely
/// slower.
pub fn take_buf<T: Scalar>(len: usize) -> Vec<T> {
    let recycled: Option<Vec<T>> = if TypeId::of::<T>() == TypeId::of::<f64>() {
        BUFS_F64.with(|s| s.borrow_mut().pop()).map(cast_vec::<f64, T>)
    } else if TypeId::of::<T>() == TypeId::of::<f32>() {
        BUFS_F32.with(|s| s.borrow_mut().pop()).map(cast_vec::<f32, T>)
    } else {
        None
    };
    let mut v = recycled.unwrap_or_default();
    if v.len() > len {
        v.truncate(len);
    } else {
        v.resize(len, T::zero());
    }
    v
}

/// Return a workspace taken with [`take_buf`] to the thread free-list.
pub fn give_buf<T: Scalar>(v: Vec<T>) {
    if TypeId::of::<T>() == TypeId::of::<f64>() {
        BUFS_F64.with(|s| s.borrow_mut().push(cast_vec::<T, f64>(v)));
    } else if TypeId::of::<T>() == TypeId::of::<f32>() {
        BUFS_F32.with(|s| s.borrow_mut().push(cast_vec::<T, f32>(v)));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pack_buf_grows_monotonically() {
        let mut pb: PackBuf<f64> = PackBuf::new();
        pb.ensure(16, 8);
        assert_eq!(pb.a.len(), 16);
        assert_eq!(pb.b.len(), 8);
        pb.a[3] = 7.0;
        pb.ensure(4, 4); // never shrinks
        assert_eq!(pb.a.len(), 16);
        assert_eq!(pb.a[3], 7.0);
        pb.ensure(32, 8);
        assert_eq!(pb.a.len(), 32);
    }

    #[test]
    fn with_pack_reuses_capacity() {
        let cap0 = with_pack(|pb: &mut PackBuf<f64>| {
            pb.ensure(1024, 1024);
            pb.a.capacity()
        });
        let cap1 = with_pack(|pb: &mut PackBuf<f64>| pb.a.capacity());
        assert!(cap1 >= cap0);
        assert!(cap1 >= 1024);
    }

    #[test]
    fn take_give_roundtrip() {
        let mut v = take_buf::<f32>(100);
        // fresh buffers are fully zero-initialized
        assert!(v.iter().all(|&x| x == 0.0));
        v[0] = 5.0;
        let cap = v.capacity();
        give_buf(v);
        // recycling keeps the allocation; contents are unspecified (and
        // deliberately NOT re-zeroed), only the length is guaranteed
        let v2 = take_buf::<f32>(50);
        assert_eq!(v2.len(), 50);
        assert!(v2.capacity() >= cap.min(50));
        give_buf(v2);
        // growing past the recycled length zero-fills the new tail
        let v3 = take_buf::<f32>(200);
        assert_eq!(v3.len(), 200);
        assert!(v3[50..].iter().all(|&x| x == 0.0));
        give_buf(v3);
    }

    #[test]
    fn nested_take_is_distinct() {
        let mut a = take_buf::<f64>(8);
        let mut b = take_buf::<f64>(8);
        a[0] = 1.0;
        b[0] = 2.0;
        assert_eq!(a[0], 1.0);
        give_buf(a);
        give_buf(b);
    }
}
