//! Runtime blocking-parameter selection for the packed kernel engine.
//!
//! The seed kernel hard-coded `MC/NC/KC`; the right values depend on the
//! host's cache sizes (the motivation in the ML-driven BLAS-L3 runtime
//! work, arXiv 2406.19621 — measured behaviour beats static constants).
//! With the `autotune` feature (default **on**) the first kernel call
//! per dtype sweeps a small `KC/MC` candidate grid on a probe-sized GEMM
//! and caches the winner for the process lifetime; without it (or with
//! `BLASX_NO_TUNE=1` in the environment) the static defaults are used.
//!
//! The probe costs a few tens of milliseconds once per process — noise
//! against any workload long enough to care about kernel throughput —
//! and never changes numerics, only blocking.

use crate::api::types::Dtype;
use std::sync::OnceLock;

/// Cache-blocking parameters of the packed GEMM engine.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct BlockDims {
    /// Rows of the packed op(A) block (L2-resident, MR-strip layout).
    pub mc: usize,
    /// Columns of the packed op(B) panel.
    pub nc: usize,
    /// Depth of both packs (L1-resident micro-panels).
    pub kc: usize,
}

/// Static defaults: `MC×KC` f64 ≈ 256 KiB (typical L2), `KC×NR` f64 =
/// 8 KiB (comfortably L1).
pub const DEFAULT_DIMS: BlockDims = BlockDims { mc: 128, nc: 2048, kc: 256 };

static DIMS_F32: OnceLock<BlockDims> = OnceLock::new();
static DIMS_F64: OnceLock<BlockDims> = OnceLock::new();

/// The process-wide blocking for `dt`, probing once on first use.
pub fn block_dims(dt: Dtype) -> BlockDims {
    let cell = match dt {
        Dtype::F32 => &DIMS_F32,
        Dtype::F64 => &DIMS_F64,
    };
    *cell.get_or_init(|| probe(dt))
}

#[cfg(feature = "autotune")]
fn probe(dt: Dtype) -> BlockDims {
    // Debug builds: timing a deoptimized kernel picks garbage and slows
    // every test binary's first kernel call — static defaults instead.
    if cfg!(debug_assertions) || std::env::var_os("BLASX_NO_TUNE").is_some() {
        return DEFAULT_DIMS;
    }
    match dt {
        Dtype::F32 => probe_t::<f32>(),
        Dtype::F64 => probe_t::<f64>(),
    }
}

#[cfg(not(feature = "autotune"))]
fn probe(_dt: Dtype) -> BlockDims {
    DEFAULT_DIMS
}

/// Candidate `(mc, kc)` pairs: the default plus neighbours that win on
/// hosts with smaller/larger private caches.
#[cfg(feature = "autotune")]
const CANDIDATES: [(usize, usize); 4] = [(128, 256), (64, 128), (96, 192), (256, 256)];

#[cfg(feature = "autotune")]
fn probe_t<T: crate::api::types::Scalar>() -> BlockDims {
    use super::gemm::gemm_packed_with;
    use crate::api::types::Trans;

    // Must exceed every candidate mc AND kc, or the clamped run would
    // be identical work to a smaller blocking and the "winner" would
    // be one the probe never actually measured. 288 > 256; ~48 MFLOP
    // per timing, ≲100 ms total once per process per dtype.
    const N: usize = 288;
    let a = vec![T::from_f64(0.37); N * N];
    let b = vec![T::from_f64(-0.81); N * N];
    let mut c = vec![T::zero(); N * N];

    let mut best = DEFAULT_DIMS;
    let mut best_ns = u128::MAX;
    for (i, &(mc, kc)) in CANDIDATES.iter().enumerate() {
        let dims = BlockDims { mc, nc: DEFAULT_DIMS.nc, kc };
        // one warm-up (page-in, branch history), then best-of-2
        let reps = if i == 0 { 3 } else { 2 };
        let mut cand_ns = u128::MAX;
        for r in 0..reps {
            let t0 = std::time::Instant::now();
            gemm_packed_with(
                dims,
                Trans::No,
                Trans::No,
                N,
                N,
                N,
                T::one(),
                &a,
                N,
                &b,
                N,
                T::zero(),
                &mut c,
                N,
            );
            let ns = t0.elapsed().as_nanos();
            if !(i == 0 && r == 0) {
                cand_ns = cand_ns.min(ns);
            }
        }
        if cand_ns < best_ns {
            best_ns = cand_ns;
            best = dims;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn block_dims_are_cached_and_sane() {
        let d1 = block_dims(Dtype::F64);
        let d2 = block_dims(Dtype::F64);
        assert_eq!(d1, d2, "probe must run at most once per dtype");
        assert!(d1.mc >= 32 && d1.kc >= 32 && d1.nc >= 128);
        let f = block_dims(Dtype::F32);
        assert!(f.mc >= 32);
    }

    #[test]
    fn defaults_fit_reasonable_caches() {
        // MC×KC f64 pack must stay within a plausible L2.
        assert!(DEFAULT_DIMS.mc * DEFAULT_DIMS.kc * 8 <= 512 << 10);
    }
}
