//! Multi-tenant job scheduling for the resident runtime (serving mode).
//!
//! PR 3's persistent runtime kept the engine warm between calls but
//! funnelled every call through a one-at-a-time submit mutex: a serving
//! daemon with many client threads left the device workers parked
//! between jobs — exactly the under-utilization BLASX's dynamic
//! asynchronous runtime exists to remove, re-created one level up. This
//! subsystem replaces the serializing slot with an **admission queue**
//! over a **multi-job slot table**:
//!
//! - **Admission** ([`admission`]) — each in-flight call becomes a
//!   *job* with its own task namespace (its private `JobState`: queue,
//!   dependency counts, reservation stations, transfer counters — the
//!   whole-job generalization of the batch subsystem's per-problem
//!   `KeyMap` namespacing; in the real engine, tile addresses are
//!   already globally namespaced by host address + stride + epoch).
//!   Jobs whose output byte ranges overlap another live job's inputs
//!   or outputs are ordered by an admission-time dependency edge —
//!   aliasing calls execute in submission order, bit-for-bit identical
//!   to serial execution — while disjoint jobs run concurrently with
//!   no global lock.
//! - **Interleaving** ([`fairness`]) — device workers pull scheduler
//!   *rounds* (up to `n_streams` tasks, the Stream-K-style quantum the
//!   batch splitter uses intra-batch) across ALL runnable jobs,
//!   picking the job with the smallest charged-flops/weight ratio so
//!   every tenant progresses proportionally to its size and small
//!   jobs are never starved behind a giant one.
//! - **Completion** ([`handle`]) — [`JobHandle`] is the per-job future
//!   returned by [`crate::api::Scope`]'s routine methods and the thin
//!   Rust side of the C ABI's `blasx_job_t`; blocking calls are
//!   submit-then-wait over the same machinery. Soundness of the scoped
//!   form lives in `handle::ScopeToken`: the completion barrier runs
//!   in `Context::scope`'s own stack frame, so no safe caller-side
//!   operation (`mem::forget` included) can skip it.
//!
//! Coherence across tenants needs no new mechanism: the epoch registry
//! stamps invalidation generations at admission (under the same lock
//! that computes conflict edges, so epoch order == admission order),
//! and tile-cache keys already carry address + stride + epoch **and
//! tile size** — each geometry is its own cache generation, so jobs
//! with different tile sizes coexist in the caches and overlap on the
//! devices like any other disjoint jobs; a tile-size switch needs no
//! barrier and no purge.

pub mod admission;
pub mod fairness;
pub mod handle;

pub use handle::JobHandle;

use crate::coordinator::real_engine::{EngineCore, RealReport, Round};
use crate::error::Result;

/// A submitted job, erased over its scalar type so one worker fleet
/// serves f32 and f64 tenants alike. Implemented by the runtime's
/// `ErasedJob`/`OwnedJob` (tiled) and `HostGemm` (host-placed) — see
/// `crate::runtime::service`.
pub(crate) trait DeviceJob: Send + Sync {
    /// Execute one scheduler round of this job on device `dev`.
    fn run_round(&self, dev: usize, core: &EngineCore) -> Round;

    /// Poison the job (contained worker panic): it fails instead of
    /// wedging the fleet.
    fn poison(&self, msg: String);

    /// Abort the job with a specific error (deadline expiry,
    /// cooperative cancellation). First failure wins; in-flight rounds
    /// finish their current tasks, no new rounds start. The default is
    /// a no-op so test doubles need not care.
    fn abort(&self, err: crate::error::Error) {
        let _ = err;
    }

    /// The job's fault-recovery counters (operations retried, tasks
    /// degraded to the host path, tasks migrated off a lost device).
    /// Safe while in flight; all zeros by default.
    fn fault_stats(&self) -> crate::coordinator::FaultStats {
        crate::coordinator::FaultStats::default()
    }

    /// Have all of the job's tasks completed? (A `Progress` round may
    /// have executed the last task without observing `Finished`; the
    /// worker folds this in to retire without an extra idle probe.)
    fn done(&self) -> bool;

    /// Assemble the job's call report. Call once, after the job has
    /// retired (the failure slot is drained).
    fn report(&self, core: &EngineCore) -> Result<RealReport>;

    /// Live observability counters of the job so far — safe to call
    /// while it is in flight (unlike `report`). The default is all
    /// zeros so test doubles need not care.
    fn stats(&self) -> crate::coordinator::JobStats {
        crate::coordinator::JobStats::default()
    }
}
