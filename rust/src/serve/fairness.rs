//! Flop-weighted fair interleaving across live jobs.
//!
//! The batch subsystem's quanta splitter (`crate::batch::quanta`)
//! balances *one* fused task set by emitting flop-balanced,
//! problem-interleaved groups up front. Multi-tenant serving is the
//! same problem one level up — many independent task sets arriving at
//! unpredictable times — so the static plan becomes a dynamic ledger:
//! every job carries a *weight* (its total chain flops) and a *charged*
//! counter (flops executed on its behalf so far), and each device picks
//! the runnable job with the smallest `charged / weight` ratio before
//! pulling its next scheduler round (≤ `n_streams` tasks — the
//! quantum). Shares converge to proportional progress: concurrent
//! same-size jobs finish together instead of in admission order, and a
//! small job admitted next to a giant completes after a bounded number
//! of rounds instead of waiting for the giant to drain.
//!
//! The picker is pure (no clocks, no randomness) so admission-order tie
//! breaking keeps scheduling reproducible under `RUST_TEST_THREADS=1`.

/// One live job's ledger as the picker sees it.
#[derive(Clone, Copy, Debug)]
pub struct JobShare {
    /// Job id (admission order — also the tie breaker).
    pub id: u64,
    /// Fair-share weight: the job's total chain flops (floored at 1.0
    /// so degenerate zero-flop jobs still get picked and retire).
    pub weight: f64,
    /// Flops executed on the job's behalf so far.
    pub charged: f64,
    /// Submitting tenant — carried through the ledger so quota
    /// accounting and per-tenant observability read the same record
    /// the picker does.
    pub tenant: u32,
}

impl JobShare {
    /// Normalized progress — the quantity the picker minimizes.
    fn ratio(&self) -> f64 {
        self.charged / self.weight.max(1.0)
    }
}

/// Pick the next job for a device: the runnable job with the smallest
/// charged/weight ratio, excluding `skip` (jobs this device already
/// probed and found idle since the table last changed). Ties break by
/// id, i.e. admission order. Runs under the job-table lock, so it
/// allocates nothing and probes `skip` in O(1).
pub fn pick(shares: &[JobShare], skip: &std::collections::HashSet<u64>) -> Option<u64> {
    shares
        .iter()
        .filter(|s| !skip.contains(&s.id))
        .min_by(|a, b| {
            a.ratio()
                .partial_cmp(&b.ratio())
                .unwrap_or(std::cmp::Ordering::Equal)
                .then(a.id.cmp(&b.id))
        })
        .map(|s| s.id)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::HashSet;

    fn share(id: u64, weight: f64, charged: f64) -> JobShare {
        JobShare { id, weight, charged, tenant: 0 }
    }

    fn skip(ids: &[u64]) -> HashSet<u64> {
        ids.iter().copied().collect()
    }

    #[test]
    fn least_served_ratio_wins() {
        let shares = [share(1, 100.0, 50.0), share(2, 100.0, 10.0), share(3, 1000.0, 400.0)];
        // ratios: 0.5, 0.1, 0.4
        assert_eq!(pick(&shares, &skip(&[])), Some(2));
    }

    #[test]
    fn weighting_gives_big_jobs_proportional_share() {
        // A big job charged the same absolute flops as a small one has
        // the smaller ratio, so it runs next: both progress toward
        // completion at the same *relative* rate.
        let shares = [share(1, 10_000.0, 500.0), share(2, 1_000.0, 500.0)];
        assert_eq!(pick(&shares, &skip(&[])), Some(1));
    }

    #[test]
    fn skip_excludes_idle_probed_jobs() {
        let shares = [share(1, 100.0, 0.0), share(2, 100.0, 90.0)];
        assert_eq!(pick(&shares, &skip(&[1])), Some(2));
        assert_eq!(pick(&shares, &skip(&[1, 2])), None);
    }

    #[test]
    fn ties_break_by_admission_order() {
        let shares = [share(7, 100.0, 10.0), share(3, 100.0, 10.0)];
        assert_eq!(pick(&shares, &skip(&[])), Some(3));
    }

    #[test]
    fn zero_weight_jobs_are_still_pickable() {
        // A degenerate empty job must be picked (and then observed
        // Finished) rather than dividing by zero or starving.
        let shares = [share(1, 0.0, 0.0)];
        assert_eq!(pick(&shares, &skip(&[])), Some(1));
    }

    #[test]
    fn proportional_progress_simulation() {
        // Simulate rounds: two jobs, 3:1 weight ratio, equal per-round
        // charge. After many picks the big job should have been served
        // ~3x the rounds of the small one.
        let mut a = share(1, 300.0, 0.0);
        let mut b = share(2, 100.0, 0.0);
        let none = skip(&[]);
        let (mut picks_a, mut picks_b) = (0u32, 0u32);
        for _ in 0..200 {
            match pick(&[a, b], &none) {
                Some(1) => {
                    a.charged += 1.0;
                    picks_a += 1;
                }
                Some(2) => {
                    b.charged += 1.0;
                    picks_b += 1;
                }
                other => panic!("unexpected pick {other:?}"),
            }
        }
        assert!(picks_a > 2 * picks_b, "{picks_a} vs {picks_b}");
        assert!(picks_b > 0, "small job must not starve");
    }
}
