//! Admission queue + multi-job slot table.
//!
//! Every in-flight API call is a [`JobEntry`] in the [`JobTable`]. The
//! table is the single piece of shared scheduler state (one mutex in
//! `runtime::service::Inner` guards it); all methods here are called
//! under that lock, so the bookkeeping is plain fields, not atomics.
//!
//! ## Conflict ordering instead of a global lock
//!
//! At admission a job's operand **byte ranges** are compared against
//! every live job's: a RAW/WAR/WAW overlap on host memory creates a
//! dependency edge (the new job waits for the live one to retire).
//! Edges only ever point at earlier-admitted jobs, so the dependency
//! graph is acyclic by construction and aliasing calls execute in
//! admission order — bit-for-bit what a serial client would get —
//! while disjoint jobs overlap freely on the devices.
//!
//! Epoch stamping (see `runtime::service`) happens under the same lock
//! and in the same order as edge creation, which is what keeps the
//! tile-cache epoch discipline equivalent to the serialized PR 3
//! runtime.
//!
//! ## Per-geometry cache generations (no barriers, no purges)
//!
//! Block geometry is a discriminant of [`crate::tile::TileKey`]: tiles
//! cached at `t=64` and `t=96` have different keys, so jobs with
//! different tile sizes coexist in one cache the same way two epochs
//! of one buffer do. A tile-size switch therefore needs **no
//! ordering at all** — the old barrier-job + global-purge path is
//! gone, and mixed-`t` tenants overlap on the devices like any other
//! disjoint jobs while each geometry's warm set survives untouched.
//! Stale generations fall out of the ALRU like any other cold tiles.
//! A *failed* job likewise needs no purge: the engine releases its
//! pins on every abort path and a lost device's cache entries are
//! invalidated surgically (`TileCaches::evict_device`), so other
//! tenants' warm tiles survive a neighbour's failure.
//!
//! ## Deadlines, cancellation and backpressure
//!
//! Tenant protection also lives here. An entry may carry an absolute
//! **deadline**; every [`JobCtl`] carries a cooperative **cancel**
//! flag ([`JobCtl::request_cancel`]); and [`JobTable::reap_expired`] —
//! run by workers before each round pick — aborts expired/cancelled
//! jobs with [`Error::DeadlineExceeded`] / [`Error::Cancelled`]
//! without disturbing their neighbours (checks happen at round
//! boundaries, never mid-kernel). Admission-side occupancy
//! ([`JobTable::live_count`], [`JobTable::tenant_inflight`]) lets the
//! runtime refuse work with an explicit [`Error::Backpressure`]
//! instead of queueing unboundedly.

use super::fairness::JobShare;
use super::DeviceJob;
use crate::error::Error;
use std::collections::HashSet;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::Instant;

/// Host byte ranges a job reads (`ins`) and writes (`outs`), one entry
/// per operand per problem.
#[derive(Clone, Debug, Default)]
pub(crate) struct JobSpan {
    pub ins: Vec<(usize, usize)>,
    pub outs: Vec<(usize, usize)>,
}

fn overlaps(a: (usize, usize), b: (usize, usize)) -> bool {
    a.0 < b.1 && b.0 < a.1
}

impl JobSpan {
    /// Must a job with span `new` wait for a live job with span `live`?
    /// True on any write-write, write-read, or read-write overlap
    /// (read-read sharing is the good case — shared cache tiles).
    pub fn conflicts(new: &JobSpan, live: &JobSpan) -> bool {
        new.outs
            .iter()
            .any(|&o| live.outs.iter().chain(live.ins.iter()).any(|&x| overlaps(o, x)))
            || new.ins.iter().any(|&i| live.outs.iter().any(|&o| overlaps(i, o)))
    }
}

/// Per-job completion latch, shared by the waiters (a blocking submit,
/// a [`super::JobHandle`], the owning scope's
/// [`super::handle::ScopeToken`], or an FFI wait) and the retiring
/// worker. `retired` means the job has left the table and no worker
/// holds a reference to it — the waiter may reclaim the memory behind
/// the job's operand wraps.
pub(crate) struct JobCtl {
    pub id: u64,
    retired: AtomicBool,
    /// Did some waiter deliver this job's report (and therefore its
    /// failure, if any) to user code? A scope's close re-reports the
    /// failures of jobs nobody observed — detached handles must not
    /// swallow errors — and skips the ones a `wait()` already
    /// surfaced.
    observed: AtomicBool,
    /// Cooperative cancellation request ([`super::JobHandle::cancel`]
    /// or an FFI cancel). Honored by [`JobTable::reap_expired`] at the
    /// next round boundary; a job that finishes first wins the race
    /// and reports normally.
    cancelled: AtomicBool,
    mx: Mutex<()>,
    cv: Condvar,
}

impl JobCtl {
    fn new(id: u64) -> JobCtl {
        JobCtl {
            id,
            retired: AtomicBool::new(false),
            observed: AtomicBool::new(false),
            cancelled: AtomicBool::new(false),
            mx: Mutex::new(()),
            cv: Condvar::new(),
        }
    }

    /// A waiter is delivering this job's report to user code.
    pub fn mark_observed(&self) {
        self.observed.store(true, Ordering::SeqCst);
    }

    /// Request cooperative cancellation: the job is aborted with
    /// [`Error::Cancelled`] at the next round boundary (in-flight
    /// rounds finish their tasks — outputs are never torn mid-tile).
    pub fn request_cancel(&self) {
        self.cancelled.store(true, Ordering::SeqCst);
    }

    pub fn is_cancelled(&self) -> bool {
        self.cancelled.load(Ordering::SeqCst)
    }

    pub fn is_observed(&self) -> bool {
        self.observed.load(Ordering::SeqCst)
    }

    /// Construct a detached latch (unit tests outside this module).
    #[cfg(test)]
    pub(crate) fn new_for_tests(id: u64) -> JobCtl {
        JobCtl::new(id)
    }

    pub fn is_retired(&self) -> bool {
        self.retired.load(Ordering::SeqCst)
    }

    /// Mark retired and wake the waiter. Called by the retiring worker
    /// AFTER the table has dropped its job reference.
    pub fn retire(&self) {
        let _g = self.mx.lock().unwrap_or_else(|e| e.into_inner());
        self.retired.store(true, Ordering::SeqCst);
        self.cv.notify_all();
    }

    /// Park until the job retires.
    pub fn wait_retired(&self) {
        let mut g = self.mx.lock().unwrap_or_else(|e| e.into_inner());
        while !self.retired.load(Ordering::SeqCst) {
            g = self.cv.wait(g).unwrap_or_else(|e| e.into_inner());
        }
    }
}

/// One live job in the table.
pub(crate) struct JobEntry {
    pub id: u64,
    pub job: Arc<dyn DeviceJob>,
    pub ctl: Arc<JobCtl>,
    pub span: JobSpan,
    /// Earlier live jobs this one must wait for (ids drain at their
    /// retirement; the job is runnable when empty).
    pub deps: HashSet<u64>,
    /// Devices currently inside a round of this job.
    pub active_rounds: usize,
    /// All tasks done (or the job failed): retire once `active_rounds`
    /// reaches zero.
    pub finishing: bool,
    /// Poisoned/errored — recorded for retirement bookkeeping (the
    /// waiter's report carries the failure). Failure schedules **no**
    /// cache purge: the engine releases the job's pins on every abort
    /// path, so neighbours keep their warm tiles.
    pub failed: bool,
    /// Fair-share ledger (see `super::fairness`).
    pub weight: f64,
    pub charged: f64,
    /// Submitting tenant (admission-side quota accounting).
    pub tenant: u32,
    /// Absolute deadline plus the configured limit in milliseconds
    /// (carried for the error message).
    pub deadline: Option<(Instant, u64)>,
}

/// What the caller (holding the table lock) must do after
/// [`JobTable::finish_round`].
#[derive(Default)]
pub(crate) struct FinishActions {
    /// The retired job's latch: count the call, then (outside the
    /// table lock) `retire()` it and wake the worker fleet.
    pub retired: Option<Arc<JobCtl>>,
    /// The retired entry's accumulated failed flag — may be true even
    /// when this round reported success (the job was reaped or failed
    /// on another device while this round was in flight).
    pub retired_failed: bool,
}

/// What the caller (holding the table lock) must do after
/// [`JobTable::reap_expired`].
#[derive(Default)]
pub(crate) struct ReapActions {
    /// Jobs reaped with no round in flight, paired with their fault
    /// counters (snapshotted before the table dropped its job
    /// reference): outside the table lock, `retire()` each latch and
    /// wake the fleet (their dependents may be runnable now).
    pub retired: Vec<(Arc<JobCtl>, crate::coordinator::FaultStats)>,
}

/// The multi-job slot table (see module docs).
pub(crate) struct JobTable {
    pub jobs: Vec<JobEntry>,
    next_id: u64,
    /// Bumped on every admission/retirement; workers use it to
    /// invalidate their "probed idle" memory cheaply.
    pub version: u64,
    /// Rounds in flight across all jobs (Σ active_rounds).
    pub rounds_active: usize,
}

impl Default for JobTable {
    fn default() -> JobTable {
        JobTable::new()
    }
}

impl JobTable {
    pub fn new() -> JobTable {
        JobTable { jobs: Vec::new(), next_id: 0, version: 0, rounds_active: 0 }
    }

    pub fn is_empty(&self) -> bool {
        self.jobs.is_empty()
    }

    /// Jobs currently admitted (running, queued, or finishing) — the
    /// quantity the runtime's admission capacity bounds.
    pub fn live_count(&self) -> usize {
        self.jobs.len()
    }

    /// Live jobs submitted by `tenant` (per-tenant in-flight quota).
    pub fn tenant_inflight(&self, tenant: u32) -> usize {
        self.jobs.iter().filter(|e| e.tenant == tenant).count()
    }

    fn entry(&mut self, id: u64) -> &mut JobEntry {
        self.jobs.iter_mut().find(|e| e.id == id).expect("job id not in table")
    }

    /// Admit a job: compute its dependency edges (byte-range conflicts
    /// against every live job — the *only* ordering that exists; tile
    /// geometry is a cache-key discriminant, not an ordering concern)
    /// and insert it.
    pub fn admit(
        &mut self,
        job: Arc<dyn DeviceJob>,
        span: JobSpan,
        weight: f64,
        tenant: u32,
        deadline: Option<(Instant, u64)>,
    ) -> Arc<JobCtl> {
        let id = self.next_id;
        self.next_id += 1;
        let deps: HashSet<u64> = self
            .jobs
            .iter()
            .filter(|e| JobSpan::conflicts(&span, &e.span))
            .map(|e| e.id)
            .collect();
        let ctl = Arc::new(JobCtl::new(id));
        self.jobs.push(JobEntry {
            id,
            job,
            ctl: ctl.clone(),
            span,
            deps,
            active_rounds: 0,
            finishing: false,
            failed: false,
            weight,
            charged: 0.0,
            tenant,
            deadline,
        });
        self.version += 1;
        ctl
    }

    /// Fair-share ledgers of the currently runnable jobs (dependencies
    /// drained, not yet finishing).
    pub fn runnable_shares(&self) -> Vec<JobShare> {
        self.jobs
            .iter()
            .filter(|e| e.deps.is_empty() && !e.finishing)
            .map(|e| JobShare {
                id: e.id,
                weight: e.weight,
                charged: e.charged,
                tenant: e.tenant,
            })
            .collect()
    }

    /// Abort every expired or cancelled job: its state is failed with
    /// the matching error ([`Error::DeadlineExceeded`] /
    /// [`Error::Cancelled`]), it stops being runnable, and — if no
    /// device is inside one of its rounds — it retires on the spot.
    /// Jobs with rounds in flight retire through the normal
    /// [`JobTable::finish_round`] path when those rounds drain (an
    /// in-flight round finishes its tasks; outputs are never torn).
    /// Called by workers before each round pick; the no-deadline,
    /// no-cancel fast path is one scan without a clock read.
    pub fn reap_expired(&mut self) -> ReapActions {
        let mut acts = ReapActions::default();
        if !self
            .jobs
            .iter()
            .any(|e| !e.finishing && (e.deadline.is_some() || e.ctl.is_cancelled()))
        {
            return acts;
        }
        let now = Instant::now();
        let mut doomed: Vec<u64> = Vec::new();
        for e in &mut self.jobs {
            if e.finishing {
                continue;
            }
            let expired = e.deadline.is_some_and(|(at, _)| now >= at);
            if !expired && !e.ctl.is_cancelled() {
                continue;
            }
            let err = if expired {
                Error::DeadlineExceeded { limit_ms: e.deadline.expect("expired").1 }
            } else {
                Error::Cancelled
            };
            e.job.abort(err);
            e.finishing = true;
            e.failed = true;
            if e.active_rounds == 0 {
                doomed.push(e.id);
            }
        }
        for id in doomed {
            let idx = self.jobs.iter().position(|e| e.id == id).expect("reaped id");
            let entry = self.jobs.remove(idx);
            self.version += 1;
            for other in &mut self.jobs {
                other.deps.remove(&id);
            }
            let faults = entry.job.fault_stats();
            acts.retired.push((entry.ctl, faults));
        }
        acts
    }

    /// Begin a round of job `id` on some device: pins the job in the
    /// table (it cannot retire while `active_rounds > 0`).
    pub fn start_round(&mut self, id: u64) -> Arc<dyn DeviceJob> {
        self.rounds_active += 1;
        let e = self.entry(id);
        e.active_rounds += 1;
        e.job.clone()
    }

    /// End a round of job `id`: charge the fair-share ledger, record a
    /// finished/failed observation, and retire the job if it is done
    /// and no device is still inside one of its rounds. The returned
    /// actions must be applied by the caller (see [`FinishActions`]).
    pub fn finish_round(
        &mut self,
        id: u64,
        flops: f64,
        finished: bool,
        failed: bool,
    ) -> FinishActions {
        self.rounds_active -= 1;
        let (finishing, active_rounds) = {
            let e = self.entry(id);
            e.active_rounds -= 1;
            e.charged += flops;
            if finished || failed {
                e.finishing = true;
                e.failed |= failed;
            }
            (e.finishing, e.active_rounds)
        };
        let mut actions = FinishActions::default();
        if finishing && active_rounds == 0 {
            let idx = self.jobs.iter().position(|e| e.id == id).unwrap();
            let entry = self.jobs.remove(idx);
            self.version += 1;
            for other in &mut self.jobs {
                other.deps.remove(&id);
            }
            actions.retired_failed = entry.failed;
            actions.retired = Some(entry.ctl);
        }
        // Neither retirement nor failure schedules any cache purge:
        // the engine releases a failed job's pins on every abort path,
        // lost-device state is evicted surgically, and tile-geometry
        // changes are cache-key generations, not cache-wide events.
        actions
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::real_engine::{EngineCore, RealReport, Round};
    use crate::error::{Error, Result};

    struct StubJob;
    impl DeviceJob for StubJob {
        fn run_round(&self, _dev: usize, _core: &EngineCore) -> Round {
            Round::Idle
        }
        fn poison(&self, _msg: String) {}
        fn done(&self) -> bool {
            false
        }
        fn report(&self, _core: &EngineCore) -> Result<RealReport> {
            Err(Error::Internal("stub".into()))
        }
    }

    fn stub() -> Arc<dyn DeviceJob> {
        Arc::new(StubJob)
    }

    fn span(ins: &[(usize, usize)], outs: &[(usize, usize)]) -> JobSpan {
        JobSpan { ins: ins.to_vec(), outs: outs.to_vec() }
    }

    #[test]
    fn disjoint_jobs_are_concurrently_runnable() {
        let mut t = JobTable::new();
        let c0 = t.admit(stub(), span(&[(0, 100)], &[(100, 200)]), 10.0, 0, None);
        let c1 = t.admit(stub(), span(&[(300, 400)], &[(400, 500)]), 10.0, 0, None);
        let ids: Vec<u64> = t.runnable_shares().iter().map(|s| s.id).collect();
        assert_eq!(ids, vec![c0.id, c1.id]);
    }

    #[test]
    fn raw_conflict_orders_by_admission() {
        let mut t = JobTable::new();
        // job0 writes [100,200); job1 reads it → dependency edge.
        let c0 = t.admit(stub(), span(&[(0, 100)], &[(100, 200)]), 10.0, 0, None);
        let c1 = t.admit(stub(), span(&[(150, 160)], &[(500, 600)]), 10.0, 0, None);
        let ids: Vec<u64> = t.runnable_shares().iter().map(|s| s.id).collect();
        assert_eq!(ids, vec![c0.id], "reader must wait for the live writer");
        // retire job0: one idle probe then a finished round
        let _ = t.start_round(c0.id);
        let a = t.finish_round(c0.id, 0.0, true, false);
        assert!(a.retired.is_some());
        let ids: Vec<u64> = t.runnable_shares().iter().map(|s| s.id).collect();
        assert_eq!(ids, vec![c1.id], "dependency drained at retirement");
    }

    #[test]
    fn waw_and_war_conflicts_also_order() {
        let mut t = JobTable::new();
        let w0 = t.admit(stub(), span(&[], &[(100, 200)]), 1.0, 0, None);
        // WAW: same output range
        let w1 = t.admit(stub(), span(&[], &[(150, 250)]), 1.0, 0, None);
        // WAR: writes what job0 reads
        let _r = t.admit(stub(), span(&[(0, 50)], &[(300, 400)]), 1.0, 0, None);
        let w2 = t.admit(stub(), span(&[], &[(0, 10)]), 1.0, 0, None);
        assert!(t.jobs.iter().find(|e| e.id == w1.id).unwrap().deps.contains(&w0.id));
        assert!(t.jobs.iter().find(|e| e.id == w2.id).unwrap().deps.is_empty());
        // read-read sharing creates no edge
        let rr = t.admit(stub(), span(&[(0, 50)], &[(700, 800)]), 1.0, 0, None);
        assert!(t.jobs.iter().find(|e| e.id == rr.id).unwrap().deps.is_empty());
    }

    #[test]
    fn retire_waits_for_active_rounds() {
        let mut t = JobTable::new();
        let c0 = t.admit(stub(), span(&[], &[(0, 8)]), 1.0, 0, None);
        let _ = t.start_round(c0.id);
        let _ = t.start_round(c0.id); // second device mid-round
        let a = t.finish_round(c0.id, 1.0, true, false);
        assert!(a.retired.is_none(), "a device is still inside a round");
        assert!(!c0.is_retired());
        let a = t.finish_round(c0.id, 0.0, false, false);
        assert!(a.retired.is_some(), "last round out retires the job");
        assert!(t.is_empty());
        assert_eq!(t.rounds_active, 0);
    }

    #[test]
    fn mixed_tile_sizes_need_no_ordering() {
        // Regression for the deleted barrier path: geometry lives in
        // the cache key now, so two disjoint jobs are both immediately
        // runnable no matter what tile sizes they were planned with —
        // there is no geometry ordering left in the table at all.
        let mut t = JobTable::new();
        let c0 = t.admit(stub(), span(&[], &[(0, 8)]), 1.0, 0, None); // planned at t=32
        let c1 = t.admit(stub(), span(&[], &[(100, 108)]), 1.0, 1, None); // planned at t=64
        let ids: Vec<u64> = t.runnable_shares().iter().map(|s| s.id).collect();
        assert_eq!(ids, vec![c0.id, c1.id], "mixed-t jobs overlap like any disjoint pair");
        assert!(t.jobs.iter().all(|e| e.deps.is_empty()));
        // …and a third job admitted later only waits for *range*
        // conflicts, never for a geometry predecessor.
        let c2 = t.admit(stub(), span(&[(100, 108)], &[(200, 208)]), 1.0, 2, None);
        let deps = &t.jobs.iter().find(|e| e.id == c2.id).unwrap().deps;
        assert!(deps.contains(&c1.id) && !deps.contains(&c0.id));
    }

    #[test]
    fn failed_job_retires_without_scheduling_a_purge() {
        // Regression (and the documented contract in this module +
        // `runtime::service`): a failed job used to set a global purge
        // flag that wiped every tenant's warm tiles. The engine now
        // releases its pins on the abort path (and evicts a lost
        // device surgically), so failure triggers no purge — its
        // retirement only drains dependency edges, leaving neighbours
        // runnable with their warm tiles intact.
        let mut t = JobTable::new();
        let c0 = t.admit(stub(), span(&[], &[(0, 8)]), 1.0, 0, None);
        let c1 = t.admit(stub(), span(&[], &[(100, 108)]), 1.0, 0, None);
        // A dependent behind the failing writer: its edge must drain.
        let c2 = t.admit(stub(), span(&[(0, 8)], &[(300, 308)]), 1.0, 0, None);
        let _ = t.start_round(c0.id);
        let _ = t.start_round(c1.id);
        let a = t.finish_round(c0.id, 0.0, false, true);
        assert!(a.retired.is_some());
        assert!(a.retired_failed, "failure is reported to the waiter…");
        assert!(
            t.jobs.iter().find(|e| e.id == c2.id).unwrap().deps.is_empty(),
            "…and the dependent is unblocked"
        );
        let a = t.finish_round(c1.id, 1.0, true, false);
        assert!(a.retired.is_some());
        assert!(!a.retired_failed, "the healthy neighbour is untouched");
    }

    /// Stub that records the abort error `reap_expired` delivers.
    struct AbortStub {
        aborted: Mutex<Option<Error>>,
    }

    impl DeviceJob for AbortStub {
        fn run_round(&self, _dev: usize, _core: &EngineCore) -> Round {
            Round::Idle
        }
        fn poison(&self, _msg: String) {}
        fn done(&self) -> bool {
            false
        }
        fn report(&self, _core: &EngineCore) -> Result<RealReport> {
            Err(Error::Internal("stub".into()))
        }
        fn abort(&self, err: Error) {
            *self.aborted.lock().unwrap() = Some(err);
        }
    }

    #[test]
    fn reap_is_a_no_op_without_deadlines_or_cancels() {
        let mut t = JobTable::new();
        let _c0 = t.admit(stub(), span(&[], &[(0, 8)]), 1.0, 0, None);
        let v = t.version;
        let acts = t.reap_expired();
        assert!(acts.retired.is_empty());
        assert_eq!(t.version, v, "fast path must not disturb the table");
        assert_eq!(t.live_count(), 1);
    }

    #[test]
    fn deadline_expiry_reaps_with_the_right_error() {
        let mut t = JobTable::new();
        let job = Arc::new(AbortStub { aborted: Mutex::new(None) });
        let deadline = Some((Instant::now(), 5)); // already expired
        let c0 = t.admit(job.clone(), span(&[], &[(0, 8)]), 1.0, 0, deadline);
        let acts = t.reap_expired();
        assert_eq!(acts.retired.len(), 1, "no round in flight: reaped on the spot");
        assert_eq!(acts.retired[0].0.id, c0.id);
        assert!(t.is_empty());
        match job.aborted.lock().unwrap().take() {
            Some(Error::DeadlineExceeded { limit_ms: 5 }) => {}
            other => panic!("expected DeadlineExceeded, got {other:?}"),
        }
    }

    #[test]
    fn cancel_reaps_a_dep_blocked_job_and_spares_its_blocker() {
        let mut t = JobTable::new();
        let c0 = t.admit(stub(), span(&[], &[(0, 8)]), 1.0, 0, None);
        let job = Arc::new(AbortStub { aborted: Mutex::new(None) });
        // Same output range: job1 is dependency-blocked behind job0.
        let c1 = t.admit(job.clone(), span(&[], &[(0, 8)]), 1.0, 0, None);
        c1.request_cancel();
        let acts = t.reap_expired();
        assert_eq!(acts.retired.len(), 1);
        assert_eq!(acts.retired[0].0.id, c1.id);
        assert!(matches!(job.aborted.lock().unwrap().take(), Some(Error::Cancelled)));
        let ids: Vec<u64> = t.runnable_shares().iter().map(|s| s.id).collect();
        assert_eq!(ids, vec![c0.id], "the blocker keeps running untouched");
    }

    #[test]
    fn reaped_job_with_an_active_round_retires_at_round_end() {
        let mut t = JobTable::new();
        let deadline = Some((Instant::now(), 1));
        let c0 = t.admit(stub(), span(&[], &[(0, 8)]), 1.0, 0, deadline);
        let _ = t.start_round(c0.id);
        let acts = t.reap_expired();
        assert!(acts.retired.is_empty(), "a device is still inside a round");
        assert!(t.runnable_shares().is_empty(), "but no new rounds start");
        let a = t.finish_round(c0.id, 0.0, false, false);
        assert!(a.retired.is_some(), "round drain retires the reaped job");
        assert!(t.is_empty());
    }

    #[test]
    fn reap_drains_a_dependency_edge() {
        // A reaped writer's dependents become runnable exactly as if
        // it had retired normally (no purge, no barrier bookkeeping).
        let mut t = JobTable::new();
        let deadline = Some((Instant::now(), 1));
        let _c0 = t.admit(stub(), span(&[], &[(0, 8)]), 1.0, 0, deadline);
        let c1 = t.admit(stub(), span(&[(0, 8)], &[(100, 108)]), 1.0, 0, None);
        let acts = t.reap_expired();
        assert_eq!(acts.retired.len(), 1);
        let ids: Vec<u64> = t.runnable_shares().iter().map(|s| s.id).collect();
        assert_eq!(ids, vec![c1.id]);
    }

    #[test]
    fn live_count_and_tenant_inflight_track_admissions() {
        let mut t = JobTable::new();
        let c0 = t.admit(stub(), span(&[], &[(0, 8)]), 1.0, 7, None);
        let _c1 = t.admit(stub(), span(&[], &[(100, 108)]), 1.0, 7, None);
        let _c2 = t.admit(stub(), span(&[], &[(200, 208)]), 1.0, 9, None);
        assert_eq!(t.live_count(), 3);
        assert_eq!(t.tenant_inflight(7), 2);
        assert_eq!(t.tenant_inflight(9), 1);
        assert_eq!(t.tenant_inflight(1), 0);
        let _ = t.start_round(c0.id);
        let _ = t.finish_round(c0.id, 0.0, true, false);
        assert_eq!(t.live_count(), 2);
        assert_eq!(t.tenant_inflight(7), 1);
    }

    #[test]
    fn version_bumps_on_admission_and_retirement() {
        let mut t = JobTable::new();
        let v0 = t.version;
        let c0 = t.admit(stub(), span(&[], &[(0, 8)]), 1.0, 0, None);
        assert!(t.version > v0);
        let v1 = t.version;
        let _ = t.start_round(c0.id);
        let _ = t.finish_round(c0.id, 0.0, true, false);
        assert!(t.version > v1);
    }

    #[test]
    fn ctl_latch_round_trip() {
        let ctl = Arc::new(JobCtl::new(7));
        assert!(!ctl.is_retired());
        let c2 = ctl.clone();
        let h = std::thread::spawn(move || c2.wait_retired());
        ctl.retire();
        h.join().unwrap();
        assert!(ctl.is_retired());
    }
}
