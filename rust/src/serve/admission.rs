//! Admission queue + multi-job slot table.
//!
//! Every in-flight API call is a [`JobEntry`] in the [`JobTable`]. The
//! table is the single piece of shared scheduler state (one mutex in
//! `runtime::service::Inner` guards it); all methods here are called
//! under that lock, so the bookkeeping is plain fields, not atomics.
//!
//! ## Conflict ordering instead of a global lock
//!
//! At admission a job's operand **byte ranges** are compared against
//! every live job's: a RAW/WAR/WAW overlap on host memory creates a
//! dependency edge (the new job waits for the live one to retire).
//! Edges only ever point at earlier-admitted jobs, so the dependency
//! graph is acyclic by construction and aliasing calls execute in
//! admission order — bit-for-bit what a serial client would get —
//! while disjoint jobs overlap freely on the devices.
//!
//! Epoch stamping (see `runtime::service`) happens under the same lock
//! and in the same order as edge creation, which is what keeps the
//! tile-cache epoch discipline equivalent to the serialized PR 3
//! runtime.
//!
//! ## Tile-size barriers and cache purges
//!
//! Block geometry participates in tile addressing, so jobs with
//! different tile sizes must never share the cache. A job whose `t`
//! differs from the table's current one is admitted as a **barrier**:
//! it depends on every live job, every later job depends on it (via
//! `last_barrier`), and the caches are purged at the quiescent point
//! where its dependencies have drained (`rounds_active == 0` is
//! guaranteed there — no other job can be mid-round). A *failed* job
//! may leave pinned blocks behind (its aborted task's C pin), so its
//! retirement sets `purge_pending`; workers stop starting rounds and
//! the first one to observe global quiescence performs the purge.

use super::fairness::JobShare;
use super::DeviceJob;
use std::collections::HashSet;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Condvar, Mutex};

/// Host byte ranges a job reads (`ins`) and writes (`outs`), one entry
/// per operand per problem.
#[derive(Clone, Debug, Default)]
pub(crate) struct JobSpan {
    pub ins: Vec<(usize, usize)>,
    pub outs: Vec<(usize, usize)>,
}

fn overlaps(a: (usize, usize), b: (usize, usize)) -> bool {
    a.0 < b.1 && b.0 < a.1
}

impl JobSpan {
    /// Must a job with span `new` wait for a live job with span `live`?
    /// True on any write-write, write-read, or read-write overlap
    /// (read-read sharing is the good case — shared cache tiles).
    pub fn conflicts(new: &JobSpan, live: &JobSpan) -> bool {
        new.outs
            .iter()
            .any(|&o| live.outs.iter().chain(live.ins.iter()).any(|&x| overlaps(o, x)))
            || new.ins.iter().any(|&i| live.outs.iter().any(|&o| overlaps(i, o)))
    }
}

/// Per-job completion latch, shared by the waiters (a blocking submit,
/// a [`super::JobHandle`], the owning scope's
/// [`super::handle::ScopeToken`], or an FFI wait) and the retiring
/// worker. `retired` means the job has left the table and no worker
/// holds a reference to it — the waiter may reclaim the memory behind
/// the job's operand wraps.
pub(crate) struct JobCtl {
    pub id: u64,
    retired: AtomicBool,
    /// Did some waiter deliver this job's report (and therefore its
    /// failure, if any) to user code? A scope's close re-reports the
    /// failures of jobs nobody observed — detached handles must not
    /// swallow errors — and skips the ones a `wait()` already
    /// surfaced.
    observed: AtomicBool,
    mx: Mutex<()>,
    cv: Condvar,
}

impl JobCtl {
    fn new(id: u64) -> JobCtl {
        JobCtl {
            id,
            retired: AtomicBool::new(false),
            observed: AtomicBool::new(false),
            mx: Mutex::new(()),
            cv: Condvar::new(),
        }
    }

    /// A waiter is delivering this job's report to user code.
    pub fn mark_observed(&self) {
        self.observed.store(true, Ordering::SeqCst);
    }

    pub fn is_observed(&self) -> bool {
        self.observed.load(Ordering::SeqCst)
    }

    /// Construct a detached latch (unit tests outside this module).
    #[cfg(test)]
    pub(crate) fn new_for_tests(id: u64) -> JobCtl {
        JobCtl::new(id)
    }

    pub fn is_retired(&self) -> bool {
        self.retired.load(Ordering::SeqCst)
    }

    /// Mark retired and wake the waiter. Called by the retiring worker
    /// AFTER the table has dropped its job reference.
    pub fn retire(&self) {
        let _g = self.mx.lock().unwrap_or_else(|e| e.into_inner());
        self.retired.store(true, Ordering::SeqCst);
        self.cv.notify_all();
    }

    /// Park until the job retires.
    pub fn wait_retired(&self) {
        let mut g = self.mx.lock().unwrap_or_else(|e| e.into_inner());
        while !self.retired.load(Ordering::SeqCst) {
            g = self.cv.wait(g).unwrap_or_else(|e| e.into_inner());
        }
    }
}

/// One live job in the table.
pub(crate) struct JobEntry {
    pub id: u64,
    pub job: Arc<dyn DeviceJob>,
    pub ctl: Arc<JobCtl>,
    pub span: JobSpan,
    /// Earlier live jobs this one must wait for (ids drain at their
    /// retirement; the job is runnable when empty).
    pub deps: HashSet<u64>,
    /// Devices currently inside a round of this job.
    pub active_rounds: usize,
    /// All tasks done (or the job failed): retire once `active_rounds`
    /// reaches zero.
    pub finishing: bool,
    /// Poisoned/errored — retirement schedules a cache purge.
    pub failed: bool,
    /// Tile-size barrier: purge the caches when this job becomes
    /// runnable (cleared once the purge has happened).
    pub needs_purge: bool,
    /// Fair-share ledger (see `super::fairness`).
    pub weight: f64,
    pub charged: f64,
}

/// What the caller (holding the table lock) must do after
/// [`JobTable::finish_round`].
#[derive(Default)]
pub(crate) struct FinishActions {
    /// Purge the engine caches NOW, then call [`JobTable::purge_done`]
    /// (still under the lock). Only set at global quiescence.
    pub purge_now: bool,
    /// The retired job's latch: count the call, then (outside the
    /// table lock) `retire()` it and wake the worker fleet.
    pub retired: Option<Arc<JobCtl>>,
}

/// The multi-job slot table (see module docs).
pub(crate) struct JobTable {
    pub jobs: Vec<JobEntry>,
    next_id: u64,
    /// Bumped on every admission/retirement; workers use it to
    /// invalidate their "probed idle" memory cheaply.
    pub version: u64,
    /// A failed job retired with blocks possibly pinned: purge at the
    /// next globally-quiescent point; no new rounds start meanwhile.
    pub purge_pending: bool,
    /// Rounds in flight across all jobs (Σ active_rounds).
    pub rounds_active: usize,
    /// Latest live tile-size barrier; later admissions depend on it.
    last_barrier: Option<u64>,
    /// Tile size of the current cache generation.
    last_t: Option<usize>,
}

impl Default for JobTable {
    fn default() -> JobTable {
        JobTable::new()
    }
}

impl JobTable {
    pub fn new() -> JobTable {
        JobTable {
            jobs: Vec::new(),
            next_id: 0,
            version: 0,
            purge_pending: false,
            rounds_active: 0,
            last_barrier: None,
            last_t: None,
        }
    }

    pub fn is_empty(&self) -> bool {
        self.jobs.is_empty()
    }

    fn entry(&mut self, id: u64) -> &mut JobEntry {
        self.jobs.iter_mut().find(|e| e.id == id).expect("job id not in table")
    }

    /// Admit a job: compute its dependency edges (byte-range conflicts
    /// against every live job, plus barrier ordering), insert it, and
    /// report whether the caller must purge the caches immediately (a
    /// barrier admitted into an already-quiescent table).
    pub fn admit(
        &mut self,
        job: Arc<dyn DeviceJob>,
        span: JobSpan,
        weight: f64,
        t: usize,
    ) -> (Arc<JobCtl>, bool) {
        let id = self.next_id;
        self.next_id += 1;
        let switch = self.last_t != Some(t);
        let needs_purge = switch && self.last_t.is_some();
        self.last_t = Some(t);
        let deps: HashSet<u64> = if needs_purge {
            // Barrier: wait for everything live, regardless of ranges.
            self.jobs.iter().map(|e| e.id).collect()
        } else {
            let mut d: HashSet<u64> = self
                .jobs
                .iter()
                .filter(|e| JobSpan::conflicts(&span, &e.span))
                .map(|e| e.id)
                .collect();
            // Nothing may overtake a pending geometry barrier: its
            // purge must not wipe blocks a newer job is computing on.
            if let Some(b) = self.last_barrier {
                if self.jobs.iter().any(|e| e.id == b) {
                    d.insert(b);
                }
            }
            d
        };
        if needs_purge {
            self.last_barrier = Some(id);
        }
        let ctl = Arc::new(JobCtl::new(id));
        let purge_immediately = needs_purge && deps.is_empty();
        self.jobs.push(JobEntry {
            id,
            job,
            ctl: ctl.clone(),
            span,
            deps,
            active_rounds: 0,
            finishing: false,
            failed: false,
            // An immediate purge (performed by the admitting caller
            // while it still holds the table lock) discharges the flag.
            needs_purge: needs_purge && !purge_immediately,
            weight,
            charged: 0.0,
        });
        self.version += 1;
        debug_assert!(!purge_immediately || self.rounds_active == 0);
        (ctl, purge_immediately)
    }

    /// Fair-share ledgers of the currently runnable jobs (dependencies
    /// drained, not yet finishing).
    pub fn runnable_shares(&self) -> Vec<JobShare> {
        self.jobs
            .iter()
            .filter(|e| e.deps.is_empty() && !e.finishing)
            .map(|e| JobShare { id: e.id, weight: e.weight, charged: e.charged })
            .collect()
    }

    /// Begin a round of job `id` on some device: pins the job in the
    /// table (it cannot retire while `active_rounds > 0`).
    pub fn start_round(&mut self, id: u64) -> Arc<dyn DeviceJob> {
        self.rounds_active += 1;
        let e = self.entry(id);
        e.active_rounds += 1;
        e.job.clone()
    }

    /// End a round of job `id`: charge the fair-share ledger, record a
    /// finished/failed observation, and retire the job if it is done
    /// and no device is still inside one of its rounds. The returned
    /// actions must be applied by the caller (see [`FinishActions`]).
    pub fn finish_round(
        &mut self,
        id: u64,
        flops: f64,
        finished: bool,
        failed: bool,
    ) -> FinishActions {
        self.rounds_active -= 1;
        let (finishing, active_rounds) = {
            let e = self.entry(id);
            e.active_rounds -= 1;
            e.charged += flops;
            if finished || failed {
                e.finishing = true;
                e.failed |= failed;
            }
            (e.finishing, e.active_rounds)
        };
        let mut actions = FinishActions::default();
        if finishing && active_rounds == 0 {
            let idx = self.jobs.iter().position(|e| e.id == id).unwrap();
            let entry = self.jobs.remove(idx);
            self.version += 1;
            if entry.failed {
                self.purge_pending = true;
            }
            if self.last_barrier == Some(id) {
                self.last_barrier = None;
            }
            for other in &mut self.jobs {
                other.deps.remove(&id);
            }
            actions.retired = Some(entry.ctl);
        }
        // A geometry barrier whose dependencies just drained purges at
        // this quiescent point (no other job can be mid-round: all its
        // predecessors retired, all its successors still dep on it);
        // a failure purge waits for global quiescence the same way.
        let barrier_ready = self.jobs.iter().any(|e| e.deps.is_empty() && e.needs_purge);
        if (barrier_ready || self.purge_pending) && self.rounds_active == 0 {
            actions.purge_now = true;
        }
        actions
    }

    /// The caller purged the caches (under the table lock, at a
    /// quiescent point): clear every discharged purge obligation.
    pub fn purge_done(&mut self) {
        self.purge_pending = false;
        for e in &mut self.jobs {
            if e.deps.is_empty() {
                e.needs_purge = false;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::real_engine::{EngineCore, RealReport, Round};
    use crate::error::{Error, Result};

    struct StubJob;
    impl DeviceJob for StubJob {
        fn run_round(&self, _dev: usize, _core: &EngineCore) -> Round {
            Round::Idle
        }
        fn poison(&self, _msg: String) {}
        fn done(&self) -> bool {
            false
        }
        fn report(&self, _core: &EngineCore) -> Result<RealReport> {
            Err(Error::Internal("stub".into()))
        }
    }

    fn stub() -> Arc<dyn DeviceJob> {
        Arc::new(StubJob)
    }

    fn span(ins: &[(usize, usize)], outs: &[(usize, usize)]) -> JobSpan {
        JobSpan { ins: ins.to_vec(), outs: outs.to_vec() }
    }

    #[test]
    fn disjoint_jobs_are_concurrently_runnable() {
        let mut t = JobTable::new();
        let (c0, p0) = t.admit(stub(), span(&[(0, 100)], &[(100, 200)]), 10.0, 32);
        let (c1, p1) = t.admit(stub(), span(&[(300, 400)], &[(400, 500)]), 10.0, 32);
        assert!(!p0 && !p1);
        let ids: Vec<u64> = t.runnable_shares().iter().map(|s| s.id).collect();
        assert_eq!(ids, vec![c0.id, c1.id]);
    }

    #[test]
    fn raw_conflict_orders_by_admission() {
        let mut t = JobTable::new();
        // job0 writes [100,200); job1 reads it → dependency edge.
        let (c0, _) = t.admit(stub(), span(&[(0, 100)], &[(100, 200)]), 10.0, 32);
        let (c1, _) = t.admit(stub(), span(&[(150, 160)], &[(500, 600)]), 10.0, 32);
        let ids: Vec<u64> = t.runnable_shares().iter().map(|s| s.id).collect();
        assert_eq!(ids, vec![c0.id], "reader must wait for the live writer");
        // retire job0: one idle probe then a finished round
        let _ = t.start_round(c0.id);
        let a = t.finish_round(c0.id, 0.0, true, false);
        assert!(a.retired.is_some());
        assert!(!a.purge_now);
        let ids: Vec<u64> = t.runnable_shares().iter().map(|s| s.id).collect();
        assert_eq!(ids, vec![c1.id], "dependency drained at retirement");
    }

    #[test]
    fn waw_and_war_conflicts_also_order() {
        let mut t = JobTable::new();
        let (w0, _) = t.admit(stub(), span(&[], &[(100, 200)]), 1.0, 32);
        // WAW: same output range
        let (w1, _) = t.admit(stub(), span(&[], &[(150, 250)]), 1.0, 32);
        // WAR: writes what job0 reads
        let (_r, _) = t.admit(stub(), span(&[(0, 50)], &[(300, 400)]), 1.0, 32);
        let (w2, _) = t.admit(stub(), span(&[], &[(0, 10)]), 1.0, 32);
        assert!(t.jobs.iter().find(|e| e.id == w1.id).unwrap().deps.contains(&w0.id));
        assert!(t.jobs.iter().find(|e| e.id == w2.id).unwrap().deps.is_empty());
        // read-read sharing creates no edge
        let (rr, _) = t.admit(stub(), span(&[(0, 50)], &[(700, 800)]), 1.0, 32);
        assert!(t.jobs.iter().find(|e| e.id == rr.id).unwrap().deps.is_empty());
    }

    #[test]
    fn retire_waits_for_active_rounds() {
        let mut t = JobTable::new();
        let (c0, _) = t.admit(stub(), span(&[], &[(0, 8)]), 1.0, 32);
        let _ = t.start_round(c0.id);
        let _ = t.start_round(c0.id); // second device mid-round
        let a = t.finish_round(c0.id, 1.0, true, false);
        assert!(a.retired.is_none(), "a device is still inside a round");
        assert!(!c0.is_retired());
        let a = t.finish_round(c0.id, 0.0, false, false);
        assert!(a.retired.is_some(), "last round out retires the job");
        assert!(t.is_empty());
        assert_eq!(t.rounds_active, 0);
    }

    #[test]
    fn tile_size_switch_is_a_full_barrier_with_purge() {
        let mut t = JobTable::new();
        let (c0, p) = t.admit(stub(), span(&[], &[(0, 8)]), 1.0, 32);
        assert!(!p, "first job establishes the geometry, nothing to purge");
        // disjoint ranges, but a different tile size ⇒ waits for job0
        let (c1, p) = t.admit(stub(), span(&[], &[(100, 108)]), 1.0, 64);
        assert!(!p, "job0 is live: purge deferred to the barrier point");
        assert!(t.jobs.iter().find(|e| e.id == c1.id).unwrap().needs_purge);
        assert!(t.jobs.iter().find(|e| e.id == c1.id).unwrap().deps.contains(&c0.id));
        // a same-size job admitted behind the barrier must not overtake it
        let (c2, _) = t.admit(stub(), span(&[], &[(200, 208)]), 1.0, 64);
        assert!(t.jobs.iter().find(|e| e.id == c2.id).unwrap().deps.contains(&c1.id));
        // retiring job0 reaches the barrier's quiescent point → purge now
        let _ = t.start_round(c0.id);
        let a = t.finish_round(c0.id, 0.0, true, false);
        assert!(a.retired.is_some());
        assert!(a.purge_now, "barrier became runnable at quiescence");
        t.purge_done();
        assert!(!t.jobs.iter().any(|e| e.needs_purge));
        let ids: Vec<u64> = t.runnable_shares().iter().map(|s| s.id).collect();
        assert_eq!(ids, vec![c1.id], "c2 still waits for the barrier job itself");
    }

    #[test]
    fn switch_into_empty_table_purges_at_admission() {
        let mut t = JobTable::new();
        let (c0, _) = t.admit(stub(), span(&[], &[(0, 8)]), 1.0, 32);
        let _ = t.start_round(c0.id);
        let _ = t.finish_round(c0.id, 0.0, true, false);
        assert!(t.is_empty());
        let (_c1, purge_now) = t.admit(stub(), span(&[], &[(0, 8)]), 1.0, 64);
        assert!(purge_now, "stale 32-blocks must go before the 64-job runs");
        t.purge_done();
    }

    #[test]
    fn failed_job_schedules_a_quiescent_purge() {
        let mut t = JobTable::new();
        let (c0, _) = t.admit(stub(), span(&[], &[(0, 8)]), 1.0, 32);
        let (c1, _) = t.admit(stub(), span(&[], &[(100, 108)]), 1.0, 32);
        let _ = t.start_round(c0.id);
        let _ = t.start_round(c1.id);
        // job0 fails while job1 is mid-round: purge must wait
        let a = t.finish_round(c0.id, 0.0, false, true);
        assert!(a.retired.is_some());
        assert!(t.purge_pending);
        assert!(!a.purge_now, "job1 still holds arena offsets");
        let a = t.finish_round(c1.id, 1.0, false, false);
        assert!(a.purge_now, "quiescent now");
        t.purge_done();
        assert!(!t.purge_pending);
    }

    #[test]
    fn version_bumps_on_admission_and_retirement() {
        let mut t = JobTable::new();
        let v0 = t.version;
        let (c0, _) = t.admit(stub(), span(&[], &[(0, 8)]), 1.0, 32);
        assert!(t.version > v0);
        let v1 = t.version;
        let _ = t.start_round(c0.id);
        let _ = t.finish_round(c0.id, 0.0, true, false);
        assert!(t.version > v1);
    }

    #[test]
    fn ctl_latch_round_trip() {
        let ctl = Arc::new(JobCtl::new(7));
        assert!(!ctl.is_retired());
        let c2 = ctl.clone();
        let h = std::thread::spawn(move || c2.wait_retired());
        ctl.retire();
        h.join().unwrap();
        assert!(ctl.is_retired());
    }
}
