//! Job handles and the scope completion barrier.
//!
//! ## Why the barrier lives in a token, not in the handle
//!
//! The first async surface made `JobHandle` carry the operand borrows
//! and *wait on drop* — the pre-1.0 `thread::scoped` design, with the
//! same hole: `std::mem::forget(handle)` is safe code that skips the
//! drop-side wait, leaving resident workers writing through pointers
//! into freed stack buffers. Soundness cannot hang off a destructor
//! the caller is allowed to skip.
//!
//! The sound shape (the one `std::thread::scope` standardized) puts
//! the barrier in a stack frame the caller *cannot* skip:
//! [`crate::api::Context::scope`] owns a [`ScopeToken`] in its own
//! frame, every job admitted through the scope registers its
//! [`JobCtl`] latch with the token, and the token waits for all of
//! them after the user closure returns — or unwinds. Handles became
//! plain observers: [`JobHandle::wait`] fetches a job's report,
//! dropping (or forgetting!) one changes nothing about buffer
//! liveness, because the job's backing (task set + operand wraps) is
//! owned by the runtime's job table until retirement and the scope
//! close is the barrier.

use super::admission::JobCtl;
use super::DeviceJob;
use crate::coordinator::real_engine::RealReport;
use crate::error::Result;
use crate::runtime::Runtime;
use std::marker::PhantomData;
use std::sync::{Arc, Mutex};

/// A job admitted through a [`crate::api::Scope`] (returned by the
/// scope's routine methods, e.g. `s.dgemm(..)`).
///
/// The handle is a thin view over the job's completion latch: call
/// [`JobHandle::wait`] for the job's [`RealReport`] (outputs are fully
/// written back when it returns), or just let the handle drop — the
/// **scope close** is the completion barrier, so dropping detaches the
/// handle without waiting and the jobs keep pipelining. There is no
/// safety obligation attached: leaking a handle (`std::mem::forget`)
/// is safe, because the runtime owns the job's backing until
/// retirement and the scope's own stack frame waits for every admitted
/// job regardless of what happened to its handle.
#[must_use = "dropping detaches the job (the scope close still waits); call .wait() for its report or `let _ = ...` to detach explicitly"]
pub struct JobHandle<'scope> {
    rt: Arc<Runtime>,
    job: Arc<dyn DeviceJob>,
    ctl: Arc<JobCtl>,
    /// Handles must not outlive their scope (the per-job report is
    /// only meaningful while the runtime the scope pinned is alive).
    _scope: PhantomData<&'scope ()>,
}

impl<'scope> JobHandle<'scope> {
    pub(crate) fn new(
        rt: Arc<Runtime>,
        job: Arc<dyn DeviceJob>,
        ctl: Arc<JobCtl>,
    ) -> JobHandle<'scope> {
        JobHandle { rt, job, ctl, _scope: PhantomData }
    }

    /// Has the job retired? (Non-blocking; `wait` returns immediately
    /// once this is true.)
    pub fn is_done(&self) -> bool {
        self.ctl.is_retired()
    }

    /// The job's admission id (diagnostics).
    pub fn job_id(&self) -> u64 {
        self.ctl.id
    }

    /// Live observability counters of the job so far (tasks executed,
    /// host/peer transfers, L1 hits, steals). Non-blocking and safe
    /// while the job is in flight — unlike [`JobHandle::wait`], which
    /// consumes the handle for the full report.
    pub fn stats(&self) -> crate::coordinator::JobStats {
        self.job.stats()
    }

    /// Request cooperative cancellation: the job is aborted with
    /// [`crate::error::Error::Cancelled`] at the next round boundary.
    /// In-flight rounds finish their current tasks (outputs are never
    /// torn mid-tile), no new rounds start, and a subsequent
    /// [`JobHandle::wait`] returns the `Cancelled` error — unless the
    /// job finished first, in which case it won the race and reports
    /// normally. Idempotent; other tenants' jobs are unaffected.
    pub fn cancel(&self) {
        self.ctl.request_cancel();
        // Wake parked workers so the reap runs promptly even on an
        // otherwise-idle runtime.
        self.rt.core().notify_work();
    }

    /// Park until the job completes and return its report. Outputs are
    /// fully written back when this returns.
    pub fn wait(self) -> Result<RealReport> {
        // The report (and any failure inside it) is being delivered to
        // user code here — the scope close must not re-surface it.
        self.ctl.mark_observed();
        self.ctl.wait_retired();
        self.job.report(self.rt.core())
    }

    /// Explicitly detach: the job keeps running and the scope close
    /// waits for it. Identical to dropping the handle, spelled out.
    pub fn detach(self) {}
}

impl std::fmt::Debug for JobHandle<'_> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("JobHandle")
            .field("job_id", &self.ctl.id)
            .field("done", &self.is_done())
            .finish()
    }
}

/// The scope's completion barrier: every job admitted through a scope
/// registers its retirement latch here, and [`ScopeToken::close`]
/// waits for all of them. The token is owned by
/// [`crate::api::Context::scope`]'s stack frame — user code only ever
/// sees `&Scope`, so no safe operation (including `mem::forget` on
/// handles or on anything else the closure can reach) can prevent the
/// close from running before the operand borrows (`'env`) end. Close
/// runs on the normal path *and* on unwind (the token's `Drop` is the
/// backstop when the user closure panics).
pub(crate) struct ScopeToken {
    rt: Arc<Runtime>,
    jobs: Mutex<Vec<(Arc<JobCtl>, Arc<dyn DeviceJob>)>>,
}

impl ScopeToken {
    pub(crate) fn new(rt: Arc<Runtime>) -> ScopeToken {
        ScopeToken { rt, jobs: Mutex::new(Vec::new()) }
    }

    /// The runtime this scope pinned (jobs are admitted to it).
    pub(crate) fn runtime(&self) -> &Arc<Runtime> {
        &self.rt
    }

    /// Track a job admitted through the scope.
    pub(crate) fn register(&self, ctl: Arc<JobCtl>, job: Arc<dyn DeviceJob>) {
        self.jobs.lock().unwrap_or_else(|e| e.into_inner()).push((ctl, job));
    }

    /// Wait for every registered job to retire. Idempotent (the list
    /// is drained), so the explicit close on the normal path and the
    /// `Drop` backstop on unwind compose.
    pub(crate) fn close(&self) {
        let jobs = std::mem::take(&mut *self.jobs.lock().unwrap_or_else(|e| e.into_inner()));
        for (ctl, _job) in jobs {
            ctl.wait_retired();
        }
    }

    /// The normal-path close: wait for every job, then surface the
    /// first failure of any job whose report was never delivered to
    /// user code (a detached or forgotten handle). Without this, a
    /// failed kernel behind a detached handle would leave the output
    /// buffer holding garbage while `scope` returned `Ok` — the same
    /// silent-error hole `std::thread::scope` closes by resuming
    /// scoped-thread panics at its close.
    pub(crate) fn close_and_report(&self) -> Result<()> {
        let jobs = std::mem::take(&mut *self.jobs.lock().unwrap_or_else(|e| e.into_inner()));
        let mut first_err = None;
        for (ctl, job) in jobs {
            ctl.wait_retired();
            if !ctl.is_observed() {
                if let Err(e) = job.report(self.rt.core()) {
                    first_err.get_or_insert(e);
                }
            }
        }
        match first_err {
            Some(e) => Err(e),
            None => Ok(()),
        }
    }
}

impl Drop for ScopeToken {
    fn drop(&mut self) {
        // Unwind path: the closure panicked past the explicit close.
        // In-flight jobs still hold raw pointers into `'env` buffers,
        // so the barrier must run before this frame's borrows end.
        self.close();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::real_engine::{EngineCore, Round};
    use crate::error::Error;
    use crate::mem::AllocStrategy;

    /// A job that reports success or a fixed failure.
    struct StubJob {
        fail: bool,
    }

    impl DeviceJob for StubJob {
        fn run_round(&self, _dev: usize, _core: &EngineCore) -> Round {
            Round::Finished
        }
        fn poison(&self, _msg: String) {}
        fn done(&self) -> bool {
            true
        }
        fn report(&self, _core: &EngineCore) -> Result<RealReport> {
            if self.fail {
                Err(Error::Internal("stub failure".into()))
            } else {
                Ok(RealReport {
                    tasks_per_device: Vec::new(),
                    cache_stats: Vec::new(),
                    cache_delta: Vec::new(),
                    steals: Vec::new(),
                    transfers: Default::default(),
                })
            }
        }
    }

    #[test]
    fn scope_token_close_is_idempotent_and_waits() {
        let rt = Arc::new(Runtime::boot(1, 1 << 20, AllocStrategy::FastHeap));
        let token = ScopeToken::new(rt);
        let ctl = Arc::new(JobCtl::new_for_tests(3));
        token.register(ctl.clone(), Arc::new(StubJob { fail: false }));
        // Latch released from another thread while close blocks on it.
        let c2 = ctl.clone();
        let h = std::thread::spawn(move || c2.retire());
        token.close();
        h.join().unwrap();
        assert!(ctl.is_retired());
        token.close(); // drained: returns immediately
        drop(token); // Drop backstop: also a no-op now
    }

    #[test]
    fn close_and_report_surfaces_unobserved_failures_only() {
        let rt = Arc::new(Runtime::boot(1, 1 << 20, AllocStrategy::FastHeap));
        // Unobserved failure → surfaced at close.
        let token = ScopeToken::new(rt.clone());
        let ctl = Arc::new(JobCtl::new_for_tests(1));
        ctl.retire();
        token.register(ctl, Arc::new(StubJob { fail: true }));
        assert!(token.close_and_report().is_err(), "detached failure must surface");
        // Observed failure → the waiter already delivered it.
        let token = ScopeToken::new(rt);
        let ctl = Arc::new(JobCtl::new_for_tests(2));
        ctl.retire();
        ctl.mark_observed();
        token.register(ctl, Arc::new(StubJob { fail: true }));
        assert!(token.close_and_report().is_ok(), "observed failure must not re-surface");
    }
}
