//! The completion future of an asynchronously submitted job.

use super::admission::JobCtl;
use super::DeviceJob;
use crate::coordinator::real_engine::RealReport;
use crate::error::Result;
use crate::runtime::Runtime;
use std::marker::PhantomData;
use std::sync::Arc;

/// A submitted-but-possibly-unfinished L3 call (returned by the
/// `*_async` entry points in [`crate::api::l3`]).
///
/// The handle keeps the resident runtime alive and pins the borrows of
/// the caller's operand buffers (`'buf`): the buffers cannot be freed
/// or mutably reused while the handle exists. [`JobHandle::wait`]
/// parks until the job retires and returns its [`RealReport`];
/// **dropping** an unwaited handle also parks until retirement (and
/// discards the report), so an early `drop` is a barrier, not a
/// cancellation.
///
/// ## Liveness contract
///
/// The runtime's workers read and write the operand buffers through
/// raw pointers until the job retires. The borrow checker enforces the
/// buffers' liveness through `'buf` *provided the handle is dropped
/// normally*; leaking it (`std::mem::forget`) while the job is in
/// flight voids that guarantee and is undefined behavior, exactly like
/// leaking a guard that lends local buffers to another thread. This is
/// the same class of contract as `Context::invalidate_host`: the
/// library cannot observe what the caller does to host memory behind
/// its back.
pub struct JobHandle<'buf> {
    rt: Arc<Runtime>,
    job: Option<Arc<dyn DeviceJob>>,
    ctl: Arc<JobCtl>,
    _buffers: PhantomData<&'buf mut [u8]>,
}

impl<'buf> JobHandle<'buf> {
    pub(crate) fn new(
        rt: Arc<Runtime>,
        job: Arc<dyn DeviceJob>,
        ctl: Arc<JobCtl>,
    ) -> JobHandle<'buf> {
        JobHandle { rt, job: Some(job), ctl, _buffers: PhantomData }
    }

    /// Has the job retired? (Non-blocking; `wait` returns immediately
    /// once this is true.)
    pub fn is_done(&self) -> bool {
        self.ctl.is_retired()
    }

    /// The job's admission id (diagnostics).
    pub fn job_id(&self) -> u64 {
        self.ctl.id
    }

    /// Park until the job completes and return its report. Outputs are
    /// fully written back to the caller's buffers when this returns.
    pub fn wait(mut self) -> Result<RealReport> {
        self.ctl.wait_retired();
        let job = self.job.take().expect("job already taken");
        let report = job.report(self.rt.core());
        // `job` drops here: the last reference into the borrowed
        // buffers dies before the caller regains use of them.
        report
    }
}

impl Drop for JobHandle<'_> {
    fn drop(&mut self) {
        if self.job.is_some() {
            // Unwaited handle: block until the workers are done with
            // the borrowed buffers, then let the job (and its report)
            // drop.
            self.ctl.wait_retired();
        }
    }
}

impl std::fmt::Debug for JobHandle<'_> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("JobHandle")
            .field("job_id", &self.ctl.id)
            .field("done", &self.is_done())
            .finish()
    }
}
