//! Scheduling infrastructure (paper §IV-C): reservation stations,
//! locality priorities, and the demand-driven load-balancing policy the
//! execution engines share.

pub mod priority;
pub mod station;

pub use priority::task_priority;
pub use station::{Slot, Station};
