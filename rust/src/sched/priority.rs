//! Locality priority (paper Eq. 3): score a task for a device by how
//! many of its input tiles are already close to it.
//!
//! `priority = Σ_k f(A_ik) + f(B_kj)` with `f = 2` on an L1 hit, `1` on
//! an L2 (peer) hit, `0` for host-resident tiles. Tasks with warm inputs
//! run first, cooling the queue's demand on the PCI-E.

use crate::cache::TileCacheSet;
use crate::task::Task;
use crate::tile::TileKey;

/// Resolve a task's input tiles to cache keys and sum their locality
/// scores on `dev`. `key_of` maps (mat, ti, tj) to the cache key — the
/// engines provide it (host addresses in real mode, synthetic ids in sim
/// mode).
pub fn task_priority<F>(task: &Task, dev: usize, caches: &TileCacheSet, key_of: F) -> u32
where
    F: Fn(crate::task::TileRef) -> TileKey,
{
    let mut p = 0;
    for step in &task.steps {
        for tile in step.inputs() {
            p += caches.locality_score(dev, &key_of(tile));
        }
    }
    p
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::types::Trans;
    use crate::mem::AllocStrategy;
    use crate::task::{Step, TileOp, TileRef, WriteMask};
    use crate::tile::MatId;

    fn key_of(r: TileRef) -> TileKey {
        // Disjoint per-operand address ranges, mirroring the real
        // KeyMap's span reservation (TileKey equality ignores the
        // role, so synthetic addresses must not collide across mats).
        let base = match r.mat {
            MatId::A => 0,
            MatId::B => 100_000,
            MatId::C => 200_000,
        };
        TileKey::synthetic(base + r.ti * 1000 + r.tj, r.mat, r.ti, r.tj)
    }

    fn gemm_task(krange: usize) -> Task {
        let steps = (0..krange)
            .map(|k| Step {
                op: TileOp::Gemm { ta: Trans::No, tb: Trans::No },
                a: Some(TileRef::new(MatId::A, 0, k)),
                b: Some(TileRef::new(MatId::B, k, 0)),
                alpha: 1.0,
                beta: 1.0,
                dims: (4, 4, 4),
            })
            .collect();
        Task {
            id: 0,
            ci: 0,
            cj: 0,
            p: 0,
            m: 4,
            n: 4,
            reads_c: true,
            mask: WriteMask::Full,
            steps,
            successor: None,
            n_deps: 0,
            flops: 0.0,
        }
        .seal()
    }

    #[test]
    fn scores_follow_eq3() {
        let mut caches =
            TileCacheSet::new(&[1 << 20, 1 << 20], vec![vec![1], vec![0]], AllocStrategy::FastHeap);
        let t = gemm_task(2); // inputs: A00 A01 B00 B10
        assert_eq!(task_priority(&t, 0, &caches, key_of), 0);

        // A00 into dev0's L1: +2
        caches.acquire(0, key_of(TileRef::new(MatId::A, 0, 0)), 64).unwrap();
        assert_eq!(task_priority(&t, 0, &caches, key_of), 2);

        // B10 into dev1's L1: dev0 sees an L2 hit: +1
        caches.acquire(1, key_of(TileRef::new(MatId::B, 1, 0)), 64).unwrap();
        assert_eq!(task_priority(&t, 0, &caches, key_of), 3);
        // and dev1 itself scores 2 for B10
        assert_eq!(task_priority(&t, 1, &caches, key_of), 2 + 1);
    }
}
