//! Reservation Station (paper §IV-C.3): per-device buffer of upcoming
//! tasks, target of priority scheduling and work stealing.
//!
//! Each slot carries a task id, its locality priority (Eq. 3, refreshed
//! whenever new tasks arrive or the cache contents shift), and the
//! stream index the task will be bound to when it becomes active.

/// One RS slot.
#[derive(Clone, Copy, Debug)]
pub struct Slot {
    pub task: usize,
    pub priority: u32,
}

/// A fixed-capacity reservation station.
#[derive(Clone, Debug)]
pub struct Station {
    slots: Vec<Slot>,
    capacity: usize,
}

impl Station {
    /// The paper sizes the RS at twice the stream count (4 active + 4
    /// staged); capacity is configurable for ablations.
    pub fn new(capacity: usize) -> Station {
        Station { slots: Vec::with_capacity(capacity), capacity }
    }

    pub fn len(&self) -> usize {
        self.slots.len()
    }

    pub fn is_empty(&self) -> bool {
        self.slots.is_empty()
    }

    pub fn is_full(&self) -> bool {
        self.slots.len() >= self.capacity
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Room for how many more tasks?
    pub fn vacancies(&self) -> usize {
        self.capacity - self.slots.len()
    }

    /// Insert a task (caller computed its priority). Panics if full —
    /// the worker loop only refills into vacancies.
    pub fn insert(&mut self, task: usize, priority: u32) {
        assert!(!self.is_full(), "RS overflow");
        self.slots.push(Slot { task, priority });
    }

    /// Pop the highest-priority task (ties: earliest inserted — FIFO
    /// keeps the taskizer's cache-friendly emission order).
    pub fn take_best(&mut self) -> Option<Slot> {
        if self.slots.is_empty() {
            return None;
        }
        let mut best = 0;
        for (i, s) in self.slots.iter().enumerate().skip(1) {
            if s.priority > self.slots[best].priority {
                best = i;
            }
        }
        Some(self.slots.remove(best))
    }

    /// Steal the *lowest*-priority task (the victim benefits least from
    /// its locality — DESIGN.md §6.5). Returns `None` if empty.
    pub fn steal_worst(&mut self) -> Option<Slot> {
        if self.slots.is_empty() {
            return None;
        }
        let mut worst = 0;
        for (i, s) in self.slots.iter().enumerate().skip(1) {
            if s.priority < self.slots[worst].priority {
                worst = i;
            }
        }
        Some(self.slots.remove(worst))
    }

    /// Recompute priorities in place (paper: "the runtime refreshes the
    /// priorities in RS after new tasks coming in").
    pub fn refresh<F: FnMut(usize) -> u32>(&mut self, mut prio: F) {
        for s in &mut self.slots {
            s.priority = prio(s.task);
        }
    }

    /// Iterate current slots (tests/metrics).
    pub fn iter(&self) -> impl Iterator<Item = &Slot> {
        self.slots.iter()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn best_and_worst_selection() {
        let mut rs = Station::new(8);
        rs.insert(10, 1);
        rs.insert(11, 5);
        rs.insert(12, 3);
        assert_eq!(rs.take_best().unwrap().task, 11);
        assert_eq!(rs.steal_worst().unwrap().task, 10);
        assert_eq!(rs.take_best().unwrap().task, 12);
        assert!(rs.take_best().is_none());
    }

    #[test]
    fn ties_resolve_fifo() {
        let mut rs = Station::new(4);
        rs.insert(1, 2);
        rs.insert(2, 2);
        rs.insert(3, 2);
        assert_eq!(rs.take_best().unwrap().task, 1);
        assert_eq!(rs.steal_worst().unwrap().task, 2);
    }

    #[test]
    fn refresh_recomputes() {
        let mut rs = Station::new(4);
        rs.insert(7, 0);
        rs.insert(8, 0);
        rs.refresh(|t| if t == 8 { 9 } else { 1 });
        assert_eq!(rs.take_best().unwrap().task, 8);
    }

    #[test]
    fn vacancy_tracking() {
        let mut rs = Station::new(2);
        assert_eq!(rs.vacancies(), 2);
        rs.insert(1, 0);
        assert_eq!(rs.vacancies(), 1);
        assert!(!rs.is_full());
        rs.insert(2, 0);
        assert!(rs.is_full());
    }

    #[test]
    #[should_panic(expected = "RS overflow")]
    fn overflow_panics() {
        let mut rs = Station::new(1);
        rs.insert(1, 0);
        rs.insert(2, 0);
    }
}
