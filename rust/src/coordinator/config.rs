//! Runtime configuration for a BLASX run.

use crate::fault::FaultPlan;
use crate::mem::AllocStrategy;

/// Which scheduling policy drives the run (BLASX or a baseline
//  re-implementation used by the benchmark harness).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Policy {
    /// The paper's locality-aware demand-driven runtime (Alg. 1).
    Blasx,
    /// cuBLAS-XT-like: static round-robin tile blocks, on-demand
    /// transfers, no tile cache, 2 streams.
    CublasXt,
    /// MAGMA-like: static 1D block-cyclic partition, per-GPU lookahead,
    /// no inter-GPU cache.
    Magma,
    /// SuperMatrix-like: central ready queue, fork-join per tile op,
    /// blocking (non-overlapped) transfers, 1 stream.
    SuperMatrix,
    /// PaRSEC-like: speed-weighted static partition with per-GPU tile
    /// reuse, in-core only (rejects problems larger than VRAM).
    Parsec,
}

impl Policy {
    pub fn name(self) -> &'static str {
        match self {
            Policy::Blasx => "blasx",
            Policy::CublasXt => "cublasxt",
            Policy::Magma => "magma",
            Policy::SuperMatrix => "supermatrix",
            Policy::Parsec => "parsec",
        }
    }

    pub fn from_name(s: &str) -> Option<Policy> {
        match s {
            "blasx" => Some(Policy::Blasx),
            "cublasxt" | "cublas-xt" | "xt" => Some(Policy::CublasXt),
            "magma" => Some(Policy::Magma),
            "supermatrix" | "sm" => Some(Policy::SuperMatrix),
            "parsec" => Some(Policy::Parsec),
            _ => None,
        }
    }

    pub const ALL: [Policy; 5] =
        [Policy::Blasx, Policy::CublasXt, Policy::Magma, Policy::SuperMatrix, Policy::Parsec];
}

/// Kernel backend for the real (threaded) engine.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Backend {
    /// Pure-Rust blocked host kernels (fast on this CPU; oracle-grade).
    Hostblas,
    /// AOT artifacts through PJRT — the paper-architecture path
    /// (L1 Pallas → L2 JAX → HLO → XLA CPU).
    Pjrt,
}

/// Everything a run needs to know.
#[derive(Clone, Debug)]
pub struct RunConfig {
    /// Tile size (the paper's only tuning parameter, §V-B).
    pub t: usize,
    /// Streams per device (paper: 4).
    pub n_streams: usize,
    /// Reservation-station capacity (paper sizing: 2× streams).
    pub rs_capacity: usize,
    /// Scheduling policy.
    pub policy: Policy,
    /// Device memory allocator strategy (FastHeap vs the Fig. 5
    /// cudaMalloc cost model).
    pub alloc: AllocStrategy,
    /// Enable the CPU computation thread (paper §IV-C.2).
    pub use_cpu: bool,
    /// Enable work stealing between reservation stations.
    pub work_stealing: bool,
    /// Real-engine kernel backend.
    pub backend: Backend,
    /// Threads per worker for the hostblas tile kernel (paper §IV-C.2:
    /// the CPU worker "solves the task with a multithreaded BLAS
    /// kernel"). 1 = single-threaded kernels; larger values let each
    /// device worker fan a big GEMM k-step across cores via
    /// `hostblas::gemm_mt`'s 2D partition (small tiles stay serial
    /// under its flop cutoff regardless).
    pub worker_threads: usize,
    /// Cap the device L1 tile-cache to this many bytes (None = device
    /// VRAM); used by cache-pressure tests and ablations.
    pub vram_override: Option<usize>,
    /// k-steps issued per task between stream-sync points (Alg. 1 line
    /// 16 closes a *batch* of k-iterations). Larger chunks cut sync
    /// overhead; smaller chunks react faster to steals — 4 balances
    /// both (ablation: benches/fig10_tile_size.rs companion).
    pub k_chunk: usize,
    /// Relative kernel-duration variance (paper §I: "the realtime
    /// performance of a GPU varies with ... kernel saturation and GPU
    /// occupancy"). Deterministic per (device, task): the same workload
    /// noise hits every policy identically, so dynamic schedulers win
    /// exactly by absorbing it.
    pub jitter: f64,
    /// Routine label of the call ("gemm", "syrk", ...), stamped by the
    /// API entry points so the metrics registry can aggregate
    /// per-routine latency/flops without threading a parameter through
    /// every engine layer. Purely observational — never branches
    /// execution.
    pub routine: &'static str,
    /// Deterministic fault-injection schedule installed at runtime
    /// boot (`None` = consult `BLASX_FAULTS`, which is itself usually
    /// unset — the injector stays disarmed and costs one relaxed load
    /// per probe).
    pub fault_plan: Option<FaultPlan>,
    /// Per-job wall-clock deadline in milliseconds (None = unbounded).
    /// Checked cooperatively at round boundaries; an expired job fails
    /// with `Error::DeadlineExceeded` without disturbing other tenants.
    pub deadline_ms: Option<u64>,
    /// Admission bound: jobs refused with `Error::Backpressure` while
    /// this many are already in flight.
    pub admit_capacity: usize,
    /// Per-tenant in-flight quota, enforced at admission against the
    /// fairness ledger's tenant column.
    pub tenant_quota: usize,
    /// Per-call serial/fork flop cutoff for `hostblas::gemm_mt` inside
    /// tile kernels (None = the process-wide
    /// `hostblas::mt_flop_cutoff()`, i.e. `MT_FLOP_CUTOFF` or its
    /// `BLASX_MT_CUTOFF` override). The adaptive dispatcher stamps this
    /// per shape.
    pub mt_cutoff: Option<f64>,
    /// Telemetry sampler interval in milliseconds, applied at runtime
    /// boot (`None` = consult `BLASX_TELEMETRY_MS`, itself usually
    /// unset; `Some(0)` forces the sampler off regardless of
    /// environment). When off, no sampler thread exists and no
    /// telemetry memory is allocated — see `crate::trace::telemetry`.
    pub telemetry_ms: Option<u64>,
    /// Lookahead depth of the asynchronous transfer pipeline: how many
    /// upcoming reservation-station tasks each device worker walks to
    /// issue tile prefetches ahead of execution (`None` = consult
    /// `BLASX_PREFETCH_DEPTH`, itself usually unset; resolved 0 =
    /// prefetch off). Prefetched blocks are pinned with a
    /// consume-or-expire TTL and the effective depth adapts to arena
    /// headroom, so prefetch can never wedge the arena.
    pub prefetch: Option<usize>,
}

impl Default for RunConfig {
    fn default() -> RunConfig {
        RunConfig {
            t: 256,
            n_streams: 4,
            rs_capacity: 8,
            policy: Policy::Blasx,
            alloc: AllocStrategy::FastHeap,
            use_cpu: false,
            work_stealing: true,
            backend: Backend::Hostblas,
            worker_threads: 1,
            vram_override: None,
            k_chunk: 4,
            jitter: 0.05,
            routine: "l3",
            fault_plan: None,
            deadline_ms: None,
            admit_capacity: 256,
            tenant_quota: 64,
            mt_cutoff: None,
            telemetry_ms: None,
            prefetch: None,
        }
    }
}

/// Deterministic kernel-duration multiplier in `[1-jitter, 1+jitter]`
/// for (device, task) — shared by the BLASX engine and every baseline.
pub fn jitter_factor(jitter: f64, dev: usize, task: usize) -> f64 {
    if jitter <= 0.0 {
        return 1.0;
    }
    let mut s = (task as u64).wrapping_mul(0x9E3779B97F4A7C15) ^ (dev as u64).wrapping_mul(0xD1B54A32D192ED03);
    let x = crate::util::prng::splitmix64(&mut s);
    let u = (x >> 11) as f64 / (1u64 << 53) as f64; // [0,1)
    1.0 + jitter * (2.0 * u - 1.0)
}

impl RunConfig {
    /// Paper-benchmark defaults: T=1024 tiles, 4 streams, stealing on.
    pub fn paper() -> RunConfig {
        RunConfig { t: 1024, ..Default::default() }
    }

    pub fn with_policy(mut self, p: Policy) -> RunConfig {
        self.policy = p;
        self
    }

    pub fn with_tile(mut self, t: usize) -> RunConfig {
        self.t = t;
        self
    }

    pub fn with_prefetch(mut self, depth: usize) -> RunConfig {
        self.prefetch = Some(depth);
        self
    }

    /// Resolved prefetch lookahead depth: the config field if set, else
    /// the `BLASX_PREFETCH_DEPTH` environment variable, else 0 (off).
    pub fn prefetch_depth(&self) -> usize {
        if let Some(d) = self.prefetch {
            return d;
        }
        std::env::var("BLASX_PREFETCH_DEPTH")
            .ok()
            .and_then(|s| s.trim().parse::<usize>().ok())
            .unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn policy_names_roundtrip() {
        for p in Policy::ALL {
            assert_eq!(Policy::from_name(p.name()), Some(p));
        }
        assert_eq!(Policy::from_name("xt"), Some(Policy::CublasXt));
        assert_eq!(Policy::from_name("bogus"), None);
    }

    #[test]
    fn defaults_sane() {
        let c = RunConfig::default();
        assert_eq!(c.n_streams, 4);
        assert!(c.rs_capacity >= c.n_streams);
        assert_eq!(c.worker_threads, 1, "kernels single-threaded unless asked");
        assert_eq!(RunConfig::paper().t, 1024);
        assert!(c.fault_plan.is_none(), "no chaos unless asked");
        assert!(c.deadline_ms.is_none(), "jobs unbounded unless asked");
        assert!(c.telemetry_ms.is_none(), "no sampler thread unless asked");
        assert!(c.admit_capacity >= c.tenant_quota, "one tenant can't starve the table alone");
        assert!(c.prefetch.is_none(), "no prefetch unless asked (env decides)");
    }

    #[test]
    fn prefetch_depth_resolution() {
        // Explicit config wins outright (no env consult).
        assert_eq!(RunConfig::default().with_prefetch(3).prefetch_depth(), 3);
        assert_eq!(RunConfig::default().with_prefetch(0).prefetch_depth(), 0);
    }
}
