//! The real (threaded) execution engine: Alg. 1 with actual bytes.
//!
//! One worker per virtual device; each device owns a memory arena (its
//! "VRAM") managed by the same FastHeap + ALRU + MESI-X machinery as
//! the simulator. Tiles are physically copied host↔arena (and
//! arena↔arena for L2/P2P hits); kernels execute through either the
//! pure-Rust hostblas kernels or the PJRT-loaded AOT artifacts (config
//! `Backend`).
//!
//! Scheduling is the identical policy to the sim engine: demand-driven
//! pulls from the shared non-blocking queue, reservation stations with
//! Eq. 3 priorities, lowest-priority work stealing, and reader releases
//! deferred to the end-of-round sync point (the ALRU "approximation").
//!
//! ## Engine core vs job state
//!
//! The engine is split into two halves so the same worker loop serves
//! both execution modes:
//!
//! - [`EngineCore`] — the *persistent* half: device arenas, the
//!   ALRU/MESI-X [`TileCacheSet`], and the condvar idle workers park
//!   on. The one-shot [`run_real`]/[`run_real_batch`] entry points
//!   build a fresh core per call (scoped worker threads, caches die
//!   with the call); the resident [`crate::runtime::Runtime`] keeps
//!   one core alive across calls, which is what turns repeated calls
//!   over the same operands into L1/L2 tile-cache hits instead of
//!   re-transfers.
//! - [`JobState`] — the per-call half: the task graph, dependency
//!   counts, reservation stations, operand wraps and trace counters of
//!   one submitted call (or fused batch).
//!
//! Arenas are byte-granular (8-byte aligned storage) so one persistent
//! core serves f32 and f64 jobs alike; cache block lengths are rounded
//! up to 8 bytes to keep FastHeap offsets aligned for either dtype.
//!
//! On this testbed the PJRT CPU client executes kernels synchronously,
//! so "streams" provide issue-order structure rather than physical
//! overlap — the overlap claim is measured on the simulated substrate
//! (DESIGN.md §1); *correctness* of the full protocol stack is what
//! runs here.

use super::config::{Backend, RunConfig};
use crate::api::Scalar;
use crate::cache::{AsyncAcquire, CacheStats, FillTicket, Source, TileCacheSet};
use crate::error::{Error, Result};
use crate::fault::{FaultAction, FaultPlan, Injector, OpKind};
use crate::hostblas;
use crate::mem::{AllocStrategy, Offset};
use crate::queue::MsQueue;
use crate::runtime::TileExecutor;
use crate::sched::{task_priority, Station};
use crate::task::{Step, Task, TaskSet, TileOp, TileRef};
use crate::tile::{HostMat, MatId, TileKey};
use crate::trace::{FlightRecorder, Recorder, SpanKind};
use crate::util::once::OnceCell;
use std::cell::UnsafeCell;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Condvar, Mutex};
use std::time::Duration;

/// The three operands of a routine call. `b` may be absent (SYRK, TRMM,
/// TRSM read only A and C).
pub struct Mats<'m, T> {
    pub a: &'m HostMat<T>,
    pub b: Option<&'m HostMat<T>>,
    pub c: &'m HostMat<T>,
}

/// Owned operand wraps of one problem — the async-submission analogue
/// of [`Mats`]. The wraps (not the user buffers they point into) are
/// owned by the job itself, so a non-blocking caller can return from
/// the API while the job is still in flight; the user buffers' liveness
/// is enforced by [`crate::serve::JobHandle`]'s borrow.
pub(crate) struct OwnedProblem<T: Scalar> {
    pub a: HostMat<T>,
    pub b: Option<HostMat<T>>,
    pub c: HostMat<T>,
}

impl<'m, T: Scalar> Mats<'m, T> {
    fn of(&self, id: MatId) -> &HostMat<T> {
        match id {
            MatId::A => self.a,
            MatId::B => self.b.unwrap_or(self.a),
            MatId::C => self.c,
        }
    }

    fn key(&self, r: TileRef) -> TileKey {
        self.of(r.mat).tile_key(r.ti, r.tj)
    }
}

/// Cache-block length of a `t × t` tile of `T`, rounded up to 8 bytes
/// so FastHeap offsets stay aligned for every dtype sharing an arena.
pub(crate) fn block_bytes<T: Scalar>(t: usize) -> usize {
    (t * t * std::mem::size_of::<T>() + 7) & !7
}

/// One device's arena: byte-granular raw storage indexed by FastHeap
/// offsets, 8-byte aligned so both f32 and f64 jobs can slice it.
pub(crate) struct Arena {
    store: UnsafeCell<Box<[u64]>>,
    bytes: usize,
}

// SAFETY: the cache set serializes *ownership*, not the copies. A
// block's bytes are written only by its filler between the reserve
// (`acquire_async` under the cache lock, which pins the block and
// marks it pending) and the ready latch (`complete_fill`): the pending
// state makes the block invisible to peer-source selection and parks
// same-key acquirers on the latch, so the filler is the exclusive
// writer even though the copy itself runs WITHOUT the lock. Once
// latched ready, an input block is immutable until it is freed (the
// identity pad is applied at fill time, never on hits), so off-lock
// peer reads — whose source block is reader-pinned by the fill ticket
// — race nothing. C accumulator blocks stay pending (never
// peer-servable) for their whole task and are written back and
// invalidated before the dependency graph lets any consumer read the
// tile.
unsafe impl Send for Arena {}
unsafe impl Sync for Arena {}

impl Arena {
    fn new(bytes: usize) -> Arena {
        Arena {
            store: UnsafeCell::new(vec![0u64; bytes.div_ceil(8)].into_boxed_slice()),
            bytes,
        }
    }

    #[allow(clippy::mut_from_ref)]
    fn slice<T: Scalar>(&self, off: Offset, n: usize) -> &mut [T] {
        debug_assert!(off + n * std::mem::size_of::<T>() <= self.bytes);
        debug_assert!(off % std::mem::size_of::<T>() == 0);
        // SAFETY: offsets come from the FastHeap, which never hands out
        // overlapping live blocks; storage is 8-byte aligned and `off`
        // is a multiple of 8 (all block lengths are), so the cast is
        // aligned for any Scalar.
        unsafe {
            let base = (*self.store.get()).as_mut_ptr() as *mut u8;
            std::slice::from_raw_parts_mut(base.add(off) as *mut T, n)
        }
    }
}

/// The persistent half of the engine: arenas + caches + worker parking.
/// The one-shot entry points build a private core per call; the
/// resident runtime keeps one core alive and interleaves rounds of
/// EVERY live job over it (each device still runs one round at a time,
/// which is what keeps per-arena pin pressure bounded to a single
/// round).
pub(crate) struct EngineCore {
    pub(crate) caches: Mutex<TileCacheSet>,
    arenas: Vec<Arena>,
    /// Idle-worker parking: guards the "queue empty" check; notified on
    /// task enqueue and job completion so sleepers never busy-spin.
    work_mx: Mutex<()>,
    work_cv: Condvar,
    /// Process-shared PJRT tile executor, built on the first PJRT job
    /// and reused by every concurrent tenant afterwards (the
    /// `KernelPool` sharing pattern — previously each job constructed
    /// its own). The underlying compiled-executable cache is already
    /// process-wide (`PjrtPool`); this removes the per-job handle and
    /// artifact-store probe from the submit path.
    executor: OnceCell<TileExecutor>,
    /// Wall-clock span recorder shared by every worker and every job
    /// on this core (disabled by default; `BLASX_TRACE=1`,
    /// `Context::set_tracing` or `--trace-out` switch it on). Lives on
    /// the core because spans are per *device worker*, which is a
    /// core-level concept — jobs come and go.
    pub(crate) rec: Recorder,
    /// Fault-injection plane (deterministic chaos). Disarmed — one
    /// relaxed load per probe — unless a plan is installed at boot
    /// (`RunConfig::fault_plan` / `BLASX_FAULTS`).
    pub(crate) faults: Injector,
    /// Devices lost to a fault. A dead device schedules nothing, its
    /// stations drain back to the job queues (migration), and its
    /// cache entries were surgically invalidated at kill time (peer
    /// replicas and host master copies stay valid).
    dead: Vec<AtomicBool>,
    /// Jobs currently runnable on the resident runtime (maintained by
    /// its scheduler; 0 under the one-shot engine). The k-chunk
    /// splitter consults this to bound per-round step bursts when the
    /// admission table is contended.
    pub(crate) runnable_jobs: AtomicUsize,
    /// Always-on black-box event trail (bounded memory even with the
    /// span recorder off) + incident auto-dump. See
    /// [`crate::trace::flight`].
    pub(crate) flight: FlightRecorder,
    /// Transfers currently copying bytes off-lock (demand fills and
    /// prefetches alike) — the in-flight-transfer gauge.
    inflight_transfers: AtomicUsize,
    /// Per-device lifetime prefetch counters (telemetry/Prometheus;
    /// the per-job view lives in each job's `TransferCounters`).
    prefetch_hits: Vec<AtomicUsize>,
    prefetch_wasted: Vec<AtomicUsize>,
    /// Per-device prefetch ledger: tiles fetched ahead of execution,
    /// still holding their consume-or-expire reader pin. The value is
    /// the remaining TTL in scheduler rounds; the round sync point
    /// decrements it and expiry releases the pin, so prefetch can
    /// never wedge the arena.
    prefetched: Vec<Mutex<std::collections::HashMap<TileKey, u32>>>,
}

impl EngineCore {
    pub(crate) fn new(n_devices: usize, arena_bytes: usize, alloc: AllocStrategy) -> EngineCore {
        assert!(n_devices >= 1);
        // All devices are peers in real mode (host RAM is one address
        // space; the "P2P copy" is an arena→arena memcpy, exercising
        // the L2 path).
        let peers: Vec<Vec<usize>> =
            (0..n_devices).map(|d| (0..n_devices).filter(|&x| x != d).collect()).collect();
        let capacities = vec![arena_bytes; n_devices];
        let core = EngineCore {
            caches: Mutex::new(TileCacheSet::new(&capacities, peers, alloc)),
            arenas: (0..n_devices).map(|_| Arena::new(arena_bytes)).collect(),
            work_mx: Mutex::new(()),
            work_cv: Condvar::new(),
            executor: OnceCell::new(),
            rec: Recorder::new(n_devices),
            faults: Injector::new(n_devices),
            dead: (0..n_devices).map(|_| AtomicBool::new(false)).collect(),
            runnable_jobs: AtomicUsize::new(0),
            flight: FlightRecorder::new(n_devices),
            inflight_transfers: AtomicUsize::new(0),
            prefetch_hits: (0..n_devices).map(|_| AtomicUsize::new(0)).collect(),
            prefetch_wasted: (0..n_devices).map(|_| AtomicUsize::new(0)).collect(),
            prefetched: (0..n_devices).map(|_| Mutex::new(std::collections::HashMap::new())).collect(),
        };
        // Environment fallback (`BLASX_FAULTS`) arms both execution
        // modes; the resident runtime overrides with the config plan
        // at boot when one is set.
        if let Some(plan) = FaultPlan::from_env() {
            core.faults.install(plan);
        }
        core
    }

    /// Is `dev` lost? (Relaxed: a stale `false` just means one more
    /// round takes the error path before observing the kill.)
    pub(crate) fn is_dead(&self, dev: usize) -> bool {
        self.dead[dev].load(Ordering::Relaxed)
    }

    /// Devices still alive.
    pub(crate) fn alive_count(&self) -> usize {
        self.dead.iter().filter(|d| !d.load(Ordering::Relaxed)).count()
    }

    /// Indices of devices lost to faults — THE source of truth for
    /// fleet health: `/healthz`, `snapshot_metrics()["devices"]` and
    /// the `blasx_device_up` gauge all derive from this one ledger (a
    /// regression test pins the agreement).
    pub(crate) fn dead_devices(&self) -> Vec<usize> {
        self.dead
            .iter()
            .enumerate()
            .filter(|(_, d)| d.load(Ordering::Relaxed))
            .map(|(i, _)| i)
            .collect()
    }

    /// Mark `dev` lost: surgically invalidate its cache entries (host
    /// master copies and peer replicas stay valid — NOT a global
    /// purge) and wake every worker so migration starts immediately.
    /// Returns `true` for the call that performed the kill.
    ///
    /// Lock discipline: callers must not hold the caches lock.
    pub(crate) fn kill_device(&self, dev: usize) -> bool {
        let first = !self.dead[dev].swap(true, Ordering::SeqCst);
        if first {
            let t0 = self.rec.now();
            self.lock_caches().evict_device(dev);
            self.rec.record(dev, SpanKind::Fault, t0, dev as f64, 0);
            self.flight.record(Some(dev), "fault", 0, 0, dev as f64);
            // The black box: a device death is THE incident the flight
            // recorder exists for — dump the ring (no-op unless a dump
            // directory is armed).
            self.flight.maybe_dump("device-kill", &self.dead_devices());
            self.notify_work();
        }
        first
    }

    /// The shared PJRT tile executor (lazy; a failed init — e.g. a
    /// missing artifact store — is retried by the next PJRT job and
    /// surfaces as that job's failure, not a poisoned fleet).
    pub(crate) fn tile_executor(&self) -> Result<&TileExecutor> {
        self.executor.get_or_try_init(TileExecutor::new)
    }

    /// The tile caches, recovering a poisoned lock: a contained worker
    /// panic (see `runtime::service`) may have died mid-update while
    /// holding it. The panicking job is failed (its pins are released
    /// on the abort path — no purge exists anymore), so recovering the
    /// guard keeps the resident fleet serviceable instead of cascading
    /// `PoisonError` panics through every later call.
    pub(crate) fn lock_caches(&self) -> std::sync::MutexGuard<'_, TileCacheSet> {
        self.caches.lock().unwrap_or_else(|e| e.into_inner())
    }

    /// Wake parked workers (new ready tasks, a job finished, or a new
    /// job was admitted). The lock round-trip pairs with the sleeper's
    /// re-check under the same lock, so wakeups cannot be missed.
    ///
    /// Lock discipline: callers must NOT hold the resident runtime's
    /// job-table lock here (parked workers take it inside their
    /// `still_idle` re-check — see [`EngineCore::park_for_work`]).
    pub(crate) fn notify_work(&self) {
        let _g = self.work_mx.lock().unwrap_or_else(|e| e.into_inner());
        self.work_cv.notify_all();
    }

    /// Park the calling worker until [`EngineCore::notify_work`] (or
    /// the timeout, used as a work-stealing re-probe backstop —
    /// station-held surplus has no notify hook). `still_idle` is
    /// re-evaluated under the park lock, pairing with the notifier's
    /// lock round-trip so a wakeup between the caller's idle check and
    /// the wait cannot be missed.
    pub(crate) fn park_for_work(
        &self,
        timeout: Option<Duration>,
        still_idle: impl FnOnce() -> bool,
    ) {
        let guard = self.work_mx.lock().unwrap_or_else(|e| e.into_inner());
        if still_idle() {
            match timeout {
                Some(d) => {
                    let _ = self.work_cv.wait_timeout(guard, d);
                }
                None => {
                    let _ = self.work_cv.wait(guard);
                }
            }
        }
    }

    /// Transfers currently moving bytes off-lock (gauge).
    pub(crate) fn inflight_transfers(&self) -> usize {
        self.inflight_transfers.load(Ordering::Relaxed)
    }

    /// Lifetime (prefetch_hits, prefetch_wasted) of one device.
    pub(crate) fn prefetch_counters(&self, dev: usize) -> (usize, usize) {
        (
            self.prefetch_hits[dev].load(Ordering::Relaxed),
            self.prefetch_wasted[dev].load(Ordering::Relaxed),
        )
    }

    /// Consume-check of the prefetch ledger: if `key` was prefetched on
    /// `dev`, drop the ledger entry and its reader pin (the caller
    /// holds the caches lock) and count the hit. The demand acquire
    /// that triggered this holds its own pin, so the block stays
    /// resident. Lock order is caches → ledger, everywhere.
    fn prefetch_consume(&self, caches: &mut TileCacheSet, dev: usize, key: &TileKey) -> bool {
        let mut ledger = self.prefetched[dev].lock().unwrap_or_else(|e| e.into_inner());
        if ledger.remove(key).is_none() {
            return false;
        }
        drop(ledger);
        caches.release(dev, key);
        self.prefetch_hits[dev].fetch_add(1, Ordering::Relaxed);
        true
    }

    /// The output path is about to invalidate a prefetched tile (an
    /// input staged ahead is being overwritten as a C block): drop its
    /// ledger entry + pin *first*, counted wasted — the staged bytes
    /// never served anyone, and the pin must not keep the doomed block's
    /// bytes allocated.
    fn prefetch_discard(&self, caches: &mut TileCacheSet, dev: usize, key: &TileKey) -> bool {
        let mut ledger = self.prefetched[dev].lock().unwrap_or_else(|e| e.into_inner());
        if ledger.remove(key).is_none() {
            return false;
        }
        drop(ledger);
        caches.release(dev, key);
        self.prefetch_wasted[dev].fetch_add(1, Ordering::Relaxed);
        true
    }

    /// Pressure valve: drop EVERY ledger pin on `dev` (counted wasted;
    /// the blocks stay resident unpinned, so a later demand still L1-
    /// hits them). The demand path's sync&retry calls this before
    /// entering the OOM ladder, so lookahead pins can never turn arena
    /// pressure into a degradation that prefetch-off would not have
    /// had. Returns how many pins were dropped.
    fn prefetch_flush(&self, caches: &mut TileCacheSet, dev: usize) -> usize {
        let keys: Vec<TileKey> = {
            let mut ledger = self.prefetched[dev].lock().unwrap_or_else(|e| e.into_inner());
            ledger.drain().map(|(k, _)| k).collect()
        };
        for k in &keys {
            caches.release(dev, k);
        }
        if !keys.is_empty() {
            self.prefetch_wasted[dev].fetch_add(keys.len(), Ordering::Relaxed);
        }
        keys.len()
    }

    /// Round-sync TTL sweep of the prefetch ledger: age every entry,
    /// release the pins of expired ones (counted as wasted prefetch).
    /// Cheap no-op while the ledger is empty — the prefetch-off path
    /// costs one mutex probe of an empty map per round. Returns the
    /// number of expired entries so the sweeping round can charge its
    /// job's counters.
    pub(crate) fn prefetch_sweep(&self, dev: usize) -> usize {
        let mut expired: Vec<TileKey> = Vec::new();
        {
            let mut ledger = self.prefetched[dev].lock().unwrap_or_else(|e| e.into_inner());
            if ledger.is_empty() {
                return 0;
            }
            ledger.retain(|key, ttl| {
                if *ttl <= 1 {
                    expired.push(*key);
                    false
                } else {
                    *ttl -= 1;
                    true
                }
            });
        }
        if expired.is_empty() {
            return 0;
        }
        let mut caches = self.lock_caches();
        for key in &expired {
            caches.release(dev, key);
        }
        drop(caches);
        self.prefetch_wasted[dev].fetch_add(expired.len(), Ordering::Relaxed);
        expired.len()
    }
}

/// Per-call host→device transfer trace: how each input acquire was
/// served. This is what makes cross-call cache reuse *observable* — a
/// warm second call over unchanged operands shows zero host reads.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct TransferStats {
    /// Host→arena tile reads per operand (A, B, C order; C counts both
    /// accumulator pre-loads and chain reads of neighbour C tiles).
    pub host_reads: [usize; 3],
    /// Arena→arena copies (L2 peer hits).
    pub peer_copies: usize,
    /// Acquires served from the device's own L1 — no bytes moved.
    pub l1_hits: usize,
    /// Demand acquires that found their tile already staged by the
    /// lookahead prefetcher (the transfer itself is also counted in
    /// `host_reads`/`peer_copies` — a hit means it was *early*, not
    /// free).
    pub prefetch_hits: usize,
    /// Prefetched tiles whose consume-or-expire TTL lapsed before any
    /// task touched them — bytes moved for nothing.
    pub prefetch_wasted: usize,
}

impl TransferStats {
    /// Total host→device tile transfers of the call.
    pub fn total_host_reads(&self) -> usize {
        self.host_reads.iter().sum()
    }

    /// Host reads of the *input* operands A and B only (C is rewritten
    /// every call, so its reads are expected on warm repeats).
    pub fn input_host_reads(&self) -> usize {
        self.host_reads[0] + self.host_reads[1]
    }
}

/// Live per-job observability counters, readable *before* the job
/// retires (unlike [`RealReport`], which exists only after). This is
/// what the C ABI's `blasx_job_stats` and `JobHandle::stats` surface —
/// the counters `blasx_wait` used to discard.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct JobStats {
    /// Tasks executed so far (across all devices).
    pub tasks: usize,
    /// Host→arena tile reads per operand (A, B, C order).
    pub host_reads: [usize; 3],
    /// Arena→arena copies (L2 peer hits).
    pub peer_copies: usize,
    /// Acquires served from a device's own L1 — no bytes moved.
    pub l1_hits: usize,
    /// Intra-job work steals (across all devices).
    pub steals: usize,
    /// Demand acquires served early by the lookahead prefetcher.
    pub prefetch_hits: usize,
    /// Prefetched tiles that expired unconsumed.
    pub prefetch_wasted: usize,
}

struct TransferCounters {
    host_reads: [AtomicUsize; 3],
    peer_copies: AtomicUsize,
    l1_hits: AtomicUsize,
    prefetch_hits: AtomicUsize,
    prefetch_wasted: AtomicUsize,
}

impl TransferCounters {
    fn new() -> TransferCounters {
        TransferCounters {
            host_reads: [AtomicUsize::new(0), AtomicUsize::new(0), AtomicUsize::new(0)],
            peer_copies: AtomicUsize::new(0),
            l1_hits: AtomicUsize::new(0),
            prefetch_hits: AtomicUsize::new(0),
            prefetch_wasted: AtomicUsize::new(0),
        }
    }

    fn count_host(&self, mat: MatId) {
        let i = match mat {
            MatId::A => 0,
            MatId::B => 1,
            MatId::C => 2,
        };
        self.host_reads[i].fetch_add(1, Ordering::Relaxed);
    }

    fn snapshot(&self) -> TransferStats {
        TransferStats {
            host_reads: [
                self.host_reads[0].load(Ordering::Relaxed),
                self.host_reads[1].load(Ordering::Relaxed),
                self.host_reads[2].load(Ordering::Relaxed),
            ],
            peer_copies: self.peer_copies.load(Ordering::Relaxed),
            l1_hits: self.l1_hits.load(Ordering::Relaxed),
            prefetch_hits: self.prefetch_hits.load(Ordering::Relaxed),
            prefetch_wasted: self.prefetch_wasted.load(Ordering::Relaxed),
        }
    }
}

/// The per-call half of the engine: one submitted call (or fused
/// batch). Borrows the task set and operand wraps for `'m`; the
/// resident runtime erases that lifetime — a blocking caller parks
/// until the job retires; an async job OWNS its wraps (`OwnedJob` in
/// `runtime::service`, alive until retirement via the job table's
/// Arc), and the liveness of the *user buffers* behind them is
/// guaranteed by the scope close barrier (`Context::scope` waits for
/// every job in its own frame — handle drop is a plain detach and is
/// NOT load-bearing) or, on the C ABI, by the caller's `blasx_wait`
/// contract.
pub(crate) struct JobState<'m, T: Scalar> {
    cfg: RunConfig,
    tasks: &'m [Task],
    deps: Vec<AtomicUsize>,
    remaining: AtomicUsize,
    queue: MsQueue<usize>,
    stations: Vec<Mutex<Station>>,
    /// Operand sets, indexed by `Task::p` / `TileRef::p` (a single
    /// routine call is a batch of one).
    mats: Vec<Mats<'m, T>>,
    /// First kernel error (poisoning the run).
    failure: Mutex<Option<Error>>,
    /// Steals per device (observability).
    steals: Vec<AtomicUsize>,
    tasks_done: Vec<AtomicUsize>,
    transfers: TransferCounters,
    /// Per-task resume cursor for the k-chunk splitter: index of the
    /// first unexecuted step (nonzero only while a split task waits
    /// to resume; a task is owned by one worker at a time, so plain
    /// relaxed loads/stores suffice).
    resume: Vec<AtomicUsize>,
    /// Ops retried after transient faults or arena pressure.
    retried: AtomicUsize,
    /// Operands served through the host-path fallback after arena OOM.
    degraded: AtomicUsize,
    /// Tasks migrated off dead devices (re-queued or drained).
    migrated: AtomicUsize,
    /// Total chain flops of the job (the multi-tenant scheduler's
    /// fair-share weight; cached at construction).
    total_flops: f64,
    /// Admission id under the resident runtime (0 for the one-shot
    /// engine) — stamps this job's spans so the Chrome export can
    /// attribute device time to jobs.
    trace_id: AtomicU64,
    /// Per-device cache counters snapshotted at admission, so
    /// [`RealReport::cache_delta`] can report *this call's* cache
    /// behaviour even though the ALRUs are cumulative across the
    /// resident core's lifetime. Empty for the one-shot engine (fresh
    /// core ⇒ cumulative == per-call).
    cache_baseline: Mutex<Vec<CacheStats>>,
}

impl<'m, T: Scalar> JobState<'m, T> {
    pub(crate) fn new(
        cfg: &RunConfig,
        ts: &'m TaskSet,
        problems: Vec<Mats<'m, T>>,
        n_devices: usize,
    ) -> Result<JobState<'m, T>> {
        debug_assert!(
            ts.tasks.iter().all(|t| t.p < problems.len()),
            "task problem index out of range"
        );
        let state = JobState {
            cfg: cfg.clone(),
            tasks: &ts.tasks,
            deps: ts.tasks.iter().map(|t| AtomicUsize::new(t.n_deps)).collect(),
            remaining: AtomicUsize::new(ts.tasks.len()),
            queue: MsQueue::new(),
            stations: (0..n_devices).map(|_| Mutex::new(Station::new(cfg.rs_capacity))).collect(),
            mats: problems,
            failure: Mutex::new(None),
            steals: (0..n_devices).map(|_| AtomicUsize::new(0)).collect(),
            tasks_done: (0..n_devices).map(|_| AtomicUsize::new(0)).collect(),
            transfers: TransferCounters::new(),
            resume: ts.tasks.iter().map(|_| AtomicUsize::new(0)).collect(),
            retried: AtomicUsize::new(0),
            degraded: AtomicUsize::new(0),
            migrated: AtomicUsize::new(0),
            total_flops: ts.total_flops(),
            trace_id: AtomicU64::new(0),
            cache_baseline: Mutex::new(Vec::new()),
        };
        for &h in &ts.heads {
            state.queue.enqueue(h);
        }
        Ok(state)
    }

    /// Record a failure (first one wins). Used by the worker loop and
    /// by the resident runtime's panic containment.
    pub(crate) fn fail(&self, e: Error) {
        let mut f = self.failure.lock().unwrap();
        if f.is_none() {
            *f = Some(e);
        }
    }

    /// Assemble the call report after every worker has finished. Takes
    /// `&self` (the failure slot is drained, so call it once): the
    /// resident runtime's waiters extract the report through a shared
    /// `Arc` without unwrapping it.
    pub(crate) fn report(&self, core: &EngineCore) -> Result<RealReport> {
        if let Some(e) = self.failure.lock().unwrap().take() {
            return Err(e);
        }
        let rem = self.remaining.load(Ordering::SeqCst);
        if rem != 0 {
            return Err(Error::Internal(format!("real engine stalled with {rem} tasks")));
        }
        let caches = core.lock_caches();
        let cache_stats: Vec<CacheStats> =
            (0..self.stations.len()).map(|d| caches.stats(d)).collect();
        drop(caches);
        let baseline = self.cache_baseline.lock().unwrap_or_else(|e| e.into_inner());
        let cache_delta = cache_stats
            .iter()
            .enumerate()
            .map(|(d, s)| s.delta_since(&baseline.get(d).copied().unwrap_or_default()))
            .collect();
        drop(baseline);
        Ok(RealReport {
            tasks_per_device: self.tasks_done.iter().map(|a| a.load(Ordering::SeqCst)).collect(),
            cache_stats,
            cache_delta,
            steals: self.steals.iter().map(|a| a.load(Ordering::SeqCst)).collect(),
            transfers: self.transfers.snapshot(),
        })
    }

    /// Stamp the resident runtime's admission id onto this job's spans.
    pub(crate) fn set_trace_id(&self, id: u64) {
        self.trace_id.store(id, Ordering::Relaxed);
    }

    /// Snapshot the per-device cache counters at admission so the
    /// report can expose a per-call delta (see `cache_baseline`).
    pub(crate) fn set_cache_baseline(&self, baseline: Vec<CacheStats>) {
        *self.cache_baseline.lock().unwrap_or_else(|e| e.into_inner()) = baseline;
    }

    /// Live counters of this job so far — readable while it is still
    /// in flight (the report exists only after retirement).
    pub(crate) fn stats(&self) -> JobStats {
        let t = self.transfers.snapshot();
        JobStats {
            tasks: self.tasks_done.iter().map(|a| a.load(Ordering::Relaxed)).sum(),
            host_reads: t.host_reads,
            peer_copies: t.peer_copies,
            l1_hits: t.l1_hits,
            steals: self.steals.iter().map(|a| a.load(Ordering::Relaxed)).sum(),
            prefetch_hits: t.prefetch_hits,
            prefetch_wasted: t.prefetch_wasted,
        }
    }

    /// The operand sets of this job (admission derives conflict byte
    /// ranges and stamps invalidation epochs through these).
    pub(crate) fn problems(&self) -> &[Mats<'m, T>] {
        &self.mats
    }

    /// Total chain flops — the fair-share weight under multi-tenant
    /// interleaving.
    pub(crate) fn weight(&self) -> f64 {
        self.total_flops
    }

    /// Every task executed (a `Progress` round may have finished the
    /// job without the worker observing `Round::Finished`).
    pub(crate) fn done(&self) -> bool {
        self.remaining.load(Ordering::SeqCst) == 0
    }

    /// Fault-recovery counters so far — live, like [`JobState::stats`]
    /// (the metrics registry reads them at retirement).
    pub(crate) fn fault_stats(&self) -> FaultStats {
        FaultStats {
            retried: self.retried.load(Ordering::Relaxed),
            degraded: self.degraded.load(Ordering::Relaxed),
            migrated: self.migrated.load(Ordering::Relaxed),
        }
    }
}

/// Fault-recovery counters of one job: how much of the fault-tolerance
/// machinery it exercised. All zero on a healthy run.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct FaultStats {
    /// Operations retried after transient faults or arena pressure.
    pub retried: usize,
    /// Operands served through the host-path OOM fallback.
    pub degraded: usize,
    /// Tasks migrated off dead devices (re-queued or drained).
    pub migrated: usize,
}

impl FaultStats {
    /// Did any recovery path fire?
    pub fn any(&self) -> bool {
        self.retried + self.degraded + self.migrated > 0
    }
}

/// Run a task set over `mats` with `n_devices` worker threads.
///
/// `arena_bytes` is each device's VRAM analogue; small arenas exercise
/// eviction (tests), large ones behave like the paper's 12 GB cards.
///
/// This is the one-shot entry point: engine state (arenas, tile
/// caches, worker threads) is built for the call and torn down with
/// it. The warm path — [`crate::api::Context`] with its default
/// persistent runtime — reuses all of that across calls.
pub fn run_real<T: Scalar>(
    cfg: &RunConfig,
    ts: &TaskSet,
    mats: Mats<'_, T>,
    n_devices: usize,
    arena_bytes: usize,
) -> Result<RealReport> {
    run_real_batch(cfg, ts, vec![mats], n_devices, arena_bytes)
}

/// Run a *fused batch* task set: `problems[p]` holds the operands of
/// every task with `Task::p == p` (see `crate::batch`). The scheduling
/// machinery is identical to the single-problem path — one queue, one
/// set of reservation stations, one tile-cache set spanning all
/// problems — which is exactly what amortizes runtime setup across the
/// batch. Operands shared between problems (e.g. one weight matrix
/// multiplied by many activation sets) share cache entries for free,
/// because tiles are keyed by host address (+ stride, so views of one
/// base pointer with different leading dimensions never alias).
pub fn run_real_batch<'m, T: Scalar>(
    cfg: &RunConfig,
    ts: &TaskSet,
    problems: Vec<Mats<'m, T>>,
    n_devices: usize,
    arena_bytes: usize,
) -> Result<RealReport> {
    assert!(n_devices >= 1);
    assert!(
        arena_bytes >= 8 * block_bytes::<T>(cfg.t),
        "arena must hold at least 8 tiles (working set of a round)"
    );
    let core = EngineCore::new(n_devices, arena_bytes, cfg.alloc);
    let job = JobState::new(cfg, ts, problems, n_devices)?;
    std::thread::scope(|scope| {
        for dev in 0..n_devices {
            let core = &core;
            let job = &job;
            scope.spawn(move || worker_loop(dev, core, job));
        }
    });
    job.report(&core)
}

/// Observability output of a real run (numerics land in the C matrix).
///
/// Under the persistent runtime `cache_stats` is *cumulative* since
/// the runtime booted (the ALRUs live across calls) — read
/// `cache_delta` for this call's cache behaviour; `transfers`,
/// `tasks_per_device` and `steals` are per-call.
#[derive(Debug)]
pub struct RealReport {
    pub tasks_per_device: Vec<usize>,
    /// Per-device ALRU counters, cumulative since the core was built.
    pub cache_stats: Vec<CacheStats>,
    /// Per-device ALRU counters accrued *since this job was admitted*
    /// (`cache_stats` minus the admission-time baseline). Note: on a
    /// shared resident core this window also contains the traffic of
    /// concurrently in-flight tenants — the devices are shared, so the
    /// delta is "what the caches did while this call ran", not "what
    /// this call alone did" (the job-private view is `transfers`).
    pub cache_delta: Vec<CacheStats>,
    pub steals: Vec<usize>,
    /// Per-call transfer trace (host reads / peer copies / L1 hits).
    pub transfers: TransferStats,
}

// -------------------------------------------------------------------
// worker

/// How long an idle worker sleeps before re-probing for stealable
/// surplus in sibling stations (the condvar covers queue arrivals and
/// completion exactly; station-level surplus has no notify hook). The
/// resident runtime's multi-job loop uses the same backstop.
pub(crate) const PARK_TIMEOUT: Duration = Duration::from_millis(1);

/// Bounded attempts for transient-fault retries and arena-OOM
/// eviction-retry before escalating: kernels escalate to a device
/// loss, allocations to the host-path fallback.
const RETRY_MAX: u32 = 3;

/// How long a wedged worker stalls before resuming (the injection
/// plane's `wedge` fault — long enough that siblings visibly absorb
/// the load, short enough for tests).
const WEDGE_STALL: Duration = Duration::from_millis(20);

/// Where a task operand lives for the duration of its kernels: a
/// pinned arena block (the normal, cached path) or a private host-side
/// copy (the arena-OOM degradation path — correctness preserved,
/// locality lost for this operand only).
enum Operand<T: Scalar> {
    Arena(Offset),
    Host(Vec<T>),
}

impl<T: Scalar> Operand<T> {
    /// The operand's elements (arena block or host copy).
    fn slice<'s>(&'s self, core: &'s EngineCore, dev: usize, n: usize) -> &'s [T] {
        match self {
            Operand::Arena(off) => &*core.arenas[dev].slice::<T>(*off, n),
            Operand::Host(v) => &v[..n],
        }
    }
}

/// Outcome of one [`run_task`] invocation.
enum TaskRun {
    /// Every remaining step executed; the task retired.
    Done { flops: f64 },
    /// A k-chunk executed and the task re-queued (contended table);
    /// `flops` is the chunk's share of the task total.
    Split { flops: f64 },
}

/// Outcome of one scheduler round (refill → bind → execute → sync) of
/// one job on one device. The one-shot [`worker_loop`] reacts by
/// parking or exiting; the resident runtime's multi-job worker uses it
/// to interleave rounds across every live job and to charge fair-share
/// flops.
#[derive(Clone, Copy, Debug)]
pub(crate) enum Round {
    /// Executed at least one task; `flops` is what the fair-share
    /// ledger is charged.
    Progress { flops: f64 },
    /// No ready task for this device right now (the job is still live:
    /// tasks are in flight elsewhere or waiting on chain predecessors).
    Idle,
    /// Every task of the job has completed.
    Finished,
    /// The job is poisoned (kernel error or contained panic).
    Failed,
}

/// One scheduler round of `job` on `dev`: refill the reservation
/// station from the job's queue (stealing intra-job surplus if dry),
/// bind up to `n_streams` tasks, execute them, and release the round's
/// readers at the sync point. Never parks — scheduling between rounds
/// (and between jobs) belongs to the caller.
pub(crate) fn worker_round<T: Scalar>(
    dev: usize,
    core: &EngineCore,
    job: &JobState<'_, T>,
) -> Round {
    let n_streams = job.cfg.n_streams;
    if job.failure.lock().unwrap().is_some() {
        core.notify_work();
        return Round::Failed;
    }
    let jid = job.trace_id.load(Ordering::Relaxed);
    // Consume-or-expire: age this device's prefetch ledger once per
    // round (dead devices included — their doomed blocks hold bytes
    // until the ledger pins drop). Attribution of the expiries to the
    // sweeping job is approximate under multi-tenancy (the ledger is
    // core-level); the core's per-device counters are exact.
    let expired = core.prefetch_sweep(dev);
    if expired > 0 {
        job.transfers.prefetch_wasted.fetch_add(expired, Ordering::Relaxed);
    }
    if core.is_dead(dev) {
        // A dead device schedules nothing; its station drains back to
        // the shared queue so survivors pick the work up (the steal
        // path generalized to migration).
        let moved = drain_station(dev, job);
        if moved > 0 {
            job.migrated.fetch_add(moved, Ordering::Relaxed);
            core.rec.record(dev, SpanKind::Migrate, core.rec.now(), moved as f64, jid);
            core.flight.record(Some(dev), "migrate", jid, 0, moved as f64);
            core.notify_work();
        }
        if job.done() {
            return Round::Finished;
        }
        if core.alive_count() == 0 {
            job.fail(Error::Degraded("all devices lost".into()));
            core.notify_work();
            return Round::Failed;
        }
        return Round::Idle;
    }
    let round_t0 = core.rec.now();
    // ---- refill the reservation station (lines 11–15)
    let mut bound: Vec<usize> = Vec::new();
    {
        let mut rs = job.stations[dev].lock().unwrap();
        while !rs.is_full() {
            match job.queue.dequeue() {
                Some(t) => {
                    let caches = core.lock_caches();
                    let p = task_priority(&job.tasks[t], dev, &caches, |r| job.mats[r.p].key(r));
                    rs.insert(t, p);
                }
                None => break,
            }
        }
        if rs.is_empty() && job.cfg.work_stealing {
            drop(rs);
            // steal from the fullest victim (within this job — tasks
            // of other live jobs are reached by the multi-job loop,
            // not by cross-job steals)
            let steal_t0 = core.rec.now();
            let mut stole = 0.0;
            let victim = (0..job.stations.len())
                .filter(|&v| v != dev)
                .max_by_key(|&v| job.stations[v].lock().unwrap().len());
            if let Some(v) = victim {
                if let Some(slot) = job.stations[v].lock().unwrap().steal_worst() {
                    job.stations[dev].lock().unwrap().insert(slot.task, slot.priority);
                    job.steals[dev].fetch_add(1, Ordering::Relaxed);
                    stole = 1.0;
                }
            }
            core.rec.record(dev, SpanKind::Steal, steal_t0, stole, jid);
            rs = job.stations[dev].lock().unwrap();
        }
        // refresh priorities after arrivals, then bind top tasks
        {
            let caches = core.lock_caches();
            rs.refresh(|t| task_priority(&job.tasks[t], dev, &caches, |r| job.mats[r.p].key(r)));
        }
        for _ in 0..n_streams {
            match rs.take_best() {
                Some(slot) => bound.push(slot.task),
                None => break,
            }
        }
    }

    if bound.is_empty() {
        if job.remaining.load(Ordering::SeqCst) == 0 {
            core.notify_work();
            return Round::Finished;
        }
        return Round::Idle;
    }

    // ---- lookahead prefetch (paper §V overlap, made explicit): stage
    // not-yet-resident operands of upcoming tasks before this round's
    // kernels run, so their H2D/P2P time sits under compute elsewhere
    // on the machine.
    prefetch_pass(dev, core, job, &bound);

    // ---- the round: solve the bound tasks (lines 18–25)
    let mut flops = 0.0;
    let mut releases: Vec<TileKey> = Vec::new();
    let mut bound = bound.into_iter();
    while let Some(tid) = bound.next() {
        match run_task(dev, core, job, tid, &mut releases) {
            Err(e) => {
                // Unpin the round's readers on either error path
                // (run_task already unpinned the failed task's C
                // block).
                let mut caches = core.lock_caches();
                for key in releases.drain(..) {
                    caches.release(dev, &key);
                }
                drop(caches);
                if core.is_dead(dev) {
                    // The device was lost mid-task. Nothing of the
                    // task escaped to host RAM (C writes back only at
                    // chunk/task end), so re-admitting it — and
                    // everything else this round had bound — onto the
                    // surviving devices is bit-for-bit safe. The job
                    // fails only if no device survives.
                    if core.alive_count() == 0 {
                        job.fail(Error::Degraded(format!(
                            "device {dev} lost and no devices survive: {e}"
                        )));
                        core.notify_work();
                        return Round::Failed;
                    }
                    let migrate_t0 = core.rec.now();
                    let mut moved = 1;
                    job.queue.enqueue(tid);
                    for rest in bound.by_ref() {
                        job.queue.enqueue(rest);
                        moved += 1;
                    }
                    moved += drain_station(dev, job);
                    job.migrated.fetch_add(moved, Ordering::Relaxed);
                    core.rec.record(dev, SpanKind::Migrate, migrate_t0, moved as f64, jid);
                    core.flight.record(Some(dev), "migrate", jid, 0, moved as f64);
                    core.notify_work();
                    return Round::Idle;
                }
                job.fail(e);
                core.notify_work();
                return Round::Failed;
            }
            Ok(TaskRun::Split { flops: f }) => {
                // Partial k-chunk: the task went back to the queue
                // with its resume cursor advanced — charge only the
                // chunk's share and leave the dependency counters
                // untouched.
                flops += f;
            }
            Ok(TaskRun::Done { flops: f }) => {
                flops += f;
                job.tasks_done[dev].fetch_add(1, Ordering::Relaxed);
                if job.remaining.fetch_sub(1, Ordering::SeqCst) == 1 {
                    // last task: wake parked siblings so they observe
                    // completion and exit promptly
                    core.notify_work();
                }
                if let Some(succ) = job.tasks[tid].successor {
                    if job.deps[succ].fetch_sub(1, Ordering::SeqCst) == 1 {
                        job.queue.enqueue(succ);
                        core.notify_work();
                    }
                }
            }
        }
    }
    // ---- sync point (line 16/17): release the round's readers
    let mut caches = core.lock_caches();
    for key in releases {
        caches.release(dev, &key);
    }
    drop(caches);
    core.rec.record(dev, SpanKind::Round, round_t0, flops, jid);
    Round::Progress { flops }
}

/// Drive one job to completion on `dev` — the one-shot engine's worker
/// body (the resident runtime interleaves [`worker_round`]s across
/// jobs instead).
pub(crate) fn worker_loop<T: Scalar>(dev: usize, core: &EngineCore, job: &JobState<'_, T>) {
    loop {
        match worker_round(dev, core, job) {
            Round::Progress { .. } => {}
            Round::Finished | Round::Failed => return,
            Round::Idle => {
                // Park until new tasks enqueue or the job completes.
                // The re-check under the lock pairs with
                // `notify_work`'s lock round-trip, so an enqueue
                // between our check and the wait cannot be missed; the
                // timeout is a backstop that lets us periodically
                // retry stealing station-held surplus.
                let park_t0 = core.rec.now();
                core.park_for_work(Some(PARK_TIMEOUT), || {
                    // A dead device parks even with a non-empty queue:
                    // that work belongs to the survivors now.
                    (core.is_dead(dev) || job.queue.is_empty())
                        && job.remaining.load(Ordering::SeqCst) != 0
                });
                core.rec.record(dev, SpanKind::Park, park_t0, 0.0, 0);
            }
        }
    }
}

/// Drain every slot of this job's reservation station on `dev` back to
/// the shared queue (device-loss migration). Returns how many moved.
fn drain_station<T: Scalar>(dev: usize, job: &JobState<'_, T>) -> usize {
    let mut rs = job.stations[dev].lock().unwrap_or_else(|e| e.into_inner());
    let mut n = 0;
    while let Some(slot) = rs.steal_worst() {
        job.queue.enqueue(slot.task);
        n += 1;
    }
    n
}

/// Solve one task: acquire C, stream the k-steps, write C back.
///
/// Under a contended job table the k-chunk splitter may stop early —
/// write the partial accumulator back, re-queue the task with its
/// resume cursor advanced, and return [`TaskRun::Split`] — so long
/// step chains yield the device between chunks instead of holding it
/// for the whole k-loop. Arena pressure and injected transfer faults
/// degrade to retries and host-path fallbacks; the only error this
/// returns on a *surviving* device is a genuine kernel failure, and
/// the C pin is released on every path (leaking it is what used to
/// force a global cache purge after any failed job).
fn run_task<T: Scalar>(
    dev: usize,
    core: &EngineCore,
    job: &JobState<'_, T>,
    tid: usize,
    releases: &mut Vec<TileKey>,
) -> Result<TaskRun> {
    let t = job.cfg.t;
    let tile_elems = t * t;
    let tile_bytes = block_bytes::<T>(t);
    let task = &job.tasks[tid];
    let cmat = job.mats[task.p].of(MatId::C);
    let ckey = cmat.tile_key(task.ci, task.cj);
    let jid = job.trace_id.load(Ordering::Relaxed);

    // k-chunk window. Resumable only for full-mask tasks: a triangle-
    // masked write-back cannot round-trip the unmasked half of the
    // accumulator through host RAM bit-for-bit.
    let total = task.steps.len();
    let start = job.resume[tid].load(Ordering::Relaxed);
    let splittable = matches!(task.mask, crate::task::WriteMask::Full);
    let contended = core.runnable_jobs.load(Ordering::Relaxed) > 1;
    let end = if splittable && contended {
        total.min(start + job.cfg.k_chunk.max(1))
    } else {
        total
    };
    let resumed = start > 0;

    // -- C accumulator block: arena if the cache can hold it, private
    // host scratch if arena pressure persists (the OOM degradation
    // ladder — never an error).
    if core.faults.tick(dev, OpKind::Alloc) {
        core.lock_caches().force_alloc_failure(dev, 1);
    }
    let mut c_ticket: Option<FillTicket> = None;
    let mut c_loc: Operand<T> = {
        let mut attempt = 0u32;
        loop {
            let mut caches = core.lock_caches();
            // If the lookahead staged this tile as an *input*, the
            // write below invalidates it: drop the ledger pin first so
            // the doomed block's bytes free immediately.
            if core.prefetch_discard(&mut caches, dev, &ckey) {
                job.transfers.prefetch_wasted.fetch_add(1, Ordering::Relaxed);
            }
            let mut acq = caches.acquire_output_async(dev, ckey, tile_bytes);
            if acq.is_none() && attempt == 0 {
                // Cache pressure: this is the paper's "sync & retry" —
                // kernels already issued this round are complete (real
                // mode is synchronous), so the round's readers can be
                // released early and the acquire retried. Lookahead
                // pins go too: prefetch must never turn pressure into
                // a degradation that prefetch-off would not have had.
                for key in releases.drain(..) {
                    caches.release(dev, &key);
                }
                let flushed = core.prefetch_flush(&mut caches, dev);
                if flushed > 0 {
                    job.transfers.prefetch_wasted.fetch_add(flushed, Ordering::Relaxed);
                }
                acq = caches.acquire_output_async(dev, ckey, tile_bytes);
            }
            match acq {
                Some(ticket) => {
                    let off = ticket.offset;
                    c_ticket = Some(ticket);
                    break Operand::Arena(off);
                }
                None if attempt < RETRY_MAX => {
                    // Bounded backoff: peer workers release readers at
                    // their round sync points; give them a moment.
                    drop(caches);
                    attempt += 1;
                    job.retried.fetch_add(1, Ordering::Relaxed);
                    core.rec.record(dev, SpanKind::Retry, core.rec.now(), attempt as f64, jid);
                    std::thread::sleep(Duration::from_micros(50 * attempt as u64));
                }
                None => {
                    drop(caches);
                    job.degraded.fetch_add(1, Ordering::Relaxed);
                    core.flight.record(Some(dev), "degrade", jid, 0, 0.0);
                    break Operand::Host(vec![T::zero(); tile_elems]);
                }
            }
        }
    };
    {
        // Initialize the accumulator OFF the cache lock: the reserved
        // block is pending — born pinned, invisible to peer-source
        // selection, and C blocks never latch ready — so this worker is
        // its exclusive writer until the write-back invalidates it.
        // Zero-pad edge tiles, pre-load C when the task reads it — or
        // when resuming a split chunk, whose partial accumulator
        // round-trips through host RAM.
        let preload = task.reads_c || resumed;
        let degraded_c = matches!(c_loc, Operand::Host(_));
        let cbuf: &mut [T] = match &mut c_loc {
            Operand::Arena(off) => core.arenas[dev].slice::<T>(*off, tile_elems),
            Operand::Host(v) => v,
        };
        // zero-pad only edge tiles (interior tiles are fully overwritten
        // by read_tile / the kernels — the memset was 15% of small-tile
        // acquire cost, EXPERIMENTS.md §Perf)
        let (h, w) = cmat.grid.tile_dims(task.ci, task.cj);
        if h < t || w < t || !preload {
            let pack_t0 = core.rec.now();
            for x in cbuf.iter_mut() {
                *x = T::zero();
            }
            core.rec.record(dev, SpanKind::Pack, pack_t0, 0.0, jid);
        }
        if preload {
            let h2d_t0 = core.rec.now();
            core.inflight_transfers.fetch_add(1, Ordering::Relaxed);
            cmat.read_tile(task.ci, task.cj, cbuf, t);
            core.inflight_transfers.fetch_sub(1, Ordering::Relaxed);
            job.transfers.count_host(MatId::C);
            // A degraded accumulator pre-load lands in private host
            // scratch — no DMA lane crossed, so it must not record as
            // H2d (that inflated COMM and the Table V volumes).
            let kind = if degraded_c { SpanKind::HostFallback } else { SpanKind::H2d };
            core.rec.record(dev, kind, h2d_t0, tile_bytes as f64, jid);
        }
    }

    // -- k-steps of this chunk
    let step_res: Result<()> = (|| {
        for step in &task.steps[start..end] {
            let mut a_op: Option<Operand<T>> = None;
            let mut b_op: Option<Operand<T>> = None;
            // Readers acquired for THIS step must survive any pressure
            // flush until its kernel has run.
            let keep_from = releases.len();
            for (slot, tile) in [(0, step.a), (1, step.b)] {
                let Some(tile) = tile else { continue };
                let op = acquire_input(dev, core, job, tile, releases, keep_from)?;
                if slot == 0 {
                    a_op = Some(op);
                } else {
                    b_op = Some(op);
                }
            }
            let a = a_op.as_ref().map(|o| o.slice(core, dev, tile_elems));
            let b = b_op.as_ref().map(|o| o.slice(core, dev, tile_elems));
            let c: &mut [T] = match &mut c_loc {
                Operand::Arena(off) => core.arenas[dev].slice::<T>(*off, tile_elems),
                Operand::Host(v) => v,
            };
            exec_step(dev, core, job, step, a, b, c)?;
        }
        Ok(())
    })();
    if let Err(e) = step_res {
        // Unpin and discard the C block on the way out: no bytes
        // reached host RAM, so the task can re-run from scratch. The
        // never-readied latch aborts, telling any (dependency-excluded,
        // so in practice nonexistent) same-key waiter to re-acquire.
        if let Operand::Arena(_) = c_loc {
            let mut caches = core.lock_caches();
            caches.writeback(dev, &ckey);
            caches.release(dev, &ckey);
            drop(caches);
            if let Some(ticket) = c_ticket.take() {
                ticket.latch.complete(false);
            }
        }
        return Err(e);
    }

    // -- write-back (M → I): store the masked extent to host RAM. A
    // split chunk writes back too; the resuming worker re-reads the
    // exact bytes.
    {
        // OFF the cache lock: the accumulator block is pending-pinned
        // (this worker is its exclusive owner), so the D2h store races
        // nothing — cache traffic on every device proceeds while the
        // bytes drain to host RAM.
        let d2h_t0 = core.rec.now();
        core.inflight_transfers.fetch_add(1, Ordering::Relaxed);
        let cbuf: &[T] = match &c_loc {
            Operand::Arena(off) => &*core.arenas[dev].slice::<T>(*off, tile_elems),
            Operand::Host(v) => v,
        };
        write_back_masked(cmat, task, cbuf, t);
        let mut attempt = 0u32;
        while attempt < RETRY_MAX && core.faults.tick(dev, OpKind::D2h) {
            // transient write-back fault: redo the store (idempotent)
            attempt += 1;
            job.retried.fetch_add(1, Ordering::Relaxed);
            core.rec.record(dev, SpanKind::Retry, d2h_t0, attempt as f64, jid);
            write_back_masked(cmat, task, cbuf, t);
        }
        core.inflight_transfers.fetch_sub(1, Ordering::Relaxed);
        core.rec.record(dev, SpanKind::D2h, d2h_t0, tile_bytes as f64, jid);
    }
    if let Operand::Arena(_) = c_loc {
        // M → I: the host copy is the master again. The accumulator
        // block spent its whole life pending (never peer-servable); the
        // abort below points any same-key waiter — none can exist while
        // the dependency graph serializes writers before readers — back
        // at the freshly written host bytes.
        let mut caches = core.lock_caches();
        caches.writeback(dev, &ckey);
        caches.release(dev, &ckey);
        drop(caches);
        if let Some(ticket) = c_ticket.take() {
            ticket.latch.complete(false);
        }
    }
    let frac = if total == 0 { 1.0 } else { (end - start) as f64 / total as f64 };
    let flops = task.flops * frac;
    if end < total {
        job.resume[tid].store(end, Ordering::Relaxed);
        job.queue.enqueue(tid);
        core.notify_work();
        return Ok(TaskRun::Split { flops });
    }
    Ok(TaskRun::Done { flops })
}

/// Acquire an input tile for a step: normally a pinned arena block (L1
/// hit, peer copy, or host copy — the reader reference is pushed to
/// `releases` for the round's sync point), or a private host-side copy
/// if the arena cannot hold it even after bounded eviction retries
/// (the OOM degradation ladder — no pin, no cache entry, locality lost
/// for this step only, correctness untouched).
///
/// Narrow-lock protocol (the tentpole): the global cache lock is held
/// only to *reserve or hit* — every H2D read and arena→arena peer copy
/// runs with the lock dropped, behind the destination block's pending
/// latch. No copy in this function (or anywhere in the engine) moves
/// bytes while holding the cache lock.
fn acquire_input<T: Scalar>(
    dev: usize,
    core: &EngineCore,
    job: &JobState<'_, T>,
    tile: TileRef,
    releases: &mut Vec<TileKey>,
    keep_from: usize,
) -> Result<Operand<T>> {
    let t = job.cfg.t;
    let tile_elems = t * t;
    let tile_bytes = block_bytes::<T>(t);
    let mat = job.mats[tile.p].of(tile.mat);
    let key = job.mats[tile.p].key(tile);
    let jid = job.trace_id.load(Ordering::Relaxed);
    if core.faults.tick(dev, OpKind::Alloc) {
        core.lock_caches().force_alloc_failure(dev, 1);
    }
    let mut attempt = 0u32;
    loop {
        let ticket: FillTicket = loop {
            let mut caches = core.lock_caches();
            let mut acq = caches.acquire_async(dev, key, tile_bytes);
            if acq.is_none() && attempt == 0 {
                // sync & retry (see the C-block acquire above): release
                // readers of *prior* steps only — the current step's
                // other operand must stay pinned until its kernel runs.
                // Lookahead pins are flushed wholesale: prefetch must
                // never cost a degradation that prefetch-off avoids.
                for key in releases.drain(..keep_from) {
                    caches.release(dev, &key);
                }
                let flushed = core.prefetch_flush(&mut caches, dev);
                if flushed > 0 {
                    job.transfers.prefetch_wasted.fetch_add(flushed, Ordering::Relaxed);
                }
                acq = caches.acquire_async(dev, key, tile_bytes);
            }
            match acq {
                Some(AsyncAcquire::Ready(a)) => {
                    // Resident and valid. If the lookahead staged it,
                    // consume the ledger entry: its TTL pin drops here,
                    // while the pin this acquire just took rides to the
                    // round's sync point as usual.
                    if core.prefetch_consume(&mut caches, dev, &key) {
                        job.transfers.prefetch_hits.fetch_add(1, Ordering::Relaxed);
                    }
                    drop(caches);
                    job.transfers.l1_hits.fetch_add(1, Ordering::Relaxed);
                    releases.push(key);
                    return Ok(Operand::Arena(a.offset));
                }
                Some(AsyncAcquire::InFlight { offset, latch }) => {
                    // Another filler is moving these bytes right now:
                    // wait on the latch WITHOUT the global lock (the
                    // lookup already pinned the block for us).
                    drop(caches);
                    if latch.wait() {
                        job.transfers.l1_hits.fetch_add(1, Ordering::Relaxed);
                        releases.push(key);
                        return Ok(Operand::Arena(offset));
                    }
                    // The fill aborted (write-back raced it): drop our
                    // pin on the doomed block and start over.
                    core.lock_caches().release(dev, &key);
                }
                Some(AsyncAcquire::Fill(ticket)) => break ticket,
                None if attempt < RETRY_MAX => {
                    drop(caches);
                    attempt += 1;
                    job.retried.fetch_add(1, Ordering::Relaxed);
                    core.rec.record(dev, SpanKind::Retry, core.rec.now(), attempt as f64, jid);
                    std::thread::sleep(Duration::from_micros(50 * attempt as u64));
                }
                None => {
                    // Host-path fallback: a private copy, padded exactly
                    // as the cached path pads (zero edges, identity
                    // diagonal). Recorded as `HostFallback`, NOT `H2d`:
                    // these bytes never cross a DMA lane, so they must
                    // not inflate COMM or the Table V transfer volumes.
                    drop(caches);
                    job.degraded.fetch_add(1, Ordering::Relaxed);
                    core.flight.record(Some(dev), "degrade", jid, 0, 0.0);
                    let fb_t0 = core.rec.now();
                    let mut v = vec![T::zero(); tile_elems];
                    mat.read_tile(tile.ti, tile.tj, &mut v, t);
                    if tile.mat != MatId::C && tile.ti == tile.tj {
                        let (h, _) = mat.grid.tile_dims(tile.ti, tile.tj);
                        for j in h..t {
                            v[j * t + j] = T::one();
                        }
                    }
                    job.transfers.count_host(tile.mat);
                    core.rec.record(dev, SpanKind::HostFallback, fb_t0, tile_bytes as f64, jid);
                    return Ok(Operand::Host(v));
                }
            }
        };
        // Reserved: this worker owns the fill. Copy off-lock, then
        // latch ready under a brief re-lock.
        let offset = ticket.offset;
        fill_input_block(dev, core, job, tile, &ticket);
        let live = core.lock_caches().complete_fill(dev, &key, ticket.peer_src());
        if live {
            releases.push(key);
            return Ok(Operand::Arena(offset));
        }
        // A write-back invalidated the tile mid-fill: the bytes are
        // stale (host RAM is the master again). Drop the filler pin on
        // the doomed block and re-acquire from scratch.
        core.lock_caches().release(dev, &key);
    }
}

/// Move one input tile's bytes into a reserved (pending) arena block —
/// the off-lock half of the narrow-lock fill protocol. The pending
/// state makes this worker the block's exclusive writer, and a P2P
/// source is reader-pinned by the ticket, so neither copy direction
/// races cache traffic. Applies the bounded idempotent-redo transfer
/// fault ladder and the fill-time pads (zero edges; identity diagonal
/// for A/B diagonal tiles — exact for every consumer since zero
/// rows/cols elsewhere annihilate the pad 1s, and it must land BEFORE
/// the ready latch: once ready a block is immutable and may be
/// peer-read off-lock). Charges the job's transfer counters and the
/// true-kind span (H2d / P2p) — shared verbatim by demand fills and
/// the lookahead prefetcher.
fn fill_input_block<T: Scalar>(
    dev: usize,
    core: &EngineCore,
    job: &JobState<'_, T>,
    tile: TileRef,
    ticket: &FillTicket,
) {
    let t = job.cfg.t;
    let tile_elems = t * t;
    let tile_bytes = block_bytes::<T>(t);
    let mat = job.mats[tile.p].of(tile.mat);
    let jid = job.trace_id.load(Ordering::Relaxed);
    core.inflight_transfers.fetch_add(1, Ordering::Relaxed);
    match ticket.source {
        Source::L1 => unreachable!("a fill ticket never plans an L1 hit"),
        Source::Peer { src, src_offset } => {
            let p2p_t0 = core.rec.now();
            let dst = core.arenas[dev].slice::<T>(ticket.offset, tile_elems);
            let srcbuf: &[T] = &*core.arenas[src].slice::<T>(src_offset, tile_elems);
            dst.copy_from_slice(srcbuf);
            let mut xfer = 0u32;
            while xfer < RETRY_MAX && core.faults.tick(dev, OpKind::P2p) {
                // transient P2P fault: redo the copy (idempotent)
                xfer += 1;
                job.retried.fetch_add(1, Ordering::Relaxed);
                core.rec.record(dev, SpanKind::Retry, p2p_t0, xfer as f64, jid);
                dst.copy_from_slice(srcbuf);
            }
            job.transfers.peer_copies.fetch_add(1, Ordering::Relaxed);
            core.rec.record(dev, SpanKind::P2p, p2p_t0, tile_bytes as f64, jid);
        }
        Source::Host => {
            let h2d_t0 = core.rec.now();
            let dst = core.arenas[dev].slice::<T>(ticket.offset, tile_elems);
            let (h, w) = mat.grid.tile_dims(tile.ti, tile.tj);
            if h < t || w < t {
                // edge tiles: zero padding is semantically load-bearing
                // (both kernel backends compute on the full t×t block)
                for x in dst.iter_mut() {
                    *x = T::zero();
                }
            }
            mat.read_tile(tile.ti, tile.tj, dst, t);
            let mut xfer = 0u32;
            while xfer < RETRY_MAX && core.faults.tick(dev, OpKind::H2d) {
                // transient DMA fault: redo the read (idempotent)
                xfer += 1;
                job.retried.fetch_add(1, Ordering::Relaxed);
                core.rec.record(dev, SpanKind::Retry, h2d_t0, xfer as f64, jid);
                mat.read_tile(tile.ti, tile.tj, dst, t);
            }
            job.transfers.count_host(tile.mat);
            core.rec.record(dev, SpanKind::H2d, h2d_t0, tile_bytes as f64, jid);
        }
    }
    if tile.mat != MatId::C && tile.ti == tile.tj {
        let (h, _) = mat.grid.tile_dims(tile.ti, tile.tj);
        if h < t {
            let pack_t0 = core.rec.now();
            let dst = core.arenas[dev].slice::<T>(ticket.offset, tile_elems);
            for j in h..t {
                dst[j * t + j] = T::one();
            }
            core.rec.record(dev, SpanKind::Pack, pack_t0, 0.0, jid);
        }
    }
    core.inflight_transfers.fetch_sub(1, Ordering::Relaxed);
}

/// How many scheduler rounds a prefetched-but-unused tile keeps its
/// ledger pin before the consume-or-expire sweep reclaims it.
const PREFETCH_TTL: u32 = 3;

/// The lookahead prefetch pass: walk the upcoming tasks in this
/// device's scheduler window — the round's still-unexecuted bound
/// tasks, then the reservation-station backlog — and stage their
/// first-unexecuted-step operands ahead of demand. Fetches reuse the
/// narrow-lock fill protocol (L2/peer-first, true-kind spans), are
/// TTL-pinned through the consume-or-expire ledger, and the depth
/// adapts to free arena headroom: lookahead never evicts on behalf of
/// a guess and never enters the OOM ladder — pressure simply stops the
/// pass.
fn prefetch_pass<T: Scalar>(dev: usize, core: &EngineCore, job: &JobState<'_, T>, bound: &[usize]) {
    let depth = job.cfg.prefetch_depth();
    if depth == 0 {
        return;
    }
    let tile_bytes = block_bytes::<T>(job.cfg.t);
    let jid = job.trace_id.load(Ordering::Relaxed);
    // Candidate operands in expected execution order. bound[0] is
    // skipped: its demand fetch starts immediately after this pass, so
    // staging it buys no overlap.
    let mut cands: Vec<TileRef> = Vec::new();
    {
        let rs = job.stations[dev].lock().unwrap_or_else(|e| e.into_inner());
        let upcoming = bound.iter().copied().skip(1).chain(rs.iter().map(|s| s.task));
        'walk: for tid in upcoming {
            let task = &job.tasks[tid];
            let start = job.resume[tid].load(Ordering::Relaxed);
            let Some(step) = task.steps.get(start) else { continue };
            for tile in [step.a, step.b].into_iter().flatten() {
                cands.push(tile);
                if cands.len() >= depth {
                    break 'walk;
                }
            }
        }
    }
    if cands.is_empty() {
        return;
    }
    let pf_t0 = core.rec.now();
    let mut staged_bytes = 0.0f64;
    for tile in cands {
        let key = job.mats[tile.p].key(tile);
        let mut caches = core.lock_caches();
        // Adaptive depth: spend spare headroom only, keeping blocks
        // free for the demand path's working set (C + two inputs).
        if caches.arena_headroom(dev) < tile_bytes.saturating_mul(3) {
            break;
        }
        // Already resident (ready, mid-fill, or a previous ledger
        // entry): residency is the goal, skip without touching LRU
        // order or hit counters.
        if caches.locality_score(dev, &key) == 2 {
            continue;
        }
        match caches.acquire_async(dev, key, tile_bytes) {
            Some(AsyncAcquire::Fill(ticket)) => {
                drop(caches);
                fill_input_block(dev, core, job, tile, &ticket);
                let live = core.lock_caches().complete_fill(dev, &key, ticket.peer_src());
                if live {
                    core.prefetched[dev]
                        .lock()
                        .unwrap_or_else(|e| e.into_inner())
                        .insert(key, PREFETCH_TTL);
                    staged_bytes += tile_bytes as f64;
                } else {
                    // Write-back raced the staging copy: drop the pin,
                    // demand will refetch if the tile still matters.
                    core.lock_caches().release(dev, &key);
                }
            }
            // Raced to residency between probe and reserve (defensive —
            // the probe and acquire share one guard): drop the lookup's
            // pin and move on.
            Some(AsyncAcquire::Ready(_)) | Some(AsyncAcquire::InFlight { .. }) => {
                caches.release(dev, &key);
            }
            // Arena pressure: the lookahead lane stops; no retries, no
            // ladder, no wedging the demand path.
            None => break,
        }
    }
    if staged_bytes > 0.0 {
        // One envelope span per pass (ev() == None keeps it out of the
        // COMM analyses; the copies above recorded their true kinds).
        core.rec.record(dev, SpanKind::Prefetch, pf_t0, staged_bytes, jid);
    }
}

/// Write the accumulator back to the host C tile honouring the task's
/// write mask (triangle-stored diagonal tiles).
fn write_back_masked<T: Scalar>(cmat: &HostMat<T>, task: &Task, cbuf: &[T], t: usize) {
    use crate::task::WriteMask;
    let (h, w) = cmat.grid.tile_dims(task.ci, task.cj);
    match task.mask {
        WriteMask::Full => cmat.write_tile(task.ci, task.cj, cbuf, t),
        WriteMask::UpperTri | WriteMask::LowerTri => {
            // read-modify-write the triangle only
            let mut host = vec![T::zero(); h * w];
            cmat.read_tile(task.ci, task.cj, &mut host, h);
            for j in 0..w {
                for i in 0..h {
                    let keep_new = match task.mask {
                        WriteMask::UpperTri => i <= j,
                        WriteMask::LowerTri => i >= j,
                        WriteMask::Full => unreachable!(),
                    };
                    if keep_new {
                        host[j * h + i] = cbuf[j * t + i];
                    }
                }
            }
            cmat.write_tile(task.ci, task.cj, &host, h);
        }
    }
}

/// Execute one step's kernel on resolved operand slices (hostblas or
/// PJRT). The slices may live in the device arena (pinned blocks) or
/// in host scratch (the OOM fallback) — the kernels cannot tell.
fn exec_step<T: Scalar>(
    dev: usize,
    core: &EngineCore,
    job: &JobState<'_, T>,
    step: &Step,
    a: Option<&[T]>,
    b: Option<&[T]>,
    c: &mut [T],
) -> Result<()> {
    let t = job.cfg.t;
    let alpha = T::from_f64(step.alpha);
    let beta = T::from_f64(step.beta);
    let jid = job.trace_id.load(Ordering::Relaxed);
    let (m, n, k) = step.dims;
    // 2mnk is the GEMM-family flop count; for the triangular/symmetric
    // diagonal ops it over-counts by a small constant factor, which the
    // COMPT *time* split does not care about (the span length is real).
    let step_flops = 2.0 * m as f64 * n as f64 * k.max(1) as f64;
    let kern_t0 = core.rec.now();

    // Fault-injection probe: the kernel stream anchors kills and
    // wedges. Transient kernel failures retry (bounded) and then
    // escalate to a device loss — the caller's migration path takes
    // it from there.
    let mut attempt = 0u32;
    loop {
        match core.faults.tick_kernel(dev) {
            FaultAction::None => break,
            FaultAction::Wedge => {
                core.rec.record(dev, SpanKind::Fault, kern_t0, dev as f64, jid);
                core.flight.record(Some(dev), "fault", jid, 0, dev as f64);
                std::thread::sleep(WEDGE_STALL);
                break;
            }
            FaultAction::FailOp if attempt < RETRY_MAX => {
                attempt += 1;
                job.retried.fetch_add(1, Ordering::Relaxed);
                core.rec.record(dev, SpanKind::Retry, kern_t0, attempt as f64, jid);
                core.flight.record(Some(dev), "retry", jid, 0, attempt as f64);
            }
            FaultAction::Kill | FaultAction::FailOp => {
                core.kill_device(dev);
                return Err(Error::Degraded(format!("device {dev} lost (injected fault)")));
            }
        }
    }

    if job.cfg.backend == Backend::Pjrt {
        // One process-shared executor serves every concurrent tenant
        // (built lazily on the first PJRT step).
        let ex = core.tile_executor()?;
        let out = ex.run(&step.op.kernel_name(), t, a, b, c, alpha, beta);
        if out.is_ok() {
            core.rec.record(dev, SpanKind::Kernel, kern_t0, step_flops, jid);
        }
        return out;
    }

    // Every tile op dispatches to the packed kernel engine — the naive
    // `*_ref` oracles are test-only (EXPERIMENTS.md §Perf documents the
    // order-of-magnitude gap this targets). GEMM k-steps additionally
    // fan out across `worker_threads` when the tile is big enough
    // (paper §IV-C.2's "multithreaded BLAS kernel"); the flop-based
    // serial cutoff is per-job (`RunConfig::mt_cutoff`, stamped by the
    // adaptive dispatcher) falling back to the process-wide value, and
    // cells run on the persistent kernel pool, so per-thread pack
    // scratch is reused.
    let wt = job.cfg.worker_threads.max(1);
    let cutoff = job.cfg.mt_cutoff.unwrap_or_else(hostblas::mt_flop_cutoff);
    match step.op {
        TileOp::Gemm { ta, tb } => {
            hostblas::gemm_mt_with_cutoff(
                wt,
                cutoff,
                ta,
                tb,
                m,
                n,
                k,
                alpha,
                a.unwrap(),
                t,
                b.unwrap(),
                t,
                beta,
                c,
                t,
            );
        }
        TileOp::SyrkDiag { uplo, trans } => {
            hostblas::syrk_packed(uplo, trans, n, k, alpha, a.unwrap(), t, beta, c, t);
        }
        TileOp::Syr2kDiag { uplo, trans } => {
            hostblas::syr2k_packed(uplo, trans, n, k, alpha, a.unwrap(), t, b.unwrap(), t, beta, c, t);
        }
        TileOp::TrmmDiag { side, uplo, ta, diag } => {
            hostblas::trmm_packed(side, uplo, ta, diag, m, n, alpha, a.unwrap(), t, c, t);
        }
        TileOp::TrsmDiag { side, uplo, ta, diag } => {
            hostblas::trsm_packed(side, uplo, ta, diag, m, n, alpha, a.unwrap(), t, c, t);
        }
        TileOp::SymmDiag { side, uplo } => {
            hostblas::symm_packed(side, uplo, m, n, alpha, a.unwrap(), t, b.unwrap(), t, beta, c, t);
        }
        TileOp::Scal => {
            for j in 0..n {
                for i in 0..m {
                    c[j * t + i] *= beta;
                }
            }
        }
    }
    core.rec.record(dev, SpanKind::Kernel, kern_t0, step_flops, jid);
    Ok(())
}
