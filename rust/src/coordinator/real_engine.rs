//! The real (threaded) execution engine: Alg. 1 with actual bytes.
//!
//! One worker thread per virtual device; each device owns a memory arena
//! (its "VRAM") managed by the same FastHeap + ALRU + MESI-X machinery as
//! the simulator. Tiles are physically copied host↔arena (and arena↔arena
//! for L2/P2P hits); kernels execute through either the pure-Rust
//! hostblas kernels or the PJRT-loaded AOT artifacts (config `Backend`).
//!
//! Scheduling is the identical policy to the sim engine: demand-driven
//! pulls from the shared non-blocking queue, reservation stations with
//! Eq. 3 priorities, lowest-priority work stealing, and reader releases
//! deferred to the end-of-round sync point (the ALRU "approximation").
//!
//! On this testbed the PJRT CPU client executes kernels synchronously, so
//! "streams" provide issue-order structure rather than physical overlap —
//! the overlap claim is measured on the simulated substrate (DESIGN.md
//! §1); *correctness* of the full protocol stack is what runs here.

use super::config::{Backend, RunConfig};
use crate::api::Scalar;
use crate::cache::{Source, TileCacheSet};
use crate::error::{Error, Result};
use crate::hostblas;
use crate::mem::Offset;
use crate::queue::MsQueue;
use crate::runtime::TileExecutor;
use crate::sched::{task_priority, Station};
use crate::task::{Step, Task, TaskSet, TileOp, TileRef};
use crate::tile::{HostMat, MatId, TileKey};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// The three operands of a routine call. `b` may be absent (SYRK, TRMM,
/// TRSM read only A and C).
pub struct Mats<'m, T> {
    pub a: &'m HostMat<T>,
    pub b: Option<&'m HostMat<T>>,
    pub c: &'m HostMat<T>,
}

impl<'m, T: Scalar> Mats<'m, T> {
    fn of(&self, id: MatId) -> &HostMat<T> {
        match id {
            MatId::A => self.a,
            MatId::B => self.b.unwrap_or(self.a),
            MatId::C => self.c,
        }
    }

    fn key(&self, r: TileRef) -> TileKey {
        self.of(r.mat).tile_key(r.ti, r.tj)
    }
}

/// One device's arena: raw storage indexed by FastHeap offsets.
struct Arena<T> {
    buf: *mut T,
    len: usize,
}
unsafe impl<T: Send> Send for Arena<T> {}
unsafe impl<T: Sync> Sync for Arena<T> {}

impl<T: Scalar> Arena<T> {
    fn slice(&self, off: Offset, n: usize) -> &mut [T] {
        debug_assert!(off + n * std::mem::size_of::<T>() <= self.len * std::mem::size_of::<T>());
        debug_assert!(off % std::mem::size_of::<T>() == 0);
        // SAFETY: offsets come from the FastHeap, which never hands out
        // overlapping live blocks; cross-thread reads of a peer block
        // happen only under the cache lock while the block is pinned.
        unsafe {
            std::slice::from_raw_parts_mut(self.buf.add(off / std::mem::size_of::<T>()), n)
        }
    }
}

struct Shared<'m, T: Scalar> {
    cfg: RunConfig,
    tasks: Vec<Task>,
    deps: Vec<AtomicUsize>,
    remaining: AtomicUsize,
    queue: MsQueue<usize>,
    caches: Mutex<TileCacheSet>,
    stations: Vec<Mutex<Station>>,
    arenas: Vec<Arena<T>>,
    /// Operand sets, indexed by `Task::p` / `TileRef::p` (a single
    /// routine call is a batch of one).
    mats: Vec<Mats<'m, T>>,
    executor: Option<TileExecutor>,
    /// First kernel error (poisoning the run).
    failure: Mutex<Option<Error>>,
    /// Steals per device (observability).
    steals: Vec<AtomicUsize>,
}

/// Run a task set over `mats` with `n_devices` worker threads.
///
/// `arena_bytes` is each device's VRAM analogue; small arenas exercise
/// eviction (tests), large ones behave like the paper's 12 GB cards.
pub fn run_real<T: Scalar>(
    cfg: &RunConfig,
    ts: &TaskSet,
    mats: Mats<'_, T>,
    n_devices: usize,
    arena_bytes: usize,
) -> Result<RealReport> {
    run_real_batch(cfg, ts, vec![mats], n_devices, arena_bytes)
}

/// Run a *fused batch* task set: `problems[p]` holds the operands of
/// every task with `Task::p == p` (see `crate::batch`). The scheduling
/// machinery is identical to the single-problem path — one queue, one
/// set of reservation stations, one tile-cache set spanning all
/// problems — which is exactly what amortizes runtime setup across the
/// batch. Operands shared between problems (e.g. one weight matrix
/// multiplied by many activation sets) share cache entries for free,
/// because tiles are keyed by host address.
pub fn run_real_batch<'m, T: Scalar>(
    cfg: &RunConfig,
    ts: &TaskSet,
    problems: Vec<Mats<'m, T>>,
    n_devices: usize,
    arena_bytes: usize,
) -> Result<RealReport> {
    assert!(n_devices >= 1);
    debug_assert!(
        ts.tasks.iter().all(|t| t.p < problems.len()),
        "task problem index out of range"
    );
    let t = cfg.t;
    let tile_bytes = t * t * std::mem::size_of::<T>();
    assert!(
        arena_bytes >= 8 * tile_bytes,
        "arena must hold at least 8 tiles (working set of a round)"
    );
    let executor = match cfg.backend {
        Backend::Pjrt => Some(TileExecutor::new()?),
        Backend::Hostblas => None,
    };
    // All devices are peers in real mode (host RAM is one address space;
    // the "P2P copy" is an arena→arena memcpy, exercising the L2 path).
    let peers: Vec<Vec<usize>> =
        (0..n_devices).map(|d| (0..n_devices).filter(|&x| x != d).collect()).collect();
    let caches = TileCacheSet::new(&vec![arena_bytes; n_devices], peers, cfg.alloc);

    let mut arena_store: Vec<Vec<T>> = Vec::new();
    for _ in 0..n_devices {
        arena_store.push(vec![T::zero(); arena_bytes / std::mem::size_of::<T>()]);
    }
    let arenas: Vec<Arena<T>> = arena_store
        .iter_mut()
        .map(|v| Arena { buf: v.as_mut_ptr(), len: v.len() })
        .collect();

    let shared = Shared {
        cfg: cfg.clone(),
        tasks: ts.tasks.clone(),
        deps: ts.tasks.iter().map(|t| AtomicUsize::new(t.n_deps)).collect(),
        remaining: AtomicUsize::new(ts.tasks.len()),
        queue: MsQueue::new(),
        caches: Mutex::new(caches),
        stations: (0..n_devices).map(|_| Mutex::new(Station::new(cfg.rs_capacity))).collect(),
        arenas,
        mats: problems,
        executor,
        failure: Mutex::new(None),
        steals: (0..n_devices).map(|_| AtomicUsize::new(0)).collect(),
    };
    for &h in &ts.heads {
        shared.queue.enqueue(h);
    }

    let tasks_done: Vec<AtomicUsize> = (0..n_devices).map(|_| AtomicUsize::new(0)).collect();
    std::thread::scope(|scope| {
        for dev in 0..n_devices {
            let shared = &shared;
            let done = &tasks_done;
            scope.spawn(move || worker_loop(dev, shared, &done[dev]));
        }
    });

    if let Some(e) = shared.failure.lock().unwrap().take() {
        return Err(e);
    }
    let rem = shared.remaining.load(Ordering::SeqCst);
    if rem != 0 {
        return Err(Error::Internal(format!("real engine stalled with {rem} tasks")));
    }
    let caches = shared.caches.lock().unwrap();
    Ok(RealReport {
        tasks_per_device: tasks_done.iter().map(|a| a.load(Ordering::SeqCst)).collect(),
        cache_stats: (0..n_devices).map(|d| caches.stats(d)).collect(),
        steals: shared.steals.iter().map(|a| a.load(Ordering::SeqCst)).collect(),
    })
}

/// Observability output of a real run (numerics land in the C matrix).
#[derive(Debug)]
pub struct RealReport {
    pub tasks_per_device: Vec<usize>,
    pub cache_stats: Vec<(u64, u64, u64)>,
    pub steals: Vec<usize>,
}

// -------------------------------------------------------------------
// worker

fn worker_loop<T: Scalar>(dev: usize, sh: &Shared<'_, T>, tasks_done: &AtomicUsize) {
    let n_streams = sh.cfg.n_streams;
    loop {
        if sh.failure.lock().unwrap().is_some() {
            return;
        }
        // ---- refill the reservation station (lines 11–15)
        let mut bound: Vec<usize> = Vec::new();
        {
            let mut rs = sh.stations[dev].lock().unwrap();
            while !rs.is_full() {
                match sh.queue.dequeue() {
                    Some(t) => {
                        let caches = sh.caches.lock().unwrap();
                        let p = task_priority(&sh.tasks[t], dev, &caches, |r| sh.mats[r.p].key(r));
                        rs.insert(t, p);
                    }
                    None => break,
                }
            }
            if rs.is_empty() && sh.cfg.work_stealing {
                drop(rs);
                // steal from the fullest victim
                let victim = (0..sh.stations.len())
                    .filter(|&v| v != dev)
                    .max_by_key(|&v| sh.stations[v].lock().unwrap().len());
                if let Some(v) = victim {
                    if let Some(slot) = sh.stations[v].lock().unwrap().steal_worst() {
                        sh.stations[dev].lock().unwrap().insert(slot.task, slot.priority);
                        sh.steals[dev].fetch_add(1, Ordering::Relaxed);
                    }
                }
                rs = sh.stations[dev].lock().unwrap();
            }
            // refresh priorities after arrivals, then bind top tasks
            {
                let caches = sh.caches.lock().unwrap();
                rs.refresh(|t| task_priority(&sh.tasks[t], dev, &caches, |r| sh.mats[r.p].key(r)));
            }
            for _ in 0..n_streams {
                match rs.take_best() {
                    Some(slot) => bound.push(slot.task),
                    None => break,
                }
            }
        }

        if bound.is_empty() {
            if sh.remaining.load(Ordering::SeqCst) == 0 {
                return;
            }
            std::thread::yield_now();
            continue;
        }

        // ---- the round: solve the bound tasks (lines 18–25)
        let mut releases: Vec<TileKey> = Vec::new();
        for tid in bound {
            if let Err(e) = run_task(dev, sh, tid, &mut releases) {
                *sh.failure.lock().unwrap() = Some(e);
                return;
            }
            tasks_done.fetch_add(1, Ordering::Relaxed);
            sh.remaining.fetch_sub(1, Ordering::SeqCst);
            if let Some(succ) = sh.tasks[tid].successor {
                if sh.deps[succ].fetch_sub(1, Ordering::SeqCst) == 1 {
                    sh.queue.enqueue(succ);
                }
            }
        }
        // ---- sync point (line 16/17): release the round's readers
        let mut caches = sh.caches.lock().unwrap();
        for key in releases {
            caches.release(dev, &key);
        }
    }
}

/// Solve one task: acquire C, stream the k-steps, write C back.
fn run_task<T: Scalar>(
    dev: usize,
    sh: &Shared<'_, T>,
    tid: usize,
    releases: &mut Vec<TileKey>,
) -> Result<()> {
    let t = sh.cfg.t;
    let tile_elems = t * t;
    let tile_bytes = tile_elems * std::mem::size_of::<T>();
    let task = &sh.tasks[tid];
    let cmat = sh.mats[task.p].of(MatId::C);
    let ckey = cmat.tile_key(task.ci, task.cj);

    // -- C accumulator block
    let c_off = {
        let mut caches = sh.caches.lock().unwrap();
        let acq = {
            let mut acq = caches.acquire_output(dev, ckey, tile_bytes);
            if acq.is_none() {
                // Cache pressure: this is the paper's "sync & retry" —
                // kernels already issued this round are complete (real
                // mode is synchronous), so the round's readers can be
                // released early and the acquire retried.
                for key in releases.drain(..) {
                    caches.release(dev, &key);
                }
                acq = caches.acquire_output(dev, ckey, tile_bytes);
            }
            match acq {
                Some(a) => a,
                None => {
                    return Err(Error::OutOfDeviceMemory {
                        device: dev,
                        need: tile_bytes,
                        capacity: caches.resident(dev) * tile_bytes,
                    });
                }
            }
        };
        let cbuf = sh.arenas[dev].slice(acq.offset, tile_elems);
        // zero-pad only edge tiles (interior tiles are fully overwritten
        // by read_tile / the kernels — the memset was 15% of small-tile
        // acquire cost, EXPERIMENTS.md §Perf)
        let (h, w) = cmat.grid.tile_dims(task.ci, task.cj);
        if h < t || w < t || !task.reads_c {
            for x in cbuf.iter_mut() {
                *x = T::zero();
            }
        }
        if task.reads_c {
            cmat.read_tile(task.ci, task.cj, cbuf, t);
        }
        acq.offset
    };

    // -- k-steps
    for step in &task.steps {
        let mut a_off: Option<Offset> = None;
        let mut b_off: Option<Offset> = None;
        // Readers acquired for THIS step must survive any pressure
        // flush until its kernel has run.
        let keep_from = releases.len();
        for (slot, tile) in [(0, step.a), (1, step.b)] {
            let Some(tile) = tile else { continue };
            let off = acquire_input(dev, sh, tile, releases, keep_from)?;
            if slot == 0 {
                a_off = Some(off);
            } else {
                b_off = Some(off);
            }
        }
        exec_step(dev, sh, step, a_off, b_off, c_off)?;
    }

    // -- write-back (M → I): store the masked extent to host RAM
    {
        let caches = sh.caches.lock().unwrap();
        let cbuf = sh.arenas[dev].slice(c_off, tile_elems);
        write_back_masked(cmat, task, cbuf, t);
        drop(caches);
    }
    let mut caches = sh.caches.lock().unwrap();
    caches.writeback(dev, &ckey);
    caches.release(dev, &ckey);
    Ok(())
}

/// Acquire an input tile into the device arena (L1 hit, peer copy, or
/// host copy), returning its offset. The reader reference is pushed to
/// `releases` for the round's sync point.
fn acquire_input<T: Scalar>(
    dev: usize,
    sh: &Shared<'_, T>,
    tile: TileRef,
    releases: &mut Vec<TileKey>,
    keep_from: usize,
) -> Result<Offset> {
    let t = sh.cfg.t;
    let tile_elems = t * t;
    let tile_bytes = tile_elems * std::mem::size_of::<T>();
    let mat = sh.mats[tile.p].of(tile.mat);
    let key = sh.mats[tile.p].key(tile);
    let mut caches = sh.caches.lock().unwrap();
    let acq = {
        let mut acq = caches.acquire(dev, key, tile_bytes);
        if acq.is_none() {
            // sync & retry (see the C-block acquire above): release
            // readers of *prior* steps only — the current step's other
            // operand must stay pinned until its kernel runs.
            for key in releases.drain(..keep_from) {
                caches.release(dev, &key);
            }
            acq = caches.acquire(dev, key, tile_bytes);
        }
        match acq {
            Some(a) => a,
            None => {
                return Err(Error::OutOfDeviceMemory {
                    device: dev,
                    need: tile_bytes,
                    capacity: caches.resident(dev) * tile_bytes,
                })
            }
        }
    };
    releases.push(key);
    match acq.source {
        Source::L1 => {}
        Source::Peer { src, src_offset } => {
            // arena→arena copy under the cache lock (the source block is
            // pinned by the directory entry while we hold the lock).
            let dst = sh.arenas[dev].slice(acq.offset, tile_elems);
            let srcbuf = sh.arenas[src].slice(src_offset, tile_elems);
            dst.copy_from_slice(srcbuf);
        }
        Source::Host => {
            let dst = sh.arenas[dev].slice(acq.offset, tile_elems);
            let (h, w) = mat.grid.tile_dims(tile.ti, tile.tj);
            if h < t || w < t {
                // edge tiles: zero padding is semantically load-bearing
                // (both kernel backends compute on the full t×t block)
                for x in dst.iter_mut() {
                    *x = T::zero();
                }
            }
            mat.read_tile(tile.ti, tile.tj, dst, t);
            // Identity-pad diagonal A tiles: exact for every consumer
            // (zero rows/cols elsewhere annihilate the pad 1s) and
            // required by the TRSM diagonal solve.
            if tile.mat != MatId::C && tile.ti == tile.tj {
                let (h, _) = mat.grid.tile_dims(tile.ti, tile.tj);
                for j in h..t {
                    dst[j * t + j] = T::one();
                }
            }
        }
    }
    Ok(acq.offset)
}

/// Write the accumulator back to the host C tile honouring the task's
/// write mask (triangle-stored diagonal tiles).
fn write_back_masked<T: Scalar>(cmat: &HostMat<T>, task: &Task, cbuf: &[T], t: usize) {
    use crate::task::WriteMask;
    let (h, w) = cmat.grid.tile_dims(task.ci, task.cj);
    match task.mask {
        WriteMask::Full => cmat.write_tile(task.ci, task.cj, cbuf, t),
        WriteMask::UpperTri | WriteMask::LowerTri => {
            // read-modify-write the triangle only
            let mut host = vec![T::zero(); h * w];
            cmat.read_tile(task.ci, task.cj, &mut host, h);
            for j in 0..w {
                for i in 0..h {
                    let keep_new = match task.mask {
                        WriteMask::UpperTri => i <= j,
                        WriteMask::LowerTri => i >= j,
                        WriteMask::Full => unreachable!(),
                    };
                    if keep_new {
                        host[j * h + i] = cbuf[j * t + i];
                    }
                }
            }
            cmat.write_tile(task.ci, task.cj, &host, h);
        }
    }
}

/// Execute one step's kernel on arena tiles (hostblas or PJRT).
fn exec_step<T: Scalar>(
    dev: usize,
    sh: &Shared<'_, T>,
    step: &Step,
    a_off: Option<Offset>,
    b_off: Option<Offset>,
    c_off: Offset,
) -> Result<()> {
    let t = sh.cfg.t;
    let tile_elems = t * t;
    let alpha = T::from_f64(step.alpha);
    let beta = T::from_f64(step.beta);
    let c = sh.arenas[dev].slice(c_off, tile_elems);

    if let Some(ex) = &sh.executor {
        // SAFETY: a/b blocks are pinned for the round; kernels never
        // write them. Slices alias no live &mut.
        let a = a_off.map(|o| &*sh.arenas[dev].slice(o, tile_elems));
        let b = b_off.map(|o| &*sh.arenas[dev].slice(o, tile_elems));
        return ex.run(&step.op.kernel_name(), t, a, b, c, alpha, beta);
    }

    // Every tile op dispatches to the packed kernel engine — the naive
    // `*_ref` oracles are test-only (EXPERIMENTS.md §Perf documents the
    // order-of-magnitude gap this targets). GEMM k-steps additionally
    // fan out across `worker_threads` when the tile is big enough
    // (paper §IV-C.2's "multithreaded BLAS kernel"); `gemm_mt` applies
    // its flop-based serial cutoff internally.
    let (m, n, k) = step.dims;
    let a = a_off.map(|o| &*sh.arenas[dev].slice(o, tile_elems));
    let b = b_off.map(|o| &*sh.arenas[dev].slice(o, tile_elems));
    let wt = sh.cfg.worker_threads.max(1);
    match step.op {
        TileOp::Gemm { ta, tb } => {
            hostblas::gemm_mt(wt, ta, tb, m, n, k, alpha, a.unwrap(), t, b.unwrap(), t, beta, c, t);
        }
        TileOp::SyrkDiag { uplo, trans } => {
            hostblas::syrk_packed(uplo, trans, n, k, alpha, a.unwrap(), t, beta, c, t);
        }
        TileOp::Syr2kDiag { uplo, trans } => {
            hostblas::syr2k_packed(uplo, trans, n, k, alpha, a.unwrap(), t, b.unwrap(), t, beta, c, t);
        }
        TileOp::TrmmDiag { side, uplo, ta, diag } => {
            hostblas::trmm_packed(side, uplo, ta, diag, m, n, alpha, a.unwrap(), t, c, t);
        }
        TileOp::TrsmDiag { side, uplo, ta, diag } => {
            hostblas::trsm_packed(side, uplo, ta, diag, m, n, alpha, a.unwrap(), t, c, t);
        }
        TileOp::SymmDiag { side, uplo } => {
            hostblas::symm_packed(side, uplo, m, n, alpha, a.unwrap(), t, b.unwrap(), t, beta, c, t);
        }
        TileOp::Scal => {
            for j in 0..n {
                for i in 0..m {
                    c[j * t + i] *= beta;
                }
            }
        }
    }
    Ok(())
}
