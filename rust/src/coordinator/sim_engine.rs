//! The BLASX scheduling runtime on the simulated substrate (Alg. 1).
//!
//! Each simulated GPU runs the per-device loop of Alg. 1 lines 8–25 as a
//! state machine advanced by sync-point events:
//!
//! 1. **wake at a sync point** (line 16 StreamsSynch): apply deferred
//!    reader releases (line 17 ReaderUpdate), complete finished tasks
//!    (C write-back = M→I), enqueue unlocked chain successors;
//! 2. **refill**: top up the reservation station from the global
//!    non-blocking queue, or steal from the fullest victim RS when both
//!    the queue and the own RS are empty (work sharing + stealing);
//! 3. **issue**: bind the top `n_streams` prioritized tasks (Eq. 3) to
//!    streams and issue every k-step — tile acquisitions through the
//!    two-level cache (transfers booked on DMA lanes only on miss),
//!    kernels booked on the device's serial kernel lane;
//! 4. schedule the next wake at the round's completion time.
//!
//! The demand-driven balance emerges exactly as in the paper: a fast
//! device's round ends sooner, so it returns to the queue sooner and
//! consumes more tasks. Everything is deterministic.
//!
//! The CPU computation thread (§IV-C.2) is a device-like worker that
//! consumes *whole tasks* from the queue at the host-BLAS rate, with no
//! transfers (it operates in host RAM).

use super::config::RunConfig;
use super::keymap::KeyMap;
use crate::api::Dtype;
use crate::cache::{CacheStats, Source, TileCacheSet};
use crate::mem::AllocStrategy;
use crate::sched::{task_priority, Station};
use crate::sim::{Dir, EventQueue, Lane, Machine, SimTime, Topology};
use crate::task::{Task, TaskSet};
use crate::tile::TileKey;
use crate::trace::{EvKind, Trace};
use std::collections::VecDeque;

/// Result of one simulated run.
#[derive(Debug)]
pub struct SimReport {
    /// Virtual seconds from first issue to last write-back.
    pub makespan: SimTime,
    /// Full event trace (Fig. 1 / Fig. 8 / Table V raw material).
    pub trace: Trace,
    /// Tasks executed per worker (devices then CPU) — load-balance data.
    pub tasks_per_worker: Vec<usize>,
    /// Total allocator cost paid (Fig. 5 signal; ~0 under FastHeap).
    pub alloc_cost: f64,
    /// L1 hits, misses, evictions per device.
    pub cache_stats: Vec<CacheStats>,
    /// Steals performed per device.
    pub steals: Vec<u64>,
    /// Measured DMA throughputs (hd, p2p) bytes/s — Table IV.
    pub dma_throughput: (f64, f64),
    /// False when the policy cannot run the problem at all (e.g. the
    /// PaRSEC-like baseline is in-core only and the matrices exceed
    /// VRAM) — rendered as "N/A" by the harness, like the paper's
    /// partial benchmarks.
    pub feasible: bool,
}

impl SimReport {
    /// Marker report for configurations a policy cannot execute.
    pub fn infeasible() -> SimReport {
        SimReport {
            makespan: f64::NAN,
            trace: Trace::new(),
            tasks_per_worker: Vec::new(),
            alloc_cost: 0.0,
            cache_stats: Vec::new(),
            steals: Vec::new(),
            dma_throughput: (0.0, 0.0),
            feasible: false,
        }
    }
}

impl SimReport {
    /// Achieved GFLOP/s given the task set's flop count.
    pub fn gflops(&self, total_flops: f64) -> f64 {
        if !self.feasible || self.makespan <= 0.0 {
            return 0.0;
        }
        total_flops / self.makespan / 1e9
    }
}

impl std::fmt::Display for SimReport {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        if !self.feasible {
            return write!(f, "infeasible");
        }
        write!(
            f,
            "makespan {:.4}s, tasks/worker {:?}, steals {:?}",
            self.makespan, self.tasks_per_worker, self.steals
        )
    }
}

/// An in-flight task bound to a stream, advancing one k-step per round
/// (Alg. 1 line 16 syncs *inside* the while loop — rounds are k-steps,
/// not whole tasks, which keeps slow devices from hoarding work the
/// fast ones could steal).
#[derive(Clone, Copy)]
struct Active {
    task: usize,
    stream: usize,
    /// Next k-step to issue.
    next_step: usize,
}

/// Per-device worker state.
struct Worker {
    rs: Station,
    active: Vec<Active>,
    /// Per-stream ready time.
    stream_free: Vec<SimTime>,
    /// Kernel engine (kernels serialize on the SMs).
    kernel_lane: Lane,
    /// Reader releases to apply at the next sync.
    deferred_releases: Vec<TileKey>,
    /// Write-backs (task id, completion booked) to finalize at sync.
    finished: Vec<usize>,
    /// Is a wake event scheduled?
    scheduled: bool,
    /// Done issuing (queue drained and nothing active).
    idle: bool,
    tasks_done: usize,
    steals: u64,
}

/// The simulated BLASX runtime.
pub struct SimEngine<'a> {
    cfg: &'a RunConfig,
    machine: &'a Machine,
    dtype: Dtype,
    keymap: KeyMap,
    tasks: Vec<Task>,
    /// Remaining predecessor count per task (chains).
    deps: Vec<usize>,
    queue: VecDeque<usize>,
    caches: TileCacheSet,
    topo: Topology,
    workers: Vec<Worker>,
    /// Devices the config's fault plan kills — modeled as absent from
    /// t=0 (the discrete-event engine has no mid-run recovery; the
    /// real engine is where kills fire live). Never kicked, never
    /// woken: the survivors absorb the whole workload, which is the
    /// degraded-machine throughput the simulator should predict.
    dead: Vec<bool>,
    /// CPU worker (consumes whole tasks) if enabled.
    cpu: Option<CpuWorker>,
    events: EventQueue<WakeEvent>,
    trace: Trace,
    alloc_cost: f64,
    remaining: usize,
}

struct CpuWorker {
    busy_until: SimTime,
    scheduled: bool,
    tasks_done: usize,
    current: Option<usize>,
}

#[derive(Clone, Copy, Debug)]
enum WakeEvent {
    Device(usize),
    Cpu,
}

impl<'a> SimEngine<'a> {
    pub fn new(
        cfg: &'a RunConfig,
        machine: &'a Machine,
        ts: &TaskSet,
        keymap: KeyMap,
        dtype: Dtype,
    ) -> SimEngine<'a> {
        let n = machine.devices.len();
        let capacities: Vec<usize> = machine
            .devices
            .iter()
            .map(|d| cfg.vram_override.unwrap_or(d.vram))
            .collect();
        let topo = Topology::new(machine.topology.clone());
        let peers: Vec<Vec<usize>> = (0..n).map(|d| topo.peers(d)).collect();
        let caches = TileCacheSet::new(&capacities, peers, cfg.alloc);
        let workers = (0..n)
            .map(|d| Worker {
                rs: Station::new(cfg.rs_capacity),
                active: Vec::new(),
                stream_free: vec![0.0; machine.devices[d].n_streams.min(cfg.n_streams)],
                kernel_lane: Lane::new(),
                deferred_releases: Vec::new(),
                finished: Vec::new(),
                scheduled: false,
                idle: false,
                tasks_done: 0,
                steals: 0,
            })
            .collect();
        let deps: Vec<usize> = ts.tasks.iter().map(|t| t.n_deps).collect();
        let queue: VecDeque<usize> = ts.heads.iter().copied().collect();
        let mut dead: Vec<bool> = (0..n)
            .map(|d| cfg.fault_plan.as_ref().is_some_and(|p| p.kills_device(d)))
            .collect();
        if dead.iter().all(|&x| x) {
            // A plan that kills every device would stall the sim; model
            // it as no machine change (the real engine fails the jobs).
            dead.iter_mut().for_each(|x| *x = false);
        }
        let cpu = if cfg.use_cpu && machine.cpu.is_some() {
            Some(CpuWorker { busy_until: 0.0, scheduled: false, tasks_done: 0, current: None })
        } else {
            None
        };
        SimEngine {
            cfg,
            machine,
            dtype,
            keymap,
            remaining: ts.tasks.len(),
            tasks: ts.tasks.clone(),
            deps,
            queue,
            caches,
            topo,
            workers,
            dead,
            cpu,
            events: EventQueue::new(),
            trace: Trace::new(),
            alloc_cost: 0.0,
        }
    }

    /// Run to completion, returning the report.
    pub fn run(mut self) -> SimReport {
        // Kick every (surviving) worker at t=0.
        for d in 0..self.workers.len() {
            if self.dead[d] {
                continue;
            }
            self.workers[d].scheduled = true;
            self.events.schedule(0.0, WakeEvent::Device(d));
        }
        if self.cpu.is_some() {
            self.cpu.as_mut().unwrap().scheduled = true;
            self.events.schedule(0.0, WakeEvent::Cpu);
        }
        let mut guard = 0u64;
        let guard_max = 1_000_000_000;
        while let Some((now, ev)) = self.events.pop() {
            guard += 1;
            assert!(guard < guard_max, "simulation runaway");
            match ev {
                WakeEvent::Device(d) => self.device_round(d, now),
                WakeEvent::Cpu => self.cpu_round(now),
            }
            if self.remaining == 0 {
                break;
            }
        }
        assert_eq!(self.remaining, 0, "simulation stalled with {} tasks left", self.remaining);
        let mut trace = self.trace;
        trace.makespan = trace
            .events
            .iter()
            .map(|e| e.end)
            .fold(0.0, f64::max);
        let mut tasks_per_worker: Vec<usize> =
            self.workers.iter().map(|w| w.tasks_done).collect();
        if let Some(cpu) = &self.cpu {
            tasks_per_worker.push(cpu.tasks_done);
        }
        SimReport {
            makespan: trace.makespan,
            tasks_per_worker,
            alloc_cost: self.alloc_cost,
            cache_stats: (0..self.workers.len()).map(|d| self.caches.stats(d)).collect(),
            steals: self.workers.iter().map(|w| w.steals).collect(),
            dma_throughput: self.topo.measured_throughput(),
            trace,
            feasible: true,
        }
    }

    // ---------------------------------------------------------------
    // device worker round (Alg. 1 lines 10–25)

    fn device_round(&mut self, d: usize, now: SimTime) {
        if self.dead[d] {
            return;
        }
        self.workers[d].scheduled = false;
        // Progress accounting for the no-spin drain below: a round that
        // entered with pending releases/write-backs can always change
        // cache state, so it must re-schedule.
        let had_pending = !self.workers[d].deferred_releases.is_empty()
            || !self.workers[d].finished.is_empty();

        // -- line 17 ReaderUpdate: releases deferred from the last round
        let releases = std::mem::take(&mut self.workers[d].deferred_releases);
        for key in releases {
            self.caches.release(d, &key);
        }
        // -- completed tasks: M→I write-back bookkeeping + chain unlock
        let finished = std::mem::take(&mut self.workers[d].finished);
        let did_writeback = !finished.is_empty();
        for tid in finished {
            let key = self.keymap.key(self.tasks[tid].c_ref());
            self.caches.writeback(d, &key);
            self.caches.release(d, &key);
            self.workers[d].tasks_done += 1;
            self.remaining -= 1;
            if let Some(succ) = self.tasks[tid].successor {
                self.deps[succ] -= 1;
                if self.deps[succ] == 0 {
                    self.queue.push_back(succ);
                    self.wake_idlers(now);
                }
            }
        }

        if did_writeback {
            // The write-backs invalidated every peer's cached copy of
            // those C tiles — memory may just have been freed on a
            // device that parked under cache pressure. Give it a wake.
            self.wake_idlers(now);
        }

        // -- lines 11–15: refill the RS
        self.refill_rs(d);

        // Streams whose issued work is done — the only ones this wake
        // touches. Syncing per stream (not device-wide) is what lets a
        // finished stream start its next task's transfers while sibling
        // streams still compute — the paper's "seamless occupancy".
        let eps = 1e-12;
        let idle_stream = |w: &Worker, s: usize| w.stream_free[s] <= now + eps;

        // -- bind top-priority tasks to free streams; the C accumulator
        //    block is acquired at bind time and held until write-back.
        let n_streams = self.workers[d].stream_free.len();
        let mut bound_any = false;
        while self.workers[d].active.len() < n_streams {
            let Some(slot) = self.workers[d].rs.take_best() else { break };
            let t = &self.tasks[slot.task];
            let ckey = self.keymap.key(t.c_ref());
            match self.caches.acquire_output(d, ckey, self.keymap.tile_bytes()) {
                Some(acq) => {
                    self.alloc_cost += acq.alloc_cost;
                    if acq.alloc_cost > 0.0 {
                        // cudaMalloc/cudaFree stall the device context
                        self.workers[d].kernel_lane.book(now, acq.alloc_cost);
                    }
                    let used: Vec<usize> =
                        self.workers[d].active.iter().map(|a| a.stream).collect();
                    let stream = (0..n_streams).find(|s| !used.contains(s)).unwrap();
                    if t.reads_c {
                        let bytes = self.keymap.transfer_bytes(t.c_ref());
                        let ready = self.workers[d].stream_free[stream].max(now);
                        let done = self.topo.book_hd(d, Dir::H2D, bytes, ready);
                        self.trace.record(d, stream, EvKind::H2d, ready, done, bytes as f64);
                        self.workers[d].stream_free[stream] = done;
                    }
                    self.workers[d].active.push(Active { task: slot.task, stream, next_step: 0 });
                    bound_any = true;
                }
                None => {
                    // cache pressure: task returns to the RS, retried
                    // after the next sync releases readers
                    self.workers[d].rs.insert(slot.task, slot.priority);
                    break;
                }
            }
        }

        if bound_any {
            // acquire_output write-invalidated peer copies of the bound
            // C tiles: parked peers may have memory again.
            self.wake_idlers(now);
        }

        if self.workers[d].active.is_empty() {
            // nothing to do: dormant until new tasks appear
            self.workers[d].idle = true;
            return;
        }
        self.workers[d].idle = false;

        // -- lines 18–25: issue a batch of k-steps per bound task whose
        //    stream has drained, k-major interleaved across streams so
        //    one stream's transfer overlaps another's kernel; each
        //    stream's own sync point closes its batch.
        let _ = idle_stream;
        let mut actives = std::mem::take(&mut self.workers[d].active);
        let mut still_active: Vec<Active> = Vec::new();
        let mut issued_any = false;
        for _k in 0..self.cfg.k_chunk.max(1) {
            for a in actives.iter_mut() {
                let Some(&step) = self.tasks[a.task].steps.get(a.next_step) else { continue };
                let mut ready = self.workers[d].stream_free[a.stream].max(now);
                let mut ok = true;
                for tile in step.inputs() {
                    let key = self.keymap.key(tile);
                    match self.caches.acquire(d, key, self.keymap.tile_bytes()) {
                        Some(acq) => {
                            self.alloc_cost += acq.alloc_cost;
                            if acq.alloc_cost > 0.0 {
                                let (_, e) = self.workers[d].kernel_lane.book(ready, acq.alloc_cost);
                                ready = e;
                            }
                            let bytes = self.keymap.transfer_bytes(tile);
                            match acq.source {
                                Source::L1 => {}
                                Source::Peer { src, .. } => {
                                    let done = self.topo.book_p2p(src, d, bytes, ready);
                                    self.trace.record(d, a.stream, EvKind::P2p, ready, done, bytes as f64);
                                    ready = done;
                                }
                                Source::Host => {
                                    let done = self.topo.book_hd(d, Dir::H2D, bytes, ready);
                                    self.trace.record(d, a.stream, EvKind::H2d, ready, done, bytes as f64);
                                    ready = done;
                                }
                            }
                            self.workers[d].deferred_releases.push(key);
                        }
                        None => {
                            // out of cache even after eviction: stall
                            // this task; the sync releases readers
                            ok = false;
                            break;
                        }
                    }
                }
                if ok {
                    let dev = &self.machine.devices[d];
                    let secs = dev.kernel_secs(step.flops(), self.cfg.t, self.dtype)
                        * super::config::jitter_factor(self.cfg.jitter, d, a.task);
                    let (ks, ke) = self.workers[d].kernel_lane.book(ready, secs);
                    self.trace.record(d, a.stream, EvKind::Kernel, ks, ke, step.flops());
                    self.workers[d].stream_free[a.stream] = ke;
                    a.next_step += 1;
                    issued_any = true;
                }
            }
        }
        for a in actives {
            if a.next_step == self.tasks[a.task].steps.len() {
                // -- task complete: C write-back after its last kernel
                let t = &self.tasks[a.task];
                let bytes = self.keymap.transfer_bytes(t.c_ref());
                let ready = self.workers[d].stream_free[a.stream];
                let done = self.topo.book_hd(d, Dir::D2H, bytes, ready);
                self.trace.record(d, a.stream, EvKind::D2h, ready, done, bytes as f64);
                self.workers[d].stream_free[a.stream] = done;
                self.workers[d].finished.push(a.task);
            } else {
                // -- prefetch the next chunk's first tiles behind this
                //    stream's last kernel (CUDA-style double buffering):
                //    the transfers ride out the sync wait, so the next
                //    round's kernels start on warm tiles. Eviction before
                //    use is possible under pressure — the next acquire
                //    simply misses again.
                if let Some(step) = self.tasks[a.task].steps.get(a.next_step) {
                    let ready = self.workers[d].stream_free[a.stream];
                    let mut done_at = ready;
                    for tile in step.inputs() {
                        let key = self.keymap.key(tile);
                        if let Some(acq) = self.caches.acquire(d, key, self.keymap.tile_bytes()) {
                            self.alloc_cost += acq.alloc_cost;
                            if acq.alloc_cost > 0.0 {
                                let (_, e) = self.workers[d].kernel_lane.book(done_at, acq.alloc_cost);
                                done_at = e;
                            }
                            let bytes = self.keymap.transfer_bytes(tile);
                            match acq.source {
                                Source::L1 => {}
                                Source::Peer { src, .. } => {
                                    let done = self.topo.book_p2p(src, d, bytes, done_at);
                                    self.trace.record(d, a.stream, EvKind::P2p, done_at, done, bytes as f64);
                                    done_at = done;
                                }
                                Source::Host => {
                                    let done = self.topo.book_hd(d, Dir::H2D, bytes, done_at);
                                    self.trace.record(d, a.stream, EvKind::H2d, done_at, done, bytes as f64);
                                    done_at = done;
                                }
                            }
                            self.workers[d].deferred_releases.push(key);
                        }
                    }
                    // the stream is busy until its prefetches land
                    self.workers[d].stream_free[a.stream] = done_at;
                }
                still_active.push(a);
            }
        }
        self.workers[d].active = still_active;

        // -- drain guard: a round that bound nothing, issued nothing
        //    and holds nothing to release/write back would repeat
        //    itself verbatim at now+ε — the old code re-scheduled
        //    anyway, busy-spinning the event queue under permanent
        //    cache pressure until the runaway guard tripped. Park
        //    instead: `wake_idlers` fires on every event that can
        //    change this device's options (new ready tasks, peer
        //    write-backs freeing invalidated copies). A genuinely
        //    wedged run now drains the event queue and surfaces as the
        //    crisp "simulation stalled" diagnostic.
        let progressed = had_pending
            || bound_any
            || issued_any
            || !self.workers[d].deferred_releases.is_empty()
            || !self.workers[d].finished.is_empty();
        if !progressed {
            self.workers[d].idle = true;
            return;
        }

        // -- lookahead prefetch (real-engine parity): with a configured
        //    depth, stage input tiles of upcoming RS tasks behind this
        //    round's work so their transfers ride out the sync wait,
        //    exactly like the per-stream double buffering above but
        //    across the scheduler window. Runs only in rounds that
        //    otherwise progressed (a parked round staging L1 hits would
        //    re-wake itself forever), stops at the first admission
        //    failure (never wedges the cache), and at depth 0 — the
        //    default — leaves the historical schedule byte-identical.
        let depth = self.cfg.prefetch_depth();
        if depth > 0 {
            let backlog: Vec<usize> = self.workers[d].rs.iter().map(|s| s.task).collect();
            let stream = 0;
            let mut done_at = self.workers[d].stream_free[stream];
            let mut staged = 0usize;
            'lookahead: for tid in backlog {
                let Some(step) = self.tasks[tid].steps.first() else { continue };
                for tile in step.inputs() {
                    if staged >= depth {
                        break 'lookahead;
                    }
                    let key = self.keymap.key(tile);
                    if self.caches.locality_score(d, &key) == 2 {
                        continue; // already resident: nothing to stage
                    }
                    let Some(acq) = self.caches.acquire(d, key, self.keymap.tile_bytes()) else {
                        break 'lookahead; // cache pressure: stop here
                    };
                    self.alloc_cost += acq.alloc_cost;
                    let bytes = self.keymap.transfer_bytes(tile);
                    match acq.source {
                        Source::L1 => {}
                        Source::Peer { src, .. } => {
                            let done = self.topo.book_p2p(src, d, bytes, done_at);
                            self.trace.record(d, stream, EvKind::P2p, done_at, done, bytes as f64);
                            done_at = done;
                        }
                        Source::Host => {
                            let done = self.topo.book_hd(d, Dir::H2D, bytes, done_at);
                            self.trace.record(d, stream, EvKind::H2d, done_at, done, bytes as f64);
                            done_at = done;
                        }
                    }
                    self.workers[d].deferred_releases.push(key);
                    staged += 1;
                }
            }
            self.workers[d].stream_free[stream] = done_at;
        }

        // -- line 16: schedule the sync point closing the round; the
        //    prefetches above keep the barrier off the transfer path.
        let t_sync = self.workers[d]
            .stream_free
            .iter()
            .cloned()
            .fold(now, f64::max);
        self.workers[d].scheduled = true;
        self.events
            .schedule(t_sync.max(now + 1e-9), WakeEvent::Device(d));
    }

    fn priority_of(&self, d: usize, task: usize) -> u32 {
        task_priority(&self.tasks[task], d, &self.caches, |r| self.keymap.key(r))
    }

    /// Lines 11–15: fill RS from the global queue; steal if both empty.
    fn refill_rs(&mut self, d: usize) {
        // Demand pacing: a wake may claim at most one stream-round's
        // worth of tasks. Draining the whole queue into the first RS
        // that wakes would hand slow devices work the fast ones will
        // want — the queue IS the demand signal (§IV-C).
        let mut budget = self.workers[d].stream_free.len();
        while !self.workers[d].rs.is_full() && budget > 0 {
            match self.queue.pop_front() {
                Some(t) => {
                    let p = self.priority_of(d, t);
                    self.workers[d].rs.insert(t, p);
                    budget -= 1;
                }
                None => break,
            }
        }
        // Paper §IV-C: stealing triggers when the device "exhausts tasks
        // on RS while the global queue is also empty" — an empty RS is
        // the demand signal even while earlier tasks still stream.
        if self.workers[d].rs.is_empty() && self.cfg.work_stealing {
            // steal from the fullest victim
            let victim = (0..self.workers.len())
                .filter(|&v| v != d)
                .max_by_key(|&v| self.workers[v].rs.len());
            if let Some(v) = victim {
                if let Some(slot) = self.workers[v].rs.steal_worst() {
                    let p = self.priority_of(d, slot.task);
                    self.workers[d].rs.insert(slot.task, p);
                    self.workers[d].steals += 1;
                }
            }
        }
        // refresh priorities after arrivals (paper §IV-C)
        let keymap = &self.keymap;
        let caches = &self.caches;
        let tasks = &self.tasks;
        self.workers[d]
            .rs
            .refresh(|t| task_priority(&tasks[t], d, caches, |r| keymap.key(r)));
    }

    /// Wake any dormant workers (new tasks became ready).
    fn wake_idlers(&mut self, now: SimTime) {
        for d in 0..self.workers.len() {
            if self.dead[d] {
                continue;
            }
            if self.workers[d].idle && !self.workers[d].scheduled {
                self.workers[d].scheduled = true;
                self.events.schedule(now, WakeEvent::Device(d));
            }
        }
        if let Some(cpu) = &mut self.cpu {
            if cpu.current.is_none() && !cpu.scheduled {
                cpu.scheduled = true;
                self.events.schedule(now, WakeEvent::Cpu);
            }
        }
    }

    // ---------------------------------------------------------------
    // CPU computation thread (§IV-C.2): whole tasks, host-rate kernels

    fn cpu_round(&mut self, now: SimTime) {
        let Some(cpu) = &mut self.cpu else { return };
        cpu.scheduled = false;
        // finish the current task
        if let Some(tid) = cpu.current.take() {
            cpu.tasks_done += 1;
            self.remaining -= 1;
            let succ = self.tasks[tid].successor;
            if let Some(succ) = succ {
                self.deps[succ] -= 1;
                if self.deps[succ] == 0 {
                    self.queue.push_back(succ);
                    self.wake_idlers(now);
                }
            }
        }
        // pull the next one (demand-driven, same queue as the GPUs) —
        // but only while the GPUs have clearly more queued work than one
        // CPU task takes: a whole task on the slow host near depletion
        // would straggle the finish line (§IV-C.2).
        let model = self.machine.cpu.as_ref().expect("cpu worker without model");
        let Some(&head) = self.queue.front() else { return };
        let cpu_secs_est = self.tasks[head].flops / (model.rate(self.dtype) * 1e9);
        let gpu_rate: f64 = self
            .machine
            .devices
            .iter()
            .map(|dev| dev.rate(self.dtype) * 1e9 * dev.efficiency(self.cfg.t))
            .sum();
        let queued_flops: f64 = self.queue.iter().map(|&t| self.tasks[t].flops).sum();
        if queued_flops / gpu_rate < 1.2 * cpu_secs_est {
            return;
        }
        let Some(tid) = self.queue.pop_front() else { return };
        let secs = self.tasks[tid].flops / (model.rate(self.dtype) * 1e9)
            * super::config::jitter_factor(self.cfg.jitter, self.workers.len(), tid);
        let dev_idx = self.workers.len(); // CPU traces as the last "device"
        self.trace.record(dev_idx, 0, EvKind::Kernel, now, now + secs, self.tasks[tid].flops);
        let cpu = self.cpu.as_mut().unwrap();
        cpu.current = Some(tid);
        cpu.busy_until = now + secs;
        cpu.scheduled = true;
        self.events.schedule(cpu.busy_until, WakeEvent::Cpu);
    }
}

/// Convenience: run a task set under a config on a machine.
pub fn simulate(
    cfg: &RunConfig,
    machine: &Machine,
    ts: &TaskSet,
    keymap: KeyMap,
    dtype: Dtype,
) -> SimReport {
    // The Fig. 5 cudaMalloc model needs the allocator cost surfaced; the
    // engine accumulates it into `alloc_cost` and (approximately) into
    // the makespan by serializing it on the kernel lane — see
    // `AllocStrategy::CudaMalloc` handling in `mem`.
    let _ = AllocStrategy::FastHeap;
    SimEngine::new(cfg, machine, ts, keymap, dtype).run()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::{Dtype, Routine};
    use crate::coordinator::dispatch::square_workload;
    use crate::sim::toy;

    #[test]
    fn fault_plan_kill_models_a_degraded_machine() {
        use crate::fault::FaultPlan;
        let machine = toy(3, 64 << 20);
        let w = square_workload(Routine::Gemm, 512, 128, Dtype::F64);
        let cfg = RunConfig { t: 128, ..Default::default() };
        let healthy = simulate(&cfg, &machine, &w.ts, w.keymap.clone(), w.dtype);
        let cfg_degraded = RunConfig {
            t: 128,
            fault_plan: Some(FaultPlan::parse("kill@dev2:op0").unwrap()),
            ..Default::default()
        };
        let degraded = simulate(&cfg_degraded, &machine, &w.ts, w.keymap.clone(), w.dtype);
        assert!(degraded.feasible);
        assert_eq!(degraded.tasks_per_worker[2], 0, "killed device must execute nothing");
        let total: usize = degraded.tasks_per_worker.iter().sum();
        assert_eq!(total, w.ts.tasks.len(), "survivors absorb the whole workload");
        assert!(
            degraded.makespan > healthy.makespan,
            "losing a device must not speed the machine up"
        );
    }

    #[test]
    fn prefetch_depth_keeps_the_sim_sound() {
        // Lookahead staging must not change what executes — only when
        // transfers are booked. Same tasks, still feasible, and the
        // trace keeps the same span taxonomy (every byte is H2d/P2p,
        // so comm_volumes stays comparable with the real engine).
        let machine = toy(3, 64 << 20);
        let w = square_workload(Routine::Gemm, 512, 128, Dtype::F64);
        let plain = simulate(
            &RunConfig { t: 128, ..Default::default() },
            &machine, &w.ts, w.keymap.clone(), w.dtype,
        );
        let pf = simulate(
            &RunConfig { t: 128, prefetch: Some(4), ..Default::default() },
            &machine, &w.ts, w.keymap.clone(), w.dtype,
        );
        assert!(pf.feasible);
        assert_eq!(
            pf.tasks_per_worker.iter().sum::<usize>(),
            plain.tasks_per_worker.iter().sum::<usize>(),
        );
        assert!(pf.makespan > 0.0 && pf.makespan.is_finite());
        let vol_plain: f64 = crate::trace::comm_volumes(&plain.trace)
            .iter().map(|v| v.hd_bytes + v.p2p_bytes).sum();
        let vol_pf: f64 = crate::trace::comm_volumes(&pf.trace)
            .iter().map(|v| v.hd_bytes + v.p2p_bytes).sum();
        assert!(vol_pf >= vol_plain * 0.5, "prefetch cannot erase demand transfers");
    }

    #[test]
    #[should_panic(expected = "simulation stalled")]
    fn wedged_cache_surfaces_as_stall_not_runaway() {
        // One tile of VRAM: the bound task's C block pins it and the
        // k-step's A tile can never be admitted. Before the drain
        // guard this spun the event queue at now+ε until the 10⁹-event
        // runaway tripped (minutes); parked workers now drain the
        // queue immediately and the run surfaces the crisp stall
        // diagnostic instead.
        let cfg = RunConfig { t: 64, ..Default::default() };
        let machine = toy(1, 64 * 64 * 8);
        let w = square_workload(Routine::Gemm, 128, 64, Dtype::F64);
        let _ = simulate(&cfg, &machine, &w.ts, w.keymap.clone(), w.dtype);
    }
}
