//! Routine dispatch: descriptor → task set + key map → engine.

use super::config::{Policy, RunConfig};
use super::keymap::KeyMap;
use super::sim_engine::{simulate, SimReport};
use crate::api::types::{Routine, Side, Trans};
use crate::api::Dtype;
use crate::sim::Machine;
use crate::task::{
    taskize_gemm, taskize_symm, taskize_syr2k, taskize_syrk, taskize_trmm, taskize_trsm,
    GemmDesc, SymmDesc, SyrkDesc, TriDesc,
};
use crate::task::TaskSet;
use crate::tile::TileGrid;

/// A fully-specified simulated workload: the routine, its geometry and
/// the derived task set.
pub struct Workload {
    pub routine: Routine,
    pub ts: TaskSet,
    pub keymap: KeyMap,
    pub dtype: Dtype,
}

impl Workload {
    pub fn total_flops(&self) -> f64 {
        self.ts.total_flops()
    }
}

/// Build the task set + key map for a square-size-`n` instance of a
/// routine — the benchmark harness' standard workload (paper §V-A:
/// square matrices, `N` from 1024 to 39936).
pub fn square_workload(routine: Routine, n: usize, t: usize, dtype: Dtype) -> Workload {
    let esz = dtype.size_bytes();
    let (ts, a, b, c) = match routine {
        Routine::Gemm => {
            let d = GemmDesc {
                ta: Trans::No,
                tb: Trans::No,
                m: n,
                n,
                k: n,
                alpha: 1.2,
                beta: 0.8,
                t,
            };
            (
                taskize_gemm(&d),
                TileGrid::new(n, n, t),
                TileGrid::new(n, n, t),
                TileGrid::new(n, n, t),
            )
        }
        Routine::Syrk => {
            let d = SyrkDesc {
                uplo: crate::api::types::Uplo::Upper,
                trans: Trans::No,
                n,
                k: n,
                alpha: 1.2,
                beta: 0.8,
                t,
            };
            (
                taskize_syrk(&d),
                TileGrid::new(n, n, t),
                TileGrid::new(n, n, t), // unused (B == A)
                TileGrid::new(n, n, t),
            )
        }
        Routine::Syr2k => {
            let d = SyrkDesc {
                uplo: crate::api::types::Uplo::Upper,
                trans: Trans::No,
                n,
                k: n,
                alpha: 1.2,
                beta: 0.8,
                t,
            };
            (
                taskize_syr2k(&d),
                TileGrid::new(n, n, t),
                TileGrid::new(n, n, t),
                TileGrid::new(n, n, t),
            )
        }
        Routine::Symm => {
            let d = SymmDesc {
                side: Side::Left,
                uplo: crate::api::types::Uplo::Upper,
                m: n,
                n,
                alpha: 1.2,
                beta: 0.8,
                t,
            };
            (
                taskize_symm(&d),
                TileGrid::new(n, n, t),
                TileGrid::new(n, n, t),
                TileGrid::new(n, n, t),
            )
        }
        Routine::Trmm => {
            let d = TriDesc {
                side: Side::Left,
                uplo: crate::api::types::Uplo::Upper,
                ta: Trans::No,
                diag: crate::api::types::Diag::NonUnit,
                m: n,
                n,
                alpha: 1.2,
                t,
            };
            (
                taskize_trmm(&d),
                TileGrid::new(n, n, t),
                TileGrid::new(n, n, t), // unused
                TileGrid::new(n, n, t),
            )
        }
        Routine::Trsm => {
            let d = TriDesc {
                side: Side::Left,
                uplo: crate::api::types::Uplo::Upper,
                ta: Trans::No,
                diag: crate::api::types::Diag::NonUnit,
                m: n,
                n,
                alpha: 1.2,
                t,
            };
            (
                taskize_trsm(&d),
                TileGrid::new(n, n, t),
                TileGrid::new(n, n, t),
                TileGrid::new(n, n, t),
            )
        }
    };
    Workload { routine, ts, keymap: KeyMap::new(a, b, c, esz), dtype }
}

/// Build the fused workload for a GEMM batch: every problem taskized at
/// tile size `t`, fused with problem-namespaced tiles, heads emitted in
/// scheduling-quantum order (see `crate::batch`). `n_workers` sizes the
/// quanta — pass the machine's device count.
pub fn gemm_batch_workload(
    problems: Vec<crate::task::GemmDesc>,
    t: usize,
    dtype: Dtype,
    n_workers: usize,
) -> Workload {
    use crate::batch::{taskize_batch, BatchDesc, BatchedGemm};
    let desc = BatchDesc::Gemm(BatchedGemm::variable(problems));
    let ts = taskize_batch(&desc, t, n_workers);
    // An empty batch is a valid no-op workload (mirrors the real-engine
    // API); give the KeyMap a degenerate problem so it has a tile size.
    let grids = if desc.is_empty() {
        vec![[crate::tile::TileGrid::new(0, 0, t); 3]]
    } else {
        desc.grids(t)
    };
    let keymap = KeyMap::for_batch(grids, dtype.size_bytes());
    Workload { routine: Routine::Gemm, ts, keymap, dtype }
}

/// Simulate a workload on a machine under a config, routing to the
/// requested policy (BLASX here; baselines live in `crate::baselines`
/// and are selected through the same entry point).
pub fn run_sim(cfg: &RunConfig, machine: &Machine, w: &Workload) -> SimReport {
    match cfg.policy {
        Policy::Blasx => simulate(cfg, machine, &w.ts, w.keymap.clone(), w.dtype),
        _ => crate::baselines::run(cfg, machine, w),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::toy;

    #[test]
    fn workloads_build_for_all_routines() {
        for r in Routine::ALL {
            let w = square_workload(r, 300, 64, Dtype::F64);
            w.ts.validate().unwrap();
            assert!(w.total_flops() > 0.0, "{r:?}");
        }
    }

    #[test]
    fn batch_workload_simulates_on_blasx() {
        let cfg = RunConfig { t: 64, ..Default::default() };
        let machine = toy(2, 64 * (64 * 64 * 8));
        let probs: Vec<GemmDesc> = (0..8)
            .map(|i| GemmDesc {
                ta: Trans::No,
                tb: Trans::No,
                m: 64 + 32 * (i % 3),
                n: 64,
                k: 64,
                alpha: 1.0,
                beta: 0.0,
                t: 0,
            })
            .collect();
        let w = gemm_batch_workload(probs, 64, Dtype::F64, machine.devices.len());
        w.ts.validate().unwrap();
        let rep = run_sim(&cfg, &machine, &w);
        assert!(rep.feasible && rep.makespan > 0.0);
        assert_eq!(rep.tasks_per_worker.iter().sum::<usize>(), w.ts.tasks.len());
        // both devices contributed — the quanta interleave feeds both
        assert!(rep.tasks_per_worker.iter().all(|&c| c > 0), "{:?}", rep.tasks_per_worker);
    }

    #[test]
    fn blasx_sim_runs_small_gemm() {
        let cfg = RunConfig { t: 64, ..Default::default() };
        let machine = toy(2, 64 * (64 * 64 * 8)); // room for 64 tiles
        let w = square_workload(Routine::Gemm, 512, 64, Dtype::F64);
        let rep = run_sim(&cfg, &machine, &w);
        assert!(rep.makespan > 0.0);
        // all 64 output tiles done
        assert_eq!(rep.tasks_per_worker.iter().sum::<usize>(), 64);
        // both devices contributed (demand-driven sharing)
        assert!(rep.tasks_per_worker.iter().all(|&c| c > 0), "{:?}", rep.tasks_per_worker);
    }
}
