//! TileRef → (TileKey, geometry) resolution.
//!
//! The caches key tiles by host address (paper Alg. 2 "HA"). The real
//! engine derives keys from the actual `HostMat` pointers; the simulator
//! runs matrices that are never allocated (N up to 39936 ⇒ 12.7 GB per
//! operand), so it lays the three operands out in a *virtual* address
//! space with the same uniqueness and alignment properties.
//!
//! Batched runs extend the scheme with a *problem index*: each problem
//! of a fused batch gets its own triple of virtual operand bases, so the
//! ALRU/MESI-X layers see one flat key space across the whole batch and
//! need no batching awareness at all.

use crate::task::TileRef;
use crate::tile::{MatId, TileGrid, TileKey};

/// Geometry of the operands of one routine invocation — or of every
/// problem of a fused batch — plus the virtual base addresses the
/// simulator keys tiles by.
#[derive(Clone, Debug)]
pub struct KeyMap {
    /// Per-problem operand grids in (A, B, C) order.
    grids: Vec<[TileGrid; 3]>,
    /// Element size in bytes.
    pub esz: usize,
    /// Tile size.
    pub t: usize,
}

/// Virtual span reserved per operand: larger than any matrix footprint
/// (2^44 bytes ≈ 17 TB) so keys can never collide across operands or
/// problems.
const SPAN: usize = 1 << 44;

impl KeyMap {
    /// Build from operand grids (A, B, C order). `esz` is the element
    /// byte width; bases are synthetic, spaced far apart.
    pub fn new(a: TileGrid, b: TileGrid, c: TileGrid, esz: usize) -> KeyMap {
        Self::for_batch(vec![[a, b, c]], esz)
    }

    /// Build for a fused batch: one (A, B, C) grid triple per problem.
    /// All problems must share the output tile size.
    pub fn for_batch(problems: Vec<[TileGrid; 3]>, esz: usize) -> KeyMap {
        assert!(!problems.is_empty(), "KeyMap needs at least one problem");
        // 3 operands × SPAN each per problem must fit the address space.
        assert!(
            problems.len() <= usize::MAX / (3 * SPAN) - 1,
            "batch too large for the virtual key space"
        );
        let t = problems[0][2].t;
        debug_assert!(problems.iter().all(|g| g[2].t == t), "mixed tile sizes in batch");
        KeyMap { grids: problems, esz, t }
    }

    fn idx(mat: MatId) -> usize {
        match mat {
            MatId::A => 0,
            MatId::B => 1,
            MatId::C => 2,
        }
    }

    /// Number of problems this map covers (1 for single-routine runs).
    pub fn n_problems(&self) -> usize {
        self.grids.len()
    }

    /// The grid of an operand of problem 0 (single-problem accessor,
    /// kept for the baseline engines which never run batches).
    pub fn grid(&self, mat: MatId) -> &TileGrid {
        &self.grids[0][Self::idx(mat)]
    }

    /// The grid of an operand of problem `p`.
    pub fn grid_of(&self, p: usize, mat: MatId) -> &TileGrid {
        &self.grids[p][Self::idx(mat)]
    }

    /// Virtual cache key of a tile (unique per (p, mat, ti, tj), stable
    /// across calls — mirrors a host address). Problem 0's bases match
    /// the historical single-problem layout exactly. Virtual operands
    /// are laid out tightly, so the stride discriminant is the grid's
    /// row count; epochs stay 0 (the simulator never runs cross-call).
    pub fn key(&self, r: TileRef) -> TileKey {
        let g = self.grid_of(r.p, r.mat);
        let base = SPAN * (1 + 3 * r.p + Self::idx(r.mat));
        let addr = base + (g.col_origin(r.tj) * g.rows + g.row_origin(r.ti)) * self.esz;
        let (h, w) = g.tile_dims(r.ti, r.tj);
        TileKey { addr, mat: r.mat, ti: r.ti, tj: r.tj, ld: g.rows.max(1), epoch: 0, h, w, t: g.t }
    }

    /// Cache-block bytes of any tile (uniform t×t padding — what the
    /// FastHeap recycles).
    pub fn tile_bytes(&self) -> usize {
        self.t * self.t * self.esz
    }

    /// *Actual* bytes of a tile (edge tiles are smaller) — what the DMA
    /// moves and what Table V counts.
    pub fn transfer_bytes(&self, r: TileRef) -> usize {
        let (h, w) = self.grid_of(r.p, r.mat).tile_dims(r.ti, r.tj);
        h * w * self.esz
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn map() -> KeyMap {
        KeyMap::new(
            TileGrid::new(100, 50, 32),
            TileGrid::new(50, 80, 32),
            TileGrid::new(100, 80, 32),
            8,
        )
    }

    #[test]
    fn keys_unique_within_and_across_mats() {
        let m = map();
        let mut seen = std::collections::HashSet::new();
        for mat in [MatId::A, MatId::B, MatId::C] {
            let g = *m.grid(mat);
            for (ti, tj) in g.iter() {
                assert!(seen.insert(m.key(TileRef::new(mat, ti, tj)).addr));
            }
        }
    }

    #[test]
    fn keys_stable() {
        let m = map();
        let r = TileRef::new(MatId::B, 1, 2);
        assert_eq!(m.key(r), m.key(r));
    }

    #[test]
    fn transfer_bytes_shrink_on_edges() {
        let m = map();
        // A is 100x50 with t=32: last tile row is 100-3*32 = 4 high
        assert_eq!(m.transfer_bytes(TileRef::new(MatId::A, 0, 0)), 32 * 32 * 8);
        assert_eq!(m.transfer_bytes(TileRef::new(MatId::A, 3, 0)), 4 * 32 * 8);
        assert_eq!(m.tile_bytes(), 32 * 32 * 8);
    }

    #[test]
    fn batch_keys_unique_across_problems() {
        let g = |n: usize| TileGrid::new(n, n, 32);
        let m = KeyMap::for_batch(vec![[g(64), g(64), g(64)], [g(64), g(64), g(64)]], 8);
        assert_eq!(m.n_problems(), 2);
        let mut seen = std::collections::HashSet::new();
        for p in 0..2 {
            for mat in [MatId::A, MatId::B, MatId::C] {
                for (ti, tj) in g(64).iter() {
                    assert!(seen.insert(m.key(TileRef::for_problem(p, mat, ti, tj)).addr));
                }
            }
        }
    }

    #[test]
    fn problem_zero_matches_single_problem_layout() {
        // A batch map's problem 0 must key exactly like the plain map,
        // so caches warmed by a single call stay valid for a batch over
        // the same operands.
        let single = map();
        let batch = KeyMap::for_batch(
            vec![
                [
                    TileGrid::new(100, 50, 32),
                    TileGrid::new(50, 80, 32),
                    TileGrid::new(100, 80, 32),
                ],
                [TileGrid::new(32, 32, 32); 3],
            ],
            8,
        );
        let r = TileRef::new(MatId::B, 1, 2);
        assert_eq!(single.key(r), batch.key(r));
    }

    #[test]
    fn per_mat_virtual_spans_stay_disjoint_without_role_in_equality() {
        // `TileKey` equality no longer includes the operand role, so
        // the sim's cross-operand safety rests entirely on the SPAN
        // reservation: every operand's virtual addresses must stay
        // inside its own span, and keys of different operands must
        // never compare equal even at identical (ti, tj).
        let m = KeyMap::for_batch(
            vec![[TileGrid::new(100, 80, 32); 3], [TileGrid::new(64, 64, 32); 3]],
            8,
        );
        for p in 0..2 {
            for (idx, mat) in [MatId::A, MatId::B, MatId::C].into_iter().enumerate() {
                let g = *m.grid_of(p, mat);
                let base = SPAN * (1 + 3 * p + idx);
                for (ti, tj) in g.iter() {
                    let k = m.key(TileRef::for_problem(p, mat, ti, tj));
                    assert!(
                        k.addr >= base && k.addr < base + SPAN,
                        "operand {mat:?} of problem {p} escaped its span"
                    );
                }
            }
            // Same coordinates across roles: unequal via addr alone.
            let a = m.key(TileRef::for_problem(p, MatId::A, 0, 0));
            let b = m.key(TileRef::for_problem(p, MatId::B, 0, 0));
            let c = m.key(TileRef::for_problem(p, MatId::C, 0, 0));
            assert_ne!(a, b);
            assert_ne!(b, c);
            assert_ne!(a, c);
        }
    }

    #[test]
    fn batch_transfer_bytes_follow_problem_geometry() {
        let m = KeyMap::for_batch(
            vec![[TileGrid::new(64, 64, 32); 3], [TileGrid::new(40, 40, 32); 3]],
            8,
        );
        assert_eq!(m.transfer_bytes(TileRef::for_problem(0, MatId::A, 1, 1)), 32 * 32 * 8);
        // problem 1's edge tile is 8x8
        assert_eq!(m.transfer_bytes(TileRef::for_problem(1, MatId::A, 1, 1)), 8 * 8 * 8);
    }
}
