//! TileRef → (TileKey, geometry) resolution.
//!
//! The caches key tiles by host address (paper Alg. 2 "HA"). The real
//! engine derives keys from the actual `HostMat` pointers; the simulator
//! runs matrices that are never allocated (N up to 39936 ⇒ 12.7 GB per
//! operand), so it lays the three operands out in a *virtual* address
//! space with the same uniqueness and alignment properties.

use crate::task::TileRef;
use crate::tile::{MatId, TileGrid, TileKey};

/// Geometry of the three operands of one routine invocation, plus the
/// virtual base addresses the simulator keys tiles by.
#[derive(Clone, Debug)]
pub struct KeyMap {
    grids: [TileGrid; 3],
    bases: [usize; 3],
    /// Element size in bytes.
    pub esz: usize,
    /// Tile size.
    pub t: usize,
}

impl KeyMap {
    /// Build from operand grids (A, B, C order). `esz` is the element
    /// byte width; bases are synthetic, spaced far apart.
    pub fn new(a: TileGrid, b: TileGrid, c: TileGrid, esz: usize) -> KeyMap {
        let t = c.t;
        // Space the virtual operands by more than any matrix footprint
        // (2^44 bytes) so keys can never collide across operands.
        const SPAN: usize = 1 << 44;
        KeyMap { grids: [a, b, c], bases: [SPAN, 2 * SPAN, 3 * SPAN], esz, t }
    }

    fn idx(mat: MatId) -> usize {
        match mat {
            MatId::A => 0,
            MatId::B => 1,
            MatId::C => 2,
        }
    }

    /// The grid of an operand.
    pub fn grid(&self, mat: MatId) -> &TileGrid {
        &self.grids[Self::idx(mat)]
    }

    /// Virtual cache key of a tile (unique per (mat, ti, tj), stable
    /// across calls — mirrors a host address).
    pub fn key(&self, r: TileRef) -> TileKey {
        let g = self.grid(r.mat);
        let addr = self.bases[Self::idx(r.mat)]
            + (g.col_origin(r.tj) * g.rows + g.row_origin(r.ti)) * self.esz;
        TileKey { addr, mat: r.mat, ti: r.ti, tj: r.tj }
    }

    /// Cache-block bytes of any tile (uniform t×t padding — what the
    /// FastHeap recycles).
    pub fn tile_bytes(&self) -> usize {
        self.t * self.t * self.esz
    }

    /// *Actual* bytes of a tile (edge tiles are smaller) — what the DMA
    /// moves and what Table V counts.
    pub fn transfer_bytes(&self, r: TileRef) -> usize {
        let (h, w) = self.grid(r.mat).tile_dims(r.ti, r.tj);
        h * w * self.esz
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn map() -> KeyMap {
        KeyMap::new(
            TileGrid::new(100, 50, 32),
            TileGrid::new(50, 80, 32),
            TileGrid::new(100, 80, 32),
            8,
        )
    }

    #[test]
    fn keys_unique_within_and_across_mats() {
        let m = map();
        let mut seen = std::collections::HashSet::new();
        for mat in [MatId::A, MatId::B, MatId::C] {
            let g = *m.grid(mat);
            for (ti, tj) in g.iter() {
                assert!(seen.insert(m.key(TileRef::new(mat, ti, tj)).addr));
            }
        }
    }

    #[test]
    fn keys_stable() {
        let m = map();
        let r = TileRef::new(MatId::B, 1, 2);
        assert_eq!(m.key(r), m.key(r));
    }

    #[test]
    fn transfer_bytes_shrink_on_edges() {
        let m = map();
        // A is 100x50 with t=32: last tile row is 100-3*32 = 4 high
        assert_eq!(m.transfer_bytes(TileRef::new(MatId::A, 0, 0)), 32 * 32 * 8);
        assert_eq!(m.transfer_bytes(TileRef::new(MatId::A, 3, 0)), 4 * 32 * 8);
        assert_eq!(m.tile_bytes(), 32 * 32 * 8);
    }
}
