//! The L3 coordinator (system S11): configuration, routine dispatch and
//! the two execution engines sharing one scheduling policy —
//!
//! - [`sim_engine`]: the DES engine producing paper-scale performance
//!   numbers on the simulated substrate (benchmark harness);
//! - [`real_engine`]: the threaded engine computing real numerics
//!   through PJRT artifacts or the hostblas kernels (public BLAS API).

pub mod config;
pub mod dispatch;
pub mod keymap;
pub mod real_engine;
pub mod sim_engine;

pub use config::{Backend, Policy, RunConfig};
pub use dispatch::{gemm_batch_workload, run_sim, square_workload, Workload};
pub use keymap::KeyMap;
pub use real_engine::{run_real, run_real_batch, FaultStats, JobStats, Mats, RealReport};
pub use sim_engine::{simulate, SimEngine, SimReport};
