//! Virtual accelerator models calibrated from the paper's own numbers.
//!
//! Scheduling behaviour (load balance, overlap quality, cache pressure)
//! depends only on *relative* compute/transfer rates and capacities, so a
//! rate-curve device is a faithful substrate for reproducing the paper's
//! comparisons even though no CUDA hardware exists here (DESIGN.md §1).
//!
//! Calibration sources: K40c in-core cuBLAS DGEMM ≈ 1.20 TFLOPS (paper
//! §V-A: "92.68% of the in-core cuBLAS DGEMM peak" against a 1.43 TFLOPS
//! DP peak); TITAN X (Maxwell) DP ≈ 0.19 TFLOPS; Fig. 10's tile-size
//! saturation curve; Fig. 5's cudaMalloc overhead.

use crate::api::Dtype;

/// A virtual GPU (or CPU pool) participating in the runtime.
#[derive(Clone, Debug)]
pub struct DeviceModel {
    /// Human-readable name ("K40c-0", "TITANX-1", "cpu").
    pub name: String,
    /// Saturated double-precision GEMM rate, GFLOP/s.
    pub dp_gflops: f64,
    /// Saturated single-precision GEMM rate, GFLOP/s.
    pub sp_gflops: f64,
    /// Onboard RAM in bytes (the L1 tile-cache capacity).
    pub vram: usize,
    /// Tile size at which the kernel reaches half of the saturated rate
    /// (the Fig. 10 "knee"; efficiency = t² / (t² + knee²)).
    pub knee: f64,
    /// Fixed kernel-launch overhead, seconds (stream gaps — the paper's
    /// OTHER component).
    pub launch_overhead: f64,
    /// Number of concurrent streams the worker drives (the paper uses 4).
    pub n_streams: usize,
}

impl DeviceModel {
    /// Kepler K40c per the paper's calibration.
    pub fn k40c(idx: usize) -> DeviceModel {
        DeviceModel {
            name: format!("K40c-{idx}"),
            dp_gflops: 1200.0,
            sp_gflops: 3300.0,
            vram: 12 * (1 << 30),
            knee: 256.0,
            launch_overhead: 8e-6,
            n_streams: 4,
        }
    }

    /// Maxwell TITAN X: strong SP, crippled DP (1/32 ratio) — the
    /// heterogeneity that breaks static schedulers on Makalu.
    pub fn titan_x(idx: usize) -> DeviceModel {
        DeviceModel {
            name: format!("TITANX-{idx}"),
            dp_gflops: 190.0,
            sp_gflops: 5000.0,
            vram: 12 * (1 << 30),
            knee: 256.0,
            launch_overhead: 8e-6,
            n_streams: 4,
        }
    }

    /// A CPU worker pool (paper §IV-C.2): consumes whole tasks with a
    /// multithreaded host BLAS.
    pub fn cpu_pool(dp_gflops: f64) -> DeviceModel {
        DeviceModel {
            name: "cpu".into(),
            dp_gflops,
            sp_gflops: dp_gflops * 2.0,
            vram: usize::MAX, // operates in host RAM directly
            knee: 64.0,
            launch_overhead: 0.0,
            n_streams: 1,
        }
    }

    /// Saturated rate for a dtype, GFLOP/s.
    pub fn rate(&self, dtype: Dtype) -> f64 {
        match dtype {
            Dtype::F32 => self.sp_gflops,
            Dtype::F64 => self.dp_gflops,
        }
    }

    /// Kernel-saturation efficiency at square-tile dimension `t`
    /// (Fig. 10: rises with tile size, plateaus past ~1024).
    pub fn efficiency(&self, t: usize) -> f64 {
        let t = t as f64;
        t * t / (t * t + self.knee * self.knee)
    }

    /// Wall-clock seconds to execute `flops` at tile dimension `t`.
    pub fn kernel_secs(&self, flops: f64, t: usize, dtype: Dtype) -> f64 {
        self.launch_overhead + flops / (self.rate(dtype) * 1e9 * self.efficiency(t))
    }

    /// Effective GFLOP/s at tile dimension `t` (for reports).
    pub fn effective_gflops(&self, t: usize, dtype: Dtype) -> f64 {
        self.rate(dtype) * self.efficiency(t)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn saturation_curve_matches_fig10_shape() {
        let d = DeviceModel::k40c(0);
        // monotone increasing, plateauing
        let e128 = d.efficiency(128);
        let e256 = d.efficiency(256);
        let e512 = d.efficiency(512);
        let e1024 = d.efficiency(1024);
        let e2048 = d.efficiency(2048);
        assert!(e128 < e256 && e256 < e512 && e512 < e1024 && e1024 < e2048);
        // knee definition: 50% at t == knee
        assert!((e256 - 0.5).abs() < 1e-12);
        // plateau: 1024 within 10% of 2048
        assert!((e2048 - e1024) / e2048 < 0.1);
    }

    #[test]
    fn kernel_secs_scales() {
        let d = DeviceModel::k40c(0);
        // one 1024³ DGEMM tile-step: 2*1024³ flops at ~94% of 1.2 TF
        let t = d.kernel_secs(2.0 * 1024f64.powi(3), 1024, Dtype::F64);
        let expect = 8e-6 + 2.0 * 1024f64.powi(3) / (1200e9 * d.efficiency(1024));
        assert!((t - expect).abs() < 1e-12);
        // SP is faster
        assert!(d.kernel_secs(1e9, 1024, Dtype::F32) < d.kernel_secs(1e9, 1024, Dtype::F64));
    }

    #[test]
    fn titan_x_dp_cripple() {
        let k = DeviceModel::k40c(0);
        let t = DeviceModel::titan_x(0);
        assert!(t.dp_gflops < k.dp_gflops / 5.0);
        assert!(t.sp_gflops > k.sp_gflops);
    }
}
