//! The paper's two testbeds as simulator presets (Table II).

use super::device::DeviceModel;
use super::topology::TopologyConfig;

/// One simulated machine: devices + interconnect + optional CPU pool.
#[derive(Clone, Debug)]
pub struct Machine {
    pub name: &'static str,
    pub devices: Vec<DeviceModel>,
    pub topology: TopologyConfig,
    /// The CPU worker pool model (None = GPU-only run).
    pub cpu: Option<DeviceModel>,
}

/// Everest: 3× Kepler K40c, 2× Xeon E5 4655 v3, 64 GB DDR3. P2P exists
/// only between GPU 1 and GPU 2 (0-indexed; the paper's GPU2/GPU3 —
/// Table V footnote).
pub fn everest(n_gpus: usize) -> Machine {
    assert!((1..=3).contains(&n_gpus), "Everest has 3 GPUs");
    let devices: Vec<DeviceModel> = (0..n_gpus).map(DeviceModel::k40c).collect();
    let groups = match n_gpus {
        3 => vec![vec![0], vec![1, 2]],
        2 => vec![vec![0, 1]], // two K40 on one switch for 2-GPU runs
        _ => vec![vec![0]],
    };
    Machine {
        name: "everest",
        devices,
        topology: TopologyConfig::paper_defaults(n_gpus, groups),
        // 2-socket 12-core Haswell (E5-4655 v3): multithreaded OpenBLAS
        // sustains ~400 DP GFLOPS — useful, but a third of one K40.
        cpu: Some(DeviceModel::cpu_pool(400.0)),
    }
}

/// Makalu: 2× Kepler K40 + 2× Maxwell TITAN X, Xeon E5 1620 v3. The
/// heterogeneous testbed: TITAN X DP is 1/6 of a K40, so static
/// schedulers collapse (paper §V, Fig. 7 analysis).
pub fn makalu(n_gpus: usize) -> Machine {
    assert!((1..=4).contains(&n_gpus), "Makalu has 4 GPUs");
    let mut devices = Vec::new();
    // Device order K40, K40, TITANX, TITANX; n_gpus trims from the end,
    // so 2-GPU runs are homogeneous K40s and 3-4 GPU runs mix in Maxwell.
    for i in 0..n_gpus.min(2) {
        devices.push(DeviceModel::k40c(i));
    }
    for i in 2..n_gpus {
        devices.push(DeviceModel::titan_x(i));
    }
    let groups = match n_gpus {
        4 => vec![vec![0, 1], vec![2, 3]],
        3 => vec![vec![0, 1], vec![2]],
        2 => vec![vec![0, 1]],
        _ => vec![vec![0]],
    };
    Machine {
        name: "makalu",
        devices,
        topology: TopologyConfig::paper_defaults(n_gpus, groups),
        // single-socket quad-core Haswell (E5-1620 v3): ~150 DP GFLOPS
        cpu: Some(DeviceModel::cpu_pool(150.0)),
    }
}

/// A tiny machine for tests: fast to simulate, small VRAM so cache
/// pressure and eviction paths actually trigger.
pub fn toy(n_gpus: usize, vram: usize) -> Machine {
    let devices: Vec<DeviceModel> = (0..n_gpus)
        .map(|i| DeviceModel {
            name: format!("toy-{i}"),
            dp_gflops: 100.0,
            sp_gflops: 200.0,
            vram,
            knee: 32.0,
            launch_overhead: 1e-6,
            n_streams: 4,
        })
        .collect();
    // all devices behind one switch: maximal P2P reach for cache tests
    let groups = vec![(0..n_gpus).collect()];
    Machine {
        name: "toy",
        devices,
        topology: TopologyConfig::paper_defaults(n_gpus, groups),
        cpu: None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn everest_matches_table2() {
        let m = everest(3);
        assert_eq!(m.devices.len(), 3);
        assert!(m.devices.iter().all(|d| d.name.starts_with("K40c")));
        assert_eq!(m.topology.switch_groups, vec![vec![0], vec![1, 2]]);
        assert!(m.cpu.is_some());
    }

    #[test]
    fn makalu_is_heterogeneous() {
        let m = makalu(4);
        assert_eq!(m.devices.len(), 4);
        assert!(m.devices[0].name.starts_with("K40c"));
        assert!(m.devices[3].name.starts_with("TITANX"));
        let dp: Vec<f64> = m.devices.iter().map(|d| d.dp_gflops).collect();
        assert!(dp[0] > 5.0 * dp[3]);
    }

    #[test]
    #[should_panic(expected = "Everest has 3 GPUs")]
    fn everest_bounds() {
        everest(4);
    }
}
