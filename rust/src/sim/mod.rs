//! Multi-GPU hardware simulator (DESIGN.md §1, system S12).
//!
//! The substrate the paper ran on — CUDA GPUs on PCI-E with P2P — does
//! not exist here, so we simulate it: calibrated device rate curves
//! ([`device`]), a link/DMA interconnect model ([`topology`]), and a
//! deterministic discrete-event core ([`clock`]). The scheduler policy
//! code is *shared* with the real threaded runtime; only time and byte
//! movement differ (DESIGN.md §6.1).

pub mod clock;
pub mod device;
pub mod presets;
pub mod topology;

pub use clock::{EventQueue, Lane, SimTime};
pub use device::DeviceModel;
pub use presets::{everest, makalu, toy, Machine};
pub use topology::{Dir, Topology, TopologyConfig};
