//! Virtual time and serial resource timelines for the DES.
//!
//! The simulator models every contended hardware unit — a GPU's kernel
//! engine, each DMA direction, a PCI-E link — as a [`Lane`]: a serial
//! resource that executes bookings in arrival order. Completion times are
//! computed greedily at booking time, which is exact for serial resources
//! and is the whole of the paper's overlap argument: communication is
//! free exactly when a DMA lane's busy interval hides inside a kernel
//! lane's busy interval.

/// Virtual time in seconds.
pub type SimTime = f64;

/// A serial resource: busy until `free_at`; bookings queue FIFO.
#[derive(Clone, Debug, Default)]
pub struct Lane {
    free_at: SimTime,
    /// Total busy seconds accumulated (for utilization reports).
    pub busy: f64,
    /// Total bookings (for launch-overhead accounting).
    pub bookings: u64,
}

impl Lane {
    pub fn new() -> Lane {
        Lane::default()
    }

    /// Book `dur` seconds no earlier than `ready`. Returns
    /// `(start, end)`; the lane is busy until `end` afterwards.
    pub fn book(&mut self, ready: SimTime, dur: SimTime) -> (SimTime, SimTime) {
        debug_assert!(dur >= 0.0);
        let start = ready.max(self.free_at);
        let end = start + dur;
        self.free_at = end;
        self.busy += dur;
        self.bookings += 1;
        (start, end)
    }

    /// When the lane next becomes idle.
    pub fn free_at(&self) -> SimTime {
        self.free_at
    }

    /// Probe the completion time of a hypothetical booking without
    /// committing it (the scheduler's locality estimates use this).
    pub fn peek(&self, ready: SimTime, dur: SimTime) -> SimTime {
        ready.max(self.free_at) + dur
    }
}

/// A serial resource that *backfills*: a booking occupies the earliest
/// gap of sufficient length at-or-after its ready time, so future-dated
/// reservations (streams book ahead of the clock) never block
/// earlier-ready work the way a FIFO lane would. Used for the shared
/// I/O-hub ceiling, where several devices' pre-booked schedules
/// interleave.
#[derive(Clone, Debug, Default)]
pub struct GapLane {
    /// Sorted, disjoint busy intervals.
    busy: std::collections::VecDeque<(SimTime, SimTime)>,
    /// Total busy seconds (utilization reports).
    pub busy_total: f64,
}

impl GapLane {
    pub fn new() -> GapLane {
        GapLane::default()
    }

    /// Book `dur` seconds at the earliest gap starting at or after
    /// `ready`. Returns `(start, end)`.
    pub fn book(&mut self, ready: SimTime, dur: SimTime) -> (SimTime, SimTime) {
        debug_assert!(dur >= 0.0);
        if dur == 0.0 {
            return (ready, ready);
        }
        let mut start = ready;
        let mut insert_at = self.busy.len();
        for (i, &(bs, be)) in self.busy.iter().enumerate() {
            if be <= start {
                continue;
            }
            if bs >= start + dur {
                // the gap before interval i fits
                insert_at = i;
                break;
            }
            // overlap: skip past this interval
            start = be;
            insert_at = i + 1;
        }
        let end = start + dur;
        // merge with neighbours when adjacent
        self.busy.insert(insert_at, (start, end));
        self.coalesce_around(insert_at);
        self.busy_total += dur;
        // bound memory: merge the two oldest intervals (conservative —
        // only ever *overestimates* past contention)
        while self.busy.len() > 4096 {
            let (s0, _) = self.busy[0];
            let (_, e1) = self.busy[1];
            self.busy.pop_front();
            self.busy[0] = (s0, e1);
        }
        (start, end)
    }

    fn coalesce_around(&mut self, i: usize) {
        // right neighbour
        while i + 1 < self.busy.len() && self.busy[i + 1].0 <= self.busy[i].1 + 1e-15 {
            let (_, e) = self.busy.remove(i + 1).unwrap();
            self.busy[i].1 = self.busy[i].1.max(e);
        }
        // left neighbour
        if i > 0 && self.busy[i].0 <= self.busy[i - 1].1 + 1e-15 {
            let (_, e) = self.busy.remove(i).unwrap();
            self.busy[i - 1].1 = self.busy[i - 1].1.max(e);
        }
    }
}

/// Monotone event queue keyed by virtual time; ties break by insertion
/// sequence so the simulation is fully deterministic.
#[derive(Debug)]
pub struct EventQueue<E> {
    heap: std::collections::BinaryHeap<Ev<E>>,
    seq: u64,
    now: SimTime,
}

#[derive(Debug)]
struct Ev<E> {
    at: SimTime,
    seq: u64,
    payload: E,
}

impl<E> PartialEq for Ev<E> {
    fn eq(&self, o: &Self) -> bool {
        self.at == o.at && self.seq == o.seq
    }
}
impl<E> Eq for Ev<E> {}
impl<E> PartialOrd for Ev<E> {
    fn partial_cmp(&self, o: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(o))
    }
}
impl<E> Ord for Ev<E> {
    fn cmp(&self, o: &Self) -> std::cmp::Ordering {
        // BinaryHeap is a max-heap: invert for earliest-first.
        o.at.total_cmp(&self.at).then(o.seq.cmp(&self.seq))
    }
}

impl<E> EventQueue<E> {
    pub fn new() -> EventQueue<E> {
        EventQueue { heap: std::collections::BinaryHeap::new(), seq: 0, now: 0.0 }
    }

    /// Current virtual time (the timestamp of the last popped event).
    pub fn now(&self) -> SimTime {
        self.now
    }

    /// Schedule `payload` at absolute time `at` (>= now).
    pub fn schedule(&mut self, at: SimTime, payload: E) {
        debug_assert!(at >= self.now - 1e-12, "schedule into the past: {at} < {}", self.now);
        self.heap.push(Ev { at: at.max(self.now), seq: self.seq, payload });
        self.seq += 1;
    }

    /// Pop the earliest event, advancing the clock to it.
    pub fn pop(&mut self) -> Option<(SimTime, E)> {
        let ev = self.heap.pop()?;
        self.now = ev.at;
        Some((ev.at, ev.payload))
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }
}

impl<E> Default for EventQueue<E> {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lane_serializes() {
        let mut l = Lane::new();
        let (s1, e1) = l.book(0.0, 2.0);
        assert_eq!((s1, e1), (0.0, 2.0));
        // requested earlier than free: queues behind
        let (s2, e2) = l.book(1.0, 1.0);
        assert_eq!((s2, e2), (2.0, 3.0));
        // requested later than free: starts at request
        let (s3, e3) = l.book(10.0, 0.5);
        assert_eq!((s3, e3), (10.0, 10.5));
        assert_eq!(l.busy, 3.5);
        assert_eq!(l.bookings, 3);
    }

    #[test]
    fn peek_does_not_commit() {
        let mut l = Lane::new();
        l.book(0.0, 1.0);
        assert_eq!(l.peek(0.0, 2.0), 3.0);
        assert_eq!(l.free_at(), 1.0);
    }

    #[test]
    fn gap_lane_backfills() {
        let mut g = GapLane::new();
        // future-dated booking first
        assert_eq!(g.book(10.0, 2.0), (10.0, 12.0));
        // earlier-ready booking backfills BEFORE it (FIFO would queue it)
        assert_eq!(g.book(0.0, 3.0), (0.0, 3.0));
        // gap between 3 and 10 takes a 5s booking
        assert_eq!(g.book(1.0, 5.0), (3.0, 8.0));
        // too big for the 8..10 gap: lands after 12
        assert_eq!(g.book(1.0, 3.0), (12.0, 15.0));
        // exactly fits the 8..10 gap
        assert_eq!(g.book(0.0, 2.0), (8.0, 10.0));
        assert_eq!(g.busy_total, 15.0);
    }

    #[test]
    fn gap_lane_zero_duration() {
        let mut g = GapLane::new();
        assert_eq!(g.book(5.0, 0.0), (5.0, 5.0));
    }

    #[test]
    fn events_pop_in_time_then_insertion_order() {
        let mut q: EventQueue<&str> = EventQueue::new();
        q.schedule(2.0, "c");
        q.schedule(1.0, "a");
        q.schedule(1.0, "b");
        assert_eq!(q.pop().unwrap(), (1.0, "a"));
        assert_eq!(q.pop().unwrap(), (1.0, "b"));
        assert_eq!(q.now(), 1.0);
        assert_eq!(q.pop().unwrap(), (2.0, "c"));
        assert!(q.pop().is_none());
    }

    #[test]
    #[cfg_attr(not(debug_assertions), ignore)]
    #[should_panic(expected = "schedule into the past")]
    fn rejects_past_events() {
        let mut q: EventQueue<()> = EventQueue::new();
        q.schedule(5.0, ());
        q.pop();
        q.schedule(1.0, ());
    }
}
