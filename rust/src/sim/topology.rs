//! PCI-E interconnect model: links, DMA engines, P2P reachability.
//!
//! A multi-GPU node (paper Fig. 2) is host RAM + an I/O hub + PCI-E
//! switches with GPUs behind them. We model:
//!
//! - per-device duplex DMA engines (one H2D lane, one D2H lane) at the
//!   paper's measured 6.54 GB/s average (Table IV);
//! - one P2P lane per unordered device pair *behind the same switch* at
//!   7.8 GB/s (Table IV) — devices on different switches have no P2P
//!   path (Everest: only GPU2/GPU3 share a switch, Table V footnote);
//! - an aggregate host-link lane per direction modelling I/O-hub
//!   saturation when several GPUs pull simultaneously (what the paper
//!   calls "overloading the PCI-E" in cuBLAS-XT).
//!
//! Every transfer books its device DMA lane AND the shared host lane (or
//! the pair's P2P lane), so both serialization and hub contention emerge.

use super::clock::{GapLane, Lane, SimTime};

/// Direction of a host↔device transfer.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Dir {
    /// Host to device.
    H2D,
    /// Device to host.
    D2H,
}

/// Interconnect configuration.
#[derive(Clone, Debug)]
pub struct TopologyConfig {
    /// Host↔device bandwidth per device DMA engine, bytes/s.
    pub hd_bw: f64,
    /// GPU↔GPU P2P bandwidth, bytes/s.
    pub p2p_bw: f64,
    /// Aggregate host-link bandwidth per direction, bytes/s (I/O-hub
    /// ceiling shared by all devices).
    pub host_bw: f64,
    /// Per-transfer latency, seconds.
    pub latency: f64,
    /// Switch groups: devices in the same group can use P2P.
    pub switch_groups: Vec<Vec<usize>>,
    /// Number of devices.
    pub n_devices: usize,
}

impl TopologyConfig {
    /// Paper Table IV defaults for `n` devices with the given grouping.
    ///
    /// The hub is a backfilling (gap-filling) lane: future-dated stream
    /// reservations cannot phantom-block earlier-ready transfers, so the
    /// ceiling models genuine aggregate contention only.
    pub fn paper_defaults(n_devices: usize, switch_groups: Vec<Vec<usize>>) -> TopologyConfig {
        TopologyConfig {
            hd_bw: 6.54e9,
            p2p_bw: 7.8e9,
            // I/O-hub aggregate ceiling per direction: ~2 devices at
            // full DMA rate before contention (what cuBLAS-XT's
            // "overloads the PCI-E" runs into on 3 GPUs, §II).
            host_bw: 26.0e9,
            latency: 15e-6,
            switch_groups,
            n_devices,
        }
    }
}

/// The interconnect state: one lane per contended unit.
#[derive(Debug)]
pub struct Topology {
    pub cfg: TopologyConfig,
    h2d: Vec<Lane>,
    d2h: Vec<Lane>,
    host_up: GapLane,
    host_down: GapLane,
    /// Lane per *ordered* reachable pair (PCI-E P2P is full duplex:
    /// src→dst and dst→src move concurrently), keyed by (src, dst).
    p2p: std::collections::HashMap<(usize, usize), Lane>,
    // traffic accounting (Table IV / Table V): bytes moved per class
    pub h2d_bytes: Vec<u64>,
    pub d2h_bytes: Vec<u64>,
    pub p2p_bytes: Vec<u64>,
    // busy time of the two DMA directions per device (Table IV rates)
    pub h2d_busy: Vec<f64>,
    pub d2h_busy: Vec<f64>,
    pub p2p_busy: Vec<f64>,
}

impl Topology {
    pub fn new(cfg: TopologyConfig) -> Topology {
        let n = cfg.n_devices;
        let mut p2p = std::collections::HashMap::new();
        for g in &cfg.switch_groups {
            for &a in g {
                for &b in g {
                    if a != b {
                        p2p.insert((a, b), Lane::new());
                    }
                }
            }
        }
        Topology {
            cfg,
            h2d: (0..n).map(|_| Lane::new()).collect(),
            d2h: (0..n).map(|_| Lane::new()).collect(),
            host_up: GapLane::new(),
            host_down: GapLane::new(),
            p2p,
            h2d_bytes: vec![0; n],
            d2h_bytes: vec![0; n],
            p2p_bytes: vec![0; n],
            h2d_busy: vec![0.0; n],
            d2h_busy: vec![0.0; n],
            p2p_busy: vec![0.0; n],
        }
    }

    /// Devices sharing a switch with `dev` (its P2P peers).
    pub fn peers(&self, dev: usize) -> Vec<usize> {
        self.cfg
            .switch_groups
            .iter()
            .find(|g| g.contains(&dev))
            .map(|g| g.iter().copied().filter(|&d| d != dev).collect())
            .unwrap_or_default()
    }

    /// Can `a` and `b` talk over P2P?
    pub fn p2p_reachable(&self, a: usize, b: usize) -> bool {
        a != b && self.p2p.contains_key(&(a, b))
    }

    /// Book a host↔device transfer of `bytes`, ready at `ready`.
    /// Returns the completion time.
    pub fn book_hd(&mut self, dev: usize, dir: Dir, bytes: usize, ready: SimTime) -> SimTime {
        let dur = self.cfg.latency + bytes as f64 / self.cfg.hd_bw;
        let host_dur = bytes as f64 / self.cfg.host_bw;
        let (lane, host, bytes_acc, busy_acc) = match dir {
            Dir::H2D => (
                &mut self.h2d[dev],
                &mut self.host_down,
                &mut self.h2d_bytes[dev],
                &mut self.h2d_busy[dev],
            ),
            Dir::D2H => (
                &mut self.d2h[dev],
                &mut self.host_up,
                &mut self.d2h_bytes[dev],
                &mut self.d2h_busy[dev],
            ),
        };
        // Hub admission (aggregate I/O-hub ceiling): the backfilling
        // lane finds the earliest window of hub bandwidth at-or-after
        // the stream's ready time, so pre-booked schedules from other
        // devices never phantom-block earlier work.
        let admitted = if host_dur > 0.0 && host_dur.is_finite() {
            let (hub_start, _) = host.book(ready, host_dur);
            hub_start
        } else {
            ready
        };
        let (start, end) = lane.book(admitted, dur);
        *bytes_acc += bytes as u64;
        *busy_acc += end - start.min(end);
        end
    }

    /// Book a P2P transfer `src → dst`; panics if not reachable
    /// (callers must check `p2p_reachable`). Returns completion time.
    pub fn book_p2p(&mut self, src: usize, dst: usize, bytes: usize, ready: SimTime) -> SimTime {
        let key = (src, dst);
        let dur = self.cfg.latency + bytes as f64 / self.cfg.p2p_bw;
        let lane = self
            .p2p
            .get_mut(&key)
            .unwrap_or_else(|| panic!("no P2P path {src}->{dst}"));
        let (start, end) = lane.book(ready, dur);
        self.p2p_bytes[dst] += bytes as u64;
        self.p2p_busy[dst] += end - start;
        end
    }

    /// Earliest idle time of the H2D engine of `dev` (for estimates).
    pub fn h2d_free(&self, dev: usize) -> SimTime {
        self.h2d[dev].free_at()
    }

    /// Measured average throughput (bytes moved / lane busy seconds) for
    /// the H2D+D2H engines and the P2P engines — the paper's Table IV.
    pub fn measured_throughput(&self) -> (f64, f64) {
        let hd_bytes: u64 =
            self.h2d_bytes.iter().sum::<u64>() + self.d2h_bytes.iter().sum::<u64>();
        let hd_busy: f64 = self.h2d_busy.iter().sum::<f64>() + self.d2h_busy.iter().sum::<f64>();
        let pp_bytes: u64 = self.p2p_bytes.iter().sum();
        let pp_busy: f64 = self.p2p_busy.iter().sum();
        (
            if hd_busy > 0.0 { hd_bytes as f64 / hd_busy } else { 0.0 },
            if pp_busy > 0.0 { pp_bytes as f64 / pp_busy } else { 0.0 },
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn everest_topo() -> Topology {
        // 3 GPUs; only 1 and 2 share a switch (paper Table V footnote).
        { let mut cfg = TopologyConfig::paper_defaults(3, vec![vec![0], vec![1, 2]]); cfg.host_bw = 13.0e9; Topology::new(cfg) }
    }

    #[test]
    fn p2p_reachability_matches_everest() {
        let t = everest_topo();
        assert!(t.p2p_reachable(1, 2));
        assert!(t.p2p_reachable(2, 1));
        assert!(!t.p2p_reachable(0, 1));
        assert!(!t.p2p_reachable(0, 2));
        assert!(!t.p2p_reachable(1, 1));
        assert_eq!(t.peers(1), vec![2]);
        assert_eq!(t.peers(0), Vec::<usize>::new());
    }

    #[test]
    fn transfers_serialize_per_engine() {
        let mut t = everest_topo();
        let mb = 8 << 20;
        let e1 = t.book_hd(0, Dir::H2D, mb, 0.0);
        let e2 = t.book_hd(0, Dir::H2D, mb, 0.0); // same engine: queues
        assert!(e2 > e1);
        // different device, below hub ceiling: starts immediately
        let e3 = t.book_hd(1, Dir::H2D, mb, 0.0);
        assert!(e3 < e2);
        // opposite direction: independent engine
        let e4 = t.book_hd(0, Dir::D2H, mb, 0.0);
        assert!(e4 < e2);
    }

    #[test]
    fn hub_saturates_with_many_devices() {
        let mut t = everest_topo();
        let mb = 64 << 20;
        // all three devices pull at once: aggregate exceeds host_bw
        let ends: Vec<f64> = (0..3).map(|d| t.book_hd(d, Dir::H2D, mb, 0.0)).collect();
        let single = t.cfg.latency + mb as f64 / t.cfg.hd_bw;
        // the last to be admitted finishes later than a lone transfer
        assert!(ends.iter().cloned().fold(0.0, f64::max) > single * 1.2);
    }

    #[test]
    fn p2p_faster_than_hd_per_table4() {
        let mut t = everest_topo();
        let mb = 32 << 20;
        let hd = t.book_hd(1, Dir::H2D, mb, 0.0);
        let pp = t.book_p2p(1, 2, mb, 0.0);
        assert!(pp < hd, "P2P {pp} should beat H2D {hd}");
        let (hd_rate, pp_rate) = t.measured_throughput();
        assert!(hd_rate > 0.0 && pp_rate > hd_rate);
    }

    #[test]
    #[should_panic(expected = "no P2P path")]
    fn p2p_unreachable_panics() {
        let mut t = everest_topo();
        t.book_p2p(0, 1, 1024, 0.0);
    }

    #[test]
    fn traffic_accounting() {
        let mut t = everest_topo();
        t.book_hd(0, Dir::H2D, 1000, 0.0);
        t.book_hd(0, Dir::D2H, 500, 0.0);
        t.book_p2p(1, 2, 250, 0.0);
        assert_eq!(t.h2d_bytes[0], 1000);
        assert_eq!(t.d2h_bytes[0], 500);
        assert_eq!(t.p2p_bytes[2], 250);
        assert_eq!(t.p2p_bytes[1], 0);
    }
}
