//! # BLASX-RS
//!
//! A reproduction of *BLASX: A High Performance Level-3 BLAS Library for
//! Heterogeneous Multi-GPU Computing* (Wang, Wu, Xiao, Yang — 2015) as a
//! three-layer Rust + JAX + Pallas system:
//!
//! - **Layer 3 (this crate)** — the paper's contribution: a locality-aware
//!   dynamic scheduling runtime for tiled L3 BLAS with a two-level
//!   hierarchical tile cache (ALRU + MESI-X), demand-driven load
//!   balancing with work sharing/stealing, multi-stream
//!   communication/computation overlap, and a fast device-heap allocator.
//! - **Layer 2/1 (python/, build-time only)** — tile kernels written in
//!   Pallas inside JAX update graphs, AOT-lowered to HLO text and
//!   executed from Rust through PJRT.
//!
//! GPUs/PCI-E are simulated (see `sim`); numerics are real. See DESIGN.md
//! for the full system inventory and experiment index.
//!
//! ## Persistent device runtime
//!
//! The engine is resident by default: [`api::Context`] lazily boots a
//! long-lived [`runtime::Runtime`] — per-device worker threads parked
//! on condvars, device arenas, and the ALRU/MESI-X tile caches — and
//! every call submits its task set to that warm fleet instead of
//! rebuilding the world. Consecutive calls touching the same host
//! matrices get L1/L2 tile-cache hits instead of re-transfers (a
//! second identical `dgemm` moves zero host bytes for unchanged
//! operands), and `gemm_mt` fans tile kernels across a persistent
//! [`runtime::KernelPool`] whose thread-local pack scratch survives
//! between calls. Coherence across calls is epoch-based: outputs bump
//! an invalidation generation for their byte range automatically;
//! mutated *input* buffers must be declared via
//! [`api::Context::invalidate_host`]. See `runtime::service` for the
//! full lifecycle (boot, warm calls, invalidation, shutdown) and
//! `tests/persistent_runtime.rs` for the cross-call guarantees.
//!
//! ## Batched execution
//!
//! The per-call runtime shines on one large problem; serving workloads
//! are the opposite regime — hundreds of small/irregular GEMMs whose
//! tile grids cannot fill the device set alone. The [`batch`] subsystem
//! turns the same runtime into a throughput engine:
//!
//! - [`api::l3::gemm_batched`] / [`api::l3::gemm_batched_strided`]
//!   (`dgemm_batched`, `sgemm_batched`, … aliases) accept uniform or
//!   variable-size batches, pointer-array or cuBLAS-style strided;
//! - every problem is taskized by the existing per-routine taskizers
//!   and *fused* into one `TaskSet`, with tasks and tile references
//!   tagged by a problem index — the ALRU cache and MESI-X coherence
//!   layers work unchanged because the batch is just a larger key
//!   space (operands shared across problems even share cache entries,
//!   since tiles are keyed by host address);
//! - a work-centric splitter (Stream-K flavour, [`batch::quanta`])
//!   emits the fused ready set in flop-balanced, problem-interleaved
//!   *scheduling quanta*, so the demand-driven stations stay saturated
//!   even when single problems are smaller than one device's streams.
//!
//! Prefer the batch entry points over looping single calls whenever
//! problems are small relative to the machine (≲ a few tiles per
//! device) or numerous; numerics are bit-for-bit identical to the
//! looped single-call reference on the same backend. See
//! `benches/batch_throughput.rs` for the throughput comparison and
//! `examples/batched_inference.rs` for an ANN-serving walkthrough.
//!
//! ## Serving mode (multi-tenant scheduling)
//!
//! Batching fuses problems the caller already holds in one hand;
//! *serving* is the case where independent clients issue calls
//! concurrently. The resident runtime schedules every in-flight call
//! as a first-class job (the [`serve`] subsystem): admission computes
//! byte-range conflict edges (aliasing calls run in submission order,
//! bit-for-bit equal to serial; disjoint calls overlap on the
//! devices), the device workers interleave scheduler rounds across all
//! runnable jobs under flop-weighted fairness, and non-blocking
//! submission goes through the closure-scoped API
//! ([`api::Context::scope`]): jobs issued inside a scope return
//! [`serve::JobHandle`]s, operand ranges may alias *across* jobs (the
//! admission edges order them), and the scope's close — a barrier in a
//! stack frame the caller cannot skip, `std::thread::scope`-style — is
//! what makes the API sound (`mem::forget` on a handle is harmless).
//! `tests/serve_concurrent.rs` and `tests/scope_async.rs` hold the
//! concurrency guarantees; `benches/serve_throughput.rs` measures
//! jobs/sec and worker-idle fraction versus client count; `blasx serve
//! --clients N` is the CLI stress mode.
//!
//! ## C ABI (drop-in replacement)
//!
//! The [`ffi`] module exports a cblas-compatible C surface —
//! `cblas_{s,d}{gemm,syrk,syr2k,symm,trmm,trsm}` plus non-blocking
//! `blasx_{s,d}gemm_async` / `blasx_{s,d}trsm_async` with
//! `blasx_wait` — over a process-global default [`api::Context`], so a
//! C (or `ctypes`, or legacy Fortran-through-CBLAS) application links
//! against `libblasx` unchanged and lands on the same multi-tenant
//! resident runtime (the paper's §I drop-in story). The header is
//! generated offline (`blasx header` → `include/blasx.h`); see
//! `examples/c/smoke.c` and `examples/python/blasx_ctypes.py`, and the
//! README's "C ABI / drop-in use" section for linkage and the
//! host-liveness contract.

pub mod api;
pub mod baselines;
pub mod batch;
pub mod bench;
pub mod cache;
pub mod cli;
pub mod coordinator;
pub mod dispatch;
pub mod error;
pub mod fault;
pub mod ffi;
pub mod hostblas;
pub mod mem;
pub mod queue;
pub mod runtime;
pub mod serve;
pub mod sim;
pub mod sched;
pub mod task;
pub mod trace;
pub mod tile;
pub mod util;

pub use error::{Error, Result};
