//! # BLASX-RS
//!
//! A reproduction of *BLASX: A High Performance Level-3 BLAS Library for
//! Heterogeneous Multi-GPU Computing* (Wang, Wu, Xiao, Yang — 2015) as a
//! three-layer Rust + JAX + Pallas system:
//!
//! - **Layer 3 (this crate)** — the paper's contribution: a locality-aware
//!   dynamic scheduling runtime for tiled L3 BLAS with a two-level
//!   hierarchical tile cache (ALRU + MESI-X), demand-driven load
//!   balancing with work sharing/stealing, multi-stream
//!   communication/computation overlap, and a fast device-heap allocator.
//! - **Layer 2/1 (python/, build-time only)** — tile kernels written in
//!   Pallas inside JAX update graphs, AOT-lowered to HLO text and
//!   executed from Rust through PJRT.
//!
//! GPUs/PCI-E are simulated (see `sim`); numerics are real. See DESIGN.md
//! for the full system inventory and experiment index.

pub mod api;
pub mod baselines;
pub mod bench;
pub mod cache;
pub mod cli;
pub mod coordinator;
pub mod error;
pub mod hostblas;
pub mod mem;
pub mod queue;
pub mod runtime;
pub mod sim;
pub mod sched;
pub mod task;
pub mod trace;
pub mod tile;
pub mod util;

pub use error::{Error, Result};
