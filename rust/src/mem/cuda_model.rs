//! Latency model of `cudaMalloc`/`cudaFree` for the Fig. 5 ablation.
//!
//! The paper's Fig. 5 shows DGEMM GFLOPS degrading with matrix size when
//! tiles are allocated with CUDA's native utilities: each call costs
//! hundreds of microseconds and `cudaFree` implicitly synchronizes the
//! device, stalling otherwise-overlapped streams. We model both effects
//! so the simulator can run the "naive allocator" baseline; the numbers
//! are calibrated against published microbenchmarks of the K40-era
//! driver (cudaMalloc ≈ 0.2–1 ms depending on size; cudaFree ≈ 0.1 ms +
//! sync).

use super::fast_heap::{FastHeap, Offset};

/// Allocation timing model. Times are virtual seconds.
#[derive(Clone, Copy, Debug)]
pub struct CudaMallocModel {
    /// Fixed per-call driver overhead of cudaMalloc.
    pub malloc_base_s: f64,
    /// Size-dependent component (per byte) — page-table setup.
    pub malloc_per_byte_s: f64,
    /// Fixed per-call overhead of cudaFree.
    pub free_base_s: f64,
    /// Does free imply a device-wide synchronization (it does)?
    pub free_syncs: bool,
    /// Fragmentation growth: the driver's free-list walk lengthens as
    /// the heap churns; each prior alloc adds this fraction of the base
    /// cost (what bends the paper's Fig. 5 curve downward with N).
    pub frag_per_alloc: f64,
}

impl Default for CudaMallocModel {
    fn default() -> Self {
        CudaMallocModel {
            malloc_base_s: 220e-6,
            malloc_per_byte_s: 25e-12, // ~0.2 ms extra for an 8 MB tile
            free_base_s: 110e-6,
            free_syncs: true,
            frag_per_alloc: 1.2e-3,
        }
    }
}

impl CudaMallocModel {
    /// Virtual cost of one cudaMalloc of `len` bytes.
    pub fn malloc_cost(&self, len: usize) -> f64 {
        self.malloc_base_s + self.malloc_per_byte_s * len as f64
    }

    /// Virtual cost of one cudaFree.
    pub fn free_cost(&self) -> f64 {
        self.free_base_s
    }
}

/// Device allocator strategy selector (the Fig. 5 A/B sides).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AllocStrategy {
    /// The paper's FastHeap: preallocated chunk, ~zero per-call cost.
    FastHeap,
    /// cudaMalloc/cudaFree per tile with the latency model above.
    CudaNative,
}

/// A device allocator: a `FastHeap` for space accounting in both modes,
/// plus a virtual-time cost per operation dependent on the strategy.
pub struct DeviceAllocator {
    pub heap: FastHeap,
    pub strategy: AllocStrategy,
    pub model: CudaMallocModel,
    /// accumulated virtual seconds spent in allocation calls
    pub alloc_time_s: f64,
    /// number of implicit syncs incurred (CudaNative frees)
    pub syncs: u64,
    /// lifetime allocation count (fragmentation model input)
    pub n_allocs: u64,
    /// fault-injection hook: this many upcoming allocation requests are
    /// refused before the heap is even consulted (arena-OOM chaos).
    forced_failures: u64,
}

impl DeviceAllocator {
    pub fn new(capacity: usize, strategy: AllocStrategy) -> DeviceAllocator {
        DeviceAllocator {
            heap: FastHeap::new(capacity),
            strategy,
            model: CudaMallocModel::default(),
            alloc_time_s: 0.0,
            syncs: 0,
            n_allocs: 0,
            forced_failures: 0,
        }
    }

    /// Arm the arena-OOM injection hook: the next `n` allocation
    /// requests are refused as if the heap were exhausted (consumed by
    /// [`DeviceAllocator::take_forced_failure`] at the request level,
    /// so one forced failure fails one whole insert, not one heap
    /// probe of the eviction loop).
    pub fn force_fail(&mut self, n: u64) {
        self.forced_failures = self.forced_failures.saturating_add(n);
    }

    /// Consume one forced failure if armed. Callers check this once
    /// per allocation *request* before touching the heap.
    pub fn take_forced_failure(&mut self) -> bool {
        if self.forced_failures > 0 {
            self.forced_failures -= 1;
            true
        } else {
            false
        }
    }

    /// Allocate; returns (offset, virtual cost of the call).
    pub fn alloc(&mut self, len: usize) -> Option<(Offset, f64)> {
        let off = self.heap.alloc(len)?;
        let cost = match self.strategy {
            AllocStrategy::FastHeap => 0.0, // sub-µs list ops; negligible
            AllocStrategy::CudaNative => {
                self.n_allocs += 1;
                self.model.malloc_cost(len)
                    * (1.0 + self.model.frag_per_alloc * self.n_allocs as f64)
            }
        };
        self.alloc_time_s += cost;
        Some((off, cost))
    }

    /// Free; returns (virtual cost, whether this forces a device sync).
    pub fn free(&mut self, off: Offset) -> (f64, bool) {
        self.heap.free(off);
        match self.strategy {
            AllocStrategy::FastHeap => (0.0, false),
            AllocStrategy::CudaNative => {
                self.syncs += u64::from(self.model.free_syncs);
                self.alloc_time_s += self.model.free_cost();
                (self.model.free_cost(), self.model.free_syncs)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn model_costs_scale_with_size() {
        let m = CudaMallocModel::default();
        let small = m.malloc_cost(1024);
        let tile = m.malloc_cost(8 * 1024 * 1024); // 1024² f64 tile
        assert!(tile > small);
        assert!(tile > 300e-6 && tile < 1e-3, "tile malloc ~{tile}");
    }

    #[test]
    fn fastheap_strategy_is_free_of_cost() {
        let mut d = DeviceAllocator::new(1 << 20, AllocStrategy::FastHeap);
        let (off, cost) = d.alloc(4096).unwrap();
        assert_eq!(cost, 0.0);
        let (fcost, sync) = d.free(off);
        assert_eq!(fcost, 0.0);
        assert!(!sync);
        assert_eq!(d.alloc_time_s, 0.0);
    }

    #[test]
    fn forced_failures_arm_and_drain() {
        let mut d = DeviceAllocator::new(1 << 20, AllocStrategy::FastHeap);
        assert!(!d.take_forced_failure());
        d.force_fail(2);
        assert!(d.take_forced_failure());
        assert!(d.take_forced_failure());
        assert!(!d.take_forced_failure(), "hook drains after n requests");
        // the heap itself is untouched by the hook
        assert!(d.alloc(4096).is_some());
    }

    #[test]
    fn cuda_strategy_accumulates_time_and_syncs() {
        let mut d = DeviceAllocator::new(1 << 20, AllocStrategy::CudaNative);
        let (off, cost) = d.alloc(4096).unwrap();
        assert!(cost > 0.0);
        let (_, sync) = d.free(off);
        assert!(sync);
        assert_eq!(d.syncs, 1);
        assert!(d.alloc_time_s > cost);
    }
}
