//! Device memory management (system S4, paper §IV-E).
//!
//! [`fast_heap::FastHeap`] is the paper's `BLASX_Malloc` (Fig. 6);
//! [`cuda_model`] provides the cudaMalloc/cudaFree latency model used by
//! the Fig. 5 ablation and the `DeviceAllocator` wrapper that the cache
//! layer allocates tile blocks from.

pub mod cuda_model;
pub mod fast_heap;

pub use cuda_model::{AllocStrategy, CudaMallocModel, DeviceAllocator};
pub use fast_heap::{FastHeap, HeapStats, Offset};
