//! FastHeap — the paper's `BLASX_Malloc` (§IV-E, Fig. 6).
//!
//! GPU tile traffic implies high-frequency allocation/deallocation;
//! `cudaMalloc`/`cudaFree` carry per-call overhead (and an implicit
//! device sync) that degrades GFLOPS as the problem grows (paper Fig. 5).
//! BLASX instead carves allocations out of one preallocated chunk:
//!
//! - a *segment list* (the paper's "meta-data list") ordered by offset,
//!   each node tracking `{offset, len, occupied}`;
//! - an *empty list* of free segments searched first-fit and split on
//!   allocation;
//! - an *occupied table* (hashtable, offset → node) so deallocation is
//!   O(1) lookup; freed nodes merge with contiguous free neighbours.
//!
//! The heap manages *offsets* into an abstract capacity: in real mode the
//! offsets index a host-backed device arena; in sim mode they track
//! virtual GPU RAM occupancy without touching memory. That is what lets
//! the same ALRU/coherence machinery run in both modes.

use std::collections::HashMap;

/// Allocation handle: offset into the device arena.
pub type Offset = usize;

#[derive(Clone, Copy, Debug)]
struct Segment {
    offset: usize,
    len: usize,
    occupied: bool,
    /// doubly-linked by index into `segs` (usize::MAX = none)
    prev: usize,
    next: usize,
}

const NONE: usize = usize::MAX;

/// Allocation statistics (also feed the Fig. 5 bench).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct HeapStats {
    pub allocs: u64,
    pub frees: u64,
    pub splits: u64,
    pub merges: u64,
    pub failed: u64,
    pub bytes_in_use: usize,
    pub high_water: usize,
}

/// First-fit heap with neighbour coalescing over a fixed capacity.
pub struct FastHeap {
    capacity: usize,
    segs: Vec<Segment>,
    /// free-slot recycling for `segs`
    free_slots: Vec<usize>,
    /// head of the segment list (offset order)
    head: usize,
    /// occupied table: offset -> segment index
    occupied: HashMap<usize, usize>,
    stats: HeapStats,
}

impl FastHeap {
    /// Create a heap over `capacity` bytes.
    pub fn new(capacity: usize) -> FastHeap {
        let root = Segment { offset: 0, len: capacity, occupied: false, prev: NONE, next: NONE };
        FastHeap {
            capacity,
            segs: vec![root],
            free_slots: Vec::new(),
            head: 0,
            occupied: HashMap::new(),
            stats: HeapStats::default(),
        }
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    pub fn stats(&self) -> HeapStats {
        self.stats
    }

    /// Bytes currently allocated.
    pub fn in_use(&self) -> usize {
        self.stats.bytes_in_use
    }

    /// Largest single free segment (for OOM diagnostics).
    pub fn largest_free(&self) -> usize {
        let mut best = 0;
        let mut cur = self.head;
        while cur != NONE {
            let s = self.segs[cur];
            if !s.occupied {
                best = best.max(s.len);
            }
            cur = s.next;
        }
        best
    }

    fn new_seg(&mut self, seg: Segment) -> usize {
        if let Some(idx) = self.free_slots.pop() {
            self.segs[idx] = seg;
            idx
        } else {
            self.segs.push(seg);
            self.segs.len() - 1
        }
    }

    /// Allocate `len` bytes; first-fit over the empty list, splitting the
    /// chosen segment (paper Fig. 6 "split into two nodes").
    pub fn alloc(&mut self, len: usize) -> Option<Offset> {
        assert!(len > 0, "zero-size allocation");
        let mut cur = self.head;
        while cur != NONE {
            let s = self.segs[cur];
            if !s.occupied && s.len >= len {
                // split if there is residue
                if s.len > len {
                    let rest = Segment {
                        offset: s.offset + len,
                        len: s.len - len,
                        occupied: false,
                        prev: cur,
                        next: s.next,
                    };
                    let rest_idx = self.new_seg(rest);
                    if s.next != NONE {
                        self.segs[s.next].prev = rest_idx;
                    }
                    self.segs[cur].next = rest_idx;
                    self.segs[cur].len = len;
                    self.stats.splits += 1;
                }
                self.segs[cur].occupied = true;
                self.occupied.insert(s.offset, cur);
                self.stats.allocs += 1;
                self.stats.bytes_in_use += len;
                self.stats.high_water = self.stats.high_water.max(self.stats.bytes_in_use);
                return Some(s.offset);
            }
            cur = s.next;
        }
        self.stats.failed += 1;
        None
    }

    /// Free the allocation at `offset`; merges with free neighbours
    /// (paper Fig. 6 "if either the node's left or right neighbors are
    /// contiguous … they merge together").
    ///
    /// Panics on double-free / unknown offset (an internal invariant —
    /// the cache is the only caller).
    pub fn free(&mut self, offset: Offset) {
        let idx = self
            .occupied
            .remove(&offset)
            .unwrap_or_else(|| panic!("free of unallocated offset {offset}"));
        let len = self.segs[idx].len;
        debug_assert!(self.segs[idx].occupied);
        self.segs[idx].occupied = false;
        self.stats.frees += 1;
        self.stats.bytes_in_use -= len;

        // merge with next if free
        let next = self.segs[idx].next;
        if next != NONE && !self.segs[next].occupied {
            let nlen = self.segs[next].len;
            let nnext = self.segs[next].next;
            self.segs[idx].len += nlen;
            self.segs[idx].next = nnext;
            if nnext != NONE {
                self.segs[nnext].prev = idx;
            }
            self.free_slots.push(next);
            self.stats.merges += 1;
        }
        // merge with prev if free
        let prev = self.segs[idx].prev;
        if prev != NONE && !self.segs[prev].occupied {
            let ilen = self.segs[idx].len;
            let inext = self.segs[idx].next;
            self.segs[prev].len += ilen;
            self.segs[prev].next = inext;
            if inext != NONE {
                self.segs[inext].prev = prev;
            }
            self.free_slots.push(idx);
            self.stats.merges += 1;
        }
    }

    /// Internal consistency check (tests + debug assertions): the
    /// segment list tiles `[0, capacity)` exactly, free neighbours are
    /// coalesced, and the occupied table matches the list.
    pub fn validate(&self) -> Result<(), String> {
        let mut cur = self.head;
        let mut expect_offset = 0usize;
        let mut prev = NONE;
        let mut occupied_seen = 0usize;
        let mut last_free = false;
        while cur != NONE {
            let s = self.segs[cur];
            if s.offset != expect_offset {
                return Err(format!("gap/overlap at offset {expect_offset} (seg says {})", s.offset));
            }
            if s.prev != prev {
                return Err(format!("bad prev link at {}", s.offset));
            }
            if s.len == 0 {
                return Err(format!("zero-length segment at {}", s.offset));
            }
            if s.occupied {
                occupied_seen += 1;
                if self.occupied.get(&s.offset) != Some(&cur) {
                    return Err(format!("occupied table missing {}", s.offset));
                }
                last_free = false;
            } else {
                if last_free {
                    return Err(format!("uncoalesced free neighbours before {}", s.offset));
                }
                last_free = true;
            }
            expect_offset += s.len;
            prev = cur;
            cur = s.next;
        }
        if expect_offset != self.capacity {
            return Err(format!("list covers {expect_offset} of {}", self.capacity));
        }
        if occupied_seen != self.occupied.len() {
            return Err("occupied table size mismatch".into());
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Prng;

    #[test]
    fn alloc_free_roundtrip() {
        let mut h = FastHeap::new(1024);
        let a = h.alloc(100).unwrap();
        let b = h.alloc(200).unwrap();
        assert_ne!(a, b);
        assert_eq!(h.in_use(), 300);
        h.validate().unwrap();
        h.free(a);
        h.free(b);
        assert_eq!(h.in_use(), 0);
        assert_eq!(h.largest_free(), 1024); // fully coalesced
        h.validate().unwrap();
    }

    #[test]
    fn exhausts_then_fails_then_recovers() {
        let mut h = FastHeap::new(100);
        let a = h.alloc(60).unwrap();
        assert!(h.alloc(50).is_none());
        assert_eq!(h.stats().failed, 1);
        h.free(a);
        assert!(h.alloc(100).is_some());
        h.validate().unwrap();
    }

    #[test]
    fn first_fit_reuses_hole() {
        let mut h = FastHeap::new(1000);
        let a = h.alloc(100).unwrap();
        let _b = h.alloc(100).unwrap();
        h.free(a);
        // a's hole is first-fit for a smaller block
        let c = h.alloc(50).unwrap();
        assert_eq!(c, a);
        h.validate().unwrap();
    }

    #[test]
    fn merge_three_way() {
        let mut h = FastHeap::new(300);
        let a = h.alloc(100).unwrap();
        let b = h.alloc(100).unwrap();
        let c = h.alloc(100).unwrap();
        h.free(a);
        h.free(c);
        h.free(b); // merges with both neighbours
        assert_eq!(h.largest_free(), 300);
        assert!(h.stats().merges >= 2);
        h.validate().unwrap();
    }

    #[test]
    #[should_panic(expected = "free of unallocated")]
    fn double_free_panics() {
        let mut h = FastHeap::new(100);
        let a = h.alloc(10).unwrap();
        h.free(a);
        h.free(a);
    }

    #[test]
    fn stress_random_alloc_free_preserves_invariants() {
        let mut rng = Prng::new(42);
        let mut h = FastHeap::new(1 << 20);
        let mut live: Vec<(Offset, usize)> = Vec::new();
        for step in 0..5000 {
            if live.is_empty() || rng.chance(0.6) {
                let len = rng.range(1, 8192);
                if let Some(off) = h.alloc(len) {
                    // no overlap with any live allocation
                    for &(o, l) in &live {
                        assert!(off + len <= o || o + l <= off, "overlap at step {step}");
                    }
                    live.push((off, len));
                }
            } else {
                let i = rng.below(live.len());
                let (off, _) = live.swap_remove(i);
                h.free(off);
            }
            if step % 512 == 0 {
                h.validate().unwrap();
            }
        }
        let total: usize = live.iter().map(|&(_, l)| l).sum();
        assert_eq!(h.in_use(), total);
        for (off, _) in live.drain(..) {
            h.free(off);
        }
        assert_eq!(h.in_use(), 0);
        assert_eq!(h.largest_free(), 1 << 20);
        h.validate().unwrap();
    }

    #[test]
    fn high_water_tracks_peak() {
        let mut h = FastHeap::new(1000);
        let a = h.alloc(400).unwrap();
        let b = h.alloc(300).unwrap();
        h.free(a);
        h.free(b);
        assert_eq!(h.stats().high_water, 700);
    }
}
