//! MESI-X coherence directory (paper §IV-B, Fig. 3).
//!
//! The per-device ALRUs "all together reflect tile states": a tile is
//! **E** (exclusive) when exactly one ALRU tracks it, **S** (shared) when
//! several do, **I** (invalid) when none does, and **M** (modified) only
//! ephemerally — a device that writes a C tile writes it straight back to
//! host RAM and the tile transitions M → I immediately.
//!
//! The directory is the global bookkeeping that makes those states
//! queryable without scanning every cache: for each tile key it records
//! the holder set. It is also where the Fig. 3 transitions live:
//!
//! - read miss, no holders      ⇒ fetch from host,  I → E
//! - read miss, holders exist   ⇒ fetch from a peer (P2P) if reachable,
//!                                 else host; state → S
//! - write-back (M, ephemeral)  ⇒ data to host; ALL holders invalidate;
//!                                 state → I

use crate::tile::TileKey;
use std::collections::HashMap;

/// Observable MESI-X state of a tile (M is never observable at rest —
/// it collapses to I within `write_back`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TileState {
    Invalid,
    Exclusive(usize),
    /// Shared by ≥ 2 devices (holder count tracked in the directory).
    Shared,
}

/// Directory entry: which devices hold a valid copy.
#[derive(Clone, Debug, Default)]
struct Entry {
    holders: Vec<usize>,
}

/// The coherence directory across `n_devices` caches.
pub struct Directory {
    n_devices: usize,
    entries: HashMap<TileKey, Entry>,
    // stats
    pub to_exclusive: u64,
    pub to_shared: u64,
    pub invalidations: u64,
}

impl Directory {
    pub fn new(n_devices: usize) -> Directory {
        Directory {
            n_devices,
            entries: HashMap::new(),
            to_exclusive: 0,
            to_shared: 0,
            invalidations: 0,
        }
    }

    pub fn n_devices(&self) -> usize {
        self.n_devices
    }

    /// Current observable state of a tile.
    pub fn state(&self, key: &TileKey) -> TileState {
        match self.entries.get(key) {
            None => TileState::Invalid,
            Some(e) => match e.holders.len() {
                0 => TileState::Invalid,
                1 => TileState::Exclusive(e.holders[0]),
                _ => TileState::Shared,
            },
        }
    }

    /// All devices currently holding a valid copy.
    pub fn holders(&self, key: &TileKey) -> &[usize] {
        self.entries.get(key).map(|e| e.holders.as_slice()).unwrap_or(&[])
    }

    /// Record that `dev` gained a valid copy (after its fetch completes).
    /// Returns the resulting state.
    pub fn add_holder(&mut self, key: TileKey, dev: usize) -> TileState {
        debug_assert!(dev < self.n_devices);
        let e = self.entries.entry(key).or_default();
        if !e.holders.contains(&dev) {
            e.holders.push(dev);
        }
        match e.holders.len() {
            1 => {
                self.to_exclusive += 1;
                TileState::Exclusive(dev)
            }
            _ => {
                self.to_shared += 1;
                TileState::Shared
            }
        }
    }

    /// Record that `dev` lost its copy (ALRU eviction). E → I or S → E/S.
    pub fn drop_holder(&mut self, key: &TileKey, dev: usize) {
        if let Some(e) = self.entries.get_mut(key) {
            e.holders.retain(|&d| d != dev);
            if e.holders.is_empty() {
                self.entries.remove(key);
            }
        }
    }

    /// Surgical device-loss invalidation: remove `dev` from every
    /// holder set (a faulted device's copies are gone, but peer
    /// replicas on survivors — and the host master copies — stay
    /// valid). Returns how many tiles lost a holder.
    pub fn drop_device(&mut self, dev: usize) -> usize {
        let mut dropped = 0;
        self.entries.retain(|_, e| {
            let before = e.holders.len();
            e.holders.retain(|&d| d != dev);
            if e.holders.len() < before {
                dropped += 1;
                self.invalidations += 1;
            }
            !e.holders.is_empty()
        });
        dropped
    }

    /// The M-state write-back: returns the holder set that must be
    /// invalidated (the caller invalidates each ALRU and writes the data
    /// to host); directory entry is removed (→ I).
    pub fn write_back(&mut self, key: &TileKey) -> Vec<usize> {
        let holders = self.entries.remove(key).map(|e| e.holders).unwrap_or_default();
        self.invalidations += holders.len() as u64;
        holders
    }

    /// Pick a P2P source for `dev` among current holders restricted to
    /// `peers` (devices reachable over the same PCI-E switch). Prefers
    /// the first reachable holder.
    pub fn peer_source(&self, key: &TileKey, dev: usize, peers: &[usize]) -> Option<usize> {
        let e = self.entries.get(key)?;
        e.holders.iter().copied().find(|h| *h != dev && peers.contains(h))
    }

    /// Number of tracked (non-invalid) tiles.
    pub fn tracked(&self) -> usize {
        self.entries.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tile::MatId;

    fn key(addr: usize) -> TileKey {
        TileKey::synthetic(addr, MatId::B, 0, addr)
    }

    #[test]
    fn i_to_e_to_s_transitions() {
        let mut d = Directory::new(3);
        assert_eq!(d.state(&key(1)), TileState::Invalid);
        assert_eq!(d.add_holder(key(1), 0), TileState::Exclusive(0));
        assert_eq!(d.state(&key(1)), TileState::Exclusive(0));
        assert_eq!(d.add_holder(key(1), 2), TileState::Shared);
        assert_eq!(d.state(&key(1)), TileState::Shared);
        assert_eq!(d.holders(&key(1)), &[0, 2]);
    }

    #[test]
    fn drop_holder_degrades_state() {
        let mut d = Directory::new(3);
        d.add_holder(key(1), 0);
        d.add_holder(key(1), 1);
        d.drop_holder(&key(1), 0);
        assert_eq!(d.state(&key(1)), TileState::Exclusive(1));
        d.drop_holder(&key(1), 1);
        assert_eq!(d.state(&key(1)), TileState::Invalid);
        assert_eq!(d.tracked(), 0);
    }

    #[test]
    fn write_back_invalidates_all_holders() {
        let mut d = Directory::new(4);
        d.add_holder(key(7), 1);
        d.add_holder(key(7), 2);
        d.add_holder(key(7), 3);
        let holders = d.write_back(&key(7));
        assert_eq!(holders, vec![1, 2, 3]);
        assert_eq!(d.state(&key(7)), TileState::Invalid);
        assert_eq!(d.invalidations, 3);
        // idempotent on absent key
        assert!(d.write_back(&key(7)).is_empty());
    }

    #[test]
    fn peer_source_respects_topology() {
        let mut d = Directory::new(4);
        d.add_holder(key(1), 0);
        d.add_holder(key(1), 3);
        // dev 1's peers are {0}: finds 0
        assert_eq!(d.peer_source(&key(1), 1, &[0]), Some(0));
        // dev 2's peers are {3}: finds 3
        assert_eq!(d.peer_source(&key(1), 2, &[3]), Some(3));
        // dev 2 with no reachable holders
        assert_eq!(d.peer_source(&key(1), 2, &[1]), None);
        // self is never a source
        assert_eq!(d.peer_source(&key(1), 0, &[0]), None);
    }

    #[test]
    fn drop_device_spares_peer_replicas() {
        let mut d = Directory::new(3);
        d.add_holder(key(1), 0); // exclusive to the dying device
        d.add_holder(key(2), 0); // shared with a survivor
        d.add_holder(key(2), 2);
        d.add_holder(key(3), 1); // untouched device
        assert_eq!(d.drop_device(0), 2);
        assert_eq!(d.state(&key(1)), TileState::Invalid);
        assert_eq!(d.state(&key(2)), TileState::Exclusive(2), "peer replica survives");
        assert_eq!(d.state(&key(3)), TileState::Exclusive(1));
        assert_eq!(d.tracked(), 2);
        // idempotent
        assert_eq!(d.drop_device(0), 0);
    }

    #[test]
    fn add_holder_idempotent() {
        let mut d = Directory::new(2);
        d.add_holder(key(1), 0);
        d.add_holder(key(1), 0);
        assert_eq!(d.holders(&key(1)), &[0]);
        assert_eq!(d.state(&key(1)), TileState::Exclusive(0));
    }
}
