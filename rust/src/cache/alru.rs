//! Approximate-LRU tile cache for one device (paper Alg. 2).
//!
//! The vanilla LRU cannot accommodate BLASX's asynchronous kernel
//! launches: a tile may still be referenced by an in-flight stream when
//! it reaches the LRU tail, and reader counts are only refreshed at
//! stream-sync points (Alg. 1 line 17). The ALRU therefore evicts the
//! first *zero-reader* block scanning from the tail — the "approximate"
//! least-recently-used victim.
//!
//! Extension beyond the paper (required for TRMM/TRSM correctness with
//! the MESI-X write-invalidate): `invalidate` marks a block *doomed* if
//! it still has readers; a doomed block is unreachable for new lookups
//! and its memory is reclaimed when the last reader releases it.

use crate::mem::{DeviceAllocator, Offset};
use crate::tile::TileKey;
use std::collections::HashMap;
use std::sync::{Arc, Condvar, Mutex};

/// Fill progress of a reserved cache block.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum FillState {
    /// Reserved under the cache lock; the filler is copying bytes in
    /// *without* the lock. The block is pinned (readers ≥ 1) so it can
    /// never be evicted mid-fill.
    Pending,
    /// Bytes landed and the block was latched ready; contents are
    /// immutable until the block is freed.
    Ready,
    /// The fill was abandoned (transfer fault exhausted its retries, or
    /// the block was invalidated mid-fill). Waiters must re-acquire.
    Aborted,
}

/// The latch a reserved block carries while its bytes are in flight.
///
/// The filler reserves the block under the global cache lock, **drops
/// the lock**, performs the copy, then calls [`FillLatch::complete`].
/// Concurrent acquirers of the same key pin the block under the lock,
/// drop it, and block on [`FillLatch::wait`] — so a slow H2D read or
/// peer memcpy never stalls unrelated cache traffic.
#[derive(Debug)]
pub struct FillLatch {
    state: Mutex<FillState>,
    cv: Condvar,
}

impl FillLatch {
    pub fn new() -> Arc<FillLatch> {
        Arc::new(FillLatch { state: Mutex::new(FillState::Pending), cv: Condvar::new() })
    }

    /// Latch the fill finished: `ok` = the bytes are valid and the block
    /// is live; `!ok` = waiters must drop their pins and retry.
    pub fn complete(&self, ok: bool) {
        let mut st = self.state.lock().unwrap_or_else(|e| e.into_inner());
        *st = if ok { FillState::Ready } else { FillState::Aborted };
        self.cv.notify_all();
    }

    /// Block until the fill completes. Returns true if the block's
    /// bytes are valid (Ready), false if the fill was aborted.
    pub fn wait(&self) -> bool {
        let mut st = self.state.lock().unwrap_or_else(|e| e.into_inner());
        while *st == FillState::Pending {
            st = self.cv.wait(st).unwrap_or_else(|e| e.into_inner());
        }
        *st == FillState::Ready
    }

    /// Non-blocking probe (tests / prefetch-skip heuristics).
    pub fn is_ready(&self) -> bool {
        *self.state.lock().unwrap_or_else(|e| e.into_inner()) == FillState::Ready
    }
}

/// A cache block: one tile resident in device memory.
#[derive(Clone, Debug)]
pub struct LruBlock {
    pub key: TileKey,
    /// Device-arena offset (the paper's "GA").
    pub offset: Offset,
    pub len: usize,
    /// In-flight references; only mutated at sync points (approximate).
    pub readers: u32,
    /// Invalidated while readers > 0: free on last release.
    pub doomed: bool,
    /// `Some` while the block's bytes are being filled off-lock; the
    /// latch lets same-key acquirers wait for the copy instead of the
    /// global mutex. Cleared (→ ready) by [`Alru::take_pending`].
    pub pending: Option<Arc<FillLatch>>,
    // intrusive LRU list (indices into `blocks`, NONE = none)
    prev: usize,
    next: usize,
}

const NONE: usize = usize::MAX;

/// Per-device ALRU over a [`DeviceAllocator`].
pub struct Alru {
    /// hashmap HA -> block index (paper Alg. 2 line 2)
    map: HashMap<TileKey, usize>,
    blocks: Vec<LruBlock>,
    free_slots: Vec<usize>,
    /// MRU end (front) and LRU end (back) of the list
    front: usize,
    back: usize,
    /// blocks doomed but unreclaimed (readers > 0), by index
    doomed: Vec<usize>,
    pub alloc: DeviceAllocator,
    /// free()-costs accrued since the last insert (drained into the
    /// next insert's reported cost — cudaFree is paid on the same
    /// device timeline as the malloc that triggered the eviction).
    pending_free_cost: f64,
    // stats
    pub hits: u64,
    pub misses: u64,
    pub evictions: u64,
}

impl Alru {
    pub fn new(alloc: DeviceAllocator) -> Alru {
        Alru {
            map: HashMap::new(),
            blocks: Vec::new(),
            free_slots: Vec::new(),
            front: NONE,
            back: NONE,
            doomed: Vec::new(),
            alloc,
            pending_free_cost: 0.0,
            hits: 0,
            misses: 0,
            evictions: 0,
        }
    }

    fn slot(&mut self, b: LruBlock) -> usize {
        if let Some(i) = self.free_slots.pop() {
            self.blocks[i] = b;
            i
        } else {
            self.blocks.push(b);
            self.blocks.len() - 1
        }
    }

    fn unlink(&mut self, i: usize) {
        let (p, n) = (self.blocks[i].prev, self.blocks[i].next);
        if p != NONE {
            self.blocks[p].next = n;
        } else {
            self.front = n;
        }
        if n != NONE {
            self.blocks[n].prev = p;
        } else {
            self.back = p;
        }
        self.blocks[i].prev = NONE;
        self.blocks[i].next = NONE;
    }

    fn push_front(&mut self, i: usize) {
        self.blocks[i].prev = NONE;
        self.blocks[i].next = self.front;
        if self.front != NONE {
            self.blocks[self.front].prev = i;
        }
        self.front = i;
        if self.back == NONE {
            self.back = i;
        }
    }

    /// Paper Alg. 2 `Translate`, split for the caller's benefit:
    /// `lookup` is the cache-hit path (returns the block offset and
    /// touches the LRU position, incrementing the reader).
    pub fn lookup(&mut self, key: &TileKey) -> Option<Offset> {
        let &i = self.map.get(key)?;
        debug_assert!(!self.blocks[i].doomed);
        self.blocks[i].readers += 1;
        self.unlink(i);
        self.push_front(i);
        self.hits += 1;
        Some(self.blocks[i].offset)
    }

    /// Non-mutating probe (for priority Eq. 3): is the tile resident?
    pub fn probe(&self, key: &TileKey) -> bool {
        self.map.contains_key(key)
    }

    /// The miss path of `Translate`: allocate a block for `key`
    /// (evicting per ALRU policy as needed), insert at MRU, reader = 1.
    /// Returns `(offset, evicted_keys, alloc_cost)`; `None` if memory
    /// cannot be found even after eviction (caller syncs & retries or
    /// reports OOM).
    pub fn insert(&mut self, key: TileKey, len: usize) -> Option<(Offset, Vec<TileKey>, f64)> {
        self.insert_with(key, len, None)
    }

    /// Miss path for the asynchronous transfer pipeline: like
    /// [`Alru::insert`], but the block is born *pending* — carrying a
    /// fresh [`FillLatch`] that the filler completes after copying the
    /// bytes in off-lock. The insert's reader pin (readers = 1) makes a
    /// pending block unevictable by construction.
    pub fn insert_pending(
        &mut self,
        key: TileKey,
        len: usize,
    ) -> Option<(Offset, Vec<TileKey>, f64, Arc<FillLatch>)> {
        let latch = FillLatch::new();
        let (off, evicted, cost) = self.insert_with(key, len, Some(latch.clone()))?;
        Some((off, evicted, cost, latch))
    }

    fn insert_with(
        &mut self,
        key: TileKey,
        len: usize,
        pending: Option<Arc<FillLatch>>,
    ) -> Option<(Offset, Vec<TileKey>, f64)> {
        debug_assert!(!self.map.contains_key(&key), "insert of resident tile");
        self.misses += 1;
        // Fault-injection hook: a forced failure refuses the whole
        // request up front, exactly as an unevictable-full arena would.
        if self.alloc.take_forced_failure() {
            return None;
        }
        let mut evicted = Vec::new();
        let mut total_cost = 0.0;
        loop {
            match self.alloc.alloc(len) {
                Some((off, cost)) => {
                    total_cost += cost + std::mem::take(&mut self.pending_free_cost);
                    let b = LruBlock {
                        key,
                        offset: off,
                        len,
                        readers: 1,
                        doomed: false,
                        pending,
                        prev: NONE,
                        next: NONE,
                    };
                    let i = self.slot(b);
                    self.push_front(i);
                    self.map.insert(key, i);
                    return Some((off, evicted, total_cost));
                }
                None => {
                    // Alg. 2 Dequeue: evict first zero-reader from tail
                    match self.evict_one() {
                        Some(k) => evicted.push(k),
                        None => return None,
                    }
                }
            }
        }
    }

    /// Alg. 2 `Dequeue`: scan from the LRU end for the first block with
    /// zero readers, remove and free it. Returns its key.
    fn evict_one(&mut self) -> Option<TileKey> {
        let mut i = self.back;
        while i != NONE {
            if self.blocks[i].readers == 0 {
                let key = self.blocks[i].key;
                self.remove_block(i);
                self.evictions += 1;
                return Some(key);
            }
            i = self.blocks[i].prev;
        }
        None
    }

    fn remove_block(&mut self, i: usize) {
        self.unlink(i);
        self.map.remove(&self.blocks[i].key);
        let (fcost, _) = self.alloc.free(self.blocks[i].offset);
        self.pending_free_cost += fcost;
        self.free_slots.push(i);
    }

    /// Release one reader reference (at a sync point). Frees the block
    /// if it was doomed and this was the last reader.
    ///
    /// When a doomed and a live block share the key (the tile was
    /// invalidated and re-fetched while readers were still in flight),
    /// the release is attributed to the DOOMED block: its references are
    /// necessarily the older acquires, and the conservative direction —
    /// freeing doomed memory sooner, pinning the live block longer —
    /// can never evict data still in use.
    pub fn release(&mut self, key: &TileKey) {
        if let Some(pos) = self.doomed.iter().position(|&i| self.blocks[i].key == *key) {
            let i = self.doomed[pos];
            debug_assert!(self.blocks[i].readers > 0);
            self.blocks[i].readers -= 1;
            if self.blocks[i].readers == 0 {
                self.doomed.swap_remove(pos);
                self.alloc.free(self.blocks[i].offset);
                self.free_slots.push(i);
            }
            return;
        }
        if let Some(&i) = self.map.get(key) {
            debug_assert!(self.blocks[i].readers > 0, "release without reader");
            self.blocks[i].readers -= 1;
            return;
        }
        panic!("release of untracked tile {key:?}");
    }

    /// MESI-X invalidation: drop the tile from this cache. If readers
    /// are in flight the block is doomed (unreachable, freed on last
    /// release). Returns true if the tile was present.
    pub fn invalidate(&mut self, key: &TileKey) -> bool {
        let Some(i) = self.map.remove(key) else {
            return false;
        };
        self.unlink(i);
        if self.blocks[i].readers == 0 {
            self.alloc.free(self.blocks[i].offset);
            self.free_slots.push(i);
        } else {
            self.blocks[i].doomed = true;
            self.doomed.push(i);
        }
        true
    }

    /// Remove and free a block the caller owns exclusively (C-tile
    /// write-back: M → I). Panics if other readers remain.
    pub fn remove_owned(&mut self, key: &TileKey) {
        let i = *self.map.get(key).unwrap_or_else(|| panic!("remove of untracked {key:?}"));
        debug_assert!(self.blocks[i].readers <= 1, "remove_owned with foreign readers");
        self.map.remove(key);
        self.unlink(i);
        self.alloc.free(self.blocks[i].offset);
        self.free_slots.push(i);
    }

    /// Number of resident (non-doomed) tiles.
    pub fn resident(&self) -> usize {
        self.map.len()
    }

    /// Keys of every resident (non-doomed) tile — the worklist for
    /// surgical whole-device invalidation on device loss.
    pub fn resident_keys(&self) -> Vec<TileKey> {
        self.map.keys().copied().collect()
    }

    /// Offset of a resident tile without touching LRU order or readers
    /// (peer reads for L2 hits).
    pub fn peek_offset(&self, key: &TileKey) -> Option<Offset> {
        self.map.get(key).map(|&i| self.blocks[i].offset)
    }

    /// Offset of a resident tile whose bytes are *ready* (not mid-fill).
    /// Peer-source selection in the async pipeline uses this so a block
    /// still being filled is never served over P2P.
    pub fn ready_offset(&self, key: &TileKey) -> Option<Offset> {
        let &i = self.map.get(key)?;
        if self.blocks[i].pending.is_some() {
            return None;
        }
        Some(self.blocks[i].offset)
    }

    /// The fill latch of a resident-but-pending block, if any. A caller
    /// that found the tile via [`Alru::lookup`] (pin taken) checks this
    /// to decide whether it must wait off-lock for the bytes.
    pub fn pending_latch(&self, key: &TileKey) -> Option<Arc<FillLatch>> {
        let &i = self.map.get(key)?;
        self.blocks[i].pending.clone()
    }

    /// Add one reader pin to a resident block *without* touching LRU
    /// order or hit counters (peer-source pinning: the filler pins its
    /// P2P source under the lock so the source cannot be evicted while
    /// the off-lock memcpy reads it). Returns false if not resident.
    pub fn pin(&mut self, key: &TileKey) -> bool {
        match self.map.get(key) {
            Some(&i) => {
                self.blocks[i].readers += 1;
                true
            }
            None => false,
        }
    }

    /// Clear the pending marker on a block (live or doomed), returning
    /// its latch so the caller can complete it outside this structure.
    /// Returns `None` if the key has no pending block.
    pub fn take_pending(&mut self, key: &TileKey) -> Option<Arc<FillLatch>> {
        if let Some(&i) = self.map.get(key) {
            return self.blocks[i].pending.take();
        }
        // Invalidated mid-fill: the block moved to the doomed list but
        // the filler still owns its latch.
        for &i in &self.doomed {
            if self.blocks[i].key == *key {
                return self.blocks[i].pending.take();
            }
        }
        None
    }

    /// Invariant check for tests: list ↔ map consistency, reader sanity.
    pub fn validate(&self) -> Result<(), String> {
        let mut count = 0;
        let mut i = self.front;
        let mut prev = NONE;
        while i != NONE {
            if self.blocks[i].prev != prev {
                return Err(format!("bad prev at {i}"));
            }
            if self.blocks[i].doomed {
                return Err(format!("doomed block {i} still in list"));
            }
            if self.map.get(&self.blocks[i].key) != Some(&i) {
                return Err(format!("map missing list block {i}"));
            }
            if self.blocks[i].pending.is_some() && self.blocks[i].readers == 0 {
                return Err(format!("pending block {i} lost its filler pin"));
            }
            count += 1;
            prev = i;
            i = self.blocks[i].next;
        }
        if count != self.map.len() {
            return Err(format!("list has {count} blocks, map {}", self.map.len()));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mem::AllocStrategy;
    use crate::tile::MatId;

    fn key(addr: usize) -> TileKey {
        TileKey::synthetic(addr, MatId::A, addr, 0)
    }

    fn alru(capacity: usize) -> Alru {
        Alru::new(DeviceAllocator::new(capacity, AllocStrategy::FastHeap))
    }

    #[test]
    fn hit_after_insert() {
        let mut c = alru(1000);
        let (off, ev, _) = c.insert(key(1), 100).unwrap();
        assert!(ev.is_empty());
        c.release(&key(1));
        assert_eq!(c.lookup(&key(1)), Some(off));
        assert_eq!(c.hits, 1);
        assert_eq!(c.misses, 1);
        c.validate().unwrap();
    }

    #[test]
    fn evicts_lru_zero_reader() {
        let mut c = alru(300);
        c.insert(key(1), 100).unwrap();
        c.insert(key(2), 100).unwrap();
        c.insert(key(3), 100).unwrap();
        // all have readers=1: nothing evictable
        assert!(c.insert(key(4), 100).is_none());
        // release 2 only; 2 is the (approximate) victim even though 1 is older
        c.release(&key(2));
        let (_, ev, _) = c.insert(key(4), 100).unwrap();
        assert_eq!(ev, vec![key(2)]);
        assert!(c.probe(&key(1)));
        assert!(!c.probe(&key(2)));
        assert_eq!(c.evictions, 1);
        c.validate().unwrap();
    }

    #[test]
    fn lru_order_respects_touch() {
        let mut c = alru(300);
        c.insert(key(1), 100).unwrap();
        c.insert(key(2), 100).unwrap();
        c.insert(key(3), 100).unwrap();
        for k in [1, 2, 3] {
            c.release(&key(k));
        }
        // touch 1 so 2 becomes LRU victim
        c.lookup(&key(1)).unwrap();
        c.release(&key(1));
        let (_, ev, _) = c.insert(key(4), 100).unwrap();
        assert_eq!(ev, vec![key(2)]);
        c.validate().unwrap();
    }

    #[test]
    fn eviction_cascades_until_fit() {
        let mut c = alru(300);
        c.insert(key(1), 100).unwrap();
        c.insert(key(2), 100).unwrap();
        c.insert(key(3), 100).unwrap();
        for k in [1, 2, 3] {
            c.release(&key(k));
        }
        // need 250 -> evicts two blocks (coalesced by the heap)
        let (_, ev, _) = c.insert(key(5), 250).unwrap();
        assert!(ev.len() >= 2, "evicted {ev:?}");
        c.validate().unwrap();
    }

    #[test]
    fn invalidate_with_readers_dooms_then_frees() {
        let mut c = alru(200);
        c.insert(key(1), 100).unwrap(); // readers = 1
        assert!(c.invalidate(&key(1)));
        assert!(!c.probe(&key(1)), "doomed tile unreachable");
        // memory not yet reclaimed
        assert_eq!(c.alloc.heap.in_use(), 100);
        c.release(&key(1));
        assert_eq!(c.alloc.heap.in_use(), 0);
        c.validate().unwrap();
    }

    #[test]
    fn invalidate_absent_is_noop() {
        let mut c = alru(100);
        assert!(!c.invalidate(&key(9)));
    }

    #[test]
    fn remove_owned_frees_immediately() {
        let mut c = alru(200);
        c.insert(key(1), 64).unwrap();
        c.remove_owned(&key(1));
        assert_eq!(c.alloc.heap.in_use(), 0);
        assert_eq!(c.resident(), 0);
    }

    #[test]
    fn readers_pin_across_reinsert_pressure() {
        let mut c = alru(200);
        c.insert(key(1), 100).unwrap(); // pinned, readers=1
        c.insert(key(2), 100).unwrap();
        c.release(&key(2));
        // pressure: key3 must evict key2, never key1
        let (_, ev, _) = c.insert(key(3), 100).unwrap();
        assert_eq!(ev, vec![key(2)]);
        assert!(c.probe(&key(1)));
        c.validate().unwrap();
    }

    #[test]
    fn peek_does_not_touch() {
        let mut c = alru(300);
        c.insert(key(1), 100).unwrap();
        c.insert(key(2), 100).unwrap();
        c.release(&key(1));
        c.release(&key(2));
        let before_hits = c.hits;
        assert!(c.peek_offset(&key(1)).is_some());
        assert_eq!(c.hits, before_hits);
        // key1 is still LRU victim despite the peek
        let (_, ev, _) = c.insert(key(3), 200).unwrap();
        assert!(ev.contains(&key(1)));
    }

    #[test]
    #[should_panic(expected = "release of untracked")]
    fn release_unknown_panics() {
        let mut c = alru(100);
        c.release(&key(42));
    }

    #[test]
    fn forced_failure_refuses_one_insert_then_recovers() {
        let mut c = alru(1000);
        c.insert(key(1), 100).unwrap();
        c.release(&key(1));
        c.alloc.force_fail(1);
        assert!(c.insert(key(2), 100).is_none(), "armed insert must fail");
        assert!(c.probe(&key(1)), "a forced failure evicts nothing");
        let (_, ev, _) = c.insert(key(2), 100).unwrap();
        assert!(ev.is_empty(), "the retry succeeds without pressure");
        c.validate().unwrap();
    }

    #[test]
    fn pending_block_is_pinned_and_invisible_to_peers() {
        let mut c = alru(300);
        let (off, ev, _, latch) = c.insert_pending(key(1), 100).unwrap();
        assert!(ev.is_empty());
        assert!(!latch.is_ready());
        // mid-fill: resident for lookups (they get the latch), but not
        // servable as a ready peer source, and never evictable.
        assert!(c.probe(&key(1)));
        assert_eq!(c.ready_offset(&key(1)), None);
        assert_eq!(c.peek_offset(&key(1)), Some(off));
        assert!(c.pending_latch(&key(1)).is_some());
        c.insert(key(2), 100).unwrap();
        c.release(&key(2));
        let (_, ev, _) = c.insert(key(3), 200).unwrap();
        assert_eq!(ev, vec![key(2)], "pending block must survive pressure");
        // latch ready: block becomes a normal ready resident
        let l = c.take_pending(&key(1)).unwrap();
        l.complete(true);
        assert!(latch.wait());
        assert_eq!(c.ready_offset(&key(1)), Some(off));
        assert!(c.pending_latch(&key(1)).is_none());
        c.release(&key(1));
        c.validate().unwrap();
    }

    #[test]
    fn pin_adds_reader_without_touching_lru() {
        let mut c = alru(300);
        c.insert(key(1), 100).unwrap();
        c.insert(key(2), 100).unwrap();
        c.release(&key(1));
        c.release(&key(2));
        let hits = c.hits;
        assert!(c.pin(&key(1)));
        assert_eq!(c.hits, hits, "pin is not a hit");
        // key1 pinned: pressure must evict key2 even though key1 is older
        let (_, ev, _) = c.insert(key(3), 100).unwrap();
        assert_eq!(ev, vec![key(2)]);
        c.release(&key(1));
        assert!(!c.pin(&key(9)), "pin of absent tile refused");
        c.validate().unwrap();
    }

    #[test]
    fn take_pending_finds_doomed_blocks() {
        let mut c = alru(300);
        let (_, _, _, latch) = c.insert_pending(key(1), 100).unwrap();
        // invalidated mid-fill (e.g. a C write-back): block is doomed
        // but the filler can still retrieve its latch to abort waiters.
        assert!(c.invalidate(&key(1)));
        let l = c.take_pending(&key(1)).unwrap();
        l.complete(false);
        assert!(!latch.wait(), "waiters must see the abort");
        assert!(c.take_pending(&key(1)).is_none());
        c.release(&key(1)); // filler pin; doomed block frees
        assert_eq!(c.alloc.heap.in_use(), 0);
        c.validate().unwrap();
    }

    #[test]
    fn latch_wait_blocks_until_complete() {
        let latch = FillLatch::new();
        let l2 = latch.clone();
        let waiter = std::thread::spawn(move || l2.wait());
        std::thread::sleep(std::time::Duration::from_millis(5));
        latch.complete(true);
        assert!(waiter.join().unwrap());
    }

    #[test]
    fn resident_keys_lists_live_blocks_only() {
        let mut c = alru(1000);
        c.insert(key(1), 100).unwrap();
        c.insert(key(2), 100).unwrap();
        c.invalidate(&key(1)); // doomed (readers in flight)
        let keys = c.resident_keys();
        assert_eq!(keys, vec![key(2)], "doomed blocks are not resident");
    }
}
