//! Two-level hierarchical tile caches (paper §IV-B).
//!
//! - [`alru`]: the per-device Approximate-LRU (Alg. 2) — L1 tile cache.
//! - [`coherence`]: the MESI-X directory (Fig. 3).
//! - [`tile_cache`]: the combined policy — L1 lookup, L2 peer fetch,
//!   write-back invalidation — shared by both execution engines.

pub mod alru;
pub mod coherence;
pub mod tile_cache;

pub use alru::{Alru, FillLatch, LruBlock};
pub use coherence::{Directory, TileState};
pub use tile_cache::{Acquire, AsyncAcquire, CacheStats, FillTicket, Source, TileCacheSet};
