//! The two-level hierarchical tile cache (paper §IV-B, Fig. 2):
//! per-device ALRUs (L1) + the MESI-X directory that turns the union of
//! peer caches into an L2.
//!
//! `TileCacheSet` is the single entry point both execution engines use:
//! `acquire` implements the full lookup policy —
//!
//! 1. **L1 hit**: the tile is in this device's ALRU → reuse, no traffic;
//! 2. **L2 hit**: a P2P-reachable peer holds it → fetch over the switch
//!    (7.8 GB/s beats 6.54 GB/s host DMA, Table IV), state → S;
//! 3. **miss**: fetch from host RAM, state → E (or S if unreachable
//!    holders exist elsewhere).
//!
//! The caller performs the actual byte movement (or books simulated
//! time) according to the returned [`Acquire`] plan, which keeps this
//! module pure policy — shared verbatim by the DES and the threaded
//! runtime (DESIGN.md §6.1).

use super::alru::Alru;
use super::coherence::Directory;
use crate::mem::{AllocStrategy, DeviceAllocator, Offset};
use crate::tile::TileKey;

/// Where the bytes for an acquired tile come from.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Source {
    /// Already resident in this device's L1 — no transfer.
    L1,
    /// Copy from peer device `src` over P2P (L2 hit).
    Peer { src: usize, src_offset: Offset },
    /// Copy from host RAM (global miss).
    Host,
}

/// The acquisition plan for one tile on one device.
#[derive(Clone, Debug)]
pub struct Acquire {
    /// Device-arena offset of the destination block.
    pub offset: Offset,
    /// Where the bytes come from.
    pub source: Source,
    /// Tiles evicted to make room (their holders were dropped in the
    /// directory; the engine may account the eviction, no copies move —
    /// input tiles are clean by construction, M is ephemeral).
    pub evicted: Vec<TileKey>,
    /// Allocator cost in seconds (nonzero only under the CudaMalloc
    /// strategy — the Fig. 5 experiment).
    pub alloc_cost: f64,
}

/// Hit/miss/eviction counters of one device's ALRU.
///
/// Under a persistent runtime these are **cumulative since the cache
/// was built** (the ALRUs live across calls); use
/// [`CacheStats::delta_since`] with a snapshot taken at job admission
/// for a per-call view. Note the devices are shared: a delta taken over
/// a job's in-flight window also counts concurrent tenants' traffic on
/// the same devices.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CacheStats {
    pub hits: u64,
    pub misses: u64,
    pub evictions: u64,
}

impl CacheStats {
    /// Per-call view: counter increments since `earlier` was
    /// snapshotted (saturating, so a delta taken across a cache
    /// rebuild — e.g. a runtime reboot on a geometry change — must
    /// not wrap).
    pub fn delta_since(&self, earlier: &CacheStats) -> CacheStats {
        CacheStats {
            hits: self.hits.saturating_sub(earlier.hits),
            misses: self.misses.saturating_sub(earlier.misses),
            evictions: self.evictions.saturating_sub(earlier.evictions),
        }
    }
}

/// Per-device ALRUs + the global coherence directory.
pub struct TileCacheSet {
    alrus: Vec<Alru>,
    pub dir: Directory,
    /// P2P peer lists per device (from the topology).
    peers: Vec<Vec<usize>>,
}

impl TileCacheSet {
    /// Build caches for `capacities[i]` bytes on device `i` with the
    /// given P2P peer lists and allocation strategy.
    pub fn new(capacities: &[usize], peers: Vec<Vec<usize>>, strategy: AllocStrategy) -> Self {
        assert_eq!(capacities.len(), peers.len());
        TileCacheSet {
            alrus: capacities
                .iter()
                .map(|&c| Alru::new(DeviceAllocator::new(c, strategy)))
                .collect(),
            dir: Directory::new(capacities.len()),
            peers,
        }
    }

    pub fn n_devices(&self) -> usize {
        self.alrus.len()
    }

    /// Non-mutating locality probe for priority Eq. 3:
    /// 2 = L1 hit, 1 = L2 hit, 0 = host.
    pub fn locality_score(&self, dev: usize, key: &TileKey) -> u32 {
        if self.alrus[dev].probe(key) {
            return 2;
        }
        if self.dir.peer_source(key, dev, &self.peers[dev]).is_some() {
            return 1;
        }
        0
    }

    /// Acquire a tile for reading on `dev` (paper Alg. 2 Translate +
    /// MESI-X read transitions). Returns `None` only if the device
    /// cannot hold the tile even after evicting everything evictable
    /// (caller must sync streams to release readers and retry).
    pub fn acquire(&mut self, dev: usize, key: TileKey, len: usize) -> Option<Acquire> {
        if let Some(offset) = self.alrus[dev].lookup(&key) {
            return Some(Acquire { offset, source: Source::L1, evicted: Vec::new(), alloc_cost: 0.0 });
        }
        // Find a P2P source among current holders *before* inserting
        // ourselves (we are not a valid source).
        let peer = self
            .dir
            .peer_source(&key, dev, &self.peers[dev])
            .map(|src| (src, self.alrus[src].peek_offset(&key).expect("directory/ALRU desync")));
        let (offset, evicted, alloc_cost) = self.alrus[dev].insert(key, len)?;
        for ek in &evicted {
            self.dir.drop_holder(ek, dev);
        }
        self.dir.add_holder(key, dev);
        let source = match peer {
            Some((src, src_offset)) => Source::Peer { src, src_offset },
            None => Source::Host,
        };
        Some(Acquire { offset, source, evicted, alloc_cost })
    }

    /// Allocate space for a task's C accumulator tile on `dev`. C tiles
    /// are *not* cached (M is ephemeral, paper Fig. 3): they are tracked
    /// by the ALRU only while the task runs, then written back and
    /// dropped via [`Self::writeback`].
    pub fn acquire_output(&mut self, dev: usize, key: TileKey, len: usize) -> Option<Acquire> {
        // An output tile may coincide with a cached input tile (TRMM/
        // TRSM chains read neighbour C tiles): invalidate every cached
        // copy first — the writer is about to make them stale.
        for holder in self.dir.write_back(&key) {
            self.alrus[holder].invalidate(&key);
        }
        let (offset, evicted, alloc_cost) = self.alrus[dev].insert(key, len)?;
        for ek in &evicted {
            self.dir.drop_holder(ek, dev);
        }
        self.dir.add_holder(key, dev);
        Some(Acquire { offset, source: Source::Host, evicted, alloc_cost })
    }

    /// Release one reader reference (stream-sync point, Alg. 1 line 17).
    pub fn release(&mut self, dev: usize, key: &TileKey) {
        self.alrus[dev].release(key);
    }

    /// M-state write-back (paper Fig. 3): the device wrote its C tile;
    /// all cached copies (including the writer's block) invalidate and
    /// the tile's directory state collapses to I. The caller moves the
    /// bytes to host before calling this.
    pub fn writeback(&mut self, dev: usize, key: &TileKey) {
        for holder in self.dir.write_back(key) {
            self.alrus[holder].invalidate(key);
        }
        // The writer's block may have readers==1 (the task itself); the
        // invalidate path dooms it and the final release frees it. If the
        // writer never registered (already invalidated), this is a no-op.
        let _ = dev;
    }

    /// Surgical whole-device invalidation for device loss: every block
    /// resident on `dev` is dropped (doomed if readers are in flight —
    /// the migrating task's releases reclaim them), and the directory
    /// forgets `dev` as a holder everywhere. Peer replicas on surviving
    /// devices stay valid, as do the host master copies; nothing on any
    /// other device is touched. Returns the number of tiles evicted.
    pub fn evict_device(&mut self, dev: usize) -> usize {
        let keys = self.alrus[dev].resident_keys();
        for k in &keys {
            self.alrus[dev].invalidate(k);
        }
        self.dir.drop_device(dev);
        keys.len()
    }

    /// Fault-injection hook: the next `n` allocation requests on `dev`
    /// are refused as if the arena were exhausted (see
    /// [`DeviceAllocator::force_fail`]).
    pub fn force_alloc_failure(&mut self, dev: usize, n: u64) {
        self.alrus[dev].alloc.force_fail(n);
    }

    /// Cache statistics of one device (cumulative since construction;
    /// see [`CacheStats::delta_since`] for the per-call view).
    pub fn stats(&self, dev: usize) -> CacheStats {
        let a = &self.alrus[dev];
        CacheStats { hits: a.hits, misses: a.misses, evictions: a.evictions }
    }

    /// Residency probe for tests.
    pub fn resident(&self, dev: usize) -> usize {
        self.alrus[dev].resident()
    }

    /// The device arena's allocator counters (bytes in use, high
    /// watermark, alloc/free totals) — the telemetry sampler's view of
    /// arena pressure.
    pub fn heap_stats(&self, dev: usize) -> crate::mem::HeapStats {
        self.alrus[dev].alloc.heap.stats()
    }

    /// Consistency check across ALRUs and the directory (tests).
    pub fn validate(&self) -> Result<(), String> {
        for (d, a) in self.alrus.iter().enumerate() {
            a.validate().map_err(|e| format!("dev {d}: {e}"))?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tile::MatId;

    fn key(addr: usize) -> TileKey {
        TileKey::synthetic(addr, MatId::A, addr, 0)
    }

    /// 3 devices, all peers, 300-byte VRAM each.
    fn set3() -> TileCacheSet {
        TileCacheSet::new(
            &[300, 300, 300],
            vec![vec![1, 2], vec![0, 2], vec![0, 1]],
            AllocStrategy::FastHeap,
        )
    }

    #[test]
    fn miss_then_l1_hit() {
        let mut s = set3();
        let a = s.acquire(0, key(1), 100).unwrap();
        assert_eq!(a.source, Source::Host);
        s.release(0, &key(1));
        let a2 = s.acquire(0, key(1), 100).unwrap();
        assert_eq!(a2.source, Source::L1);
        assert_eq!(s.locality_score(0, &key(1)), 2);
        s.validate().unwrap();
    }

    #[test]
    fn peer_fetch_is_l2_hit() {
        let mut s = set3();
        s.acquire(0, key(1), 100).unwrap();
        // device 1 misses L1, finds device 0 as P2P source
        assert_eq!(s.locality_score(1, &key(1)), 1);
        let a = s.acquire(1, key(1), 100).unwrap();
        match a.source {
            Source::Peer { src, .. } => assert_eq!(src, 0),
            other => panic!("expected peer fetch, got {other:?}"),
        }
        // now shared: both hold it
        assert_eq!(s.dir.holders(&key(1)), &[0, 1]);
        s.validate().unwrap();
    }

    #[test]
    fn unreachable_peer_is_host_miss() {
        // device 2 unreachable from 0 and 1
        let mut s = TileCacheSet::new(
            &[300, 300, 300],
            vec![vec![1], vec![0], vec![]],
            AllocStrategy::FastHeap,
        );
        s.acquire(0, key(1), 100).unwrap();
        assert_eq!(s.locality_score(2, &key(1)), 0);
        let a = s.acquire(2, key(1), 100).unwrap();
        assert_eq!(a.source, Source::Host);
        s.validate().unwrap();
    }

    #[test]
    fn eviction_updates_directory() {
        let mut s = set3();
        s.acquire(0, key(1), 100).unwrap();
        s.acquire(0, key(2), 100).unwrap();
        s.acquire(0, key(3), 100).unwrap();
        s.release(0, &key(1));
        s.release(0, &key(2));
        s.release(0, &key(3));
        // inserting key4 evicts key1 (LRU); directory must drop it
        let a = s.acquire(0, key(4), 100).unwrap();
        assert!(a.evicted.contains(&key(1)));
        assert!(s.dir.holders(&key(1)).is_empty());
        // peer lookup for key1 from dev1 now misses to host
        assert_eq!(s.locality_score(1, &key(1)), 0);
        s.validate().unwrap();
    }

    #[test]
    fn writeback_invalidates_all_copies() {
        let mut s = set3();
        s.acquire(0, key(9), 100).unwrap();
        s.acquire(1, key(9), 100).unwrap();
        assert_eq!(s.dir.holders(&key(9)), &[0, 1]);
        // device 2 wrote the tile (as a C output): all copies die
        s.writeback(2, &key(9));
        assert!(s.dir.holders(&key(9)).is_empty());
        assert_eq!(s.locality_score(0, &key(9)), 0);
        // in-flight readers on 0/1 still release safely (doomed blocks)
        s.release(0, &key(9));
        s.release(1, &key(9));
        assert_eq!(s.resident(0), 0);
        s.validate().unwrap();
    }

    #[test]
    fn acquire_output_invalidates_stale_readers_copies() {
        let mut s = set3();
        // dev 0 cached the tile as an *input* earlier
        s.acquire(0, key(5), 100).unwrap();
        s.release(0, &key(5));
        // dev 1 now takes it as its task's *output*
        let a = s.acquire_output(1, key(5), 100).unwrap();
        assert_eq!(a.source, Source::Host);
        // dev 0's copy must be gone (it would read stale data next round)
        assert_eq!(s.locality_score(0, &key(5)), 1, "only dev1's copy remains");
        assert_eq!(s.dir.holders(&key(5)), &[1]);
        s.validate().unwrap();
    }

    #[test]
    fn evict_device_is_surgical() {
        let mut s = set3();
        s.acquire(0, key(1), 100).unwrap(); // exclusive to the dying device
        s.acquire(0, key(2), 100).unwrap(); // shared with dev 1
        s.acquire(1, key(2), 100).unwrap();
        s.acquire(2, key(3), 100).unwrap(); // bystander
        assert_eq!(s.evict_device(0), 2);
        assert_eq!(s.resident(0), 0);
        assert_eq!(s.dir.holders(&key(2)), &[1], "peer replica survives");
        assert_eq!(s.locality_score(2, &key(3)), 2, "bystander untouched");
        // in-flight readers on the dead device release safely (doomed)
        s.release(0, &key(1));
        s.release(0, &key(2));
        s.validate().unwrap();
    }

    #[test]
    fn forced_alloc_failure_reaches_the_device() {
        let mut s = set3();
        s.force_alloc_failure(0, 1);
        assert!(s.acquire(0, key(1), 100).is_none(), "armed acquire refused");
        assert!(s.acquire(0, key(1), 100).is_some(), "retry succeeds");
        assert!(s.acquire(1, key(2), 100).is_some(), "other devices unaffected");
        s.validate().unwrap();
    }

    #[test]
    fn full_cache_with_pinned_tiles_returns_none() {
        let mut s = set3();
        s.acquire(0, key(1), 150).unwrap(); // readers = 1, pinned
        s.acquire(0, key(2), 150).unwrap(); // readers = 1, pinned
        assert!(s.acquire(0, key(3), 100).is_none());
        // after a sync point releases readers, it succeeds
        s.release(0, &key(1));
        assert!(s.acquire(0, key(3), 100).is_some());
        s.validate().unwrap();
    }
}
