//! The two-level hierarchical tile cache (paper §IV-B, Fig. 2):
//! per-device ALRUs (L1) + the MESI-X directory that turns the union of
//! peer caches into an L2.
//!
//! `TileCacheSet` is the single entry point both execution engines use:
//! `acquire` implements the full lookup policy —
//!
//! 1. **L1 hit**: the tile is in this device's ALRU → reuse, no traffic;
//! 2. **L2 hit**: a P2P-reachable peer holds it → fetch over the switch
//!    (7.8 GB/s beats 6.54 GB/s host DMA, Table IV), state → S;
//! 3. **miss**: fetch from host RAM, state → E (or S if unreachable
//!    holders exist elsewhere).
//!
//! The caller performs the actual byte movement (or books simulated
//! time) according to the returned [`Acquire`] plan, which keeps this
//! module pure policy — shared verbatim by the DES and the threaded
//! runtime (DESIGN.md §6.1).

use super::alru::{Alru, FillLatch};
use super::coherence::Directory;
use crate::mem::{AllocStrategy, DeviceAllocator, Offset};
use crate::tile::TileKey;
use std::sync::Arc;

/// Where the bytes for an acquired tile come from.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Source {
    /// Already resident in this device's L1 — no transfer.
    L1,
    /// Copy from peer device `src` over P2P (L2 hit).
    Peer { src: usize, src_offset: Offset },
    /// Copy from host RAM (global miss).
    Host,
}

/// The acquisition plan for one tile on one device.
#[derive(Clone, Debug)]
pub struct Acquire {
    /// Device-arena offset of the destination block.
    pub offset: Offset,
    /// Where the bytes come from.
    pub source: Source,
    /// Tiles evicted to make room (their holders were dropped in the
    /// directory; the engine may account the eviction, no copies move —
    /// input tiles are clean by construction, M is ephemeral).
    pub evicted: Vec<TileKey>,
    /// Allocator cost in seconds (nonzero only under the CudaMalloc
    /// strategy — the Fig. 5 experiment).
    pub alloc_cost: f64,
}

/// Outcome of an asynchronous (narrow-lock) acquire.
///
/// The contract that keeps every copy **off** the global cache lock:
///
/// - `Ready` — the bytes are resident and valid; use them (pin already
///   taken, release at the sync point as usual).
/// - `InFlight` — another filler reserved this block and is copying
///   off-lock. The pin is already taken; drop the global lock and block
///   on the latch. `wait() == true` → consume `offset` as an L1 hit;
///   `false` → release the pin and re-acquire from scratch.
/// - `Fill` — this caller reserved the block and owns the fill: drop
///   the lock, move the bytes per `ticket.source`, then re-lock briefly
///   for [`TileCacheSet::complete_fill`] (or
///   [`TileCacheSet::abort_fill`] on failure).
#[derive(Debug)]
pub enum AsyncAcquire {
    Ready(Acquire),
    InFlight { offset: Offset, latch: Arc<FillLatch> },
    Fill(FillTicket),
}

/// A reserved destination block whose bytes the holder must move in
/// off-lock, then latch ready. If `source` is `Peer`, the source block
/// is reader-pinned (so the off-lock memcpy can read it safely); the
/// pin is dropped by `complete_fill` / `abort_fill`.
#[derive(Debug)]
pub struct FillTicket {
    pub offset: Offset,
    pub source: Source,
    pub evicted: Vec<TileKey>,
    pub alloc_cost: f64,
    pub latch: Arc<FillLatch>,
}

impl FillTicket {
    /// The pinned peer-source device, if the plan is a P2P copy.
    pub fn peer_src(&self) -> Option<usize> {
        match self.source {
            Source::Peer { src, .. } => Some(src),
            _ => None,
        }
    }
}

/// Hit/miss/eviction counters of one device's ALRU.
///
/// Under a persistent runtime these are **cumulative since the cache
/// was built** (the ALRUs live across calls); use
/// [`CacheStats::delta_since`] with a snapshot taken at job admission
/// for a per-call view. Note the devices are shared: a delta taken over
/// a job's in-flight window also counts concurrent tenants' traffic on
/// the same devices.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CacheStats {
    pub hits: u64,
    pub misses: u64,
    pub evictions: u64,
}

impl CacheStats {
    /// Per-call view: counter increments since `earlier` was
    /// snapshotted (saturating, so a delta taken across a cache
    /// rebuild — e.g. a runtime reboot on a geometry change — must
    /// not wrap).
    pub fn delta_since(&self, earlier: &CacheStats) -> CacheStats {
        CacheStats {
            hits: self.hits.saturating_sub(earlier.hits),
            misses: self.misses.saturating_sub(earlier.misses),
            evictions: self.evictions.saturating_sub(earlier.evictions),
        }
    }
}

/// Per-device ALRUs + the global coherence directory.
pub struct TileCacheSet {
    alrus: Vec<Alru>,
    pub dir: Directory,
    /// P2P peer lists per device (from the topology).
    peers: Vec<Vec<usize>>,
}

impl TileCacheSet {
    /// Build caches for `capacities[i]` bytes on device `i` with the
    /// given P2P peer lists and allocation strategy.
    pub fn new(capacities: &[usize], peers: Vec<Vec<usize>>, strategy: AllocStrategy) -> Self {
        assert_eq!(capacities.len(), peers.len());
        TileCacheSet {
            alrus: capacities
                .iter()
                .map(|&c| Alru::new(DeviceAllocator::new(c, strategy)))
                .collect(),
            dir: Directory::new(capacities.len()),
            peers,
        }
    }

    pub fn n_devices(&self) -> usize {
        self.alrus.len()
    }

    /// Non-mutating locality probe for priority Eq. 3:
    /// 2 = L1 hit, 1 = L2 hit, 0 = host.
    pub fn locality_score(&self, dev: usize, key: &TileKey) -> u32 {
        if self.alrus[dev].probe(key) {
            return 2;
        }
        if self.dir.peer_source(key, dev, &self.peers[dev]).is_some() {
            return 1;
        }
        0
    }

    /// Acquire a tile for reading on `dev` (paper Alg. 2 Translate +
    /// MESI-X read transitions). Returns `None` only if the device
    /// cannot hold the tile even after evicting everything evictable
    /// (caller must sync streams to release readers and retry).
    pub fn acquire(&mut self, dev: usize, key: TileKey, len: usize) -> Option<Acquire> {
        if let Some(offset) = self.alrus[dev].lookup(&key) {
            return Some(Acquire { offset, source: Source::L1, evicted: Vec::new(), alloc_cost: 0.0 });
        }
        // Find a P2P source among current holders *before* inserting
        // ourselves (we are not a valid source).
        let peer = self
            .dir
            .peer_source(&key, dev, &self.peers[dev])
            .map(|src| (src, self.alrus[src].peek_offset(&key).expect("directory/ALRU desync")));
        let (offset, evicted, alloc_cost) = self.alrus[dev].insert(key, len)?;
        for ek in &evicted {
            self.dir.drop_holder(ek, dev);
        }
        self.dir.add_holder(key, dev);
        let source = match peer {
            Some((src, src_offset)) => Source::Peer { src, src_offset },
            None => Source::Host,
        };
        Some(Acquire { offset, source, evicted, alloc_cost })
    }

    /// Narrow-lock variant of [`TileCacheSet::acquire`] for the
    /// asynchronous transfer pipeline: instead of expecting the caller
    /// to copy while holding whatever lock guards this set, a miss
    /// reserves a *pending* block (born pinned, carrying a
    /// [`FillLatch`]) and returns a [`FillTicket`] — the caller drops
    /// the lock, fills the block, then calls
    /// [`TileCacheSet::complete_fill`]. A concurrent same-key acquirer
    /// gets [`AsyncAcquire::InFlight`] and waits on the latch off-lock.
    ///
    /// Peer-source selection only considers *ready* holders (a block
    /// mid-fill is never served over P2P) and reader-pins the chosen
    /// source so the off-lock memcpy cannot race an eviction.
    ///
    /// Returns `None` on arena exhaustion, exactly like `acquire`.
    pub fn acquire_async(&mut self, dev: usize, key: TileKey, len: usize) -> Option<AsyncAcquire> {
        if let Some(offset) = self.alrus[dev].lookup(&key) {
            if let Some(latch) = self.alrus[dev].pending_latch(&key) {
                return Some(AsyncAcquire::InFlight { offset, latch });
            }
            return Some(AsyncAcquire::Ready(Acquire {
                offset,
                source: Source::L1,
                evicted: Vec::new(),
                alloc_cost: 0.0,
            }));
        }
        // Ready P2P source among current holders, selected *before*
        // inserting ourselves (we are not a valid source).
        let peer = self
            .dir
            .holders(&key)
            .iter()
            .copied()
            .filter(|&h| h != dev && self.peers[dev].contains(&h))
            .find_map(|h| self.alrus[h].ready_offset(&key).map(|off| (h, off)));
        let (offset, evicted, alloc_cost, latch) = self.alrus[dev].insert_pending(key, len)?;
        for ek in &evicted {
            self.dir.drop_holder(ek, dev);
        }
        self.dir.add_holder(key, dev);
        let source = match peer {
            Some((src, src_offset)) => {
                assert!(self.alrus[src].pin(&key), "directory/ALRU desync");
                Source::Peer { src, src_offset }
            }
            None => Source::Host,
        };
        Some(AsyncAcquire::Fill(FillTicket { offset, source, evicted, alloc_cost, latch }))
    }

    /// Narrow-lock variant of [`TileCacheSet::acquire_output`]: the C
    /// destination block is reserved pending so the zero-fill / host
    /// preload happens off-lock. C tiles are never peer-served, so the
    /// ticket's source is always `Host`.
    pub fn acquire_output_async(
        &mut self,
        dev: usize,
        key: TileKey,
        len: usize,
    ) -> Option<FillTicket> {
        for holder in self.dir.write_back(&key) {
            self.alrus[holder].invalidate(&key);
        }
        let (offset, evicted, alloc_cost, latch) = self.alrus[dev].insert_pending(key, len)?;
        for ek in &evicted {
            self.dir.drop_holder(ek, dev);
        }
        self.dir.add_holder(key, dev);
        Some(FillTicket { offset, source: Source::Host, evicted, alloc_cost, latch })
    }

    /// Latch a filled block ready and drop the peer-source pin (if the
    /// ticket's plan was a P2P copy). Returns `true` if the block is
    /// still live — `false` means it was invalidated mid-fill (a write-
    /// back raced the copy): the bytes are stale, the latch aborts its
    /// waiters, and the filler must release its pin and re-acquire.
    pub fn complete_fill(&mut self, dev: usize, key: &TileKey, peer_src: Option<usize>) -> bool {
        if let Some(src) = peer_src {
            self.alrus[src].release(key);
        }
        let live = self.alrus[dev].probe(key);
        if let Some(latch) = self.alrus[dev].take_pending(key) {
            latch.complete(live);
        }
        live
    }

    /// Abandon a fill (transfer fault exhausted its retries): the
    /// reserved block is torn down, same-key waiters are aborted (they
    /// re-acquire), and the peer-source pin is dropped. The filler's
    /// own pin is consumed — do **not** release the key afterwards.
    pub fn abort_fill(&mut self, dev: usize, key: &TileKey, peer_src: Option<usize>) {
        if let Some(src) = peer_src {
            self.alrus[src].release(key);
        }
        let latch = self.alrus[dev].take_pending(key);
        if self.alrus[dev].probe(key) {
            // Drop the filler pin first so a waiter-free block frees
            // immediately; waiters keep it doomed until they wake.
            self.alrus[dev].release(key);
            self.alrus[dev].invalidate(key);
            self.dir.drop_holder(key, dev);
        } else {
            // Already invalidated mid-fill: just drop the filler pin.
            self.alrus[dev].release(key);
        }
        if let Some(latch) = latch {
            latch.complete(false);
        }
    }

    /// Allocate space for a task's C accumulator tile on `dev`. C tiles
    /// are *not* cached (M is ephemeral, paper Fig. 3): they are tracked
    /// by the ALRU only while the task runs, then written back and
    /// dropped via [`Self::writeback`].
    pub fn acquire_output(&mut self, dev: usize, key: TileKey, len: usize) -> Option<Acquire> {
        // An output tile may coincide with a cached input tile (TRMM/
        // TRSM chains read neighbour C tiles): invalidate every cached
        // copy first — the writer is about to make them stale.
        for holder in self.dir.write_back(&key) {
            self.alrus[holder].invalidate(&key);
        }
        let (offset, evicted, alloc_cost) = self.alrus[dev].insert(key, len)?;
        for ek in &evicted {
            self.dir.drop_holder(ek, dev);
        }
        self.dir.add_holder(key, dev);
        Some(Acquire { offset, source: Source::Host, evicted, alloc_cost })
    }

    /// Release one reader reference (stream-sync point, Alg. 1 line 17).
    pub fn release(&mut self, dev: usize, key: &TileKey) {
        self.alrus[dev].release(key);
    }

    /// M-state write-back (paper Fig. 3): the device wrote its C tile;
    /// all cached copies (including the writer's block) invalidate and
    /// the tile's directory state collapses to I. The caller moves the
    /// bytes to host before calling this.
    pub fn writeback(&mut self, dev: usize, key: &TileKey) {
        for holder in self.dir.write_back(key) {
            self.alrus[holder].invalidate(key);
        }
        // The writer's block may have readers==1 (the task itself); the
        // invalidate path dooms it and the final release frees it. If the
        // writer never registered (already invalidated), this is a no-op.
        let _ = dev;
    }

    /// Surgical whole-device invalidation for device loss: every block
    /// resident on `dev` is dropped (doomed if readers are in flight —
    /// the migrating task's releases reclaim them), and the directory
    /// forgets `dev` as a holder everywhere. Peer replicas on surviving
    /// devices stay valid, as do the host master copies; nothing on any
    /// other device is touched. Returns the number of tiles evicted.
    pub fn evict_device(&mut self, dev: usize) -> usize {
        let keys = self.alrus[dev].resident_keys();
        for k in &keys {
            self.alrus[dev].invalidate(k);
        }
        self.dir.drop_device(dev);
        keys.len()
    }

    /// Fault-injection hook: the next `n` allocation requests on `dev`
    /// are refused as if the arena were exhausted (see
    /// [`DeviceAllocator::force_fail`]).
    pub fn force_alloc_failure(&mut self, dev: usize, n: u64) {
        self.alrus[dev].alloc.force_fail(n);
    }

    /// Cache statistics of one device (cumulative since construction;
    /// see [`CacheStats::delta_since`] for the per-call view).
    pub fn stats(&self, dev: usize) -> CacheStats {
        let a = &self.alrus[dev];
        CacheStats { hits: a.hits, misses: a.misses, evictions: a.evictions }
    }

    /// Residency probe for tests.
    pub fn resident(&self, dev: usize) -> usize {
        self.alrus[dev].resident()
    }

    /// The device arena's allocator counters (bytes in use, high
    /// watermark, alloc/free totals) — the telemetry sampler's view of
    /// arena pressure.
    pub fn heap_stats(&self, dev: usize) -> crate::mem::HeapStats {
        self.alrus[dev].alloc.heap.stats()
    }

    /// Free bytes in `dev`'s arena *without* eviction — the prefetch
    /// depth-adaptation signal: lookahead spends spare headroom only,
    /// never eviction pressure.
    pub fn arena_headroom(&self, dev: usize) -> usize {
        let heap = &self.alrus[dev].alloc.heap;
        heap.capacity().saturating_sub(heap.in_use())
    }

    /// Consistency check across ALRUs and the directory (tests).
    pub fn validate(&self) -> Result<(), String> {
        for (d, a) in self.alrus.iter().enumerate() {
            a.validate().map_err(|e| format!("dev {d}: {e}"))?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tile::MatId;

    fn key(addr: usize) -> TileKey {
        TileKey::synthetic(addr, MatId::A, addr, 0)
    }

    /// 3 devices, all peers, 300-byte VRAM each.
    fn set3() -> TileCacheSet {
        TileCacheSet::new(
            &[300, 300, 300],
            vec![vec![1, 2], vec![0, 2], vec![0, 1]],
            AllocStrategy::FastHeap,
        )
    }

    #[test]
    fn miss_then_l1_hit() {
        let mut s = set3();
        let a = s.acquire(0, key(1), 100).unwrap();
        assert_eq!(a.source, Source::Host);
        s.release(0, &key(1));
        let a2 = s.acquire(0, key(1), 100).unwrap();
        assert_eq!(a2.source, Source::L1);
        assert_eq!(s.locality_score(0, &key(1)), 2);
        s.validate().unwrap();
    }

    #[test]
    fn peer_fetch_is_l2_hit() {
        let mut s = set3();
        s.acquire(0, key(1), 100).unwrap();
        // device 1 misses L1, finds device 0 as P2P source
        assert_eq!(s.locality_score(1, &key(1)), 1);
        let a = s.acquire(1, key(1), 100).unwrap();
        match a.source {
            Source::Peer { src, .. } => assert_eq!(src, 0),
            other => panic!("expected peer fetch, got {other:?}"),
        }
        // now shared: both hold it
        assert_eq!(s.dir.holders(&key(1)), &[0, 1]);
        s.validate().unwrap();
    }

    #[test]
    fn unreachable_peer_is_host_miss() {
        // device 2 unreachable from 0 and 1
        let mut s = TileCacheSet::new(
            &[300, 300, 300],
            vec![vec![1], vec![0], vec![]],
            AllocStrategy::FastHeap,
        );
        s.acquire(0, key(1), 100).unwrap();
        assert_eq!(s.locality_score(2, &key(1)), 0);
        let a = s.acquire(2, key(1), 100).unwrap();
        assert_eq!(a.source, Source::Host);
        s.validate().unwrap();
    }

    #[test]
    fn eviction_updates_directory() {
        let mut s = set3();
        s.acquire(0, key(1), 100).unwrap();
        s.acquire(0, key(2), 100).unwrap();
        s.acquire(0, key(3), 100).unwrap();
        s.release(0, &key(1));
        s.release(0, &key(2));
        s.release(0, &key(3));
        // inserting key4 evicts key1 (LRU); directory must drop it
        let a = s.acquire(0, key(4), 100).unwrap();
        assert!(a.evicted.contains(&key(1)));
        assert!(s.dir.holders(&key(1)).is_empty());
        // peer lookup for key1 from dev1 now misses to host
        assert_eq!(s.locality_score(1, &key(1)), 0);
        s.validate().unwrap();
    }

    #[test]
    fn writeback_invalidates_all_copies() {
        let mut s = set3();
        s.acquire(0, key(9), 100).unwrap();
        s.acquire(1, key(9), 100).unwrap();
        assert_eq!(s.dir.holders(&key(9)), &[0, 1]);
        // device 2 wrote the tile (as a C output): all copies die
        s.writeback(2, &key(9));
        assert!(s.dir.holders(&key(9)).is_empty());
        assert_eq!(s.locality_score(0, &key(9)), 0);
        // in-flight readers on 0/1 still release safely (doomed blocks)
        s.release(0, &key(9));
        s.release(1, &key(9));
        assert_eq!(s.resident(0), 0);
        s.validate().unwrap();
    }

    #[test]
    fn acquire_output_invalidates_stale_readers_copies() {
        let mut s = set3();
        // dev 0 cached the tile as an *input* earlier
        s.acquire(0, key(5), 100).unwrap();
        s.release(0, &key(5));
        // dev 1 now takes it as its task's *output*
        let a = s.acquire_output(1, key(5), 100).unwrap();
        assert_eq!(a.source, Source::Host);
        // dev 0's copy must be gone (it would read stale data next round)
        assert_eq!(s.locality_score(0, &key(5)), 1, "only dev1's copy remains");
        assert_eq!(s.dir.holders(&key(5)), &[1]);
        s.validate().unwrap();
    }

    #[test]
    fn evict_device_is_surgical() {
        let mut s = set3();
        s.acquire(0, key(1), 100).unwrap(); // exclusive to the dying device
        s.acquire(0, key(2), 100).unwrap(); // shared with dev 1
        s.acquire(1, key(2), 100).unwrap();
        s.acquire(2, key(3), 100).unwrap(); // bystander
        assert_eq!(s.evict_device(0), 2);
        assert_eq!(s.resident(0), 0);
        assert_eq!(s.dir.holders(&key(2)), &[1], "peer replica survives");
        assert_eq!(s.locality_score(2, &key(3)), 2, "bystander untouched");
        // in-flight readers on the dead device release safely (doomed)
        s.release(0, &key(1));
        s.release(0, &key(2));
        s.validate().unwrap();
    }

    #[test]
    fn forced_alloc_failure_reaches_the_device() {
        let mut s = set3();
        s.force_alloc_failure(0, 1);
        assert!(s.acquire(0, key(1), 100).is_none(), "armed acquire refused");
        assert!(s.acquire(0, key(1), 100).is_some(), "retry succeeds");
        assert!(s.acquire(1, key(2), 100).is_some(), "other devices unaffected");
        s.validate().unwrap();
    }

    #[test]
    fn async_fill_roundtrip_miss_then_hit() {
        let mut s = set3();
        let ticket = match s.acquire_async(0, key(1), 100).unwrap() {
            AsyncAcquire::Fill(t) => t,
            other => panic!("expected Fill, got {other:?}"),
        };
        assert_eq!(ticket.source, Source::Host);
        assert!(ticket.peer_src().is_none());
        // mid-fill the tile is a directory holder but not peer-servable
        assert_eq!(s.dir.holders(&key(1)), &[0]);
        match s.acquire_async(1, key(1), 100).unwrap() {
            AsyncAcquire::Fill(t) => assert_eq!(t.source, Source::Host, "pending peer skipped"),
            other => panic!("expected independent Fill on dev1, got {other:?}"),
        }
        assert!(s.complete_fill(0, &key(1), None));
        assert!(ticket.latch.is_ready());
        s.release(0, &key(1));
        // ready now: dev0 L1-hits, and dev2 gets dev0 as a pinned peer
        match s.acquire_async(0, key(1), 100).unwrap() {
            AsyncAcquire::Ready(a) => assert_eq!(a.source, Source::L1),
            other => panic!("expected Ready, got {other:?}"),
        }
        s.release(0, &key(1));
        let t2 = match s.acquire_async(2, key(1), 100).unwrap() {
            AsyncAcquire::Fill(t) => t,
            other => panic!("expected Fill, got {other:?}"),
        };
        assert_eq!(t2.peer_src(), Some(0));
        assert!(s.complete_fill(2, &key(1), t2.peer_src()));
        s.release(2, &key(1));
        s.validate().unwrap();
    }

    #[test]
    fn same_key_acquire_waits_on_the_latch() {
        let mut s = set3();
        let ticket = match s.acquire_async(0, key(1), 100).unwrap() {
            AsyncAcquire::Fill(t) => t,
            other => panic!("expected Fill, got {other:?}"),
        };
        let (offset, latch) = match s.acquire_async(0, key(1), 100).unwrap() {
            AsyncAcquire::InFlight { offset, latch } => (offset, latch),
            other => panic!("expected InFlight, got {other:?}"),
        };
        assert_eq!(offset, ticket.offset);
        let waiter = std::thread::spawn(move || latch.wait());
        assert!(s.complete_fill(0, &key(1), None));
        assert!(waiter.join().unwrap());
        s.release(0, &key(1)); // filler pin
        s.release(0, &key(1)); // waiter pin
        s.validate().unwrap();
    }

    #[test]
    fn abort_fill_tears_down_and_wakes_waiters_with_retry() {
        let mut s = set3();
        let ticket = match s.acquire_async(0, key(1), 100).unwrap() {
            AsyncAcquire::Fill(t) => t,
            other => panic!("expected Fill, got {other:?}"),
        };
        let latch = match s.acquire_async(0, key(1), 100).unwrap() {
            AsyncAcquire::InFlight { latch, .. } => latch,
            other => panic!("expected InFlight, got {other:?}"),
        };
        s.abort_fill(0, &key(1), ticket.peer_src());
        assert!(!latch.wait(), "waiter must be told to retry");
        assert!(s.dir.holders(&key(1)).is_empty());
        s.release(0, &key(1)); // waiter pin frees the doomed block
        assert_eq!(s.alrus[0].alloc.heap.in_use(), 0);
        // a fresh acquire starts over from host
        match s.acquire_async(0, key(1), 100).unwrap() {
            AsyncAcquire::Fill(t) => assert_eq!(t.source, Source::Host),
            other => panic!("expected Fill after abort, got {other:?}"),
        }
        s.complete_fill(0, &key(1), None);
        s.release(0, &key(1));
        s.validate().unwrap();
    }

    #[test]
    fn peer_source_pin_blocks_source_eviction_mid_copy() {
        let mut s = set3();
        s.acquire(0, key(1), 100).unwrap();
        s.release(0, &key(1));
        // dev1 plans a P2P copy from dev0; source must be pinned
        let t = match s.acquire_async(1, key(1), 100).unwrap() {
            AsyncAcquire::Fill(t) => t,
            other => panic!("expected Fill, got {other:?}"),
        };
        assert_eq!(t.peer_src(), Some(0));
        // pressure on dev0 cannot evict the pinned source
        assert!(s.acquire(0, key(2), 100).is_some());
        s.release(0, &key(2));
        assert!(s.acquire(0, key(3), 250).is_none(), "only the pinned source's bytes would fit");
        assert!(s.alrus[0].probe(&key(1)), "source survived mid-copy pressure");
        assert!(s.complete_fill(1, &key(1), t.peer_src()));
        s.release(1, &key(1));
        // pin dropped: dev0 can evict key1 now
        assert!(s.acquire(0, key(3), 250).is_some());
        s.validate().unwrap();
    }

    #[test]
    fn writeback_racing_a_fill_aborts_consumers() {
        let mut s = set3();
        let t = match s.acquire_async(0, key(7), 100).unwrap() {
            AsyncAcquire::Fill(t) => t,
            other => panic!("expected Fill, got {other:?}"),
        };
        // a C write-back invalidates the tile while its bytes are in flight
        s.writeback(1, &key(7));
        assert!(!s.complete_fill(0, &key(7), t.peer_src()), "stale fill must not go live");
        assert!(!t.latch.wait());
        s.release(0, &key(7)); // filler pin frees the doomed block
        assert_eq!(s.alrus[0].alloc.heap.in_use(), 0);
        s.validate().unwrap();
    }

    #[test]
    fn async_oom_returns_none_like_acquire() {
        let mut s = set3();
        s.acquire(0, key(1), 150).unwrap(); // pinned
        s.acquire(0, key(2), 150).unwrap(); // pinned
        assert!(s.acquire_async(0, key(3), 100).is_none());
        s.release(0, &key(1));
        assert!(matches!(s.acquire_async(0, key(3), 100), Some(AsyncAcquire::Fill(_))));
        s.complete_fill(0, &key(3), None);
        s.validate().unwrap();
    }

    #[test]
    fn acquire_output_async_invalidates_then_reserves() {
        let mut s = set3();
        s.acquire(0, key(5), 100).unwrap();
        s.release(0, &key(5));
        let t = s.acquire_output_async(1, key(5), 100).unwrap();
        assert_eq!(t.source, Source::Host);
        assert_eq!(s.dir.holders(&key(5)), &[1]);
        assert!(s.locality_score(0, &key(5)) < 2, "stale input copy invalidated");
        assert!(s.complete_fill(1, &key(5), None));
        s.release(1, &key(5));
        s.validate().unwrap();
    }

    #[test]
    fn full_cache_with_pinned_tiles_returns_none() {
        let mut s = set3();
        s.acquire(0, key(1), 150).unwrap(); // readers = 1, pinned
        s.acquire(0, key(2), 150).unwrap(); // readers = 1, pinned
        assert!(s.acquire(0, key(3), 100).is_none());
        // after a sync point releases readers, it succeeds
        s.release(0, &key(1));
        assert!(s.acquire(0, key(3), 100).is_some());
        s.validate().unwrap();
    }
}
