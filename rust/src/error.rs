//! BLASX error types.

use thiserror::Error;

/// Library-wide error type.
#[derive(Debug, Error)]
pub enum Error {
    /// Invalid argument to a BLAS routine (xerbla-style): the 1-based
    /// parameter index and a human-readable description.
    #[error("blasx: illegal parameter #{index} to {routine}: {reason}")]
    IllegalParam {
        routine: &'static str,
        index: usize,
        reason: String,
    },

    /// The runtime context is misconfigured (no devices, bad tile size…).
    #[error("blasx config error: {0}")]
    Config(String),

    /// PJRT / XLA failure while loading or executing an artifact.
    #[error("blasx runtime error: {0}")]
    Runtime(String),

    /// A required AOT artifact is missing — run `make artifacts`.
    #[error("missing artifact `{0}` (run `make artifacts`)")]
    MissingArtifact(String),

    /// The artifact store (manifest.json / *.hlo.txt) is unreadable.
    #[error("blasx artifact error: {0}")]
    Artifact(String),

    /// Device memory exhausted and nothing evictable.
    #[error("device {device} out of memory: need {need} bytes, capacity {capacity}")]
    OutOfDeviceMemory {
        device: usize,
        need: usize,
        capacity: usize,
    },

    /// Internal invariant violation (a bug in BLASX itself).
    #[error("blasx internal error: {0}")]
    Internal(String),

    /// I/O error (artifact files, trace export…).
    #[error("blasx io error: {0}")]
    Io(#[from] std::io::Error),
}

impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Runtime(format!("{e:?}"))
    }
}

/// Library-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;

/// Helper to build an IllegalParam error.
pub fn illegal(routine: &'static str, index: usize, reason: impl Into<String>) -> Error {
    Error::IllegalParam { routine, index, reason: reason.into() }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn error_messages_render() {
        let e = illegal("dgemm", 3, "m < 0");
        assert!(e.to_string().contains("dgemm"));
        assert!(e.to_string().contains("#3"));
        let e = Error::MissingArtifact("gemm_nn_f64_256".into());
        assert!(e.to_string().contains("make artifacts"));
    }
}
