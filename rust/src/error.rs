//! BLASX error types.
//!
//! Hand-written `Display`/`Error` impls — the offline crate set has no
//! `thiserror`, and the surface is small enough that the derive buys
//! nothing but a dependency.

use std::fmt;

/// Library-wide error type.
#[derive(Debug)]
pub enum Error {
    /// Invalid argument to a BLAS routine (xerbla-style): the 1-based
    /// parameter index and a human-readable description.
    IllegalParam {
        routine: &'static str,
        index: usize,
        reason: String,
    },

    /// The runtime context is misconfigured (no devices, bad tile size…).
    Config(String),

    /// PJRT / XLA failure while loading or executing an artifact.
    Runtime(String),

    /// A required AOT artifact is missing — run `make artifacts`.
    MissingArtifact(String),

    /// The artifact store (manifest.json / *.hlo.txt) is unreadable.
    Artifact(String),

    /// Device memory exhausted and nothing evictable.
    OutOfDeviceMemory {
        device: usize,
        need: usize,
        capacity: usize,
    },

    /// Internal invariant violation (a bug in BLASX itself).
    Internal(String),

    /// I/O error (artifact files, trace export…).
    Io(std::io::Error),

    /// The device set degraded below what the job needs (every device
    /// faulted mid-run and no survivor can retire the remaining tasks).
    Degraded(String),

    /// The job's per-call deadline elapsed before it retired; the job
    /// was aborted at a round boundary.
    DeadlineExceeded {
        /// The configured limit, in milliseconds.
        limit_ms: u64,
    },

    /// The job was cancelled via `JobHandle::cancel` (cooperative,
    /// honoured at the next round boundary).
    Cancelled,

    /// Admission refused the job: the runtime's in-flight bound or the
    /// tenant's quota is full. Retry after in-flight jobs retire.
    Backpressure(String),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::IllegalParam { routine, index, reason } => {
                write!(f, "blasx: illegal parameter #{index} to {routine}: {reason}")
            }
            Error::Config(msg) => write!(f, "blasx config error: {msg}"),
            Error::Runtime(msg) => write!(f, "blasx runtime error: {msg}"),
            Error::MissingArtifact(name) => {
                write!(f, "missing artifact `{name}` (run `make artifacts`)")
            }
            Error::Artifact(msg) => write!(f, "blasx artifact error: {msg}"),
            Error::OutOfDeviceMemory { device, need, capacity } => {
                write!(f, "device {device} out of memory: need {need} bytes, capacity {capacity}")
            }
            Error::Internal(msg) => write!(f, "blasx internal error: {msg}"),
            Error::Io(e) => write!(f, "blasx io error: {e}"),
            Error::Degraded(msg) => write!(f, "blasx degraded beyond recovery: {msg}"),
            Error::DeadlineExceeded { limit_ms } => {
                write!(f, "blasx job deadline exceeded ({limit_ms} ms)")
            }
            Error::Cancelled => write!(f, "blasx job cancelled"),
            Error::Backpressure(msg) => write!(f, "blasx admission backpressure: {msg}"),
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Runtime(format!("{e:?}"))
    }
}

/// Library-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;

/// Helper to build an IllegalParam error.
pub fn illegal(routine: &'static str, index: usize, reason: impl Into<String>) -> Error {
    Error::IllegalParam { routine, index, reason: reason.into() }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn error_messages_render() {
        let e = illegal("dgemm", 3, "m < 0");
        assert!(e.to_string().contains("dgemm"));
        assert!(e.to_string().contains("#3"));
        let e = Error::MissingArtifact("gemm_nn_f64_256".into());
        assert!(e.to_string().contains("make artifacts"));
    }

    #[test]
    fn fault_tolerance_errors_render_distinctly() {
        let texts = [
            Error::Degraded("all 2 devices lost".into()).to_string(),
            Error::DeadlineExceeded { limit_ms: 250 }.to_string(),
            Error::Cancelled.to_string(),
            Error::Backpressure("tenant 3 at quota 8".into()).to_string(),
        ];
        assert!(texts[0].contains("degraded"));
        assert!(texts[1].contains("deadline") && texts[1].contains("250"));
        assert!(texts[2].contains("cancelled"));
        assert!(texts[3].contains("backpressure") && texts[3].contains("quota"));
        // each message is distinguishable from the others
        for (i, a) in texts.iter().enumerate() {
            for b in texts.iter().skip(i + 1) {
                assert_ne!(a, b);
            }
        }
    }

    #[test]
    fn io_errors_chain_as_source() {
        use std::error::Error as _;
        let e = Error::from(std::io::Error::new(std::io::ErrorKind::NotFound, "gone"));
        assert!(e.to_string().contains("io error"));
        assert!(e.source().is_some());
    }
}
