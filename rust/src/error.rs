//! BLASX error types.
//!
//! Hand-written `Display`/`Error` impls — the offline crate set has no
//! `thiserror`, and the surface is small enough that the derive buys
//! nothing but a dependency.

use std::fmt;

/// Library-wide error type.
#[derive(Debug)]
pub enum Error {
    /// Invalid argument to a BLAS routine (xerbla-style): the 1-based
    /// parameter index and a human-readable description.
    IllegalParam {
        routine: &'static str,
        index: usize,
        reason: String,
    },

    /// The runtime context is misconfigured (no devices, bad tile size…).
    Config(String),

    /// PJRT / XLA failure while loading or executing an artifact.
    Runtime(String),

    /// A required AOT artifact is missing — run `make artifacts`.
    MissingArtifact(String),

    /// The artifact store (manifest.json / *.hlo.txt) is unreadable.
    Artifact(String),

    /// Device memory exhausted and nothing evictable.
    OutOfDeviceMemory {
        device: usize,
        need: usize,
        capacity: usize,
    },

    /// Internal invariant violation (a bug in BLASX itself).
    Internal(String),

    /// I/O error (artifact files, trace export…).
    Io(std::io::Error),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::IllegalParam { routine, index, reason } => {
                write!(f, "blasx: illegal parameter #{index} to {routine}: {reason}")
            }
            Error::Config(msg) => write!(f, "blasx config error: {msg}"),
            Error::Runtime(msg) => write!(f, "blasx runtime error: {msg}"),
            Error::MissingArtifact(name) => {
                write!(f, "missing artifact `{name}` (run `make artifacts`)")
            }
            Error::Artifact(msg) => write!(f, "blasx artifact error: {msg}"),
            Error::OutOfDeviceMemory { device, need, capacity } => {
                write!(f, "device {device} out of memory: need {need} bytes, capacity {capacity}")
            }
            Error::Internal(msg) => write!(f, "blasx internal error: {msg}"),
            Error::Io(e) => write!(f, "blasx io error: {e}"),
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Runtime(format!("{e:?}"))
    }
}

/// Library-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;

/// Helper to build an IllegalParam error.
pub fn illegal(routine: &'static str, index: usize, reason: impl Into<String>) -> Error {
    Error::IllegalParam { routine, index, reason: reason.into() }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn error_messages_render() {
        let e = illegal("dgemm", 3, "m < 0");
        assert!(e.to_string().contains("dgemm"));
        assert!(e.to_string().contains("#3"));
        let e = Error::MissingArtifact("gemm_nn_f64_256".into());
        assert!(e.to_string().contains("make artifacts"));
    }

    #[test]
    fn io_errors_chain_as_source() {
        use std::error::Error as _;
        let e = Error::from(std::io::Error::new(std::io::ErrorKind::NotFound, "gone"));
        assert!(e.to_string().contains("io error"));
        assert!(e.source().is_some());
    }
}
