//! Tile executor: run one tile-op step through a compiled PJRT program.
//!
//! The coordinator hands us column-major T×T tile buffers (the device
//! heap's block layout); XLA literals are row-major, so pack/unpack
//! transposes — an O(T²) shuffle against the O(T³) kernel, invisible in
//! the profile (verified in EXPERIMENTS.md §Perf).
//!
//! Marshalling scratch is per-thread and reused across `run` calls
//! (mirroring the kernel-side `PackBuf`): the row-major staging bytes
//! live in a thread-local, and the output tile comes from the hostblas
//! scratch free-list — steady-state execution allocates nothing here.
//!
//! Argument marshalling follows the artifact manifest signature, so this
//! file knows nothing about individual variants.

use super::artifact::ArgSlot;
use super::pjrt::PjrtPool;
use crate::api::{Dtype, Scalar};
use crate::hostblas::pack::{give_buf, take_buf};
use crate::{Error, Result};
use std::cell::RefCell;

thread_local! {
    /// Reusable row-major staging buffer for [`pack_rm`] (one per
    /// thread; `run` is re-entrant only across threads).
    static PACK_SCRATCH: RefCell<Vec<u8>> = const { RefCell::new(Vec::new()) };
}

/// Stateless handle over the process-wide PJRT pool.
pub struct TileExecutor {
    pool: &'static PjrtPool,
}

/// Pack a column-major `t×t` tile into a row-major byte vector.
fn pack_rm<T: Scalar>(src: &[T], t: usize, scratch: &mut Vec<u8>) {
    let esz = std::mem::size_of::<T>();
    scratch.clear();
    scratch.reserve(t * t * esz);
    for r in 0..t {
        for c in 0..t {
            let v = src[c * t + r];
            scratch.extend_from_slice(unsafe {
                std::slice::from_raw_parts(&v as *const T as *const u8, esz)
            });
        }
    }
}

/// Unpack a row-major element slice into a column-major tile buffer.
fn unpack_cm<T: Scalar>(src: &[T], t: usize, dst: &mut [T]) {
    for r in 0..t {
        for c in 0..t {
            dst[c * t + r] = src[r * t + c];
        }
    }
}

fn elem_type(dtype: Dtype) -> xla::ElementType {
    match dtype {
        Dtype::F32 => xla::ElementType::F32,
        Dtype::F64 => xla::ElementType::F64,
    }
}

impl TileExecutor {
    /// Connect to the process-wide pool (compiling nothing yet).
    pub fn new() -> Result<TileExecutor> {
        Ok(TileExecutor { pool: PjrtPool::global()? })
    }

    /// Artifact availability probe (used by the coordinator to pick
    /// between the PJRT path and the hostblas fallback).
    pub fn available(&self, name: &str, dtype: Dtype, t: usize) -> bool {
        self.pool.store().available(name, dtype, t)
    }

    /// Execute one tile-op step: `c` is updated in place. `a`/`b` are
    /// required or forbidden per the variant's manifest signature; all
    /// tile slices are column-major `t*t`.
    pub fn run<T: Scalar>(
        &self,
        name: &str,
        t: usize,
        a: Option<&[T]>,
        b: Option<&[T]>,
        c: &mut [T],
        alpha: T,
        beta: T,
    ) -> Result<()> {
        debug_assert_eq!(c.len(), t * t);
        let sig = self.pool.store().signature(name)?.to_vec();
        let exe = self.pool.executable(name, T::DTYPE, t)?;
        let ety = elem_type(T::DTYPE);

        let args = PACK_SCRATCH.with(|cell| -> Result<Vec<xla::Literal>> {
            let mut guard = cell.borrow_mut();
            let scratch = &mut *guard;
            let mut args: Vec<xla::Literal> = Vec::with_capacity(sig.len());
            for slot in &sig {
                let lit = match slot {
                    ArgSlot::TileA => {
                        let a = a.ok_or_else(|| {
                            Error::Runtime(format!("{name}: missing tile operand a"))
                        })?;
                        debug_assert_eq!(a.len(), t * t);
                        pack_rm(a, t, scratch);
                        xla::Literal::create_from_shape_and_untyped_data(ety, &[t, t], scratch)
                            .map_err(|e| Error::Runtime(format!("literal a: {e}")))?
                    }
                    ArgSlot::TileB => {
                        let b = b.ok_or_else(|| {
                            Error::Runtime(format!("{name}: missing tile operand b"))
                        })?;
                        debug_assert_eq!(b.len(), t * t);
                        pack_rm(b, t, scratch);
                        xla::Literal::create_from_shape_and_untyped_data(ety, &[t, t], scratch)
                            .map_err(|e| Error::Runtime(format!("literal b: {e}")))?
                    }
                    ArgSlot::TileC => {
                        pack_rm(c, t, scratch);
                        xla::Literal::create_from_shape_and_untyped_data(ety, &[t, t], scratch)
                            .map_err(|e| Error::Runtime(format!("literal c: {e}")))?
                    }
                    ArgSlot::Alpha => scalar_literal(alpha, ety)?,
                    ArgSlot::Beta => scalar_literal(beta, ety)?,
                };
                args.push(lit);
            }
            Ok(args)
        })?;

        let result = exe
            .execute::<xla::Literal>(&args)
            .map_err(|e| Error::Runtime(format!("execute {name}: {e}")))?;
        let lit = result[0][0]
            .to_literal_sync()
            .map_err(|e| Error::Runtime(format!("fetch {name}: {e}")))?
            // aot.py lowers with return_tuple=True: unwrap the 1-tuple.
            .to_tuple1()
            .map_err(|e| Error::Runtime(format!("untuple {name}: {e}")))?;

        let mut out = take_buf::<T>(t * t);
        copy_out(&lit, &mut out)?;
        unpack_cm(&out, t, c);
        give_buf(out);
        Ok(())
    }
}

fn scalar_literal<T: Scalar>(v: T, ety: xla::ElementType) -> Result<xla::Literal> {
    let esz = std::mem::size_of::<T>();
    let bytes =
        unsafe { std::slice::from_raw_parts(&v as *const T as *const u8, esz) }.to_vec();
    xla::Literal::create_from_shape_and_untyped_data(ety, &[], &bytes)
        .map_err(|e| Error::Runtime(format!("scalar literal: {e}")))
}

fn copy_out<T: Scalar>(lit: &xla::Literal, dst: &mut [T]) -> Result<()> {
    // Monomorphize through the two concrete ArrayElement impls.
    match T::DTYPE {
        Dtype::F32 => {
            let dst32 = unsafe {
                std::slice::from_raw_parts_mut(dst.as_mut_ptr() as *mut f32, dst.len())
            };
            lit.copy_raw_to::<f32>(dst32)
                .map_err(|e| Error::Runtime(format!("copy_raw_to: {e}")))
        }
        Dtype::F64 => {
            let dst64 = unsafe {
                std::slice::from_raw_parts_mut(dst.as_mut_ptr() as *mut f64, dst.len())
            };
            lit.copy_raw_to::<f64>(dst64)
                .map_err(|e| Error::Runtime(format!("copy_raw_to: {e}")))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pack_unpack_roundtrip() {
        let t = 3;
        let cm: Vec<f64> = (0..9).map(|x| x as f64).collect();
        let mut bytes = Vec::new();
        pack_rm(&cm, t, &mut bytes);
        let rm: Vec<f64> = bytes
            .chunks_exact(8)
            .map(|b| f64::from_le_bytes(b.try_into().unwrap()))
            .collect();
        // column-major [0,1,2 | 3,4,5 | 6,7,8] => row-major rows (0,3,6),(1,4,7),(2,5,8)
        assert_eq!(rm, vec![0.0, 3.0, 6.0, 1.0, 4.0, 7.0, 2.0, 5.0, 8.0]);
        let mut back = vec![0.0; 9];
        unpack_cm(&rm, t, &mut back);
        assert_eq!(back, cm);
    }
}
