//! Artifact store: the AOT output directory written by `make artifacts`.
//!
//! `python/compile/aot.py` emits one HLO-text program per (tile-op
//! variant, dtype, tile size) plus a `manifest.json` recording each
//! variant's argument signature. This module locates artifacts and parses
//! the manifest so the executor can marshal arguments without guessing.

use crate::api::Dtype;
use crate::util::json::{self, Json};
use crate::{Error, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};

/// One operand slot of an artifact's calling convention.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ArgSlot {
    /// T×T tile operand `a`.
    TileA,
    /// T×T tile operand `b`.
    TileB,
    /// T×T accumulator tile `c`.
    TileC,
    /// Runtime scalar `alpha`.
    Alpha,
    /// Runtime scalar `beta`.
    Beta,
}

impl ArgSlot {
    fn from_str(s: &str) -> Option<ArgSlot> {
        match s {
            "a" => Some(ArgSlot::TileA),
            "b" => Some(ArgSlot::TileB),
            "c" => Some(ArgSlot::TileC),
            "alpha" => Some(ArgSlot::Alpha),
            "beta" => Some(ArgSlot::Beta),
            _ => None,
        }
    }
}

/// Parsed manifest: variant name → ordered argument slots.
#[derive(Debug)]
pub struct ArtifactStore {
    dir: PathBuf,
    sigs: HashMap<String, Vec<ArgSlot>>,
    /// Tile sizes the artifact set was built for.
    pub tile_sizes: Vec<usize>,
    /// Dtypes the artifact set was built for.
    pub dtypes: Vec<Dtype>,
}

impl ArtifactStore {
    /// Open `dir` and parse its `manifest.json`.
    pub fn open(dir: impl AsRef<Path>) -> Result<ArtifactStore> {
        let dir = dir.as_ref().to_path_buf();
        let man_path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&man_path).map_err(|e| {
            Error::Artifact(format!(
                "cannot read {} (run `make artifacts` first): {e}",
                man_path.display()
            ))
        })?;
        let man = json::parse(&text)
            .map_err(|e| Error::Artifact(format!("manifest parse error: {e}")))?;
        let mut sigs = HashMap::new();
        let kernels = man
            .get("kernels")
            .ok_or_else(|| Error::Artifact("manifest missing `kernels`".into()))?;
        if let Json::Obj(entries) = kernels {
            for (name, spec) in entries {
                let args = spec
                    .get("args")
                    .and_then(Json::as_arr)
                    .ok_or_else(|| Error::Artifact(format!("kernel {name} missing args")))?;
                let slots = args
                    .iter()
                    .map(|a| {
                        a.as_str().and_then(ArgSlot::from_str).ok_or_else(|| {
                            Error::Artifact(format!("kernel {name}: bad arg {a:?}"))
                        })
                    })
                    .collect::<Result<Vec<_>>>()?;
                sigs.insert(name.clone(), slots);
            }
        }
        let tile_sizes = man
            .get("tile_sizes")
            .and_then(Json::as_arr)
            .map(|xs| xs.iter().filter_map(Json::as_usize).collect())
            .unwrap_or_default();
        let dtypes = man
            .get("dtypes")
            .and_then(Json::as_arr)
            .map(|xs| {
                xs.iter()
                    .filter_map(Json::as_str)
                    .filter_map(|s| match s {
                        "f32" => Some(Dtype::F32),
                        "f64" => Some(Dtype::F64),
                        _ => None,
                    })
                    .collect()
            })
            .unwrap_or_default();
        Ok(ArtifactStore { dir, sigs, tile_sizes, dtypes })
    }

    /// Default location: `$BLASX_ARTIFACTS` or `<repo>/artifacts`.
    pub fn open_default() -> Result<ArtifactStore> {
        let dir = std::env::var("BLASX_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|_| default_dir());
        ArtifactStore::open(dir)
    }

    /// The argument signature of a variant.
    pub fn signature(&self, name: &str) -> Result<&[ArgSlot]> {
        self.sigs
            .get(name)
            .map(Vec::as_slice)
            .ok_or_else(|| Error::Artifact(format!("unknown kernel variant {name}")))
    }

    /// Path of the HLO text for `(name, dtype, t)`.
    pub fn hlo_path(&self, name: &str, dtype: Dtype, t: usize) -> PathBuf {
        self.dir.join(format!("{name}_{}_{t}.hlo.txt", dtype.name()))
    }

    /// Does the artifact file exist?
    pub fn available(&self, name: &str, dtype: Dtype, t: usize) -> bool {
        self.sigs.contains_key(name) && self.hlo_path(name, dtype, t).exists()
    }

    /// All variant names in the manifest.
    pub fn variants(&self) -> impl Iterator<Item = &str> {
        self.sigs.keys().map(String::as_str)
    }
}

/// `<workspace>/artifacts` resolved relative to the crate root at build
/// time (works from `cargo run/test/bench` in any subdirectory).
pub fn default_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

#[cfg(test)]
mod tests {
    use super::*;

    fn write_manifest(dir: &Path, body: &str) {
        std::fs::create_dir_all(dir).unwrap();
        std::fs::write(dir.join("manifest.json"), body).unwrap();
    }

    fn tmp(name: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("blasx_artifact_test_{name}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        d
    }

    #[test]
    fn parses_manifest() {
        let d = tmp("parse");
        write_manifest(
            &d,
            r#"{"tile_sizes":[64,256],"dtypes":["f32","f64"],
               "kernels":{"gemm_nn":{"args":["a","b","c","alpha","beta"]},
                          "scal":{"args":["c","beta"]}}}"#,
        );
        let s = ArtifactStore::open(&d).unwrap();
        assert_eq!(
            s.signature("gemm_nn").unwrap(),
            &[ArgSlot::TileA, ArgSlot::TileB, ArgSlot::TileC, ArgSlot::Alpha, ArgSlot::Beta]
        );
        assert_eq!(s.signature("scal").unwrap(), &[ArgSlot::TileC, ArgSlot::Beta]);
        assert_eq!(s.tile_sizes, vec![64, 256]);
        assert_eq!(s.dtypes, vec![Dtype::F32, Dtype::F64]);
        assert!(s.signature("nope").is_err());
        let p = s.hlo_path("gemm_nn", Dtype::F64, 256);
        assert!(p.ends_with("gemm_nn_f64_256.hlo.txt"));
        assert!(!s.available("gemm_nn", Dtype::F64, 256));
        std::fs::remove_dir_all(&d).unwrap();
    }

    #[test]
    fn missing_manifest_is_helpful() {
        let d = tmp("missing");
        let err = ArtifactStore::open(&d).unwrap_err();
        assert!(err.to_string().contains("make artifacts"), "{err}");
    }

    #[test]
    fn rejects_bad_arg() {
        let d = tmp("badarg");
        write_manifest(&d, r#"{"kernels":{"x":{"args":["q"]}}}"#);
        assert!(ArtifactStore::open(&d).is_err());
        std::fs::remove_dir_all(&d).unwrap();
    }
}
