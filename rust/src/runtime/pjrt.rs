//! PJRT plumbing: one process-wide CPU client plus an executable cache.
//!
//! Pattern per /opt/xla-example/load_hlo: `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `XlaComputation::from_proto` →
//! `client.compile` → `execute`. Compilation is the expensive step
//! (tens of ms per artifact), so executables are compiled lazily on first
//! use and cached for the life of the process, keyed by
//! `(variant, dtype, tile)`.
//!
//! The PJRT CPU client is thread-safe for `execute`; the cache hands out
//! `Arc`s so worker threads never hold the cache lock across a kernel.

use super::artifact::ArtifactStore;
use crate::api::Dtype;
use crate::{Error, Result};
use crate::util::once::OnceCell;
use std::collections::HashMap;
use std::sync::{Arc, Mutex};

/// Cache key: one compiled tile program.
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
pub struct ExeKey {
    pub name: String,
    pub dtype: Dtype,
    pub t: usize,
}

/// Lazily-initialized process-wide PJRT CPU client + compiled programs.
pub struct PjrtPool {
    client: xla::PjRtClient,
    store: ArtifactStore,
    exes: Mutex<HashMap<ExeKey, Arc<xla::PjRtLoadedExecutable>>>,
    /// Number of compiles performed (observability; tests assert reuse).
    pub compiles: std::sync::atomic::AtomicU64,
}

// SAFETY: the PJRT CPU client is internally synchronized; the xla crate
// merely forgot the auto-traits on its opaque pointers. Execution from
// multiple threads is the documented PJRT usage model.
unsafe impl Send for PjrtPool {}
unsafe impl Sync for PjrtPool {}

static POOL: OnceCell<PjrtPool> = OnceCell::new();

impl PjrtPool {
    /// The process-wide pool, opening the default artifact directory on
    /// first use.
    pub fn global() -> Result<&'static PjrtPool> {
        POOL.get_or_try_init(|| {
            let client = xla::PjRtClient::cpu()
                .map_err(|e| Error::Runtime(format!("PjRtClient::cpu: {e}")))?;
            let store = ArtifactStore::open_default()?;
            Ok(PjrtPool {
                client,
                store,
                exes: Mutex::new(HashMap::new()),
                compiles: std::sync::atomic::AtomicU64::new(0),
            })
        })
    }

    pub fn store(&self) -> &ArtifactStore {
        &self.store
    }

    /// Fetch (compiling on miss) the executable for a tile program.
    pub fn executable(
        &self,
        name: &str,
        dtype: Dtype,
        t: usize,
    ) -> Result<Arc<xla::PjRtLoadedExecutable>> {
        let key = ExeKey { name: name.to_string(), dtype, t };
        if let Some(exe) = self.exes.lock().unwrap().get(&key) {
            return Ok(exe.clone());
        }
        // Compile outside the lock: first-touch compiles of distinct
        // kernels may proceed concurrently; a duplicate compile of the
        // same key is benign (last insert wins, both exes are valid).
        let path = self.store.hlo_path(name, dtype, t);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str()
                .ok_or_else(|| Error::Runtime(format!("non-utf8 path {}", path.display())))?,
        )
        .map_err(|e| Error::Runtime(format!("load {}: {e}", path.display())))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| Error::Runtime(format!("compile {name}_{}_{t}: {e}", dtype.name())))?;
        self.compiles.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        let exe = Arc::new(exe);
        self.exes.lock().unwrap().insert(key, exe.clone());
        Ok(exe)
    }

    /// Number of distinct compiled programs resident.
    pub fn cached(&self) -> usize {
        self.exes.lock().unwrap().len()
    }
}
