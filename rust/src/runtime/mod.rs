//! PJRT runtime: load `artifacts/*.hlo.txt` (AOT output of
//! `python/compile/aot.py`) and execute tile programs from the L3 hot
//! path. Python never runs here — the artifacts are the only bridge.
//!
//! - [`artifact`]: manifest + artifact discovery
//! - [`pjrt`]: process-wide CPU client + lazy executable cache
//! - [`executor`]: per-step literal marshalling and execution

pub mod artifact;
pub mod executor;
pub mod pjrt;

pub use artifact::{ArgSlot, ArtifactStore};
pub use executor::TileExecutor;
pub use pjrt::PjrtPool;
