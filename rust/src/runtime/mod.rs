//! The execution runtime: the resident device service, the persistent
//! kernel pool, and the PJRT bridge.
//!
//! - [`service`]: long-lived per-device worker threads + cross-call
//!   tile-cache reuse with epoch invalidation (the warm engine behind
//!   [`crate::api::Context`]), fronted by the multi-tenant job
//!   scheduler of [`crate::serve`] — concurrent calls interleave on
//!   the resident workers
//! - [`pool`]: the process-wide kernel thread pool `gemm_mt` fans tile
//!   kernels across (pack-scratch thread-locals survive between calls)
//! - [`artifact`]: manifest + artifact discovery
//! - [`pjrt`]: process-wide CPU client + lazy executable cache
//! - [`executor`]: per-step literal marshalling and execution
//!
//! Python never runs here — the AOT artifacts are the only bridge.

pub mod artifact;
pub mod executor;
pub mod pjrt;
pub mod pool;
pub mod service;

pub use artifact::{ArgSlot, ArtifactStore};
pub use executor::TileExecutor;
pub use pjrt::PjrtPool;
pub use pool::KernelPool;
pub use service::Runtime;
