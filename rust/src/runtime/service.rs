//! The resident device runtime: long-lived worker threads, persistent
//! arenas/tile-caches, cross-call invalidation epochs — and, since the
//! serve PR, a **concurrent multi-tenant job scheduler** in front of
//! them.
//!
//! BLASX's headline wins come from a *persistent* dynamic runtime whose
//! tile cache amortizes transfers across task progression. Tearing the
//! engine down per API call (the one-shot `run_real` path) forfeits
//! exactly that: worker threads respawn, arenas reallocate, and every
//! call re-transfers tiles the previous call already staged. The
//! [`Runtime`] keeps the [`EngineCore`] — device arenas + ALRU/MESI-X
//! caches + parked worker threads — alive between calls, so a call
//! touching host matrices the runtime has seen before starts on a warm
//! cache (L1/L2 tile hits instead of host DMA).
//!
//! ## Lifecycle
//!
//! - **Boot** — lazy: the first call through a persistent
//!   [`crate::api::Context`] spawns one worker thread per virtual
//!   device and allocates the arenas. Clones of a `Context` share the
//!   booted runtime.
//! - **Calls** — every call (blocking, scope-async, or C-ABI) is **admitted** as
//!   a *job* into the [`crate::serve::admission::JobTable`]: its
//!   operand byte ranges are compared against every live job's to wire
//!   dependency edges (aliasing calls run in admission order,
//!   bit-for-bit equal to serial execution; disjoint calls overlap),
//!   its input epochs are resolved and output epochs bumped under the
//!   same lock, and the resident workers then pull scheduler rounds
//!   across ALL runnable jobs under flop-weighted fair interleaving
//!   (see [`crate::serve::fairness`]). Blocking calls are
//!   submit-then-wait; scope-async calls return a
//!   [`crate::serve::JobHandle`] and the scope close waits for them.
//! - **Invalidation** — every output matrix bumps an *epoch* for its
//!   byte range in the [`EpochRegistry`] at admission time; input
//!   wraps resolve their epoch from the registry. Epochs are folded
//!   into [`crate::tile::TileKey`], so tiles cached from a buffer that
//!   has since been rewritten become unreachable (and age out of the
//!   ALRU) instead of serving stale bytes. Users who mutate an *input*
//!   buffer between calls must declare it via
//!   [`crate::api::Context::invalidate_host`] — the library cannot
//!   observe foreign writes to host memory.
//! - **Shutdown** — dropping the last handle (the last `Context`
//!   clone, plus any outstanding `JobHandle`s, which keep the runtime
//!   alive) signals the workers and joins them.
//!
//! Tile-size changes between calls cost **nothing**: the tile size is
//! a discriminant of [`crate::tile::TileKey`], so each geometry is its
//! own cache generation — mixed-`t` jobs coexist in the caches and
//! overlap on the devices like any other disjoint jobs, and a switch
//! neither barriers nor purges (stale generations age out of the ALRU
//! like any other cold tiles). A failed job schedules **no** purge:
//! the engine releases its pins on every abort path, and a lost
//! device's cache entries are evicted surgically, so other tenants'
//! warm tiles survive a neighbour's failure.
//!
//! ## Tenant protection
//!
//! Admission is bounded ([`RunConfig::admit_capacity`] live jobs
//! overall, [`RunConfig::tenant_quota`] per submitting tenant); over
//! either limit the call fails fast with [`Error::Backpressure`]
//! instead of queueing unboundedly. Jobs may carry a deadline
//! ([`RunConfig::deadline_ms`]) and every [`crate::serve::JobHandle`]
//! can [`cancel`](crate::serve::JobHandle::cancel); both are enforced
//! cooperatively at round boundaries by
//! [`crate::serve::admission::JobTable::reap_expired`], so a reaped
//! job aborts with [`Error::DeadlineExceeded`] / [`Error::Cancelled`]
//! while its neighbours' rounds run undisturbed.

use crate::api::types::Trans;
use crate::api::Scalar;
use crate::cache::CacheStats;
use crate::coordinator::config::RunConfig;
use crate::coordinator::real_engine::{
    block_bytes, worker_round, EngineCore, JobState, JobStats, Mats, OwnedProblem, RealReport,
    Round, TransferStats, PARK_TIMEOUT,
};
use crate::coordinator::FaultStats;
use crate::error::{Error, Result};
use crate::fault::FaultPlan;
use crate::hostblas;
use crate::mem::AllocStrategy;
use crate::serve::admission::{JobCtl, JobSpan, JobTable};
use crate::serve::{fairness, DeviceJob};
use crate::task::TaskSet;
use crate::trace::telemetry::{fill_windowed_rates, DevGauges, Telemetry, TelemetrySample};
use crate::trace::{tenant_id, FlightRecorder, JobRec, MetricsRegistry, SpanKind};
use crate::util::json::Json;
use std::collections::{BTreeMap, HashSet};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Host-buffer invalidation generations, keyed by byte range.
///
/// `bump` opens a fresh generation for a range (outputs at admission
/// time, or user-declared mutations); `epoch_of` resolves the newest
/// generation overlapping a range (inputs at admission time).
///
/// The store is an ordered map of **disjoint** intervals (an interval
/// tree degenerate-cased on non-overlap): each bump removes covered
/// intervals and trims partial overlaps, so the registry stays
/// proportional to the number of distinct *live* buffer-range
/// fragments rather than the call count. A serving daemon cycling
/// through millions of distinct short-lived output buffers would still
/// accrete fragments, so past [`MAX_EXACT_RANGES`] the registry falls
/// back to coarse pages: intervals are rounded out to
/// [`COARSE_PAGE`]-aligned runs and merged keeping the **max** epoch.
/// That direction is conservative — `epoch_of` may report a *newer*
/// generation than the exact answer, costing a spurious tile re-fetch,
/// never a stale hit.
#[derive(Default)]
struct EpochRegistry {
    counter: u64,
    /// Disjoint intervals: start → (end, epoch), ordered by start.
    ranges: BTreeMap<usize, (usize, u64)>,
}

/// Interval count that triggers coarse-page compaction.
const MAX_EXACT_RANGES: usize = 4096;
/// Compaction granularity (64 KiB — allocators recycle small buffers
/// within arenas of roughly this locality).
const COARSE_PAGE: usize = 1 << 16;

impl EpochRegistry {
    fn bump(&mut self, lo: usize, hi: usize) -> u64 {
        self.counter += 1;
        if lo < hi {
            self.insert(lo, hi, self.counter);
            if self.ranges.len() > MAX_EXACT_RANGES {
                self.compact();
            }
        }
        self.counter
    }

    /// Insert `[lo, hi) → e`, trimming/evicting whatever it overlaps
    /// (the map stays disjoint).
    fn insert(&mut self, lo: usize, hi: usize, e: u64) {
        // Only the closest interval starting at or before `lo` can
        // overlap from the left; everything else overlapping starts in
        // [lo, hi).
        let first = self
            .ranges
            .range(..=lo)
            .next_back()
            .filter(|&(_, &(h, _))| h > lo)
            .map(|(&l, _)| l)
            .unwrap_or(lo);
        let hit: Vec<usize> = self.ranges.range(first..hi).map(|(&l, _)| l).collect();
        for l in hit {
            let (h, ep) = self.ranges.remove(&l).expect("interval vanished");
            if l < lo {
                self.ranges.insert(l, (lo, ep));
            }
            if h > hi {
                self.ranges.insert(hi, (h, ep));
            }
        }
        self.ranges.insert(lo, (hi, e));
    }

    fn epoch_of(&self, lo: usize, hi: usize) -> u64 {
        if lo >= hi {
            return 0;
        }
        let first = self
            .ranges
            .range(..=lo)
            .next_back()
            .filter(|&(_, &(h, _))| h > lo)
            .map(|(&l, _)| l)
            .unwrap_or(lo);
        self.ranges.range(first..hi).map(|(_, &(_, e))| e).max().unwrap_or(0)
    }

    fn len(&self) -> usize {
        self.ranges.len()
    }

    /// Coarse-page fallback: round intervals out to `page`-aligned
    /// runs and merge overlapping/adjacent ones, keeping the max
    /// epoch. Doubles the page until the map is comfortably small.
    fn compact(&mut self) {
        let mut page = COARSE_PAGE;
        loop {
            self.ranges = Self::coalesce(&self.ranges, page);
            if self.ranges.len() <= MAX_EXACT_RANGES / 2 || page >= usize::MAX / 8 {
                return;
            }
            page = page.saturating_mul(4);
        }
    }

    fn coalesce(
        ranges: &BTreeMap<usize, (usize, u64)>,
        page: usize,
    ) -> BTreeMap<usize, (usize, u64)> {
        let mut merged: Vec<(usize, usize, u64)> = Vec::new();
        for (&l, &(h, e)) in ranges {
            let cl = l - l % page;
            let ch = h.div_ceil(page).saturating_mul(page).max(h);
            match merged.last_mut() {
                // Half-open runs: touching counts as mergeable.
                Some(last) if cl <= last.1 => {
                    last.1 = last.1.max(ch);
                    last.2 = last.2.max(e);
                }
                _ => merged.push((cl, ch, e)),
            }
        }
        merged.into_iter().map(|(l, h, e)| (l, (h, e))).collect()
    }
}

/// A blocking submission, erased over its scalar type so one worker
/// fleet serves f32 and f64 jobs alike. The task set and operand wraps
/// live in the submitting caller's frame (which parks until the job
/// retires); the `'static` on `state` is lifetime erasure only.
struct ErasedJob<T: Scalar> {
    state: JobState<'static, T>,
}

impl<T: Scalar> DeviceJob for ErasedJob<T> {
    fn run_round(&self, dev: usize, core: &EngineCore) -> Round {
        worker_round(dev, core, &self.state)
    }

    fn poison(&self, msg: String) {
        self.state.fail(Error::Internal(msg));
    }

    fn abort(&self, err: Error) {
        self.state.fail(err);
    }

    fn report(&self, core: &EngineCore) -> Result<RealReport> {
        self.state.report(core)
    }

    fn done(&self) -> bool {
        self.state.done()
    }

    fn stats(&self) -> JobStats {
        self.state.stats()
    }

    fn fault_stats(&self) -> FaultStats {
        self.state.fault_stats()
    }
}

/// An asynchronously submitted job that OWNS its backing: the task set
/// and operand wraps are fields of the job itself, held alive by the
/// job table's `Arc` until retirement. This is what closes the old
/// wait-on-drop forget-hole — no caller-side destructor is load-bearing
/// for the workers' access to the task graph or the wraps; only the
/// *user buffers* the wraps point into are borrowed, and the scope
/// close (or the C caller's `blasx_wait` contract) guarantees those
/// outlive retirement.
struct OwnedJob<T: Scalar> {
    /// Declared (and therefore dropped) BEFORE the backing fields: the
    /// state holds references into them.
    state: JobState<'static, T>,
    /// Boxed for stable addresses — `state` points into both.
    _ts: Box<TaskSet>,
    _problems: Box<[OwnedProblem<T>]>,
}

impl<T: Scalar> DeviceJob for OwnedJob<T> {
    fn run_round(&self, dev: usize, core: &EngineCore) -> Round {
        worker_round(dev, core, &self.state)
    }

    fn poison(&self, msg: String) {
        self.state.fail(Error::Internal(msg));
    }

    fn abort(&self, err: Error) {
        self.state.fail(err);
    }

    fn report(&self, core: &EngineCore) -> Result<RealReport> {
        self.state.report(core)
    }

    fn done(&self) -> bool {
        self.state.done()
    }

    fn stats(&self) -> JobStats {
        self.state.stats()
    }

    fn fault_stats(&self) -> FaultStats {
        self.state.fault_stats()
    }
}

/// A shared-read operand pointer that may cross into a device worker.
/// Safety rests on the submit-then-wait contract of [`Runtime::submit_host`]:
/// the caller's borrows outlive retirement, and the kernel only reads.
struct HostRead<T>(*const T, usize);
unsafe impl<T> Send for HostRead<T> {}
unsafe impl<T> Sync for HostRead<T> {}

/// The output pointer of a host-placed job. Exactly one worker claims
/// the kernel (the `claimed` latch), so the `&mut` reconstructed from
/// it is unique.
struct HostWrite<T>(*mut T, usize);
unsafe impl<T> Send for HostWrite<T> {}
unsafe impl<T> Sync for HostWrite<T> {}

/// A host-placed GEMM, admitted through the job table like any device
/// job — the byte-range dependency edges order it against aliasing
/// in-flight work and its output epoch bump invalidates cached C tiles
/// — but executed as a single `hostblas::gemm_mt_with_cutoff` shot on
/// whichever resident worker claims it first. This is the adaptive
/// dispatcher's `Placement::Host` arm: small/skinny shapes where tiling
/// and staging cost more than the multiply itself.
struct HostGemm<T: Scalar> {
    ta: Trans,
    tb: Trans,
    m: usize,
    n: usize,
    k: usize,
    alpha: T,
    beta: T,
    a: HostRead<T>,
    lda: usize,
    b: HostRead<T>,
    ldb: usize,
    c: HostWrite<T>,
    ldc: usize,
    /// Kernel fan-out + serial/fork cutoff, resolved at submission.
    threads: usize,
    cutoff: f64,
    n_devices: usize,
    /// First-claim latch: the winning worker runs the kernel; probing
    /// workers see an in-flight (not finished!) job and go idle — the
    /// claimer's active round pins the table entry until `done`.
    claimed: AtomicBool,
    done: AtomicBool,
    failure: Mutex<Option<Error>>,
}

impl<T: Scalar> HostGemm<T> {
    fn flops(&self) -> f64 {
        2.0 * self.m as f64 * self.n as f64 * self.k as f64
    }
}

impl<T: Scalar> DeviceJob for HostGemm<T> {
    fn run_round(&self, _dev: usize, _core: &EngineCore) -> Round {
        if self.claimed.swap(true, Ordering::SeqCst) {
            // Claimed by another worker. Report Idle while the kernel
            // is mid-flight (premature Finished would retire the job
            // under the claimer); Finished once it lands.
            return if self.done.load(Ordering::SeqCst) { Round::Finished } else { Round::Idle };
        }
        // SAFETY: submit_host parks its caller until retirement, so
        // the operand borrows behind these pointers are live; a/b are
        // shared reads and the claim latch above makes this the only
        // path that ever touches c.
        let (a, b, c) = unsafe {
            (
                std::slice::from_raw_parts(self.a.0, self.a.1),
                std::slice::from_raw_parts(self.b.0, self.b.1),
                std::slice::from_raw_parts_mut(self.c.0, self.c.1),
            )
        };
        hostblas::gemm_mt_with_cutoff(
            self.threads,
            self.cutoff,
            self.ta,
            self.tb,
            self.m,
            self.n,
            self.k,
            self.alpha,
            a,
            self.lda,
            b,
            self.ldb,
            self.beta,
            c,
            self.ldc,
        );
        self.done.store(true, Ordering::SeqCst);
        Round::Progress { flops: self.flops() }
    }

    fn poison(&self, msg: String) {
        self.abort(Error::Internal(msg));
    }

    fn abort(&self, err: Error) {
        let mut f = self.failure.lock().unwrap_or_else(|e| e.into_inner());
        if f.is_none() {
            *f = Some(err);
        }
    }

    fn report(&self, _core: &EngineCore) -> Result<RealReport> {
        if let Some(e) = self.failure.lock().unwrap_or_else(|e| e.into_inner()).take() {
            return Err(e);
        }
        // Host placement moves no tiles: the report is all-zeros by
        // construction (warm-path assertions on host_reads stay valid).
        Ok(RealReport {
            tasks_per_device: vec![0; self.n_devices],
            cache_stats: vec![CacheStats::default(); self.n_devices],
            cache_delta: vec![CacheStats::default(); self.n_devices],
            steals: vec![0; self.n_devices],
            transfers: TransferStats::default(),
        })
    }

    fn done(&self) -> bool {
        self.done.load(Ordering::SeqCst)
    }

    fn stats(&self) -> JobStats {
        JobStats::default()
    }
}

struct Inner {
    core: EngineCore,
    n_devices: usize,
    arena_bytes: usize,
    /// The multi-job slot table: the single shared scheduler state.
    /// Lock order: `table` → `caches` (the admission-time counter
    /// baseline snapshot) and `table` → `epochs`; never call
    /// [`EngineCore::notify_work`] while holding it.
    table: Mutex<JobTable>,
    epochs: Mutex<EpochRegistry>,
    shutdown: AtomicBool,
    /// Jobs served since boot (observability).
    calls: AtomicUsize,
    /// Per-tenant/per-routine latency histograms + per-device busy
    /// accounting. The single source of truth for `blasx serve`'s
    /// stress output and `benches/serve_throughput.rs` — no ad-hoc
    /// timers elsewhere. Lock order: may be taken while holding
    /// `table` (admission), never the reverse.
    metrics: MetricsRegistry,
    /// Live telemetry plane: the sample ring the background sampler
    /// (when enabled) feeds and the scrape endpoint reads. Allocation-
    /// free and thread-free when disabled (the default) — see
    /// [`crate::trace::telemetry`].
    telemetry: Telemetry,
}

/// The resident device runtime (see module docs). Cloneably shared via
/// `Arc` by [`crate::api::Context`] and by in-flight
/// [`crate::serve::JobHandle`]s; dropping the last handle shuts the
/// workers down.
pub struct Runtime {
    inner: Arc<Inner>,
    handles: Vec<JoinHandle<()>>,
    /// Whether a background telemetry sampler thread was spawned at
    /// boot (`BLASX_TELEMETRY_MS` / `RunConfig::telemetry_ms`).
    sampler_active: bool,
}

impl std::fmt::Debug for Runtime {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Runtime")
            .field("n_devices", &self.inner.n_devices)
            .field("arena_bytes", &self.inner.arena_bytes)
            .field("calls", &self.inner.calls.load(Ordering::Relaxed))
            .finish()
    }
}

impl Runtime {
    /// Spawn the resident workers and allocate the persistent arenas.
    /// The telemetry sampler interval comes from `BLASX_TELEMETRY_MS`
    /// (unset/0 = off); use [`Runtime::boot_with_telemetry`] for a
    /// programmatic interval.
    pub fn boot(n_devices: usize, arena_bytes: usize, alloc: AllocStrategy) -> Runtime {
        Runtime::boot_with_telemetry(n_devices, arena_bytes, alloc, None)
    }

    /// [`Runtime::boot`] with an explicit telemetry interval override:
    /// `Some(ms)` wins over the environment (`Some(0)` forces the
    /// sampler off), `None` consults `BLASX_TELEMETRY_MS`. When the
    /// resolved interval is 0 no sampler thread is spawned and no
    /// telemetry memory is allocated.
    pub fn boot_with_telemetry(
        n_devices: usize,
        arena_bytes: usize,
        alloc: AllocStrategy,
        telemetry_ms: Option<u64>,
    ) -> Runtime {
        assert!(n_devices >= 1);
        let interval_ms = Telemetry::interval_from_env(telemetry_ms);
        let inner = Arc::new(Inner {
            core: EngineCore::new(n_devices, arena_bytes, alloc),
            n_devices,
            arena_bytes,
            table: Mutex::new(JobTable::new()),
            epochs: Mutex::new(EpochRegistry::default()),
            shutdown: AtomicBool::new(false),
            calls: AtomicUsize::new(0),
            metrics: MetricsRegistry::new(n_devices),
            telemetry: Telemetry::new(interval_ms),
        });
        let mut handles: Vec<JoinHandle<()>> = (0..n_devices)
            .map(|dev| {
                let inner = inner.clone();
                std::thread::Builder::new()
                    .name(format!("blasx-dev-{dev}"))
                    .spawn(move || device_worker(inner, dev))
                    .expect("spawn device worker")
            })
            .collect();
        let sampler_active = interval_ms > 0;
        if sampler_active {
            let inner2 = inner.clone();
            handles.push(
                std::thread::Builder::new()
                    .name("blasx-telemetry".into())
                    .spawn(move || telemetry_sampler(inner2))
                    .expect("spawn telemetry sampler"),
            );
        }
        Runtime { inner, handles, sampler_active }
    }

    pub fn n_devices(&self) -> usize {
        self.inner.n_devices
    }

    pub fn arena_bytes(&self) -> usize {
        self.inner.arena_bytes
    }

    /// Jobs served since boot.
    pub fn calls(&self) -> usize {
        self.inner.calls.load(Ordering::Relaxed)
    }

    /// Cumulative per-device busy time (nanoseconds inside scheduler
    /// rounds) since boot. Compare against wall time × device count
    /// for the worker-idle fraction.
    pub fn busy_nanos(&self) -> Vec<u64> {
        self.inner.metrics.busy_nanos()
    }

    /// The runtime's metrics registry (per-tenant/per-routine latency
    /// histograms, worker busy accounting). Snapshot with
    /// [`MetricsRegistry::snapshot`].
    pub fn metrics(&self) -> &MetricsRegistry {
        &self.inner.metrics
    }

    /// The metrics snapshot *plus* the fleet-health section — the one
    /// JSON document `blasx serve --metrics-out`, the C ABI and tests
    /// consume. Device-death state comes from the same
    /// `EngineCore::dead_devices` ledger `/healthz` reads, so the two
    /// views can never disagree (regression-tested).
    pub fn snapshot_metrics(&self) -> Json {
        let mut snap = self.inner.metrics.snapshot();
        let dead = self.inner.core.dead_devices();
        let mut devices = Vec::with_capacity(self.inner.n_devices);
        for dev in 0..self.inner.n_devices {
            let mut d = Json::obj();
            d.set("dev", dev.into()).set("up", (!dead.contains(&dev)).into());
            devices.push(d);
        }
        snap.set("devices", Json::Arr(devices)).set("fleet_healthy", dead.is_empty().into());
        snap
    }

    /// Devices lost to faults (the `/healthz` + `blasx_device_up`
    /// source of truth).
    pub fn dead_devices(&self) -> Vec<usize> {
        self.inner.core.dead_devices()
    }

    /// Gather a fresh telemetry sample NOW (windowed rates computed
    /// against the sampler's most recent ring entry when one exists).
    /// This is what `/metrics` scrapes render, so the exporter works
    /// even with the background sampler off.
    pub fn telemetry_now(&self) -> TelemetrySample {
        let mut s = gather_sample(&self.inner);
        let prev = self.inner.telemetry.latest();
        fill_windowed_rates(&mut s, prev.as_ref());
        s
    }

    /// The telemetry sample ring (history inspection; empty unless the
    /// background sampler is on).
    pub fn telemetry(&self) -> &Telemetry {
        &self.inner.telemetry
    }

    /// Is a background sampler thread running for this runtime?
    pub fn sampler_running(&self) -> bool {
        self.sampler_active
    }

    /// The always-on flight recorder (bounded incident trail).
    pub fn flight(&self) -> &FlightRecorder {
        &self.inner.core.flight
    }

    /// Live jobs currently admitted (in flight or queued behind
    /// dependencies).
    pub fn jobs_in_flight(&self) -> usize {
        self.inner.table.lock().unwrap_or_else(|e| e.into_inner()).jobs.len()
    }

    pub(crate) fn core(&self) -> &EngineCore {
        &self.inner.core
    }

    /// Arm (or re-arm) the fault-injection plane for this runtime.
    /// Called at boot by the API layer when `RunConfig.fault_plan` is
    /// set, and by `blasx serve --chaos`; the `BLASX_FAULTS`
    /// environment fallback was already installed at core
    /// construction.
    pub fn install_fault_plan(&self, plan: FaultPlan) {
        self.inner.core.faults.install(plan);
    }

    /// Open a new invalidation generation for `[lo, hi)`: tiles cached
    /// from host bytes in that range become unreachable. The public
    /// doorway is [`crate::api::Context::invalidate_host`].
    pub fn invalidate_bytes(&self, lo: usize, hi: usize) {
        self.inner.epochs.lock().unwrap_or_else(|e| e.into_inner()).bump(lo, hi);
    }

    fn assert_arena_floor<T: Scalar>(&self, cfg: &RunConfig) {
        // Checked BEFORE any lock: panicking while holding the table
        // lock would poison it for every Context clone.
        assert!(
            self.inner.arena_bytes >= 8 * block_bytes::<T>(cfg.t),
            "arena must hold at least 8 tiles (working set of a round)"
        );
    }

    /// Admission core shared by every submission path: enforce the
    /// backpressure bounds, stamp epochs via `stamp_epochs` (same lock,
    /// same order), insert into the table wiring dependency edges, run
    /// `after_admit` still under the table lock (trace-id / baseline
    /// stamps — no worker round of the job can precede them), wake
    /// workers. Fails fast with [`Error::Backpressure`] when the table
    /// is at capacity or the submitting tenant is at its in-flight
    /// quota.
    fn admit_raw(
        &self,
        cfg: &RunConfig,
        span: JobSpan,
        weight: f64,
        erased: Arc<dyn DeviceJob>,
        stamp_epochs: impl FnOnce(&mut EpochRegistry),
        after_admit: impl FnOnce(&JobCtl),
    ) -> Result<Arc<JobCtl>> {
        let tenant = tenant_id();
        let ctl = {
            let mut table = self.inner.table.lock().unwrap_or_else(|e| e.into_inner());
            // Bounded admission: refuse BEFORE stamping epochs so a
            // rejected call leaves no trace in the registry.
            if table.live_count() >= cfg.admit_capacity.max(1) {
                self.inner.metrics.on_reject(tenant, cfg.routine);
                self.inner.core.flight.record(None, "reject", 0, tenant, table.live_count() as f64);
                return Err(Error::Backpressure(format!(
                    "admission queue full ({} jobs in flight, capacity {})",
                    table.live_count(),
                    cfg.admit_capacity.max(1)
                )));
            }
            if table.tenant_inflight(tenant) >= cfg.tenant_quota.max(1) {
                self.inner.metrics.on_reject(tenant, cfg.routine);
                self.inner.core.flight.record(None, "reject", 0, tenant, 0.0);
                return Err(Error::Backpressure(format!(
                    "tenant {tenant} at its in-flight quota ({})",
                    cfg.tenant_quota.max(1)
                )));
            }
            // Epoch stamping under the admission lock: inputs resolve
            // against the current generation map, then every output
            // range opens a fresh one. Epoch order == dependency-edge
            // order, which is what keeps aliasing concurrent jobs
            // bit-for-bit equal to serial execution.
            {
                let mut reg = self.inner.epochs.lock().unwrap_or_else(|e| e.into_inner());
                stamp_epochs(&mut reg);
            }
            let deadline =
                cfg.deadline_ms.map(|ms| (Instant::now() + Duration::from_millis(ms), ms));
            let ctl = table.admit(erased, span, weight, tenant, deadline);
            after_admit(&ctl);
            self.inner.metrics.on_admit(
                ctl.id,
                tenant,
                cfg.routine,
                weight,
                self.inner.core.rec.now(),
            );
            self.inner.core.flight.record(None, "admit", ctl.id, tenant, weight);
            ctl
        };
        self.inner.core.notify_work();
        Ok(ctl)
    }

    /// Admit a constructed tiled job (see [`Runtime::admit_raw`] for
    /// the shared admission mechanics).
    fn admit<T: Scalar>(
        &self,
        cfg: &RunConfig,
        state: &JobState<'static, T>,
        erased: Arc<dyn DeviceJob>,
    ) -> Result<Arc<JobCtl>> {
        let mut span = JobSpan::default();
        for m in state.problems() {
            for hm in [Some(m.a), m.b].into_iter().flatten() {
                span.ins.push(hm.byte_range());
            }
            span.outs.push(m.c.byte_range());
        }
        self.admit_raw(
            cfg,
            span,
            state.weight(),
            erased,
            |reg| {
                for m in state.problems() {
                    for hm in [Some(m.a), m.b].into_iter().flatten() {
                        let (lo, hi) = hm.byte_range();
                        hm.set_epoch(reg.epoch_of(lo, hi));
                    }
                }
                for m in state.problems() {
                    let (lo, hi) = m.c.byte_range();
                    m.c.set_epoch(reg.bump(lo, hi));
                }
            },
            |ctl| {
                // Stamp the admission id onto the job's spans and
                // snapshot the cache counters as the per-call delta
                // baseline.
                state.set_trace_id(ctl.id);
                let caches = self.inner.core.lock_caches();
                state.set_cache_baseline(
                    (0..self.inner.n_devices).map(|d| caches.stats(d)).collect::<Vec<CacheStats>>(),
                );
            },
        )
    }

    /// Execute a task set over the resident engine; parks the caller
    /// until the job retires (submit-then-wait). See the module docs
    /// for the coherence contract.
    pub(crate) fn submit<T: Scalar>(
        &self,
        cfg: &RunConfig,
        ts: &TaskSet,
        problems: Vec<Mats<'_, T>>,
    ) -> Result<RealReport> {
        self.assert_arena_floor::<T>(cfg);
        let state = JobState::new(cfg, ts, problems, self.inner.n_devices)?;
        // SAFETY: the lifetime is erased only for the trait object's
        // benefit. Every borrow inside `state` (task set, operand
        // wraps) outlives this function call, and this function does
        // not return until the job has RETIRED — retirement is
        // signalled only after the table has dropped its job reference
        // and every worker has dropped its round-scoped clone (the
        // drop happens-before the retire latch, both under the table
        // lock). Our own Arc is dropped before returning, so no
        // reference to the borrowed data survives the call.
        let state =
            unsafe { std::mem::transmute::<JobState<'_, T>, JobState<'static, T>>(state) };
        let job = Arc::new(ErasedJob { state });
        let erased: Arc<dyn DeviceJob> = job.clone();
        let ctl = self.admit(cfg, &job.state, erased)?;
        ctl.wait_retired();
        let report = job.state.report(&self.inner.core);
        drop(job);
        report
    }

    /// Admit a job that OWNS its task set and operand wraps (the
    /// scope-async and C-ABI paths) and return the pieces the API
    /// layer wraps into a [`crate::serve::JobHandle`] or an FFI
    /// handle. The runtime's job table keeps the [`OwnedJob`] alive
    /// until retirement, so no caller-side value is load-bearing for
    /// the workers; the *user buffers* the wraps point into must
    /// outlive retirement — guaranteed by the scope close barrier
    /// (safe API) or the C caller's wait contract (FFI).
    pub(crate) fn submit_owned<T: Scalar>(
        &self,
        cfg: &RunConfig,
        ts: TaskSet,
        problems: Vec<OwnedProblem<T>>,
    ) -> Result<(Arc<dyn DeviceJob>, Arc<JobCtl>)> {
        self.assert_arena_floor::<T>(cfg);
        let ts = Box::new(ts);
        let problems = problems.into_boxed_slice();
        // SAFETY: the boxes give the task set and operand wraps stable
        // heap addresses, unaffected by moving them into the OwnedJob
        // below. The references created here live inside the SAME
        // OwnedJob (whose `state` field drops before the backing
        // fields), and the OwnedJob is kept alive by the job table's
        // Arc until the job retires.
        let ts_ref: &'static TaskSet = unsafe { &*(ts.as_ref() as *const TaskSet) };
        let mats: Vec<Mats<'static, T>> = problems
            .iter()
            .map(|p| {
                let m = Mats { a: &p.a, b: p.b.as_ref(), c: &p.c };
                // SAFETY: lifetime erasure only (see above).
                unsafe { std::mem::transmute::<Mats<'_, T>, Mats<'static, T>>(m) }
            })
            .collect();
        let state = JobState::new(cfg, ts_ref, mats, self.inner.n_devices)?;
        let job = Arc::new(OwnedJob { state, _ts: ts, _problems: problems });
        let erased: Arc<dyn DeviceJob> = job.clone();
        let ctl = self.admit(cfg, &job.state, erased.clone())?;
        Ok((erased, ctl))
    }

    /// Execute a GEMM *on the host*, admitted through the job table so
    /// it orders correctly against aliasing in-flight tiled jobs (RAW/
    /// WAR/WAW edges from the same byte ranges) and bumps the output
    /// epoch so previously cached C tiles become unreachable — but
    /// without tiling, staging, or touching the device caches. This is
    /// the dispatcher's `Placement::Host` arm for shapes where the
    /// multiply is cheaper than the staging it would take to ship it.
    /// Blocking (submit-then-wait), mirroring [`Runtime::submit`].
    #[allow(clippy::too_many_arguments)]
    pub(crate) fn submit_host<T: Scalar>(
        &self,
        cfg: &RunConfig,
        ta: Trans,
        tb: Trans,
        m: usize,
        n: usize,
        k: usize,
        alpha: T,
        a: &[T],
        lda: usize,
        b: &[T],
        ldb: usize,
        beta: T,
        c: &mut [T],
        ldc: usize,
    ) -> Result<RealReport> {
        let esz = std::mem::size_of::<T>();
        let range = |p: *const T, len: usize| (p as usize, p as usize + len * esz);
        let span = JobSpan {
            ins: vec![range(a.as_ptr(), a.len()), range(b.as_ptr(), b.len())],
            outs: vec![range(c.as_ptr(), c.len())],
        };
        let (c_lo, c_hi) = range(c.as_ptr(), c.len());
        let job = Arc::new(HostGemm {
            ta,
            tb,
            m,
            n,
            k,
            alpha,
            beta,
            a: HostRead(a.as_ptr(), a.len()),
            lda,
            b: HostRead(b.as_ptr(), b.len()),
            ldb,
            c: HostWrite(c.as_mut_ptr(), c.len()),
            ldc,
            threads: cfg.worker_threads.max(1),
            cutoff: cfg.mt_cutoff.unwrap_or_else(hostblas::mt_flop_cutoff),
            n_devices: self.inner.n_devices,
            claimed: AtomicBool::new(false),
            done: AtomicBool::new(false),
            failure: Mutex::new(None),
        });
        let weight = job.flops();
        let erased: Arc<dyn DeviceJob> = job.clone();
        let ctl = self.admit_raw(
            cfg,
            span,
            weight,
            erased,
            // Inputs are read straight from host memory (always
            // current), so only the output generation matters: the
            // bump makes stale cached C tiles unreachable for every
            // later tiled job.
            |reg| {
                reg.bump(c_lo, c_hi);
            },
            |_| {},
        )?;
        ctl.wait_retired();
        let report = job.report(&self.inner.core);
        drop(job);
        report
    }
}

impl Drop for Runtime {
    fn drop(&mut self) {
        self.inner.shutdown.store(true, Ordering::SeqCst);
        // Wake the sampler first (condvar latch): otherwise the join
        // below would block for up to one full sampling interval.
        self.inner.telemetry.request_stop();
        self.inner.core.notify_work();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

/// Collect one telemetry sample. The three lock holders are visited
/// strictly *sequentially* — table, then caches, then the metrics
/// registry's own lock (inside `job_gauges`) — never nested, which
/// keeps the sampler trivially compatible with the runtime's
/// `table` → `caches` lock order no matter which workers it races.
/// Dispatch gauges stay 0 here: the dispatcher lives on the API-layer
/// `Context`, which overlays them in `render_prometheus`.
fn gather_sample(inner: &Inner) -> TelemetrySample {
    let mut s = TelemetrySample { t_s: inner.metrics.uptime(), ..Default::default() };
    {
        let table = inner.table.lock().unwrap_or_else(|e| e.into_inner());
        s.queue_depth = table.live_count();
        for e in &table.jobs {
            if e.finishing {
                continue;
            }
            if e.deps.is_empty() {
                s.runnable += 1;
            } else {
                s.blocked += 1;
            }
        }
    }
    let busy = inner.metrics.busy_nanos();
    let rounds = inner.metrics.rounds();
    {
        let caches = inner.core.lock_caches();
        for dev in 0..inner.n_devices {
            let hs = caches.heap_stats(dev);
            let cs = caches.stats(dev);
            let (pf_hits, pf_wasted) = inner.core.prefetch_counters(dev);
            s.devices.push(DevGauges {
                dev,
                dead: inner.core.is_dead(dev),
                arena_in_use: hs.bytes_in_use,
                arena_high_water: hs.high_water,
                cache_resident: caches.resident(dev),
                cache_hits: cs.hits,
                cache_misses: cs.misses,
                cache_evictions: cs.evictions,
                hit_rate: 0.0,
                prefetch_hits: pf_hits as u64,
                prefetch_wasted: pf_wasted as u64,
                busy_nanos: busy.get(dev).copied().unwrap_or(0),
                busy_fraction: 0.0,
                rounds: rounds.get(dev).copied().unwrap_or(0),
            });
        }
    }
    s.inflight_transfers = inner.core.inflight_transfers();
    let jg = inner.metrics.job_gauges();
    s.in_flight = jg.in_flight;
    s.admitted = jg.admitted;
    s.retired = jg.retired;
    s.failed = jg.failed;
    s.rejected = jg.rejected;
    s.per_tenant = jg.per_tenant_inflight;
    s
}

/// Body of the background sampler thread: park one interval (woken
/// early by `Drop`), gather, rate-fill against the previous ring entry,
/// push. Exits as soon as `request_stop` fires.
fn telemetry_sampler(inner: Arc<Inner>) {
    while inner.telemetry.park_interval() {
        let mut s = gather_sample(&inner);
        let prev = inner.telemetry.latest();
        fill_windowed_rates(&mut s, prev.as_ref());
        inner.telemetry.push(s);
    }
}

/// What a worker does next.
enum Pick {
    /// Run one round of this job.
    Run(u64, Arc<dyn DeviceJob>),
    /// Nothing runnable; park (indefinitely iff the table is empty —
    /// admission wakes us; otherwise with the steal-retry backstop).
    Park { indefinitely: bool },
}

/// Post-retirement bookkeeping shared by the worker path and the reap
/// path: count the call, fold the metrics, forward the lifecycle to
/// the span recorder. Must run with the table lock released.
fn retire_bookkeeping(inner: &Inner, id: u64, failed: bool, faults: &FaultStats) {
    inner.calls.fetch_add(1, Ordering::Relaxed);
    if let Some(r) = inner.metrics.on_retire(id, failed, inner.core.rec.now(), faults) {
        inner.core.flight.record(None, "retire", id, r.tenant, if failed { 1.0 } else { 0.0 });
        inner.core.rec.record_job(JobRec {
            job: id,
            tenant: r.tenant,
            routine: r.routine,
            admit: r.admit_s,
            first_round: r.first_round_s,
            retire: r.retire_s,
            failed,
        });
    } else {
        inner.core.flight.record(None, "retire", id, 0, if failed { 1.0 } else { 0.0 });
    }
}

fn next_round(inner: &Inner, tried: &mut HashSet<u64>, seen_version: &mut u64) -> Pick {
    let (pick, reaped) = {
        let mut table = inner.table.lock().unwrap_or_else(|e| e.into_inner());
        // Deadline/cancel enforcement lives at the round boundary:
        // expired or cancelled jobs abort with their distinct error
        // and, if no round of theirs is in flight, retire on the spot
        // — neighbours' rounds are untouched.
        let reap = table.reap_expired();
        if table.version != *seen_version {
            *seen_version = table.version;
            tried.clear();
        }
        let shares = table.runnable_shares();
        // The k-chunk splitter consults this: under a contended table
        // a task's step chain executes in bounded chunks so the round
        // quantum stays fair.
        inner.core.runnable_jobs.store(shares.len(), Ordering::Relaxed);
        let pick = match fairness::pick(&shares, tried) {
            Some(id) => Pick::Run(id, table.start_round(id)),
            None => Pick::Park { indefinitely: table.is_empty() },
        };
        (pick, reap.retired)
    };
    if !reaped.is_empty() {
        for (ctl, faults) in &reaped {
            inner.core.flight.record(None, "reap", ctl.id, 0, reaped.len() as f64);
            retire_bookkeeping(inner, ctl.id, true, faults);
            ctl.retire();
        }
        // A reap is a black-box incident: a tenant lost work to a
        // deadline or cancellation. Dump the flight ring (bounded per
        // reason — see `FlightRecorder::maybe_dump`).
        inner.core.flight.maybe_dump("deadline-reap", &inner.core.dead_devices());
        // Dependents of the reaped jobs may be runnable now.
        inner.core.notify_work();
    }
    pick
}

fn device_worker(inner: Arc<Inner>, dev: usize) {
    // Jobs this device probed and found idle since the table last
    // changed (don't re-spin on them; cleared on any table version
    // bump, progress, or wakeup).
    let mut tried: HashSet<u64> = HashSet::new();
    let mut seen_version = u64::MAX;
    loop {
        if inner.shutdown.load(Ordering::SeqCst) {
            return;
        }
        match next_round(&inner, &mut tried, &mut seen_version) {
            Pick::Run(id, job) => {
                inner.metrics.on_round_start(id, inner.core.rec.now());
                let t0 = Instant::now();
                // Contain panics (a poisoned kernel must not kill the
                // resident worker — the job fails, the fleet stays
                // serviceable).
                let round =
                    match catch_unwind(AssertUnwindSafe(|| job.run_round(dev, &inner.core))) {
                        Ok(r) => r,
                        Err(_) => {
                            job.poison(format!("device worker {dev} panicked"));
                            inner.core.flight.record(Some(dev), "panic", id, 0, 0.0);
                            inner.core.flight.maybe_dump("worker-panic", &inner.core.dead_devices());
                            Round::Failed
                        }
                    };
                inner.metrics.on_round_end(dev, t0.elapsed().as_nanos() as u64);
                let (flops, finished, failed) = match round {
                    // A Progress round may have executed the job's
                    // last task — fold that observation in now rather
                    // than waiting for an extra idle probe.
                    Round::Progress { flops } => (flops, job.done(), false),
                    Round::Idle => (0.0, false, false),
                    Round::Finished => (0.0, true, false),
                    Round::Failed => (0.0, false, true),
                };
                // Snapshot the fault counters, then drop our job
                // reference BEFORE retirement can become observable:
                // once the latch is set, the waiter reclaims the
                // borrows behind the job.
                let faults = job.fault_stats();
                drop(job);
                let (retired, retired_failed) = {
                    let mut table = inner.table.lock().unwrap_or_else(|e| e.into_inner());
                    let actions = table.finish_round(id, flops, finished, failed);
                    (actions.retired, actions.retired_failed)
                };
                if let Some(ctl) = retired {
                    retire_bookkeeping(&inner, id, retired_failed, &faults);
                    ctl.retire();
                    // Dependents of the retired job may be runnable now.
                    inner.core.notify_work();
                }
                match round {
                    Round::Idle => {
                        tried.insert(id);
                    }
                    Round::Progress { .. } => tried.clear(),
                    _ => {}
                }
            }
            Pick::Park { indefinitely } => {
                let timeout = if indefinitely { None } else { Some(PARK_TIMEOUT) };
                let park_t0 = inner.core.rec.now();
                inner.core.park_for_work(timeout, || {
                    !inner.shutdown.load(Ordering::SeqCst)
                        && (!indefinitely
                            || inner
                                .table
                                .lock()
                                .unwrap_or_else(|e| e.into_inner())
                                .is_empty())
                });
                inner.core.rec.record(dev, SpanKind::Park, park_t0, 0.0, 0);
                tried.clear();
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn epoch_registry_bumps_and_resolves() {
        let mut r = EpochRegistry::default();
        assert_eq!(r.epoch_of(0, 100), 0);
        let e1 = r.bump(100, 200);
        assert_eq!(r.epoch_of(150, 160), e1);
        assert_eq!(r.epoch_of(0, 100), 0, "adjacent, non-overlapping");
        assert_eq!(r.epoch_of(199, 300), e1, "partial overlap counts");
        let e2 = r.bump(150, 180);
        assert_eq!(r.epoch_of(150, 160), e2);
        assert_eq!(r.epoch_of(100, 110), e1, "older range still visible outside the new one");
        assert_eq!(r.epoch_of(185, 300), e1, "right remnant of the split survives");
        assert!(e2 > e1);
    }

    #[test]
    fn epoch_registry_compacts_covered_ranges() {
        let mut r = EpochRegistry::default();
        for _ in 0..50 {
            r.bump(1000, 2000); // same output rewritten every call
        }
        assert_eq!(r.len(), 1, "covered ranges compact away");
        r.bump(0, 10_000); // superset swallows it
        assert_eq!(r.len(), 1);
    }

    #[test]
    fn epoch_registry_trims_partial_overlaps_to_disjoint_fragments() {
        // The flat-list registry retained partially-overlapped ranges
        // whole; the interval map trims them, keeping the store
        // disjoint while every fragment still resolves to the newest
        // generation that touched it.
        let mut r = EpochRegistry::default();
        let e1 = r.bump(0, 100);
        let e2 = r.bump(50, 150);
        let e3 = r.bump(25, 75);
        assert_eq!(r.len(), 3, "[0,25)e1 [25,75)e3 [75,150)e2");
        assert_eq!(r.epoch_of(0, 10), e1);
        assert_eq!(r.epoch_of(30, 40), e3);
        assert_eq!(r.epoch_of(100, 110), e2);
        assert_eq!(r.epoch_of(60, 80), e3, "max over the queried overlap");
        // A covering bump collapses everything back to one interval.
        r.bump(0, 1000);
        assert_eq!(r.len(), 1);
    }

    #[test]
    fn epoch_registry_growth_is_bounded() {
        // Millions of distinct short-lived output buffers (the serving
        // regime): the registry must not grow unboundedly.
        let mut r = EpochRegistry::default();
        for i in 0..(3 * MAX_EXACT_RANGES) {
            // Disjoint 128-byte buffers spread over a wide heap.
            let lo = 0x10_0000 + i * 4096;
            r.bump(lo, lo + 128);
        }
        assert!(
            r.len() <= MAX_EXACT_RANGES,
            "registry must stay bounded, got {} ranges",
            r.len()
        );
    }

    #[test]
    fn epoch_registry_compaction_is_conservative() {
        // After coarse-page fallback, resolved epochs may only be
        // NEWER than exact (spurious re-fetch), never older (stale
        // tiles). Verify every bumped range still resolves at or above
        // its own generation.
        let mut r = EpochRegistry::default();
        let mut bumps = Vec::new();
        for i in 0..(2 * MAX_EXACT_RANGES) {
            let lo = i * (COARSE_PAGE / 16);
            let e = r.bump(lo, lo + 64);
            bumps.push((lo, e));
        }
        for &(lo, e) in &bumps {
            assert!(r.epoch_of(lo, lo + 64) >= e, "stale epoch after compaction at {lo:#x}");
        }
    }

    #[test]
    fn boot_and_drop_join_cleanly() {
        let rt = Runtime::boot(3, 1 << 20, AllocStrategy::FastHeap);
        assert_eq!(rt.n_devices(), 3);
        assert_eq!(rt.calls(), 0);
        assert_eq!(rt.jobs_in_flight(), 0);
        drop(rt); // must not hang
    }
}
