//! The resident device runtime: long-lived worker threads, persistent
//! arenas/tile-caches, and cross-call invalidation epochs.
//!
//! BLASX's headline wins come from a *persistent* dynamic runtime whose
//! tile cache amortizes transfers across task progression. Tearing the
//! engine down per API call (the one-shot `run_real` path) forfeits
//! exactly that: worker threads respawn, arenas reallocate, and every
//! call re-transfers tiles the previous call already staged. The
//! [`Runtime`] keeps the [`EngineCore`] — device arenas + ALRU/MESI-X
//! caches + parked worker threads — alive between calls, so a call
//! touching host matrices the runtime has seen before starts on a warm
//! cache (L1/L2 tile hits instead of host DMA).
//!
//! ## Lifecycle
//!
//! - **Boot** — lazy: the first call through a persistent
//!   [`crate::api::Context`] spawns one worker thread per virtual
//!   device and allocates the arenas. Clones of a `Context` share the
//!   booted runtime.
//! - **Warm calls** — [`Runtime::submit`] publishes a type-erased job
//!   to the resident workers over the dispatch slot (a seq-numbered
//!   mutex/condvar channel) and parks the caller until every worker
//!   has finished the job. Submissions serialize: the engine runs one
//!   call at a time, callers queue on the submit mutex.
//! - **Invalidation** — every output matrix bumps an *epoch* for its
//!   byte range in the [`EpochRegistry`] at submit time; input wraps
//!   resolve their epoch from the registry. Epochs are folded into
//!   [`crate::tile::TileKey`], so tiles cached from a buffer that has
//!   since been rewritten become unreachable (and age out of the ALRU)
//!   instead of serving stale bytes. Users who mutate an *input*
//!   buffer between calls must declare it via
//!   [`crate::api::Context::invalidate_host`] — the library cannot
//!   observe foreign writes to host memory.
//! - **Shutdown** — dropping the last handle (the last `Context`
//!   clone) signals the workers and joins them.
//!
//! Tile-size changes between calls purge the cache wholesale: block
//! geometry participates in tile addressing, so cross-size reuse would
//! be incoherent. A failed job also purges (readers may have been left
//! pinned on the abort path).

use crate::api::Scalar;
use crate::coordinator::config::RunConfig;
use crate::coordinator::real_engine::{
    block_bytes, worker_loop, EngineCore, JobState, Mats, RealReport,
};
use crate::error::Result;
use crate::mem::AllocStrategy;
use crate::task::TaskSet;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

/// Host-buffer invalidation generations, keyed by byte range.
///
/// `bump` opens a fresh generation for a range (outputs at submit
/// time, or user-declared mutations); `epoch_of` resolves the newest
/// generation overlapping a range (inputs at submit time). Ranges
/// fully covered by a newer bump are compacted away, so the registry
/// stays proportional to the number of *distinct* live output buffers
/// rather than the call count.
#[derive(Default)]
struct EpochRegistry {
    counter: u64,
    ranges: Vec<(usize, usize, u64)>,
}

impl EpochRegistry {
    fn bump(&mut self, lo: usize, hi: usize) -> u64 {
        self.counter += 1;
        if lo < hi {
            self.ranges.retain(|&(l, h, _)| !(l >= lo && h <= hi));
            self.ranges.push((lo, hi, self.counter));
        }
        self.counter
    }

    fn epoch_of(&self, lo: usize, hi: usize) -> u64 {
        self.ranges
            .iter()
            .filter(|&&(l, h, _)| l < hi && h > lo)
            .map(|&(_, _, e)| e)
            .max()
            .unwrap_or(0)
    }
}

/// A submitted call, erased over its scalar type so one worker fleet
/// serves f32 and f64 jobs alike.
trait DeviceJob: Send + Sync {
    fn run_device(&self, dev: usize, core: &EngineCore);
    fn poison(&self, msg: String);
}

struct ErasedJob<T: Scalar> {
    state: JobState<'static, T>,
}

impl<T: Scalar> DeviceJob for ErasedJob<T> {
    fn run_device(&self, dev: usize, core: &EngineCore) {
        worker_loop(dev, core, &self.state);
    }

    fn poison(&self, msg: String) {
        self.state.fail(crate::error::Error::Internal(msg));
    }
}

/// The job dispatch slot: a one-deep seq-numbered channel from the
/// submitting caller to every resident worker.
struct Slot {
    seq: u64,
    job: Option<Arc<dyn DeviceJob>>,
    /// Workers still executing the current job.
    left: Arc<AtomicUsize>,
}

struct Inner {
    core: EngineCore,
    n_devices: usize,
    arena_bytes: usize,
    /// One call at a time through the engine.
    submit_mx: Mutex<()>,
    slot: Mutex<Slot>,
    slot_cv: Condvar,
    done_mx: Mutex<()>,
    done_cv: Condvar,
    epochs: Mutex<EpochRegistry>,
    /// Tile size of the cached generation (None = cold).
    last_t: Mutex<Option<usize>>,
    shutdown: AtomicBool,
    /// Calls served since boot (observability).
    calls: AtomicUsize,
}

/// The resident device runtime (see module docs). Cloneably shared via
/// `Arc` by [`crate::api::Context`]; dropping the last handle shuts
/// the workers down.
pub struct Runtime {
    inner: Arc<Inner>,
    handles: Vec<JoinHandle<()>>,
}

impl std::fmt::Debug for Runtime {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Runtime")
            .field("n_devices", &self.inner.n_devices)
            .field("arena_bytes", &self.inner.arena_bytes)
            .field("calls", &self.inner.calls.load(Ordering::Relaxed))
            .finish()
    }
}

impl Runtime {
    /// Spawn the resident workers and allocate the persistent arenas.
    pub fn boot(n_devices: usize, arena_bytes: usize, alloc: AllocStrategy) -> Runtime {
        assert!(n_devices >= 1);
        let inner = Arc::new(Inner {
            core: EngineCore::new(n_devices, arena_bytes, alloc),
            n_devices,
            arena_bytes,
            submit_mx: Mutex::new(()),
            slot: Mutex::new(Slot { seq: 0, job: None, left: Arc::new(AtomicUsize::new(0)) }),
            slot_cv: Condvar::new(),
            done_mx: Mutex::new(()),
            done_cv: Condvar::new(),
            epochs: Mutex::new(EpochRegistry::default()),
            last_t: Mutex::new(None),
            shutdown: AtomicBool::new(false),
            calls: AtomicUsize::new(0),
        });
        let handles = (0..n_devices)
            .map(|dev| {
                let inner = inner.clone();
                std::thread::Builder::new()
                    .name(format!("blasx-dev-{dev}"))
                    .spawn(move || device_worker(inner, dev))
                    .expect("spawn device worker")
            })
            .collect();
        Runtime { inner, handles }
    }

    pub fn n_devices(&self) -> usize {
        self.inner.n_devices
    }

    pub fn arena_bytes(&self) -> usize {
        self.inner.arena_bytes
    }

    /// Calls served since boot.
    pub fn calls(&self) -> usize {
        self.inner.calls.load(Ordering::Relaxed)
    }

    /// Open a new invalidation generation for `[lo, hi)`: tiles cached
    /// from host bytes in that range become unreachable. The public
    /// doorway is [`crate::api::Context::invalidate_host`].
    pub fn invalidate_bytes(&self, lo: usize, hi: usize) {
        self.inner.epochs.lock().unwrap_or_else(|e| e.into_inner()).bump(lo, hi);
    }

    /// Execute a task set over the resident engine; parks the caller
    /// until the job completes. See the module docs for the coherence
    /// contract.
    pub(crate) fn submit<T: Scalar>(
        &self,
        cfg: &RunConfig,
        ts: &TaskSet,
        problems: Vec<Mats<'_, T>>,
    ) -> Result<RealReport> {
        // Precondition check BEFORE taking the submit lock: panicking
        // while holding it would poison the mutex and brick every
        // Context clone with PoisonError instead of this diagnostic.
        assert!(
            self.inner.arena_bytes >= 8 * block_bytes::<T>(cfg.t),
            "arena must hold at least 8 tiles (working set of a round)"
        );
        let _call = self.inner.submit_mx.lock().unwrap_or_else(|e| e.into_inner());
        // Tile-size switch: block geometry changed, cached tiles of the
        // old size must not be reachable at the new one.
        {
            let mut last = self.inner.last_t.lock().unwrap_or_else(|e| e.into_inner());
            if *last != Some(cfg.t) {
                if last.is_some() {
                    self.inner.core.purge();
                }
                *last = Some(cfg.t);
            }
        }
        // Stamp invalidation epochs: inputs resolve against the current
        // generation map, then every output range opens a fresh one (so
        // this call's C tiles can never collide with a stale cached
        // copy, and the *next* call reading this buffer sees new keys).
        {
            let mut reg = self.inner.epochs.lock().unwrap_or_else(|e| e.into_inner());
            for m in &problems {
                for hm in [Some(m.a), m.b].into_iter().flatten() {
                    let (lo, hi) = hm.byte_range();
                    hm.set_epoch(reg.epoch_of(lo, hi));
                }
            }
            for m in &problems {
                let (lo, hi) = m.c.byte_range();
                m.c.set_epoch(reg.bump(lo, hi));
            }
        }

        let state = JobState::new(cfg, ts, problems, self.inner.n_devices)?;
        // SAFETY: the lifetime is erased only for the trait object's
        // benefit. Every borrow inside `state` (task set, operand
        // wraps) outlives this function call, and this function does
        // not return until `left` reaches zero — which each worker
        // signals only *after* dropping its clone of the job Arc (the
        // decrement happens-after the drop, both under `done_mx`). The
        // slot's clone is cleared below before the state is reclaimed,
        // so no reference to the borrowed data survives the call.
        let state = unsafe {
            std::mem::transmute::<JobState<'_, T>, JobState<'static, T>>(state)
        };
        let job: Arc<ErasedJob<T>> = Arc::new(ErasedJob { state });
        let left = Arc::new(AtomicUsize::new(self.inner.n_devices));
        {
            let mut s = self.inner.slot.lock().unwrap_or_else(|e| e.into_inner());
            s.seq += 1;
            s.job = Some(job.clone() as Arc<dyn DeviceJob>);
            s.left = left.clone();
            self.inner.slot_cv.notify_all();
        }
        {
            let mut g = self.inner.done_mx.lock().unwrap_or_else(|e| e.into_inner());
            while left.load(Ordering::SeqCst) != 0 {
                g = self.inner.done_cv.wait(g).unwrap();
            }
        }
        {
            let mut s = self.inner.slot.lock().unwrap_or_else(|e| e.into_inner());
            s.job = None;
        }
        let job = Arc::try_unwrap(job)
            .unwrap_or_else(|_| unreachable!("job still shared after completion"));
        self.inner.calls.fetch_add(1, Ordering::Relaxed);
        let report = job.state.into_report(&self.inner.core);
        if report.is_err() {
            // The abort path may leave readers pinned; start the next
            // call on a clean cache rather than leak arena space.
            self.inner.core.purge();
        }
        report
    }
}

impl Drop for Runtime {
    fn drop(&mut self) {
        self.inner.shutdown.store(true, Ordering::SeqCst);
        {
            let _s = self.inner.slot.lock().unwrap_or_else(|e| e.into_inner());
            self.inner.slot_cv.notify_all();
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

fn device_worker(inner: Arc<Inner>, dev: usize) {
    let mut last_seq = 0u64;
    loop {
        let (job, left) = {
            let mut s = inner.slot.lock().unwrap_or_else(|e| e.into_inner());
            loop {
                if inner.shutdown.load(Ordering::SeqCst) {
                    return;
                }
                if s.seq > last_seq {
                    if let Some(job) = &s.job {
                        last_seq = s.seq;
                        break (job.clone(), s.left.clone());
                    }
                }
                s = inner.slot_cv.wait(s).unwrap();
            }
        };
        // Contain panics (a poisoned kernel must not kill the resident
        // worker — the job is failed and the fleet stays serviceable).
        if catch_unwind(AssertUnwindSafe(|| job.run_device(dev, &inner.core))).is_err() {
            job.poison(format!("device worker {dev} panicked"));
        }
        // Drop our job handle BEFORE signalling: `submit` reclaims the
        // job (and the borrowed operands inside) once `left` hits zero.
        drop(job);
        let _g = inner.done_mx.lock().unwrap_or_else(|e| e.into_inner());
        if left.fetch_sub(1, Ordering::SeqCst) == 1 {
            inner.done_cv.notify_all();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn epoch_registry_bumps_and_resolves() {
        let mut r = EpochRegistry::default();
        assert_eq!(r.epoch_of(0, 100), 0);
        let e1 = r.bump(100, 200);
        assert_eq!(r.epoch_of(150, 160), e1);
        assert_eq!(r.epoch_of(0, 100), 0, "adjacent, non-overlapping");
        assert_eq!(r.epoch_of(199, 300), e1, "partial overlap counts");
        let e2 = r.bump(150, 180);
        assert_eq!(r.epoch_of(150, 160), e2);
        assert_eq!(r.epoch_of(100, 110), e1, "older range still visible outside the new one");
        assert!(e2 > e1);
    }

    #[test]
    fn epoch_registry_compacts_covered_ranges() {
        let mut r = EpochRegistry::default();
        for _ in 0..50 {
            r.bump(1000, 2000); // same output rewritten every call
        }
        assert_eq!(r.ranges.len(), 1, "covered ranges compact away");
        r.bump(0, 10_000); // superset swallows it
        assert_eq!(r.ranges.len(), 1);
    }

    #[test]
    fn boot_and_drop_join_cleanly() {
        let rt = Runtime::boot(3, 1 << 20, AllocStrategy::FastHeap);
        assert_eq!(rt.n_devices(), 3);
        assert_eq!(rt.calls(), 0);
        drop(rt); // must not hang
    }
}
