//! Persistent kernel thread pool for intra-tile parallelism.
//!
//! `hostblas::gemm_mt` used to fork fresh scoped threads per call,
//! which meant every cell started with an empty thread-local
//! [`crate::hostblas::pack::PackBuf`] — the zero-allocation guarantee
//! of the packed engine never engaged on the forked path (PR 2 open
//! item). [`KernelPool`] keeps a process-wide set of long-lived worker
//! threads instead: cells submitted by any caller run on threads whose
//! pack scratch and free-list thread-locals survive across kernel
//! invocations, so steady-state multithreaded GEMM allocates nothing.
//!
//! The pool is deliberately simple — a mutex-guarded injector deque
//! plus a condvar — because cells are coarse (a cell is a whole packed
//! GEMM over a C sub-block, milliseconds of work): queue overhead is
//! noise. Threads spawn lazily up to the largest parallelism any
//! caller has requested (capped at [`MAX_POOL_THREADS`]) and park on
//! the condvar when idle; the pool lives for the process (there is no
//! teardown — idle parked threads cost nothing).
//!
//! ## Scoped submission
//!
//! [`KernelPool::run`] accepts non-`'static` closures: the borrow is
//! sound because `run` does not return until every submitted cell has
//! finished executing (a per-group completion count, observed under
//! the group's mutex). The submitting thread participates — it
//! executes its own group's queued cells while it waits — so a group
//! always completes even if every pool thread is busy elsewhere, and a
//! `threads`-way `gemm_mt` needs only `threads - 1` pool workers.

use std::collections::VecDeque;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};

/// Upper bound on pool threads — far above any sensible
/// `worker_threads` setting; a runaway-request backstop, not a tuning
/// knob.
pub const MAX_POOL_THREADS: usize = 64;

type Cell = Box<dyn FnOnce() + Send + 'static>;

/// Completion tracking for one `run` call's batch of cells.
struct Group {
    outstanding: AtomicUsize,
    panicked: AtomicBool,
    mx: Mutex<()>,
    cv: Condvar,
}

struct Injector {
    jobs: VecDeque<(Arc<Group>, Cell)>,
}

/// The process-wide persistent kernel pool (see module docs).
pub struct KernelPool {
    mx: Mutex<Injector>,
    cv: Condvar,
    /// Threads spawned so far (grow-only, under this lock).
    started: Mutex<usize>,
}

static POOL: OnceLock<KernelPool> = OnceLock::new();

impl KernelPool {
    /// The process-wide pool instance.
    pub fn global() -> &'static KernelPool {
        POOL.get_or_init(|| KernelPool {
            mx: Mutex::new(Injector { jobs: VecDeque::new() }),
            cv: Condvar::new(),
            started: Mutex::new(0),
        })
    }

    /// Number of live pool threads (observability / tests).
    pub fn threads(&self) -> usize {
        *self.started.lock().unwrap()
    }

    /// Grow the pool to at least `want` threads (capped).
    pub fn ensure_threads(&'static self, want: usize) {
        let want = want.min(MAX_POOL_THREADS);
        let mut started = self.started.lock().unwrap();
        while *started < want {
            let name = format!("blasx-kern-{}", *started);
            std::thread::Builder::new()
                .name(name)
                .spawn(move || self.worker())
                .expect("spawn kernel pool thread");
            *started += 1;
        }
    }

    fn worker(&'static self) {
        loop {
            let (group, cell) = {
                let mut q = self.mx.lock().unwrap();
                loop {
                    if let Some(j) = q.jobs.pop_front() {
                        break j;
                    }
                    q = self.cv.wait(q).unwrap();
                }
            };
            run_cell(&group, cell);
        }
    }

    /// Execute every closure, in parallel across the pool plus the
    /// calling thread, returning when all have finished. Panics in a
    /// cell are propagated to the caller after the whole group
    /// completes (scoped-thread semantics).
    pub fn run<'s>(&'static self, cells: Vec<Box<dyn FnOnce() + Send + 's>>) {
        let n = cells.len();
        if n == 0 {
            return;
        }
        self.ensure_threads(n - 1);
        let group = Arc::new(Group {
            outstanding: AtomicUsize::new(n),
            panicked: AtomicBool::new(false),
            mx: Mutex::new(()),
            cv: Condvar::new(),
        });
        {
            let mut q = self.mx.lock().unwrap();
            for cell in cells {
                // SAFETY: the closure is executed (and dropped) before
                // `run` returns — the completion wait below does not
                // pass until `outstanding` reaches zero, and a cell is
                // only counted down after it has finished running. No
                // borrow inside the closure outlives this call.
                let cell: Cell = unsafe {
                    std::mem::transmute::<Box<dyn FnOnce() + Send + 's>, Cell>(cell)
                };
                q.jobs.push_back((group.clone(), cell));
            }
            self.cv.notify_all();
        }
        // Help: drain our own group's cells while pool threads chew.
        loop {
            let mine = {
                let mut q = self.mx.lock().unwrap();
                match q.jobs.iter().position(|(g, _)| Arc::ptr_eq(g, &group)) {
                    Some(pos) => q.jobs.remove(pos),
                    None => None,
                }
            };
            match mine {
                Some((g, cell)) => run_cell(&g, cell),
                None => break,
            }
        }
        // Wait for cells stolen by pool threads.
        let mut g = group.mx.lock().unwrap();
        while group.outstanding.load(Ordering::SeqCst) != 0 {
            g = group.cv.wait(g).unwrap();
        }
        drop(g);
        if group.panicked.load(Ordering::SeqCst) {
            panic!("kernel pool cell panicked");
        }
    }
}

fn run_cell(group: &Group, cell: Cell) {
    if catch_unwind(AssertUnwindSafe(cell)).is_err() {
        group.panicked.store(true, Ordering::SeqCst);
    }
    // Count down under the group lock so the submitter's completion
    // wait cannot miss the final notify.
    let _g = group.mx.lock().unwrap();
    if group.outstanding.fetch_sub(1, Ordering::SeqCst) == 1 {
        group.cv.notify_all();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn runs_all_cells_and_waits() {
        let sum = AtomicU64::new(0);
        let cells: Vec<Box<dyn FnOnce() + Send + '_>> = (1..=32u64)
            .map(|i| {
                let sum = &sum;
                Box::new(move || {
                    sum.fetch_add(i, Ordering::SeqCst);
                }) as Box<dyn FnOnce() + Send + '_>
            })
            .collect();
        KernelPool::global().run(cells);
        assert_eq!(sum.load(Ordering::SeqCst), 32 * 33 / 2);
    }

    #[test]
    fn borrows_local_state_safely() {
        // Non-'static borrows: the scoped contract in action.
        let mut out = vec![0usize; 64];
        {
            let cells: Vec<Box<dyn FnOnce() + Send + '_>> = out
                .chunks_mut(16)
                .enumerate()
                .map(|(i, chunk)| {
                    Box::new(move || {
                        for (j, x) in chunk.iter_mut().enumerate() {
                            *x = i * 100 + j;
                        }
                    }) as Box<dyn FnOnce() + Send + '_>
                })
                .collect();
            KernelPool::global().run(cells);
        }
        for (i, &x) in out.iter().enumerate() {
            assert_eq!(x, (i / 16) * 100 + i % 16);
        }
    }

    #[test]
    fn threads_grow_monotonically_and_cap() {
        let pool = KernelPool::global();
        pool.ensure_threads(3);
        assert!(pool.threads() >= 3);
        let before = pool.threads();
        pool.ensure_threads(1); // never shrinks
        assert_eq!(pool.threads(), before);
        pool.ensure_threads(MAX_POOL_THREADS + 50);
        assert!(pool.threads() <= MAX_POOL_THREADS);
    }

    #[test]
    fn empty_group_is_a_noop() {
        KernelPool::global().run(Vec::new());
    }

    #[test]
    fn concurrent_groups_complete_independently() {
        std::thread::scope(|scope| {
            for _ in 0..4 {
                scope.spawn(|| {
                    let count = AtomicU64::new(0);
                    let cells: Vec<Box<dyn FnOnce() + Send + '_>> = (0..16)
                        .map(|_| {
                            let count = &count;
                            Box::new(move || {
                                count.fetch_add(1, Ordering::SeqCst);
                            })
                                as Box<dyn FnOnce() + Send + '_>
                        })
                        .collect();
                    KernelPool::global().run(cells);
                    assert_eq!(count.load(Ordering::SeqCst), 16);
                });
            }
        });
    }
}
