//! Benchmark-harness support (system S18): result tables, JSON export,
//! and the shared run grids used by the per-figure bench binaries in
//! `benches/`.
//!
//! criterion is unavailable offline, so the binaries are `harness =
//! false` mains built on these helpers. Every bench prints the paper's
//! rows to stdout AND writes machine-readable JSON under `bench_out/`.

use crate::util::json::Json;
use std::io::Write;
use std::path::PathBuf;

/// Pretty-print a table: header + rows of equal arity.
pub fn print_table(title: &str, header: &[&str], rows: &[Vec<String>]) {
    println!("\n== {title} ==");
    let mut widths: Vec<usize> = header.iter().map(|h| h.len()).collect();
    for r in rows {
        for (i, cell) in r.iter().enumerate() {
            widths[i] = widths[i].max(cell.len());
        }
    }
    let line = |cells: &[String]| {
        let mut s = String::new();
        for (i, c) in cells.iter().enumerate() {
            s.push_str(&format!("{:>w$}  ", c, w = widths[i]));
        }
        s
    };
    let hdr: Vec<String> = header.iter().map(|s| s.to_string()).collect();
    println!("{}", line(&hdr));
    println!("{}", "-".repeat(widths.iter().sum::<usize>() + 2 * widths.len()));
    for r in rows {
        println!("{}", line(r));
    }
}

/// Output directory for bench artifacts (JSON series for replotting).
pub fn out_dir() -> PathBuf {
    let d = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("bench_out");
    let _ = std::fs::create_dir_all(&d);
    d
}

/// Write a JSON value under `bench_out/<name>.json`.
pub fn write_json(name: &str, value: &Json) {
    let path = out_dir().join(format!("{name}.json"));
    match std::fs::File::create(&path) {
        Ok(mut f) => {
            let _ = f.write_all(value.to_string_pretty().as_bytes());
            println!("[bench] wrote {}", path.display());
        }
        Err(e) => crate::util::logger::warn(
            "bench",
            &format!("cannot write {}: {e}", path.display()),
        ),
    }
}

/// Is the full paper-scale grid requested? (`BLASX_BENCH_FULL=1`)
pub fn full_grid() -> bool {
    std::env::var("BLASX_BENCH_FULL").map(|v| v == "1").unwrap_or(false)
}

/// The Fig. 7 matrix-size grid: the paper sweeps 1024..39936 step 1024;
/// the default grid subsamples it to keep `cargo bench` minutes-scale.
pub fn size_grid() -> Vec<usize> {
    if full_grid() {
        (1..=39).map(|i| i * 1024).collect()
    } else {
        vec![2048, 6144, 10240, 14336, 16384, 20480, 24576, 30720]
    }
}

/// Format a GFLOPS value or N/A.
pub fn fmt_gf(feasible: bool, gf: f64) -> String {
    if feasible {
        format!("{gf:.0}")
    } else {
        "N/A".to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grids() {
        assert!(!size_grid().is_empty());
        assert!(size_grid().windows(2).all(|w| w[0] < w[1]));
        assert_eq!(fmt_gf(false, 123.0), "N/A");
        assert_eq!(fmt_gf(true, 123.4), "123");
    }
}
