//! `blasx_*` exports: non-blocking submission, waits, and runtime
//! control for C callers.
//!
//! `blasx_{s,d}gemm_async` / `blasx_{s,d}trsm_async` admit a job to
//! the resident multi-tenant runtime and return an opaque
//! `blasx_job_t*` immediately; `blasx_wait` parks until the job
//! retires, frees the handle, and returns a status code. Jobs whose
//! operand byte ranges alias an in-flight job's are ordered by the
//! admission table (RAW/WAR/WAW edges), so a chain like
//!
//! ```c
//! blasx_job_t *j1 = blasx_dgemm_async(..., C, ldc);        /* C := A·B   */
//! blasx_job_t *j2 = blasx_dtrsm_async(..., T, ldt, C, ldc); /* solve in C */
//! blasx_wait(j2); blasx_wait(j1);
//! ```
//!
//! is pipelined yet bit-for-bit identical to the blocking sequence.
//!
//! **Liveness contract**: every buffer passed to an `*_async` entry
//! must remain valid until `blasx_wait` returns for that job (C has no
//! borrow checker; this is the standard asynchronous-C-API contract —
//! the safe-Rust surface gets the same guarantee from
//! `Context::scope`'s close barrier instead). An unwaited job keeps
//! running; leaking its handle leaks memory but the runtime owns the
//! job's backing, so workers never touch a freed task graph.

use super::{
    default_context, diag_of, dim_of, fold_gemm_row_major, fold_sided_row_major, order_of,
    raw_operand, record_error, seed_default_context, side_of, status_of, trans_of, uplo_of,
    Order, BLASX_ERR_CONFIG, BLASX_ERR_INTERNAL, BLASX_OK,
};
use crate::api::l3::{plan_gemm, plan_trsm};
use crate::api::types::Scalar;
use crate::api::Context;
use crate::coordinator::real_engine::OwnedProblem;
use crate::error::{illegal, Error, Result};
use crate::fault::FaultPlan;
use crate::runtime::Runtime;
use crate::serve::admission::JobCtl;
use crate::serve::DeviceJob;
use crate::task::{taskize_gemm, taskize_trsm, GemmDesc, TriDesc};
use crate::tile::{HostMat, MatId};
use core::ffi::{c_char, c_int, c_void};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::Arc;

/// Opaque in-flight job handle handed across the ABI (`blasx_job_t`).
/// Holds the runtime alive until waited or leaked.
pub struct BlasxJob {
    rt: Arc<Runtime>,
    job: Arc<dyn DeviceJob>,
    ctl: Arc<JobCtl>,
}

/// Explicit library configuration (`blasx_config_t`): the programmatic
/// twin of the `BLASX_*` environment knobs, consumed by `blasx_init`.
/// A zero-initialized struct means "all defaults": every numeric field
/// treats `<= 0` (or `0` for `deadline_ms`) as "use the default", so
/// `blasx_config_t cfg = {0};` followed by setting just the fields of
/// interest is the intended idiom.
#[repr(C)]
#[derive(Clone, Copy, Debug)]
pub struct BlasxConfigC {
    /// Devices to run on (`<= 0`: default).
    pub devices: c_int,
    /// Square tile edge (`<= 0`: default).
    pub tile: c_int,
    /// Per-device arena size in MiB (`<= 0`: default).
    pub arena_mb: c_int,
    /// Kernel threads per device worker (`<= 0`: default).
    pub kernel_threads: c_int,
    /// Nonzero: disable the resident runtime (one-shot engine per
    /// call; async entries will refuse).
    pub one_shot: c_int,
    /// Per-job deadline in milliseconds (`0`: no deadline). Overrun
    /// jobs fail with `BLASX_ERR_DEADLINE`.
    pub deadline_ms: u64,
    /// Admission-queue capacity across all tenants (`<= 0`: default).
    /// At capacity, calls fail fast with `BLASX_ERR_BACKPRESSURE`.
    pub max_inflight: c_int,
    /// Per-tenant in-flight job quota (`<= 0`: default).
    pub tenant_quota: c_int,
    /// Lookahead prefetch depth: tiles each device worker stages ahead
    /// of demand (`<= 0`: default — `BLASX_PREFETCH_DEPTH`, else off).
    pub prefetch: c_int,
    /// Fault-injection schedule in the `BLASX_FAULTS` grammar
    /// (NUL-terminated; NULL or empty: no injected faults).
    pub faults: *const c_char,
    /// Path to a `blasx tune` dispatch profile (NUL-terminated; NULL
    /// or empty: no per-shape dispatch — fixed tile size, device
    /// placement). See the "Adaptive dispatch" section of the README.
    pub profile: *const c_char,
}

/// Configure the process-global BLASX context before first use.
/// Returns `BLASX_OK`, `BLASX_ERR_PARAM` (malformed `faults` string —
/// nothing is configured), or `BLASX_ERR_CONFIG` (some BLASX entry
/// already materialized the env-driven default context; init must be
/// the first BLASX call in the process). `cfg` may be NULL to claim
/// the defaults explicitly. The struct is copied; the `faults` string
/// is parsed during the call and need not outlive it.
///
/// # Safety
/// `cfg`, when non-NULL, must point to a readable `blasx_config_t`
/// whose `faults` field is NULL or a NUL-terminated string.
#[no_mangle]
pub unsafe extern "C" fn blasx_init(cfg: *const BlasxConfigC) -> c_int {
    match catch_unwind(AssertUnwindSafe(|| init_context(cfg))) {
        Ok(Ok(ctx)) => match seed_default_context(ctx) {
            Ok(()) => BLASX_OK,
            Err(_) => {
                record_error(
                    "blasx_init",
                    &Error::Config(
                        "default context already initialized (blasx_init must be the first \
                         BLASX call)"
                            .into(),
                    ),
                );
                BLASX_ERR_CONFIG
            }
        },
        Ok(Err(e)) => {
            record_error("blasx_init", &e);
            status_of(&e)
        }
        Err(_) => {
            record_error("blasx_init", &Error::Internal("panic contained at the C ABI".into()));
            BLASX_ERR_INTERNAL
        }
    }
}

/// Build a [`Context`] from a C config (NULL = defaults).
///
/// # Safety
/// See `blasx_init`.
unsafe fn init_context(cfg: *const BlasxConfigC) -> Result<Context> {
    let mut ctx = Context::default();
    if cfg.is_null() {
        return Ok(ctx);
    }
    let c = *cfg;
    if c.devices > 0 {
        ctx.n_devices = c.devices as usize;
    }
    if c.tile > 0 {
        ctx = ctx.with_tile(c.tile as usize);
    }
    if c.arena_mb > 0 {
        ctx = ctx.with_arena((c.arena_mb as usize) << 20);
    }
    if c.kernel_threads > 0 {
        ctx = ctx.with_kernel_threads(c.kernel_threads as usize);
    }
    if c.one_shot != 0 {
        ctx = ctx.with_persistent(false);
    }
    if c.deadline_ms > 0 {
        ctx = ctx.with_deadline_ms(Some(c.deadline_ms));
    }
    if c.max_inflight > 0 {
        ctx = ctx.with_admit_capacity(c.max_inflight as usize);
    }
    if c.tenant_quota > 0 {
        ctx = ctx.with_tenant_quota(c.tenant_quota as usize);
    }
    if c.prefetch > 0 {
        ctx = ctx.with_prefetch(Some(c.prefetch as usize));
    }
    if !c.faults.is_null() {
        let text = std::ffi::CStr::from_ptr(c.faults)
            .to_str()
            .map_err(|_| illegal("blasx_init", 9, "faults string is not UTF-8"))?;
        if !text.trim().is_empty() {
            let plan = FaultPlan::parse(text)
                .map_err(|e| illegal("blasx_init", 9, format!("bad faults schedule: {e}")))?;
            if !plan.specs.is_empty() {
                ctx = ctx.with_fault_plan(Some(plan));
            }
        }
    }
    if !c.profile.is_null() {
        let path = std::ffi::CStr::from_ptr(c.profile)
            .to_str()
            .map_err(|_| illegal("blasx_init", 10, "profile path is not UTF-8"))?;
        if !path.trim().is_empty() {
            // Unlike the BLASX_PROFILE env fallback (which must not
            // break legacy callers), an explicit init with a bad
            // profile is a caller error and fails loudly.
            ctx = ctx.with_profile_file(path.trim())?;
        }
    }
    Ok(ctx)
}

/// Admit an owned-problem job on the default context and box its
/// handle for C.
fn admit<T: Scalar>(
    routine: &'static str,
    ts: crate::task::TaskSet,
    problem: OwnedProblem<T>,
) -> Result<*mut BlasxJob> {
    let ctx = default_context();
    if !ctx.persistent {
        return Err(Error::Config(
            "async submission requires the persistent runtime (unset BLASX_PERSISTENT=0)".into(),
        ));
    }
    let rt = ctx.runtime();
    let mut cfg = ctx.cfg.clone();
    cfg.routine = routine;
    let (job, ctl) = rt.submit_owned(&cfg, ts, vec![problem])?;
    Ok(Box::into_raw(Box::new(BlasxJob { rt, job, ctl })))
}

/// A zero-footprint operand wrap for a degenerate (m==0 or n==0) job.
/// The blocking `cblas_*` path quick-returns on these, but an async
/// entry must still hand back a waitable handle (NULL signals error),
/// so it admits an empty task set over wraps whose pointers — NULL
/// included, exactly as the blocking path tolerates — are never read.
///
/// # Safety
/// Trivially safe to call (the pointer is stored, never dereferenced:
/// rows = cols = 0); unsafe only to mirror `raw_operand`'s contract.
unsafe fn zero_wrap<T: Scalar>(ptr: *mut T, t: usize, id: MatId) -> HostMat<T> {
    HostMat::from_raw(ptr, 0, 0, 1, t, id)
}

/// Run `f` with panics contained; null on any error.
fn async_entry(routine: &'static str, f: impl FnOnce() -> Result<*mut BlasxJob>) -> *mut BlasxJob {
    match catch_unwind(AssertUnwindSafe(f)) {
        Ok(Ok(p)) => p,
        Ok(Err(e)) => {
            record_error(routine, &e);
            std::ptr::null_mut()
        }
        Err(_) => {
            record_error(routine, &Error::Internal("panic contained at the C ABI".into()));
            std::ptr::null_mut()
        }
    }
}

#[allow(clippy::too_many_arguments)]
fn gemm_async_entry<T: Scalar>(
    routine: &'static str,
    order: c_int,
    transa: c_int,
    transb: c_int,
    m: c_int,
    n: c_int,
    k: c_int,
    alpha: T,
    a: *const T,
    lda: c_int,
    b: *const T,
    ldb: c_int,
    beta: T,
    c: *mut T,
    ldc: c_int,
) -> *mut BlasxJob {
    async_entry(routine, || {
        let order = order_of(order).ok_or_else(|| illegal(routine, 1, "bad order"))?;
        let mut ta = trans_of(transa).ok_or_else(|| illegal(routine, 2, "bad transA"))?;
        let mut tb = trans_of(transb).ok_or_else(|| illegal(routine, 3, "bad transB"))?;
        let mut m = dim_of(m).ok_or_else(|| illegal(routine, 4, "m < 0"))?;
        let mut n = dim_of(n).ok_or_else(|| illegal(routine, 5, "n < 0"))?;
        let k = dim_of(k).ok_or_else(|| illegal(routine, 6, "k < 0"))?;
        let mut lda = dim_of(lda).ok_or_else(|| illegal(routine, 9, "lda < 0"))?;
        let mut ldb = dim_of(ldb).ok_or_else(|| illegal(routine, 11, "ldb < 0"))?;
        let ldc = dim_of(ldc).ok_or_else(|| illegal(routine, 14, "ldc < 0"))?;
        let (mut a, mut b) = (a, b);
        if order == Order::RowMajor {
            fold_gemm_row_major(&mut ta, &mut tb, &mut m, &mut n, &mut lda, &mut ldb, &mut a, &mut b);
        }
        let t = default_context().tile();
        if m == 0 || n == 0 {
            // Degenerate no-op (parity with the blocking quick return):
            // empty task set, pointers never read.
            let d = GemmDesc { ta, tb, m, n, k, alpha: alpha.to_f64(), beta: beta.to_f64(), t };
            // SAFETY: zero-footprint wraps — see `zero_wrap`.
            let (am, bm, cm) = unsafe {
                (
                    zero_wrap(a as *mut T, t, MatId::A),
                    zero_wrap(b as *mut T, t, MatId::B),
                    zero_wrap(c, t, MatId::C),
                )
            };
            return admit(routine, taskize_gemm(&d), OwnedProblem { a: am, b: Some(bm), c: cm });
        }
        let (ts, dims) =
            plan_gemm(t, ta, tb, m, n, k, alpha.to_f64(), beta.to_f64(), lda, ldb, ldc)?;
        let (ar, ac) = dims.a;
        let (br, bc) = dims.b.expect("gemm has a B operand");
        // SAFETY: liveness contract (module docs) — buffers valid until
        // blasx_wait; aliasing writers ordered by admission.
        let (am, bm, cm) = unsafe {
            (
                raw_operand(routine, 8, a as *mut T, ar, ac, lda, t, MatId::A)?,
                raw_operand(routine, 10, b as *mut T, br, bc, ldb, t, MatId::B)?,
                raw_operand(routine, 13, c, m, n, ldc, t, MatId::C)?,
            )
        };
        admit(routine, ts, OwnedProblem { a: am, b: Some(bm), c: cm })
    })
}

#[allow(clippy::too_many_arguments)]
fn trsm_async_entry<T: Scalar>(
    routine: &'static str,
    order: c_int,
    side: c_int,
    uplo: c_int,
    transa: c_int,
    diag: c_int,
    m: c_int,
    n: c_int,
    alpha: T,
    a: *const T,
    lda: c_int,
    b: *mut T,
    ldb: c_int,
) -> *mut BlasxJob {
    async_entry(routine, || {
        let order = order_of(order).ok_or_else(|| illegal(routine, 1, "bad order"))?;
        let mut side = side_of(side).ok_or_else(|| illegal(routine, 2, "bad side"))?;
        let mut uplo = uplo_of(uplo).ok_or_else(|| illegal(routine, 3, "bad uplo"))?;
        let ta = trans_of(transa).ok_or_else(|| illegal(routine, 4, "bad transA"))?;
        let diag = diag_of(diag).ok_or_else(|| illegal(routine, 5, "bad diag"))?;
        let mut m = dim_of(m).ok_or_else(|| illegal(routine, 6, "m < 0"))?;
        let mut n = dim_of(n).ok_or_else(|| illegal(routine, 7, "n < 0"))?;
        let lda = dim_of(lda).ok_or_else(|| illegal(routine, 10, "lda < 0"))?;
        let ldb = dim_of(ldb).ok_or_else(|| illegal(routine, 12, "ldb < 0"))?;
        if order == Order::RowMajor {
            fold_sided_row_major(&mut side, &mut uplo, &mut m, &mut n);
        }
        let t = default_context().tile();
        if m == 0 || n == 0 {
            // Degenerate no-op — see the gemm twin above.
            let d = TriDesc { side, uplo, ta, diag, m, n, alpha: alpha.to_f64(), t };
            // SAFETY: zero-footprint wraps — see `zero_wrap`.
            let (am, cm) = unsafe {
                (zero_wrap(a as *mut T, t, MatId::A), zero_wrap(b, t, MatId::C))
            };
            return admit(routine, taskize_trsm(&d), OwnedProblem { a: am, b: None, c: cm });
        }
        let (ts, dims) = plan_trsm(t, side, uplo, ta, diag, m, n, alpha.to_f64(), lda, ldb)?;
        let (na, _) = dims.a;
        // SAFETY: liveness contract (module docs).
        let (am, cm) = unsafe {
            (
                raw_operand(routine, 9, a as *mut T, na, na, lda, t, MatId::A)?,
                raw_operand(routine, 11, b, m, n, ldb, t, MatId::C)?,
            )
        };
        admit(routine, ts, OwnedProblem { a: am, b: None, c: cm })
    })
}

/// Non-blocking double-precision GEMM; returns a `blasx_job_t*` (NULL
/// on error — see `blasx_last_error`). Pass the handle to
/// `blasx_wait`.
///
/// # Safety
/// As the blocking entries (BLAS buffer contract), plus the async
/// liveness rule: all buffers must stay valid until `blasx_wait`
/// returns for the job this call created.
#[no_mangle]
#[allow(clippy::too_many_arguments)]
pub unsafe extern "C" fn blasx_dgemm_async(
    order: c_int,
    transa: c_int,
    transb: c_int,
    m: c_int,
    n: c_int,
    k: c_int,
    alpha: f64,
    a: *const f64,
    lda: c_int,
    b: *const f64,
    ldb: c_int,
    beta: f64,
    c: *mut f64,
    ldc: c_int,
) -> *mut BlasxJob {
    gemm_async_entry(
        "blasx_dgemm_async", order, transa, transb, m, n, k, alpha, a, lda, b, ldb, beta, c, ldc,
    )
}

/// Non-blocking single-precision GEMM (see `blasx_dgemm_async`).
///
/// # Safety
/// As the blocking entries (BLAS buffer contract), plus the async
/// liveness rule: all buffers must stay valid until `blasx_wait`
/// returns for the job this call created.
#[no_mangle]
#[allow(clippy::too_many_arguments)]
pub unsafe extern "C" fn blasx_sgemm_async(
    order: c_int,
    transa: c_int,
    transb: c_int,
    m: c_int,
    n: c_int,
    k: c_int,
    alpha: f32,
    a: *const f32,
    lda: c_int,
    b: *const f32,
    ldb: c_int,
    beta: f32,
    c: *mut f32,
    ldc: c_int,
) -> *mut BlasxJob {
    gemm_async_entry(
        "blasx_sgemm_async", order, transa, transb, m, n, k, alpha, a, lda, b, ldb, beta, c, ldc,
    )
}

/// Non-blocking double-precision TRSM, X overwriting B (see
/// `blasx_dgemm_async` for the handle/liveness contract).
///
/// # Safety
/// As the blocking entries (BLAS buffer contract), plus the async
/// liveness rule: all buffers must stay valid until `blasx_wait`
/// returns for the job this call created.
#[no_mangle]
#[allow(clippy::too_many_arguments)]
pub unsafe extern "C" fn blasx_dtrsm_async(
    order: c_int,
    side: c_int,
    uplo: c_int,
    transa: c_int,
    diag: c_int,
    m: c_int,
    n: c_int,
    alpha: f64,
    a: *const f64,
    lda: c_int,
    b: *mut f64,
    ldb: c_int,
) -> *mut BlasxJob {
    trsm_async_entry("blasx_dtrsm_async", order, side, uplo, transa, diag, m, n, alpha, a, lda, b, ldb)
}

/// Non-blocking single-precision TRSM.
///
/// # Safety
/// As the blocking entries (BLAS buffer contract), plus the async
/// liveness rule: all buffers must stay valid until `blasx_wait`
/// returns for the job this call created.
#[no_mangle]
#[allow(clippy::too_many_arguments)]
pub unsafe extern "C" fn blasx_strsm_async(
    order: c_int,
    side: c_int,
    uplo: c_int,
    transa: c_int,
    diag: c_int,
    m: c_int,
    n: c_int,
    alpha: f32,
    a: *const f32,
    lda: c_int,
    b: *mut f32,
    ldb: c_int,
) -> *mut BlasxJob {
    trsm_async_entry("blasx_strsm_async", order, side, uplo, transa, diag, m, n, alpha, a, lda, b, ldb)
}

/// Park until the job retires, free its handle, and return its status
/// (0 = success; see `include/blasx.h` for the code table). Outputs
/// are fully written back when this returns 0. Passing NULL returns
/// BLASX_ERR_INTERNAL.
///
/// # Safety
/// `job` must be a pointer returned by a `blasx_*_async` entry, not
/// yet waited (each handle is freed by exactly one wait).
#[no_mangle]
pub unsafe extern "C" fn blasx_wait(job: *mut BlasxJob) -> c_int {
    if job.is_null() {
        record_error("blasx_wait", &Error::Internal("null job handle".into()));
        return BLASX_ERR_INTERNAL;
    }
    let job = Box::from_raw(job);
    match catch_unwind(AssertUnwindSafe(|| {
        job.ctl.wait_retired();
        job.job.report(job.rt.core()).map(|_| ())
    })) {
        Ok(Ok(())) => BLASX_OK,
        Ok(Err(e)) => {
            record_error("blasx_wait", &e);
            status_of(&e)
        }
        Err(_) => {
            record_error("blasx_wait", &Error::Internal("panic contained at the C ABI".into()));
            BLASX_ERR_INTERNAL
        }
    }
}

/// Has the job retired? 1 = done (wait will not block), 0 = in flight,
/// -1 = NULL handle. Does not free the handle.
///
/// # Safety
/// `job` must be a live handle from a `blasx_*_async` entry.
#[no_mangle]
pub unsafe extern "C" fn blasx_job_done(job: *const BlasxJob) -> c_int {
    if job.is_null() {
        return -1;
    }
    (*job).ctl.is_retired() as c_int
}

/// Request cooperative cancellation of an in-flight job: it is aborted
/// with `BLASX_ERR_CANCELLED` at the next round boundary (outputs are
/// never torn mid-tile) — the subsequent `blasx_wait` on the handle
/// returns that code, unless the job finished first and reports
/// normally. Idempotent; does not free the handle (the wait still
/// must run). Returns 0, or BLASX_ERR_INTERNAL for a NULL handle.
///
/// # Safety
/// `job` must be a live handle from a `blasx_*_async` entry (not yet
/// waited).
#[no_mangle]
pub unsafe extern "C" fn blasx_job_cancel(job: *const BlasxJob) -> c_int {
    if job.is_null() {
        record_error("blasx_job_cancel", &Error::Internal("null job handle".into()));
        return BLASX_ERR_INTERNAL;
    }
    (*job).ctl.request_cancel();
    (*job).rt.core().notify_work();
    BLASX_OK
}

/// Observability counters of one job (`struct blasx_stats`), the
/// numbers `blasx_wait` discards with the report: scheduler tasks
/// executed, host→device tile reads per operand, device→device peer
/// copies, L1 tile-cache hits, and tasks obtained by work stealing.
#[repr(C)]
#[derive(Clone, Copy, Debug, Default)]
pub struct BlasxStatsC {
    /// Scheduler tasks executed so far.
    pub tasks: u64,
    /// Host→device tile reads of operand A.
    pub host_reads_a: u64,
    /// Host→device tile reads of operand B.
    pub host_reads_b: u64,
    /// Host→device tile reads of operand C.
    pub host_reads_c: u64,
    /// Device→device (peer) tile copies.
    pub peer_copies: u64,
    /// L1 tile-cache hits (reads served without any transfer).
    pub l1_hits: u64,
    /// Tasks obtained by work stealing.
    pub steals: u64,
    /// Operations retried after transient injected/hardware faults.
    pub retried: u64,
    /// Operands served through the host-path OOM degradation ladder.
    pub degraded: u64,
    /// Tasks migrated off devices lost mid-job.
    pub migrated: u64,
    /// Demand acquires served from a tile staged by lookahead prefetch
    /// (the transfer happened early, off the critical path).
    pub prefetch_hits: u64,
    /// Prefetched tiles dropped unconsumed (TTL expiry, invalidation,
    /// or memory-pressure flush).
    pub prefetch_wasted: u64,
}

/// Snapshot the job's live observability counters into `*out`.
/// Non-blocking and valid while the job is in flight — counters are
/// monotone, so polling draws the job's transfer/locality profile over
/// time. Returns 0 on success, BLASX_ERR_INTERNAL on a NULL argument.
/// Does not free the handle (the handle stays waitable).
///
/// # Safety
/// `job` must be a live handle from a `blasx_*_async` entry (not yet
/// waited); `out` must point to a writable `struct blasx_stats`.
#[no_mangle]
pub unsafe extern "C" fn blasx_job_stats(job: *const BlasxJob, out: *mut BlasxStatsC) -> c_int {
    if job.is_null() || out.is_null() {
        record_error("blasx_job_stats", &Error::Internal("null argument".into()));
        return BLASX_ERR_INTERNAL;
    }
    let s = (*job).job.stats();
    let f = (*job).job.fault_stats();
    *out = BlasxStatsC {
        tasks: s.tasks as u64,
        host_reads_a: s.host_reads[0] as u64,
        host_reads_b: s.host_reads[1] as u64,
        host_reads_c: s.host_reads[2] as u64,
        peer_copies: s.peer_copies as u64,
        l1_hits: s.l1_hits as u64,
        steals: s.steals as u64,
        retried: f.retried as u64,
        degraded: f.degraded as u64,
        migrated: f.migrated as u64,
        prefetch_hits: s.prefetch_hits as u64,
        prefetch_wasted: s.prefetch_wasted as u64,
    };
    BLASX_OK
}

/// Render the library's live telemetry gauges in Prometheus text
/// exposition format (the same body `blasx serve --telemetry-addr`
/// serves at `/metrics`), copy the NUL-terminated text into `buf`, and
/// return the full text length (excluding the NUL) — call with NULL/0
/// to size a buffer. A cold (never-used) library renders the
/// `blasx_up 0` stub without booting the runtime.
///
/// # Safety
/// `buf` must point to `cap` writable bytes (or be NULL with cap 0 to
/// query the length).
#[no_mangle]
pub unsafe extern "C" fn blasx_telemetry_text(buf: *mut c_char, cap: usize) -> usize {
    let text = catch_unwind(AssertUnwindSafe(|| default_context().render_prometheus()))
        .unwrap_or_default();
    let bytes = text.as_bytes();
    if !buf.is_null() && cap > 0 {
        let n = bytes.len().min(cap - 1);
        std::ptr::copy_nonoverlapping(bytes.as_ptr() as *const c_char, buf, n);
        *buf.add(n) = 0;
    }
    bytes.len()
}

/// Dump the flight recorder's event ring (the black box: last ~256
/// admissions/faults/migrations per device) into directory `dir` as an
/// incident report — a structured JSON file plus a Chrome trace —
/// with reason `"manual"`. Returns 0 on success, BLASX_ERR_CONFIG when
/// the runtime has not booted (nothing recorded yet), BLASX_ERR_INTERNAL
/// on an I/O failure (see `blasx_last_error`).
///
/// # Safety
/// `dir` must be a NUL-terminated path string.
#[no_mangle]
pub unsafe extern "C" fn blasx_flight_dump(dir: *const c_char) -> c_int {
    if dir.is_null() {
        record_error("blasx_flight_dump", &Error::Internal("null dir".into()));
        return BLASX_ERR_INTERNAL;
    }
    let Ok(path) = std::ffi::CStr::from_ptr(dir).to_str() else {
        record_error("blasx_flight_dump", &Error::Config("dir is not UTF-8".into()));
        return BLASX_ERR_CONFIG;
    };
    match catch_unwind(AssertUnwindSafe(|| {
        default_context().flight_dump(std::path::Path::new(path))
    })) {
        Ok(Some(Ok(_))) => BLASX_OK,
        Ok(Some(Err(e))) => {
            record_error(
                "blasx_flight_dump",
                &Error::Internal(format!("cannot write incident report: {e}")),
            );
            BLASX_ERR_INTERNAL
        }
        Ok(None) => {
            record_error(
                "blasx_flight_dump",
                &Error::Config("runtime not booted; nothing recorded".into()),
            );
            BLASX_ERR_CONFIG
        }
        Err(_) => {
            record_error(
                "blasx_flight_dump",
                &Error::Internal("panic contained at the C ABI".into()),
            );
            BLASX_ERR_INTERNAL
        }
    }
}

/// Declare that `bytes` bytes at `ptr` were mutated (or freed and
/// reallocated) by the caller since a previous call read them: cached
/// tiles of that range are invalidated. Outputs never need this —
/// every call re-epochs its output range automatically.
///
/// # Safety
/// `ptr` is only used as an address (never dereferenced); any value is
/// safe.
#[no_mangle]
pub unsafe extern "C" fn blasx_invalidate_host(ptr: *const c_void, bytes: usize) {
    let lo = ptr as usize;
    if let Some(rt) = default_context().runtime_if_booted() {
        rt.invalidate_bytes(lo, lo.saturating_add(bytes));
    }
}

/// Shut the default context's resident runtime down (it reboots
/// lazily on the next call). Call after the last outstanding
/// `blasx_wait` if the host application wants the worker threads gone.
#[no_mangle]
pub extern "C" fn blasx_shutdown() {
    let _ = catch_unwind(AssertUnwindSafe(|| default_context().shutdown_runtime()));
}

/// Copy the calling thread's last BLASX error message (NUL-terminated)
/// into `buf` and return the full message length (excluding the NUL).
/// A return of 0 means no error has been recorded on this thread.
///
/// # Safety
/// `buf` must point to `cap` writable bytes (or be NULL with cap 0 to
/// query the length).
#[no_mangle]
pub unsafe extern "C" fn blasx_last_error(buf: *mut c_char, cap: usize) -> usize {
    let msg = super::last_error_message();
    let bytes = msg.as_bytes();
    if !buf.is_null() && cap > 0 {
        let n = bytes.len().min(cap - 1);
        std::ptr::copy_nonoverlapping(bytes.as_ptr() as *const c_char, buf, n);
        *buf.add(n) = 0;
    }
    bytes.len()
}

/// Library identification string (static storage).
#[no_mangle]
pub extern "C" fn blasx_version() -> *const c_char {
    // Static NUL-terminated literal: always valid to hand out.
    concat!("blasx ", env!("CARGO_PKG_VERSION"), "\0").as_ptr() as *const c_char
}
